//! Regenerate every table and figure from the paper's evaluation section
//! in one run (also available piecewise via `cargo bench` or
//! `layerkv experiment <id>`).
//!
//! ```sh
//! cargo run --release --example paper_experiments            # full sweep
//! LAYERKV_QUICK=1 cargo run --release --example paper_experiments
//! cargo run --release --example paper_experiments fig4 fig8  # subset
//! ```

use layerkv::experiments as exp;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let all = ["table1", "fig1", "fig4", "fig5", "fig6", "fig7", "fig8"];
    let which: Vec<&str> = if args.is_empty() {
        all.to_vec()
    } else {
        args.iter().map(String::as_str).collect()
    };
    for id in which {
        let t0 = std::time::Instant::now();
        match id {
            "table1" => exp::print_table1(),
            "fig1" => exp::print_fig1(&exp::fig1()),
            "fig4" => exp::print_fig4(&exp::fig4()),
            "fig5" => exp::print_fig5(&exp::fig5()),
            "fig6" => exp::print_fig6(&exp::fig6_7()),
            "fig7" => exp::print_fig7(&exp::fig6_7()),
            "fig8" => exp::print_fig8(&exp::fig8()),
            other => eprintln!("unknown experiment '{other}' (choose from {all:?})"),
        }
        eprintln!("[{id} done in {:.1}s]", t0.elapsed().as_secs_f64());
    }
}
