//! Quickstart: load the AOT artifacts, serve three prompts through the
//! real PJRT path with layer-wise KV management, print tokens + latency.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! The tiny model is byte-level (vocab 256): prompts are just bytes. The
//! weights are random, so the "text" is gibberish — the point is that the
//! whole three-layer stack (Pallas kernels -> JAX model -> HLO -> PJRT ->
//! rust coordinator) runs end-to-end with Python nowhere on the path.

use layerkv::config::Policy;
use layerkv::runtime::{artifacts, RealEngine, RealEngineConfig, ServeRequest};

fn main() -> anyhow::Result<()> {
    let dir = artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts not found at {} — run `make artifacts` first", dir.display());
    }
    println!("loading + compiling artifacts from {} ...", dir.display());
    let mut engine = RealEngine::load(
        &dir,
        RealEngineConfig {
            device_kv_budget: 256 << 10, // 256 KiB: tight, so offloading engages
            policy: Policy::LayerKv { slo_aware: true },
            max_batch: 8,
            ..Default::default()
        },
    )?;

    let prompts: Vec<&[u8]> = vec![
        b"Attention is all you need",
        b"layer-wise KV cache management",
        b"hello world",
    ];
    let jobs: Vec<ServeRequest> = prompts
        .iter()
        .enumerate()
        .map(|(id, p)| ServeRequest {
            id,
            prompt: p.iter().map(|&b| b as i32).collect(),
            max_new_tokens: 12,
            arrival_s: 0.0,
        })
        .collect();

    let out = engine.serve(jobs)?;
    for (id, why) in &out.dropped {
        println!("req {id}: rejected — {why}");
    }
    let report = &out.report;
    for r in &out.results {
        println!(
            "req {}: prompt {:2} tokens -> {:2} new tokens {:?}  (TTFT {:.1} ms, TPOT {:.2} ms)",
            r.id,
            r.record.prompt_len,
            r.output.len(),
            &r.output[..r.output.len().min(8)],
            r.record.ttft() * 1e3,
            r.record.tpot() * 1e3,
        );
    }
    let kv = engine.kv_stats();
    println!(
        "\nthroughput: {:.1} tok/s | layer offloads: {} ({:.1} KiB), onloads: {}",
        report.throughput_tok_s(),
        kv.offloads,
        kv.offload_bytes as f64 / 1024.0,
        kv.onloads,
    );
    println!("quickstart OK");
    Ok(())
}
