//! End-to-end validation driver (DESIGN.md §5 "e2e"): serve a batched
//! ShareGPT-mini workload through the REAL model — Pallas-kernel HLO
//! executing under PJRT, the coordinator moving actual per-layer KV
//! tensors between the bounded device pool and the host pool — and report
//! latency/throughput. Results are recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use layerkv::config::Policy;
use layerkv::experiments::Table;
use layerkv::runtime::{artifacts, RealEngine, RealEngineConfig, ServeRequest};
use layerkv::util::Rng;

fn workload(n: usize, seed: u64, max_prompt: usize, rate: f64) -> Vec<ServeRequest> {
    // ShareGPT-shaped mini trace scaled to the tiny model's 256-token
    // window: log-normal prompt/output mix, Poisson arrivals.
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|id| {
            t += rng.exponential(rate);
            let prompt_len = (rng.lognormal(3.2, 0.8) as usize).clamp(4, max_prompt);
            let out = (rng.lognormal(2.8, 0.7) as usize).clamp(4, 48);
            ServeRequest {
                id,
                prompt: (0..prompt_len).map(|i| ((id * 31 + i * 7) % 256) as i32).collect(),
                max_new_tokens: out,
                arrival_s: t,
            }
        })
        .collect()
}

fn run(policy: Policy, budget: usize, jobs: Vec<ServeRequest>) -> anyhow::Result<(String, f64, f64, f64, f64, u64)> {
    let dir = artifacts::default_dir();
    let mut engine = RealEngine::load(
        &dir,
        RealEngineConfig { device_kv_budget: budget, policy, max_batch: 8, ..Default::default() },
    )?;
    let out = engine.serve(jobs)?;
    let report = out.report;
    let mut ttft = report.ttft();
    let mut tpot = report.tpot();
    Ok((
        policy.name().to_string(),
        ttft.mean() * 1e3,
        ttft.p99() * 1e3,
        tpot.mean() * 1e3,
        report.throughput_tok_s(),
        engine.kv_stats().offload_bytes,
    ))
}

fn main() -> anyhow::Result<()> {
    let dir = artifacts::default_dir();
    if !dir.join("manifest.json").exists() {
        anyhow::bail!("artifacts not found at {} — run `make artifacts` first", dir.display());
    }
    let n = 32;
    println!("serving {n} ShareGPT-mini requests through the PJRT tiny model ...");

    // A device-KV budget tight enough that request-wise (vLLM) admission
    // head-of-line blocks, while layer-wise admission sails through — the
    // paper's Fig. 2 scenario on real tensors.
    let budget = 128 << 10;

    let mut t = Table::new(
        "End-to-end real-model serving (tiny GQA transformer, CPU PJRT)",
        &["policy", "TTFT mean(ms)", "TTFT p99(ms)", "TPOT mean(ms)", "tok/s", "offload KiB"],
    );
    for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
        let jobs = workload(n, 99, 224, 8.0);
        let (name, ttft, p99, tpot, tput, off) = run(policy, budget, jobs)?;
        t.row(&[
            name,
            format!("{ttft:.1}"),
            format!("{p99:.1}"),
            format!("{tpot:.2}"),
            format!("{tput:.1}"),
            format!("{:.0}", off as f64 / 1024.0),
        ]);
    }
    t.print();
    println!("\nserve_e2e OK — all three layers composed on a real workload");
    Ok(())
}
