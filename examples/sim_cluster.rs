//! Cluster-scale scenario: Llama-3.1-70B on 4x L20 (the paper's biggest
//! setup) under a bursty long-context workload — LayerKV vs vLLM, with the
//! engine's internal counters exposed (preemptions, offload traffic,
//! streaming stalls).
//!
//! ```sh
//! cargo run --release --example sim_cluster
//! ```

use layerkv::config::Policy;
use layerkv::coordinator::run_trace;
use layerkv::experiments::Table;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;
use layerkv::workload::Trace;

fn mixed_trace(seed: u64) -> Trace {
    // 60 ShareGPT-like chat requests + 25 long-document requests (12k):
    // the long documents make the run KV-bound (the regime the paper
    // targets), not merely prefill-compute-bound.
    let mut rng = Rng::new(seed);
    let mut chat = ShareGptWorkload::paper(1.5, 60).generate(&mut rng);
    let docs = FixedWorkload {
        prompt_len: 12288,
        output_len: 192,
        n_requests: 15,
        arrivals: Arrivals::Poisson { rate: 0.3 },
    }
    .generate(&mut rng);
    for (i, mut d) in docs.requests.into_iter().enumerate() {
        d.id = 60 + i;
        chat.requests.push(d);
    }
    chat.requests.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    for (i, r) in chat.requests.iter_mut().enumerate() {
        r.id = i;
    }
    chat
}

fn main() {
    let trace = mixed_trace(42);
    println!(
        "mixed workload: {} requests, {} total tokens, max prompt {}",
        trace.len(),
        trace.total_tokens(),
        trace.max_prompt_len()
    );

    let mut t = Table::new(
        "Llama-3.1-70B, TP4 on L20s — chat + long-document mix",
        &[
            "policy",
            "TTFT mean(s)",
            "TTFT p99(s)",
            "TPOT mean(s)",
            "tok/s",
            "preempts",
            "offload GB",
            "stream stalls(s)",
        ],
    );
    for policy in
        [Policy::Vllm, Policy::LayerKv { slo_aware: true }, Policy::LayerKv { slo_aware: false }]
    {
        let cfg = layerkv::config::ServingConfig::llama31_70b_tp4().with_policy(policy);
        let (rep, stats) = run_trace(cfg, &trace, 0.8);
        let mut ttft = rep.ttft();
        t.row(&[
            policy.name().to_string(),
            format!("{:.2}", ttft.mean()),
            format!("{:.2}", ttft.p99()),
            format!("{:.4}", rep.tpot().mean()),
            format!("{:.1}", rep.throughput_tok_s()),
            stats.preemptions.to_string(),
            format!("{:.2}", stats.offload_bytes / 1e9),
            format!("{:.2}", stats.stream_stall_s),
        ]);
    }
    t.print();
    println!("\nsim_cluster OK");
}
