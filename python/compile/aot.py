"""AOT build: lower the L2 model (with its L1 Pallas kernels) to HLO *text*
artifacts the rust runtime loads via the xla crate's PJRT CPU client.

HLO text — NOT ``lowered.compile()`` / ``.serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs (in ``artifacts/``):
  weights.bin            f32 LE weights, concatenated in sorted-key order
  prefill_t{T}.hlo.txt   per prompt-length bucket
  decode_b{B}.hlo.txt    per decode batch-size bucket
  paged_attn.hlo.txt     standalone paged-attention kernel (perf target)
  manifest.json          model config + weight table + executable index

Run once via ``make artifacts``; python never appears on the request path.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .kernels import paged_decode_attention
from .model import ModelConfig, decode_step, init_params, param_specs, prefill

PREFILL_BUCKETS = (16, 32, 64, 128, 256)
DECODE_BATCHES = (1, 2, 4, 8)
PAGED_SHAPE = dict(batch=4, pages=64, page_size=16, max_pages_per_seq=16)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    rust side always unwraps one tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build(out_dir: pathlib.Path, cfg: ModelConfig, seed: int) -> dict:
    out_dir.mkdir(parents=True, exist_ok=True)
    params = init_params(cfg, seed)
    specs = param_specs(cfg)

    # --- weights.bin (sorted-key order == jax dict flatten order) ---
    flat = np.concatenate([np.asarray(params[name]).reshape(-1) for name, _ in specs])
    flat.astype("<f4").tofile(out_dir / "weights.bin")

    params_spec = {
        name: jax.ShapeDtypeStruct(shape, jnp.float32) for name, shape in specs
    }
    executables = []

    # --- prefill buckets ---
    for t in PREFILL_BUCKETS:
        if t > cfg.max_seq:
            continue
        tok = jax.ShapeDtypeStruct((t,), jnp.int32)
        lowered = jax.jit(lambda p, tk: prefill(p, tk, cfg=cfg)).lower(params_spec, tok)
        path = f"prefill_t{t}.hlo.txt"
        (out_dir / path).write_text(to_hlo_text(lowered))
        executables.append(
            {
                "kind": "prefill",
                "path": path,
                "seq_len": t,
                # inputs: weights (sorted order), tokens[t] i32
                # outputs: logits[vocab], n_layers x kv [2, KH, t, D]
            }
        )
        print(f"  lowered prefill T={t}")

    # --- decode buckets ---
    for b in DECODE_BATCHES:
        tok = jax.ShapeDtypeStruct((b,), jnp.int32)
        lens = jax.ShapeDtypeStruct((b,), jnp.int32)
        kvs = [
            jax.ShapeDtypeStruct(
                (b, 2, cfg.n_kv_heads, cfg.max_seq, cfg.head_dim), jnp.float32
            )
            for _ in range(cfg.n_layers)
        ]
        lowered = jax.jit(
            lambda p, tk, ln, *kv: decode_step(p, tk, ln, *kv, cfg=cfg)
        ).lower(params_spec, tok, lens, *kvs)
        path = f"decode_b{b}.hlo.txt"
        (out_dir / path).write_text(to_hlo_text(lowered))
        executables.append({"kind": "decode", "path": path, "batch": b, "max_seq": cfg.max_seq})
        print(f"  lowered decode B={b}")

    # --- standalone paged-attention kernel (kernel-level perf target) ---
    ps = PAGED_SHAPE
    q = jax.ShapeDtypeStruct((ps["batch"], cfg.n_heads, cfg.head_dim), jnp.float32)
    pages = jax.ShapeDtypeStruct(
        (ps["pages"], 2, cfg.n_kv_heads, ps["page_size"], cfg.head_dim), jnp.float32
    )
    table = jax.ShapeDtypeStruct((ps["batch"], ps["max_pages_per_seq"]), jnp.int32)
    lens = jax.ShapeDtypeStruct((ps["batch"],), jnp.int32)
    lowered = jax.jit(
        lambda q, p, t, l: (paged_decode_attention(q, p, t, l),)
    ).lower(q, pages, table, lens)
    (out_dir / "paged_attn.hlo.txt").write_text(to_hlo_text(lowered))
    executables.append({"kind": "paged_attn", "path": "paged_attn.hlo.txt", **ps})
    print("  lowered paged_attn")

    manifest = {
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "n_kv_heads": cfg.n_kv_heads,
            "head_dim": cfg.head_dim,
            "ffn_hidden": cfg.ffn_hidden,
            "max_seq": cfg.max_seq,
            "rope_theta": cfg.rope_theta,
            "seed": seed,
        },
        "weights": {
            "file": "weights.bin",
            "dtype": "f32",
            "entries": [{"name": n, "shape": list(s)} for n, s in specs],
        },
        "prefill_buckets": [t for t in PREFILL_BUCKETS if t <= cfg.max_seq],
        "decode_batches": list(DECODE_BATCHES),
        "executables": executables,
    }
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = ModelConfig()
    out_dir = pathlib.Path(args.out)
    print(f"AOT-lowering tiny model ({cfg.n_params} params) to {out_dir}")
    manifest = build(out_dir, cfg, args.seed)
    print(f"wrote {len(manifest['executables'])} executables + weights.bin + manifest.json")


if __name__ == "__main__":
    main()
