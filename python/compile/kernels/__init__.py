# L1: Pallas kernel(s) for the paper's compute hot-spot.
from .decode_attn import decode_attention
from .flash_attn import flash_attention
from .paged_attn import paged_decode_attention

__all__ = ["flash_attention", "decode_attention", "paged_decode_attention"]
