"""Layer-1 Pallas kernel: batched single-token decode attention.

Dense, length-masked KV cache with native GQA: the grid walks (batch,
kv-head) and each cell computes the whole query-head *group* against that
kv head's cache, so the cache tile is loaded into VMEM once per group
rather than once per query head — the same KV-reuse trick GQA buys on
CUDA, expressed via BlockSpec.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, scale: float):
    # q_ref: [1, group, D]; k_ref/v_ref: [1, 1, S, D]; len_ref: [1] i32
    s = k_ref.shape[2]
    q = q_ref[0].astype(jnp.float32) * scale  # [group, D]
    k = k_ref[0, 0].astype(jnp.float32)  # [S, D]
    v = v_ref[0, 0].astype(jnp.float32)
    length = len_ref[0]
    scores = q @ k.T  # [group, S]
    pos = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    scores = jnp.where(pos < length, scores, NEG_INF)
    m = jnp.max(scores, axis=1, keepdims=True)
    p = jnp.exp(scores - m)
    denom = jnp.sum(p, axis=1, keepdims=True)
    denom = jnp.where(denom == 0.0, 1.0, denom)
    o_ref[0] = ((p / denom) @ v).astype(o_ref.dtype)


def decode_attention(q, k_cache, v_cache, lengths, *, scale: float | None = None):
    """q: [B, H, D]; k_cache/v_cache: [B, KH, S, D]; lengths: [B] i32.

    Returns [B, H, D]. Query head h attends kv head h // (H // KH).
    """
    b, h, d = q.shape
    _, kh, s, _ = k_cache.shape
    group = h // kh
    if h % kh != 0:
        raise ValueError(f"H={h} not divisible by KH={kh}")
    if scale is None:
        scale = 1.0 / (d**0.5)
    kernel = functools.partial(_decode_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, kh),
        in_specs=[
            pl.BlockSpec((1,), lambda bb, hh: (bb,)),
            pl.BlockSpec((1, group, d), lambda bb, hh: (bb, hh, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bb, hh: (bb, hh, 0, 0)),
            pl.BlockSpec((1, 1, s, d), lambda bb, hh: (bb, hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda bb, hh: (bb, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=True,
    )(lengths, q, k_cache, v_cache)
