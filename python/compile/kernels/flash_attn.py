"""Layer-1 Pallas kernel: tiled causal flash attention (prefill hot path).

TPU adaptation of the paper's CUDA prefill path (DESIGN.md
§Hardware-Adaptation): instead of threadblock/SMEM staging, the HBM->VMEM
schedule is expressed with BlockSpecs — the grid walks (head, q-tile) and an
inner fori_loop streams k/v tiles through VMEM with an online-softmax
accumulator, so VMEM holds only O(block_q * D + block_k * D + block_q *
block_k) floats regardless of sequence length.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; the real-TPU perf story is estimated in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int, causal: bool, scale: float, seq_k: int):
    """One (head, q-tile) cell: stream k/v tiles with online softmax."""
    qi = pl.program_id(1)
    block_q = q_ref.shape[1]
    d = q_ref.shape[2]

    q = q_ref[0].astype(jnp.float32) * scale  # [block_q, D]

    # Causal: kv index offset of this q tile's last row; only k tiles whose
    # first index <= that row can contribute.
    offset = seq_k - pl.num_programs(1) * block_q  # kv len minus q len
    if causal:
        last_q = (qi + 1) * block_q + offset
        num_kb = jnp.minimum(pl.cdiv(seq_k, block_k), pl.cdiv(last_q, block_k))
    else:
        num_kb = pl.cdiv(seq_k, block_k)

    def body(kb, carry):
        acc, m_prev, l_prev = carry
        k = pl.load(k_ref, (0, pl.ds(kb * block_k, block_k), slice(None))).astype(jnp.float32)
        v = pl.load(v_ref, (0, pl.ds(kb * block_k, block_k), slice(None))).astype(jnp.float32)
        s = q @ k.T  # [block_q, block_k]
        ki = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = ki < seq_k
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) + offset
            valid = valid & (ki <= qpos)
        s = jnp.where(valid, s, NEG_INF)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    init = (
        jnp.zeros((block_q, d), jnp.float32),
        jnp.full((block_q,), NEG_INF, jnp.float32),
        jnp.zeros((block_q,), jnp.float32),
    )
    acc, _m, l = jax.lax.fori_loop(0, num_kb, body, init)
    # Fully-masked rows (can't happen for causal self-attention, but guard
    # against l == 0 from padded tails) normalise to zero.
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def flash_attention(
    q,
    k,
    v,
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 64,
    block_k: int = 64,
):
    # §Perf (EXPERIMENTS.md): 64x64 tiles halve the grid/loop trip count
    # vs 32x32 at a VMEM cost of (Bq*D + 2*Bk*D + Bq*Bk)*4B ~ 73 KiB for
    # D=128 — far under the ~16 MiB/core budget, and still (8,128)-aligned.
    """Tiled causal attention. q: [H, Tq, D], k/v: [H, Tk, D] -> [H, Tq, D].

    Tq must be a multiple of block_q (callers pad to bucket sizes); Tk is
    masked so any Tk works. GQA callers repeat kv heads to H beforehand.
    """
    h, tq, d = q.shape
    _, tk, _ = k.shape
    if scale is None:
        scale = 1.0 / (d**0.5)
    block_q = min(block_q, tq)
    block_k = min(block_k, tk)
    if tq % block_q != 0:
        raise ValueError(f"Tq={tq} not a multiple of block_q={block_q}")
    grid = (h, tq // block_q)
    kernel = functools.partial(
        _flash_kernel, block_k=block_k, causal=causal, scale=scale, seq_k=tk
    )
    # Pad Tk up to a multiple of block_k so pl.ds tile loads stay in bounds;
    # the in-kernel `ki < seq_k` mask discards the padding.
    tk_pad = (block_k - tk % block_k) % block_k
    if tk_pad:
        k = jnp.pad(k, ((0, 0), (0, tk_pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, tk_pad), (0, 0)))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda hh, qq: (hh, qq, 0)),
            pl.BlockSpec((1, k.shape[1], d), lambda hh, qq: (hh, 0, 0)),
            pl.BlockSpec((1, v.shape[1], d), lambda hh, qq: (hh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda hh, qq: (hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct((h, tq, d), q.dtype),
        interpret=True,
    )(q, k, v)
