"""Layer-1 Pallas kernel: paged (block-table) decode attention.

TPU rethink of vLLM's PagedAttention CUDA kernel (the mechanism LayerKV
plugs into): the KV cache lives in fixed-size physical pages; a per-request
block table maps logical page -> physical page. On CUDA the gather happens
through SMEM staging per threadblock; here the whole page pool stays in the
kernel's memory space and an inner fori_loop walks the block table,
pl.ds-loading one page at a time (the VMEM-resident tile) with an
online-softmax accumulator, masking the tail page against the context
length.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _paged_kernel(q_ref, pages_ref, table_ref, len_ref, o_ref, *, scale: float):
    # q_ref: [1, group, D]; pages_ref: [P, 2, 1, page, D] (this kv head's
    # slice of the pool); table_ref: [1, maxp] i32; len_ref: [1] i32
    page = pages_ref.shape[3]
    d = pages_ref.shape[4]
    group = q_ref.shape[1]
    q = q_ref[0].astype(jnp.float32) * scale  # [group, D]
    length = len_ref[0]
    num_pages = pl.cdiv(length, page)

    def body(lp, carry):
        acc, m_prev, l_prev = carry
        phys = table_ref[0, lp]
        kv = pl.load(pages_ref, (pl.ds(phys, 1), slice(None), 0, slice(None), slice(None)))
        k = kv[0, 0].astype(jnp.float32)  # [page, D]
        v = kv[0, 1].astype(jnp.float32)
        s = q @ k.T  # [group, page]
        pos = lp * page + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(pos < length, s, NEG_INF)
        m_cur = jnp.max(s, axis=1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    init = (
        jnp.zeros((group, d), jnp.float32),
        jnp.full((group,), NEG_INF, jnp.float32),
        jnp.zeros((group,), jnp.float32),
    )
    acc, _m, l = jax.lax.fori_loop(0, num_pages, body, init)
    l = jnp.where(l == 0.0, 1.0, l)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


def paged_decode_attention(q, kv_pages, block_table, lengths, *, scale: float | None = None):
    """q: [B, H, D]; kv_pages: [P, 2, KH, page, D]; block_table: [B, maxp]
    i32; lengths: [B] i32 -> [B, H, D]."""
    b, h, d = q.shape
    p_, two, kh, page, _ = kv_pages.shape
    group = h // kh
    if h % kh != 0:
        raise ValueError(f"H={h} not divisible by KH={kh}")
    if scale is None:
        scale = 1.0 / (d**0.5)
    maxp = block_table.shape[1]
    kernel = functools.partial(_paged_kernel, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(b, kh),
        in_specs=[
            pl.BlockSpec((1, group, d), lambda bb, hh: (bb, hh, 0)),
            pl.BlockSpec((p_, 2, 1, page, d), lambda bb, hh: (0, 0, hh, 0, 0)),
            pl.BlockSpec((1, maxp), lambda bb, hh: (bb, 0)),
            pl.BlockSpec((1,), lambda bb, hh: (bb,)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda bb, hh: (bb, hh, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        interpret=True,
    )(q, kv_pages, block_table, lengths)
