"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here, written
with plain jax.numpy so it is obviously correct. pytest/hypothesis compare
the Pallas kernels (interpret=True) against these under shape/dtype sweeps.
"""

from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Full multi-head attention.

    q: [H, Tq, D], k/v: [H, Tk, D]  ->  [H, Tq, D]

    With ``causal=True`` query position i (counted from the *end* of the
    kv sequence, i.e. offset = Tk - Tq) attends to kv positions <= offset+i.
    """
    h, tq, d = q.shape
    tk = k.shape[1]
    if scale is None:
        scale = 1.0 / (d**0.5)
    scores = jnp.einsum("hqd,hkd->hqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        offset = tk - tq
        qi = jnp.arange(tq)[:, None] + offset
        ki = jnp.arange(tk)[None, :]
        scores = jnp.where(ki <= qi, scores, NEG_INF)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("hqk,hkd->hqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def ref_decode_attention(q, k_cache, v_cache, lengths, *, scale: float | None = None):
    """Single-token (decode) attention over a dense, length-masked KV cache.

    q: [B, H, D]; k_cache/v_cache: [B, KH, S, D]; lengths: [B] (valid kv
    entries per batch element, including the current token's KV).
    GQA: query head h reads kv head h // (H // KH).  ->  [B, H, D]
    """
    b, h, d = q.shape
    kh = k_cache.shape[1]
    s = k_cache.shape[2]
    group = h // kh
    if scale is None:
        scale = 1.0 / (d**0.5)
    # expand kv heads to query heads
    k = jnp.repeat(k_cache, group, axis=1).astype(jnp.float32)  # [B, H, S, D]
    v = jnp.repeat(v_cache, group, axis=1).astype(jnp.float32)
    scores = jnp.einsum("bhd,bhsd->bhs", q.astype(jnp.float32), k) * scale
    mask = jnp.arange(s)[None, None, :] < lengths[:, None, None]
    scores = jnp.where(mask, scores, NEG_INF)
    p = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bhs,bhsd->bhd", p, v)
    return out.astype(q.dtype)


def ref_paged_decode_attention(q, kv_pages, block_table, lengths, *, scale: float | None = None):
    """Decode attention over a paged KV cache (vLLM-style block gather).

    q: [B, H, D]; kv_pages: [P, 2, KH, page, D]; block_table: [B, maxp] i32
    (physical page id per logical page; entries past the context are
    arbitrary); lengths: [B].  ->  [B, H, D]

    The oracle simply gathers the pages into a dense cache and defers to
    ref_decode_attention.
    """
    b = q.shape[0]
    p_, two, kh, page, d = kv_pages.shape
    maxp = block_table.shape[1]
    # gather: dense[b, :, l*page:(l+1)*page, :] = kv_pages[block_table[b, l]]
    gathered = kv_pages[block_table.reshape(-1)]  # [B*maxp, 2, KH, page, D]
    gathered = gathered.reshape(b, maxp, 2, kh, page, d)
    dense = jnp.moveaxis(gathered, 1, 3).reshape(b, 2, kh, maxp * page, d)
    return ref_decode_attention(q, dense[:, 0], dense[:, 1], lengths, scale=scale)
