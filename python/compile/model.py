"""Layer-2: tiny GQA llama-style transformer served end-to-end by the rust
coordinator.

Two entry points are AOT-lowered by aot.py:

* ``prefill(params, tokens[T])`` -> ``(logits[T, V], kv_0 .. kv_{L-1})``
  where each per-layer ``kv_i`` is ``[2, KH, T, D]``. Per-layer outputs are
  deliberately *separate* tuple elements: the rust coordinator takes
  ownership of each layer's KV independently, which is exactly the handle
  LayerKV's layer-wise offloading needs (a layer can live in the device
  pool or the host pool without reassembling a monolithic cache).

* ``decode_step(params, tokens[B], cache_lens[B], kv_0 .. kv_{L-1})`` ->
  ``(logits[B, V], new_kv_0 .. new_kv_{L-1})`` with each ``kv_i`` shaped
  ``[B, 2, KH, Smax, D]``. The new token's K/V is written at position
  ``cache_lens[b]`` and attention runs over ``cache_lens[b] + 1`` entries.

Attention hot paths call the Pallas kernels from ``kernels/`` so they lower
into the same HLO module (interpret=True -> plain HLO ops the CPU PJRT
client executes).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import decode_attention, flash_attention


class ModelConfig(NamedTuple):
    """Shape of the tiny serving model (llama-flavoured, GQA)."""

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 32
    ffn_hidden: int = 256
    max_seq: int = 256
    rope_theta: float = 10000.0

    @property
    def group(self) -> int:
        return self.n_heads // self.n_kv_heads

    @property
    def n_params(self) -> int:
        return sum(int(np.prod(s)) for _, s in param_specs(self))


def param_specs(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Ordered (name, shape) for every weight. jax flattens dicts in sorted
    key order; this list IS sorted, and the rust loader reads weights.bin
    in exactly this order (recorded in the manifest)."""
    dm, hd = cfg.d_model, cfg.head_dim
    specs: list[tuple[str, tuple[int, ...]]] = []
    for i in range(cfg.n_layers):
        p = f"l{i:02d}."
        specs += [
            (p + "norm_attn", (dm,)),
            (p + "norm_ffn", (dm,)),
            (p + "w_down", (cfg.ffn_hidden, dm)),
            (p + "w_gate", (dm, cfg.ffn_hidden)),
            (p + "w_up", (dm, cfg.ffn_hidden)),
            (p + "wk", (dm, cfg.n_kv_heads * hd)),
            (p + "wo", (cfg.n_heads * hd, dm)),
            (p + "wq", (dm, cfg.n_heads * hd)),
            (p + "wv", (dm, cfg.n_kv_heads * hd)),
        ]
    specs += [("z_embed", (cfg.vocab, dm)), ("z_norm_f", (dm,)), ("z_unembed", (dm, cfg.vocab))]
    return sorted(specs)


def init_params(cfg: ModelConfig, seed: int = 0) -> dict[str, jax.Array]:
    """Deterministic random init (scaled normal; ones for norms)."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in param_specs(cfg):
        if "norm" in name:
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else 1
            params[name] = jnp.asarray(
                rng.standard_normal(shape).astype(np.float32) / np.sqrt(max(fan_in, 1))
            )
    return params


def _rms_norm(x, w, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def _rope(x, positions, theta: float):
    """Rotary embedding. x: [..., T, H, D]; positions: [..., T]."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., T, D/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., 0::2], x[..., 1::2]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(x.shape).astype(x.dtype)


def _ffn(p, prefix, x):
    gate = jax.nn.silu(x @ p[prefix + "w_gate"])
    return (gate * (x @ p[prefix + "w_up"])) @ p[prefix + "w_down"]


def prefill(params: dict, tokens: jax.Array, cfg: ModelConfig | None = None):
    """Process a whole prompt. tokens: [T] i32 -> (last_logits[V], *kv)."""
    cfg = cfg or _cfg_of(params)
    t = tokens.shape[0]
    pos = jnp.arange(t)
    x = params["z_embed"][tokens]  # [T, dm]
    kvs = []
    for i in range(cfg.n_layers):
        p = f"l{i:02d}."
        h = _rms_norm(x, params[p + "norm_attn"])
        q = (h @ params[p + "wq"]).reshape(t, cfg.n_heads, cfg.head_dim)
        k = (h @ params[p + "wk"]).reshape(t, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ params[p + "wv"]).reshape(t, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(q, pos, cfg.rope_theta)
        k = _rope(k, pos, cfg.rope_theta)
        kvs.append(jnp.stack([k, v]).transpose(0, 2, 1, 3))  # [2, KH, T, D]
        # GQA: expand kv heads for the prefill kernel.
        k_full = jnp.repeat(k, cfg.group, axis=1).transpose(1, 0, 2)  # [H, T, D]
        v_full = jnp.repeat(v, cfg.group, axis=1).transpose(1, 0, 2)
        attn = flash_attention(q.transpose(1, 0, 2), k_full, v_full, causal=True)
        x = x + attn.transpose(1, 0, 2).reshape(t, -1) @ params[p + "wo"]
        x = x + _ffn(params, p, _rms_norm(x, params[p + "norm_ffn"]))
    normed = _rms_norm(x, params["z_norm_f"])
    logits = normed @ params["z_unembed"]  # [T, V]: rust picks the row at
    # the true prompt end (prompts are padded up to the bucket length)
    return (logits, *kvs)


def decode_step(params: dict, tokens: jax.Array, cache_lens: jax.Array, *kvs, cfg: ModelConfig | None = None):
    """One decode iteration for a batch.

    tokens: [B] i32; cache_lens: [B] i32 (entries already in the cache);
    kvs: n_layers tensors [B, 2, KH, Smax, D]. Returns (logits[B, V],
    *new_kvs) with the new token's KV written at cache_lens[b].
    """
    cfg = cfg or _cfg_of(params)
    b = tokens.shape[0]
    x = params["z_embed"][tokens]  # [B, dm]
    new_kvs = []
    for i in range(cfg.n_layers):
        p = f"l{i:02d}."
        kv = kvs[i]
        h = _rms_norm(x, params[p + "norm_attn"])
        q = (h @ params[p + "wq"]).reshape(b, cfg.n_heads, cfg.head_dim)
        k = (h @ params[p + "wk"]).reshape(b, cfg.n_kv_heads, cfg.head_dim)
        v = (h @ params[p + "wv"]).reshape(b, cfg.n_kv_heads, cfg.head_dim)
        q = _rope(q[:, None], cache_lens[:, None], cfg.rope_theta)[:, 0]
        k = _rope(k[:, None], cache_lens[:, None], cfg.rope_theta)[:, 0]
        # Append this token's K/V at position cache_lens[b].
        new = jnp.stack([k, v], axis=1).transpose(0, 1, 2, 3)  # [B, 2, KH, D]
        kv = _scatter_kv(kv, new, cache_lens)
        new_kvs.append(kv)
        attn = decode_attention(q, kv[:, 0], kv[:, 1], cache_lens + 1)
        x = x + attn.reshape(b, -1) @ params[p + "wo"]
        x = x + _ffn(params, p, _rms_norm(x, params[p + "norm_ffn"]))
    last = _rms_norm(x, params["z_norm_f"])
    logits = last @ params["z_unembed"]
    return (logits, *new_kvs)


def _scatter_kv(kv, new, cache_lens):
    """kv: [B, 2, KH, S, D]; new: [B, 2, KH, D]; write at S-index len[b]."""

    def one(kv_b, new_b, len_b):
        return jax.lax.dynamic_update_slice(
            kv_b, new_b[:, :, None, :], (0, 0, len_b, 0)
        )

    return jax.vmap(one)(kv, new, cache_lens)


def _cfg_of(params: dict) -> ModelConfig:
    """Reconstruct the default-head-dim config from weight shapes (callers
    that deviate from head_dim=32 must pass cfg explicitly)."""
    dm = params["z_norm_f"].shape[0]
    vocab = params["z_embed"].shape[0]
    n_layers = sum(1 for k in params if k.endswith(".wq"))
    hd = 32
    n_heads = params["l00.wq"].shape[1] // hd
    n_kv_heads = params["l00.wk"].shape[1] // hd
    ffn_hidden = params["l00.w_up"].shape[1]
    return ModelConfig(
        vocab=vocab,
        d_model=dm,
        n_layers=n_layers,
        n_heads=n_heads,
        n_kv_heads=n_kv_heads,
        head_dim=hd,
        ffn_hidden=ffn_hidden,
    )
