"""AOT pipeline tests: lowering produces parseable HLO text + a coherent
manifest + a weights.bin that round-trips."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import ModelConfig, init_params, param_specs, prefill

TINY = ModelConfig(n_layers=2, max_seq=32, vocab=64, ffn_hidden=64)


@pytest.fixture(scope="module")
def built(tmp_path_factory, monkeypatch_module=None):
    out = tmp_path_factory.mktemp("artifacts")
    import unittest.mock as mock

    with mock.patch.object(aot, "PREFILL_BUCKETS", (16, 32)), mock.patch.object(
        aot, "DECODE_BATCHES", (1, 2)
    ):
        manifest = aot.build(out, TINY, seed=0)
    return out, manifest


def test_manifest_contents(built):
    out, manifest = built
    assert manifest["model"]["n_layers"] == 2
    kinds = [e["kind"] for e in manifest["executables"]]
    assert kinds.count("prefill") == 2
    assert kinds.count("decode") == 2
    assert kinds.count("paged_attn") == 1
    on_disk = json.loads((out / "manifest.json").read_text())
    assert on_disk == manifest


def test_hlo_text_parseable_header(built):
    out, manifest = built
    for e in manifest["executables"]:
        text = (out / e["path"]).read_text()
        assert text.startswith("HloModule"), e["path"]
        assert "ROOT" in text


def test_weights_bin_roundtrip(built):
    out, _ = built
    params = init_params(TINY, 0)
    specs = param_specs(TINY)
    data = np.fromfile(out / "weights.bin", dtype="<f4")
    assert data.size == TINY.n_params
    off = 0
    for name, shape in specs:
        n = int(np.prod(shape))
        np.testing.assert_array_equal(
            data[off : off + n].reshape(shape), np.asarray(params[name])
        )
        off += n


def test_prefill_hlo_executes_like_python(built):
    """Compile the emitted HLO text with jax's own runtime and compare
    against directly executing the python model — proves the artifact is a
    faithful serialization, independent of the rust loader."""
    out, manifest = built
    params = init_params(TINY, 0)
    toks = jnp.asarray(np.arange(16) % TINY.vocab, jnp.int32)

    expect = prefill(params, toks, cfg=TINY)

    # Round-trip: text was produced from the same lowering; re-lower and
    # execute via jax to compare numerics.
    lowered = jax.jit(lambda p, t: prefill(p, t, cfg=TINY)).lower(
        {n: jax.ShapeDtypeStruct(s, jnp.float32) for n, s in param_specs(TINY)},
        jax.ShapeDtypeStruct((16,), jnp.int32),
    )
    compiled = lowered.compile()
    got = compiled(params, toks)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(expect[0]), rtol=1e-5, atol=1e-5)
