"""Kernel-vs-oracle correctness: the CORE signal for the L1 layer.

hypothesis sweeps shapes/dtypes; every example asserts allclose against the
pure-jnp oracle in kernels/ref.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode_attention, flash_attention, paged_decode_attention
from compile.kernels.ref import (
    ref_attention,
    ref_decode_attention,
    ref_paged_decode_attention,
)

SETTINGS = dict(max_examples=25, deadline=None)


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


class TestFlashAttention:
    @settings(**SETTINGS)
    @given(
        h=st.sampled_from([1, 2, 4]),
        tq_blocks=st.integers(1, 4),
        block_q=st.sampled_from([8, 16, 32]),
        d=st.sampled_from([16, 32, 64]),
        block_k=st.sampled_from([8, 16, 32]),
        causal=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_self_attention_matches_ref(self, h, tq_blocks, block_q, d, block_k, causal, seed):
        rng = np.random.default_rng(seed)
        t = tq_blocks * block_q
        q = _rand(rng, (h, t, d), jnp.float32)
        k = _rand(rng, (h, t, d), jnp.float32)
        v = _rand(rng, (h, t, d), jnp.float32)
        out = flash_attention(q, k, v, causal=causal, block_q=block_q, block_k=block_k)
        ref = ref_attention(q, k, v, causal=causal)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    @settings(**SETTINGS)
    @given(
        tk_extra=st.integers(1, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_cross_length_kv(self, tk_extra, seed):
        """Tk > Tq and Tk not a multiple of block_k (tail masking)."""
        rng = np.random.default_rng(seed)
        h, tq, d = 2, 32, 32
        tk = tq + tk_extra
        q = _rand(rng, (h, tq, d), jnp.float32)
        k = _rand(rng, (h, tk, d), jnp.float32)
        v = _rand(rng, (h, tk, d), jnp.float32)
        out = flash_attention(q, k, v, causal=True, block_q=16, block_k=16)
        ref = ref_attention(q, k, v, causal=True)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_bf16(self):
        rng = np.random.default_rng(0)
        q = _rand(rng, (2, 32, 32), jnp.bfloat16)
        k = _rand(rng, (2, 32, 32), jnp.bfloat16)
        v = _rand(rng, (2, 32, 32), jnp.bfloat16)
        out = flash_attention(q, k, v)
        ref = ref_attention(q, k, v)
        assert out.dtype == jnp.bfloat16
        np.testing.assert_allclose(
            out.astype(np.float32), ref.astype(np.float32), **_tol(jnp.bfloat16)
        )

    def test_rejects_ragged_q(self):
        rng = np.random.default_rng(0)
        q = _rand(rng, (1, 33, 16), jnp.float32)
        k = _rand(rng, (1, 33, 16), jnp.float32)
        with pytest.raises(ValueError, match="multiple"):
            flash_attention(q, k, k, block_q=16)

    def test_first_row_attends_only_itself(self):
        """Causal row 0 output == v[0] exactly (softmax over one entry)."""
        rng = np.random.default_rng(0)
        q = _rand(rng, (1, 16, 16), jnp.float32)
        k = _rand(rng, (1, 16, 16), jnp.float32)
        v = _rand(rng, (1, 16, 16), jnp.float32)
        out = flash_attention(q, k, v, causal=True)
        np.testing.assert_allclose(out[0, 0], v[0, 0], rtol=1e-6, atol=1e-6)


class TestDecodeAttention:
    @settings(**SETTINGS)
    @given(
        b=st.integers(1, 8),
        kh=st.sampled_from([1, 2, 4]),
        group=st.sampled_from([1, 2, 4]),
        s=st.sampled_from([16, 48, 256]),
        d=st.sampled_from([16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, b, kh, group, s, d, seed):
        rng = np.random.default_rng(seed)
        h = kh * group
        q = _rand(rng, (b, h, d), jnp.float32)
        kc = _rand(rng, (b, kh, s, d), jnp.float32)
        vc = _rand(rng, (b, kh, s, d), jnp.float32)
        lens = jnp.asarray(rng.integers(1, s + 1, (b,)), jnp.int32)
        out = decode_attention(q, kc, vc, lens)
        ref = ref_decode_attention(q, kc, vc, lens)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_length_one_returns_v0(self):
        rng = np.random.default_rng(3)
        q = _rand(rng, (2, 4, 16), jnp.float32)
        kc = _rand(rng, (2, 2, 32, 16), jnp.float32)
        vc = _rand(rng, (2, 2, 32, 16), jnp.float32)
        lens = jnp.array([1, 1], jnp.int32)
        out = decode_attention(q, kc, vc, lens)
        # every query head h reads kv head h//2's v[0]
        for b in range(2):
            for h in range(4):
                np.testing.assert_allclose(out[b, h], vc[b, h // 2, 0], rtol=1e-6, atol=1e-6)

    def test_mask_ignores_garbage_tail(self):
        """Entries past `lengths` must not affect the output."""
        rng = np.random.default_rng(4)
        q = _rand(rng, (1, 2, 16), jnp.float32)
        kc = _rand(rng, (1, 1, 16, 16), jnp.float32)
        vc = _rand(rng, (1, 1, 16, 16), jnp.float32)
        lens = jnp.array([7], jnp.int32)
        base = decode_attention(q, kc, vc, lens)
        kc2 = kc.at[:, :, 7:, :].set(1e6)
        vc2 = vc.at[:, :, 7:, :].set(-1e6)
        poisoned = decode_attention(q, kc2, vc2, lens)
        np.testing.assert_allclose(base, poisoned, rtol=0, atol=0)


class TestPagedDecodeAttention:
    @settings(**SETTINGS)
    @given(
        b=st.integers(1, 4),
        kh=st.sampled_from([1, 2]),
        group=st.sampled_from([1, 2]),
        page=st.sampled_from([4, 8, 16]),
        maxp=st.integers(1, 6),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref(self, b, kh, group, page, maxp, seed):
        rng = np.random.default_rng(seed)
        h, d = kh * group, 16
        pool = maxp * b + 3
        q = _rand(rng, (b, h, d), jnp.float32)
        pages = _rand(rng, (pool, 2, kh, page, d), jnp.float32)
        table = jnp.asarray(rng.integers(0, pool, (b, maxp)), jnp.int32)
        lens = jnp.asarray(rng.integers(1, maxp * page + 1, (b,)), jnp.int32)
        out = paged_decode_attention(q, pages, table, lens)
        ref = ref_paged_decode_attention(q, pages, table, lens)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)

    def test_equivalent_to_dense_decode(self):
        """A contiguous block table must reproduce dense decode attention."""
        rng = np.random.default_rng(5)
        b, kh, h, page, maxp, d = 2, 2, 4, 8, 4, 16
        pages = _rand(rng, (b * maxp, 2, kh, page, d), jnp.float32)
        table = jnp.arange(b * maxp, dtype=jnp.int32).reshape(b, maxp)
        q = _rand(rng, (b, h, d), jnp.float32)
        lens = jnp.array([13, 29], jnp.int32)
        dense = (
            pages.reshape(b, maxp, 2, kh, page, d)
            .transpose(0, 2, 3, 1, 4, 5)
            .reshape(b, 2, kh, maxp * page, d)
        )
        out = paged_decode_attention(q, pages, table, lens)
        ref = decode_attention(q, dense[:, 0], dense[:, 1], lens)
        np.testing.assert_allclose(out, ref, rtol=2e-5, atol=2e-5)
