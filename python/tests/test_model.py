"""L2 model tests: shapes, prefill/decode consistency, determinism."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    _rope,
    _scatter_kv,
    decode_step,
    init_params,
    param_specs,
    prefill,
)

CFG = ModelConfig()


@pytest.fixture(scope="module")
def params():
    return init_params(CFG, 0)


def _dec_cache(kvs, t):
    """Per-layer prefill kv [2, KH, T, D] -> decode cache [1, 2, KH, Smax, D]."""
    out = []
    for kv in kvs:
        buf = jnp.zeros((1, 2, CFG.n_kv_heads, CFG.max_seq, CFG.head_dim), jnp.float32)
        out.append(buf.at[0, :, :, :t, :].set(kv[:, :, :t, :]))
    return out


class TestParamSpecs:
    def test_sorted_and_unique(self):
        specs = param_specs(CFG)
        names = [n for n, _ in specs]
        assert names == sorted(names)
        assert len(set(names)) == len(names)

    def test_matches_init(self, params):
        for name, shape in param_specs(CFG):
            assert params[name].shape == shape

    def test_n_params(self):
        assert CFG.n_params == sum(int(np.prod(s)) for _, s in param_specs(CFG))

    def test_sorted_keys_equals_tree_flatten_order(self, params):
        """The weights.bin contract: jax dict flatten order == sorted keys."""
        leaves, _ = jax.tree_util.tree_flatten(params)
        by_sorted = [params[k] for k in sorted(params)]
        assert all(a is b for a, b in zip(leaves, by_sorted))


class TestPrefill:
    def test_shapes(self, params):
        t = 16
        toks = jnp.zeros((t,), jnp.int32)
        out = prefill(params, toks)
        assert out[0].shape == (t, CFG.vocab)
        assert len(out) == 1 + CFG.n_layers
        for kv in out[1:]:
            assert kv.shape == (2, CFG.n_kv_heads, t, CFG.head_dim)

    def test_deterministic(self, params):
        toks = jnp.arange(16, dtype=jnp.int32) % CFG.vocab
        a = prefill(params, toks)
        b = prefill(params, toks)
        np.testing.assert_array_equal(a[0], b[0])

    def test_causality(self, params):
        """Changing the last token must not change earlier layers' KV rows."""
        rng = np.random.default_rng(0)
        toks = jnp.asarray(rng.integers(0, CFG.vocab, (16,)), jnp.int32)
        toks2 = toks.at[-1].set((toks[-1] + 1) % CFG.vocab)
        kv_a = prefill(params, toks)[1]
        kv_b = prefill(params, toks2)[1]
        np.testing.assert_allclose(kv_a[:, :, :-1, :], kv_b[:, :, :-1, :], atol=0)


class TestDecodeConsistency:
    @pytest.mark.parametrize("t", [8, 16, 32])
    def test_decode_matches_prefill(self, params, t):
        """prefill(t-1) + decode(token t-1) == prefill(t) logits."""
        rng = np.random.default_rng(t)
        toks = jnp.asarray(rng.integers(0, CFG.vocab, (t,)), jnp.int32)
        full = prefill(params, toks)
        part = prefill(params, toks[: t - 1])
        caches = _dec_cache(part[1:], t - 1)
        res = decode_step(params, toks[t - 1 : t], jnp.array([t - 1], jnp.int32), *caches)
        np.testing.assert_allclose(res[0][0], full[0][-1], rtol=1e-4, atol=1e-4)
        # appended KV row equals prefill's row t-1
        for i in range(CFG.n_layers):
            np.testing.assert_allclose(
                res[1 + i][0, :, :, t - 1, :], full[1 + i][:, :, t - 1, :], rtol=1e-4, atol=1e-4
            )

    def test_batched_decode_is_per_request(self, params):
        """Batching two requests must give identical logits to running each
        alone — the core soundness requirement for continuous batching."""
        rng = np.random.default_rng(9)
        t1, t2 = 8, 12
        toks1 = jnp.asarray(rng.integers(0, CFG.vocab, (t1,)), jnp.int32)
        toks2 = jnp.asarray(rng.integers(0, CFG.vocab, (t2,)), jnp.int32)
        p1, p2 = prefill(params, toks1), prefill(params, toks2)
        c1, c2 = _dec_cache(p1[1:], t1), _dec_cache(p2[1:], t2)
        batch = [jnp.concatenate([a, b]) for a, b in zip(c1, c2)]
        tok = jnp.array([3, 5], jnp.int32)
        lens = jnp.array([t1, t2], jnp.int32)
        out_b = decode_step(params, tok, lens, *batch)
        out_1 = decode_step(params, tok[:1], lens[:1], *c1)
        out_2 = decode_step(params, tok[1:], lens[1:], *c2)
        np.testing.assert_allclose(out_b[0][0], out_1[0][0], rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(out_b[0][1], out_2[0][0], rtol=1e-4, atol=1e-4)


class TestHelpers:
    def test_rope_norm_preserving(self):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((4, 2, 32)).astype(np.float32))
        pos = jnp.arange(4)
        y = _rope(x, pos, 10000.0)
        np.testing.assert_allclose(
            jnp.linalg.norm(x, axis=-1), jnp.linalg.norm(y, axis=-1), rtol=1e-5
        )

    def test_rope_position_zero_identity(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.standard_normal((1, 2, 32)).astype(np.float32))
        y = _rope(x, jnp.zeros((1,), jnp.int32), 10000.0)
        np.testing.assert_allclose(x, y, rtol=1e-6, atol=1e-6)

    def test_rope_relative(self):
        """<rope(q,p), rope(k,p)> depends only on relative offset."""
        rng = np.random.default_rng(2)
        q = jnp.asarray(rng.standard_normal((1, 1, 32)).astype(np.float32))
        k = jnp.asarray(rng.standard_normal((1, 1, 32)).astype(np.float32))
        dots = []
        for base in (0, 7):
            qr = _rope(q, jnp.array([base + 3]), 10000.0)
            kr = _rope(k, jnp.array([base]), 10000.0)
            dots.append(float(jnp.vdot(qr, kr)))
        assert abs(dots[0] - dots[1]) < 1e-3

    def test_scatter_kv_writes_only_target_row(self):
        kv = jnp.zeros((2, 2, 2, 8, 4), jnp.float32)
        new = jnp.ones((2, 2, 2, 4), jnp.float32)
        lens = jnp.array([3, 5], jnp.int32)
        out = _scatter_kv(kv, new, lens)
        assert float(out[0, :, :, 3, :].min()) == 1.0
        assert float(out[1, :, :, 5, :].min()) == 1.0
        # one [2, KH, D] row of ones per batch element -> 2 * (2*2*4) = 32
        assert float(jnp.abs(out).sum()) == 32.0
