//! Ablation benches for the design choices DESIGN.md §7 calls out:
//!
//! 1. retained-layer solve: x from Eqs. 3-4 vs forced x=0 vs x=L;
//! 2. predictor accuracy sweep (oracle -> coin-flip buckets);
//! 3. §3.1.3 PCIe chunking on/off under TP over PCIe;
//! 4. Eq. 5 proactive-offload threshold sweep.

use layerkv::config::Policy;
use layerkv::experiments as exp;
use layerkv::experiments::Table;
use layerkv::coordinator::run_trace;
use layerkv::sim::{BusyWindow, PcieLink};
use layerkv::util::Rng;
use layerkv::workload::fixed::FixedWorkload;

fn main() {
    let n = if exp::quick() { 30 } else { 100 };

    // --- 1. retained-layer policy -----------------------------------
    // §Perf: independent cells fan across cores (exp::par_map), rows stay
    // in sweep order.
    let mut t = Table::new(
        "Ablation: retained layers x at admission (7B, ctx 8192, 1 req/s)",
        &["x policy", "TTFT mean(s)", "TPOT mean(s)", "tput tok/s"],
    );
    let cells = [
        ("solve Eq.3/4", None),
        ("x = 0 (offload all)", Some(0)),
        ("x = L/2", Some(16)),
        ("x = L (no offload)", Some(32)),
    ];
    for row in exp::par_map(&cells, |&(name, x_override)| {
        let mut cfg = exp::setup("7b").with_policy(Policy::LayerKv { slo_aware: true });
        cfg.x_override = x_override;
        let rep = exp::run_fixed(cfg, 8192, n, 23);
        [
            name.to_string(),
            format!("{:.2}", rep.ttft().mean()),
            format!("{:.4}", rep.tpot().mean()),
            format!("{:.1}", rep.throughput_tok_s()),
        ]
    }) {
        t.row(&row);
    }
    t.print();

    // --- 2. predictor accuracy --------------------------------------
    let mut t = Table::new(
        "Ablation: output-length predictor accuracy (7B, ShareGPT-like, 7 req/s)",
        &["bucket accuracy", "TTFT mean(s)", "violations %"],
    );
    for row in exp::par_map(&[1.0, 0.8, 0.5, 0.2], |&acc| {
        let cfg = exp::setup("7b").with_policy(Policy::LayerKv { slo_aware: true });
        // rate past the saturation knee so the forecast/slack paths that
        // consume the prediction actually bind
        let trace = layerkv::workload::sharegpt::ShareGptWorkload::paper(7.0, n * 5)
            .generate(&mut Rng::new(29));
        let (rep, _) = run_trace(cfg.clone(), &trace, acc);
        [
            format!("{acc:.1}"),
            format!("{:.2}", rep.ttft().mean()),
            format!("{:.1}", 100.0 * rep.slo_violation_rate(&cfg.slo)),
        ]
    }) {
        t.row(&row);
    }
    t.print();

    // --- 3. PCIe chunking (§3.1.3), kernel-level --------------------
    let mut t = Table::new(
        "Ablation: PCIe contention mechanism (1 GB swap vs 50%-duty all-reduces)",
        &["mechanism", "swap finish (s)", "all-reduce contention (s)"],
    );
    let busy: Vec<BusyWindow> = (0..50)
        .map(|i| BusyWindow { start: i as f64 * 0.02, end: i as f64 * 0.02 + 0.01 })
        .collect();
    for (name, chunking) in [("check + chunk (LayerKV)", true), ("naive swap", false)] {
        let link = PcieLink::new(13.0e9, 10e-6, chunking);
        let out = link.schedule_swap(0.0, 1.0e9, &busy);
        t.row(&[
            name.to_string(),
            format!("{:.4}", out.finish),
            format!("{:.4}", out.contended),
        ]);
    }
    t.print();

    // --- 4. Eq. 5 threshold -----------------------------------------
    let mut t = Table::new(
        "Ablation: Eq. 5 proactive-offload threshold (7B, ctx 4096, 1 req/s)",
        &["threshold frac", "TTFT mean(s)", "TPOT mean(s)"],
    );
    for row in exp::par_map(&[0.0, 0.05, 0.10, 0.25], |&thresh| {
        let mut cfg = exp::setup("7b").with_policy(Policy::LayerKv { slo_aware: true });
        cfg.avail_threshold_frac = thresh;
        let trace = FixedWorkload::paper(4096).generate(&mut Rng::new(31));
        let trace = layerkv::workload::Trace { requests: trace.requests[..n].to_vec() };
        let (rep, _) = run_trace(cfg, &trace, exp::PREDICTOR_ACC);
        [
            format!("{thresh:.2}"),
            format!("{:.2}", rep.ttft().mean()),
            format!("{:.4}", rep.tpot().mean()),
        ]
    }) {
        t.row(&row);
    }
    t.print();

    // --- 5. §8 extension: KV quantization on the offload path --------
    {
        use layerkv::config::OffloadQuant;
        let mut t = Table::new(
            "Extension (§8): offload-path KV quantization (7B, ctx 8192, 1 req/s)",
            &["offload precision", "TTFT mean(s)", "TPOT mean(s)", "offload GB"],
        );
        let cells = [
            ("fp16 (lossless)", OffloadQuant::None),
            ("fp8", OffloadQuant::Fp8),
            ("int4", OffloadQuant::Int4),
        ];
        for row in exp::par_map(&cells, |&(name, q)| {
            let mut cfg = exp::setup("7b").with_policy(Policy::LayerKv { slo_aware: true });
            cfg.offload_quant = q;
            let trace = FixedWorkload::paper(8192).generate(&mut Rng::new(37));
            let trace = layerkv::workload::Trace { requests: trace.requests[..n].to_vec() };
            let (rep, stats) = run_trace(cfg, &trace, exp::PREDICTOR_ACC);
            [
                name.to_string(),
                format!("{:.2}", rep.ttft().mean()),
                format!("{:.4}", rep.tpot().mean()),
                format!("{:.2}", stats.offload_bytes / 1e9),
            ]
        }) {
            t.row(&row);
        }
        t.print();
    }
}
