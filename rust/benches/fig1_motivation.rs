//! Regenerates Fig. 1: TTFT/TPOT and the queueing-vs-prefill breakdown
//! across context lengths (Llama-2-7B, 1 GPU, 1 req/s, output 512, vLLM).
//!
//! Expected shape (paper): TTFT rises superlinearly with context while
//! TPOT grows ~linearly; past ~1k tokens queueing dominates TTFT.

use layerkv::benchutil::bench;
use layerkv::experiments as exp;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = exp::fig1();
    exp::print_fig1(&rows);
    println!("\n(fig1 sweep took {:.1}s)", t0.elapsed().as_secs_f64());

    // micro: one full 2k-context simulation run, timed
    bench("sim_run/7b_vllm_ctx2048_n20", 3.0, || {
        std::env::set_var("LAYERKV_QUICK", "1");
        let cfg = exp::setup("7b");
        let _ = exp::run_fixed(cfg, 2048, 20, 3);
    });
}
