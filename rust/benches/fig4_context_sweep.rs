//! Regenerates Fig. 4: LayerKV vs vLLM across context lengths on the three
//! paper models (Llama-2-7B TP1, Yi-34B TP2, Llama-3.1-70B TP4).
//!
//! Expected shape (paper): vLLM TTFT explodes with context (queueing);
//! LayerKV rises gently — gap widening to >=an order of magnitude — while
//! throughput stays within ~3%.

use layerkv::experiments as exp;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = exp::fig4();
    exp::print_fig4(&rows);
    println!("\n(fig4 sweep took {:.1}s)", t0.elapsed().as_secs_f64());
}
