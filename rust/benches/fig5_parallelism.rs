//! Regenerates Fig. 5: Yi-34B-200K under varying degree of parallelism
//! (TP 2/4/8).
//!
//! Expected shape (paper): higher DoP shrinks absolute TTFT and narrows
//! the throughput gap, but LayerKV keeps a clear TTFT lead.

use layerkv::experiments as exp;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = exp::fig5();
    exp::print_fig5(&rows);
    println!("\n(fig5 sweep took {:.1}s)", t0.elapsed().as_secs_f64());
}
