//! Regenerates Fig. 6: ShareGPT trace, arrival-rate sweep — mean TTFT and
//! throughput for LayerKV vs vLLM (Llama-2-7B).
//!
//! Expected shape (paper): vLLM TTFT spikes at high rates (queueing);
//! LayerKV stays low (up to ~69x mean TTFT reduction); throughput gap
//! bounded (<~3%) once saturated.

use layerkv::experiments as exp;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = exp::fig6_7();
    exp::print_fig6(&rows);
    println!("\n(fig6 sweep took {:.1}s)", t0.elapsed().as_secs_f64());
}
