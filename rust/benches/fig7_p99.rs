//! Regenerates Fig. 7: P99 TTFT across arrival rates (same runs as Fig. 6).
//!
//! Expected shape (paper): tail latency gap even larger than the mean gap
//! (paper reports up to 45x P99 reduction).

use layerkv::experiments as exp;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = exp::fig6_7();
    exp::print_fig7(&rows);
    println!("\n(fig7 sweep took {:.1}s)", t0.elapsed().as_secs_f64());
}
