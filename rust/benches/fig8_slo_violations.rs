//! Regenerates Fig. 8: SLO violation rate (TTFT<=3s, TPOT<=200ms) across
//! arrival rates, including the LayerKV-without-SLO-scheduler ablation.
//!
//! Expected shape (paper): vLLM violations surge past ~6 req/s; LayerKV
//! stays 17.7-28.7 points lower; the no-SLO ablation trades TPOT
//! violations for TTFT and can dip below vLLM around ~5.5 req/s.

use layerkv::experiments as exp;

fn main() {
    let t0 = std::time::Instant::now();
    let rows = exp::fig8();
    exp::print_fig8(&rows);
    exp::print_table1();
    println!("\n(fig8 sweep took {:.1}s)", t0.elapsed().as_secs_f64());
}
