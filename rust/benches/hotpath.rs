//! Hot-path micro-benchmarks (the §Perf targets in DESIGN.md):
//!
//! * scheduler decision latency (vLLM + LayerKV) under a deep queue;
//! * block allocator alloc/release throughput;
//! * one simulated engine decode step;
//! * PcieLink chunked-swap scheduling;
//! * real PJRT prefill/decode latency (skipped if artifacts are absent).
//!
//! Results also land in `BENCH_hotpath.json` (name, ns/iter, iters) so the
//! perf trajectory is comparable across PRs.

use layerkv::benchutil::{bench, black_box, write_results_json};
use layerkv::config::{Policy, ServingConfig};
use layerkv::coordinator::block::KvManager;
use layerkv::coordinator::predict::LengthPredictor;
use layerkv::coordinator::request::{Phase, Request};
use layerkv::coordinator::{run_trace, Engine};
use layerkv::coordinator::scheduler::{
    LayerKvScheduler, SchedContext, Scheduler, VllmScheduler,
};
use layerkv::sim::{BusyWindow, CostModel, PcieLink};
use layerkv::util::Rng;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::TraceRequest;

/// Deep-queue scheduler fixture: 64 decoding requests holding most of the
/// pool, 512 waiting long prompts behind them.
struct SchedFixture {
    cfg: ServingConfig,
    cost: CostModel,
    kv: KvManager,
    requests: Vec<Request>,
    waiting: Vec<usize>,
    running: Vec<usize>,
}

impl SchedFixture {
    fn new(policy: Policy) -> Self {
        Self::with_pool(policy, 200_000)
    }

    fn with_pool(policy: Policy, gpu_layer_blocks: usize) -> Self {
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
        let cost = CostModel::new(cfg.clone());
        let mut kv =
            KvManager::new(gpu_layer_blocks, 1_000_000, cfg.block_size, cfg.model.n_layers);
        let mut requests = Vec::new();
        let mut running = Vec::new();
        for i in 0..64usize {
            let id = requests.len();
            let mut r = Request::from_trace(
                &TraceRequest { id, arrival: 0.0, prompt_len: 1024, output_len: 512, ..Default::default() },
                (256, 512),
            );
            r.phase = Phase::Decoding;
            r.generated = 32;
            r.prefill_start = Some(0.1 + i as f64 * 0.05);
            r.first_token = Some(0.2 + i as f64 * 0.05);
            requests.push(r);
            kv.allocate_full(id, 1024 + 32).expect("fixture decode alloc");
            running.push(id);
        }
        let mut waiting = Vec::new();
        for _ in 0..512usize {
            let id = requests.len();
            requests.push(Request::from_trace(
                &TraceRequest { id, arrival: 1.0, prompt_len: 8192, output_len: 512, ..Default::default() },
                (256, 512),
            ));
            waiting.push(id);
        }
        SchedFixture { cfg, cost, kv, requests, waiting, running }
    }

    fn ctx(&self, now: f64) -> SchedContext<'_> {
        SchedContext {
            now,
            waiting: &self.waiting,
            running: &self.running,
            requests: &self.requests,
            kv: &self.kv,
            cost: &self.cost,
            cfg: &self.cfg,
        }
    }
}

fn main() {
    // --- scheduler decision latency -----------------------------------
    {
        let f = SchedFixture::new(Policy::Vllm);
        let mut s = VllmScheduler::new();
        bench("scheduler/vllm_decide_deep_queue", 2.0, || {
            black_box(s.decide(&f.ctx(5.0)));
        });
    }
    {
        let f = SchedFixture::new(Policy::LayerKv { slo_aware: true });
        let mut s = LayerKvScheduler::new(true);
        s.observe_decode_step(0.15);
        bench("scheduler/layerkv_decide_deep_queue", 2.0, || {
            black_box(s.decide(&f.ctx(5.0)));
        });
        // tight pool so the Eq. 5 forecast actually runs (the 25%-free
        // fast-path gate would skip it on the roomy fixture); fresh
        // scheduler because the threshold cache is per-pool, as in
        // production where make_scheduler is per-engine
        let tight = SchedFixture::with_pool(Policy::LayerKv { slo_aware: true }, 150_000);
        let mut st = LayerKvScheduler::new(true);
        st.observe_decode_step(0.15);
        bench("scheduler/layerkv_proactive_offload_check", 2.0, || {
            black_box(st.proactive_offloads(&tight.ctx(5.0)));
        });
    }

    // --- allocator ----------------------------------------------------
    bench("kv_manager/alloc_release_64_layerwise", 2.0, || {
        let mut m = KvManager::new(100_000, 500_000, 16, 32);
        for i in 0..64 {
            m.allocate_layerwise(i, 2048, 4).unwrap();
        }
        for i in 0..64 {
            m.release(i).unwrap();
        }
        black_box(m.gpu.available());
    });

    bench("kv_manager/append_token_4096", 2.0, || {
        let mut m = KvManager::new(200_000, 200_000, 16, 32);
        m.allocate_layerwise(0, 16, 32).unwrap();
        for _ in 0..4096 {
            m.append_token(0).unwrap();
        }
        m.release(0).unwrap();
    });

    // §Perf guard for the tier-query fix: the per-step residency queries
    // are O(1) aggregate reads and the per-tier index walks are
    // allocation-free iterators (formerly Vec-returning).
    {
        let mut m = KvManager::new_tiered(100_000, 100_000, 100_000, 16, 32);
        for i in 0..64 {
            m.allocate_layerwise(i, 2048, 8).unwrap();
        }
        for i in 0..64 {
            for layer in 0..8usize {
                let _ = m.spill_layer(i, layer);
            }
        }
        bench("kv_manager/tier_query", 2.0, || {
            let mut acc = 0usize;
            for i in 0..64 {
                let t = m.table(i).unwrap();
                acc += t.n_gpu_layers() + t.n_cpu_layers() + t.n_disk_layers();
                acc += usize::from(t.fully_resident());
                acc += t.gpu_layers().sum::<usize>();
                acc += t.cpu_layers().sum::<usize>();
                acc += t.disk_layers().sum::<usize>();
            }
            black_box(acc);
        });
        for i in 0..64 {
            m.release(i).unwrap();
        }
    }

    // --- cluster routing ------------------------------------------------
    // One route() call over 8 heterogeneously-loaded replica views, per
    // policy. Routing runs once per arriving request at fleet scale, so
    // it must stay allocation-free and O(replicas) — this series guards
    // that alongside kv_manager/*.
    {
        use layerkv::cluster::{make_router, ReplicaView, RouterPolicy};
        let cfg = ServingConfig::llama2_7b_tp1();
        let cost = CostModel::new(cfg.clone());
        let kvs: Vec<KvManager> = (0..8)
            .map(|i| {
                let mut m =
                    KvManager::new(100_000, 500_000, cfg.block_size, cfg.model.n_layers);
                for r in 0..(i * 6) {
                    m.allocate_layerwise(r, 2048, 8).unwrap();
                }
                m
            })
            .collect();
        let views: Vec<ReplicaView> = kvs
            .iter()
            .enumerate()
            .map(|(i, kv)| ReplicaView {
                idx: i,
                waiting_len: i * 3,
                running_len: i * 6,
                waiting_tokens: i * 3 * 900,
                running_tokens: i * 6 * 2056,
                waiting_prefill_s: i as f64 * 0.3,
                running_remaining_tokens: i * 6 * 128,
                slowdown: 1.0,
                kv,
                cost: &cost,
                cfg: &cfg,
            })
            .collect();
        for policy in RouterPolicy::ALL {
            let mut router = make_router(*policy, 8);
            for i in 0..8 {
                router.observe_ttft(i, 0.1 + i as f64 * 0.05);
            }
            let name = format!("cluster/route_decision_{}", policy.name());
            bench(&name, 1.0, || {
                black_box(router.route(4096, &views));
            });
        }
    }

    // --- pcie link ------------------------------------------------------
    let busy: Vec<BusyWindow> = (0..100)
        .map(|i| BusyWindow { start: i as f64 * 0.01, end: i as f64 * 0.01 + 0.004 })
        .collect();
    let link = PcieLink::new(13.0e9, 10e-6, true);
    bench("pcie/schedule_swap_1GB_100_windows", 2.0, || {
        black_box(link.schedule_swap(0.0, 1.0e9, &busy));
    });

    // --- whole-engine step throughput ----------------------------------
    for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
        let name = format!("engine/steps_per_run_{}", policy.name());
        bench(&name, 5.0, || {
            let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
            let trace = FixedWorkload {
                prompt_len: 2048,
                output_len: 64,
                n_requests: 20,
                arrivals: Arrivals::Poisson { rate: 2.0 },
            }
            .generate(&mut Rng::new(5));
            black_box(run_trace(cfg, &trace, 0.8));
        });
    }

    // --- unified coordinator (ExecutionBackend seam overhead) -----------
    // One iteration = one full Engine::<SimBackend> run of a FIXED mini
    // trace (same seed, same config every PR), so the series tracks the
    // per-step cost of the backend seam across PRs. Dispatch is
    // monomorphised — this should sit at the pre-refactor engine level.
    {
        let trace = FixedWorkload {
            prompt_len: 512,
            output_len: 32,
            n_requests: 8,
            arrivals: Arrivals::Poisson { rate: 4.0 },
        }
        .generate(&mut Rng::new(9));
        for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
            let name = format!("engine/unified_step_{}", policy.name());
            bench(&name, 2.0, || {
                let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
                let mut e = Engine::new(cfg, LengthPredictor::new(64, 0.8, 42));
                black_box(e.run(&trace));
            });
        }
    }

    // --- decode fast-forward (macro-stepping) ---------------------------
    // Long-decode trace: the O(total output tokens) decode tail the event
    // horizon collapses to O(events). Same trace, same seed, macro on vs
    // off — results are property-tested bit-identical, so the gap is pure
    // scheduler-invocation and step-loop overhead.
    {
        let trace = FixedWorkload {
            prompt_len: 1024,
            output_len: 768,
            n_requests: 12,
            arrivals: Arrivals::Poisson { rate: 4.0 },
        }
        .generate(&mut Rng::new(17));
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for (name, on) in [
            ("engine/fastforward_on_long_decode", true),
            ("engine/fastforward_off_long_decode", false),
        ] {
            let cfg = cfg.clone();
            let trace = &trace;
            bench(name, 3.0, || {
                let mut e = Engine::new(cfg.clone(), LengthPredictor::new(1024, 0.8, 42));
                e.set_macro_steps(on);
                black_box(e.run(trace));
            });
        }
        // context for the series: the invocation gap behind the time gap
        let mut fast = Engine::new(cfg.clone(), LengthPredictor::new(1024, 0.8, 42));
        fast.set_macro_steps(true);
        let _ = fast.run(&trace);
        let mut slow = Engine::new(cfg, LengthPredictor::new(1024, 0.8, 42));
        slow.set_macro_steps(false);
        let _ = slow.run(&trace);
        println!(
            "fastforward: {} scheduler invocations (macro) vs {} (single-step) = {:.1}x fewer",
            fast.sched_invocations(),
            slow.sched_invocations(),
            slow.sched_invocations() as f64 / fast.sched_invocations().max(1) as f64,
        );
        // roofline context: the KV traffic the skipped steps stand for
        // (12 lanes of 1024-token prompts decoding 768 tokens each)
        let span_gb = fast.cost.decode_span_kv_bytes(12 * 1024, 12, 768) / 1e9;
        println!("fastforward: macro-stepped tail streams ~{span_gb:.0} GB of modeled KV");
    }

    // --- cluster lockstep skip ------------------------------------------
    // The lockstep loop advances each replica to the next routed arrival;
    // with fast-forwarding a stable replica gets there in one macro-step
    // instead of one step_once per decode token.
    {
        use layerkv::cluster::{Cluster, ClusterConfig, RouterPolicy};
        let trace = FixedWorkload {
            prompt_len: 1024,
            output_len: 384,
            n_requests: 48,
            arrivals: Arrivals::bursty(6.0, 3.0),
        }
        .generate(&mut Rng::new(29));
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for (name, on) in [
            ("cluster/lockstep_skip_on", true),
            ("cluster/lockstep_skip_off", false),
        ] {
            let ccfg = ClusterConfig::homogeneous(&cfg, 4, RouterPolicy::KvPressure);
            let trace = &trace;
            bench(name, 3.0, || {
                let mut c = Cluster::new(&ccfg);
                c.set_macro_steps(on);
                black_box(c.run(trace).expect("sim cluster run"));
            });
        }
    }

    // --- cluster event heap vs lockstep ---------------------------------
    // The same fleet trace on both drives: the heap pops only the
    // replicas whose horizons land (O(total events)); lockstep touches
    // every replica at every arrival. Bit-identical results — the prop
    // suite pins them — so the gap is pure drive overhead.
    {
        use layerkv::cluster::{Cluster, ClusterConfig, RouterPolicy};
        let trace = FixedWorkload {
            prompt_len: 1024,
            output_len: 384,
            n_requests: 96,
            arrivals: Arrivals::bursty(12.0, 3.0),
        }
        .generate(&mut Rng::new(37));
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for (name, lockstep) in [
            ("cluster/heap_pop_heap", false),
            ("cluster/heap_pop_lockstep", true),
        ] {
            let ccfg = ClusterConfig::homogeneous(&cfg, 8, RouterPolicy::KvPressure);
            let trace = &trace;
            bench(name, 3.0, || {
                let mut c = Cluster::new(&ccfg);
                c.set_lockstep(lockstep);
                black_box(c.run(trace).expect("sim cluster run"));
            });
        }
        // context for the series: the advance gap behind the time gap
        let ccfg = ClusterConfig::homogeneous(&cfg, 8, RouterPolicy::KvPressure);
        let mut heap = Cluster::new(&ccfg);
        let _ = heap.run(&trace).expect("sim cluster run");
        let mut lock = Cluster::new(&ccfg);
        lock.set_lockstep(true);
        let _ = lock.run(&trace).expect("sim cluster run");
        println!(
            "event heap: {} replica advances vs {} lockstep = {:.1}x fewer",
            heap.advances(),
            lock.advances(),
            lock.advances() as f64 / heap.advances().max(1) as f64,
        );
    }

    // --- engine horizon query -------------------------------------------
    // The heap's arming call on a stable all-decoding engine. Stable:
    // span already cached, the query reads span_end (O(1)). Replan: an
    // invalidation (the no-op slowdown write) forces every query through
    // the horizon solver — the cost a submit/fault pays to re-arm.
    {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let trace = FixedWorkload {
            prompt_len: 512,
            output_len: 256,
            n_requests: 8,
            arrivals: Arrivals::Burst,
        }
        .generate(&mut Rng::new(41));
        let p = LengthPredictor::new(256, 0.8, 42);
        let mut e = Engine::new(cfg, LengthPredictor::new(256, 0.8, 42));
        for tr in &trace.requests {
            e.submit(tr, p.predict(tr.id, tr.output_len));
        }
        // step into the stable all-decoding regime the span cache covers
        let mut guard = 0;
        loop {
            let h = e.next_event_horizon();
            if h.is_finite() && h > e.now() {
                break; // span planned and cached
            }
            guard += 1;
            assert!(guard < 10_000, "bench fixture never reached a stable span");
            assert!(
                e.step_once(true).expect("sim engine"),
                "bench fixture engine blocked before a span formed"
            );
        }
        bench("cluster/horizon_query_stable", 2.0, || {
            black_box(e.next_event_horizon());
        });
        bench("cluster/horizon_query_replan", 2.0, || {
            e.set_slowdown(1.0);
            black_box(e.next_event_horizon());
        });
    }

    // --- predictor ------------------------------------------------------
    let p = LengthPredictor::new(2048, 0.8, 1);
    bench("predictor/predict", 1.0, || {
        for id in 0..1000 {
            black_box(p.predict(id, 300));
        }
    });

    // --- observability hot path -----------------------------------------
    // The per-event cost an instrumented run pays: lifecycle records
    // through the handle (lock + ring push, overwrite-oldest, 1000 per
    // iter like predictor/predict) and one full gauge sweep (8 samples
    // read straight off live engine state). Tracing OFF is a single
    // branch per hook — this series prices tracing ON.
    {
        use layerkv::obs::{EventKind, TraceHandle, TraceRecord};
        let h = TraceHandle::new(1 << 16, 1 << 14);
        let mut t = 0.0f64;
        bench("obs/trace_record", 1.0, || {
            for i in 0..1000u64 {
                t += 1e-4;
                h.record(TraceRecord {
                    t0: t,
                    t1: t + 5e-5,
                    kind: EventKind::Decode,
                    track: (i % 4) as u32,
                    req: i,
                    a: 1,
                    b: 0,
                    c: 0,
                });
            }
            black_box(h.lock().spans_len());
        });

        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let trace = FixedWorkload {
            prompt_len: 512,
            output_len: 64,
            n_requests: 16,
            arrivals: Arrivals::Burst,
        }
        .generate(&mut Rng::new(7));
        let p = LengthPredictor::new(64, 0.8, 42);
        let mut e = Engine::new(cfg, LengthPredictor::new(64, 0.8, 42));
        e.set_tracer(h.clone());
        for tr in &trace.requests {
            e.submit(tr, p.predict(tr.id, tr.output_len));
        }
        bench("obs/gauge_sample", 1.0, || {
            e.trace_sample_gauges();
        });
        black_box(e.now());
    }

    // --- real PJRT path --------------------------------------------------
    let dir = layerkv::runtime::artifacts::default_dir();
    if dir.join("manifest.json").exists() {
        let model = layerkv::runtime::TinyModel::load(&dir).expect("artifacts");
        let prompt: Vec<i32> = (0..120).map(|i| (i * 5) % 256).collect();
        bench("pjrt/prefill_t128", 5.0, || {
            black_box(model.prefill(&prompt).unwrap());
        });
        let m = &model.art.model;
        let b = 4usize;
        let per_layer = b * 2 * m.n_kv_heads * m.max_seq * m.head_dim;
        let mut kvs: Vec<Vec<f32>> = (0..m.n_layers).map(|_| vec![0.0f32; per_layer]).collect();
        let tokens = vec![1i32; b];
        let lens = vec![64i32; b];
        bench("pjrt/decode_b4", 5.0, || {
            black_box(model.decode(&tokens, &lens, &mut kvs).unwrap());
        });
        if model.has_paged_kernel() {
            let q = vec![0.1f32; 4 * m.n_heads * m.head_dim];
            let pages = vec![0.1f32; 64 * 2 * m.n_kv_heads * 16 * m.head_dim];
            let table: Vec<i32> = (0..64).cycle().take(4 * 16).collect();
            let lens = vec![100i32; 4];
            bench("pjrt/paged_attn_kernel", 5.0, || {
                black_box(
                    model
                        .paged_attn(
                            &q,
                            &[4, m.n_heads, m.head_dim],
                            &pages,
                            &[64, 2, m.n_kv_heads, 16, m.head_dim],
                            &table,
                            &[4, 16],
                            &lens,
                        )
                        .unwrap(),
                );
            });
        }
    } else {
        println!("pjrt benches skipped: run `make artifacts` first");
    }

    // machine-readable perf trajectory, tracked across PRs
    write_results_json("BENCH_hotpath.json").expect("writing bench json");
}
