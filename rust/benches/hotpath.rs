//! Hot-path micro-benchmarks (the §Perf targets in DESIGN.md):
//!
//! * scheduler decision latency (vLLM + LayerKV) under a deep queue;
//! * block allocator alloc/release throughput;
//! * one simulated engine decode step;
//! * PcieLink chunked-swap scheduling;
//! * real PJRT prefill/decode latency (skipped if artifacts are absent).

use layerkv::benchutil::{bench, black_box};
use layerkv::config::{Policy, ServingConfig};
use layerkv::coordinator::block::KvManager;
use layerkv::coordinator::predict::LengthPredictor;
use layerkv::coordinator::run_trace;
use layerkv::sim::{BusyWindow, PcieLink};
use layerkv::util::Rng;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::arrivals::Arrivals;

fn main() {
    // --- allocator ----------------------------------------------------
    bench("kv_manager/alloc_release_64_layerwise", 2.0, || {
        let mut m = KvManager::new(100_000, 500_000, 16, 32);
        for i in 0..64 {
            m.allocate_layerwise(i, 2048, 4).unwrap();
        }
        for i in 0..64 {
            m.release(i).unwrap();
        }
        black_box(m.gpu.available());
    });

    bench("kv_manager/append_token_4096", 2.0, || {
        let mut m = KvManager::new(200_000, 200_000, 16, 32);
        m.allocate_layerwise(0, 16, 32).unwrap();
        for _ in 0..4096 {
            m.append_token(0).unwrap();
        }
        m.release(0).unwrap();
    });

    // --- pcie link ------------------------------------------------------
    let busy: Vec<BusyWindow> = (0..100)
        .map(|i| BusyWindow { start: i as f64 * 0.01, end: i as f64 * 0.01 + 0.004 })
        .collect();
    let link = PcieLink::new(13.0e9, 10e-6, true);
    bench("pcie/schedule_swap_1GB_100_windows", 2.0, || {
        black_box(link.schedule_swap(0.0, 1.0e9, &busy));
    });

    // --- whole-engine step throughput ----------------------------------
    for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
        let name = format!("engine/steps_per_run_{}", policy.name());
        bench(&name, 5.0, || {
            let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
            let trace = FixedWorkload {
                prompt_len: 2048,
                output_len: 64,
                n_requests: 20,
                arrivals: Arrivals::Poisson { rate: 2.0 },
            }
            .generate(&mut Rng::new(5));
            black_box(run_trace(cfg, &trace, 0.8));
        });
    }

    // --- predictor ------------------------------------------------------
    let p = LengthPredictor::new(2048, 0.8, 1);
    bench("predictor/predict", 1.0, || {
        for id in 0..1000 {
            black_box(p.predict(id, 300));
        }
    });

    // --- real PJRT path --------------------------------------------------
    let dir = layerkv::runtime::artifacts::default_dir();
    if dir.join("manifest.json").exists() {
        let model = layerkv::runtime::TinyModel::load(&dir).expect("artifacts");
        let prompt: Vec<i32> = (0..120).map(|i| (i * 5) % 256).collect();
        bench("pjrt/prefill_t128", 5.0, || {
            black_box(model.prefill(&prompt).unwrap());
        });
        let m = &model.art.model;
        let b = 4usize;
        let per_layer = b * 2 * m.n_kv_heads * m.max_seq * m.head_dim;
        let mut kvs: Vec<Vec<f32>> = (0..m.n_layers).map(|_| vec![0.0f32; per_layer]).collect();
        let tokens = vec![1i32; b];
        let lens = vec![64i32; b];
        bench("pjrt/decode_b4", 5.0, || {
            black_box(model.decode(&tokens, &lens, &mut kvs).unwrap());
        });
        if model.has_paged_kernel() {
            let q = vec![0.1f32; 4 * m.n_heads * m.head_dim];
            let pages = vec![0.1f32; 64 * 2 * m.n_kv_heads * 16 * m.head_dim];
            let table: Vec<i32> = (0..64).cycle().take(4 * 16).collect();
            let lens = vec![100i32; 4];
            bench("pjrt/paged_attn_kernel", 5.0, || {
                black_box(
                    model
                        .paged_attn(
                            &q,
                            &[4, m.n_heads, m.head_dim],
                            &pages,
                            &[64, 2, m.n_kv_heads, 16, m.head_dim],
                            &table,
                            &[4, 16],
                            &lens,
                        )
                        .unwrap(),
                );
            });
        }
    } else {
        println!("pjrt benches skipped: run `make artifacts` first");
    }
}
