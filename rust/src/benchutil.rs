//! Minimal benchmark harness (in-tree substitute for `criterion`,
//! unavailable offline — DESIGN.md §2).
//!
//! Benches are `harness = false` binaries: they time closures with warmup,
//! report mean / stddev / min like criterion's summary line, and print the
//! experiment tables the paper's figures correspond to. `cargo bench`
//! runs them all.
//!
//! Every `bench()` result is also recorded in-process; a bench binary
//! calls `write_results_json` before exiting to dump the machine-readable
//! series (name, ns/iter, iters) — `hotpath.rs` writes `BENCH_hotpath.json`
//! so the perf trajectory is tracked across PRs.

use std::sync::Mutex;
use std::time::Instant;

/// Results recorded by `bench()` in program order.
static RESULTS: Mutex<Vec<BenchResult>> = Mutex::new(Vec::new());

/// Timing summary for one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<44} {:>10}  ±{:>9}  (min {:>9}, n={})",
            self.name,
            fmt_time(self.mean_s),
            fmt_time(self.std_s),
            fmt_time(self.min_s),
            self.iters
        );
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Time `f` adaptively: warm up, then run until ~`budget_s` seconds or
/// `max_iters`, whichever first. Returns per-iteration stats.
pub fn bench<F: FnMut()>(name: &str, budget_s: f64, mut f: F) -> BenchResult {
    // warmup
    let warm = Instant::now();
    let mut warm_iters = 0usize;
    while warm.elapsed().as_secs_f64() < budget_s * 0.2 && warm_iters < 3 {
        f();
        warm_iters += 1;
    }
    let mut samples: Vec<f64> = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < budget_s && samples.len() < 10_000 {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    if samples.is_empty() {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    let n = samples.len();
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = if n > 1 {
        samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
    } else {
        0.0
    };
    let min = samples.iter().copied().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        iters: n,
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: min,
    };
    r.print();
    RESULTS.lock().unwrap().push(r.clone());
    r
}

/// Minimal JSON string escaping (names are plain identifiers, but stay
/// strict anyway).
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// All results recorded so far, rendered as a JSON array of
/// `{name, ns_per_iter, std_ns, min_ns, iters}` objects.
pub fn results_json() -> String {
    let results = RESULTS.lock().unwrap();
    let mut out = String::from("[\n");
    for (i, r) in results.iter().enumerate() {
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"ns_per_iter\": {:.1}, \"std_ns\": {:.1}, \"min_ns\": {:.1}, \"iters\": {}}}{}\n",
            json_escape(&r.name),
            r.mean_s * 1e9,
            r.std_s * 1e9,
            r.min_s * 1e9,
            r.iters,
            if i + 1 < results.len() { "," } else { "" },
        ));
    }
    out.push_str("]\n");
    out
}

/// Dump every recorded result to `path` (bench binaries call this last;
/// `hotpath.rs` uses `BENCH_hotpath.json`). `LAYERKV_BENCH_JSON` overrides
/// the destination.
pub fn write_results_json(path: &str) -> std::io::Result<()> {
    let path = std::env::var("LAYERKV_BENCH_JSON").unwrap_or_else(|_| path.to_string());
    std::fs::write(&path, results_json())?;
    println!("bench results written to {path}");
    Ok(())
}

/// Black-box to keep the optimizer honest.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_stats() {
        let r = bench("noop-spin", 0.05, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters >= 1);
        assert!(r.mean_s >= 0.0);
        assert!(r.min_s <= r.mean_s + 1e-12);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).contains("µs"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }

    #[test]
    fn results_json_records_benches() {
        bench("json-probe", 0.01, || {
            black_box(1 + 1);
        });
        let json = results_json();
        assert!(json.starts_with('['));
        assert!(json.trim_end().ends_with(']'));
        assert!(json.contains("\"name\": \"json-probe\""));
        assert!(json.contains("\"ns_per_iter\""));
        assert!(json.contains("\"iters\""));
    }

    #[test]
    fn json_escaping() {
        assert_eq!(json_escape("plain/name_1"), "plain/name_1");
        assert_eq!(json_escape("a\"b\\c"), "a\\\"b\\\\c");
    }
}
