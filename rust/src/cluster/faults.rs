//! Deterministic fault injection for cluster runs: a [`FaultPlan`] is a
//! virtual-time schedule of replica crash/recover windows, straggler
//! slowdown windows, and disk-tier I/O error bursts. The cluster compiles
//! it to a time-sorted [`FaultEvent`] stream and interleaves it with the
//! trace's arrivals — merged into the cluster-wide event heap on the
//! default drive, scanned per arrival on the lockstep oracle; both apply
//! the stream in the identical compiled order, so a (plan, trace, seed)
//! triple replays byte-identically — crashes included.
//!
//! The empty plan is the load-bearing special case: `Cluster::with_faults`
//! on `FaultPlan::default()` must be **bit-identical** to a cluster built
//! without faults (`tests/prop_faults.rs` pins this), which is why
//! [`HealthRouter`] delegates with the caller's untouched view slice
//! whenever no replica is down or in probation.
//!
//! The health model:
//! * **down** — crashed replicas are fenced: never routed to, their
//!   engine drained (admission closed, unfinished requests exported).
//! * **probation** — a freshly recovered replica is routable but
//!   deprioritized for `probation_s` seconds: it only receives requests
//!   when every non-probation replica is down. Its pools are cold and its
//!   EWMA feedback stale; probation keeps one recovery from instantly
//!   re-absorbing the load that crashed it.
//! * **stragglers** — not a health state but a view signal: the backend's
//!   `slowdown()` factor rides into [`ReplicaView`] and the
//!   `kv-pressure`/`slo-aware` scores stretch their estimates by it.

use std::cell::RefCell;
use std::rc::Rc;

use crate::metrics::{FaultEvent, FaultKind};
use crate::util::Rng;

use super::router::{ReplicaView, Router};

/// One replica crash window: down at `at`, back at `recover_at`
/// (`f64::INFINITY` = never recovers).
#[derive(Debug, Clone, PartialEq)]
pub struct CrashWindow {
    pub replica: usize,
    pub at: f64,
    pub recover_at: f64,
}

/// One straggler window: the replica's backend runs `slowdown`x slower
/// between `from` and `until`.
#[derive(Debug, Clone, PartialEq)]
pub struct Straggler {
    pub replica: usize,
    pub from: f64,
    pub until: f64,
    /// Factor >= 1.0 (1.0 is nominal).
    pub slowdown: f64,
}

/// One disk-tier I/O error burst: every spill/restore on the replica
/// fails between `from` and `until` (K consecutive failures fence the
/// tier — see `Engine::set_disk_faulty`).
#[derive(Debug, Clone, PartialEq)]
pub struct IoBurst {
    pub replica: usize,
    pub from: f64,
    pub until: f64,
}

/// One planned live migration: at `at`, replica `src` drains with full
/// state ([`crate::coordinator::Engine::drain_with_state`]) and `dst`
/// adopts every exported request; `src` is then fenced for the rest of
/// the run (scale-down / rebalance semantics — administratively down,
/// not crashed, so nothing counts against retry budgets).
#[derive(Debug, Clone, PartialEq)]
pub struct Migration {
    pub src: usize,
    pub dst: usize,
    pub at: f64,
}

/// A deterministic, virtual-time fault schedule for one cluster run.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub crashes: Vec<CrashWindow>,
    pub stragglers: Vec<Straggler>,
    pub io_bursts: Vec<IoBurst>,
    pub migrations: Vec<Migration>,
    /// Max re-submissions per request after crash drains; a request
    /// drained more than this many times is failed, exactly once.
    pub retry_budget: u32,
    /// Seconds a recovered replica stays deprioritized.
    pub probation_s: f64,
}

impl Default for FaultPlan {
    fn default() -> Self {
        FaultPlan {
            crashes: Vec::new(),
            stragglers: Vec::new(),
            io_bursts: Vec::new(),
            migrations: Vec::new(),
            retry_budget: 2,
            probation_s: 5.0,
        }
    }
}

impl FaultPlan {
    /// No faults scheduled (budget/probation knobs don't count: with no
    /// events they can never fire).
    pub fn is_empty(&self) -> bool {
        self.crashes.is_empty()
            && self.stragglers.is_empty()
            && self.io_bursts.is_empty()
            && self.migrations.is_empty()
    }

    /// Seeded random plan over `n_replicas` replicas and a `horizon_s`
    /// run window — the property suite's generator. Same seed, same plan.
    /// Never crashes all replicas at once is NOT guaranteed; conservation
    /// must hold anyway (requests park until a recovery, or fail).
    pub fn generate(seed: u64, n_replicas: usize, horizon_s: f64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0xFA417);
        let mut plan = FaultPlan {
            retry_budget: rng.range(0, 4) as u32,
            probation_s: rng.f64() * horizon_s * 0.2,
            ..FaultPlan::default()
        };
        for replica in 0..n_replicas {
            if rng.chance(0.5) {
                let at = rng.f64() * horizon_s;
                let recover_at = if rng.chance(0.25) {
                    f64::INFINITY // permanent loss
                } else {
                    at + rng.f64() * horizon_s * 0.5
                };
                plan.crashes.push(CrashWindow { replica, at, recover_at });
            }
            if rng.chance(0.4) {
                let from = rng.f64() * horizon_s;
                plan.stragglers.push(Straggler {
                    replica,
                    from,
                    until: from + rng.f64() * horizon_s * 0.5,
                    slowdown: 1.5 + rng.f64() * 6.5,
                });
            }
            if rng.chance(0.4) {
                let from = rng.f64() * horizon_s;
                plan.io_bursts.push(IoBurst {
                    replica,
                    from,
                    until: from + rng.f64() * horizon_s * 0.5,
                });
            }
        }
        plan
    }

    /// Compile to a time-sorted event stream. Window ends at or before
    /// their starts are dropped (zero-length crash windows still fire:
    /// crash sorts before recover at the same instant, so the drain +
    /// failover happens). Ties order by (time, kind rank, replica) — a
    /// total order, so the stream is deterministic for a given plan.
    pub fn events(&self) -> Vec<FaultEvent> {
        let mut evs = Vec::new();
        for c in &self.crashes {
            evs.push(FaultEvent { t: c.at, replica: c.replica, kind: FaultKind::Crash });
            if c.recover_at.is_finite() && c.recover_at >= c.at {
                evs.push(FaultEvent {
                    t: c.recover_at,
                    replica: c.replica,
                    kind: FaultKind::Recover,
                });
            }
        }
        for s in &self.stragglers {
            if s.until <= s.from || s.slowdown == 1.0 {
                continue;
            }
            evs.push(FaultEvent {
                t: s.from,
                replica: s.replica,
                kind: FaultKind::StragglerStart { slowdown: s.slowdown },
            });
            if s.until.is_finite() {
                evs.push(FaultEvent {
                    t: s.until,
                    replica: s.replica,
                    kind: FaultKind::StragglerEnd,
                });
            }
        }
        for b in &self.io_bursts {
            if b.until <= b.from {
                continue;
            }
            evs.push(FaultEvent {
                t: b.from,
                replica: b.replica,
                kind: FaultKind::IoErrorStart,
            });
            if b.until.is_finite() {
                evs.push(FaultEvent {
                    t: b.until,
                    replica: b.replica,
                    kind: FaultKind::IoErrorEnd,
                });
            }
        }
        for m in &self.migrations {
            evs.push(FaultEvent {
                t: m.at,
                replica: m.src,
                kind: FaultKind::Migrate { dst: m.dst },
            });
        }
        // total_cmp, not partial_cmp: `validate()` rejects NaN times at
        // every construction edge, but a sort must never be the thing
        // that panics on a hostile plan (this used to be a user-reachable
        // `.expect` via `--faults`)
        evs.sort_by(|a, b| {
            a.t.total_cmp(&b.t)
                .then(a.kind.rank().cmp(&b.kind.rank()))
                .then(a.replica.cmp(&b.replica))
        });
        evs
    }

    /// Reject plans whose times could poison the event stream or the
    /// cluster clock: every start must be finite and non-negative; every
    /// end must be >= its start and never NaN (`INFINITY` = open window);
    /// slowdowns must be finite and >= 1; probation must be finite and
    /// non-negative. Called by `parse_spec` so a hostile `--faults` spec
    /// is a parse error, and available to programmatic builders.
    pub fn validate(&self) -> Result<(), String> {
        let closed = |what: &str, t1: f64, t2: f64| -> Result<(), String> {
            if !t1.is_finite() || t1 < 0.0 {
                return Err(format!("{what}: start {t1} must be finite and >= 0"));
            }
            if t2.is_nan() || t2 < t1 {
                return Err(format!("{what}: end {t2} invalid for start {t1}"));
            }
            Ok(())
        };
        for c in &self.crashes {
            closed(&format!("crash on replica {}", c.replica), c.at, c.recover_at)?;
        }
        for s in &self.stragglers {
            closed(&format!("straggler on replica {}", s.replica), s.from, s.until)?;
            if !s.slowdown.is_finite() || s.slowdown < 1.0 {
                return Err(format!(
                    "straggler on replica {}: slowdown {} must be finite and >= 1",
                    s.replica, s.slowdown
                ));
            }
        }
        for b in &self.io_bursts {
            closed(&format!("io burst on replica {}", b.replica), b.from, b.until)?;
        }
        for m in &self.migrations {
            if !m.at.is_finite() || m.at < 0.0 {
                return Err(format!(
                    "migration {} -> {}: time {} must be finite and >= 0",
                    m.src, m.dst, m.at
                ));
            }
            if m.src == m.dst {
                return Err(format!("migration {} -> {}: source equals destination", m.src, m.dst));
            }
        }
        if !self.probation_s.is_finite() || self.probation_s < 0.0 {
            return Err(format!("probation {} must be finite and >= 0", self.probation_s));
        }
        // Overlapping crash windows on the same replica would double-drain
        // it: the second crash fires while the replica is already down and
        // empty, and its recover re-opens a window the first crash still
        // owns. Touching windows (next starts exactly when the previous
        // recovers) and zero-length windows stay legal — only a strict
        // overlap is a plan bug.
        let mut by_replica: std::collections::BTreeMap<usize, Vec<(f64, f64)>> =
            std::collections::BTreeMap::new();
        for c in &self.crashes {
            by_replica.entry(c.replica).or_default().push((c.at, c.recover_at));
        }
        for (replica, mut windows) in by_replica {
            windows.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.total_cmp(&b.1)));
            for w in windows.windows(2) {
                if w[1].0 < w[0].1 {
                    return Err(format!(
                        "crash windows on replica {replica} overlap: \
                         [{}, {}) and [{}, {}) (a replica cannot crash while down)",
                        w[0].0, w[0].1, w[1].0, w[1].1
                    ));
                }
            }
        }
        Ok(())
    }

    /// Largest replica index any window names (for validation).
    pub fn max_replica(&self) -> Option<usize> {
        let c = self.crashes.iter().map(|c| c.replica);
        let s = self.stragglers.iter().map(|s| s.replica);
        let b = self.io_bursts.iter().map(|b| b.replica);
        let m = self.migrations.iter().flat_map(|m| [m.src, m.dst]);
        c.chain(s).chain(b).chain(m).max()
    }

    /// Parse a CLI fault spec: comma-separated clauses
    ///
    /// * `crash=R@T1:T2` — replica R down from T1 to T2 (`crash=R@T1`
    ///   never recovers)
    /// * `straggle=R@T1:T2xF` — replica R runs Fx slower from T1 to T2
    /// * `io=R@T1:T2` — replica R's disk tier errors from T1 to T2
    /// * `migrate=S>D@T` — at T, drain replica S with state and adopt
    ///   everything on replica D; S is fenced afterwards (scale-down)
    /// * `retries=N` — per-request retry budget (default 2)
    /// * `probation=S` — post-recovery probation seconds (default 5)
    ///
    /// e.g. `--faults crash=1@20:60,straggle=0@10:40x4,migrate=2>0@80,retries=3`
    pub fn parse_spec(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(',').filter(|c| !c.is_empty()) {
            let (key, val) = clause
                .split_once('=')
                .ok_or_else(|| format!("fault clause `{clause}` has no `=`"))?;
            match key {
                "retries" => {
                    plan.retry_budget =
                        val.parse().map_err(|_| format!("bad retries `{val}`"))?;
                }
                "probation" => {
                    plan.probation_s =
                        val.parse().map_err(|_| format!("bad probation `{val}`"))?;
                }
                "migrate" => {
                    let (pair, t) = val
                        .split_once('@')
                        .ok_or_else(|| format!("`{clause}`: expected S>D@T"))?;
                    let (src, dst) = pair
                        .split_once('>')
                        .ok_or_else(|| format!("`{clause}`: expected S>D@T"))?;
                    let src: usize =
                        src.parse().map_err(|_| format!("bad replica `{src}`"))?;
                    let dst: usize =
                        dst.parse().map_err(|_| format!("bad replica `{dst}`"))?;
                    let at: f64 = t.parse().map_err(|_| format!("bad time `{t}`"))?;
                    plan.migrations.push(Migration { src, dst, at });
                }
                "crash" | "straggle" | "io" => {
                    let (rep, win) = val
                        .split_once('@')
                        .ok_or_else(|| format!("`{clause}`: expected R@T1[:T2]"))?;
                    let replica: usize =
                        rep.parse().map_err(|_| format!("bad replica `{rep}`"))?;
                    match key {
                        "crash" => {
                            let (t1, t2) = parse_window(win, true)?;
                            plan.crashes.push(CrashWindow {
                                replica,
                                at: t1,
                                recover_at: t2,
                            });
                        }
                        "io" => {
                            let (t1, t2) = parse_window(win, false)?;
                            plan.io_bursts.push(IoBurst { replica, from: t1, until: t2 });
                        }
                        _ => {
                            let (range, factor) = win
                                .split_once('x')
                                .ok_or_else(|| format!("`{clause}`: expected T1:T2xF"))?;
                            let (t1, t2) = parse_window(range, false)?;
                            let slowdown: f64 = factor
                                .parse()
                                .map_err(|_| format!("bad slowdown `{factor}`"))?;
                            if slowdown < 1.0 {
                                return Err(format!("slowdown {slowdown} < 1.0"));
                            }
                            plan.stragglers.push(Straggler {
                                replica,
                                from: t1,
                                until: t2,
                                slowdown,
                            });
                        }
                    }
                }
                _ => return Err(format!("unknown fault key `{key}`")),
            }
        }
        plan.validate()?;
        Ok(plan)
    }
}

/// `T1:T2` (or bare `T1`, which means "forever" when `open_ok`). Times
/// must be finite and non-negative — Rust's float parser happily accepts
/// `NaN`, `inf`, and negatives, and a NaN here used to survive all the
/// way to the event-stream sort's `.expect` (a user-reachable panic via
/// `--faults`). `t2 < t1` alone cannot catch NaN (every comparison with
/// NaN is false), hence the explicit finiteness checks.
fn parse_window(win: &str, open_ok: bool) -> Result<(f64, f64), String> {
    let time = |s: &str| -> Result<f64, String> {
        let t: f64 = s.parse().map_err(|_| format!("bad time `{s}`"))?;
        if !t.is_finite() || t < 0.0 {
            return Err(format!("time `{s}` must be finite and >= 0"));
        }
        Ok(t)
    };
    match win.split_once(':') {
        Some((a, b)) => {
            let t1 = time(a)?;
            let t2 = time(b)?;
            if t2 < t1 {
                return Err(format!("window `{win}` ends before it starts"));
            }
            Ok((t1, t2))
        }
        None if open_ok => Ok((time(win)?, f64::INFINITY)),
        None => Err(format!("`{win}`: expected T1:T2")),
    }
}

/// Shared replica health table: the cluster's fault loop writes it, the
/// [`HealthRouter`] inside the `Box<dyn Router>` reads it (single-threaded
/// interior mutability — `Rc<RefCell>` — so the wrapper needs no API on
/// the `Router` trait).
#[derive(Debug)]
pub struct HealthState {
    pub down: Vec<bool>,
    /// Probation deadline per replica (engine virtual time).
    pub probation_until: Vec<f64>,
    /// Cluster virtual "now", advanced by the fault loop before routing.
    pub now: f64,
}

impl HealthState {
    pub fn new(n_replicas: usize) -> Self {
        HealthState {
            down: vec![false; n_replicas],
            probation_until: vec![f64::NEG_INFINITY; n_replicas],
            now: 0.0,
        }
    }

    pub fn any_up(&self) -> bool {
        self.down.iter().any(|&d| !d)
    }

    fn in_probation(&self, i: usize) -> bool {
        self.now < self.probation_until[i]
    }
}

/// Health-aware wrapper around any routing policy: fences crashed
/// replicas out of the candidate views, holds freshly recovered ones in
/// probation (used only when every non-probation replica is down), and
/// otherwise delegates — with the caller's *original* slice when nothing
/// is fenced, preserving the empty-plan bit-identity property.
pub struct HealthRouter {
    inner: Box<dyn Router>,
    state: Rc<RefCell<HealthState>>,
}

impl HealthRouter {
    pub fn new(inner: Box<dyn Router>, state: Rc<RefCell<HealthState>>) -> Self {
        HealthRouter { inner, state }
    }
}

impl Router for HealthRouter {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn route(&mut self, prompt_len: usize, views: &[ReplicaView]) -> usize {
        let st = self.state.borrow();
        let fenced = views
            .iter()
            .any(|v| st.down[v.idx] || st.in_probation(v.idx));
        if !fenced {
            drop(st);
            return self.inner.route(prompt_len, views);
        }
        // prefer healthy non-probation replicas; fall back to probation
        // ones; a fully-down cluster falls through to the caller's slice
        // (callers park instead of routing then, so this is defensive)
        let mut candidates: Vec<ReplicaView> = views
            .iter()
            .filter(|v| !st.down[v.idx] && !st.in_probation(v.idx))
            .cloned()
            .collect();
        if candidates.is_empty() {
            candidates = views.iter().filter(|v| !st.down[v.idx]).cloned().collect();
        }
        drop(st);
        if candidates.is_empty() {
            return self.inner.route(prompt_len, views);
        }
        self.inner.route(prompt_len, &candidates)
    }

    /// Same fencing as `route`, but preserving the full [`RouteQuery`]
    /// for the inner policy (a prefix-aware inner router must still see
    /// the prefix identity after crashed replicas are filtered out).
    fn route_query(&mut self, q: &super::router::RouteQuery, views: &[ReplicaView]) -> usize {
        let st = self.state.borrow();
        let fenced = views
            .iter()
            .any(|v| st.down[v.idx] || st.in_probation(v.idx));
        if !fenced {
            drop(st);
            return self.inner.route_query(q, views);
        }
        let mut candidates: Vec<ReplicaView> = views
            .iter()
            .filter(|v| !st.down[v.idx] && !st.in_probation(v.idx))
            .cloned()
            .collect();
        if candidates.is_empty() {
            candidates = views.iter().filter(|v| !st.down[v.idx]).cloned().collect();
        }
        drop(st);
        if candidates.is_empty() {
            return self.inner.route_query(q, views);
        }
        self.inner.route_query(q, &candidates)
    }

    fn observe_ttft(&mut self, replica: usize, ttft_s: f64) {
        self.inner.observe_ttft(replica, ttft_s);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::router::{make_router, RouterPolicy};
    use crate::config::ServingConfig;
    use crate::coordinator::block::KvManager;
    use crate::sim::CostModel;

    #[test]
    fn empty_plan_compiles_to_no_events() {
        let plan = FaultPlan::default();
        assert!(plan.is_empty());
        assert!(plan.events().is_empty());
        assert_eq!(plan.max_replica(), None);
    }

    #[test]
    fn events_sort_by_time_then_rank_crash_before_recover() {
        let plan = FaultPlan {
            crashes: vec![CrashWindow { replica: 1, at: 20.0, recover_at: 20.0 }],
            stragglers: vec![Straggler {
                replica: 0,
                from: 5.0,
                until: 20.0,
                slowdown: 3.0,
            }],
            io_bursts: vec![IoBurst { replica: 0, from: 25.0, until: 30.0 }],
            ..FaultPlan::default()
        };
        let evs = plan.events();
        assert_eq!(evs.len(), 6);
        assert!(evs.windows(2).all(|w| w[0].t <= w[1].t));
        // at t=20: crash (rank 0) fires before straggler-end and recover
        let at20: Vec<&FaultEvent> = evs.iter().filter(|e| e.t == 20.0).collect();
        assert_eq!(at20[0].kind, FaultKind::Crash);
        assert_eq!(at20.last().unwrap().kind, FaultKind::Recover);
        assert_eq!(plan.max_replica(), Some(1));
    }

    #[test]
    fn generate_is_seed_deterministic() {
        let a = FaultPlan::generate(7, 4, 100.0);
        let b = FaultPlan::generate(7, 4, 100.0);
        assert_eq!(a, b);
        assert_ne!(a, FaultPlan::generate(8, 4, 100.0));
        if let Some(m) = a.max_replica() {
            assert!(m < 4);
        }
    }

    #[test]
    fn spec_roundtrip_and_rejections() {
        let plan =
            FaultPlan::parse_spec("crash=1@20:60,crash=0@75,straggle=2@10:40x3.5,io=0@5:15,retries=3,probation=8")
                .unwrap();
        assert_eq!(plan.retry_budget, 3);
        assert_eq!(plan.probation_s, 8.0);
        assert_eq!(plan.crashes.len(), 2);
        assert_eq!(plan.crashes[1].recover_at, f64::INFINITY);
        assert_eq!(plan.stragglers[0].slowdown, 3.5);
        assert_eq!(plan.io_bursts[0].until, 15.0);

        assert!(FaultPlan::parse_spec("crash=1").is_err());
        assert!(FaultPlan::parse_spec("nope=3@1:2").is_err());
        assert!(FaultPlan::parse_spec("straggle=0@1:2x0.5").is_err());
        assert!(FaultPlan::parse_spec("io=0@9:4").is_err());
        assert!(FaultPlan::parse_spec("io=0@5").is_err(), "io needs a closed window");
    }

    #[test]
    fn overlapping_crash_windows_on_one_replica_are_rejected() {
        // hand-built: [10, 50) and [30, 70) on replica 1 — the second
        // crash would fire while the replica is already down (the
        // double-drain hazard), so validate refuses the plan
        let plan = FaultPlan {
            crashes: vec![
                CrashWindow { replica: 1, at: 10.0, recover_at: 50.0 },
                CrashWindow { replica: 1, at: 30.0, recover_at: 70.0 },
            ],
            ..FaultPlan::default()
        };
        let err = plan.validate().unwrap_err();
        assert!(err.contains("overlap"), "{err}");
        // a strict overlap with an open (never-recover) first window too
        let plan = FaultPlan {
            crashes: vec![
                CrashWindow { replica: 0, at: 10.0, recover_at: f64::INFINITY },
                CrashWindow { replica: 0, at: 30.0, recover_at: 40.0 },
            ],
            ..FaultPlan::default()
        };
        assert!(plan.validate().is_err());
        // the same windows on DIFFERENT replicas are fine
        let plan = FaultPlan {
            crashes: vec![
                CrashWindow { replica: 0, at: 10.0, recover_at: 50.0 },
                CrashWindow { replica: 1, at: 30.0, recover_at: 70.0 },
            ],
            ..FaultPlan::default()
        };
        assert!(plan.validate().is_ok());
        // touching windows (recover exactly at the next crash) and
        // zero-length windows stay legal — only strict overlap rejects
        let plan = FaultPlan {
            crashes: vec![
                CrashWindow { replica: 2, at: 10.0, recover_at: 20.0 },
                CrashWindow { replica: 2, at: 20.0, recover_at: 20.0 },
                CrashWindow { replica: 2, at: 25.0, recover_at: 25.0 },
            ],
            ..FaultPlan::default()
        };
        assert!(plan.validate().is_ok());
        // and the same hazard arriving via the CLI spec is a parse error
        let res = FaultPlan::parse_spec("crash=1@10:50,crash=1@30:70");
        assert!(res.is_err(), "overlapping spec must be rejected, got {res:?}");
        assert!(FaultPlan::parse_spec("crash=1@10:30,crash=1@30:70").is_ok());
    }

    #[test]
    fn migrate_spec_roundtrip_and_rejections() {
        let plan = FaultPlan::parse_spec("migrate=2>0@80,crash=1@20:60").unwrap();
        assert_eq!(plan.migrations, vec![Migration { src: 2, dst: 0, at: 80.0 }]);
        assert!(!plan.is_empty());
        assert_eq!(plan.max_replica(), Some(2));
        let evs = plan.events();
        assert!(evs
            .iter()
            .any(|e| e.replica == 2 && e.kind == FaultKind::Migrate { dst: 0 }));

        assert!(FaultPlan::parse_spec("migrate=2@80").is_err(), "needs S>D");
        assert!(FaultPlan::parse_spec("migrate=2>2@80").is_err(), "src == dst");
        assert!(FaultPlan::parse_spec("migrate=2>0@NaN").is_err());
        assert!(FaultPlan::parse_spec("migrate=2>0@-5").is_err());
        assert!(FaultPlan::parse_spec("migrate=2>0").is_err(), "needs @T");
    }

    #[test]
    fn spec_rejects_non_finite_and_negative_times() {
        // regression: a NaN time parsed fine and survived to the event
        // stream's sort, where `.expect("fault times are never NaN")`
        // panicked — user-reachable straight from `--faults crash=0@NaN`
        for bad in [
            "crash=0@NaN",
            "crash=0@nan:5",
            "crash=0@5:NaN",
            "crash=0@inf",
            "crash=0@-5",
            "crash=0@1:-2",
            "straggle=0@NaN:5x2",
            "straggle=0@0:5xNaN",
            "straggle=0@0:5xinf",
            "io=0@NaN:5",
            "io=0@-1:5",
            "probation=NaN",
            "probation=-3",
        ] {
            let res = FaultPlan::parse_spec(bad);
            assert!(res.is_err(), "`{bad}` must be rejected, got {res:?}");
        }
    }

    #[test]
    fn events_never_panic_even_on_hand_built_nan_plans() {
        // parse/validate fence the CLI, but a programmatic plan that
        // skipped `validate()` must still sort (total_cmp), not panic
        let plan = FaultPlan {
            crashes: vec![
                CrashWindow { replica: 0, at: f64::NAN, recover_at: 5.0 },
                CrashWindow { replica: 1, at: 1.0, recover_at: 2.0 },
            ],
            ..FaultPlan::default()
        };
        assert!(plan.validate().is_err());
        let evs = plan.events();
        assert!(!evs.is_empty()); // sorted under total order, no panic
    }

    #[test]
    fn generated_plans_always_validate() {
        for seed in 0..32 {
            let plan = FaultPlan::generate(seed, 4, 120.0);
            assert!(plan.validate().is_ok(), "seed {seed}: {:?}", plan.validate());
        }
    }

    struct Fixture {
        cfg: ServingConfig,
        cost: CostModel,
        kvs: Vec<KvManager>,
    }

    impl Fixture {
        fn new(n: usize) -> Self {
            let cfg = ServingConfig::llama2_7b_tp1();
            let cost = CostModel::new(cfg.clone());
            let kvs = (0..n)
                .map(|_| KvManager::new(100_000, 500_000, cfg.block_size, cfg.model.n_layers))
                .collect();
            Fixture { cfg, cost, kvs }
        }

        fn views(&self) -> Vec<ReplicaView<'_>> {
            self.kvs
                .iter()
                .enumerate()
                .map(|(i, kv)| ReplicaView {
                    idx: i,
                    waiting_len: 0,
                    running_len: 0,
                    waiting_tokens: 0,
                    running_tokens: 0,
                    waiting_prefill_s: 0.0,
                    running_remaining_tokens: 0,
                    slowdown: 1.0,
                    kv,
                    cost: &self.cost,
                    cfg: &self.cfg,
                })
                .collect()
        }
    }

    #[test]
    fn health_router_fences_down_and_deprioritizes_probation() {
        let f = Fixture::new(3);
        let views = f.views();
        let state = Rc::new(RefCell::new(HealthState::new(3)));
        let mut hr =
            HealthRouter::new(make_router(RouterPolicy::RoundRobin, 3), Rc::clone(&state));
        assert_eq!(hr.name(), "round-robin");
        // nothing fenced: transparent delegation (round-robin cycles all)
        let picks: Vec<usize> = (0..3).map(|_| hr.route(128, &views)).collect();
        assert_eq!(picks, vec![0, 1, 2]);
        // replica 0 down: never picked
        state.borrow_mut().down[0] = true;
        for _ in 0..4 {
            assert_ne!(hr.route(128, &views), 0);
        }
        // replica 1 also in probation: only 2 remains
        state.borrow_mut().probation_until[1] = 100.0;
        state.borrow_mut().now = 50.0;
        for _ in 0..3 {
            assert_eq!(hr.route(128, &views), 2);
        }
        // 2 goes down too: probation is better than nothing
        state.borrow_mut().down[2] = true;
        for _ in 0..3 {
            assert_eq!(hr.route(128, &views), 1);
        }
        // probation expires with time
        state.borrow_mut().now = 150.0;
        assert_eq!(hr.route(128, &views), 1);
        assert!(state.borrow().any_up());
        state.borrow_mut().down[1] = true;
        assert!(!state.borrow().any_up());
    }
}
