//! Cluster-scale serving: N independent engine replicas behind one
//! KV-pressure- / SLO-aware router.
//!
//! The paper's Fig. 1 queueing blowups are competition for KV blocks on
//! *one* engine; at fleet scale the same competition reappears one level
//! up, as replica choice. A router that ignores per-replica KV pressure
//! recreates exactly the head-of-line blocking LayerKV removed — so the
//! router here reads each replica's live pool aggregates and cost model,
//! the same signals the in-engine scheduler uses (see `router.rs` for
//! the four policies).
//!
//! [`Cluster<B>`] owns N [`Engine<B>`] replicas — homogeneous or
//! heterogeneous [`ServingConfig`]s, each with its own GPU/host/disk
//! hierarchy — behind one **cluster-wide event heap**: arrivals, compiled
//! [`FaultEvent`]s, and per-replica *horizon events* (the instant a
//! replica's cached decode span lands, `Engine::next_event_horizon`) all
//! merge into a single time-ordered binary heap, and the run loop pops
//! the globally earliest one, advancing **only** the replica(s) that
//! event involves. Replica entries use lazy invalidation — a per-replica
//! stamp kills superseded entries on pop instead of deleting from the
//! heap — so a routing decision that perturbs one replica never forces a
//! fleet-wide re-solve. Idle and mid-span replicas are never stepped
//! between their own events: fleet cost is O(total events), not
//! O(replicas x arrivals).
//!
//! Routing semantics are unchanged: every live replica is advanced to
//! each routing instant (through the engine's span cache, without
//! scheduler invocations) before the router sees the views, so decisions
//! observe exactly the state a front-end would at that moment. Replicas
//! never interact below the router (separate pools, separate clocks),
//! which is what makes per-event advancement exact: stepping order
//! between replicas cannot change any replica's outcome.
//!
//! The PR-6 virtual-time lockstep drive is kept verbatim as the oracle
//! (`Cluster::set_lockstep` / `LAYERKV_LOCKSTEP=1` / `sim --lockstep`):
//! the heap drive is property-tested **bit-identical** to it — records,
//! drops, fault logs, pool state, rendered reports — across routers x
//! macro-stepping x generated fault plans (`tests/prop_cluster_heap.rs`),
//! and a 1-replica cluster stays bit-identical to a bare
//! `Engine<SimBackend>` run on the same trace (`tests/prop_cluster.rs`,
//! both in CI's prop-deep job).
//!
//! In a real deployment each replica is one serving process (one GPU or
//! TP group), and the router is the front-end: `serve --replicas N
//! --router <policy>` runs exactly that shape with real engine workers
//! (see `server/`), and README "Cluster architecture" maps the pieces.

pub mod faults;
pub mod replica;
pub mod report;
pub mod router;

pub use faults::{CrashWindow, FaultPlan, HealthRouter, IoBurst, Migration, Straggler};
pub use replica::Replica;
pub use report::{ClusterReport, ReplicaOutcome, RequestAttribution};
pub use router::{
    kv_pressure_score, make_router, prefix_affinity_score, ReplicaView, RouteQuery, Router,
    RouterPolicy,
};

use std::cell::RefCell;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};
use std::rc::Rc;

use crate::config::ServingConfig;
use crate::coordinator::backend::{ExecutionBackend, SimBackend};
use crate::coordinator::block::RequestSnapshot;
use crate::coordinator::{standard_predictor, Engine, LengthPredictor, CLOCK_EPS};
use crate::metrics::{FaultEvent, FaultKind, FaultSummary, RequestRecord};
use crate::obs::{self, EventKind, TraceHandle, TraceRecord};
use crate::workload::{Trace, TraceRequest};

use faults::HealthState;

/// How a cluster is assembled: one `ServingConfig` per replica (mixed
/// hardware is fine — each engine sizes its own pools) plus the routing
/// policy.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub replicas: Vec<ServingConfig>,
    pub router: RouterPolicy,
    pub predictor_accuracy: f64,
}

/// Default predictor accuracy (the same 0.8 regime as
/// `experiments::PREDICTOR_ACC`, defined here so the core cluster module
/// does not depend on the experiment harness).
pub const DEFAULT_PREDICTOR_ACC: f64 = 0.8;

impl ClusterConfig {
    /// N identical replicas of one config.
    pub fn homogeneous(cfg: &ServingConfig, n: usize, router: RouterPolicy) -> Self {
        ClusterConfig {
            replicas: vec![cfg.clone(); n],
            router,
            predictor_accuracy: DEFAULT_PREDICTOR_ACC,
        }
    }
}

/// N engine replicas + a router, stepped in virtual-time lockstep.
pub struct Cluster<B: ExecutionBackend = SimBackend> {
    replicas: Vec<Replica<B>>,
    router: Box<dyn Router>,
    predictor_accuracy: f64,
    /// `run` is single-shot (engines keep their stats/id maps); this
    /// turns a second call into a clear error instead of bad data.
    ran: bool,
    /// Fault-injection state; `None` (the default) takes the exact
    /// pre-fault code path — no health checks, no event stream.
    faults: Option<FaultRun>,
    /// Drive mode: `true` replays the PR-6 virtual-time lockstep (the
    /// bit-identity oracle), `false` (default) runs the event-heap core.
    lockstep: bool,
    /// Scheduler-bearing engine steps the cluster drive has issued —
    /// `step_once_until` calls and heap-forced decides; span-cache chunk
    /// commits count zero. The O(total events) claim is pinned on this
    /// counter (`tests/prop_cluster_heap.rs` asserts the heap drive takes
    /// >=5x fewer than lockstep on a bursty 32-replica trace).
    advances: u64,
    /// Cluster-level trace attachment for fault/resubmit/failed instants
    /// (replica engines carry their own `EngineTrace`). None = off.
    trace: Option<TraceHandle>,
}

/// Fleet-wide drive-mode default: `LAYERKV_LOCKSTEP=1` forces every
/// cluster onto the lockstep oracle (mirrors `LAYERKV_MACRO=0`).
fn lockstep_default() -> bool {
    std::env::var("LAYERKV_LOCKSTEP").map(|v| v == "1").unwrap_or(false)
}

/// One entry in the cluster-wide event heap, min-ordered by time with a
/// deterministic tie chain: replica horizon events fire before fault
/// events fire before arrivals at the same instant (a replica is always
/// caught up before an external event observes it; a crash at an arrival
/// instant fences the replica before the router can pick it, exactly the
/// lockstep order), and same-kind ties fire in stream/index order.
#[derive(Debug, Clone, Copy)]
struct HeapEvent {
    t: f64,
    rank: u8,
    /// Replica index (RANK_REPLICA), compiled fault-stream index
    /// (RANK_FAULT), or trace index (RANK_ARRIVAL).
    idx: usize,
    /// Lazy invalidation for replica entries: stale when it no longer
    /// matches the replica's current stamp. Always 0 for external events.
    stamp: u64,
}

const RANK_REPLICA: u8 = 0;
const RANK_FAULT: u8 = 1;
const RANK_ARRIVAL: u8 = 2;

impl Ord for HeapEvent {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t
            .total_cmp(&other.t)
            .then(self.rank.cmp(&other.rank))
            .then(self.idx.cmp(&other.idx))
            .then(self.stamp.cmp(&other.stamp))
    }
}

impl PartialOrd for HeapEvent {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl PartialEq for HeapEvent {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == std::cmp::Ordering::Equal
    }
}

impl Eq for HeapEvent {}

/// Lazy-invalidation bookkeeping for the per-replica heap entries: a
/// popped replica entry is live only while its stamp matches, and
/// `armed`/`t` remember the entry currently sitting in the heap so a
/// refresh that finds the horizon unchanged re-pushes nothing — the heap
/// holds at most one live entry per replica, O(live events), not
/// O(refreshes).
struct ArmState {
    stamp: Vec<u64>,
    armed: Vec<bool>,
    t: Vec<f64>,
}

impl ArmState {
    fn new(n: usize) -> Self {
        ArmState { stamp: vec![0; n], armed: vec![false; n], t: vec![0.0; n] }
    }
}

/// Live state of one fault-injected run: the compiled event stream, the
/// health table shared with the [`HealthRouter`], and the failover
/// bookkeeping (retry counts, parked requests, exhausted ids).
struct FaultRun {
    plan: FaultPlan,
    events: Vec<FaultEvent>,
    next_event: usize,
    health: Rc<RefCell<HealthState>>,
    /// Global id -> crash drains so far.
    retries: HashMap<usize, u32>,
    /// Total re-submissions performed (failover traffic).
    retries_total: u64,
    /// Requests with no live replica to land on, waiting for a recovery.
    parked: Vec<TraceRequest>,
    /// Checkpointed snapshots with no live replica (or a down migration
    /// destination) to land on — the stateful analogue of `parked`,
    /// adopted instead of re-submitted when a recovery comes.
    parked_snaps: Vec<RequestSnapshot>,
    /// Global ids that exhausted the retry budget (or never found a live
    /// replica).
    failed: Vec<usize>,
    /// Events actually applied, in order — a determinism witness.
    log: Vec<FaultEvent>,
    /// Drained requests adopted from checkpoint snapshots (stateful
    /// failover + migrations) instead of re-submitted from scratch.
    adoptions: u64,
    /// Prefill-equivalent tokens failover had to recompute: prompt +
    /// committed for from-scratch re-submissions and degraded adoptions,
    /// only the suffix past the checkpoint for real adoptions.
    recomputed_tokens: u64,
    /// Tokens resumed straight from durable checkpoints (prompt +
    /// resumed progress per successful adoption) — lost work failover
    /// did NOT have to redo.
    resumed_tokens: u64,
}

impl FaultRun {
    fn summary(&self, end: f64) -> FaultSummary {
        let count = |pred: fn(&FaultKind) -> bool| {
            self.log.iter().filter(|e| pred(&e.kind)).count()
        };
        let mut downtime_s = 0.0;
        for c in &self.plan.crashes {
            let until = c.recover_at.min(end);
            if until > c.at {
                downtime_s += until - c.at;
            }
        }
        FaultSummary {
            crashes: count(|k| matches!(k, FaultKind::Crash)),
            recoveries: count(|k| matches!(k, FaultKind::Recover)),
            straggler_windows: count(|k| matches!(k, FaultKind::StragglerStart { .. })),
            io_bursts: count(|k| matches!(k, FaultKind::IoErrorStart)),
            retries: self.retries_total,
            failed: self.failed.len(),
            downtime_s,
            migrations: count(|k| matches!(k, FaultKind::Migrate { .. })),
            adoptions: self.adoptions,
            recomputed_tokens: self.recomputed_tokens,
            resumed_tokens: self.resumed_tokens,
        }
    }

    /// Fold one adoption's outcome into the failover cost counters. A
    /// degraded adoption (`resumed == 0`: destination cannot restore, or
    /// the snapshot carried no durable checkpoint) recomputes the whole
    /// context, exactly like a from-scratch re-submission.
    fn note_adoption(&mut self, snap: &RequestSnapshot, resumed: usize) {
        self.adoptions += 1;
        if resumed > 0 {
            self.resumed_tokens += (snap.prompt_len + resumed) as u64;
            self.recomputed_tokens += (snap.generated - resumed) as u64;
        } else {
            self.recomputed_tokens += (snap.prompt_len + snap.generated) as u64;
        }
    }
}

impl Cluster<SimBackend> {
    /// Build a simulation cluster: one `Engine<SimBackend>` per replica
    /// config, pools sized by each config's memory-profiling pass.
    pub fn new(cfg: &ClusterConfig) -> Self {
        assert!(!cfg.replicas.is_empty(), "cluster needs at least one replica");
        let replicas = cfg
            .replicas
            .iter()
            .map(|c| {
                // placeholder predictor: the incremental path receives
                // each request's prediction at submit time, from the
                // cluster's own trace-wide predictor (so a 1-replica
                // cluster sees exactly run_trace's predictions)
                let p = LengthPredictor::new(2, cfg.predictor_accuracy, 42);
                Replica::new(Engine::new(c.clone(), p))
            })
            .collect();
        Cluster {
            replicas,
            router: make_router(cfg.router, cfg.replicas.len()),
            predictor_accuracy: cfg.predictor_accuracy,
            ran: false,
            faults: None,
            lockstep: lockstep_default(),
            advances: 0,
            trace: obs::sink::current(),
        }
    }
}

impl<B: ExecutionBackend> Cluster<B> {
    /// Assemble from pre-built engines (any backend) and a router.
    pub fn from_replicas(
        engines: Vec<Engine<B>>,
        router: Box<dyn Router>,
        predictor_accuracy: f64,
    ) -> Self {
        assert!(!engines.is_empty(), "cluster needs at least one replica");
        Cluster {
            replicas: engines.into_iter().map(Replica::new).collect(),
            router,
            predictor_accuracy,
            ran: false,
            faults: None,
            lockstep: lockstep_default(),
            advances: 0,
            trace: obs::sink::current(),
        }
    }

    /// Attach a [`FaultPlan`]: wraps the router in a [`HealthRouter`]
    /// sharing a health table with the fault loop, and compiles the plan
    /// to its event stream. An empty plan is bit-identical to not calling
    /// this at all (`tests/prop_faults.rs` pins that).
    pub fn with_faults(self, plan: FaultPlan) -> Self {
        assert!(!self.ran, "attach faults before running");
        let n = self.replicas.len();
        if let Some(m) = plan.max_replica() {
            assert!(m < n, "fault plan names replica {m}, cluster has {n}");
        }
        let health = Rc::new(RefCell::new(HealthState::new(n)));
        let events = plan.events();
        Cluster {
            replicas: self.replicas,
            router: Box::new(HealthRouter::new(self.router, Rc::clone(&health))),
            predictor_accuracy: self.predictor_accuracy,
            ran: self.ran,
            lockstep: self.lockstep,
            advances: self.advances,
            trace: self.trace,
            faults: Some(FaultRun {
                plan,
                events,
                next_event: 0,
                health,
                retries: HashMap::new(),
                retries_total: 0,
                parked: Vec::new(),
                parked_snaps: Vec::new(),
                failed: Vec::new(),
                log: Vec::new(),
                adoptions: 0,
                recomputed_tokens: 0,
                resumed_tokens: 0,
            }),
        }
    }

    /// Fault events applied so far, in application order (empty when no
    /// plan is attached). Stable render via `FaultEvent::render` makes
    /// this a byte-identity witness for same-seed replays.
    pub fn fault_log(&self) -> &[FaultEvent] {
        self.faults.as_ref().map(|f| f.log.as_slice()).unwrap_or(&[])
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// Toggle decode fast-forwarding (macro-stepping) on every replica.
    /// Off = the pure single-step lockstep, the debugging reference the
    /// property suite and the hotpath bench compare against.
    pub fn set_macro_steps(&mut self, on: bool) {
        for rep in &mut self.replicas {
            rep.engine.set_macro_steps(on);
        }
    }

    /// Every replica recomputes its cached state from scratch each step
    /// and single-steps every decode — the frozen-oracle path the golden
    /// cluster replay pins router + lockstep changes against.
    pub fn use_recompute_oracle(&mut self) {
        for rep in &mut self.replicas {
            rep.engine.use_recompute_oracle();
        }
    }

    /// Force the virtual-time lockstep drive (the bit-identity oracle)
    /// instead of the event heap. Also settable fleet-wide via
    /// `LAYERKV_LOCKSTEP=1`, or per run with `sim --lockstep`.
    pub fn set_lockstep(&mut self, on: bool) {
        self.lockstep = on;
    }

    pub fn lockstep(&self) -> bool {
        self.lockstep
    }

    /// Attach the cluster and every replica engine to a tracer (each
    /// engine allocates its own track, in replica order). Tests use this
    /// for isolation; the CLI path attaches via the global sink instead.
    pub fn set_tracer(&mut self, handle: TraceHandle) {
        for rep in &mut self.replicas {
            rep.engine.set_tracer(handle.clone());
        }
        self.trace = Some(handle);
    }

    /// Record a cluster-level instant on a replica's track (fault
    /// applications, failover resubmits, retry exhaustions).
    fn trace_cluster_instant(
        &self,
        kind: EventKind,
        replica: usize,
        t: f64,
        gid: u64,
        a: u64,
        c: u64,
    ) {
        if let Some(h) = self.trace.as_ref() {
            let track = self
                .replicas
                .get(replica)
                .and_then(|r| r.engine.trace_track())
                .unwrap_or(replica as u32);
            h.record(TraceRecord { t0: t, t1: t, kind, track, req: gid, a, b: 0, c });
        }
    }

    /// Fold one applied fault event into the trace as a Fault instant on
    /// its target replica's track.
    fn trace_fault(&self, ev: &FaultEvent) {
        if self.trace.is_none() {
            return;
        }
        let (code, slowdown_bits) = match ev.kind {
            FaultKind::Crash => (obs::FAULT_CRASH, 0),
            FaultKind::Recover => (obs::FAULT_RECOVER, 0),
            FaultKind::StragglerStart { slowdown } => {
                (obs::FAULT_STRAGGLER_START, slowdown.to_bits())
            }
            FaultKind::StragglerEnd => (obs::FAULT_STRAGGLER_END, 0),
            FaultKind::IoErrorStart => (obs::FAULT_IO_ERROR_START, 0),
            FaultKind::IoErrorEnd => (obs::FAULT_IO_ERROR_END, 0),
            // the payload word carries the destination instead of a
            // slowdown factor — `fault_name` disambiguates on the code
            FaultKind::Migrate { dst } => (obs::FAULT_MIGRATE, dst as u64),
        };
        self.trace_cluster_instant(
            EventKind::Fault,
            ev.replica,
            ev.t,
            u64::MAX,
            code,
            slowdown_bits,
        );
        // fault boundaries are exactly where tier pressure and slowdown
        // gauges change shape: sample the target replica
        self.replicas[ev.replica].engine.trace_sample_gauges();
    }

    /// Scheduler-bearing replica advances the drive has issued so far
    /// (span-cache chunk commits count zero) — the O(total events) yard
    /// stick the heap-vs-lockstep tests measure.
    pub fn advances(&self) -> u64 {
        self.advances
    }

    /// Serve a whole trace: route every request at its arrival instant,
    /// drain all replicas, and merge the per-replica reports back into
    /// trace order. Single-shot — build a fresh `Cluster` per trace (the
    /// replica engines keep their clocks, stats, and id maps).
    pub fn run(&mut self, trace: &Trace) -> anyhow::Result<ClusterReport> {
        anyhow::ensure!(
            !self.ran,
            "Cluster::run is single-shot — build a fresh Cluster per trace"
        );
        self.ran = true;
        let predictor = standard_predictor(trace, self.predictor_accuracy);
        // The heap drive pops arrivals through the same time-ordered heap
        // as everything else, so it needs them non-decreasing (lockstep
        // processes a trace in its own order). Generators emit sorted
        // traces; a hand-built out-of-order one takes the oracle path.
        let sorted =
            trace.requests.windows(2).all(|w| w[0].arrival <= w[1].arrival);
        if self.lockstep || !sorted {
            self.run_lockstep(trace, &predictor)?;
        } else {
            self.run_heap(trace, &predictor)?;
        }
        Ok(self.take_report())
    }

    /// Administrative live migration: immediately drain `src` with full
    /// state and adopt everything on `dst` (scale-down / rebalance). The
    /// planned mid-run equivalent is the `migrate=SRC>DST@T` fault-plan
    /// clause. The source's admission stays closed afterwards; with a
    /// fault plan attached it is also health-fenced and the migration
    /// joins the fault log and summary. Returns the requests moved.
    pub fn migrate(&mut self, src: usize, dst: usize) -> anyhow::Result<usize> {
        let n = self.replicas.len();
        anyhow::ensure!(src < n && dst < n, "migrate {src}->{dst}: cluster has {n} replicas");
        anyhow::ensure!(src != dst, "migration source and destination must differ");
        anyhow::ensure!(
            self.ran || self.faults.is_some(),
            "migrate before run needs a fault plan attached — the health \
             table is what keeps the router off the drained source"
        );
        if let Some(f) = &self.faults {
            anyhow::ensure!(
                !f.health.borrow().down[dst],
                "migration destination {dst} is down"
            );
        }
        let at = self.replicas[src].engine.now().max(self.replicas[dst].engine.now());
        let snaps = self.drain_replica_with_state(src, at);
        let moved = snaps.len();
        for snap in snaps {
            let rep = &mut self.replicas[dst];
            if at > rep.engine.now() + CLOCK_EPS {
                rep.engine.wait_until(at);
            }
            let (_, resumed) = rep.adopt(&snap);
            if let Some(f) = &mut self.faults {
                f.note_adoption(&snap, resumed);
            }
        }
        let ev = FaultEvent { t: at, replica: src, kind: FaultKind::Migrate { dst } };
        if let Some(f) = &mut self.faults {
            f.health.borrow_mut().down[src] = true;
            f.log.push(ev);
        }
        self.trace_fault(&ev);
        Ok(moved)
    }

    /// The PR-6 virtual-time lockstep drive, kept verbatim as the oracle
    /// the event-heap path is property-tested bit-identical against
    /// (`set_lockstep` / `LAYERKV_LOCKSTEP=1`). Every live replica is
    /// advanced at every external event — O(replicas x arrivals)
    /// scheduler-bearing steps.
    fn run_lockstep(
        &mut self,
        trace: &Trace,
        predictor: &LengthPredictor,
    ) -> anyhow::Result<()> {
        for tr in &trace.requests {
            // fault events scheduled before this arrival fire first (a
            // crash at the arrival instant fences the replica before the
            // router can pick it)
            if self.faults.is_some() {
                self.fire_events_until(tr.arrival, false, predictor)?;
            }
            // lockstep: every replica catches up to this arrival before
            // the router looks at the views (CLOCK_EPS mirrors try_run's
            // arrival-admission epsilon). The arrival is each engine's
            // decode fast-forward horizon, so a stable replica advances to
            // its next event in ONE macro-step instead of one `step_once`
            // per decode token — the loop runs O(events) turns, not
            // O(tokens).
            let down = self.down_flags();
            let mut adv = 0u64;
            for (i, rep) in self.replicas.iter_mut().enumerate() {
                if down.as_ref().is_some_and(|d| d[i]) {
                    continue; // crashed: fenced until its recovery event
                }
                while tr.arrival > rep.engine.now() + CLOCK_EPS {
                    adv += 1;
                    if !rep.engine.step_once_until(false, tr.arrival)? {
                        break; // idle: its clock advances at its next submit
                    }
                }
            }
            self.advances += adv;
            if let Some(f) = &mut self.faults {
                let mut st = f.health.borrow_mut();
                st.now = tr.arrival;
                if !st.any_up() {
                    // whole cluster down: park until a recovery (failed at
                    // the end of the run if none comes)
                    drop(st);
                    f.parked.push(tr.clone());
                    continue;
                }
            }
            self.pump_feedback();
            let idx = self.route_request(tr);
            let rep = &mut self.replicas[idx];
            if tr.arrival > rep.engine.now() + CLOCK_EPS {
                rep.engine.wait_until(tr.arrival);
            }
            rep.submit(tr, predictor.predict(tr.id, tr.output_len));
            rep.engine.trace_sample_gauges();
        }
        // remaining fault events (crashes/recoveries past the last
        // arrival) fire in order while the replicas drain toward them
        if self.faults.is_some() {
            self.fire_events_until(f64::INFINITY, true, predictor)?;
        }
        // drain: no more input — replicas run independently to empty
        let down = self.down_flags();
        let mut adv = 0u64;
        for (i, rep) in self.replicas.iter_mut().enumerate() {
            if down.as_ref().is_some_and(|d| d[i]) {
                continue;
            }
            while rep.engine.has_work() {
                adv += 1;
                if !rep.engine.step_once(true)? {
                    break;
                }
            }
        }
        self.advances += adv;
        // requests still parked (no replica ever recovered): failed
        self.fail_parked();
        self.pump_feedback();
        Ok(())
    }

    /// Requests (and checkpointed snapshots) still parked at the end of a
    /// run — no replica ever recovered to take them — fail terminally.
    /// Both drive modes end here.
    fn fail_parked(&mut self) {
        let Some(f) = &mut self.faults else { return };
        let trace = self.trace.as_ref();
        let t = f.health.borrow().now;
        let ids = std::mem::take(&mut f.parked)
            .into_iter()
            .map(|tr| tr.id)
            .chain(std::mem::take(&mut f.parked_snaps).into_iter().map(|s| s.id));
        for id in ids {
            if let Some(h) = trace {
                // never-recovered requests fail at the end of the run:
                // stamp the last health instant (the exporter re-sorts
                // events by timestamp, so track 0 is just a home lane)
                h.record(TraceRecord {
                    t0: t,
                    t1: t,
                    kind: EventKind::Failed,
                    track: 0,
                    req: id as u64,
                    a: 0,
                    b: 0,
                    c: 0,
                });
            }
            f.failed.push(id);
        }
    }

    /// The event-heap drive: pop the globally earliest event — a replica
    /// horizon, a fault, or an arrival — and advance only the replica(s)
    /// it involves. Bit-identity with `run_lockstep` rests on the engine
    /// span cache (`Engine::advance_until` commits exactly the decode
    /// iterations lockstep's deadline-bounded macro-steps would, chunked
    /// at the same sync instants) and on every handler advancing every
    /// replica whose state it observes to the event instant first.
    fn run_heap(
        &mut self,
        trace: &Trace,
        predictor: &LengthPredictor,
    ) -> anyhow::Result<()> {
        let n_arr = trace.requests.len();
        let n_faults = self.faults.as_ref().map(|f| f.events.len()).unwrap_or(0);
        let mut heap: BinaryHeap<Reverse<HeapEvent>> =
            BinaryHeap::with_capacity(n_arr + n_faults + self.replicas.len());
        for (i, tr) in trace.requests.iter().enumerate() {
            heap.push(Reverse(HeapEvent {
                t: tr.arrival,
                rank: RANK_ARRIVAL,
                idx: i,
                stamp: 0,
            }));
        }
        if let Some(f) = &self.faults {
            for (i, ev) in f.events.iter().enumerate() {
                heap.push(Reverse(HeapEvent { t: ev.t, rank: RANK_FAULT, idx: i, stamp: 0 }));
            }
        }
        let mut arm = ArmState::new(self.replicas.len());
        let mut next_arrival = 0usize;
        let mut next_fault = 0usize;
        while let Some(Reverse(ev)) = heap.pop() {
            match ev.rank {
                RANK_REPLICA => {
                    if arm.stamp[ev.idx] != ev.stamp {
                        continue; // stale: superseded by a later refresh
                    }
                    // consume the live entry
                    arm.armed[ev.idx] = false;
                    arm.stamp[ev.idx] += 1;
                    if self.is_down(ev.idx) {
                        continue; // crashed after arming: fenced until recovery
                    }
                    let draining = next_arrival >= n_arr;
                    let cap = self.external_cap(trace, next_arrival, next_fault);
                    // catch up to the event instant (span chunks, no
                    // decides while stable), then take the one forced
                    // scheduling step lockstep would take at the next
                    // external sync — same state, same deadline
                    let (decides, progressed) = self.replicas[ev.idx]
                        .engine
                        .service_horizon_event(ev.t, cap, draining)?;
                    self.advances += decides;
                    self.replicas[ev.idx].engine.trace_sample_gauges();
                    // a blocked replica (`progressed == false`) is not
                    // re-armed — it cannot change state without new input,
                    // and every external handler below refreshes it
                    if progressed {
                        self.refresh_horizon(ev.idx, cap, &mut heap, &mut arm);
                    }
                }
                RANK_FAULT => {
                    next_fault = ev.idx + 1;
                    let draining = next_arrival >= n_arr;
                    // take the fault state out so the handler can borrow
                    // replicas and router mutably alongside it
                    let Some(mut f) = self.faults.take() else {
                        unreachable!("fault heap event without fault state")
                    };
                    let result = self.fire_heap_event(&mut f, ev.idx, draining, predictor);
                    self.faults = Some(f);
                    result?;
                    let cap = self.external_cap(trace, next_arrival, next_fault);
                    self.refresh_all(cap, &mut heap, &mut arm);
                }
                _ => {
                    debug_assert_eq!(ev.rank, RANK_ARRIVAL);
                    let tr = &trace.requests[ev.idx];
                    next_arrival = ev.idx + 1;
                    // every live replica catches up to the routing instant,
                    // exactly as lockstep — but through the span cache, so
                    // stable replicas commit pre-solved chunks and idle
                    // ones break immediately, both without a decide
                    let down = self.down_flags();
                    let mut adv = 0u64;
                    for (i, rep) in self.replicas.iter_mut().enumerate() {
                        if down.as_ref().is_some_and(|d| d[i]) {
                            continue;
                        }
                        adv += rep.engine.advance_until(tr.arrival, false)?;
                    }
                    self.advances += adv;
                    let mut parked = false;
                    if let Some(f) = &mut self.faults {
                        let mut st = f.health.borrow_mut();
                        st.now = tr.arrival;
                        if !st.any_up() {
                            drop(st);
                            f.parked.push(tr.clone());
                            parked = true;
                        }
                    }
                    if !parked {
                        self.pump_feedback();
                        let idx = self.route_request(tr);
                        let rep = &mut self.replicas[idx];
                        if tr.arrival > rep.engine.now() + CLOCK_EPS {
                            rep.engine.wait_until(tr.arrival);
                        }
                        rep.submit(tr, predictor.predict(tr.id, tr.output_len));
                        rep.engine.trace_sample_gauges();
                    }
                    let cap = self.external_cap(trace, next_arrival, next_fault);
                    self.refresh_all(cap, &mut heap, &mut arm);
                }
            }
        }
        // heap empty: every live replica is quiescent (a replica with work
        // always re-arms), every arrival and fault has fired
        self.fail_parked();
        self.pump_feedback();
        Ok(())
    }

    /// Apply the `ei`-th compiled fault event in heap mode: advance the
    /// replica(s) whose state the handler observes to the event instant,
    /// then apply. Crash/recover handlers route drained or parked work
    /// through the router's views, so every live replica must be at
    /// `ev.t`; straggler and I/O toggles observe nothing — only their
    /// target advances (its pending step durations depend on the toggle),
    /// the rest catch up lazily at their next event, committing the same
    /// steps either way.
    fn fire_heap_event(
        &mut self,
        f: &mut FaultRun,
        ei: usize,
        draining: bool,
        predictor: &LengthPredictor,
    ) -> anyhow::Result<()> {
        debug_assert_eq!(f.next_event, ei, "heap must fire fault events in stream order");
        f.next_event = ei + 1;
        let ev = f.events[ei];
        let mut adv = 0u64;
        {
            // `f` is detached from `self`, so the health borrow can be
            // held across the replica walk
            let health = f.health.borrow();
            match ev.kind {
                // crash/recover route work through the router's views and
                // a migration hands work to its destination: every live
                // replica must be at the event instant, exactly as the
                // lockstep drive has it
                FaultKind::Crash | FaultKind::Recover | FaultKind::Migrate { .. } => {
                    for (i, rep) in self.replicas.iter_mut().enumerate() {
                        if health.down[i] {
                            continue;
                        }
                        adv += rep.engine.advance_until(ev.t, draining)?;
                    }
                }
                _ => {
                    if !health.down[ev.replica] {
                        adv +=
                            self.replicas[ev.replica].engine.advance_until(ev.t, draining)?;
                    }
                }
            }
        }
        self.advances += adv;
        f.health.borrow_mut().now = ev.t;
        self.apply_event(f, &ev, predictor)?;
        f.log.push(ev);
        self.trace_fault(&ev);
        Ok(())
    }

    /// Was replica `i` down (crash-fenced) at the last health update?
    fn is_down(&self, i: usize) -> bool {
        self.faults.as_ref().is_some_and(|f| f.health.borrow().down[i])
    }

    /// The next external event instant — the earliest unprocessed arrival
    /// or fault — bounding every replica-local advance, exactly as the
    /// lockstep drive's per-sync deadlines do.
    fn external_cap(&self, trace: &Trace, next_arrival: usize, next_fault: usize) -> f64 {
        let a = trace
            .requests
            .get(next_arrival)
            .map(|r| r.arrival)
            .unwrap_or(f64::INFINITY);
        let b = self
            .faults
            .as_ref()
            .and_then(|f| f.events.get(next_fault))
            .map(|e| e.t)
            .unwrap_or(f64::INFINITY);
        a.min(b)
    }

    /// Re-arm one replica's heap entry against its current horizon: bump
    /// the stamp (lazily invalidating any stale entry) and push the new
    /// horizon when it lands before `cap`. Horizons at or past the next
    /// external event need no entry — that handler's refresh re-derives
    /// them — and an entry whose horizon is unchanged is left in place.
    fn refresh_horizon(
        &mut self,
        idx: usize,
        cap: f64,
        heap: &mut BinaryHeap<Reverse<HeapEvent>>,
        arm: &mut ArmState,
    ) {
        let h = self.replicas[idx].horizon();
        if arm.armed[idx] && arm.t[idx].to_bits() == h.to_bits() {
            return; // the live entry is already exact
        }
        arm.stamp[idx] += 1;
        arm.armed[idx] = false;
        if h < cap {
            heap.push(Reverse(HeapEvent {
                t: h,
                rank: RANK_REPLICA,
                idx,
                stamp: arm.stamp[idx],
            }));
            arm.armed[idx] = true;
            arm.t[idx] = h;
        }
    }

    /// Refresh every live replica's heap entry against a new external cap.
    /// Every external-event handler ends here: it is what guarantees a
    /// replica whose horizon sat past the *previous* cap is re-armed once
    /// that cap moves — without it, a replica could be stranded with work
    /// after the last external event and never drain.
    fn refresh_all(
        &mut self,
        cap: f64,
        heap: &mut BinaryHeap<Reverse<HeapEvent>>,
        arm: &mut ArmState,
    ) {
        let down = self.down_flags();
        for i in 0..self.replicas.len() {
            if down.as_ref().is_some_and(|d| d[i]) {
                continue;
            }
            self.refresh_horizon(i, cap, heap, arm);
        }
    }

    /// Pick a replica for a request through the router. Callers must have
    /// advanced every live replica to the routing instant first (both
    /// drive modes do), so the views are lockstep-fresh. Routes through
    /// `route_query` so cache-affine policies see the prefix identity;
    /// every length-only policy's default delegation keeps its decisions
    /// bit-identical to the old `route(prompt_len, ..)` path.
    fn route_request(&mut self, tr: &TraceRequest) -> usize {
        let views: Vec<ReplicaView> =
            self.replicas.iter().enumerate().map(|(i, r)| r.view(i)).collect();
        let q = RouteQuery {
            prompt_len: tr.prompt_len,
            prefix_hash: tr.prefix.hash,
            prefix_len: tr.prefix.len,
        };
        let picked = self.router.route_query(&q, &views);
        assert!(
            picked < self.replicas.len(),
            "router {} returned out-of-range replica {picked} of {}",
            self.router.name(),
            self.replicas.len()
        );
        picked
    }

    /// Per-replica down flags when faults are active (`None` on the
    /// fault-free path, which must stay branch-identical to the
    /// pre-fault code).
    fn down_flags(&self) -> Option<Vec<bool>> {
        self.faults.as_ref().map(|f| f.health.borrow().down.clone())
    }

    /// Fire every scheduled fault event with `t <= horizon`, advancing
    /// live replicas to each event instant first so drains and health
    /// flips happen at exactly the scheduled virtual time.
    fn fire_events_until(
        &mut self,
        horizon: f64,
        draining: bool,
        predictor: &LengthPredictor,
    ) -> anyhow::Result<()> {
        // take the fault state out so event handlers can borrow replicas
        // and router mutably alongside it
        let Some(mut f) = self.faults.take() else { return Ok(()) };
        let result = self.fire_events_inner(&mut f, horizon, draining, predictor);
        self.faults = Some(f);
        result
    }

    fn fire_events_inner(
        &mut self,
        f: &mut FaultRun,
        horizon: f64,
        draining: bool,
        predictor: &LengthPredictor,
    ) -> anyhow::Result<()> {
        while f.next_event < f.events.len() && f.events[f.next_event].t <= horizon {
            // fire by copy — `FaultEvent` is a three-word `Copy`; this
            // loop used to clone the event AND the whole down-vector per
            // event, on the hot path of every faulted arrival
            let ev = f.events[f.next_event];
            f.next_event += 1;
            let mut adv = 0u64;
            {
                // `f` is detached from `self` (see `fire_events_until`),
                // so the health borrow can be held across the replica walk
                let health = f.health.borrow();
                for (i, rep) in self.replicas.iter_mut().enumerate() {
                    if health.down[i] {
                        continue;
                    }
                    while ev.t > rep.engine.now() + CLOCK_EPS {
                        adv += 1;
                        if !rep.engine.step_once_until(draining, ev.t)? {
                            break;
                        }
                    }
                }
            }
            self.advances += adv;
            f.health.borrow_mut().now = ev.t;
            self.apply_event(f, &ev, predictor)?;
            f.log.push(ev);
            self.trace_fault(&ev);
        }
        Ok(())
    }

    fn apply_event(
        &mut self,
        f: &mut FaultRun,
        ev: &FaultEvent,
        predictor: &LengthPredictor,
    ) -> anyhow::Result<()> {
        match ev.kind {
            FaultKind::Crash => {
                if f.health.borrow().down[ev.replica] {
                    return Ok(()); // overlapping windows: already down
                }
                f.health.borrow_mut().down[ev.replica] = true;
                for snap in self.drain_replica_with_state(ev.replica, ev.t) {
                    if snap.checkpointed > 0 {
                        // a durable checkpoint survives the crash: adopt on
                        // a survivor, re-prefilling only the suffix past
                        // the checkpoint. Not a retry — the budget is
                        // charged only for full recomputation.
                        self.adopt_snapshot(f, snap, ev.t)?;
                        continue;
                    }
                    let gid = snap.id;
                    let n = f.retries.entry(gid).or_insert(0);
                    *n += 1;
                    if *n > f.plan.retry_budget {
                        f.failed.push(gid); // budget exhausted: terminal
                        self.trace_cluster_instant(
                            EventKind::Failed,
                            ev.replica,
                            ev.t,
                            gid as u64,
                            0,
                            0,
                        );
                        continue;
                    }
                    f.retries_total += 1;
                    // from-scratch failover redoes the whole context
                    f.recomputed_tokens += (snap.prompt_len + snap.generated) as u64;
                    let tr = TraceRequest {
                        id: gid,
                        arrival: snap.arrival, // original: TTFT includes downtime
                        prompt_len: snap.prompt_len,
                        output_len: snap.output_len,
                        prefix: snap.prefix, // failover target can still match/publish
                    };
                    self.resubmit(f, tr, predictor, ev.t)?;
                }
            }
            FaultKind::Recover => {
                {
                    let mut st = f.health.borrow_mut();
                    st.down[ev.replica] = false;
                    st.probation_until[ev.replica] = ev.t + f.plan.probation_s;
                }
                let rep = &mut self.replicas[ev.replica];
                if ev.t > rep.engine.now() + CLOCK_EPS {
                    rep.engine.wait_until(ev.t);
                }
                rep.engine.reopen_admission();
                // a recovery means at least one replica is up: flush the
                // parked backlog through the (health-aware) router
                for tr in std::mem::take(&mut f.parked) {
                    self.resubmit(f, tr, predictor, ev.t)?;
                }
                for snap in std::mem::take(&mut f.parked_snaps) {
                    self.adopt_snapshot(f, snap, ev.t)?;
                }
            }
            FaultKind::Migrate { dst } => {
                if f.health.borrow().down[ev.replica] {
                    return Ok(()); // source already fenced: nothing to move
                }
                for snap in self.drain_replica_with_state(ev.replica, ev.t) {
                    // migration always moves state — even requests with no
                    // checkpoint are adopted (degrading to recompute on the
                    // destination), never charged against the retry budget
                    self.adopt_snapshot_to(f, snap, dst, ev.t)?;
                }
                // the source leaves the fleet after handing its state over:
                // fenced like a crash with no scheduled recovery
                f.health.borrow_mut().down[ev.replica] = true;
            }
            FaultKind::StragglerStart { slowdown } => {
                // through the engine, not the backend: the engine's cached
                // horizon span embeds the old factor and must die with it
                self.replicas[ev.replica].engine.set_slowdown(slowdown);
            }
            FaultKind::StragglerEnd => {
                self.replicas[ev.replica].engine.set_slowdown(1.0);
            }
            FaultKind::IoErrorStart => {
                self.replicas[ev.replica].engine.set_disk_faulty(true);
            }
            FaultKind::IoErrorEnd => {
                self.replicas[ev.replica].engine.set_disk_faulty(false);
            }
        }
        Ok(())
    }

    /// Route a failover or parked request at cluster time `at`. Parks it
    /// when every replica is down.
    fn resubmit(
        &mut self,
        f: &mut FaultRun,
        tr: TraceRequest,
        predictor: &LengthPredictor,
        at: f64,
    ) -> anyhow::Result<()> {
        if !f.health.borrow().any_up() {
            f.parked.push(tr);
            return Ok(());
        }
        self.pump_feedback();
        let idx = self.route_request(&tr);
        debug_assert!(
            !f.health.borrow().down[idx],
            "health router must fence crashed replicas"
        );
        let rep = &mut self.replicas[idx];
        if at > rep.engine.now() + CLOCK_EPS {
            rep.engine.wait_until(at);
        }
        rep.submit(&tr, predictor.predict(tr.id, tr.output_len));
        self.trace_cluster_instant(EventKind::Resubmit, idx, at, tr.id as u64, 0, 0);
        self.replicas[idx].engine.trace_sample_gauges();
        Ok(())
    }

    /// Drain one replica with full per-request state at cluster time `t`,
    /// re-keying every snapshot to its global trace id. Execution side
    /// effects are bit-identical to the stateless `Engine::drain` the
    /// crash path used before snapshots existed.
    fn drain_replica_with_state(&mut self, replica: usize, t: f64) -> Vec<RequestSnapshot> {
        let rep = &mut self.replicas[replica];
        if t > rep.engine.now() + CLOCK_EPS {
            rep.engine.wait_until(t);
        }
        let mut snaps = rep.engine.drain_with_state();
        for s in &mut snaps {
            s.id = rep.global_ids[s.id];
        }
        snaps
    }

    /// Route a drained snapshot (global-keyed) to a live replica at
    /// cluster time `at` and adopt it there, resuming from its durable
    /// checkpoint when the destination can restore. Parks it when every
    /// replica is down.
    fn adopt_snapshot(
        &mut self,
        f: &mut FaultRun,
        snap: RequestSnapshot,
        at: f64,
    ) -> anyhow::Result<()> {
        if !f.health.borrow().any_up() {
            f.parked_snaps.push(snap);
            return Ok(());
        }
        self.pump_feedback();
        let tr = TraceRequest {
            id: snap.id,
            arrival: snap.arrival,
            prompt_len: snap.prompt_len,
            output_len: snap.output_len,
            prefix: snap.prefix,
        };
        let idx = self.route_request(&tr);
        debug_assert!(
            !f.health.borrow().down[idx],
            "health router must fence crashed replicas"
        );
        self.adopt_on(f, snap, idx, at);
        Ok(())
    }

    /// Adopt a drained snapshot on an explicit destination (migration).
    /// Parks it when the destination is itself down.
    fn adopt_snapshot_to(
        &mut self,
        f: &mut FaultRun,
        snap: RequestSnapshot,
        dst: usize,
        at: f64,
    ) -> anyhow::Result<()> {
        if f.health.borrow().down[dst] {
            f.parked_snaps.push(snap);
            return Ok(());
        }
        self.adopt_on(f, snap, dst, at);
        Ok(())
    }

    /// The shared tail of both adoption paths: hand the snapshot to the
    /// chosen replica's engine and fold the outcome into the failover
    /// cost counters. The engine emits the Adopt trace instant itself
    /// (it knows how many tokens actually resumed).
    fn adopt_on(&mut self, f: &mut FaultRun, snap: RequestSnapshot, idx: usize, at: f64) {
        let rep = &mut self.replicas[idx];
        if at > rep.engine.now() + CLOCK_EPS {
            rep.engine.wait_until(at);
        }
        let (_, resumed) = rep.adopt(&snap);
        f.note_adoption(&snap, resumed);
        self.replicas[idx].engine.trace_sample_gauges();
    }

    /// Feed newly completed requests' TTFTs to the router.
    fn pump_feedback(&mut self) {
        for (i, rep) in self.replicas.iter_mut().enumerate() {
            // `self.replicas` and `self.router` are disjoint fields, so
            // the record borrow and the router call coexist clone-free
            let records = rep.engine.records();
            for r in &records[rep.records_seen..] {
                self.router.observe_ttft(i, r.ttft());
            }
            rep.records_seen = records.len();
        }
    }

    /// Merge per-replica results, remapping local ids to global trace ids.
    fn take_report(&mut self) -> ClusterReport {
        let mut merged: Vec<RequestRecord> = Vec::new();
        let mut dropped = Vec::new();
        let mut attribution = Vec::new();
        let mut per_replica = Vec::with_capacity(self.replicas.len());
        let retries = self.faults.as_ref().map(|f| &f.retries);
        for (i, rep) in self.replicas.iter_mut().enumerate() {
            let report = rep.engine.take_report();
            let stats = rep.engine.stats().clone();
            for r in &report.records {
                let mut g = r.clone();
                g.id = rep.global_ids[r.id];
                attribution.push(RequestAttribution {
                    id: g.id,
                    replica: i,
                    retries: retries.and_then(|m| m.get(&g.id)).copied().unwrap_or(0),
                });
                merged.push(g);
            }
            for &local in &stats.dropped {
                dropped.push(rep.global_ids[local]);
            }
            per_replica.push(ReplicaOutcome { routed: rep.routed(), report, stats });
        }
        dropped.sort_unstable();
        attribution.sort_unstable_by_key(|a| a.id);
        let (failed, faults) = match self.faults.as_mut() {
            Some(f) => {
                // summary first: it reads `failed.len()` before the take
                let end = self
                    .replicas
                    .iter()
                    .map(|r| r.engine.now())
                    .fold(0.0, f64::max);
                let summary = f.summary(end);
                let mut failed = std::mem::take(&mut f.failed);
                failed.sort_unstable();
                (failed, Some(summary))
            }
            None => (Vec::new(), None),
        };
        ClusterReport {
            merged: crate::metrics::Report::new(merged),
            dropped,
            failed,
            faults,
            per_replica,
            attribution,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;
    use crate::util::Rng;
    use crate::workload::arrivals::Arrivals;
    use crate::workload::fixed::FixedWorkload;

    fn trace(n: usize, rate: f64) -> Trace {
        FixedWorkload {
            prompt_len: 1024,
            output_len: 64,
            n_requests: n,
            arrivals: Arrivals::Poisson { rate },
        }
        .generate(&mut Rng::new(3))
    }

    #[test]
    fn every_request_accounted_across_replicas() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for router in RouterPolicy::ALL {
            let t = trace(24, 3.0);
            let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router));
            let out = cluster.run(&t).unwrap();
            assert_eq!(out.accounted(), 24, "router {}", router.name());
            assert_eq!(
                out.per_replica.iter().map(|o| o.routed).sum::<usize>(),
                24
            );
            // merged ids are exactly the trace's ids
            let mut ids: Vec<usize> = out.merged.records.iter().map(|r| r.id).collect();
            ids.extend(out.dropped.iter().copied());
            ids.sort_unstable();
            assert_eq!(ids, (0..24).collect::<Vec<_>>());
        }
    }

    #[test]
    fn round_robin_balances_exactly() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(20, 2.0);
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, 4, RouterPolicy::RoundRobin));
        let out = cluster.run(&t).unwrap();
        for o in &out.per_replica {
            assert_eq!(o.routed, 5);
        }
        let s = out.summary(&cfg.slo);
        assert!((s.max_share() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn run_is_single_shot() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(4, 2.0);
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, RouterPolicy::RoundRobin));
        cluster.run(&t).unwrap();
        assert!(cluster.run(&t).is_err(), "second run must be a clear error");
    }

    #[test]
    fn crash_failover_conserves_every_request() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for router in RouterPolicy::ALL {
            let t = trace(24, 3.0);
            let plan = FaultPlan {
                crashes: vec![CrashWindow {
                    replica: 0,
                    at: 1.5,
                    recover_at: f64::INFINITY,
                }],
                ..FaultPlan::default()
            };
            let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router))
                .with_faults(plan);
            let out = cluster.run(&t).unwrap();
            assert_eq!(out.accounted(), 24, "router {}", router.name());
            let mut ids: Vec<usize> = out.merged.records.iter().map(|r| r.id).collect();
            ids.extend(out.dropped.iter().copied());
            ids.extend(out.failed.iter().copied());
            ids.sort_unstable();
            assert_eq!(ids, (0..24).collect::<Vec<_>>(), "router {}", router.name());
            let f = out.faults.expect("plan attached");
            assert_eq!(f.crashes, 1);
            assert_eq!(f.recoveries, 0);
            assert_eq!(cluster.fault_log().len(), 1);
            // the dead replica never receives post-crash traffic: its
            // routed count is frozen at its pre-crash share
            assert!(out.per_replica[0].routed < 24, "router {}", router.name());
        }
    }

    #[test]
    fn recovery_reopens_admission_and_probation_expires() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(24, 3.0);
        let plan = FaultPlan {
            crashes: vec![CrashWindow { replica: 1, at: 1.0, recover_at: 2.0 }],
            probation_s: 0.5,
            ..FaultPlan::default()
        };
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            2,
            RouterPolicy::RoundRobin,
        ))
        .with_faults(plan);
        let out = cluster.run(&t).unwrap();
        assert_eq!(out.accounted(), 24);
        let f = out.faults.unwrap();
        assert_eq!(f.crashes, 1);
        assert_eq!(f.recoveries, 1);
        assert!((f.downtime_s - 1.0).abs() < 1e-12);
        assert_eq!(cluster.fault_log().len(), 2);
        // post-recovery the replica takes traffic again: round-robin over
        // a 2-cluster would give it ~half absent faults; it must at least
        // have received something after rejoining
        assert!(out.per_replica[1].routed > 0);
    }

    #[test]
    fn whole_cluster_down_parks_then_fails_unrecovered() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(12, 3.0);
        // the only replica dies before the first arrival and never returns
        let plan = FaultPlan {
            crashes: vec![CrashWindow { replica: 0, at: 0.0, recover_at: f64::INFINITY }],
            ..FaultPlan::default()
        };
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            1,
            RouterPolicy::RoundRobin,
        ))
        .with_faults(plan);
        let out = cluster.run(&t).unwrap();
        assert!(out.merged.records.is_empty());
        assert_eq!(out.failed, (0..12).collect::<Vec<_>>());
        assert_eq!(out.accounted(), 12);
    }

    #[test]
    fn straggler_and_io_burst_windows_apply_and_clear() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(16, 4.0);
        let plan = FaultPlan {
            stragglers: vec![Straggler {
                replica: 0,
                from: 0.5,
                until: 2.5,
                slowdown: 5.0,
            }],
            io_bursts: vec![IoBurst { replica: 1, from: 0.5, until: 2.5 }],
            ..FaultPlan::default()
        };
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            2,
            RouterPolicy::KvPressure,
        ))
        .with_faults(plan);
        let out = cluster.run(&t).unwrap();
        assert_eq!(out.accounted(), 16);
        assert!(out.failed.is_empty(), "stragglers/io bursts never fail requests");
        let f = out.faults.unwrap();
        assert_eq!(f.straggler_windows, 1);
        assert_eq!(f.io_bursts, 1);
        assert_eq!(f.crashes, 0);
        assert_eq!(cluster.fault_log().len(), 4);
        // both windows closed: backends are nominal again
        // (whitebox via the per-replica stats: the run completed, which
        // already exercises set_slowdown/set_disk_faulty on and off)
    }

    #[test]
    fn empty_plan_matches_no_plan_bit_for_bit() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for router in RouterPolicy::ALL {
            let t = trace(16, 3.0);
            let mut plain = Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, *router));
            let a = plain.run(&t).unwrap();
            let mut faulted = Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, *router))
                .with_faults(FaultPlan::default());
            let b = faulted.run(&t).unwrap();
            assert_eq!(a.merged.records, b.merged.records, "router {}", router.name());
            assert_eq!(a.dropped, b.dropped);
            assert!(b.failed.is_empty());
            assert_eq!(
                a.merged.makespan.to_bits(),
                b.merged.makespan.to_bits(),
                "router {}",
                router.name()
            );
        }
    }

    #[test]
    fn heap_drive_matches_lockstep_bit_for_bit() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for router in RouterPolicy::ALL {
            let t = trace(24, 3.0);
            let mut heap = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router));
            heap.set_lockstep(false);
            let a = heap.run(&t).unwrap();
            let mut lock = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router));
            lock.set_lockstep(true);
            let b = lock.run(&t).unwrap();
            assert_eq!(a.merged.records, b.merged.records, "router {}", router.name());
            assert_eq!(a.dropped, b.dropped, "router {}", router.name());
            assert_eq!(
                a.merged.makespan.to_bits(),
                b.merged.makespan.to_bits(),
                "router {}",
                router.name()
            );
            assert!(
                heap.advances() <= lock.advances(),
                "heap drive took {} scheduler-bearing steps, lockstep {} (router {})",
                heap.advances(),
                lock.advances(),
                router.name()
            );
        }
    }

    #[test]
    fn heap_drive_matches_lockstep_under_faults() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let plan = FaultPlan {
            crashes: vec![CrashWindow { replica: 1, at: 1.0, recover_at: 2.5 }],
            stragglers: vec![Straggler {
                replica: 0,
                from: 0.5,
                until: 3.0,
                slowdown: 4.0,
            }],
            io_bursts: vec![IoBurst { replica: 2, from: 0.5, until: 2.0 }],
            probation_s: 0.5,
            ..FaultPlan::default()
        };
        for router in RouterPolicy::ALL {
            let t = trace(24, 3.0);
            let mut heap = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router))
                .with_faults(plan.clone());
            heap.set_lockstep(false);
            let a = heap.run(&t).unwrap();
            let log_a: Vec<String> =
                heap.fault_log().iter().map(|e| e.render()).collect();
            let mut lock = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router))
                .with_faults(plan.clone());
            lock.set_lockstep(true);
            let b = lock.run(&t).unwrap();
            let log_b: Vec<String> =
                lock.fault_log().iter().map(|e| e.render()).collect();
            assert_eq!(a.merged.records, b.merged.records, "router {}", router.name());
            assert_eq!(a.dropped, b.dropped, "router {}", router.name());
            assert_eq!(a.failed, b.failed, "router {}", router.name());
            assert_eq!(log_a, log_b, "router {}", router.name());
            assert_eq!(a.faults, b.faults, "router {}", router.name());
        }
    }

    #[test]
    fn unsorted_trace_falls_back_to_lockstep_order() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let mut t = trace(8, 3.0);
        t.requests.swap(2, 5); // ids keep their arrivals: now out of order
        let mut a = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            2,
            RouterPolicy::RoundRobin,
        ));
        let out_a = a.run(&t).unwrap();
        let mut b = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            2,
            RouterPolicy::RoundRobin,
        ));
        b.set_lockstep(true);
        let out_b = b.run(&t).unwrap();
        // the dispatcher must notice the disorder and take the oracle path
        assert_eq!(out_a.merged.records, out_b.merged.records);
        assert_eq!(a.advances(), b.advances());
    }

    #[test]
    fn checkpointed_failover_adopts_and_never_inflates_recompute() {
        // one crash window, disk-tiered config: execution up to the crash
        // is bit-identical with checkpointing on or off (the write is
        // virtual), so both runs drain the same victims with the same
        // progress — adoption can only shrink the recompute bill
        let base = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true })
            .with_disk(crate::config::DiskSpec::nvme_4tb());
        let plan = FaultPlan {
            crashes: vec![CrashWindow { replica: 0, at: 1.5, recover_at: f64::INFINITY }],
            ..FaultPlan::default()
        };
        let run = |cfg: &ServingConfig| {
            let t = trace(24, 3.0);
            let mut cluster =
                Cluster::new(&ClusterConfig::homogeneous(cfg, 3, RouterPolicy::KvPressure))
                    .with_faults(plan.clone());
            let out = cluster.run(&t).unwrap();
            assert_eq!(out.accounted(), 24);
            out.faults.unwrap()
        };
        let off = run(&base.clone());
        let on = run(&base.with_checkpointing(8));
        assert_eq!(off.adoptions, 0, "checkpointing off never adopts");
        assert_eq!(off.resumed_tokens, 0);
        assert!(on.recomputed_tokens <= off.recomputed_tokens);
        // adopted requests skip the retry budget, so retries can only drop
        assert!(on.retries <= off.retries);
        // every adoption either resumed tokens or degraded to recompute;
        // resumed work never appears without an adoption
        assert!(on.resumed_tokens == 0 || on.adoptions > 0);
    }

    #[test]
    fn planned_migration_moves_state_and_fences_source() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(24, 3.0);
        let plan = FaultPlan {
            migrations: vec![Migration { src: 0, dst: 1, at: 1.0 }],
            ..FaultPlan::default()
        };
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            2,
            RouterPolicy::RoundRobin,
        ))
        .with_faults(plan);
        let out = cluster.run(&t).unwrap();
        // migration never loses or fails a request: everything the source
        // held is adopted by the destination and runs to completion
        assert_eq!(out.accounted(), 24);
        assert!(out.failed.is_empty());
        assert!(out.dropped.is_empty());
        let f = out.faults.unwrap();
        assert_eq!(f.migrations, 1);
        assert_eq!(f.retries, 0, "migration is adoption, not failover retries");
        // the fenced source takes no post-migration traffic
        assert!(out.per_replica[0].routed < 24);
        assert_eq!(cluster.fault_log().len(), 1);
    }

    #[test]
    fn migration_heap_matches_lockstep_bit_for_bit() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true })
            .with_disk(crate::config::DiskSpec::nvme_4tb())
            .with_checkpointing(8);
        let plan = FaultPlan {
            crashes: vec![CrashWindow { replica: 2, at: 2.0, recover_at: 4.0 }],
            migrations: vec![Migration { src: 0, dst: 1, at: 1.0 }],
            probation_s: 0.5,
            ..FaultPlan::default()
        };
        for router in RouterPolicy::ALL {
            let t = trace(24, 3.0);
            let mut heap = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router))
                .with_faults(plan.clone());
            heap.set_lockstep(false);
            let a = heap.run(&t).unwrap();
            let log_a: Vec<String> =
                heap.fault_log().iter().map(|e| e.render()).collect();
            let mut lock = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router))
                .with_faults(plan.clone());
            lock.set_lockstep(true);
            let b = lock.run(&t).unwrap();
            let log_b: Vec<String> =
                lock.fault_log().iter().map(|e| e.render()).collect();
            assert_eq!(a.merged.records, b.merged.records, "router {}", router.name());
            assert_eq!(a.dropped, b.dropped, "router {}", router.name());
            assert_eq!(a.failed, b.failed, "router {}", router.name());
            assert_eq!(log_a, log_b, "router {}", router.name());
            assert_eq!(a.faults, b.faults, "router {}", router.name());
        }
    }

    #[test]
    fn administrative_migrate_validates_and_moves() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            2,
            RouterPolicy::RoundRobin,
        ));
        // no fault plan and not yet run: the router would keep routing to
        // the drained source, so this must be refused
        assert!(cluster.migrate(0, 1).is_err());
        let mut faulted = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            2,
            RouterPolicy::RoundRobin,
        ))
        .with_faults(FaultPlan::default());
        assert!(faulted.migrate(0, 0).is_err(), "src == dst");
        assert!(faulted.migrate(0, 7).is_err(), "out of range");
        // idle pre-run migration: nothing to move, source fenced, logged
        assert_eq!(faulted.migrate(0, 1).unwrap(), 0);
        assert_eq!(faulted.fault_log().len(), 1);
        let t = trace(8, 3.0);
        let out = faulted.run(&t).unwrap();
        assert_eq!(out.accounted(), 8);
        assert_eq!(out.per_replica[0].routed, 0, "fenced source takes nothing");
        assert_eq!(out.faults.unwrap().migrations, 1);
    }

    #[test]
    fn summary_matches_merged_report() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(16, 2.0);
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, RouterPolicy::KvPressure));
        let out = cluster.run(&t).unwrap();
        let s = out.summary(&cfg.slo);
        assert_eq!(
            s.per_replica.iter().map(|r| r.completed).sum::<usize>(),
            out.merged.records.len()
        );
        assert!((s.ttft_mean - out.merged.ttft().mean()).abs() < 1e-12);
    }
}
