//! Cluster-scale serving: N independent engine replicas behind one
//! KV-pressure- / SLO-aware router.
//!
//! The paper's Fig. 1 queueing blowups are competition for KV blocks on
//! *one* engine; at fleet scale the same competition reappears one level
//! up, as replica choice. A router that ignores per-replica KV pressure
//! recreates exactly the head-of-line blocking LayerKV removed — so the
//! router here reads each replica's live pool aggregates and cost model,
//! the same signals the in-engine scheduler uses (see `router.rs` for
//! the four policies).
//!
//! [`Cluster<B>`] owns N [`Engine<B>`] replicas — homogeneous or
//! heterogeneous [`ServingConfig`]s, each with its own GPU/host/disk
//! hierarchy — and steps them in virtual-time lockstep: every replica is
//! advanced to each request's arrival instant before the router sees the
//! views, so routing decisions observe exactly the state a front-end
//! would at that moment. Replicas never interact below the router
//! (separate pools, separate clocks), which is what makes the lockstep
//! exact: stepping order between replicas cannot change any replica's
//! outcome.
//!
//! The per-replica drive uses the engine's incremental API
//! (`submit`/`step_once`), which mirrors `Engine::try_run` line for
//! line — a 1-replica cluster is **bit-identical** to a bare
//! `Engine<SimBackend>` run on the same trace, under every router
//! (`tests/prop_cluster.rs`, and the acceptance gate in CI's prop-deep
//! job).
//!
//! In a real deployment each replica is one serving process (one GPU or
//! TP group), and the router is the front-end: `serve --replicas N
//! --router <policy>` runs exactly that shape with real engine workers
//! (see `server/`), and README "Cluster architecture" maps the pieces.

pub mod faults;
pub mod replica;
pub mod report;
pub mod router;

pub use faults::{CrashWindow, FaultPlan, HealthRouter, IoBurst, Straggler};
pub use replica::Replica;
pub use report::{ClusterReport, ReplicaOutcome};
pub use router::{
    kv_pressure_score, make_router, ReplicaView, Router, RouterPolicy,
};

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::config::ServingConfig;
use crate::coordinator::backend::{ExecutionBackend, SimBackend};
use crate::coordinator::{standard_predictor, Engine, LengthPredictor, CLOCK_EPS};
use crate::metrics::{FaultEvent, FaultKind, FaultSummary, RequestRecord};
use crate::workload::{Trace, TraceRequest};

use faults::HealthState;

/// How a cluster is assembled: one `ServingConfig` per replica (mixed
/// hardware is fine — each engine sizes its own pools) plus the routing
/// policy.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub replicas: Vec<ServingConfig>,
    pub router: RouterPolicy,
    pub predictor_accuracy: f64,
}

/// Default predictor accuracy (the same 0.8 regime as
/// `experiments::PREDICTOR_ACC`, defined here so the core cluster module
/// does not depend on the experiment harness).
pub const DEFAULT_PREDICTOR_ACC: f64 = 0.8;

impl ClusterConfig {
    /// N identical replicas of one config.
    pub fn homogeneous(cfg: &ServingConfig, n: usize, router: RouterPolicy) -> Self {
        ClusterConfig {
            replicas: vec![cfg.clone(); n],
            router,
            predictor_accuracy: DEFAULT_PREDICTOR_ACC,
        }
    }
}

/// N engine replicas + a router, stepped in virtual-time lockstep.
pub struct Cluster<B: ExecutionBackend = SimBackend> {
    replicas: Vec<Replica<B>>,
    router: Box<dyn Router>,
    predictor_accuracy: f64,
    /// `run` is single-shot (engines keep their stats/id maps); this
    /// turns a second call into a clear error instead of bad data.
    ran: bool,
    /// Fault-injection state; `None` (the default) takes the exact
    /// pre-fault code path — no health checks, no event stream.
    faults: Option<FaultRun>,
}

/// Live state of one fault-injected run: the compiled event stream, the
/// health table shared with the [`HealthRouter`], and the failover
/// bookkeeping (retry counts, parked requests, exhausted ids).
struct FaultRun {
    plan: FaultPlan,
    events: Vec<FaultEvent>,
    next_event: usize,
    health: Rc<RefCell<HealthState>>,
    /// Global id -> crash drains so far.
    retries: HashMap<usize, u32>,
    /// Total re-submissions performed (failover traffic).
    retries_total: u64,
    /// Requests with no live replica to land on, waiting for a recovery.
    parked: Vec<TraceRequest>,
    /// Global ids that exhausted the retry budget (or never found a live
    /// replica).
    failed: Vec<usize>,
    /// Events actually applied, in order — a determinism witness.
    log: Vec<FaultEvent>,
}

impl FaultRun {
    fn summary(&self, end: f64) -> FaultSummary {
        let count = |pred: fn(&FaultKind) -> bool| {
            self.log.iter().filter(|e| pred(&e.kind)).count()
        };
        let mut downtime_s = 0.0;
        for c in &self.plan.crashes {
            let until = c.recover_at.min(end);
            if until > c.at {
                downtime_s += until - c.at;
            }
        }
        FaultSummary {
            crashes: count(|k| matches!(k, FaultKind::Crash)),
            recoveries: count(|k| matches!(k, FaultKind::Recover)),
            straggler_windows: count(|k| matches!(k, FaultKind::StragglerStart { .. })),
            io_bursts: count(|k| matches!(k, FaultKind::IoErrorStart)),
            retries: self.retries_total,
            failed: self.failed.len(),
            downtime_s,
        }
    }
}

impl Cluster<SimBackend> {
    /// Build a simulation cluster: one `Engine<SimBackend>` per replica
    /// config, pools sized by each config's memory-profiling pass.
    pub fn new(cfg: &ClusterConfig) -> Self {
        assert!(!cfg.replicas.is_empty(), "cluster needs at least one replica");
        let replicas = cfg
            .replicas
            .iter()
            .map(|c| {
                // placeholder predictor: the incremental path receives
                // each request's prediction at submit time, from the
                // cluster's own trace-wide predictor (so a 1-replica
                // cluster sees exactly run_trace's predictions)
                let p = LengthPredictor::new(2, cfg.predictor_accuracy, 42);
                Replica::new(Engine::new(c.clone(), p))
            })
            .collect();
        Cluster {
            replicas,
            router: make_router(cfg.router, cfg.replicas.len()),
            predictor_accuracy: cfg.predictor_accuracy,
            ran: false,
            faults: None,
        }
    }
}

impl<B: ExecutionBackend> Cluster<B> {
    /// Assemble from pre-built engines (any backend) and a router.
    pub fn from_replicas(
        engines: Vec<Engine<B>>,
        router: Box<dyn Router>,
        predictor_accuracy: f64,
    ) -> Self {
        assert!(!engines.is_empty(), "cluster needs at least one replica");
        Cluster {
            replicas: engines.into_iter().map(Replica::new).collect(),
            router,
            predictor_accuracy,
            ran: false,
            faults: None,
        }
    }

    /// Attach a [`FaultPlan`]: wraps the router in a [`HealthRouter`]
    /// sharing a health table with the fault loop, and compiles the plan
    /// to its event stream. An empty plan is bit-identical to not calling
    /// this at all (`tests/prop_faults.rs` pins that).
    pub fn with_faults(self, plan: FaultPlan) -> Self {
        assert!(!self.ran, "attach faults before running");
        let n = self.replicas.len();
        if let Some(m) = plan.max_replica() {
            assert!(m < n, "fault plan names replica {m}, cluster has {n}");
        }
        let health = Rc::new(RefCell::new(HealthState::new(n)));
        let events = plan.events();
        Cluster {
            replicas: self.replicas,
            router: Box::new(HealthRouter::new(self.router, Rc::clone(&health))),
            predictor_accuracy: self.predictor_accuracy,
            ran: self.ran,
            faults: Some(FaultRun {
                plan,
                events,
                next_event: 0,
                health,
                retries: HashMap::new(),
                retries_total: 0,
                parked: Vec::new(),
                failed: Vec::new(),
                log: Vec::new(),
            }),
        }
    }

    /// Fault events applied so far, in application order (empty when no
    /// plan is attached). Stable render via `FaultEvent::render` makes
    /// this a byte-identity witness for same-seed replays.
    pub fn fault_log(&self) -> &[FaultEvent] {
        self.faults.as_ref().map(|f| f.log.as_slice()).unwrap_or(&[])
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// Toggle decode fast-forwarding (macro-stepping) on every replica.
    /// Off = the pure single-step lockstep, the debugging reference the
    /// property suite and the hotpath bench compare against.
    pub fn set_macro_steps(&mut self, on: bool) {
        for rep in &mut self.replicas {
            rep.engine.set_macro_steps(on);
        }
    }

    /// Every replica recomputes its cached state from scratch each step
    /// and single-steps every decode — the frozen-oracle path the golden
    /// cluster replay pins router + lockstep changes against.
    pub fn use_recompute_oracle(&mut self) {
        for rep in &mut self.replicas {
            rep.engine.use_recompute_oracle();
        }
    }

    /// Serve a whole trace: route every request at its arrival instant,
    /// drain all replicas, and merge the per-replica reports back into
    /// trace order. Single-shot — build a fresh `Cluster` per trace (the
    /// replica engines keep their clocks, stats, and id maps).
    pub fn run(&mut self, trace: &Trace) -> anyhow::Result<ClusterReport> {
        anyhow::ensure!(
            !self.ran,
            "Cluster::run is single-shot — build a fresh Cluster per trace"
        );
        self.ran = true;
        let predictor = standard_predictor(trace, self.predictor_accuracy);
        for tr in &trace.requests {
            // fault events scheduled before this arrival fire first (a
            // crash at the arrival instant fences the replica before the
            // router can pick it)
            if self.faults.is_some() {
                self.fire_events_until(tr.arrival, false, &predictor)?;
            }
            // lockstep: every replica catches up to this arrival before
            // the router looks at the views (CLOCK_EPS mirrors try_run's
            // arrival-admission epsilon). The arrival is each engine's
            // decode fast-forward horizon, so a stable replica advances to
            // its next event in ONE macro-step instead of one `step_once`
            // per decode token — the loop runs O(events) turns, not
            // O(tokens).
            let down = self.down_flags();
            for (i, rep) in self.replicas.iter_mut().enumerate() {
                if down.as_ref().is_some_and(|d| d[i]) {
                    continue; // crashed: fenced until its recovery event
                }
                while tr.arrival > rep.engine.now() + CLOCK_EPS {
                    if !rep.engine.step_once_until(false, tr.arrival)? {
                        break; // idle: its clock advances at its next submit
                    }
                }
            }
            if let Some(f) = &mut self.faults {
                let mut st = f.health.borrow_mut();
                st.now = tr.arrival;
                if !st.any_up() {
                    // whole cluster down: park until a recovery (failed at
                    // the end of the run if none comes)
                    drop(st);
                    f.parked.push(tr.clone());
                    continue;
                }
            }
            self.pump_feedback();
            let idx = {
                let views: Vec<ReplicaView> =
                    self.replicas.iter().enumerate().map(|(i, r)| r.view(i)).collect();
                let picked = self.router.route(tr.prompt_len, &views);
                assert!(
                    picked < self.replicas.len(),
                    "router {} returned out-of-range replica {picked} of {}",
                    self.router.name(),
                    self.replicas.len()
                );
                picked
            };
            let rep = &mut self.replicas[idx];
            if tr.arrival > rep.engine.now() + CLOCK_EPS {
                rep.engine.wait_until(tr.arrival);
            }
            rep.submit(tr, predictor.predict(tr.id, tr.output_len));
        }
        // remaining fault events (crashes/recoveries past the last
        // arrival) fire in order while the replicas drain toward them
        if self.faults.is_some() {
            self.fire_events_until(f64::INFINITY, true, &predictor)?;
        }
        // drain: no more input — replicas run independently to empty
        let down = self.down_flags();
        for (i, rep) in self.replicas.iter_mut().enumerate() {
            if down.as_ref().is_some_and(|d| d[i]) {
                continue;
            }
            while rep.engine.has_work() {
                if !rep.engine.step_once(true)? {
                    break;
                }
            }
        }
        // requests still parked (no replica ever recovered): failed
        if let Some(f) = &mut self.faults {
            for tr in std::mem::take(&mut f.parked) {
                f.failed.push(tr.id);
            }
        }
        self.pump_feedback();
        Ok(self.take_report())
    }

    /// Per-replica down flags when faults are active (`None` on the
    /// fault-free path, which must stay branch-identical to the
    /// pre-fault code).
    fn down_flags(&self) -> Option<Vec<bool>> {
        self.faults.as_ref().map(|f| f.health.borrow().down.clone())
    }

    /// Fire every scheduled fault event with `t <= horizon`, advancing
    /// live replicas to each event instant first so drains and health
    /// flips happen at exactly the scheduled virtual time.
    fn fire_events_until(
        &mut self,
        horizon: f64,
        draining: bool,
        predictor: &LengthPredictor,
    ) -> anyhow::Result<()> {
        // take the fault state out so event handlers can borrow replicas
        // and router mutably alongside it
        let Some(mut f) = self.faults.take() else { return Ok(()) };
        let result = self.fire_events_inner(&mut f, horizon, draining, predictor);
        self.faults = Some(f);
        result
    }

    fn fire_events_inner(
        &mut self,
        f: &mut FaultRun,
        horizon: f64,
        draining: bool,
        predictor: &LengthPredictor,
    ) -> anyhow::Result<()> {
        while f.next_event < f.events.len() && f.events[f.next_event].t <= horizon {
            let ev = f.events[f.next_event].clone();
            f.next_event += 1;
            let down = f.health.borrow().down.clone();
            for (i, rep) in self.replicas.iter_mut().enumerate() {
                if down[i] {
                    continue;
                }
                while ev.t > rep.engine.now() + CLOCK_EPS {
                    if !rep.engine.step_once_until(draining, ev.t)? {
                        break;
                    }
                }
            }
            f.health.borrow_mut().now = ev.t;
            self.apply_event(f, &ev, predictor)?;
            f.log.push(ev);
        }
        Ok(())
    }

    fn apply_event(
        &mut self,
        f: &mut FaultRun,
        ev: &FaultEvent,
        predictor: &LengthPredictor,
    ) -> anyhow::Result<()> {
        match ev.kind {
            FaultKind::Crash => {
                if f.health.borrow().down[ev.replica] {
                    return Ok(()); // overlapping windows: already down
                }
                f.health.borrow_mut().down[ev.replica] = true;
                let (drained, gids) = {
                    let rep = &mut self.replicas[ev.replica];
                    if ev.t > rep.engine.now() + CLOCK_EPS {
                        rep.engine.wait_until(ev.t);
                    }
                    let drained = rep.engine.drain();
                    let gids: Vec<usize> =
                        drained.iter().map(|d| rep.global_ids[d.id]).collect();
                    (drained, gids)
                };
                for (d, gid) in drained.into_iter().zip(gids) {
                    let n = f.retries.entry(gid).or_insert(0);
                    *n += 1;
                    if *n > f.plan.retry_budget {
                        f.failed.push(gid); // budget exhausted: terminal
                        continue;
                    }
                    f.retries_total += 1;
                    let tr = TraceRequest {
                        id: gid,
                        arrival: d.arrival, // original: TTFT includes downtime
                        prompt_len: d.prompt_len,
                        output_len: d.output_len,
                    };
                    self.resubmit(f, tr, predictor, ev.t)?;
                }
            }
            FaultKind::Recover => {
                {
                    let mut st = f.health.borrow_mut();
                    st.down[ev.replica] = false;
                    st.probation_until[ev.replica] = ev.t + f.plan.probation_s;
                }
                let rep = &mut self.replicas[ev.replica];
                if ev.t > rep.engine.now() + CLOCK_EPS {
                    rep.engine.wait_until(ev.t);
                }
                rep.engine.reopen_admission();
                // a recovery means at least one replica is up: flush the
                // parked backlog through the (health-aware) router
                for tr in std::mem::take(&mut f.parked) {
                    self.resubmit(f, tr, predictor, ev.t)?;
                }
            }
            FaultKind::StragglerStart { slowdown } => {
                self.replicas[ev.replica].engine.backend.set_slowdown(slowdown);
            }
            FaultKind::StragglerEnd => {
                self.replicas[ev.replica].engine.backend.set_slowdown(1.0);
            }
            FaultKind::IoErrorStart => {
                self.replicas[ev.replica].engine.set_disk_faulty(true);
            }
            FaultKind::IoErrorEnd => {
                self.replicas[ev.replica].engine.set_disk_faulty(false);
            }
        }
        Ok(())
    }

    /// Route a failover or parked request at cluster time `at`. Parks it
    /// when every replica is down.
    fn resubmit(
        &mut self,
        f: &mut FaultRun,
        tr: TraceRequest,
        predictor: &LengthPredictor,
        at: f64,
    ) -> anyhow::Result<()> {
        if !f.health.borrow().any_up() {
            f.parked.push(tr);
            return Ok(());
        }
        self.pump_feedback();
        let idx = {
            let views: Vec<ReplicaView> =
                self.replicas.iter().enumerate().map(|(i, r)| r.view(i)).collect();
            let picked = self.router.route(tr.prompt_len, &views);
            assert!(
                picked < self.replicas.len(),
                "router {} returned out-of-range replica {picked} of {}",
                self.router.name(),
                self.replicas.len()
            );
            picked
        };
        debug_assert!(
            !f.health.borrow().down[idx],
            "health router must fence crashed replicas"
        );
        let rep = &mut self.replicas[idx];
        if at > rep.engine.now() + CLOCK_EPS {
            rep.engine.wait_until(at);
        }
        rep.submit(&tr, predictor.predict(tr.id, tr.output_len));
        Ok(())
    }

    /// Feed newly completed requests' TTFTs to the router.
    fn pump_feedback(&mut self) {
        for (i, rep) in self.replicas.iter_mut().enumerate() {
            // `self.replicas` and `self.router` are disjoint fields, so
            // the record borrow and the router call coexist clone-free
            let records = rep.engine.records();
            for r in &records[rep.records_seen..] {
                self.router.observe_ttft(i, r.ttft());
            }
            rep.records_seen = records.len();
        }
    }

    /// Merge per-replica results, remapping local ids to global trace ids.
    fn take_report(&mut self) -> ClusterReport {
        let mut merged: Vec<RequestRecord> = Vec::new();
        let mut dropped = Vec::new();
        let mut per_replica = Vec::with_capacity(self.replicas.len());
        for rep in &mut self.replicas {
            let report = rep.engine.take_report();
            let stats = rep.engine.stats().clone();
            for r in &report.records {
                let mut g = r.clone();
                g.id = rep.global_ids[r.id];
                merged.push(g);
            }
            for &local in &stats.dropped {
                dropped.push(rep.global_ids[local]);
            }
            per_replica.push(ReplicaOutcome { routed: rep.routed(), report, stats });
        }
        dropped.sort_unstable();
        let (failed, faults) = match self.faults.as_mut() {
            Some(f) => {
                // summary first: it reads `failed.len()` before the take
                let end = self
                    .replicas
                    .iter()
                    .map(|r| r.engine.now())
                    .fold(0.0, f64::max);
                let summary = f.summary(end);
                let mut failed = std::mem::take(&mut f.failed);
                failed.sort_unstable();
                (failed, Some(summary))
            }
            None => (Vec::new(), None),
        };
        ClusterReport {
            merged: crate::metrics::Report::new(merged),
            dropped,
            failed,
            faults,
            per_replica,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;
    use crate::util::Rng;
    use crate::workload::arrivals::Arrivals;
    use crate::workload::fixed::FixedWorkload;

    fn trace(n: usize, rate: f64) -> Trace {
        FixedWorkload {
            prompt_len: 1024,
            output_len: 64,
            n_requests: n,
            arrivals: Arrivals::Poisson { rate },
        }
        .generate(&mut Rng::new(3))
    }

    #[test]
    fn every_request_accounted_across_replicas() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for router in RouterPolicy::ALL {
            let t = trace(24, 3.0);
            let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router));
            let out = cluster.run(&t).unwrap();
            assert_eq!(out.accounted(), 24, "router {}", router.name());
            assert_eq!(
                out.per_replica.iter().map(|o| o.routed).sum::<usize>(),
                24
            );
            // merged ids are exactly the trace's ids
            let mut ids: Vec<usize> = out.merged.records.iter().map(|r| r.id).collect();
            ids.extend(out.dropped.iter().copied());
            ids.sort_unstable();
            assert_eq!(ids, (0..24).collect::<Vec<_>>());
        }
    }

    #[test]
    fn round_robin_balances_exactly() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(20, 2.0);
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, 4, RouterPolicy::RoundRobin));
        let out = cluster.run(&t).unwrap();
        for o in &out.per_replica {
            assert_eq!(o.routed, 5);
        }
        let s = out.summary(&cfg.slo);
        assert!((s.max_share() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn run_is_single_shot() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(4, 2.0);
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, RouterPolicy::RoundRobin));
        cluster.run(&t).unwrap();
        assert!(cluster.run(&t).is_err(), "second run must be a clear error");
    }

    #[test]
    fn crash_failover_conserves_every_request() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for router in RouterPolicy::ALL {
            let t = trace(24, 3.0);
            let plan = FaultPlan {
                crashes: vec![CrashWindow {
                    replica: 0,
                    at: 1.5,
                    recover_at: f64::INFINITY,
                }],
                ..FaultPlan::default()
            };
            let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router))
                .with_faults(plan);
            let out = cluster.run(&t).unwrap();
            assert_eq!(out.accounted(), 24, "router {}", router.name());
            let mut ids: Vec<usize> = out.merged.records.iter().map(|r| r.id).collect();
            ids.extend(out.dropped.iter().copied());
            ids.extend(out.failed.iter().copied());
            ids.sort_unstable();
            assert_eq!(ids, (0..24).collect::<Vec<_>>(), "router {}", router.name());
            let f = out.faults.expect("plan attached");
            assert_eq!(f.crashes, 1);
            assert_eq!(f.recoveries, 0);
            assert_eq!(cluster.fault_log().len(), 1);
            // the dead replica never receives post-crash traffic: its
            // routed count is frozen at its pre-crash share
            assert!(out.per_replica[0].routed < 24, "router {}", router.name());
        }
    }

    #[test]
    fn recovery_reopens_admission_and_probation_expires() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(24, 3.0);
        let plan = FaultPlan {
            crashes: vec![CrashWindow { replica: 1, at: 1.0, recover_at: 2.0 }],
            probation_s: 0.5,
            ..FaultPlan::default()
        };
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            2,
            RouterPolicy::RoundRobin,
        ))
        .with_faults(plan);
        let out = cluster.run(&t).unwrap();
        assert_eq!(out.accounted(), 24);
        let f = out.faults.unwrap();
        assert_eq!(f.crashes, 1);
        assert_eq!(f.recoveries, 1);
        assert!((f.downtime_s - 1.0).abs() < 1e-12);
        assert_eq!(cluster.fault_log().len(), 2);
        // post-recovery the replica takes traffic again: round-robin over
        // a 2-cluster would give it ~half absent faults; it must at least
        // have received something after rejoining
        assert!(out.per_replica[1].routed > 0);
    }

    #[test]
    fn whole_cluster_down_parks_then_fails_unrecovered() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(12, 3.0);
        // the only replica dies before the first arrival and never returns
        let plan = FaultPlan {
            crashes: vec![CrashWindow { replica: 0, at: 0.0, recover_at: f64::INFINITY }],
            ..FaultPlan::default()
        };
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            1,
            RouterPolicy::RoundRobin,
        ))
        .with_faults(plan);
        let out = cluster.run(&t).unwrap();
        assert!(out.merged.records.is_empty());
        assert_eq!(out.failed, (0..12).collect::<Vec<_>>());
        assert_eq!(out.accounted(), 12);
    }

    #[test]
    fn straggler_and_io_burst_windows_apply_and_clear() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(16, 4.0);
        let plan = FaultPlan {
            stragglers: vec![Straggler {
                replica: 0,
                from: 0.5,
                until: 2.5,
                slowdown: 5.0,
            }],
            io_bursts: vec![IoBurst { replica: 1, from: 0.5, until: 2.5 }],
            ..FaultPlan::default()
        };
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(
            &cfg,
            2,
            RouterPolicy::KvPressure,
        ))
        .with_faults(plan);
        let out = cluster.run(&t).unwrap();
        assert_eq!(out.accounted(), 16);
        assert!(out.failed.is_empty(), "stragglers/io bursts never fail requests");
        let f = out.faults.unwrap();
        assert_eq!(f.straggler_windows, 1);
        assert_eq!(f.io_bursts, 1);
        assert_eq!(f.crashes, 0);
        assert_eq!(cluster.fault_log().len(), 4);
        // both windows closed: backends are nominal again
        // (whitebox via the per-replica stats: the run completed, which
        // already exercises set_slowdown/set_disk_faulty on and off)
    }

    #[test]
    fn empty_plan_matches_no_plan_bit_for_bit() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for router in RouterPolicy::ALL {
            let t = trace(16, 3.0);
            let mut plain = Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, *router));
            let a = plain.run(&t).unwrap();
            let mut faulted = Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, *router))
                .with_faults(FaultPlan::default());
            let b = faulted.run(&t).unwrap();
            assert_eq!(a.merged.records, b.merged.records, "router {}", router.name());
            assert_eq!(a.dropped, b.dropped);
            assert!(b.failed.is_empty());
            assert_eq!(
                a.merged.makespan.to_bits(),
                b.merged.makespan.to_bits(),
                "router {}",
                router.name()
            );
        }
    }

    #[test]
    fn summary_matches_merged_report() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(16, 2.0);
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, RouterPolicy::KvPressure));
        let out = cluster.run(&t).unwrap();
        let s = out.summary(&cfg.slo);
        assert_eq!(
            s.per_replica.iter().map(|r| r.completed).sum::<usize>(),
            out.merged.records.len()
        );
        assert!((s.ttft_mean - out.merged.ttft().mean()).abs() < 1e-12);
    }
}
