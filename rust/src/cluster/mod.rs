//! Cluster-scale serving: N independent engine replicas behind one
//! KV-pressure- / SLO-aware router.
//!
//! The paper's Fig. 1 queueing blowups are competition for KV blocks on
//! *one* engine; at fleet scale the same competition reappears one level
//! up, as replica choice. A router that ignores per-replica KV pressure
//! recreates exactly the head-of-line blocking LayerKV removed — so the
//! router here reads each replica's live pool aggregates and cost model,
//! the same signals the in-engine scheduler uses (see `router.rs` for
//! the four policies).
//!
//! [`Cluster<B>`] owns N [`Engine<B>`] replicas — homogeneous or
//! heterogeneous [`ServingConfig`]s, each with its own GPU/host/disk
//! hierarchy — and steps them in virtual-time lockstep: every replica is
//! advanced to each request's arrival instant before the router sees the
//! views, so routing decisions observe exactly the state a front-end
//! would at that moment. Replicas never interact below the router
//! (separate pools, separate clocks), which is what makes the lockstep
//! exact: stepping order between replicas cannot change any replica's
//! outcome.
//!
//! The per-replica drive uses the engine's incremental API
//! (`submit`/`step_once`), which mirrors `Engine::try_run` line for
//! line — a 1-replica cluster is **bit-identical** to a bare
//! `Engine<SimBackend>` run on the same trace, under every router
//! (`tests/prop_cluster.rs`, and the acceptance gate in CI's prop-deep
//! job).
//!
//! In a real deployment each replica is one serving process (one GPU or
//! TP group), and the router is the front-end: `serve --replicas N
//! --router <policy>` runs exactly that shape with real engine workers
//! (see `server/`), and README "Cluster architecture" maps the pieces.

pub mod replica;
pub mod report;
pub mod router;

pub use replica::Replica;
pub use report::{ClusterReport, ReplicaOutcome};
pub use router::{
    kv_pressure_score, make_router, ReplicaView, Router, RouterPolicy,
};

use crate::config::ServingConfig;
use crate::coordinator::backend::{ExecutionBackend, SimBackend};
use crate::coordinator::{standard_predictor, Engine, LengthPredictor, CLOCK_EPS};
use crate::metrics::RequestRecord;
use crate::workload::Trace;

/// How a cluster is assembled: one `ServingConfig` per replica (mixed
/// hardware is fine — each engine sizes its own pools) plus the routing
/// policy.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub replicas: Vec<ServingConfig>,
    pub router: RouterPolicy,
    pub predictor_accuracy: f64,
}

/// Default predictor accuracy (the same 0.8 regime as
/// `experiments::PREDICTOR_ACC`, defined here so the core cluster module
/// does not depend on the experiment harness).
pub const DEFAULT_PREDICTOR_ACC: f64 = 0.8;

impl ClusterConfig {
    /// N identical replicas of one config.
    pub fn homogeneous(cfg: &ServingConfig, n: usize, router: RouterPolicy) -> Self {
        ClusterConfig {
            replicas: vec![cfg.clone(); n],
            router,
            predictor_accuracy: DEFAULT_PREDICTOR_ACC,
        }
    }
}

/// N engine replicas + a router, stepped in virtual-time lockstep.
pub struct Cluster<B: ExecutionBackend = SimBackend> {
    replicas: Vec<Replica<B>>,
    router: Box<dyn Router>,
    predictor_accuracy: f64,
    /// `run` is single-shot (engines keep their stats/id maps); this
    /// turns a second call into a clear error instead of bad data.
    ran: bool,
}

impl Cluster<SimBackend> {
    /// Build a simulation cluster: one `Engine<SimBackend>` per replica
    /// config, pools sized by each config's memory-profiling pass.
    pub fn new(cfg: &ClusterConfig) -> Self {
        assert!(!cfg.replicas.is_empty(), "cluster needs at least one replica");
        let replicas = cfg
            .replicas
            .iter()
            .map(|c| {
                // placeholder predictor: the incremental path receives
                // each request's prediction at submit time, from the
                // cluster's own trace-wide predictor (so a 1-replica
                // cluster sees exactly run_trace's predictions)
                let p = LengthPredictor::new(2, cfg.predictor_accuracy, 42);
                Replica::new(Engine::new(c.clone(), p))
            })
            .collect();
        Cluster {
            replicas,
            router: make_router(cfg.router, cfg.replicas.len()),
            predictor_accuracy: cfg.predictor_accuracy,
            ran: false,
        }
    }
}

impl<B: ExecutionBackend> Cluster<B> {
    /// Assemble from pre-built engines (any backend) and a router.
    pub fn from_replicas(
        engines: Vec<Engine<B>>,
        router: Box<dyn Router>,
        predictor_accuracy: f64,
    ) -> Self {
        assert!(!engines.is_empty(), "cluster needs at least one replica");
        Cluster {
            replicas: engines.into_iter().map(Replica::new).collect(),
            router,
            predictor_accuracy,
            ran: false,
        }
    }

    pub fn n_replicas(&self) -> usize {
        self.replicas.len()
    }

    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// Toggle decode fast-forwarding (macro-stepping) on every replica.
    /// Off = the pure single-step lockstep, the debugging reference the
    /// property suite and the hotpath bench compare against.
    pub fn set_macro_steps(&mut self, on: bool) {
        for rep in &mut self.replicas {
            rep.engine.set_macro_steps(on);
        }
    }

    /// Every replica recomputes its cached state from scratch each step
    /// and single-steps every decode — the frozen-oracle path the golden
    /// cluster replay pins router + lockstep changes against.
    pub fn use_recompute_oracle(&mut self) {
        for rep in &mut self.replicas {
            rep.engine.use_recompute_oracle();
        }
    }

    /// Serve a whole trace: route every request at its arrival instant,
    /// drain all replicas, and merge the per-replica reports back into
    /// trace order. Single-shot — build a fresh `Cluster` per trace (the
    /// replica engines keep their clocks, stats, and id maps).
    pub fn run(&mut self, trace: &Trace) -> anyhow::Result<ClusterReport> {
        anyhow::ensure!(
            !self.ran,
            "Cluster::run is single-shot — build a fresh Cluster per trace"
        );
        self.ran = true;
        let predictor = standard_predictor(trace, self.predictor_accuracy);
        for tr in &trace.requests {
            // lockstep: every replica catches up to this arrival before
            // the router looks at the views (CLOCK_EPS mirrors try_run's
            // arrival-admission epsilon). The arrival is each engine's
            // decode fast-forward horizon, so a stable replica advances to
            // its next event in ONE macro-step instead of one `step_once`
            // per decode token — the loop runs O(events) turns, not
            // O(tokens).
            for rep in &mut self.replicas {
                while tr.arrival > rep.engine.now() + CLOCK_EPS {
                    if !rep.engine.step_once_until(false, tr.arrival)? {
                        break; // idle: its clock advances at its next submit
                    }
                }
            }
            self.pump_feedback();
            let idx = {
                let views: Vec<ReplicaView> =
                    self.replicas.iter().enumerate().map(|(i, r)| r.view(i)).collect();
                let picked = self.router.route(tr.prompt_len, &views);
                assert!(
                    picked < self.replicas.len(),
                    "router {} returned out-of-range replica {picked} of {}",
                    self.router.name(),
                    self.replicas.len()
                );
                picked
            };
            let rep = &mut self.replicas[idx];
            if tr.arrival > rep.engine.now() + CLOCK_EPS {
                rep.engine.wait_until(tr.arrival);
            }
            rep.submit(tr, predictor.predict(tr.id, tr.output_len));
        }
        // drain: no more input — replicas run independently to empty
        for rep in &mut self.replicas {
            while rep.engine.has_work() {
                if !rep.engine.step_once(true)? {
                    break;
                }
            }
        }
        self.pump_feedback();
        Ok(self.take_report())
    }

    /// Feed newly completed requests' TTFTs to the router.
    fn pump_feedback(&mut self) {
        for (i, rep) in self.replicas.iter_mut().enumerate() {
            // `self.replicas` and `self.router` are disjoint fields, so
            // the record borrow and the router call coexist clone-free
            let records = rep.engine.records();
            for r in &records[rep.records_seen..] {
                self.router.observe_ttft(i, r.ttft());
            }
            rep.records_seen = records.len();
        }
    }

    /// Merge per-replica results, remapping local ids to global trace ids.
    fn take_report(&mut self) -> ClusterReport {
        let mut merged: Vec<RequestRecord> = Vec::new();
        let mut dropped = Vec::new();
        let mut per_replica = Vec::with_capacity(self.replicas.len());
        for rep in &mut self.replicas {
            let report = rep.engine.take_report();
            let stats = rep.engine.stats().clone();
            for r in &report.records {
                let mut g = r.clone();
                g.id = rep.global_ids[r.id];
                merged.push(g);
            }
            for &local in &stats.dropped {
                dropped.push(rep.global_ids[local]);
            }
            per_replica.push(ReplicaOutcome { routed: rep.routed(), report, stats });
        }
        dropped.sort_unstable();
        ClusterReport {
            merged: crate::metrics::Report::new(merged),
            dropped,
            per_replica,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Policy;
    use crate::util::Rng;
    use crate::workload::arrivals::Arrivals;
    use crate::workload::fixed::FixedWorkload;

    fn trace(n: usize, rate: f64) -> Trace {
        FixedWorkload {
            prompt_len: 1024,
            output_len: 64,
            n_requests: n,
            arrivals: Arrivals::Poisson { rate },
        }
        .generate(&mut Rng::new(3))
    }

    #[test]
    fn every_request_accounted_across_replicas() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        for router in RouterPolicy::ALL {
            let t = trace(24, 3.0);
            let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, 3, *router));
            let out = cluster.run(&t).unwrap();
            assert_eq!(out.accounted(), 24, "router {}", router.name());
            assert_eq!(
                out.per_replica.iter().map(|o| o.routed).sum::<usize>(),
                24
            );
            // merged ids are exactly the trace's ids
            let mut ids: Vec<usize> = out.merged.records.iter().map(|r| r.id).collect();
            ids.extend(out.dropped.iter().copied());
            ids.sort_unstable();
            assert_eq!(ids, (0..24).collect::<Vec<_>>());
        }
    }

    #[test]
    fn round_robin_balances_exactly() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(20, 2.0);
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, 4, RouterPolicy::RoundRobin));
        let out = cluster.run(&t).unwrap();
        for o in &out.per_replica {
            assert_eq!(o.routed, 5);
        }
        let s = out.summary(&cfg.slo);
        assert!((s.max_share() - 0.25).abs() < 1e-12);
    }

    #[test]
    fn run_is_single_shot() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(4, 2.0);
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, RouterPolicy::RoundRobin));
        cluster.run(&t).unwrap();
        assert!(cluster.run(&t).is_err(), "second run must be a clear error");
    }

    #[test]
    fn summary_matches_merged_report() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let t = trace(16, 2.0);
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, 2, RouterPolicy::KvPressure));
        let out = cluster.run(&t).unwrap();
        let s = out.summary(&cfg.slo);
        assert_eq!(
            s.per_replica.iter().map(|r| r.completed).sum::<usize>(),
            out.merged.records.len()
        );
        assert!((s.ttft_mean - out.merged.ttft().mean()).abs() < 1e-12);
    }
}
