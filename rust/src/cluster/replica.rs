//! One cluster member: an [`Engine`] plus the local<->global request-id
//! bookkeeping the cluster needs to merge per-replica reports back into
//! trace order.
//!
//! Engines number requests densely in submission order (their `ReqId` is
//! an index into their own request table), so a replica records, for each
//! local id, the global trace id it was routed for. TTFT feedback for the
//! router is read incrementally off the tail of the engine's completed
//! records.

use crate::coordinator::backend::ExecutionBackend;
use crate::coordinator::block::RequestSnapshot;
use crate::coordinator::{Engine, ReqId};
use crate::workload::TraceRequest;

use super::router::ReplicaView;

pub struct Replica<B: ExecutionBackend> {
    pub engine: Engine<B>,
    /// Local engine id -> global trace id, in submission order. Its
    /// length is the number of requests routed here.
    pub global_ids: Vec<usize>,
    /// How many completed records have already been fed to the router.
    pub(crate) records_seen: usize,
}

impl<B: ExecutionBackend> Replica<B> {
    pub fn new(engine: Engine<B>) -> Self {
        Replica { engine, global_ids: Vec::new(), records_seen: 0 }
    }

    /// Requests routed to this replica so far.
    pub fn routed(&self) -> usize {
        self.global_ids.len()
    }

    /// Hand a routed request to the engine, recording the id mapping.
    pub fn submit(&mut self, tr: &TraceRequest, predicted: (usize, usize)) -> ReqId {
        let local = self.engine.submit(tr, predicted);
        debug_assert_eq!(local, self.global_ids.len());
        self.global_ids.push(tr.id);
        local
    }

    /// Adopt a snapshot drained from another replica (its `id` must
    /// already be the global trace id), recording the id mapping exactly
    /// like `submit`. Returns `(engine-local id, tokens resumed from the
    /// checkpoint — 0 when the engine degraded to recompute)`.
    pub fn adopt(&mut self, snap: &RequestSnapshot) -> (ReqId, usize) {
        let (local, resumed) = self.engine.adopt(snap);
        debug_assert_eq!(local, self.global_ids.len());
        self.global_ids.push(snap.id);
        (local, resumed)
    }

    /// The earliest instant this replica's state can change without new
    /// input — the cluster event heap's arming query. `INFINITY` when
    /// idle; the cached decode span's landing instant when stable; `now`
    /// when the engine needs an ordinary scheduling step to find out.
    /// Lazily (re)solves the engine's span cache; commits nothing.
    pub fn horizon(&mut self) -> f64 {
        self.engine.next_event_horizon()
    }

    /// The router's snapshot of this replica.
    pub fn view(&self, idx: usize) -> ReplicaView<'_> {
        ReplicaView {
            idx,
            waiting_len: self.engine.waiting_len(),
            running_len: self.engine.running_len(),
            waiting_tokens: self.engine.waiting_tokens(),
            running_tokens: self.engine.running_tokens(),
            waiting_prefill_s: self.engine.waiting_prefill_s(),
            running_remaining_tokens: self.engine.running_remaining_tokens(),
            slowdown: self.engine.backend.slowdown(),
            kv: &self.engine.kv,
            cost: &self.engine.cost,
            cfg: &self.engine.cfg,
        }
    }
}
