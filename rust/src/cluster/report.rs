//! What a cluster run returns: the merged latency report (records keyed
//! by *global* trace ids, so it is directly comparable to a single-engine
//! `Report` on the same trace) plus each replica's own report, stats, and
//! routed count.

use crate::config::SloTargets;
use crate::coordinator::EngineStats;
use crate::metrics::{ClusterSummary, FaultSummary, ReplicaSummary, Report};

/// Which engine served a completed request, and how many crash-failover
/// re-submissions it survived on the way. Kept beside the merged report —
/// not inside `RequestRecord` — because the record layout is pinned by
/// the frozen pre-refactor oracle the property suites compare against.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestAttribution {
    /// Global trace id (matches `merged.records[i].id`).
    pub id: usize,
    /// Replica index whose engine completed the request.
    pub replica: usize,
    /// Crash drains this request survived before completing (0 on a
    /// fault-free run).
    pub retries: u32,
}

/// One replica's share of a finished cluster run.
#[derive(Debug, Clone)]
pub struct ReplicaOutcome {
    /// Requests the router sent to this replica.
    pub routed: usize,
    /// Its latency report (records keyed by replica-local ids).
    pub report: Report,
    /// Its engine counters (dropped ids are replica-local).
    pub stats: EngineStats,
}

/// A finished cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// All completions across replicas, with ids remapped to global trace
    /// ids and sorted into trace order.
    pub merged: Report,
    /// Global trace ids of rejected requests, sorted.
    pub dropped: Vec<usize>,
    /// Global trace ids of requests that exhausted their crash-failover
    /// retry budget (or never found a live replica), sorted. Always empty
    /// on a fault-free run.
    pub failed: Vec<usize>,
    /// Fault rollup, present iff the run carried a `FaultPlan`.
    pub faults: Option<FaultSummary>,
    pub per_replica: Vec<ReplicaOutcome>,
    /// Per-completion serving attribution (replica + failover retries),
    /// sorted by global id — one entry per record in `merged`.
    pub attribution: Vec<RequestAttribution>,
}

impl ClusterReport {
    /// Conservation check: completions + drops + retry-exhaustions must
    /// account for every trace request exactly once.
    pub fn accounted(&self) -> usize {
        self.merged.records.len() + self.dropped.len() + self.failed.len()
    }

    /// Roll up into the metrics-layer summary.
    pub fn summary(&self, slo: &SloTargets) -> ClusterSummary {
        let per = self
            .per_replica
            .iter()
            .enumerate()
            .map(|(i, o)| {
                ReplicaSummary::from_report(i, o.routed, o.stats.dropped.len(), &o.report, slo)
            })
            .collect();
        ClusterSummary::new(&self.merged, slo, per)
    }
}
