//! Replica-selection policies: which engine a newly arrived request goes
//! to. The router sees one read-only [`ReplicaView`] per replica —
//! queue/running aggregates plus the replica's live `KvManager` pool
//! counters (O(1) cached aggregates) and its `CostModel` — and returns a
//! replica index. All four policies are deterministic (ties break toward
//! the lowest index) and allocation-free on the decision path
//! (`cluster/route_decision_*` in the hotpath bench guards this).
//!
//! * [`RoundRobin`](RouterPolicy::RoundRobin) — cycle the replicas,
//!   ignoring all state. The baseline the cluster experiment measures
//!   against: under skewed load it recreates exactly the head-of-line
//!   blocking LayerKV removed inside one engine, one level up.
//! * [`JoinShortestQueue`](RouterPolicy::JoinShortestQueue) — classic
//!   JSQ over `waiting + running` request counts.
//! * [`KvPressure`](RouterPolicy::KvPressure) — score replicas by free
//!   blocks per tier (GPU full weight, host/disk discounted by their
//!   restore cost) minus the queued + running token demand, all read
//!   from the `KvManager`'s cached pool aggregates. Routes to the
//!   highest score: the replica whose KV hierarchy has the most headroom
//!   for this request's blocks.
//! * [`SloAware`](RouterPolicy::SloAware) — predict each replica's
//!   queueing delay (queued prefill backlog + a KV-admission stall term
//!   derived from the §3.1.1 x-solve) and smooth it with an EWMA of the
//!   TTFTs the replica actually delivered (the latency-probe idiom:
//!   `ewma = alpha * sample + (1 - alpha) * ewma`). Routes to the lowest
//!   predicted delay.
//! * [`PrefixAware`](RouterPolicy::PrefixAware) — the KvPressure score
//!   plus a cache-affinity bonus: probe each replica's prefix cache for
//!   the request's prefix hash and credit the fraction of the prompt it
//!   would serve, discounted by the tier the cached blocks sit on (a GPU
//!   hit is worth the full prefill savings, a disk hit much less).
//!   Requests with no prefix key score identically to KvPressure.

use crate::config::ServingConfig;
use crate::coordinator::block::{BlockPool, KvManager, Residency};
use crate::sim::CostModel;

/// EWMA smoothing for observed TTFT feedback: weight on the newest
/// sample (the latency-probe idiom). Public so the serve front-end's
/// ledger smooths TTFTs identically to the simulated SloAware policy.
pub const EWMA_ALPHA: f64 = 0.7;

/// One EWMA step: seed on the first sample, smooth thereafter. Shared by
/// the SloAware router and the serve front-end's ledger so the two
/// smoothing paths can never diverge.
pub fn ewma_update(prev: Option<f64>, sample: f64) -> f64 {
    match prev {
        Some(e) => EWMA_ALPHA * sample + (1.0 - EWMA_ALPHA) * e,
        None => sample,
    }
}

/// Which replica-selection policy a cluster runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    JoinShortestQueue,
    KvPressure,
    SloAware,
    PrefixAware,
}

impl RouterPolicy {
    /// Every policy, in reporting order.
    pub const ALL: &'static [RouterPolicy] = &[
        RouterPolicy::RoundRobin,
        RouterPolicy::JoinShortestQueue,
        RouterPolicy::KvPressure,
        RouterPolicy::SloAware,
        RouterPolicy::PrefixAware,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::JoinShortestQueue => "jsq",
            RouterPolicy::KvPressure => "kv-pressure",
            RouterPolicy::SloAware => "slo-aware",
            RouterPolicy::PrefixAware => "prefix-aware",
        }
    }

    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s {
            "round-robin" | "rr" => Some(RouterPolicy::RoundRobin),
            "jsq" | "shortest-queue" => Some(RouterPolicy::JoinShortestQueue),
            "kv-pressure" | "kv" => Some(RouterPolicy::KvPressure),
            "slo-aware" | "slo" => Some(RouterPolicy::SloAware),
            "prefix-aware" | "prefix" => Some(RouterPolicy::PrefixAware),
            _ => None,
        }
    }
}

/// What the router knows about an arriving request. `prompt_len` is what
/// the legacy `route` path sees; the prefix fields let cache-affine
/// policies probe replica caches. A zero `prefix_hash` means "no shared
/// prefix" and makes every policy behave exactly as if it only saw the
/// length.
#[derive(Debug, Clone, Copy, Default)]
pub struct RouteQuery {
    pub prompt_len: usize,
    /// Content hash of the request's reusable prefix (0 = none).
    pub prefix_hash: u64,
    /// Token length of that prefix.
    pub prefix_len: usize,
}

/// Read-only snapshot of one replica at routing time. The pool counters
/// behind `kv` are the `BlockPool`s' O(1) cached aggregates; the
/// queue/running sums are O(queue) scans taken once per routing decision
/// (per *arrival*, not per engine step — cheap at that cadence).
/// `kv`/`cost`/`cfg` borrow the replica's live state directly (no
/// copies; `Clone` just re-borrows, so the health wrapper can filter a
/// candidate subset without touching the replicas).
///
/// **Freshness contract:** views are only ever read at routing instants,
/// and the cluster drive — lockstep *and* event-heap — advances every
/// live replica to that instant first, so a view always reflects the
/// state a front-end would observe at that moment. The event heap
/// preserves this without scheduler work: stable replicas catch up by
/// committing pre-solved span chunks, idle ones are already exact.
#[derive(Clone)]
pub struct ReplicaView<'a> {
    pub idx: usize,
    pub waiting_len: usize,
    pub running_len: usize,
    /// Σ prefill tokens over the queue.
    pub waiting_tokens: usize,
    /// Σ context tokens over the running set.
    pub running_tokens: usize,
    /// Σ modeled prefill seconds over the queue.
    pub waiting_prefill_s: f64,
    /// Σ predicted-median remaining output tokens over the running set.
    pub running_remaining_tokens: usize,
    /// Service-rate degradation factor from the replica's backend: 1.0 is
    /// nominal, 3.0 means every step takes 3x as long (a straggler).
    /// State-aware policies stretch their delay/headroom estimates by it.
    pub slowdown: f64,
    pub kv: &'a KvManager,
    pub cost: &'a CostModel,
    pub cfg: &'a ServingConfig,
}

/// A replica-selection policy instance (may carry state: the round-robin
/// cursor, the EWMA table).
pub trait Router {
    fn name(&self) -> &'static str;

    /// Pick a replica for a request of `prompt_len` tokens. `views` holds
    /// one entry per replica, in replica order; implementations must
    /// return one of the given `idx` values.
    fn route(&mut self, prompt_len: usize, views: &[ReplicaView]) -> usize;

    /// Pick a replica for a full [`RouteQuery`]. Length-only policies
    /// inherit this delegation; cache-affine ones override it. The
    /// cluster always routes through this entry point, so the default
    /// keeps every legacy policy's decisions bit-identical.
    fn route_query(&mut self, q: &RouteQuery, views: &[ReplicaView]) -> usize {
        self.route(q.prompt_len, views)
    }

    /// Feedback: a request routed to `replica` completed with this TTFT.
    /// Only feedback-driven policies keep it.
    fn observe_ttft(&mut self, replica: usize, ttft_s: f64) {
        let _ = (replica, ttft_s);
    }
}

/// Construct the router for a policy.
pub fn make_router(policy: RouterPolicy, n_replicas: usize) -> Box<dyn Router> {
    match policy {
        RouterPolicy::RoundRobin => Box::new(RoundRobinRouter { next: 0 }),
        RouterPolicy::JoinShortestQueue => Box::new(JsqRouter),
        RouterPolicy::KvPressure => Box::new(KvPressureRouter),
        RouterPolicy::SloAware => {
            Box::new(SloAwareRouter { ewma_ttft_s: vec![None; n_replicas] })
        }
        RouterPolicy::PrefixAware => Box::new(PrefixAwareRouter),
    }
}

/// Cycle replicas in order, state-blind.
#[derive(Debug)]
pub struct RoundRobinRouter {
    next: usize,
}

impl Router for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _prompt_len: usize, views: &[ReplicaView]) -> usize {
        let i = self.next % views.len();
        self.next = self.next.wrapping_add(1);
        views[i].idx
    }
}

/// Join the shortest queue (waiting + running request count).
#[derive(Debug)]
pub struct JsqRouter;

impl Router for JsqRouter {
    fn name(&self) -> &'static str {
        "jsq"
    }

    fn route(&mut self, _prompt_len: usize, views: &[ReplicaView]) -> usize {
        let mut best = views[0].idx;
        let mut best_depth = usize::MAX;
        for v in views {
            let depth = v.waiting_len + v.running_len;
            if depth < best_depth {
                best_depth = depth;
                best = v.idx;
            }
        }
        best
    }
}

/// Free-blocks-per-tier minus queued token demand. Deeper tiers count for
/// less headroom (their restores cost more), in rough proportion to the
/// PCIe-vs-NVMe link gap.
#[derive(Debug)]
pub struct KvPressureRouter;

/// The KvPressure score (higher = more headroom). Public so the hotpath
/// bench and tests can pin its behaviour directly.
pub fn kv_pressure_score(v: &ReplicaView) -> f64 {
    let frac = |p: &BlockPool| {
        if p.total() == 0 {
            0.0
        } else {
            p.available() as f64 / p.total() as f64
        }
    };
    let free = frac(&v.kv.gpu) + 0.25 * frac(&v.kv.cpu) + 0.10 * frac(&v.kv.disk);
    // all queued + running tokens, charged at full-KV block demand — the
    // upper bound on what this replica's pools still owe
    let demand_blocks = (v.waiting_tokens + v.running_tokens).div_ceil(v.cfg.block_size)
        * v.cfg.model.n_layers;
    let demand = demand_blocks as f64 / v.kv.gpu.total().max(1) as f64;
    // a straggler frees blocks slower and sits on its queued demand
    // longer: its headroom is worth less and its debt weighs more. Gated
    // so the nominal path stays bit-identical to the slowdown-free score.
    if v.slowdown != 1.0 {
        return free / v.slowdown - demand * v.slowdown;
    }
    free - demand
}

impl Router for KvPressureRouter {
    fn name(&self) -> &'static str {
        "kv-pressure"
    }

    fn route(&mut self, _prompt_len: usize, views: &[ReplicaView]) -> usize {
        let mut best = views[0].idx;
        let mut best_score = f64::NEG_INFINITY;
        for v in views {
            let score = kv_pressure_score(v);
            if score > best_score {
                best_score = score;
                best = v.idx;
            }
        }
        best
    }
}

/// Weight of the cache-affinity term against the KvPressure headroom
/// score. A full-prompt GPU hit is worth half a "whole pool of free GPU
/// blocks" — strong enough to pull session turns back to their cache,
/// weak enough that a saturated replica still sheds load.
pub const PREFIX_AFFINITY_WEIGHT: f64 = 0.5;

/// How much of a hit's prefill savings survives each tier: GPU blocks
/// reuse at full value, host blocks pay an onload, disk blocks a far
/// slower restore (mirrors the tier discounts in `kv_pressure_score`'s
/// headroom weighting, scaled to the restore-vs-recompute gap).
fn prefix_tier_discount(tier: Residency) -> f64 {
    match tier {
        Residency::Gpu => 1.0,
        Residency::Cpu => 0.6,
        Residency::Disk => 0.25,
    }
}

/// KvPressure plus cache affinity: score each replica's headroom, then
/// credit the block-aligned fraction of this prompt its prefix cache
/// would serve, tier-discounted. Highest score wins; ties break low.
#[derive(Debug)]
pub struct PrefixAwareRouter;

/// The PrefixAware score (public so tests can pin the affinity math).
pub fn prefix_affinity_score(q: &RouteQuery, v: &ReplicaView) -> f64 {
    let mut score = kv_pressure_score(v);
    if q.prefix_hash != 0 && q.prompt_len > 0 {
        if let Some((tokens, tier)) = v.kv.prefix_probe(q.prefix_hash) {
            let usable = tokens.min(q.prefix_len).min(q.prompt_len);
            let frac = usable as f64 / q.prompt_len as f64;
            score += PREFIX_AFFINITY_WEIGHT * frac * prefix_tier_discount(tier);
        }
    }
    score
}

impl Router for PrefixAwareRouter {
    fn name(&self) -> &'static str {
        "prefix-aware"
    }

    /// Length-only entry point: no prefix identity to probe, so this is
    /// exactly the KvPressure decision.
    fn route(&mut self, _prompt_len: usize, views: &[ReplicaView]) -> usize {
        let mut best = views[0].idx;
        let mut best_score = f64::NEG_INFINITY;
        for v in views {
            let score = kv_pressure_score(v);
            if score > best_score {
                best_score = score;
                best = v.idx;
            }
        }
        best
    }

    fn route_query(&mut self, q: &RouteQuery, views: &[ReplicaView]) -> usize {
        let mut best = views[0].idx;
        let mut best_score = f64::NEG_INFINITY;
        for v in views {
            let score = prefix_affinity_score(q, v);
            if score > best_score {
                best_score = score;
                best = v.idx;
            }
        }
        best
    }
}

/// Predicted queueing delay + EWMA-smoothed observed TTFT, lowest wins.
#[derive(Debug)]
pub struct SloAwareRouter {
    /// Per-replica EWMA of delivered TTFTs (None until first feedback).
    ewma_ttft_s: Vec<Option<f64>>,
}

impl SloAwareRouter {
    /// Model-predicted queueing delay for a `prompt_len` request landing
    /// on this replica now: the queued prefill backlog, plus — when the
    /// §3.1.1 x-solve says more GPU blocks must stay resident than are
    /// free — the fraction of the outstanding decode work that has to
    /// finish before those blocks exist.
    pub fn predicted_delay(&self, prompt_len: usize, v: &ReplicaView) -> f64 {
        let mut delay = v.waiting_prefill_s;
        // every second of modeled service on a straggler takes
        // `slowdown` wall seconds (gated: nominal path is bit-identical)
        if v.slowdown != 1.0 {
            delay *= v.slowdown;
        }
        let x = v.cost.min_resident_layers(prompt_len);
        let need = prompt_len.div_ceil(v.cfg.block_size) * x;
        let free = v.kv.gpu.available();
        if need > free {
            let used = v.kv.gpu.total().saturating_sub(free);
            let deficit_frac = ((need - free) as f64 / used.max(1) as f64).min(1.0);
            let lanes = v.running_len.max(1);
            let iters = (v.running_remaining_tokens as f64 / lanes as f64).ceil();
            let iter_s = v.cost.decode_step_time_sum(v.running_tokens, lanes);
            let mut stall = deficit_frac * iters * iter_s;
            if v.slowdown != 1.0 {
                stall *= v.slowdown;
            }
            delay += stall;
        }
        delay + self.ewma_ttft_s.get(v.idx).copied().flatten().unwrap_or(0.0)
    }
}

impl Router for SloAwareRouter {
    fn name(&self) -> &'static str {
        "slo-aware"
    }

    fn route(&mut self, prompt_len: usize, views: &[ReplicaView]) -> usize {
        let mut best = views[0].idx;
        let mut best_delay = f64::INFINITY;
        for v in views {
            let d = self.predicted_delay(prompt_len, v);
            if d < best_delay {
                best_delay = d;
                best = v.idx;
            }
        }
        best
    }

    fn observe_ttft(&mut self, replica: usize, ttft_s: f64) {
        if replica >= self.ewma_ttft_s.len() {
            self.ewma_ttft_s.resize(replica + 1, None);
        }
        self.ewma_ttft_s[replica] = Some(ewma_update(self.ewma_ttft_s[replica], ttft_s));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;

    struct Fixture {
        cfg: ServingConfig,
        cost: CostModel,
        kvs: Vec<KvManager>,
    }

    impl Fixture {
        /// `fills[i]` requests of 2048 tokens, 8 resident layers each,
        /// pre-allocated on replica i's pools.
        fn new(fills: &[usize]) -> Self {
            let cfg = ServingConfig::llama2_7b_tp1();
            let cost = CostModel::new(cfg.clone());
            let kvs = fills
                .iter()
                .map(|&fill| {
                    let mut m =
                        KvManager::new(100_000, 500_000, cfg.block_size, cfg.model.n_layers);
                    for r in 0..fill {
                        m.allocate_layerwise(r, 2048, 8).unwrap();
                    }
                    m
                })
                .collect();
            Fixture { cfg, cost, kvs }
        }

        /// Views with queue depth `queues[i]` requests of 1k tokens each.
        fn views(&self, queues: &[usize]) -> Vec<ReplicaView<'_>> {
            self.kvs
                .iter()
                .enumerate()
                .map(|(i, kv)| ReplicaView {
                    idx: i,
                    waiting_len: queues[i],
                    running_len: 0,
                    waiting_tokens: queues[i] * 1024,
                    running_tokens: 0,
                    waiting_prefill_s: queues[i] as f64
                        * self.cost.prefill_time(1024),
                    running_remaining_tokens: 0,
                    slowdown: 1.0,
                    kv,
                    cost: &self.cost,
                    cfg: &self.cfg,
                })
                .collect()
        }
    }

    #[test]
    fn round_robin_cycles() {
        let f = Fixture::new(&[0, 0, 0]);
        let views = f.views(&[0, 0, 0]);
        let mut r = make_router(RouterPolicy::RoundRobin, 3);
        let picks: Vec<usize> = (0..6).map(|_| r.route(512, &views)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn jsq_picks_shortest_and_breaks_ties_low() {
        let f = Fixture::new(&[0, 0, 0]);
        let views = f.views(&[4, 1, 1]);
        let mut r = make_router(RouterPolicy::JoinShortestQueue, 3);
        assert_eq!(r.route(512, &views), 1); // tie between 1 and 2 -> 1
        let views = f.views(&[0, 0, 0]);
        assert_eq!(r.route(512, &views), 0);
    }

    #[test]
    fn kv_pressure_prefers_free_pools_over_queued_demand() {
        // replica 0: heavily allocated pools; replica 1: empty
        let f = Fixture::new(&[64, 0]);
        let views = f.views(&[0, 0]);
        let mut r = make_router(RouterPolicy::KvPressure, 2);
        assert_eq!(r.route(2048, &views), 1);
        assert!(kv_pressure_score(&views[1]) > kv_pressure_score(&views[0]));
        // equal pools but replica 1 has queued token demand -> pick 0
        let g = Fixture::new(&[0, 0]);
        let views = g.views(&[0, 8]);
        assert_eq!(r.route(2048, &views), 0);
    }

    #[test]
    fn slo_aware_avoids_prefill_backlog_and_bad_ttft_history() {
        let f = Fixture::new(&[0, 0]);
        // replica 0 has a deep prefill backlog -> route to 1
        let views = f.views(&[10, 0]);
        let mut r = make_router(RouterPolicy::SloAware, 2);
        assert_eq!(r.route(2048, &views), 1);
        // equal backlogs, but replica 1 has been delivering terrible TTFT
        let views = f.views(&[1, 1]);
        r.observe_ttft(0, 0.1);
        r.observe_ttft(1, 30.0);
        assert_eq!(r.route(2048, &views), 0);
    }

    #[test]
    fn slo_aware_ewma_converges_toward_new_samples() {
        let mut r = SloAwareRouter { ewma_ttft_s: vec![None; 1] };
        r.observe_ttft(0, 1.0);
        assert_eq!(r.ewma_ttft_s[0], Some(1.0));
        r.observe_ttft(0, 2.0);
        // alpha = 0.7: 0.7*2 + 0.3*1
        assert!((r.ewma_ttft_s[0].unwrap() - 1.7).abs() < 1e-12);
    }

    #[test]
    fn stragglers_repel_state_aware_policies() {
        let f = Fixture::new(&[0, 0]);
        let mut views = f.views(&[2, 2]);
        views[0].slowdown = 4.0; // replica 0 is dragging
        let mut kv = make_router(RouterPolicy::KvPressure, 2);
        assert_eq!(kv.route(2048, &views), 1);
        assert!(kv_pressure_score(&views[1]) > kv_pressure_score(&views[0]));
        let mut slo = make_router(RouterPolicy::SloAware, 2);
        assert_eq!(slo.route(2048, &views), 1);
        // the gate leaves nominal views bit-identical: ties break to 0
        let nominal = f.views(&[2, 2]);
        assert_eq!(kv.route(2048, &nominal), 0);
        assert_eq!(slo.route(2048, &nominal), 0);
    }

    #[test]
    fn policy_names_roundtrip() {
        for p in RouterPolicy::ALL {
            assert_eq!(RouterPolicy::parse(p.name()), Some(*p));
            assert_eq!(make_router(*p, 4).name(), p.name());
        }
        assert_eq!(RouterPolicy::parse("prefix"), Some(RouterPolicy::PrefixAware));
        assert_eq!(RouterPolicy::parse("nope"), None);
    }

    #[test]
    fn prefix_aware_pulls_hits_to_their_cache() {
        let mut f = Fixture::new(&[0, 0]);
        // replica 1 holds a cached 2048-token prefix under hash 7
        f.kvs[1].prefix_publish(7, 2048);
        assert!(f.kvs[1].prefix_probe(7).is_some());
        let views = f.views(&[0, 0]);
        let mut r = make_router(RouterPolicy::PrefixAware, 2);
        let q = RouteQuery { prompt_len: 2048, prefix_hash: 7, prefix_len: 2048 };
        assert_eq!(r.route_query(&q, &views), 1);
        // no prefix identity -> pure KvPressure, ties break low
        let plain = RouteQuery { prompt_len: 2048, prefix_hash: 0, prefix_len: 0 };
        assert_eq!(r.route_query(&plain, &views), 0);
        assert_eq!(r.route(2048, &views), 0);
    }

    #[test]
    fn prefix_affinity_discounts_deeper_tiers() {
        let mut f = Fixture::new(&[0, 0]);
        f.kvs[0].prefix_publish(7, 2048);
        f.kvs[1].prefix_publish(7, 2048);
        // demote replica 1's copy off the GPU: its hit is worth less
        let mut moves = Vec::new();
        f.kvs[1].prefix_demote_gpu(usize::MAX, &mut moves);
        assert!(!moves.is_empty());
        let views = f.views(&[0, 0]);
        let q = RouteQuery { prompt_len: 2048, prefix_hash: 7, prefix_len: 2048 };
        assert!(prefix_affinity_score(&q, &views[0]) > prefix_affinity_score(&q, &views[1]));
        // and both beat a replica with no cached copy at all
        let g = Fixture::new(&[0]);
        let empty = g.views(&[0]);
        assert!(prefix_affinity_score(&q, &views[1]) > prefix_affinity_score(&q, &empty[0]));
    }

    #[test]
    fn prefix_affinity_does_not_override_heavy_pressure() {
        // replica 0 has the cache hit but a nearly exhausted GPU pool and
        // deep queued demand; affinity must not pin the request there
        let mut f = Fixture::new(&[90, 0]);
        f.kvs[0].prefix_publish(7, 2048);
        let views = f.views(&[64, 0]);
        let mut r = make_router(RouterPolicy::PrefixAware, 2);
        let q = RouteQuery { prompt_len: 2048, prefix_hash: 7, prefix_len: 2048 };
        assert_eq!(r.route_query(&q, &views), 1);
    }
}
