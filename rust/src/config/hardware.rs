//! Hardware description: GPU, interconnect, host. Numbers default to the
//! paper's testbed (NVIDIA L20 48 GB, PCIe 4.0 x16 shared per GPU pair,
//! 2 TB host RAM) so the simulator's cost models (sim/costmodel.rs)
//! reproduce the paper's latency regime.

/// One accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Device memory in bytes.
    pub memory_bytes: u64,
    /// Peak dense fp16 throughput in FLOP/s.
    pub peak_flops: f64,
    /// HBM/GDDR bandwidth in bytes/s (decode is memory-bound).
    pub mem_bw: f64,
}

impl GpuSpec {
    /// NVIDIA L20: 48 GB GDDR6, 119.5 TFLOPs fp16 tensor, 864 GB/s.
    pub fn l20() -> Self {
        GpuSpec {
            name: "L20",
            memory_bytes: 48 * (1 << 30),
            peak_flops: 119.5e12,
            mem_bw: 864.0e9,
        }
    }

    /// NVIDIA A100-80G, for cross-checking against common baselines.
    pub fn a100_80g() -> Self {
        GpuSpec {
            name: "A100-80G",
            memory_bytes: 80 * (1 << 30),
            peak_flops: 312.0e12,
            mem_bw: 2039.0e9,
        }
    }
}

/// Host-device interconnect.
#[derive(Debug, Clone, PartialEq)]
pub struct PcieSpec {
    /// Unidirectional bandwidth in bytes/s for one x16 link.
    pub bandwidth: f64,
    /// Per-transfer fixed latency (launch + DMA setup), seconds.
    pub latency: f64,
    /// GPUs sharing one link (the paper's testbed: each two GPUs share one
    /// PCIe connection).
    pub gpus_per_link: usize,
}

impl PcieSpec {
    /// PCIe 4.0 x16: ~32 GB/s raw, ~26 GB/s achievable.
    pub fn gen4_x16() -> Self {
        PcieSpec { bandwidth: 26.0e9, latency: 10e-6, gpus_per_link: 2 }
    }
}

/// The disk tier backing the GPU -> host -> disk hierarchy: a slow,
/// high-capacity "link + pool" below host RAM. Modeled exactly like the
/// PCIe link (bandwidth + fixed latency), just with storage numbers.
/// `capacity_bytes = 0` disables the tier — the two-tier configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct DiskSpec {
    /// Sustained sequential bandwidth in bytes/s (reads ~ writes for the
    /// NVMe class this models).
    pub bandwidth: f64,
    /// Per-transfer fixed latency (submission + seek/flash overhead), s.
    pub latency: f64,
    /// Bytes of spill space available to KV (0 = tier disabled).
    pub capacity_bytes: u64,
}

impl DiskSpec {
    /// No disk tier (the default on every preset: seed semantics).
    pub fn none() -> Self {
        DiskSpec { bandwidth: 0.0, latency: 0.0, capacity_bytes: 0 }
    }

    /// A datacenter NVMe drive (~6 GB/s sustained, ~80 us per op) with
    /// the given spill capacity.
    pub fn nvme(capacity_bytes: u64) -> Self {
        DiskSpec { bandwidth: 6.0e9, latency: 80e-6, capacity_bytes }
    }

    /// The 4 TB instance the tiered presets use.
    pub fn nvme_4tb() -> Self {
        Self::nvme(4096 * (1u64 << 30))
    }

    pub fn enabled(&self) -> bool {
        self.capacity_bytes > 0
    }
}

/// Inter-GPU fabric for tensor parallelism.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fabric {
    /// NVLink: all-reduce does not touch PCIe (no contention with LayerKV
    /// swaps — §3.1.3).
    NvLink,
    /// All-reduce shares PCIe with KV offload traffic (contention path).
    Pcie,
}

/// A serving node.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeSpec {
    pub gpu: GpuSpec,
    pub n_gpus: usize,
    pub pcie: PcieSpec,
    pub fabric: Fabric,
    /// Host DRAM available for offloaded KV (bytes).
    pub host_memory_bytes: u64,
    /// NVLink bandwidth if fabric == NvLink (bytes/s per direction).
    pub nvlink_bw: f64,
    /// The disk tier below host RAM (capacity 0 = two-tier node).
    pub disk: DiskSpec,
}

impl NodeSpec {
    /// The paper's testbed: 8x L20, PCIe-only fabric (L20 has no NVLink),
    /// 2048 GB host memory.
    pub fn l20_node() -> Self {
        NodeSpec {
            gpu: GpuSpec::l20(),
            n_gpus: 8,
            pcie: PcieSpec::gen4_x16(),
            fabric: Fabric::Pcie,
            host_memory_bytes: 2048 * (1u64 << 30),
            nvlink_bw: 0.0,
            disk: DiskSpec::none(),
        }
    }

    /// NVLink variant (for the §3.1.3 contention ablation).
    pub fn l20_node_nvlink() -> Self {
        NodeSpec { fabric: Fabric::NvLink, nvlink_bw: 300.0e9, ..Self::l20_node() }
    }

    /// The testbed with an NVMe spill tier below host RAM (the tier-sweep
    /// experiments' three-tier configuration).
    pub fn l20_node_nvme() -> Self {
        NodeSpec { disk: DiskSpec::nvme_4tb(), ..Self::l20_node() }
    }

    /// The PJRT-CPU testbed the real tiny-model path runs on: the
    /// "device" pool is host RAM and pool-to-pool transfers are memcpys.
    /// Orders of magnitude rather than datasheet numbers — on this path
    /// the cost model only steers the scheduler's heuristics (the §3.1.1
    /// x-solve, TPOT slack, Eq. 5 forecasts), never the measured
    /// latencies, which come from the wall clock. A slow "link" relative
    /// to "compute" keeps the x-solve in the long-prompt regime (x -> 0,
    /// admit layer-wise), which is the behaviour a host-offload serving
    /// path wants.
    pub fn cpu_pjrt_testbed() -> Self {
        NodeSpec {
            gpu: GpuSpec {
                name: "cpu-pjrt",
                memory_bytes: 8 * (1 << 30),
                peak_flops: 5.0e10,
                mem_bw: 2.0e10,
            },
            n_gpus: 1,
            pcie: PcieSpec { bandwidth: 1.0e10, latency: 1.0e-6, gpus_per_link: 1 },
            fabric: Fabric::Pcie,
            host_memory_bytes: 16 * (1u64 << 30),
            nvlink_bw: 0.0,
            disk: DiskSpec::none(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn l20_datasheet() {
        let g = GpuSpec::l20();
        assert_eq!(g.memory_bytes, 51_539_607_552);
        assert!(g.peak_flops > 1e14);
    }

    #[test]
    fn testbed_matches_paper() {
        let n = NodeSpec::l20_node();
        assert_eq!(n.n_gpus, 8);
        assert_eq!(n.fabric, Fabric::Pcie);
        assert_eq!(n.pcie.gpus_per_link, 2);
        assert_eq!(n.host_memory_bytes, 2048 * (1u64 << 30));
        // the paper's testbed has no disk tier: two-tier semantics
        assert!(!n.disk.enabled());
    }

    #[test]
    fn nvme_tier_is_slower_and_bigger_than_host_link() {
        let n = NodeSpec::l20_node_nvme();
        assert!(n.disk.enabled());
        assert!(n.disk.bandwidth < n.pcie.bandwidth);
        assert!(n.disk.latency > n.pcie.latency);
        assert!(n.disk.capacity_bytes > n.host_memory_bytes);
    }
}
