//! Configuration system: model zoo, hardware descriptions, serving knobs.

pub mod hardware;
pub mod model;
pub mod serving;

pub use hardware::{DiskSpec, Fabric, GpuSpec, NodeSpec, PcieSpec};
pub use model::ModelSpec;
pub use serving::{OffloadQuant, Policy, ServingConfig, SloTargets};
