//! Model zoo: the shapes of the models the paper evaluates plus the tiny
//! model actually served end-to-end through PJRT.
//!
//! Latency/memory experiments need shapes and parameter counts, not
//! weights (DESIGN.md §2): every cost in the simulator derives from these
//! numbers through Eqs. 3–4 of the paper.

/// Architectural description of a decoder-only transformer.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: &'static str,
    pub n_layers: usize,
    pub n_heads: usize,
    /// GQA: number of KV heads (== n_heads for vanilla MHA).
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub hidden: usize,
    pub ffn_hidden: usize,
    pub vocab: usize,
    /// Total parameter count (reported, not derived, to match the paper's
    /// n_param in Eq. 3).
    pub n_params: u64,
    /// Bytes per weight/KV element as served (fp16 on the paper's testbed).
    pub dtype_bytes: usize,
    /// Maximum context window the serving config may allow.
    pub max_context: usize,
}

impl ModelSpec {
    /// KV cache bytes for ONE token across ALL layers.
    pub fn kv_bytes_per_token(&self) -> u64 {
        (2 * self.n_layers * self.n_kv_heads * self.head_dim * self.dtype_bytes) as u64
    }

    /// KV cache bytes for one token of ONE layer.
    pub fn kv_bytes_per_token_layer(&self) -> u64 {
        (2 * self.n_kv_heads * self.head_dim * self.dtype_bytes) as u64
    }

    /// Weight bytes (total across the whole model, before TP sharding).
    pub fn weight_bytes(&self) -> u64 {
        self.n_params * self.dtype_bytes as u64
    }

    /// Llama-2-7B: 32 layers, MHA, 4k native context (paper runs it to 16k
    /// prompts on 1 GPU).
    pub fn llama2_7b() -> Self {
        ModelSpec {
            name: "llama2-7b",
            n_layers: 32,
            n_heads: 32,
            n_kv_heads: 32,
            head_dim: 128,
            hidden: 4096,
            ffn_hidden: 11008,
            vocab: 32000,
            n_params: 6_738_000_000,
            dtype_bytes: 2,
            max_context: 16384,
        }
    }

    /// Yi-34B-200K: 60 layers, GQA 8 kv heads, long-context flagship.
    pub fn yi_34b_200k() -> Self {
        ModelSpec {
            name: "yi-34b-200k",
            n_layers: 60,
            n_heads: 56,
            n_kv_heads: 8,
            head_dim: 128,
            hidden: 7168,
            ffn_hidden: 20480,
            vocab: 64000,
            n_params: 34_400_000_000,
            dtype_bytes: 2,
            max_context: 200_000,
        }
    }

    /// Llama-3.1-70B: 80 layers, GQA 8 kv heads.
    pub fn llama31_70b() -> Self {
        ModelSpec {
            name: "llama3.1-70b",
            n_layers: 80,
            n_heads: 64,
            n_kv_heads: 8,
            head_dim: 128,
            hidden: 8192,
            ffn_hidden: 28672,
            vocab: 128_256,
            n_params: 70_600_000_000,
            dtype_bytes: 2,
            max_context: 131_072,
        }
    }

    /// The tiny model actually compiled by `make artifacts` and served via
    /// PJRT (matches python/compile/model.py ModelConfig defaults).
    pub fn tiny() -> Self {
        ModelSpec {
            name: "tiny",
            n_layers: 4,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 32,
            hidden: 128,
            ffn_hidden: 256,
            vocab: 256,
            n_params: 656_384, // filled from manifest at load; this is the default-seed count
            dtype_bytes: 4,    // f32 on the CPU PJRT path
            max_context: 256,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "llama2-7b" => Some(Self::llama2_7b()),
            "yi-34b-200k" => Some(Self::yi_34b_200k()),
            "llama3.1-70b" => Some(Self::llama31_70b()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_bytes_match_hand_calc() {
        let m = ModelSpec::llama2_7b();
        // 2 (K+V) * 32 layers * 32 heads * 128 dim * 2 bytes = 524288 B/token
        assert_eq!(m.kv_bytes_per_token(), 524_288);
        assert_eq!(m.kv_bytes_per_token_layer(), 16_384);
    }

    #[test]
    fn gqa_models_have_smaller_kv() {
        let mha = ModelSpec::llama2_7b();
        let gqa = ModelSpec::yi_34b_200k();
        // Yi-34B has ~5x the params but GQA keeps per-token-per-layer KV smaller
        assert!(gqa.kv_bytes_per_token_layer() < mha.kv_bytes_per_token_layer());
    }

    #[test]
    fn zoo_lookup() {
        for name in ["llama2-7b", "yi-34b-200k", "llama3.1-70b", "tiny"] {
            assert_eq!(ModelSpec::by_name(name).unwrap().name, name);
        }
        assert!(ModelSpec::by_name("gpt-5").is_none());
    }

    #[test]
    fn weight_bytes_fp16() {
        assert_eq!(ModelSpec::llama2_7b().weight_bytes(), 13_476_000_000);
    }
}
