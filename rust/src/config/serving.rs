//! Serving configuration: the knobs vLLM exposes (block size, memory
//! utilisation, batching caps) plus LayerKV's additions (policy, SLO
//! targets, offload thresholds).

use super::hardware::NodeSpec;
use super::model::ModelSpec;

/// Which scheduler/KV-management policy the engine runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Baseline: request-wise KV block admission, prefill-priority
    /// continuous batching, recompute preemption (vLLM 0.5.x semantics).
    Vllm,
    /// LayerKV: layer-wise allocation + offloading. `slo_aware = false` is
    /// the Fig. 8 ablation (admit prefills whenever layer-blocks allow,
    /// ignoring decoding requests' TPOT slack).
    LayerKv { slo_aware: bool },
}

impl Policy {
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Vllm => "vllm",
            Policy::LayerKv { slo_aware: true } => "layerkv",
            Policy::LayerKv { slo_aware: false } => "layerkv-no-slo",
        }
    }
}

/// Service level objectives (per request). Paper §5.2.4: TTFT 3000 ms,
/// TPOT 200 ms.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloTargets {
    pub ttft_s: f64,
    pub tpot_s: f64,
}

impl Default for SloTargets {
    fn default() -> Self {
        SloTargets { ttft_s: 3.0, tpot_s: 0.2 }
    }
}

/// Everything the engine needs to size pools and drive policies.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    pub model: ModelSpec,
    pub node: NodeSpec,
    /// Tensor-parallel degree (1 for 7B, 2 for 34B, 4 for 70B in the paper).
    pub tp: usize,
    /// Tokens per KV block (vLLM default 16).
    pub block_size: usize,
    /// Fraction of post-weights GPU memory given to KV blocks (vLLM 0.9).
    pub gpu_mem_util: f64,
    /// Maximum configured input size — drives the activation reserve during
    /// profiling (the Fig. 2 effect: bigger max input => fewer KV blocks).
    pub max_model_len: usize,
    /// Iteration-level batching caps (vLLM defaults).
    pub max_num_seqs: usize,
    pub max_batched_tokens: usize,
    pub policy: Policy,
    pub slo: SloTargets,
    /// LayerKV Eq. 5: offload retained layers when forecast free blocks
    /// drop below this fraction of the pool.
    pub avail_threshold_frac: f64,
    /// §3.1.3: chunk swaps + check PCIe before launching (multi-GPU).
    pub pcie_chunking: bool,
    /// Host KV swap space in bytes.
    pub cpu_swap_bytes: u64,
    /// Empirical correction factors of Eqs. 3-4 (calibrated in EXPERIMENTS.md).
    pub alpha: f64,
    pub beta: f64,
    /// Ablation override for §3.1.1's x (retained layers at admission):
    /// None = solve Eq. 3 vs Eq. 4; Some(x) = force x.
    pub x_override: Option<usize>,
    /// §8 future-work extension: quantize KV on the offload path. Scales
    /// every PCIe transfer (Eq. 4, decode streaming) by
    /// `quant_bytes / dtype_bytes`; on-GPU compute stays full precision.
    pub offload_quant: OffloadQuant,
    /// Cross-request prefix caching over the tier hierarchy. On by
    /// default (`LAYERKV_PREFIX=0` or `--no-prefix-cache` disables it);
    /// requests without a prefix hash never touch the cache, so traces
    /// with zero shared prefixes behave identically either way.
    pub prefix_cache: bool,
    /// Incremental KV checkpointing: write a durable disk checkpoint of
    /// each running request every K committed tokens (0 = off, the
    /// default; `LAYERKV_CKPT=K` or `--ckpt K` enables it). Checkpoints
    /// are *virtual* on the execution path — they never advance the clock
    /// (the write rides under decode like the §3.1.1 offload legs), so
    /// turning them on is execution-bit-identical off the failover path.
    /// A fenced disk tier stops checkpointing cleanly (recompute path).
    pub ckpt_every_tokens: usize,
}

/// Default for [`ServingConfig::prefix_cache`]: on unless
/// `LAYERKV_PREFIX=0`.
fn prefix_cache_default() -> bool {
    std::env::var("LAYERKV_PREFIX").map(|v| v != "0").unwrap_or(true)
}

/// Default for [`ServingConfig::ckpt_every_tokens`]: off unless
/// `LAYERKV_CKPT=K` (K > 0).
fn ckpt_default() -> usize {
    std::env::var("LAYERKV_CKPT").ok().and_then(|v| v.parse().ok()).unwrap_or(0)
}

/// Precision of offloaded KV (paper §8: "integrating KV cache quantization
/// techniques to further optimize memory efficiency").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OffloadQuant {
    /// Keep the serving dtype (lossless — the paper's shipped design).
    None,
    /// 8-bit with per-block scales (~2x fp16 traffic reduction).
    Fp8,
    /// 4-bit (KIVI-style) (~4x reduction).
    Int4,
}

impl OffloadQuant {
    /// Bytes on the wire per original dtype byte-pair, as a ratio.
    pub fn ratio(&self, dtype_bytes: usize) -> f64 {
        match self {
            OffloadQuant::None => 1.0,
            OffloadQuant::Fp8 => 1.0 / dtype_bytes as f64,
            OffloadQuant::Int4 => 0.5 / dtype_bytes as f64,
        }
    }
}

impl ServingConfig {
    pub fn new(model: ModelSpec, node: NodeSpec, tp: usize) -> Self {
        let max_model_len = model.max_context.min(16384);
        ServingConfig {
            model,
            node,
            tp,
            block_size: 16,
            gpu_mem_util: 0.9,
            max_model_len,
            max_num_seqs: 256,
            max_batched_tokens: max_model_len.max(2048),
            policy: Policy::Vllm,
            slo: SloTargets::default(),
            avail_threshold_frac: 0.10,
            pcie_chunking: true,
            cpu_swap_bytes: 256 * (1u64 << 30),
            alpha: 1.0,
            beta: 1.10,
            x_override: None,
            offload_quant: OffloadQuant::None,
            prefix_cache: prefix_cache_default(),
            ckpt_every_tokens: ckpt_default(),
        }
    }

    /// Paper's three eval setups.
    pub fn llama2_7b_tp1() -> Self {
        Self::new(ModelSpec::llama2_7b(), NodeSpec::l20_node(), 1)
    }
    pub fn yi_34b_tp2() -> Self {
        Self::new(ModelSpec::yi_34b_200k(), NodeSpec::l20_node(), 2)
    }
    pub fn llama31_70b_tp4() -> Self {
        Self::new(ModelSpec::llama31_70b(), NodeSpec::l20_node(), 4)
    }

    pub fn with_policy(mut self, policy: Policy) -> Self {
        self.policy = policy;
        self
    }

    pub fn with_max_model_len(mut self, len: usize) -> Self {
        self.max_model_len = len;
        self.max_batched_tokens = len.max(2048);
        self
    }

    /// Enable/disable cross-request prefix caching.
    pub fn with_prefix_cache(mut self, on: bool) -> Self {
        self.prefix_cache = on;
        self
    }

    /// Checkpoint every `k` committed tokens (0 disables).
    pub fn with_checkpointing(mut self, k: usize) -> Self {
        self.ckpt_every_tokens = k;
        self
    }

    /// Per-GPU weight bytes under TP sharding.
    pub fn weight_bytes_per_gpu(&self) -> u64 {
        self.model.weight_bytes() / self.tp as u64
    }

    /// Activation reserve measured by the init-time profiling pass: vLLM
    /// runs `max_model_len` tokens through the model and keeps the peak
    /// activation footprint out of the KV pool. Model: per-token peak
    /// activations ~ (4*hidden + 2*ffn_hidden) elements (attention proj
    /// buffers + the fused FFN intermediate), sharded by TP.
    pub fn activation_reserve_bytes(&self) -> u64 {
        let per_token = (4 * self.model.hidden + 2 * self.model.ffn_hidden)
            * self.model.dtype_bytes;
        (self.max_model_len as u64 * per_token as u64) / self.tp as u64
    }

    /// Bytes of one KV block (all layers, `block_size` tokens), per GPU.
    /// KV heads shard across TP ranks.
    pub fn block_bytes_per_gpu(&self) -> u64 {
        self.model.kv_bytes_per_token() * self.block_size as u64 / self.tp as u64
    }

    /// Number of whole-request KV blocks the profiling pass yields
    /// (request-wise accounting, i.e. a block spans all layers — vLLM's
    /// unit). LayerKV subdivides each into `n_layers` layer-blocks.
    pub fn num_gpu_blocks(&self) -> usize {
        let gpu = &self.node.gpu;
        let budget = (gpu.memory_bytes as f64 * self.gpu_mem_util) as i128
            - self.weight_bytes_per_gpu() as i128
            - self.activation_reserve_bytes() as i128;
        if budget <= 0 {
            return 0;
        }
        (budget as u64 / self.block_bytes_per_gpu()) as usize
    }

    /// LayerKV's allocation unit: one block of ONE layer.
    pub fn num_gpu_layer_blocks(&self) -> usize {
        self.num_gpu_blocks() * self.model.n_layers
    }

    /// Capacity of the host swap pool in layer-blocks.
    pub fn num_cpu_layer_blocks(&self) -> usize {
        let layer_block_bytes = self.block_bytes_per_gpu() / self.model.n_layers as u64;
        if layer_block_bytes == 0 {
            return 0;
        }
        (self.cpu_swap_bytes / layer_block_bytes) as usize
    }

    /// Capacity of the disk spill tier in layer-blocks (0 when the node
    /// has no disk tier — the two-tier configuration).
    pub fn num_disk_layer_blocks(&self) -> usize {
        let layer_block_bytes = self.block_bytes_per_gpu() / self.model.n_layers as u64;
        if layer_block_bytes == 0 {
            return 0;
        }
        (self.node.disk.capacity_bytes / layer_block_bytes) as usize
    }

    /// Attach (or replace) the node's disk tier.
    pub fn with_disk(mut self, disk: crate::config::DiskSpec) -> Self {
        self.node.disk = disk;
        self
    }

    /// Blocks a prompt of `len` tokens needs under request-wise accounting.
    pub fn blocks_for_tokens(&self, len: usize) -> usize {
        len.div_ceil(self.block_size)
    }

    /// Bytes per token-layer actually pushed over PCIe when offloading
    /// (full dtype, scaled by the §8 quantization extension if enabled).
    pub fn offload_bytes_per_token_layer(&self) -> f64 {
        self.model.kv_bytes_per_token_layer() as f64
            * self.offload_quant.ratio(self.model.dtype_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiling_matches_hand_calc_7b() {
        let c = ServingConfig::llama2_7b_tp1();
        // 48 GiB * 0.9 - 13.476 GB weights - act reserve; block = 8 MiB
        let blocks = c.num_gpu_blocks();
        assert!(blocks > 3000 && blocks < 4500, "blocks={blocks}");
        assert_eq!(c.num_gpu_layer_blocks(), blocks * 32);
    }

    #[test]
    fn bigger_max_len_fewer_blocks() {
        let short = ServingConfig::llama2_7b_tp1().with_max_model_len(2048);
        let long = ServingConfig::llama2_7b_tp1().with_max_model_len(16384);
        assert!(short.num_gpu_blocks() > long.num_gpu_blocks());
    }

    #[test]
    fn tp_shards_weights_and_kv() {
        let c = ServingConfig::yi_34b_tp2();
        assert_eq!(c.weight_bytes_per_gpu(), ModelSpec::yi_34b_200k().weight_bytes() / 2);
        // 34B in fp16 = 68.8 GB > 48 GB: must not fit on one GPU
        let c1 = ServingConfig::new(ModelSpec::yi_34b_200k(), NodeSpec::l20_node(), 1);
        assert_eq!(c1.num_gpu_blocks(), 0);
        assert!(c.num_gpu_blocks() > 0);
    }

    #[test]
    fn policy_names() {
        assert_eq!(Policy::Vllm.name(), "vllm");
        assert_eq!(Policy::LayerKv { slo_aware: true }.name(), "layerkv");
        assert_eq!(Policy::LayerKv { slo_aware: false }.name(), "layerkv-no-slo");
    }

    #[test]
    fn disk_pool_sizing() {
        let two_tier = ServingConfig::llama2_7b_tp1();
        assert_eq!(two_tier.num_disk_layer_blocks(), 0);
        let three_tier =
            ServingConfig::llama2_7b_tp1().with_disk(crate::config::DiskSpec::nvme_4tb());
        // 4 TB of spill space dwarfs the 256 GB host swap pool
        assert!(three_tier.num_disk_layer_blocks() > three_tier.num_cpu_layer_blocks());
    }

    #[test]
    fn blocks_for_tokens_rounds_up() {
        let c = ServingConfig::llama2_7b_tp1();
        assert_eq!(c.blocks_for_tokens(1), 1);
        assert_eq!(c.blocks_for_tokens(16), 1);
        assert_eq!(c.blocks_for_tokens(17), 2);
    }

    #[test]
    fn offload_quant_ratios() {
        // fp16 serving dtype: fp8 halves traffic, int4 quarters it
        assert_eq!(OffloadQuant::None.ratio(2), 1.0);
        assert_eq!(OffloadQuant::Fp8.ratio(2), 0.5);
        assert_eq!(OffloadQuant::Int4.ratio(2), 0.25);
        let mut c = ServingConfig::llama2_7b_tp1();
        let full = c.offload_bytes_per_token_layer();
        c.offload_quant = OffloadQuant::Int4;
        assert_eq!(c.offload_bytes_per_token_layer(), full * 0.25);
    }
}
