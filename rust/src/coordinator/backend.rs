//! The execution-backend seam: one coordinator, pluggable executors.
//!
//! LayerKV's claim is that a single policy layer — layer-wise KV
//! allocation/offload plus the SLO-aware scheduler — plugs into an
//! existing serving engine. This module is that plug. `Engine<B>`
//! (engine.rs) owns the policy loop: FCFS queueing, `make_scheduler`
//! decisions, `KvManager` layer-table accounting, restore/offload
//! hysteresis, recompute preemption, and metrics. An `ExecutionBackend`
//! owns only *execution*: what a prefill or decode iteration physically
//! does, how long it takes, and where the bytes actually move.
//!
//! Two backends ship:
//!
//! * [`SimBackend`] — the analytical executor. Steps cost what the
//!   `CostModel` (Eqs. 3–4 + roofline decode + PCIe link sharing) says
//!   they cost, and time is a [`VirtualClock`] the engine advances by
//!   each step's modeled duration. This preserves the pre-refactor
//!   simulation engine bit-for-bit (see
//!   `tests/support/reference_engine.rs`).
//! * `PjrtBackend` (`runtime/realengine.rs`) — the real executor: actual
//!   tokens through the compiled HLO, actual per-layer KV tensors moving
//!   between the bounded device pool and the host pool, timed by a
//!   [`WallClock`].
//!
//! A CUDA/TPU backend would implement the same trait: run the kernels in
//! `prefill`/`decode`, mirror `offload_layer`/`onload_layer` as real
//! d2h/h2d copies, and use `WallClock`.

use crate::config::{Fabric, ServingConfig};
use crate::coordinator::block::KvManager;
use crate::coordinator::request::{ReqId, Request};
use crate::sim::CostModel;

/// Engine-time source. Virtual time advances by each step's modeled
/// duration (the simulator measures latency with the same clock the
/// paper measures with wall time); wall time advances on its own and
/// `advance` is a no-op.
pub trait Clock {
    /// Seconds since engine start.
    fn now(&self) -> f64;
    /// The step that just executed took `dt` seconds of engine time.
    fn advance(&mut self, dt: f64);
    /// Idle until `t`: jump for virtual time, a bounded sleep for wall
    /// time (the caller loops, so arrivals are re-checked promptly).
    fn wait_until(&mut self, t: f64);
}

/// Simulation time: a counter the engine advances.
#[derive(Debug, Clone, Copy, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: 0.0 }
    }
}

impl Clock for VirtualClock {
    fn now(&self) -> f64 {
        self.now
    }

    fn advance(&mut self, dt: f64) {
        self.now += dt;
    }

    fn wait_until(&mut self, t: f64) {
        self.now = t.max(self.now);
    }
}

/// Real time since construction.
#[derive(Debug)]
pub struct WallClock {
    t0: std::time::Instant,
}

impl WallClock {
    pub fn new() -> Self {
        WallClock { t0: std::time::Instant::now() }
    }
}

impl Default for WallClock {
    fn default() -> Self {
        Self::new()
    }
}

impl Clock for WallClock {
    fn now(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }

    fn advance(&mut self, _dt: f64) {}

    fn wait_until(&mut self, t: f64) {
        let dt = t - self.now();
        if dt > 0.0 {
            // coarse sleep: the engine loop re-polls arrivals each pass
            std::thread::sleep(std::time::Duration::from_secs_f64(dt.min(0.005)));
        }
    }
}

/// One executed prefill, as the engine accounts it.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefillOutcome {
    /// Seconds of engine time the prefill consumed (modeled or measured).
    pub duration: f64,
    /// d2h bytes of the non-retained layers' KV moved under the prefill.
    pub offload_bytes: f64,
    /// Bytes that continued past host RAM onto the disk tier (the subset
    /// of `offload_bytes` whose layers were admitted straight to disk).
    pub spill_bytes: f64,
    /// When this request's first token actually materialised. Wall-clock
    /// backends report it so batched admissions don't inflate earlier
    /// requests' TTFT with later requests' prefill time; `None` (the
    /// simulated backend) means "at batch end", the virtual-time
    /// semantics.
    pub first_token_at: Option<f64>,
}

/// One executed decode iteration over the chosen batch.
#[derive(Debug, Clone, Copy, Default)]
pub struct DecodeOutcome {
    /// Seconds of engine time the step consumed.
    pub duration: f64,
    /// Seconds the step was inflated by host-KV streaming.
    pub stream_stall_s: f64,
    /// Seconds lost to PCIe contention (TP all-reduce vs KV streams).
    pub contention_s: f64,
    /// Seconds of the stall attributable to the disk tier's link (0 in
    /// the two-tier configuration).
    pub disk_stall_s: f64,
}

/// What turns scheduler decisions into executed steps.
///
/// The engine calls `prefill` only after the `KvManager` granted the
/// layer-wise allocation (the table's residency *is* the retained set),
/// and mirrors every residency move (`offload_layer` / `onload_layer` /
/// `evict` / `release`) so a real backend keeps its tensor store in
/// lock-step with the block accounting.
pub trait ExecutionBackend {
    type Clk: Clock;

    fn clock(&self) -> &Self::Clk;
    fn clock_mut(&mut self) -> &mut Self::Clk;

    /// Largest decode batch the executor can run in one step
    /// (`usize::MAX` when unconstrained, as in simulation).
    fn max_decode_lanes(&self) -> usize {
        usize::MAX
    }

    /// Can this prompt ever be executed (e.g. fits a compiled prefill
    /// bucket)? Requests failing this are rejected at arrival and land in
    /// `EngineStats::dropped` instead of corrupting the latency records.
    fn supports_prompt(&self, prompt_len: usize) -> bool {
        let _ = prompt_len;
        true
    }

    /// Whether the engine's livelock step bound applies. Wall-clock
    /// backends idle-spin between arrivals, so their step counts are not
    /// evidence of livelock.
    fn bounded_steps(&self) -> bool {
        true
    }

    /// Whether the engine may *fast-forward* stable decode spans past this
    /// backend: commit `k` decode iterations in one macro-step, advancing
    /// the clock by the per-step analytic durations without calling
    /// `decode`/`commit_token` per iteration (see `coordinator/horizon.rs`).
    /// Only valid for backends whose decode cost is exactly
    /// `CostModel::decode_step_time_sum` for a fully-GPU-resident batch
    /// and whose per-token `commit_token` is a no-op — i.e. the analytic
    /// simulator. Wall-clock executors must keep the default (`false`):
    /// their step durations are measured, not modeled.
    fn supports_fast_forward(&self) -> bool {
        false
    }

    /// Execute one admitted prefill. The request's `KvManager` table
    /// already records which layers were retained on the GPU.
    fn prefill(&mut self, req: &Request, kv: &KvManager) -> anyhow::Result<PrefillOutcome>;

    /// Execute one decode iteration over `lanes`. `stream_bytes` > 0 when
    /// the batch includes host-resident KV that must stream in (the
    /// forced-progress path); `disk_stream_bytes` is the portion that must
    /// additionally traverse the disk tier's link first (always 0 in the
    /// two-tier configuration). A real backend stages each lane's next
    /// token internally; the engine confirms per lane via `commit_token`
    /// once the block accounting accepted the growth.
    fn decode(
        &mut self,
        lanes: &[ReqId],
        requests: &[Request],
        kv: &KvManager,
        total_ctx: usize,
        stream_bytes: f64,
        disk_stream_bytes: f64,
    ) -> anyhow::Result<DecodeOutcome>;

    /// The engine accepted this lane's token from the last `decode` call
    /// (`KvManager::append_token` succeeded). Uncommitted staged tokens
    /// are discarded and recomputed next step.
    fn commit_token(&mut self, rid: ReqId) {
        let _ = rid;
    }

    /// Mirror of a granted `KvManager::offload_layer` (GPU -> host).
    fn offload_layer(&mut self, rid: ReqId, layer: usize) {
        let _ = (rid, layer);
    }

    /// Mirror of a granted `KvManager::onload_layer` (host -> GPU).
    fn onload_layer(&mut self, rid: ReqId, layer: usize) {
        let _ = (rid, layer);
    }

    /// Mirror of a granted `KvManager::spill_layer` (host -> disk). A real
    /// backend writes the layer's tensor to a spill file and frees the
    /// host copy. `Err` means the disk-tier I/O failed and the layer is
    /// still host-resident; the engine rolls the block accounting back and
    /// counts the error toward its disk-tier fence (K consecutive errors
    /// retire the tier — see `Engine::fence_disk`).
    fn spill_layer(&mut self, rid: ReqId, layer: usize) -> anyhow::Result<()> {
        let _ = (rid, layer);
        Ok(())
    }

    /// Mirror of a granted `KvManager::unspill_layer` (disk -> host).
    /// `Err` means the spill file could not be read back; the layer stays
    /// disk-resident.
    fn unspill_layer(&mut self, rid: ReqId, layer: usize) -> anyhow::Result<()> {
        let _ = (rid, layer);
        Ok(())
    }

    /// Mirror of a granted `KvManager::promote_disk_layer` (disk -> GPU):
    /// a disk read followed by the h2d copy. `Err` means the disk read
    /// failed and the layer stays disk-resident.
    fn promote_disk_layer(&mut self, rid: ReqId, layer: usize) -> anyhow::Result<()> {
        let _ = (rid, layer);
        Ok(())
    }

    /// Straggler injection: scale this executor's step durations by
    /// `factor` (1.0 = nominal). Only meaningful for modeled time; the
    /// default ignores it — a wall-clock backend is exactly as slow as it
    /// really is.
    fn set_slowdown(&mut self, factor: f64) {
        let _ = factor;
    }

    /// Current straggler factor (1.0 = nominal). Routers fold this into
    /// their load scores so degraded replicas attract less traffic.
    fn slowdown(&self) -> f64 {
        1.0
    }

    /// Whether adopted KV state can resume decoding directly from a
    /// restored block map. The analytic simulator can (its KV is pure
    /// accounting, so `allocate_layerwise` + a restore charge recreates
    /// it); a real backend whose tensors died with the source process
    /// cannot — adopted requests there take the recompute (re-prefill)
    /// path, which the deterministic RefModel makes token-bit-identical.
    fn supports_kv_restore(&self) -> bool {
        false
    }

    /// Export the real token streams `(prompt, out)` for a live request
    /// so a snapshot can carry them across replicas. `None` for modeled
    /// backends — no actual tokens exist.
    fn snapshot_tokens(&self, rid: ReqId) -> Option<(Vec<i32>, Vec<i32>)> {
        let _ = rid;
        None
    }

    /// Install a snapshot's token streams for an adopted request (lane
    /// `rid` on *this* backend). No-op for modeled backends.
    fn adopt(&mut self, rid: ReqId, tokens: Option<(Vec<i32>, Vec<i32>)>) {
        let _ = (rid, tokens);
    }

    /// Recompute preemption: the request's KV is dropped everywhere; its
    /// generated-so-far tokens survive for the re-prefill.
    fn evict(&mut self, rid: ReqId) {
        let _ = rid;
    }

    /// The request finished; its KV is released everywhere.
    fn release(&mut self, rid: ReqId) {
        let _ = rid;
    }
}

/// The analytical executor: steps cost what the `CostModel` says, KV
/// "moves" are pure accounting. Wraps the cost model (Eqs. 3–4, the
/// roofline decode step, and the shared-PCIe-link bandwidth model), the
/// disk tier's `TransferLink`, and a virtual clock.
#[derive(Debug)]
pub struct SimBackend {
    cfg: ServingConfig,
    cost: CostModel,
    /// The host<->disk link (a slow, high-latency PCIe-like link).
    disk_link: crate::sim::TransferLink,
    clock: VirtualClock,
    /// Straggler factor: every step duration is scaled by this (1.0 =
    /// nominal, the only value on the fault-free path — the multiply is
    /// gated so bit-identity holds there).
    slowdown: f64,
}

impl SimBackend {
    pub fn new(cfg: &ServingConfig) -> Self {
        SimBackend {
            cfg: cfg.clone(),
            cost: CostModel::new(cfg.clone()),
            disk_link: crate::sim::TransferLink::disk(&cfg.node.disk),
            clock: VirtualClock::new(),
            slowdown: 1.0,
        }
    }
}

impl ExecutionBackend for SimBackend {
    type Clk = VirtualClock;

    fn clock(&self) -> &VirtualClock {
        &self.clock
    }

    fn clock_mut(&mut self) -> &mut VirtualClock {
        &mut self.clock
    }

    /// Stable decode spans cost exactly `decode_step_time_sum` here (no
    /// stream bytes, no contention), so macro-stepping them is free —
    /// unless a straggler slowdown is active: the fast-forward horizon
    /// replays *nominal* per-step durations, so a degraded replica must
    /// single-step until the slowdown lifts.
    fn supports_fast_forward(&self) -> bool {
        self.slowdown == 1.0
    }

    /// Modeled KV is pure accounting: an adopted block map plus the
    /// restore-time charge fully recreates the drained state.
    fn supports_kv_restore(&self) -> bool {
        true
    }

    fn set_slowdown(&mut self, factor: f64) {
        debug_assert!(factor >= 1.0, "slowdown scales durations up");
        self.slowdown = factor;
    }

    fn slowdown(&self) -> f64 {
        self.slowdown
    }

    fn prefill(&mut self, req: &Request, kv: &KvManager) -> anyhow::Result<PrefillOutcome> {
        let len = req.prefill_len();
        let l = self.cfg.model.n_layers;
        // the table's residency is the retained set the scheduler solved
        let x = kv.table(req.id).map(|t| t.n_gpu_layers()).unwrap_or(l);
        // d2h of the L-x offloaded layers rides under the prefill
        // (§3.1.1 chose x so T_offload <= T_prefill); layers the host
        // pool could not hold continue over the disk link — the tiered
        // x-solve already sized x so that leg hides too
        let disk_layers = kv.table(req.id).map(|t| t.n_disk_layers()).unwrap_or(0);
        let offload_bytes = len as f64
            * (l - x) as f64
            * self.cfg.offload_bytes_per_token_layer()
            / self.cfg.tp as f64;
        let spill_bytes = len as f64
            * disk_layers as f64
            * self.cfg.offload_bytes_per_token_layer()
            / self.cfg.tp as f64;
        // Compute only the un-cached suffix: tokens restored from the
        // prefix cache skip the forward pass (their restore cost was
        // already charged by the engine at admission). Offload/spill
        // bytes still cover the *full* table — cached layers were
        // re-materialised into this request's table and ride the same
        // links out.
        let compute_len = len - req.cached_prefix.min(len.saturating_sub(1));
        let mut duration = self.cost.prefill_time(compute_len);
        if self.slowdown != 1.0 {
            duration *= self.slowdown;
        }
        Ok(PrefillOutcome {
            duration,
            offload_bytes,
            spill_bytes,
            first_token_at: None, // virtual time: first token at batch end
        })
    }

    fn decode(
        &mut self,
        lanes: &[ReqId],
        requests: &[Request],
        kv: &KvManager,
        total_ctx: usize,
        stream_bytes: f64,
        disk_stream_bytes: f64,
    ) -> anyhow::Result<DecodeOutcome> {
        let _ = (requests, kv);
        let batch = lanes.len();
        let compute = self.cost.decode_step_time_sum(total_ctx, batch);
        let stream_time = if stream_bytes > 0.0 {
            stream_bytes / self.cost.pcie_bw_per_gpu() + self.cfg.node.pcie.latency
        } else {
            0.0
        };
        // Disk-resident layers stream serially through both links:
        // disk -> host first, then the shared h2d path. transfer_time is
        // 0 for 0 bytes (the two-tier configuration, keeping
        // `total_stream == stream_time` bit-for-bit) and INFINITY for a
        // capacity>0/bandwidth=0 misconfiguration — loud, not free.
        let disk_time = self.disk_link.transfer_time(disk_stream_bytes);
        let total_stream = stream_time + disk_time;
        let mut step = compute.max(total_stream);
        let mut stream_stall_s = (total_stream - compute).max(0.0);
        // only the portion that actually inflated the step counts as a
        // disk stall (compute can hide part or all of the disk leg)
        let mut disk_stall_s = disk_time.min(stream_stall_s);

        // §3.1.3 PCIe contention: TP over PCIe shares the link between
        // all-reduce and KV streams. The check+chunk mechanism confines the
        // penalty to chunk tails; without it the overlap serializes.
        let mut contention_s = 0.0;
        if self.cfg.tp > 1 && self.cfg.node.fabric == Fabric::Pcie && stream_bytes > 0.0 {
            let ar = self.cost.allreduce_time(batch);
            let penalty =
                if self.cfg.pcie_chunking { 0.05 * ar } else { ar.min(stream_time) };
            step += penalty;
            contention_s = penalty;
        }
        if self.slowdown != 1.0 {
            // a straggler is uniformly degraded: compute and stalls alike
            step *= self.slowdown;
            stream_stall_s *= self.slowdown;
            contention_s *= self.slowdown;
            disk_stall_s *= self.slowdown;
        }
        Ok(DecodeOutcome { duration: step, stream_stall_s, contention_s, disk_stall_s })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn virtual_clock_advances_and_jumps() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance(1.5);
        assert_eq!(c.now(), 1.5);
        c.wait_until(3.0);
        assert_eq!(c.now(), 3.0);
        c.wait_until(2.0); // never goes backwards
        assert_eq!(c.now(), 3.0);
    }

    #[test]
    fn wall_clock_is_monotone_and_ignores_advance() {
        let mut c = WallClock::new();
        let a = c.now();
        c.advance(100.0); // no-op
        let b = c.now();
        assert!(b >= a);
        assert!(b < 50.0, "wall clock must not jump on advance");
    }

    #[test]
    fn sim_backend_decode_matches_cost_model() {
        let cfg = ServingConfig::llama2_7b_tp1();
        let cost = CostModel::new(cfg.clone());
        let kv = KvManager::new(16, 16, cfg.block_size, cfg.model.n_layers);
        let mut b = SimBackend::new(&cfg);
        let reqs: Vec<Request> = Vec::new();
        let out = b.decode(&[0, 1], &reqs, &kv, 2048, 0.0, 0.0).unwrap();
        assert_eq!(out.duration, cost.decode_step_time_sum(2048, 2));
        assert_eq!(out.stream_stall_s, 0.0);
        assert_eq!(out.contention_s, 0.0);
        assert_eq!(out.disk_stall_s, 0.0);
    }

    #[test]
    fn sim_backend_slowdown_scales_steps_and_gates_fast_forward() {
        let cfg = ServingConfig::llama2_7b_tp1();
        let kv = KvManager::new(16, 16, cfg.block_size, cfg.model.n_layers);
        let reqs: Vec<Request> = Vec::new();
        let mut b = SimBackend::new(&cfg);
        assert!(b.supports_fast_forward());
        let nominal = b.decode(&[0, 1], &reqs, &kv, 2048, 0.0, 0.0).unwrap();
        b.set_slowdown(3.0);
        assert!(!b.supports_fast_forward(), "stragglers must single-step");
        let slow = b.decode(&[0, 1], &reqs, &kv, 2048, 0.0, 0.0).unwrap();
        assert!((slow.duration - 3.0 * nominal.duration).abs() < 1e-15);
        b.set_slowdown(1.0);
        let back = b.decode(&[0, 1], &reqs, &kv, 2048, 0.0, 0.0).unwrap();
        assert_eq!(back.duration.to_bits(), nominal.duration.to_bits());
        assert!(b.supports_fast_forward());
    }

    #[test]
    fn sim_backend_disk_stream_serializes_both_links() {
        use crate::config::DiskSpec;
        let mut cfg = ServingConfig::llama2_7b_tp1();
        cfg.node.disk = DiskSpec::nvme_4tb();
        let kv = KvManager::new(16, 16, cfg.block_size, cfg.model.n_layers);
        let reqs: Vec<Request> = Vec::new();
        let mut b = SimBackend::new(&cfg);
        let host_only = b.decode(&[0], &reqs, &kv, 8192, 1.0e9, 0.0).unwrap();
        let with_disk = b.decode(&[0], &reqs, &kv, 8192, 1.0e9, 1.0e9).unwrap();
        assert!(with_disk.duration > host_only.duration);
        assert!(with_disk.disk_stall_s > 0.0);
        // the disk leg is the NVMe transfer time of those bytes
        let want = 1.0e9 / cfg.node.disk.bandwidth + cfg.node.disk.latency;
        assert!((with_disk.disk_stall_s - want).abs() < 1e-12);
    }
}
