//! Physical KV block pools (free-list allocators with real block ids).
//!
//! The GPU pool is denominated in *layer-blocks* — one block of one layer,
//! LayerKV's allocation unit (§3.1.1). The vLLM baseline allocates in
//! whole-request units of `n_layers` layer-blocks, so both policies draw
//! from the same physical pool and the comparison is apples-to-apples.

pub type BlockId = u32;

/// Free-list pool. O(1) alloc/free, duplicate-free by construction, with
/// a debug-mode double-free guard.
#[derive(Debug, Clone)]
pub struct BlockPool {
    free: Vec<BlockId>,
    total: usize,
    #[cfg(debug_assertions)]
    allocated: std::collections::HashSet<BlockId>,
}

impl BlockPool {
    pub fn new(total: usize) -> Self {
        BlockPool {
            free: (0..total as BlockId).rev().collect(),
            total,
            #[cfg(debug_assertions)]
            allocated: std::collections::HashSet::new(),
        }
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn available(&self) -> usize {
        self.free.len()
    }

    pub fn used(&self) -> usize {
        self.total - self.free.len()
    }

    /// Allocate exactly `n` blocks or nothing, appending them to `out`.
    /// The hot-path entry point: reuses the caller's buffer, so steady-state
    /// allocation churn is zero. Returns false (and leaves `out` untouched)
    /// when fewer than `n` blocks are free.
    pub fn alloc_into(&mut self, n: usize, out: &mut Vec<BlockId>) -> bool {
        if self.free.len() < n {
            return false;
        }
        let start = self.free.len() - n;
        #[cfg(debug_assertions)]
        for &b in &self.free[start..] {
            assert!(self.allocated.insert(b), "double allocation of block {b}");
        }
        out.extend_from_slice(&self.free[start..]);
        self.free.truncate(start);
        true
    }

    /// Allocate exactly `n` blocks or nothing (fresh-Vec convenience; cold
    /// paths and tests — hot paths use `alloc_into`).
    pub fn alloc(&mut self, n: usize) -> Option<Vec<BlockId>> {
        let mut out = Vec::with_capacity(n);
        if self.alloc_into(n, &mut out) {
            Some(out)
        } else {
            None
        }
    }

    /// Pop one block straight off the free list — no intermediate Vec
    /// (`append_token` calls this once per layer per block boundary).
    pub fn alloc_one(&mut self) -> Option<BlockId> {
        let b = self.free.pop()?;
        #[cfg(debug_assertions)]
        assert!(self.allocated.insert(b), "double allocation of block {b}");
        Some(b)
    }

    /// Allocate exactly `n` blocks or nothing, appending them to `out` in
    /// the order `n` successive `alloc_one` pops would have produced (the
    /// free list's tail, last id first) — the macro-stepping engine's bulk
    /// equivalent of per-token growth, same free-list discipline. Returns
    /// false (and leaves `out` untouched) when fewer than `n` are free.
    pub fn alloc_span(&mut self, n: usize, out: &mut Vec<BlockId>) -> bool {
        if self.free.len() < n {
            return false;
        }
        let start = self.free.len() - n;
        #[cfg(debug_assertions)]
        for &b in &self.free[start..] {
            assert!(self.allocated.insert(b), "double allocation of block {b}");
        }
        out.extend(self.free[start..].iter().rev().copied());
        self.free.truncate(start);
        true
    }

    pub fn release(&mut self, blocks: &[BlockId]) {
        #[cfg(debug_assertions)]
        for &b in blocks {
            assert!((b as usize) < self.total, "foreign block {b}");
            assert!(self.allocated.remove(&b), "double free of block {b}");
        }
        self.free.extend_from_slice(blocks);
        debug_assert!(self.free.len() <= self.total);
    }

    /// Permanently take the pool out of service: capacity drops to zero,
    /// so every future allocation fails and `total() == 0` — the
    /// "tier disabled" sentinel the scheduler keys on. The pool must be
    /// fully free: the engine's disk-tier fence guarantees this by
    /// preempting every request still holding disk layers first (their
    /// ids would otherwise dangle above the shrunk capacity).
    pub fn retire(&mut self) {
        debug_assert_eq!(self.used(), 0, "retire requires all blocks released");
        self.total = 0;
        self.free.clear();
    }

    /// Validate free-list integrity (property tests): every free id is in
    /// range and unique, and free + allocated never exceeds the capacity.
    /// The per-tier conservation suite runs this against every pool in
    /// the hierarchy after each step.
    pub fn check(&self) -> Result<(), String> {
        if self.free.len() > self.total {
            return Err(format!(
                "free list overflow: {} free of {} total",
                self.free.len(),
                self.total
            ));
        }
        let mut seen = vec![false; self.total];
        for &b in &self.free {
            let i = b as usize;
            if i >= self.total {
                return Err(format!("foreign block {b} on the free list"));
            }
            if seen[i] {
                return Err(format!("block {b} on the free list twice"));
            }
            seen[i] = true;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop;

    #[test]
    fn alloc_free_roundtrip() {
        let mut p = BlockPool::new(10);
        assert_eq!(p.available(), 10);
        let a = p.alloc(4).unwrap();
        assert_eq!(a.len(), 4);
        assert_eq!(p.available(), 6);
        assert_eq!(p.used(), 4);
        p.release(&a);
        assert_eq!(p.available(), 10);
    }

    #[test]
    fn all_or_nothing() {
        let mut p = BlockPool::new(3);
        assert!(p.alloc(4).is_none());
        assert_eq!(p.available(), 3, "failed alloc must not leak");
        assert!(p.alloc(3).is_some());
        assert!(p.alloc_one().is_none());
    }

    #[test]
    #[should_panic(expected = "double free")]
    #[cfg(debug_assertions)]
    fn double_free_caught() {
        let mut p = BlockPool::new(4);
        let a = p.alloc(1).unwrap();
        p.release(&a);
        p.release(&a);
    }

    #[test]
    fn alloc_into_reuses_buffer_and_is_all_or_nothing() {
        let mut p = BlockPool::new(8);
        let mut buf = Vec::new();
        assert!(p.alloc_into(3, &mut buf));
        assert_eq!(buf.len(), 3);
        assert!(!p.alloc_into(6, &mut buf), "only 5 left");
        assert_eq!(buf.len(), 3, "failed alloc must not touch the buffer");
        p.release(&buf);
        buf.clear();
        let cap = buf.capacity();
        assert!(p.alloc_into(3, &mut buf));
        assert_eq!(buf.capacity(), cap, "buffer reused, not regrown");
        p.release(&buf);
    }

    #[test]
    fn alloc_span_matches_repeated_alloc_one() {
        let mut a = BlockPool::new(12);
        let mut b = BlockPool::new(12);
        let mut ids_a = Vec::new();
        let mut ids_b = Vec::new();
        assert!(a.alloc_span(5, &mut ids_a));
        for _ in 0..5 {
            ids_b.push(b.alloc_one().unwrap());
        }
        assert_eq!(ids_a, ids_b, "span must replay alloc_one's pop order");
        assert_eq!(a.available(), b.available());
        // all-or-nothing, buffer untouched on failure
        assert!(!a.alloc_span(8, &mut ids_a), "only 7 left");
        assert_eq!(ids_a.len(), 5);
        assert!(a.alloc_span(0, &mut ids_a), "empty span always succeeds");
        assert_eq!(ids_a.len(), 5);
        a.release(&ids_a);
        a.check().unwrap();
    }

    #[test]
    fn alloc_one_pops_directly() {
        let mut p = BlockPool::new(2);
        let a = p.alloc_one().unwrap();
        let b = p.alloc_one().unwrap();
        assert_ne!(a, b);
        assert!(p.alloc_one().is_none());
        p.release(&[a, b]);
        assert_eq!(p.available(), 2);
    }

    #[test]
    fn ids_unique_across_live_allocations() {
        let mut p = BlockPool::new(100);
        let a = p.alloc(50).unwrap();
        let b = p.alloc(50).unwrap();
        let mut all: Vec<_> = a.iter().chain(b.iter()).collect();
        all.sort();
        all.dedup();
        assert_eq!(all.len(), 100);
    }

    #[test]
    fn retire_kills_the_pool() {
        let mut p = BlockPool::new(8);
        let a = p.alloc(3).unwrap();
        p.release(&a);
        p.retire();
        assert_eq!(p.total(), 0);
        assert_eq!(p.available(), 0);
        assert!(p.alloc_one().is_none());
        assert!(p.alloc(1).is_none());
        p.check().unwrap();
    }

    #[test]
    fn check_validates_free_list() {
        let mut p = BlockPool::new(8);
        p.check().unwrap();
        let a = p.alloc(3).unwrap();
        p.check().unwrap();
        p.release(&a);
        p.check().unwrap();
        assert!(BlockPool::new(0).check().is_ok());
    }

    #[test]
    fn prop_conservation_under_random_ops() {
        prop(200, |rng| {
            let total = rng.range_usize(1, 64);
            let mut pool = BlockPool::new(total);
            let mut live: Vec<Vec<BlockId>> = Vec::new();
            for _ in 0..100 {
                if rng.chance(0.5) {
                    let n = rng.range_usize(0, 8);
                    if let Some(blocks) = pool.alloc(n) {
                        live.push(blocks);
                    }
                } else if !live.is_empty() {
                    let i = rng.range_usize(0, live.len());
                    let blocks = live.swap_remove(i);
                    pool.release(&blocks);
                }
                // invariant: free + live == total
                let live_count: usize = live.iter().map(Vec::len).sum();
                assert_eq!(pool.available() + live_count, total);
            }
        });
    }
}
