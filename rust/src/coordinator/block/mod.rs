//! KV cache manager: the physical tier pools + layer-wise block tables +
//! the residency moves the LayerKV execution engine performs across the
//! GPU -> host -> disk hierarchy (offload/onload at the GPU boundary,
//! spill/unspill at the host boundary, promote for deep restores).

pub mod allocator;
pub mod prefix;
pub mod snapshot;
pub mod table;

pub use allocator::{BlockId, BlockPool};
pub use prefix::{PrefixHit, PrefixMove, PrefixPublish, PrefixStore};
pub use snapshot::RequestSnapshot;
pub use table::{LayerBlockTable, LayerEntry, Residency};

use std::collections::HashMap;

use crate::coordinator::request::ReqId;

/// Why an allocation failed. `CpuExhausted` covers the whole host-side
/// hierarchy: the host pool is full AND the disk tier (if configured)
/// cannot absorb the overflow. (No separate disk variant: the two-tier
/// configuration's error surface is frozen by the pre-refactor reference
/// engine, which matches this enum exhaustively.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvError {
    GpuExhausted,
    CpuExhausted,
    UnknownRequest,
}

/// Manages the tier pools (denominated in layer-blocks) and every live
/// request's layer-wise block table. `disk` has capacity 0 in the
/// two-tier configuration, which makes every disk path unreachable and
/// preserves the pre-hierarchy semantics bit-for-bit.
///
/// §Perf: the steady-state request lifecycle is allocation-free. Released
/// tables (with their per-layer block Vecs' capacity) are recycled through
/// `spare_tables` for the next admission, block ids move through the
/// reusable `scratch` buffer on offload/onload/spill, and per-token growth
/// pops straight off the pools' free lists.
#[derive(Debug)]
pub struct KvManager {
    pub gpu: BlockPool,
    pub cpu: BlockPool,
    /// The deepest tier (spill files / NVMe). Capacity 0 = disabled.
    pub disk: BlockPool,
    pub block_size: usize,
    pub n_layers: usize,
    tables: HashMap<ReqId, LayerBlockTable>,
    /// Released tables kept for reuse (bounded by peak live requests).
    spare_tables: Vec<LayerBlockTable>,
    /// Staging buffer for block ids in flight between pools.
    scratch: Vec<BlockId>,
    /// Cross-request prefix cache (see `prefix.rs`); empty — and
    /// bit-invisible — unless the engine publishes into it.
    pub(crate) prefix: PrefixStore,
}

impl KvManager {
    /// Two-tier manager (GPU + host), the pre-hierarchy constructor.
    pub fn new(gpu_layer_blocks: usize, cpu_layer_blocks: usize, block_size: usize, n_layers: usize) -> Self {
        Self::new_tiered(gpu_layer_blocks, cpu_layer_blocks, 0, block_size, n_layers)
    }

    /// Full GPU -> host -> disk hierarchy. `disk_layer_blocks = 0` is the
    /// two-tier configuration.
    pub fn new_tiered(
        gpu_layer_blocks: usize,
        cpu_layer_blocks: usize,
        disk_layer_blocks: usize,
        block_size: usize,
        n_layers: usize,
    ) -> Self {
        KvManager {
            gpu: BlockPool::new(gpu_layer_blocks),
            cpu: BlockPool::new(cpu_layer_blocks),
            disk: BlockPool::new(disk_layer_blocks),
            block_size,
            n_layers,
            tables: HashMap::new(),
            spare_tables: Vec::new(),
            scratch: Vec::new(),
            prefix: PrefixStore::new(),
        }
    }

    pub fn table(&self, req: ReqId) -> Option<&LayerBlockTable> {
        self.tables.get(&req)
    }

    pub fn live_requests(&self) -> usize {
        self.tables.len()
    }

    fn blocks_per_layer(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// GPU layer-blocks a *request-wise* (vLLM) admission of `tokens` needs:
    /// every layer resident.
    pub fn gpu_blocks_full(&self, tokens: usize) -> usize {
        self.blocks_per_layer(tokens) * self.n_layers
    }

    /// GPU layer-blocks a *layer-wise* (LayerKV) admission needs when only
    /// `x` layers are retained.
    pub fn gpu_blocks_layerwise(&self, tokens: usize, x: usize) -> usize {
        self.blocks_per_layer(tokens) * x
    }

    /// vLLM-style admission: all layers on GPU, or nothing.
    pub fn allocate_full(&mut self, req: ReqId, tokens: usize) -> Result<(), KvError> {
        self.allocate_layerwise(req, tokens, self.n_layers)
    }

    /// Non-retained layers the host pool can hold right now (whole layers
    /// of `per_layer` blocks, filled in layer order; the rest overflow to
    /// the disk tier). `CostModel::tiered_admission` computes the same
    /// `min(avail / per_layer, non_retained)` split from the scheduler's
    /// tracked availability, so admission feasibility and the allocator's
    /// actual placement can never diverge.
    pub fn host_fit_layers(&self, per_layer: usize, non_retained: usize) -> usize {
        if per_layer == 0 {
            non_retained
        } else {
            (self.cpu.available() / per_layer).min(non_retained)
        }
    }

    /// LayerKV admission (§3.1.1): retain `x` interleaved layers on GPU,
    /// place the other L-x on the host — spilling whatever the host pool
    /// cannot hold straight to the disk tier. All-or-nothing: when even
    /// host + disk cannot take the non-retained layers, nothing mutates
    /// and the host-side error is returned (with a 0-capacity disk pool
    /// this is exactly the pre-hierarchy behaviour).
    pub fn allocate_layerwise(&mut self, req: ReqId, tokens: usize, x: usize) -> Result<(), KvError> {
        let x = x.min(self.n_layers);
        let per_layer = self.blocks_per_layer(tokens);
        let non_retained = self.n_layers - x;
        let need_gpu = per_layer * x;
        let cpu_layers = self.host_fit_layers(per_layer, non_retained);
        let need_disk = per_layer * (non_retained - cpu_layers);
        if self.gpu.available() < need_gpu {
            return Err(KvError::GpuExhausted);
        }
        if need_disk > 0 && self.disk.available() < need_disk {
            return Err(KvError::CpuExhausted);
        }
        let mut t = self
            .spare_tables
            .pop()
            .unwrap_or_else(|| LayerBlockTable::new(self.n_layers, self.block_size));
        t.reset(self.n_layers, self.block_size, tokens);
        let mut hosted = 0usize;
        if self.n_layers <= 128 {
            // §Perf: bitmask retained-set — O(1) membership, no Vec.
            let mask = LayerBlockTable::interleaved_retained_mask(self.n_layers, x);
            for (i, entry) in t.layers.iter_mut().enumerate() {
                if mask >> i & 1 == 1 {
                    entry.residency = Residency::Gpu;
                    assert!(self.gpu.alloc_into(per_layer, &mut entry.blocks), "checked above");
                } else if hosted < cpu_layers {
                    hosted += 1;
                    entry.residency = Residency::Cpu;
                    assert!(self.cpu.alloc_into(per_layer, &mut entry.blocks), "checked above");
                } else {
                    entry.residency = Residency::Disk;
                    assert!(self.disk.alloc_into(per_layer, &mut entry.blocks), "checked above");
                }
            }
        } else {
            let retained = LayerBlockTable::interleaved_retained(self.n_layers, x);
            for (i, entry) in t.layers.iter_mut().enumerate() {
                if retained.contains(&i) {
                    entry.residency = Residency::Gpu;
                    assert!(self.gpu.alloc_into(per_layer, &mut entry.blocks), "checked above");
                } else if hosted < cpu_layers {
                    hosted += 1;
                    entry.residency = Residency::Cpu;
                    assert!(self.cpu.alloc_into(per_layer, &mut entry.blocks), "checked above");
                } else {
                    entry.residency = Residency::Disk;
                    assert!(self.disk.alloc_into(per_layer, &mut entry.blocks), "checked above");
                }
            }
        }
        t.recount();
        let prev = self.tables.insert(req, t);
        debug_assert!(prev.is_none(), "request {req} allocated twice");
        Ok(())
    }

    /// One more token for `req` (a decode iteration). Grows each layer's
    /// block list across a block boundary, drawing from the pool that
    /// layer currently resides in. On GPU exhaustion nothing is mutated
    /// (caller decides: preempt, or offload someone and retry).
    pub fn append_token(&mut self, req: ReqId) -> Result<(), KvError> {
        // §Perf: single map lookup per call (the per-token hot path), O(1)
        // residency aggregates, and block ids popped straight off the free
        // lists — no intermediate Vec per layer.
        let t = self.tables.get_mut(&req).ok_or(KvError::UnknownRequest)?;
        let old = t.blocks_per_layer(t.tokens);
        let new = t.blocks_per_layer(t.tokens + 1);
        if new > old {
            let gpu_layers = t.n_gpu_layers();
            let cpu_layers = t.n_cpu_layers();
            let disk_layers = t.n_disk_layers();
            if self.gpu.available() < gpu_layers {
                return Err(KvError::GpuExhausted);
            }
            if self.cpu.available() < cpu_layers {
                return Err(KvError::CpuExhausted);
            }
            if self.disk.available() < disk_layers {
                return Err(KvError::CpuExhausted);
            }
            for entry in &mut t.layers {
                let b = match entry.residency {
                    Residency::Gpu => self.gpu.alloc_one().expect("checked"),
                    Residency::Cpu => self.cpu.alloc_one().expect("checked"),
                    Residency::Disk => self.disk.alloc_one().expect("checked"),
                };
                entry.blocks.push(b);
            }
            t.note_block_growth();
        }
        t.tokens += 1;
        Ok(())
    }

    /// `k` more tokens for `req` at once — the macro-stepping engine's
    /// bulk equivalent of `k` successive [`KvManager::append_token`]s.
    /// Each layer grows by the span's block-boundary count in one
    /// `alloc_span` draw from its residency tier's pool (same free-list
    /// discipline as the per-token `alloc_one` path). All-or-nothing: on
    /// any tier shortfall nothing is mutated and the per-token error
    /// surface is returned, so callers can fall back to single-stepping.
    pub fn alloc_span(&mut self, req: ReqId, k: usize) -> Result<(), KvError> {
        if k == 0 {
            return Ok(());
        }
        let t = self.tables.get_mut(&req).ok_or(KvError::UnknownRequest)?;
        let growth = t.blocks_per_layer(t.tokens + k) - t.blocks_per_layer(t.tokens);
        if growth > 0 {
            let gpu_layers = t.n_gpu_layers();
            let cpu_layers = t.n_cpu_layers();
            let disk_layers = t.n_disk_layers();
            if self.gpu.available() < growth * gpu_layers {
                return Err(KvError::GpuExhausted);
            }
            if self.cpu.available() < growth * cpu_layers {
                return Err(KvError::CpuExhausted);
            }
            if self.disk.available() < growth * disk_layers {
                return Err(KvError::CpuExhausted);
            }
            for entry in &mut t.layers {
                let pool = match entry.residency {
                    Residency::Gpu => &mut self.gpu,
                    Residency::Cpu => &mut self.cpu,
                    Residency::Disk => &mut self.disk,
                };
                assert!(pool.alloc_span(growth, &mut entry.blocks), "checked above");
            }
            t.note_span_growth(growth);
        }
        t.tokens += k;
        Ok(())
    }

    /// Move one layer GPU -> host (§3.1.1 proactive offload / OOM relief).
    /// Returns the number of GPU layer-blocks freed. Allocation-free: the
    /// departing ids stage through `scratch` and the layer's Vec is
    /// refilled in place.
    pub fn offload_layer(&mut self, req: ReqId, layer: usize) -> Result<usize, KvError> {
        let t = self.tables.get(&req).ok_or(KvError::UnknownRequest)?;
        let entry = &t.layers[layer];
        if entry.residency != Residency::Gpu {
            return Ok(0);
        }
        let n = entry.blocks.len();
        if self.cpu.available() < n {
            return Err(KvError::CpuExhausted);
        }
        let t = self.tables.get_mut(&req).unwrap();
        let entry = &mut t.layers[layer];
        self.scratch.clear();
        std::mem::swap(&mut self.scratch, &mut entry.blocks); // scratch := GPU ids
        assert!(self.cpu.alloc_into(n, &mut entry.blocks), "checked");
        entry.residency = Residency::Cpu;
        t.note_offloaded(n);
        self.gpu.release(&self.scratch);
        Ok(n)
    }

    /// Move one layer host -> GPU (decode-phase restore). Disk-resident
    /// layers are not touched — they restore via `promote_disk_layer` (or
    /// `unspill_layer` + `onload_layer`), so the caller can charge the
    /// deeper tier's transfer cost explicitly.
    pub fn onload_layer(&mut self, req: ReqId, layer: usize) -> Result<usize, KvError> {
        let t = self.tables.get(&req).ok_or(KvError::UnknownRequest)?;
        let entry = &t.layers[layer];
        if entry.residency != Residency::Cpu {
            return Ok(0);
        }
        let n = entry.blocks.len();
        if self.gpu.available() < n {
            return Err(KvError::GpuExhausted);
        }
        let t = self.tables.get_mut(&req).unwrap();
        let entry = &mut t.layers[layer];
        self.scratch.clear();
        std::mem::swap(&mut self.scratch, &mut entry.blocks); // scratch := CPU ids
        assert!(self.gpu.alloc_into(n, &mut entry.blocks), "checked");
        entry.residency = Residency::Gpu;
        t.note_onloaded(n);
        self.cpu.release(&self.scratch);
        Ok(n)
    }

    /// Move one layer host -> disk (spill under host pressure). Returns
    /// the host layer-blocks freed; `Ok(0)` when the layer is not on the
    /// host. `CpuExhausted` (the host-side hierarchy error) when the disk
    /// tier cannot take it.
    pub fn spill_layer(&mut self, req: ReqId, layer: usize) -> Result<usize, KvError> {
        let t = self.tables.get(&req).ok_or(KvError::UnknownRequest)?;
        let entry = &t.layers[layer];
        if entry.residency != Residency::Cpu {
            return Ok(0);
        }
        let n = entry.blocks.len();
        if self.disk.available() < n {
            return Err(KvError::CpuExhausted);
        }
        let t = self.tables.get_mut(&req).unwrap();
        let entry = &mut t.layers[layer];
        self.scratch.clear();
        std::mem::swap(&mut self.scratch, &mut entry.blocks); // scratch := CPU ids
        assert!(self.disk.alloc_into(n, &mut entry.blocks), "checked");
        entry.residency = Residency::Disk;
        t.note_spilled(n);
        self.cpu.release(&self.scratch);
        Ok(n)
    }

    /// Move one layer disk -> host (shallow restore).
    pub fn unspill_layer(&mut self, req: ReqId, layer: usize) -> Result<usize, KvError> {
        let t = self.tables.get(&req).ok_or(KvError::UnknownRequest)?;
        let entry = &t.layers[layer];
        if entry.residency != Residency::Disk {
            return Ok(0);
        }
        let n = entry.blocks.len();
        if self.cpu.available() < n {
            return Err(KvError::CpuExhausted);
        }
        let t = self.tables.get_mut(&req).unwrap();
        let entry = &mut t.layers[layer];
        self.scratch.clear();
        std::mem::swap(&mut self.scratch, &mut entry.blocks); // scratch := disk ids
        assert!(self.cpu.alloc_into(n, &mut entry.blocks), "checked");
        entry.residency = Residency::Cpu;
        t.note_unspilled(n);
        self.disk.release(&self.scratch);
        Ok(n)
    }

    /// Move one layer disk -> GPU directly (deep restore; physically a
    /// disk read + h2d copy — the caller charges both links' costs).
    pub fn promote_disk_layer(&mut self, req: ReqId, layer: usize) -> Result<usize, KvError> {
        let t = self.tables.get(&req).ok_or(KvError::UnknownRequest)?;
        let entry = &t.layers[layer];
        if entry.residency != Residency::Disk {
            return Ok(0);
        }
        let n = entry.blocks.len();
        if self.gpu.available() < n {
            return Err(KvError::GpuExhausted);
        }
        let t = self.tables.get_mut(&req).unwrap();
        let entry = &mut t.layers[layer];
        self.scratch.clear();
        std::mem::swap(&mut self.scratch, &mut entry.blocks); // scratch := disk ids
        assert!(self.gpu.alloc_into(n, &mut entry.blocks), "checked");
        entry.residency = Residency::Gpu;
        t.note_promoted(n);
        self.disk.release(&self.scratch);
        Ok(n)
    }

    /// Move one layer GPU -> disk directly — the exact inverse of
    /// `promote_disk_layer`. Used only to roll back a promote whose
    /// backend disk read failed (the bytes never actually moved); the
    /// disk blocks the failed promote just freed make it infallible in
    /// that context, but the signature stays fallible for symmetry.
    pub(crate) fn demote_gpu_layer_to_disk(
        &mut self,
        req: ReqId,
        layer: usize,
    ) -> Result<usize, KvError> {
        let t = self.tables.get(&req).ok_or(KvError::UnknownRequest)?;
        let entry = &t.layers[layer];
        if entry.residency != Residency::Gpu {
            return Ok(0);
        }
        let n = entry.blocks.len();
        if self.disk.available() < n {
            return Err(KvError::CpuExhausted);
        }
        let t = self.tables.get_mut(&req).unwrap();
        let entry = &mut t.layers[layer];
        self.scratch.clear();
        std::mem::swap(&mut self.scratch, &mut entry.blocks); // scratch := GPU ids
        assert!(self.disk.alloc_into(n, &mut entry.blocks), "checked");
        entry.residency = Residency::Disk;
        t.note_demoted(n);
        self.gpu.release(&self.scratch);
        Ok(n)
    }

    /// Release everything a request holds (completion or recompute
    /// preemption — serving systems are stateless across requests, §2.2).
    /// The table (and its per-layer Vec capacity) is recycled for the next
    /// admission.
    pub fn release(&mut self, req: ReqId) -> Result<(), KvError> {
        let mut t = self.tables.remove(&req).ok_or(KvError::UnknownRequest)?;
        for entry in &mut t.layers {
            match entry.residency {
                Residency::Gpu => self.gpu.release(&entry.blocks),
                Residency::Cpu => self.cpu.release(&entry.blocks),
                Residency::Disk => self.disk.release(&entry.blocks),
            }
            entry.blocks.clear();
        }
        self.spare_tables.push(t);
        Ok(())
    }

    /// Bytes of one layer of a request's KV (for transfer-time estimates).
    pub fn layer_tokens(&self, req: ReqId) -> usize {
        self.tables.get(&req).map(|t| t.tokens).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop;

    fn mgr(gpu: usize, cpu: usize) -> KvManager {
        KvManager::new(gpu, cpu, 16, 4)
    }

    #[test]
    fn full_allocation_uses_all_layers() {
        let mut m = mgr(64, 64);
        m.allocate_full(0, 33).unwrap(); // 3 blocks/layer * 4 layers
        assert_eq!(m.gpu.used(), 12);
        assert_eq!(m.cpu.used(), 0);
        let t = m.table(0).unwrap();
        assert_eq!(t.n_gpu_layers(), 4);
        t.check().unwrap();
    }

    #[test]
    fn layerwise_allocation_splits_pools() {
        let mut m = mgr(64, 64);
        m.allocate_layerwise(0, 33, 1).unwrap();
        assert_eq!(m.gpu.used(), 3);
        assert_eq!(m.cpu.used(), 9);
        assert_eq!(m.table(0).unwrap().n_gpu_layers(), 1);
    }

    #[test]
    fn layerwise_x0_needs_no_gpu() {
        let mut m = mgr(0, 64);
        m.allocate_layerwise(0, 40, 0).unwrap();
        assert_eq!(m.gpu.used(), 0);
        assert_eq!(m.cpu.used(), 12);
    }

    #[test]
    fn admission_is_all_or_nothing() {
        let mut m = mgr(10, 0);
        // needs 12 gpu blocks -> must fail without touching pools
        assert_eq!(m.allocate_full(0, 33), Err(KvError::GpuExhausted));
        assert_eq!(m.gpu.used(), 0);
        assert!(m.table(0).is_none());
    }

    #[test]
    fn append_token_grows_on_boundary() {
        let mut m = mgr(64, 64);
        m.allocate_full(0, 16).unwrap();
        assert_eq!(m.gpu.used(), 4);
        m.append_token(0).unwrap(); // token 17 -> new block per layer
        assert_eq!(m.gpu.used(), 8);
        for _ in 0..15 {
            m.append_token(0).unwrap(); // up to 32: no growth
        }
        assert_eq!(m.gpu.used(), 8);
        assert_eq!(m.table(0).unwrap().tokens, 32);
        m.table(0).unwrap().check().unwrap();
    }

    #[test]
    fn append_oom_leaves_state_clean() {
        let mut m = mgr(4, 0);
        m.allocate_full(0, 16).unwrap(); // uses all 4
        assert_eq!(m.append_token(0), Err(KvError::GpuExhausted));
        assert_eq!(m.table(0).unwrap().tokens, 16);
        m.table(0).unwrap().check().unwrap();
    }

    #[test]
    fn alloc_span_matches_repeated_append_token() {
        // bulk span growth must land exactly where k single appends land:
        // same per-tier pool usage, same table aggregates — across a
        // mixed-residency (GPU + host + disk) table
        let mut bulk = KvManager::new_tiered(64, 64, 64, 16, 4);
        let mut single = KvManager::new_tiered(64, 64, 64, 16, 4);
        for m in [&mut bulk, &mut single] {
            m.allocate_layerwise(0, 20, 2).unwrap();
            let parked = m.table(0).unwrap().cpu_layers().next().unwrap();
            m.spill_layer(0, parked).unwrap();
        }
        bulk.alloc_span(0, 45).unwrap();
        for _ in 0..45 {
            single.append_token(0).unwrap();
        }
        let (tb, ts) = (bulk.table(0).unwrap(), single.table(0).unwrap());
        assert_eq!(tb.tokens, ts.tokens);
        assert_eq!(
            (tb.gpu_blocks_held(), tb.cpu_blocks_held(), tb.disk_blocks_held()),
            (ts.gpu_blocks_held(), ts.cpu_blocks_held(), ts.disk_blocks_held())
        );
        tb.check().unwrap();
        assert_eq!(bulk.gpu.used(), single.gpu.used());
        assert_eq!(bulk.cpu.used(), single.cpu.used());
        assert_eq!(bulk.disk.used(), single.disk.used());
        // a span inside the current block grows nothing but the count
        let used = bulk.gpu.used();
        bulk.alloc_span(0, 1).unwrap(); // 65 -> 66 tokens, still 5 blocks
        assert_eq!(bulk.gpu.used(), used);
        assert_eq!(bulk.table(0).unwrap().tokens, 66);
        bulk.table(0).unwrap().check().unwrap();
    }

    #[test]
    fn alloc_span_is_all_or_nothing() {
        let mut m = mgr(8, 0); // 4 layers * 16-token blocks, tiny GPU pool
        m.allocate_full(0, 16).unwrap(); // 4 blocks used, 4 free
        // +17 tokens needs 2 more blocks/layer = 8 > 4 free
        assert_eq!(m.alloc_span(0, 17), Err(KvError::GpuExhausted));
        assert_eq!(m.table(0).unwrap().tokens, 16, "failed span must not mutate");
        assert_eq!(m.gpu.used(), 4);
        m.table(0).unwrap().check().unwrap();
        assert_eq!(m.alloc_span(1, 4), Err(KvError::UnknownRequest));
        m.alloc_span(0, 0).unwrap(); // empty span is a no-op
        assert_eq!(m.table(0).unwrap().tokens, 16);
    }

    #[test]
    fn offload_onload_roundtrip() {
        let mut m = mgr(64, 64);
        m.allocate_full(0, 33).unwrap();
        let freed = m.offload_layer(0, 2).unwrap();
        assert_eq!(freed, 3);
        assert_eq!(m.gpu.used(), 9);
        assert_eq!(m.cpu.used(), 3);
        assert_eq!(m.table(0).unwrap().cpu_layers().collect::<Vec<_>>(), vec![2]);
        // idempotent
        assert_eq!(m.offload_layer(0, 2).unwrap(), 0);
        let back = m.onload_layer(0, 2).unwrap();
        assert_eq!(back, 3);
        assert_eq!(m.gpu.used(), 12);
        assert_eq!(m.cpu.used(), 0);
    }

    #[test]
    fn release_returns_everything() {
        let mut m = mgr(64, 64);
        m.allocate_layerwise(0, 40, 2).unwrap();
        m.allocate_layerwise(1, 16, 4).unwrap();
        m.release(0).unwrap();
        m.release(1).unwrap();
        assert_eq!(m.gpu.used(), 0);
        assert_eq!(m.cpu.used(), 0);
        assert_eq!(m.release(0), Err(KvError::UnknownRequest));
    }

    #[test]
    fn spill_unspill_promote_roundtrip() {
        let mut m = KvManager::new_tiered(64, 64, 64, 16, 4);
        m.allocate_full(0, 33).unwrap();
        m.offload_layer(0, 1).unwrap();
        // host -> disk
        assert_eq!(m.spill_layer(0, 1).unwrap(), 3);
        assert_eq!((m.cpu.used(), m.disk.used()), (0, 3));
        assert_eq!(m.table(0).unwrap().disk_layers().collect::<Vec<_>>(), vec![1]);
        m.table(0).unwrap().check().unwrap();
        // idempotent / wrong-tier calls are no-ops
        assert_eq!(m.spill_layer(0, 1).unwrap(), 0);
        assert_eq!(m.spill_layer(0, 0).unwrap(), 0); // GPU layer: not spillable
        assert_eq!(m.onload_layer(0, 1).unwrap(), 0); // disk layer: not onloadable
        // disk -> host -> disk -> GPU
        assert_eq!(m.unspill_layer(0, 1).unwrap(), 3);
        assert_eq!((m.cpu.used(), m.disk.used()), (3, 0));
        assert_eq!(m.spill_layer(0, 1).unwrap(), 3);
        assert_eq!(m.promote_disk_layer(0, 1).unwrap(), 3);
        assert!(m.table(0).unwrap().fully_resident());
        assert_eq!((m.gpu.used(), m.cpu.used(), m.disk.used()), (12, 0, 0));
        m.table(0).unwrap().check().unwrap();
        m.release(0).unwrap();
        assert_eq!((m.gpu.used(), m.cpu.used(), m.disk.used()), (0, 0, 0));
    }

    #[test]
    fn spill_fails_cleanly_without_disk_tier() {
        let mut m = mgr(64, 64); // two-tier: disk capacity 0
        m.allocate_layerwise(0, 33, 2).unwrap();
        let parked = m.table(0).unwrap().cpu_layers().next().unwrap();
        assert_eq!(m.spill_layer(0, parked), Err(KvError::CpuExhausted));
        m.table(0).unwrap().check().unwrap();
        assert_eq!(m.disk.used(), 0);
    }

    #[test]
    fn admission_overflows_host_to_disk() {
        // host holds 5 blocks; x=1 leaves 3 non-retained layers needing
        // 9 blocks -> 1 layer on host (3 blocks), 2 layers on disk.
        let mut m = KvManager::new_tiered(64, 5, 64, 16, 4);
        m.allocate_layerwise(0, 33, 1).unwrap();
        let t = m.table(0).unwrap();
        assert_eq!((t.n_gpu_layers(), t.n_cpu_layers(), t.n_disk_layers()), (1, 1, 2));
        assert_eq!(m.gpu.used(), 3);
        assert_eq!(m.cpu.used(), 3);
        assert_eq!(m.disk.used(), 6);
        t.check().unwrap();
        // without the disk tier the same admission is the two-tier error
        let mut two = mgr(64, 5);
        assert_eq!(two.allocate_layerwise(0, 33, 1), Err(KvError::CpuExhausted));
        assert_eq!((two.gpu.used(), two.cpu.used()), (0, 0));
    }

    #[test]
    fn append_token_grows_disk_resident_layers() {
        // no host pool at all: every non-retained layer lands on disk
        let mut m = KvManager::new_tiered(64, 0, 64, 16, 4);
        m.allocate_layerwise(0, 16, 1).unwrap();
        assert_eq!(m.table(0).unwrap().n_disk_layers(), 3);
        m.append_token(0).unwrap(); // token 17: block boundary, all tiers grow
        assert_eq!(m.gpu.used(), 2);
        assert_eq!(m.cpu.used(), 0);
        assert_eq!(m.disk.used(), 6);
        m.table(0).unwrap().check().unwrap();
    }

    #[test]
    fn prop_no_leaks_under_random_lifecycle() {
        prop(100, |rng| {
            let gpu_total = rng.range_usize(8, 128);
            let cpu_total = rng.range_usize(8, 128);
            // half the cases run the two-tier configuration (disk 0)
            let disk_total =
                if rng.chance(0.5) { 0 } else { rng.range_usize(8, 128) };
            let mut m = KvManager::new_tiered(gpu_total, cpu_total, disk_total, 16, 4);
            let mut live: Vec<ReqId> = Vec::new();
            let mut next_id = 0;
            for _ in 0..200 {
                match rng.range(0, 8) {
                    0 => {
                        let tokens = rng.range_usize(1, 100);
                        let x = rng.range_usize(0, 5);
                        if m.allocate_layerwise(next_id, tokens, x).is_ok() {
                            live.push(next_id);
                        }
                        next_id += 1;
                    }
                    1 => {
                        if !live.is_empty() {
                            let r = live[rng.range_usize(0, live.len())];
                            let _ = m.append_token(r);
                        }
                    }
                    2 => {
                        if !live.is_empty() {
                            let r = live[rng.range_usize(0, live.len())];
                            let _ = m.offload_layer(r, rng.range_usize(0, 4));
                        }
                    }
                    3 => {
                        if !live.is_empty() {
                            let r = live[rng.range_usize(0, live.len())];
                            let _ = m.onload_layer(r, rng.range_usize(0, 4));
                        }
                    }
                    4 => {
                        if !live.is_empty() {
                            let r = live[rng.range_usize(0, live.len())];
                            let _ = m.spill_layer(r, rng.range_usize(0, 4));
                        }
                    }
                    5 => {
                        if !live.is_empty() {
                            let r = live[rng.range_usize(0, live.len())];
                            let _ = m.unspill_layer(r, rng.range_usize(0, 4));
                        }
                    }
                    6 => {
                        if !live.is_empty() {
                            let r = live[rng.range_usize(0, live.len())];
                            let _ = m.promote_disk_layer(r, rng.range_usize(0, 4));
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let i = rng.range_usize(0, live.len());
                            let r = live.swap_remove(i);
                            m.release(r).unwrap();
                        }
                    }
                }
                // conservation after every step: each tier's pool
                // accounting matches the sum over live tables, and
                // held + free == capacity per tier
                let gpu_held: usize =
                    live.iter().map(|&r| m.table(r).unwrap().gpu_blocks_held()).sum();
                let cpu_held: usize =
                    live.iter().map(|&r| m.table(r).unwrap().cpu_blocks_held()).sum();
                let disk_held: usize =
                    live.iter().map(|&r| m.table(r).unwrap().disk_blocks_held()).sum();
                assert_eq!(m.gpu.used(), gpu_held);
                assert_eq!(m.cpu.used(), cpu_held);
                assert_eq!(m.disk.used(), disk_held);
                assert_eq!(m.gpu.available() + gpu_held, gpu_total);
                assert_eq!(m.cpu.available() + cpu_held, cpu_total);
                assert_eq!(m.disk.available() + disk_held, disk_total);
                m.gpu.check().unwrap();
                m.cpu.check().unwrap();
                m.disk.check().unwrap();
                for &r in &live {
                    m.table(r).unwrap().check().unwrap();
                }
                if disk_total == 0 {
                    assert!(live
                        .iter()
                        .all(|&r| m.table(r).unwrap().n_disk_layers() == 0));
                }
            }
            // drain
            for r in live {
                m.release(r).unwrap();
            }
            assert_eq!(m.gpu.used(), 0);
            assert_eq!(m.cpu.used(), 0);
            assert_eq!(m.disk.used(), 0);
        });
    }
}
