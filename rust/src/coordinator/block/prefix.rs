//! Cross-request prefix cache: content-addressed KV retained *after*
//! request completion, tiered over the same GPU -> host -> disk pools as
//! live tables.
//!
//! Entries are keyed by the trace's 48-bit prefix hash ([`PrefixKey`] in
//! `workload`) at block granularity: an entry retains `tokens`
//! (block-aligned) x `n_layers` layer-blocks, all on one tier. Admission
//! of a request whose prompt opens with a cached prefix skips recompute
//! of the matched tokens — GPU-resident entries are free, host/disk
//! entries charge the onload / disk-restore transfer through the cost
//! model (the engine does the charging; this module only reports the
//! tier the hit was served from).
//!
//! Retention is tier-aware and deterministic:
//!
//! * publish prefers GPU only while the pool keeps >= half its capacity
//!   free after the insert (live decode always wins the GPU), then host,
//!   then disk;
//! * under pressure the engine demotes prefix blocks *first* — cache
//!   entries are strictly lower-value than live requests, so
//!   [`KvManager::prefix_demote_gpu`] / [`KvManager::prefix_demote_host`]
//!   run before any live-table offload/spill/preemption;
//! * eviction is LRU with a total order (`(last_use, hash)`), so the
//!   `HashMap`'s iteration order can never leak into behaviour;
//! * leased entries (a running request is counting on the hit) are
//!   never demoted or evicted.
//!
//! With caching off the engine never calls into this module, the store
//! stays empty, and every pool observable is bit-identical to the
//! pre-cache engine — the frozen reference oracle pins that.

use std::collections::HashMap;

use super::allocator::BlockId;
use super::table::Residency;
use super::KvManager;

/// One retained prefix: `tokens` is block-aligned, `blocks` holds
/// `tokens / block_size * n_layers` ids, all resident on `tier`.
#[derive(Debug, Clone)]
pub struct PrefixEntry {
    pub hash: u64,
    pub tokens: usize,
    pub tier: Residency,
    pub blocks: Vec<BlockId>,
    /// Running requests currently served by this entry; leased entries
    /// are pinned (never demoted or evicted).
    pub leases: usize,
    pub hits: u64,
    pub last_use: u64,
}

/// The content-addressed store: hash -> entry plus a logical clock for
/// LRU. Owned by [`KvManager`]; all mutation goes through the
/// `prefix_*` methods so block conservation stays in one place.
#[derive(Debug, Default)]
pub struct PrefixStore {
    pub(crate) entries: HashMap<u64, PrefixEntry>,
    seq: u64,
}

impl PrefixStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn next_seq(&mut self) -> u64 {
        self.seq += 1;
        self.seq
    }

    /// LRU victim among unleased entries matching `pred`: smallest
    /// `(last_use, hash)` — a total order, deterministic regardless of
    /// map iteration order.
    fn victim(&self, pred: impl Fn(&PrefixEntry) -> bool) -> Option<u64> {
        self.entries
            .values()
            .filter(|e| e.leases == 0 && pred(e))
            .min_by_key(|e| (e.last_use, e.hash))
            .map(|e| e.hash)
    }
}

/// A served cache hit. `tokens` is the matched (block-aligned) span the
/// request skips recomputing; `tier` is where the entry resided *before*
/// any promote-on-hit, so the engine charges the right transfer link.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixHit {
    pub tokens: usize,
    pub tier: Residency,
    pub blocks: usize,
    /// Entry was moved host -> GPU as part of serving the hit.
    pub promoted: bool,
}

/// Outcome of a publish attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixPublish {
    pub inserted: bool,
    /// Tier the new entry landed on (`None` when not inserted).
    pub tier: Option<Residency>,
    /// Entries evicted to make room.
    pub evicted: usize,
}

/// One demotion step, for the engine's transition log. `to == None`
/// means the entry was evicted outright (no tier could take it).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrefixMove {
    pub from: Residency,
    pub to: Option<Residency>,
    pub blocks: usize,
}

impl KvManager {
    /// Non-mutating lookup: matched tokens + current tier for `hash`.
    /// The scheduler uses this to solve admission for the un-cached
    /// suffix without perturbing LRU state.
    pub fn prefix_probe(&self, hash: u64) -> Option<(usize, Residency)> {
        self.prefix.entries.get(&hash).map(|e| (e.tokens, e.tier))
    }

    /// Live entries in the store.
    pub fn prefix_entries(&self) -> usize {
        self.prefix.entries.len()
    }

    /// Sum of lease counts across entries.
    pub fn prefix_leases(&self) -> usize {
        self.prefix.entries.values().map(|e| e.leases).sum()
    }

    /// Layer-blocks the cache holds on `tier`.
    pub fn prefix_blocks_on(&self, tier: Residency) -> usize {
        self.prefix
            .entries
            .values()
            .filter(|e| e.tier == tier)
            .map(|e| e.blocks.len())
            .sum()
    }

    /// Serve a hit for `hash` capped at `want_tokens` (the engine passes
    /// `min(key.len, prefill_len - 1)` so at least one token is always
    /// computed). Returns `None` on a miss or when the match rounds down
    /// to zero blocks. On a hit the entry is leased (pinned until
    /// [`KvManager::prefix_release`]) and, when the GPU has room for its
    /// blocks, promoted GPU-ward so decode-adjacent reuse is free.
    pub fn prefix_acquire(&mut self, hash: u64, want_tokens: usize) -> Option<PrefixHit> {
        let want_aligned = want_tokens / self.block_size * self.block_size;
        let seq = self.prefix.next_seq();
        let e = self.prefix.entries.get_mut(&hash)?;
        let matched = e.tokens.min(want_aligned);
        if matched == 0 {
            return None;
        }
        e.leases += 1;
        e.hits += 1;
        e.last_use = seq;
        let tier = e.tier;
        let n = e.blocks.len();
        let mut promoted = false;
        if tier != Residency::Gpu && self.gpu.available() >= n {
            self.scratch.clear();
            std::mem::swap(&mut self.scratch, &mut e.blocks);
            assert!(self.gpu.alloc_into(n, &mut e.blocks), "checked above");
            e.tier = Residency::Gpu;
            match tier {
                Residency::Cpu => self.cpu.release(&self.scratch),
                Residency::Disk => self.disk.release(&self.scratch),
                Residency::Gpu => unreachable!(),
            }
            promoted = true;
        }
        Some(PrefixHit { tokens: matched, tier, blocks: n, promoted })
    }

    /// Drop one lease on `hash` (request completed or was preempted).
    /// Unknown hashes are ignored — the entry may have been cleared by a
    /// drain while the request ran.
    pub fn prefix_release(&mut self, hash: u64) {
        if let Some(e) = self.prefix.entries.get_mut(&hash) {
            e.leases = e.leases.saturating_sub(1);
        }
    }

    /// Publish `tokens` of context under `hash` (called at request
    /// completion with its final context length). Tokens floor to block
    /// granularity; re-publishing an existing hash only refreshes its
    /// LRU stamp. Placement: GPU while it keeps >= half the pool free,
    /// else host, else disk, evicting LRU unleased entries until a
    /// host-side tier fits (never evicting to force a GPU landing).
    pub fn prefix_publish(&mut self, hash: u64, tokens: usize) -> PrefixPublish {
        let seq = self.prefix.next_seq();
        if let Some(e) = self.prefix.entries.get_mut(&hash) {
            e.last_use = seq;
            return PrefixPublish { inserted: false, tier: None, evicted: 0 };
        }
        let tokens = tokens / self.block_size * self.block_size;
        let need = tokens / self.block_size * self.n_layers;
        if need == 0 {
            return PrefixPublish { inserted: false, tier: None, evicted: 0 };
        }
        let mut evicted = 0usize;
        let tier = loop {
            if self.gpu.available() >= need
                && self.gpu.available() - need >= self.gpu.total() / 2
            {
                break Residency::Gpu;
            }
            if self.cpu.available() >= need {
                break Residency::Cpu;
            }
            if self.disk.available() >= need {
                break Residency::Disk;
            }
            match self.prefix.victim(|_| true) {
                Some(v) => {
                    self.prefix_evict(v);
                    evicted += 1;
                }
                None => return PrefixPublish { inserted: false, tier: None, evicted },
            }
        };
        let pool = match tier {
            Residency::Gpu => &mut self.gpu,
            Residency::Cpu => &mut self.cpu,
            Residency::Disk => &mut self.disk,
        };
        let mut blocks = Vec::with_capacity(need);
        assert!(pool.alloc_into(need, &mut blocks), "checked above");
        self.prefix.entries.insert(
            hash,
            PrefixEntry { hash, tokens, tier, blocks, leases: 0, hits: 0, last_use: seq },
        );
        PrefixPublish { inserted: true, tier: Some(tier), evicted }
    }

    /// Remove `hash` outright, returning its blocks to its tier's pool.
    fn prefix_evict(&mut self, hash: u64) {
        let e = self.prefix.entries.remove(&hash).expect("victim exists");
        match e.tier {
            Residency::Gpu => self.gpu.release(&e.blocks),
            Residency::Cpu => self.cpu.release(&e.blocks),
            Residency::Disk => self.disk.release(&e.blocks),
        }
    }

    /// Demote GPU-resident cache entries (LRU first, leased pinned)
    /// until at least `need` GPU layer-blocks are freed or none remain.
    /// Each entry goes host-ward — host if it fits, else disk, else out
    /// of the cache entirely. Returns GPU blocks freed; every step is
    /// appended to `moves` for the engine's transition log.
    pub fn prefix_demote_gpu(&mut self, need: usize, moves: &mut Vec<PrefixMove>) -> usize {
        let mut freed = 0usize;
        while freed < need {
            let Some(v) = self.prefix.victim(|e| e.tier == Residency::Gpu) else {
                break;
            };
            let n = self.prefix.entries[&v].blocks.len();
            let to = if self.cpu.available() >= n {
                Some(Residency::Cpu)
            } else if self.disk.available() >= n {
                Some(Residency::Disk)
            } else {
                None
            };
            match to {
                Some(t) => self.prefix_move(v, t),
                None => self.prefix_evict(v),
            }
            moves.push(PrefixMove { from: Residency::Gpu, to, blocks: n });
            freed += n;
        }
        freed
    }

    /// Demote host-resident cache entries (spill to disk, else evict)
    /// until `need` host layer-blocks are freed or none remain.
    pub fn prefix_demote_host(&mut self, need: usize, moves: &mut Vec<PrefixMove>) -> usize {
        let mut freed = 0usize;
        while freed < need {
            let Some(v) = self.prefix.victim(|e| e.tier == Residency::Cpu) else {
                break;
            };
            let n = self.prefix.entries[&v].blocks.len();
            let to = if self.disk.available() >= n {
                Some(Residency::Disk)
            } else {
                None
            };
            match to {
                Some(t) => self.prefix_move(v, t),
                None => self.prefix_evict(v),
            }
            moves.push(PrefixMove { from: Residency::Cpu, to, blocks: n });
            freed += n;
        }
        freed
    }

    /// Move an entry's blocks to `to`'s pool (caller checked it fits).
    fn prefix_move(&mut self, hash: u64, to: Residency) {
        let e = self.prefix.entries.get_mut(&hash).expect("entry exists");
        let n = e.blocks.len();
        let from = e.tier;
        debug_assert_ne!(from, to);
        self.scratch.clear();
        std::mem::swap(&mut self.scratch, &mut e.blocks);
        let pool = match to {
            Residency::Gpu => &mut self.gpu,
            Residency::Cpu => &mut self.cpu,
            Residency::Disk => &mut self.disk,
        };
        assert!(pool.alloc_into(n, &mut e.blocks), "caller checked fit");
        e.tier = to;
        match from {
            Residency::Gpu => self.gpu.release(&self.scratch),
            Residency::Cpu => self.cpu.release(&self.scratch),
            Residency::Disk => self.disk.release(&self.scratch),
        }
    }

    /// Drop every entry (leased or not), returning all blocks. A crash
    /// drain physically loses the memory the cache modelled, so the
    /// store must not survive it. Returns entries cleared.
    pub fn prefix_clear(&mut self) -> usize {
        let hashes: Vec<u64> = self.prefix.entries.keys().copied().collect();
        let n = hashes.len();
        for h in hashes {
            let e = self.prefix.entries.remove(&h).expect("listed");
            match e.tier {
                Residency::Gpu => self.gpu.release(&e.blocks),
                Residency::Cpu => self.cpu.release(&e.blocks),
                Residency::Disk => self.disk.release(&e.blocks),
            }
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mgr(gpu: usize, cpu: usize, disk: usize) -> KvManager {
        KvManager::new_tiered(gpu, cpu, disk, 16, 4)
    }

    #[test]
    fn publish_probe_acquire_roundtrip() {
        let mut m = mgr(64, 64, 64);
        // 33 tokens floor to 32 -> 2 blocks/layer x 4 layers = 8 blocks
        let out = m.prefix_publish(7, 33);
        assert_eq!(out, PrefixPublish { inserted: true, tier: Some(Residency::Gpu), evicted: 0 });
        assert_eq!(m.gpu.used(), 8);
        assert_eq!(m.prefix_probe(7), Some((32, Residency::Gpu)));
        assert_eq!(m.prefix_probe(8), None);
        // the hit is capped at the caller's want (block-aligned)
        let hit = m.prefix_acquire(7, 100).unwrap();
        assert_eq!(hit, PrefixHit { tokens: 32, tier: Residency::Gpu, blocks: 8, promoted: false });
        assert_eq!(m.prefix_leases(), 1);
        let hit = m.prefix_acquire(7, 20).unwrap();
        assert_eq!(hit.tokens, 16);
        m.prefix_release(7);
        m.prefix_release(7);
        assert_eq!(m.prefix_leases(), 0);
        // a want below one block is a miss, not a zero-token hit
        assert!(m.prefix_acquire(7, 15).is_none());
        assert_eq!(m.prefix_leases(), 0);
    }

    #[test]
    fn publish_respects_gpu_headroom_watermark() {
        // GPU total 16: an 8-block insert would leave 8 = total/2 free
        // (allowed); first fill 1 block so the insert would leave 7 < 8
        // and the entry must land on the host instead.
        let mut m = mgr(16, 64, 0);
        m.allocate_layerwise(0, 16, 1).unwrap(); // 1 GPU block + 3 CPU
        let out = m.prefix_publish(1, 32);
        assert_eq!(out.tier, Some(Residency::Cpu));
        assert_eq!(m.prefix_blocks_on(Residency::Cpu), 8);
        // re-publish refreshes, never re-inserts
        let again = m.prefix_publish(1, 32);
        assert!(!again.inserted);
        assert_eq!(m.prefix_entries(), 1);
    }

    #[test]
    fn publish_evicts_lru_unleased_when_full() {
        // host 8 blocks, no disk: two 4-block entries fill it; a third
        // publish must evict the LRU one (hash 1), not the leased or
        // recently-used one.
        let mut m = mgr(0, 8, 0);
        assert_eq!(m.prefix_publish(1, 16).tier, Some(Residency::Cpu));
        assert_eq!(m.prefix_publish(2, 16).tier, Some(Residency::Cpu));
        // touching 1 makes 2 the LRU entry
        m.prefix_acquire(1, 16).unwrap();
        m.prefix_release(1);
        let out = m.prefix_publish(3, 16);
        assert_eq!(out, PrefixPublish { inserted: true, tier: Some(Residency::Cpu), evicted: 1 });
        assert!(m.prefix_probe(2).is_none(), "hash 2 was LRU");
        assert!(m.prefix_probe(1).is_some());
        // lease everything: publish must fail rather than evict pinned entries
        m.prefix_acquire(1, 16).unwrap();
        m.prefix_acquire(3, 16).unwrap();
        let out = m.prefix_publish(4, 16);
        assert_eq!(out, PrefixPublish { inserted: false, tier: None, evicted: 0 });
        assert_eq!(m.prefix_entries(), 2);
    }

    #[test]
    fn acquire_promotes_host_entry_when_gpu_has_room() {
        let mut m = mgr(16, 64, 0);
        m.allocate_layerwise(0, 16, 1).unwrap(); // keeps GPU below watermark
        assert_eq!(m.prefix_publish(9, 32).tier, Some(Residency::Cpu));
        m.release(0).unwrap();
        let hit = m.prefix_acquire(9, 32).unwrap();
        assert_eq!(hit.tier, Residency::Cpu, "tier reports the pre-promote residency");
        assert!(hit.promoted);
        assert_eq!(m.prefix_probe(9), Some((32, Residency::Gpu)));
        assert_eq!(m.prefix_blocks_on(Residency::Gpu), 8);
        assert_eq!(m.cpu.used(), 0);
    }

    #[test]
    fn demote_gpu_walks_host_then_disk_then_evicts() {
        let mut m = mgr(64, 4, 4);
        assert_eq!(m.prefix_publish(1, 16).tier, Some(Residency::Gpu));
        assert_eq!(m.prefix_publish(2, 16).tier, Some(Residency::Gpu));
        assert_eq!(m.prefix_publish(3, 16).tier, Some(Residency::Gpu));
        let mut moves = Vec::new();
        let freed = m.prefix_demote_gpu(12, &mut moves);
        assert_eq!(freed, 12);
        // LRU order 1, 2, 3: host takes the first, disk the second, the
        // third has nowhere to go and falls out of the cache
        assert_eq!(
            moves,
            vec![
                PrefixMove { from: Residency::Gpu, to: Some(Residency::Cpu), blocks: 4 },
                PrefixMove { from: Residency::Gpu, to: Some(Residency::Disk), blocks: 4 },
                PrefixMove { from: Residency::Gpu, to: None, blocks: 4 },
            ]
        );
        assert_eq!(m.gpu.used(), 0);
        assert_eq!(m.prefix_entries(), 2);
        // host pressure: the host entry spills to disk... which is full,
        // so it evicts
        let mut moves = Vec::new();
        let freed = m.prefix_demote_host(4, &mut moves);
        assert_eq!(freed, 4);
        assert_eq!(moves, vec![PrefixMove { from: Residency::Cpu, to: None, blocks: 4 }]);
        assert_eq!(m.prefix_entries(), 1);
    }

    #[test]
    fn clear_returns_every_block() {
        let mut m = mgr(64, 64, 64);
        m.prefix_publish(1, 32);
        m.prefix_publish(2, 64);
        m.prefix_acquire(1, 32).unwrap(); // leased entries are cleared too
        assert_eq!(m.prefix_clear(), 2);
        assert_eq!(m.gpu.used(), 0);
        assert_eq!(m.cpu.used(), 0);
        assert_eq!(m.disk.used(), 0);
        assert_eq!(m.prefix_entries(), 0);
        // releasing a lease on a cleared hash is a harmless no-op
        m.prefix_release(1);
    }

    #[test]
    fn lru_is_deterministic_under_hash_ties() {
        // entries published in one batch share last_use only if seq were
        // reused — it is not; but two never-touched entries order by
        // (last_use, hash), which is total. Evicting twice must pick the
        // two oldest in publish order regardless of map iteration.
        let mut m = mgr(0, 12, 0);
        for h in [5u64, 3, 9] {
            assert!(m.prefix_publish(h, 16).inserted);
        }
        let out = m.prefix_publish(11, 32); // needs 8 -> evicts 5 then 3
        assert_eq!(out.evicted, 2);
        assert!(m.prefix_probe(5).is_none());
        assert!(m.prefix_probe(3).is_none());
        assert!(m.prefix_probe(9).is_some());
    }
}
