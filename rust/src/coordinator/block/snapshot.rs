//! Serializable per-request KV snapshots — the unit of stateful failover.
//!
//! A [`RequestSnapshot`] captures everything `Engine::adopt` needs to
//! resume a drained request on another replica without re-prefilling the
//! committed span: identity and lengths from the trace, scheduler
//! progress (generated count, timing fields, the predictor's bucket —
//! carried so the adopting engine needs no predictor), the layer-wise
//! residency map at drain time, the durable checkpoint watermark, and —
//! for real (token-producing) backends — the actual token streams.
//!
//! The JSON codec is hand-rolled over `util::Json` (no serde offline) so
//! snapshots can cross process boundaries (server workers, future
//! scale-down tooling); `parse` rejects malformed input instead of
//! defaulting fields.

use crate::coordinator::block::Residency;
use crate::util::Json;
use crate::workload::PrefixKey;

/// Everything needed to resume one drained request elsewhere.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestSnapshot {
    /// Trace-global request id (the cluster's identity, not the engine's
    /// dense local id).
    pub id: usize,
    pub arrival: f64,
    pub prompt_len: usize,
    pub output_len: usize,
    pub prefix: PrefixKey,
    /// Tokens committed at drain time (scheduler progress).
    pub generated: usize,
    /// Tokens covered by the last durable disk checkpoint (0 = none; the
    /// adopter can resume at most this far without recompute).
    pub checkpointed: usize,
    pub prefill_start: Option<f64>,
    pub first_token: Option<f64>,
    pub preemptions: usize,
    /// The predictor's output-length bucket, carried verbatim so adoption
    /// is predictor-free and bit-stable across replicas.
    pub predicted: (usize, usize),
    /// Layer-wise residency at drain time (empty when the request was
    /// still queued — nothing was allocated).
    pub layers: Vec<Residency>,
    /// Real-backend token streams `(prompt, out)`; `None` for modeled
    /// backends (no actual tokens exist).
    pub tokens: Option<(Vec<i32>, Vec<i32>)>,
}

impl RequestSnapshot {
    /// Tokens a resumed decode can keep without recompute: the committed
    /// span up to the durable checkpoint.
    pub fn resumable(&self) -> usize {
        self.generated.min(self.checkpointed)
    }

    /// Serialize to a JSON string (stable key order via `Json::dump`).
    pub fn render(&self) -> String {
        self.to_json().dump()
    }

    fn to_json(&self) -> Json {
        use std::collections::BTreeMap;
        let mut m = BTreeMap::new();
        m.insert("id".into(), Json::Num(self.id as f64));
        m.insert("arrival".into(), Json::Num(self.arrival));
        m.insert("prompt_len".into(), Json::Num(self.prompt_len as f64));
        m.insert("output_len".into(), Json::Num(self.output_len as f64));
        m.insert(
            "prefix".into(),
            Json::Arr(vec![
                Json::Num(self.prefix.hash as f64),
                Json::Num(self.prefix.len as f64),
                Json::Num(self.prefix.publish as f64),
            ]),
        );
        m.insert("generated".into(), Json::Num(self.generated as f64));
        m.insert("checkpointed".into(), Json::Num(self.checkpointed as f64));
        m.insert("prefill_start".into(), opt_num(self.prefill_start));
        m.insert("first_token".into(), opt_num(self.first_token));
        m.insert("preemptions".into(), Json::Num(self.preemptions as f64));
        m.insert(
            "predicted".into(),
            Json::Arr(vec![
                Json::Num(self.predicted.0 as f64),
                Json::Num(self.predicted.1 as f64),
            ]),
        );
        m.insert(
            "layers".into(),
            Json::Arr(
                self.layers
                    .iter()
                    .map(|r| Json::Num(r.tier_index() as f64))
                    .collect(),
            ),
        );
        m.insert(
            "tokens".into(),
            match &self.tokens {
                None => Json::Null,
                Some((prompt, out)) => Json::Arr(vec![
                    Json::Arr(prompt.iter().map(|&t| Json::Num(t as f64)).collect()),
                    Json::Arr(out.iter().map(|&t| Json::Num(t as f64)).collect()),
                ]),
            },
        );
        Json::Obj(m)
    }

    /// Parse a snapshot back from its `render` output.
    pub fn parse(s: &str) -> anyhow::Result<RequestSnapshot> {
        let j = Json::parse(s).map_err(|e| anyhow::anyhow!("snapshot: {e}"))?;
        Self::from_json(&j)
    }

    fn from_json(j: &Json) -> anyhow::Result<RequestSnapshot> {
        let num = |k: &str| -> anyhow::Result<usize> {
            j.req(k)?.as_usize().ok_or_else(|| anyhow::anyhow!("snapshot key '{k}' not a number"))
        };
        let f = |k: &str| -> anyhow::Result<f64> {
            j.req(k)?.as_f64().ok_or_else(|| anyhow::anyhow!("snapshot key '{k}' not a number"))
        };
        let opt = |k: &str| -> anyhow::Result<Option<f64>> {
            match j.req(k)? {
                Json::Null => Ok(None),
                v => v
                    .as_f64()
                    .map(Some)
                    .ok_or_else(|| anyhow::anyhow!("snapshot key '{k}' not a number")),
            }
        };
        let pair = j
            .req("predicted")?
            .as_arr()
            .filter(|a| a.len() == 2)
            .ok_or_else(|| anyhow::anyhow!("snapshot 'predicted' not a pair"))?;
        let prefix = j
            .req("prefix")?
            .as_arr()
            .filter(|a| a.len() == 3)
            .ok_or_else(|| anyhow::anyhow!("snapshot 'prefix' not a triple"))?;
        let layers = j
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("snapshot 'layers' not an array"))?
            .iter()
            .map(|v| match v.as_usize() {
                Some(0) => Ok(Residency::Gpu),
                Some(1) => Ok(Residency::Cpu),
                Some(2) => Ok(Residency::Disk),
                _ => Err(anyhow::anyhow!("snapshot layer tier out of range")),
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let tokens = match j.req("tokens")? {
            Json::Null => None,
            v => {
                let streams = v
                    .as_arr()
                    .filter(|a| a.len() == 2)
                    .ok_or_else(|| anyhow::anyhow!("snapshot 'tokens' not a stream pair"))?;
                let decode = |s: &Json| -> anyhow::Result<Vec<i32>> {
                    s.as_arr()
                        .ok_or_else(|| anyhow::anyhow!("snapshot token stream not an array"))?
                        .iter()
                        .map(|t| {
                            t.as_f64()
                                .map(|x| x as i32)
                                .ok_or_else(|| anyhow::anyhow!("snapshot token not a number"))
                        })
                        .collect()
                };
                Some((decode(&streams[0])?, decode(&streams[1])?))
            }
        };
        Ok(RequestSnapshot {
            id: num("id")?,
            arrival: f("arrival")?,
            prompt_len: num("prompt_len")?,
            output_len: num("output_len")?,
            prefix: PrefixKey {
                hash: prefix[0].as_f64().unwrap_or(0.0) as u64,
                len: prefix[1].as_usize().unwrap_or(0),
                publish: prefix[2].as_f64().unwrap_or(0.0) as u64,
            },
            generated: num("generated")?,
            checkpointed: num("checkpointed")?,
            prefill_start: opt("prefill_start")?,
            first_token: opt("first_token")?,
            preemptions: num("preemptions")?,
            predicted: (
                pair[0].as_usize().unwrap_or(0),
                pair[1].as_usize().unwrap_or(0),
            ),
            layers,
            tokens,
        })
    }
}

fn opt_num(v: Option<f64>) -> Json {
    match v {
        Some(x) => Json::Num(x),
        None => Json::Null,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap() -> RequestSnapshot {
        RequestSnapshot {
            id: 17,
            arrival: 3.25,
            prompt_len: 2048,
            output_len: 256,
            prefix: PrefixKey { hash: 0xABCD, len: 512, publish: 0x1234 },
            generated: 120,
            checkpointed: 96,
            prefill_start: Some(4.5),
            first_token: Some(5.125),
            preemptions: 1,
            predicted: (64, 256),
            layers: vec![Residency::Gpu, Residency::Cpu, Residency::Disk, Residency::Gpu],
            tokens: Some((vec![1, 2, 3], vec![7, 8])),
        }
    }

    #[test]
    fn roundtrips_bit_identically() {
        let s = snap();
        let back = RequestSnapshot::parse(&s.render()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.arrival.to_bits(), s.arrival.to_bits());
        assert_eq!(back.resumable(), 96);
    }

    #[test]
    fn roundtrips_queued_request_without_state() {
        let s = RequestSnapshot {
            generated: 0,
            checkpointed: 0,
            prefill_start: None,
            first_token: None,
            layers: Vec::new(),
            tokens: None,
            ..snap()
        };
        let back = RequestSnapshot::parse(&s.render()).unwrap();
        assert_eq!(back, s);
        assert_eq!(back.resumable(), 0);
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(RequestSnapshot::parse("{").is_err());
        assert!(RequestSnapshot::parse("{}").is_err());
        // a layer tier out of range must not default to something valid
        let mut s = snap().render();
        s = s.replace("\"layers\":[0,1,2,0]", "\"layers\":[0,9,2,0]");
        assert!(RequestSnapshot::parse(&s).is_err());
    }

    #[test]
    fn resumable_clamps_to_generated() {
        let s = RequestSnapshot { generated: 10, checkpointed: 50, ..snap() };
        assert_eq!(s.resumable(), 10);
    }
}
