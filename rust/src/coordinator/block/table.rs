//! Layer-wise block tables (§3.1.2): per request, per layer, the ordered
//! list of physical blocks holding its KV and *where each layer lives*
//! (GPU or host). This is the paper's extension of vLLM's block table —
//! "we add layer-wise information to each block, indicating the indices of
//! the layers where the KV cache is retained on the GPU and the indices of
//! the layers stored on the CPU."

use super::allocator::BlockId;

/// Which memory holds a layer's blocks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    Gpu,
    Cpu,
}

/// One layer's slice of a request's KV cache.
#[derive(Debug, Clone)]
pub struct LayerEntry {
    pub residency: Residency,
    /// Physical blocks, in token order. Ids are in the GPU pool's space
    /// when residency == Gpu, the CPU pool's space otherwise.
    pub blocks: Vec<BlockId>,
}

/// Per-request layer-wise block table.
#[derive(Debug, Clone)]
pub struct LayerBlockTable {
    pub layers: Vec<LayerEntry>,
    /// Tokens currently stored (same for every layer).
    pub tokens: usize,
    pub block_size: usize,
}

impl LayerBlockTable {
    pub fn new(n_layers: usize, block_size: usize) -> Self {
        LayerBlockTable {
            layers: (0..n_layers)
                .map(|_| LayerEntry { residency: Residency::Gpu, blocks: Vec::new() })
                .collect(),
            tokens: 0,
            block_size,
        }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Blocks needed per layer for `tokens` tokens.
    pub fn blocks_per_layer(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Layers currently resident on GPU.
    pub fn gpu_layers(&self) -> Vec<usize> {
        (0..self.layers.len())
            .filter(|&i| self.layers[i].residency == Residency::Gpu)
            .collect()
    }

    pub fn cpu_layers(&self) -> Vec<usize> {
        (0..self.layers.len())
            .filter(|&i| self.layers[i].residency == Residency::Cpu)
            .collect()
    }

    pub fn n_gpu_layers(&self) -> usize {
        self.layers.iter().filter(|l| l.residency == Residency::Gpu).count()
    }

    /// Total GPU layer-blocks held.
    pub fn gpu_blocks_held(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| l.residency == Residency::Gpu)
            .map(|l| l.blocks.len())
            .sum()
    }

    pub fn cpu_blocks_held(&self) -> usize {
        self.layers
            .iter()
            .filter(|l| l.residency == Residency::Cpu)
            .map(|l| l.blocks.len())
            .sum()
    }

    /// §3.1.2 interleaving: which layer indices to *retain on GPU* when
    /// keeping `x` of `l` layers. The retained set is spread evenly so each
    /// offloaded layer's h2d can overlap the compute of the retained layer
    /// before it (the paper's 8-layer example keeps 1,3,5,7 and offloads
    /// 0,2,4,6).
    pub fn interleaved_retained(l: usize, x: usize) -> Vec<usize> {
        if x == 0 {
            return Vec::new();
        }
        if x >= l {
            return (0..l).collect();
        }
        // Evenly spaced, biased to the *later* congruence class like the
        // paper's example (offload even indices, retain odd).
        let mut out: Vec<usize> = (0..x)
            .map(|i| ((2 * i + 1) * l / (2 * x)).min(l - 1))
            .collect();
        out.dedup();
        // rare collisions at tiny l: fill greedily
        let mut next = 0;
        while out.len() < x {
            if !out.contains(&next) {
                out.push(next);
            }
            next += 1;
        }
        out.sort_unstable();
        out
    }

    /// Validate internal consistency (used by property tests).
    pub fn check(&self) -> Result<(), String> {
        let want = self.blocks_per_layer(self.tokens);
        for (i, l) in self.layers.iter().enumerate() {
            if l.blocks.len() != want && self.tokens > 0 {
                return Err(format!(
                    "layer {i}: {} blocks for {} tokens (want {want})",
                    l.blocks.len(),
                    self.tokens
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_8_layers_keep_4() {
        // §3.1.2: 8-layer model keeping 4 on GPU retains 1,3,5,7
        assert_eq!(LayerBlockTable::interleaved_retained(8, 4), vec![1, 3, 5, 7]);
    }

    #[test]
    fn retained_edge_cases() {
        assert!(LayerBlockTable::interleaved_retained(8, 0).is_empty());
        assert_eq!(LayerBlockTable::interleaved_retained(8, 8), (0..8).collect::<Vec<_>>());
        assert_eq!(LayerBlockTable::interleaved_retained(4, 1).len(), 1);
        for x in 0..=32 {
            let r = LayerBlockTable::interleaved_retained(32, x);
            assert_eq!(r.len(), x, "x={x}");
            let mut d = r.clone();
            d.dedup();
            assert_eq!(d, r, "duplicates at x={x}");
            assert!(r.iter().all(|&i| i < 32));
        }
    }

    #[test]
    fn residency_bookkeeping() {
        let mut t = LayerBlockTable::new(4, 16);
        t.tokens = 33;
        for l in &mut t.layers {
            l.blocks = vec![0, 1, 2];
        }
        t.layers[1].residency = Residency::Cpu;
        t.layers[3].residency = Residency::Cpu;
        assert_eq!(t.gpu_layers(), vec![0, 2]);
        assert_eq!(t.cpu_layers(), vec![1, 3]);
        assert_eq!(t.n_gpu_layers(), 2);
        assert_eq!(t.gpu_blocks_held(), 6);
        assert_eq!(t.cpu_blocks_held(), 6);
        t.check().unwrap();
    }

    #[test]
    fn check_catches_inconsistency() {
        let mut t = LayerBlockTable::new(2, 16);
        t.tokens = 40; // needs 3 blocks/layer
        t.layers[0].blocks = vec![0, 1, 2];
        t.layers[1].blocks = vec![3];
        assert!(t.check().is_err());
    }
}
