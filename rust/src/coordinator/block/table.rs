//! Layer-wise block tables (§3.1.2): per request, per layer, the ordered
//! list of physical blocks holding its KV and *where each layer lives*
//! in the tier hierarchy (GPU, host RAM, or disk). This is the paper's
//! extension of vLLM's block table — "we add layer-wise information to
//! each block, indicating the indices of the layers where the KV cache is
//! retained on the GPU and the indices of the layers stored on the CPU" —
//! generalized to N tiers: once host RAM fills, cold layers spill one
//! level further down, to a slow high-capacity disk tier.
//!
//! §Perf: the table carries cached residency aggregates (resident-layer
//! count, blocks held per pool) so the scheduler's per-step queries —
//! `n_gpu_layers`, `gpu_blocks_held`, `fully_resident` — are O(1) reads
//! instead of O(L) scans that allocate. `KvManager` keeps them in sync via
//! the `note_*` hooks; `check()` cross-validates them against the layers.
//!
//! A layer lives in exactly ONE tier by construction: `Residency` is a
//! single enum per layer, so "no layer resident in two tiers" is a
//! structural invariant; `check()` additionally re-derives every cached
//! per-tier aggregate from the layers and rejects any drift.

use super::allocator::BlockId;

/// Which memory tier holds a layer's blocks (GPU > host > disk, fastest
/// to slowest).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Residency {
    Gpu,
    Cpu,
    /// The deepest tier: spill files / NVMe, reached only when the host
    /// pool is under pressure and a disk pool is configured.
    Disk,
}

impl Residency {
    /// Stable tier index for logs/metrics: GPU=0, host=1, disk=2.
    pub fn tier_index(self) -> u8 {
        match self {
            Residency::Gpu => 0,
            Residency::Cpu => 1,
            Residency::Disk => 2,
        }
    }
}

/// One layer's slice of a request's KV cache.
#[derive(Debug, Clone)]
pub struct LayerEntry {
    pub residency: Residency,
    /// Physical blocks, in token order. Ids are in the GPU pool's space
    /// when residency == Gpu, the CPU pool's space otherwise.
    pub blocks: Vec<BlockId>,
}

/// Per-request layer-wise block table.
#[derive(Debug, Clone)]
pub struct LayerBlockTable {
    pub layers: Vec<LayerEntry>,
    /// Tokens currently stored (same for every layer).
    pub tokens: usize,
    pub block_size: usize,
    /// Cached aggregates (see module docs). Private so only the mutation
    /// hooks and `recount` touch them.
    gpu_layer_count: usize,
    disk_layer_count: usize,
    gpu_blocks: usize,
    cpu_blocks: usize,
    disk_blocks: usize,
}

impl LayerBlockTable {
    pub fn new(n_layers: usize, block_size: usize) -> Self {
        LayerBlockTable {
            layers: (0..n_layers)
                .map(|_| LayerEntry { residency: Residency::Gpu, blocks: Vec::new() })
                .collect(),
            tokens: 0,
            block_size,
            gpu_layer_count: n_layers,
            disk_layer_count: 0,
            gpu_blocks: 0,
            cpu_blocks: 0,
            disk_blocks: 0,
        }
    }

    /// Re-arm a recycled table for a fresh request: every layer back to
    /// GPU residency with its block list cleared *but capacity kept* —
    /// the whole point of `KvManager`'s table recycling.
    pub(crate) fn reset(&mut self, n_layers: usize, block_size: usize, tokens: usize) {
        if self.layers.len() != n_layers {
            self.layers = (0..n_layers)
                .map(|_| LayerEntry { residency: Residency::Gpu, blocks: Vec::new() })
                .collect();
        } else {
            for e in &mut self.layers {
                e.residency = Residency::Gpu;
                e.blocks.clear();
            }
        }
        self.block_size = block_size;
        self.tokens = tokens;
        self.gpu_layer_count = n_layers;
        self.disk_layer_count = 0;
        self.gpu_blocks = 0;
        self.cpu_blocks = 0;
        self.disk_blocks = 0;
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Blocks needed per layer for `tokens` tokens.
    pub fn blocks_per_layer(&self, tokens: usize) -> usize {
        tokens.div_ceil(self.block_size)
    }

    /// Layer indices currently in `tier`, in layer order. Allocation-free:
    /// hot paths fold the iterator directly (the PR 1 scratch-buffer
    /// idiom's sibling — callers that need a `Vec` collect explicitly).
    pub fn layers_in(&self, tier: Residency) -> impl Iterator<Item = usize> + '_ {
        self.layers
            .iter()
            .enumerate()
            .filter(move |(_, e)| e.residency == tier)
            .map(|(i, _)| i)
    }

    /// Layers currently resident on GPU (allocation-free iterator).
    pub fn gpu_layers(&self) -> impl Iterator<Item = usize> + '_ {
        self.layers_in(Residency::Gpu)
    }

    /// Layers currently parked on the host (allocation-free iterator).
    pub fn cpu_layers(&self) -> impl Iterator<Item = usize> + '_ {
        self.layers_in(Residency::Cpu)
    }

    /// Layers currently spilled to the disk tier (allocation-free iterator).
    pub fn disk_layers(&self) -> impl Iterator<Item = usize> + '_ {
        self.layers_in(Residency::Disk)
    }

    /// O(1): layers resident on GPU.
    pub fn n_gpu_layers(&self) -> usize {
        self.gpu_layer_count
    }

    /// O(1): layers parked on the host.
    pub fn n_cpu_layers(&self) -> usize {
        self.layers.len() - self.gpu_layer_count - self.disk_layer_count
    }

    /// O(1): layers spilled to the disk tier.
    pub fn n_disk_layers(&self) -> usize {
        self.disk_layer_count
    }

    /// O(1): true when every layer's KV is on the GPU (the decode-batch
    /// membership test the scheduler runs per request per step).
    pub fn fully_resident(&self) -> bool {
        self.gpu_layer_count == self.layers.len()
    }

    /// O(1): total GPU layer-blocks held.
    pub fn gpu_blocks_held(&self) -> usize {
        self.gpu_blocks
    }

    /// O(1): total host layer-blocks held.
    pub fn cpu_blocks_held(&self) -> usize {
        self.cpu_blocks
    }

    /// O(1): total disk layer-blocks held.
    pub fn disk_blocks_held(&self) -> usize {
        self.disk_blocks
    }

    // --- aggregate maintenance hooks (KvManager only) -------------------

    /// One block was appended to every layer (a block-boundary grow).
    pub(crate) fn note_block_growth(&mut self) {
        self.note_span_growth(1);
    }

    /// `growth` blocks were appended to every layer at once (a
    /// macro-stepped span crossing `growth` block boundaries).
    pub(crate) fn note_span_growth(&mut self, growth: usize) {
        self.gpu_blocks += growth * self.gpu_layer_count;
        self.cpu_blocks +=
            growth * (self.layers.len() - self.gpu_layer_count - self.disk_layer_count);
        self.disk_blocks += growth * self.disk_layer_count;
    }

    /// Layer moved GPU -> host, `n` blocks.
    pub(crate) fn note_offloaded(&mut self, n: usize) {
        self.gpu_layer_count -= 1;
        self.gpu_blocks -= n;
        self.cpu_blocks += n;
    }

    /// Layer moved host -> GPU, `n` blocks.
    pub(crate) fn note_onloaded(&mut self, n: usize) {
        self.gpu_layer_count += 1;
        self.cpu_blocks -= n;
        self.gpu_blocks += n;
    }

    /// Layer moved host -> disk, `n` blocks (spill under host pressure).
    pub(crate) fn note_spilled(&mut self, n: usize) {
        self.disk_layer_count += 1;
        self.cpu_blocks -= n;
        self.disk_blocks += n;
    }

    /// Layer moved disk -> host, `n` blocks.
    pub(crate) fn note_unspilled(&mut self, n: usize) {
        self.disk_layer_count -= 1;
        self.disk_blocks -= n;
        self.cpu_blocks += n;
    }

    /// Layer moved disk -> GPU directly, `n` blocks (restore from the
    /// deepest tier).
    pub(crate) fn note_promoted(&mut self, n: usize) {
        self.disk_layer_count -= 1;
        self.gpu_layer_count += 1;
        self.disk_blocks -= n;
        self.gpu_blocks += n;
    }

    /// Layer moved GPU -> disk directly, `n` blocks (the engine rolling
    /// back a deep restore whose disk read failed: the bytes never
    /// actually left the disk tier).
    pub(crate) fn note_demoted(&mut self, n: usize) {
        self.gpu_layer_count -= 1;
        self.disk_layer_count += 1;
        self.gpu_blocks -= n;
        self.disk_blocks += n;
    }

    /// Rebuild the cached aggregates from the layers (after bulk edits —
    /// admission fills, or tests that poke `layers` directly).
    pub fn recount(&mut self) {
        self.gpu_layer_count = 0;
        self.disk_layer_count = 0;
        self.gpu_blocks = 0;
        self.cpu_blocks = 0;
        self.disk_blocks = 0;
        for e in &self.layers {
            match e.residency {
                Residency::Gpu => {
                    self.gpu_layer_count += 1;
                    self.gpu_blocks += e.blocks.len();
                }
                Residency::Cpu => self.cpu_blocks += e.blocks.len(),
                Residency::Disk => {
                    self.disk_layer_count += 1;
                    self.disk_blocks += e.blocks.len();
                }
            }
        }
    }

    /// §3.1.2 interleaving as a bitmask: bit i set means layer i is
    /// *retained on GPU* when keeping `x` of `l` layers. The retained set
    /// is spread evenly so each offloaded layer's h2d can overlap the
    /// compute of the retained layer before it (the paper's 8-layer
    /// example keeps 1,3,5,7 and offloads 0,2,4,6). Branch-free to query
    /// and allocation-free to build — the admission hot path.
    pub fn interleaved_retained_mask(l: usize, x: usize) -> u128 {
        assert!(l <= 128, "mask form supports up to 128 layers (got {l})");
        if x == 0 || l == 0 {
            return 0;
        }
        let all = if l == 128 { u128::MAX } else { (1u128 << l) - 1 };
        if x >= l {
            return all;
        }
        // Evenly spaced, biased to the *later* congruence class like the
        // paper's example (offload even indices, retain odd).
        let mut mask = 0u128;
        let mut count = 0usize;
        for i in 0..x {
            let idx = ((2 * i + 1) * l / (2 * x)).min(l - 1);
            if mask >> idx & 1 == 0 {
                mask |= 1u128 << idx;
                count += 1;
            }
        }
        // rare collisions at tiny l: fill greedily from the bottom
        let mut next = 0usize;
        while count < x {
            if mask >> next & 1 == 0 {
                mask |= 1u128 << next;
                count += 1;
            }
            next += 1;
        }
        mask
    }

    /// §3.1.2 interleaving as a sorted index list (Vec-returning
    /// convenience over the mask form; `l > 128` falls back to the direct
    /// construction).
    pub fn interleaved_retained(l: usize, x: usize) -> Vec<usize> {
        if l <= 128 {
            let mask = Self::interleaved_retained_mask(l, x);
            return (0..l).filter(|&i| mask >> i & 1 == 1).collect();
        }
        if x == 0 {
            return Vec::new();
        }
        if x >= l {
            return (0..l).collect();
        }
        let mut out: Vec<usize> = (0..x)
            .map(|i| ((2 * i + 1) * l / (2 * x)).min(l - 1))
            .collect();
        out.dedup();
        let mut next = 0;
        while out.len() < x {
            if !out.contains(&next) {
                out.push(next);
            }
            next += 1;
        }
        out.sort_unstable();
        out
    }

    /// Validate internal consistency (used by property tests): per-layer
    /// block counts match the token count, and every cached per-tier
    /// aggregate matches a from-scratch recount. A layer lives in exactly
    /// one tier by construction (`Residency` is a single enum per layer),
    /// so the recount below is also a proof that no layer is counted in
    /// two tiers: the per-tier sums partition the layers.
    pub fn check(&self) -> Result<(), String> {
        let want = self.blocks_per_layer(self.tokens);
        for (i, l) in self.layers.iter().enumerate() {
            if l.blocks.len() != want && self.tokens > 0 {
                return Err(format!(
                    "layer {i}: {} blocks for {} tokens (want {want})",
                    l.blocks.len(),
                    self.tokens
                ));
            }
        }
        let (mut gpu_layers, mut disk_layers) = (0usize, 0usize);
        let (mut gpu_blocks, mut cpu_blocks, mut disk_blocks) = (0usize, 0usize, 0usize);
        for e in &self.layers {
            match e.residency {
                Residency::Gpu => {
                    gpu_layers += 1;
                    gpu_blocks += e.blocks.len();
                }
                Residency::Cpu => cpu_blocks += e.blocks.len(),
                Residency::Disk => {
                    disk_layers += 1;
                    disk_blocks += e.blocks.len();
                }
            }
        }
        if (gpu_layers, gpu_blocks, cpu_blocks)
            != (self.gpu_layer_count, self.gpu_blocks, self.cpu_blocks)
        {
            return Err(format!(
                "stale aggregates: cached ({}, {}, {}) vs actual ({gpu_layers}, {gpu_blocks}, {cpu_blocks})",
                self.gpu_layer_count, self.gpu_blocks, self.cpu_blocks
            ));
        }
        if (disk_layers, disk_blocks) != (self.disk_layer_count, self.disk_blocks) {
            return Err(format!(
                "stale disk-tier aggregates: cached ({}, {}) vs actual ({disk_layers}, {disk_blocks})",
                self.disk_layer_count, self.disk_blocks
            ));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_8_layers_keep_4() {
        // §3.1.2: 8-layer model keeping 4 on GPU retains 1,3,5,7
        assert_eq!(LayerBlockTable::interleaved_retained(8, 4), vec![1, 3, 5, 7]);
        assert_eq!(
            LayerBlockTable::interleaved_retained_mask(8, 4),
            0b1010_1010u128
        );
    }

    #[test]
    fn retained_edge_cases() {
        assert!(LayerBlockTable::interleaved_retained(8, 0).is_empty());
        assert_eq!(LayerBlockTable::interleaved_retained(8, 8), (0..8).collect::<Vec<_>>());
        assert_eq!(LayerBlockTable::interleaved_retained(4, 1).len(), 1);
        for x in 0..=32 {
            let r = LayerBlockTable::interleaved_retained(32, x);
            assert_eq!(r.len(), x, "x={x}");
            let mut d = r.clone();
            d.dedup();
            assert_eq!(d, r, "duplicates at x={x}");
            assert!(r.iter().all(|&i| i < 32));
        }
    }

    /// The original (pre-mask) list construction, kept here as the
    /// independent reference the bitmask form is checked against.
    fn reference_retained(l: usize, x: usize) -> Vec<usize> {
        if x == 0 {
            return Vec::new();
        }
        if x >= l {
            return (0..l).collect();
        }
        let mut out: Vec<usize> =
            (0..x).map(|i| ((2 * i + 1) * l / (2 * x)).min(l - 1)).collect();
        out.dedup();
        let mut next = 0;
        while out.len() < x {
            if !out.contains(&next) {
                out.push(next);
            }
            next += 1;
        }
        out.sort_unstable();
        out
    }

    #[test]
    fn mask_matches_reference_construction() {
        for l in [1usize, 2, 3, 7, 8, 31, 32, 33, 80, 127, 128] {
            for x in 0..=l {
                let mask = LayerBlockTable::interleaved_retained_mask(l, x);
                let list = LayerBlockTable::interleaved_retained(l, x);
                let reference = reference_retained(l, x);
                assert_eq!(mask.count_ones() as usize, x, "l={l} x={x}");
                assert_eq!(list, reference, "l={l} x={x}: list form drifted");
                for i in 0..l {
                    assert_eq!(
                        mask >> i & 1 == 1,
                        reference.contains(&i),
                        "l={l} x={x} layer {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn residency_bookkeeping() {
        let mut t = LayerBlockTable::new(4, 16);
        t.tokens = 33;
        for l in &mut t.layers {
            l.blocks = vec![0, 1, 2];
        }
        t.layers[1].residency = Residency::Cpu;
        t.layers[3].residency = Residency::Cpu;
        t.recount(); // hand-edited layers -> rebuild aggregates
        assert_eq!(t.gpu_layers().collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(t.cpu_layers().collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(t.disk_layers().count(), 0);
        assert_eq!(t.n_gpu_layers(), 2);
        assert_eq!(t.n_cpu_layers(), 2);
        assert_eq!(t.n_disk_layers(), 0);
        assert!(!t.fully_resident());
        assert_eq!(t.gpu_blocks_held(), 6);
        assert_eq!(t.cpu_blocks_held(), 6);
        assert_eq!(t.disk_blocks_held(), 0);
        t.check().unwrap();
    }

    #[test]
    fn three_tier_bookkeeping() {
        let mut t = LayerBlockTable::new(4, 16);
        t.tokens = 33;
        for l in &mut t.layers {
            l.blocks = vec![0, 1, 2];
        }
        t.layers[1].residency = Residency::Cpu;
        t.layers[3].residency = Residency::Disk;
        t.recount();
        assert_eq!(t.gpu_layers().collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(t.cpu_layers().collect::<Vec<_>>(), vec![1]);
        assert_eq!(t.disk_layers().collect::<Vec<_>>(), vec![3]);
        assert_eq!(
            (t.n_gpu_layers(), t.n_cpu_layers(), t.n_disk_layers()),
            (2, 1, 1)
        );
        assert_eq!(t.gpu_blocks_held(), 6);
        assert_eq!(t.cpu_blocks_held(), 3);
        assert_eq!(t.disk_blocks_held(), 3);
        t.check().unwrap();
        // note hooks keep the tier aggregates in lock-step with moves
        t.note_spilled(3); // layer 1: host -> disk
        t.layers[1].residency = Residency::Disk;
        assert_eq!((t.n_cpu_layers(), t.n_disk_layers()), (0, 2));
        assert_eq!((t.cpu_blocks_held(), t.disk_blocks_held()), (0, 6));
        t.check().unwrap();
        t.note_promoted(3); // layer 3: disk -> GPU
        t.layers[3].residency = Residency::Gpu;
        assert_eq!((t.n_gpu_layers(), t.n_disk_layers()), (3, 1));
        assert_eq!(t.gpu_blocks_held(), 9);
        t.check().unwrap();
        t.note_unspilled(3); // layer 1: disk -> host
        t.layers[1].residency = Residency::Cpu;
        assert_eq!((t.n_cpu_layers(), t.n_disk_layers()), (1, 0));
        t.check().unwrap();
    }

    #[test]
    fn check_catches_stale_disk_aggregates() {
        let mut t = LayerBlockTable::new(2, 16);
        t.tokens = 16;
        t.layers[0].blocks = vec![0];
        t.layers[1].blocks = vec![1];
        t.layers[1].residency = Residency::Disk;
        t.recount();
        t.check().unwrap();
        // hand-move without a recount: disk aggregates go stale
        t.layers[1].residency = Residency::Cpu;
        assert!(t.check().is_err());
    }

    #[test]
    fn check_catches_inconsistency() {
        let mut t = LayerBlockTable::new(2, 16);
        t.tokens = 40; // needs 3 blocks/layer
        t.layers[0].blocks = vec![0, 1, 2];
        t.layers[1].blocks = vec![3];
        t.recount();
        assert!(t.check().is_err());
    }

    #[test]
    fn check_catches_stale_aggregates() {
        let mut t = LayerBlockTable::new(2, 16);
        t.tokens = 16;
        t.layers[0].blocks = vec![0];
        t.layers[1].blocks = vec![1];
        // no recount: cached counts still say zero blocks held
        assert!(t.check().unwrap_err().contains("stale aggregates"));
    }

    #[test]
    fn reset_keeps_capacity_and_rearms() {
        let mut t = LayerBlockTable::new(2, 16);
        t.layers[0].blocks = vec![5, 6, 7];
        t.layers[0].residency = Residency::Cpu;
        t.recount();
        let cap = t.layers[0].blocks.capacity();
        t.reset(2, 16, 40);
        assert_eq!(t.tokens, 40);
        assert!(t.fully_resident());
        assert_eq!(t.gpu_blocks_held(), 0);
        assert!(t.layers[0].blocks.is_empty());
        assert_eq!(t.layers[0].blocks.capacity(), cap, "capacity recycled");
    }
}
