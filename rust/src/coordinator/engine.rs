//! The continuous-batching coordinator, generic over its executor.
//!
//! `Engine<B: ExecutionBackend>` drives the full request lifecycle —
//! iteration-level scheduling (one prefill batch or one decode iteration
//! per step), layer-wise KV allocation/offloading per the active policy,
//! recompute preemption, and the decode-phase host-KV streaming penalty —
//! while the backend decides what a step physically does and how long it
//! takes (see `coordinator/backend.rs`):
//!
//! * `Engine<SimBackend>` is the discrete-event simulator: virtual time,
//!   analytical cost models. `run_trace` builds it.
//! * `Engine<PjrtBackend>` (`runtime/realengine.rs`) serves real tokens
//!   through the compiled HLO on wall time — same scheduler policies,
//!   same `KvManager` layer-table accounting.
//!
//! §Perf architecture: the per-step hot loop does zero steady-state heap
//! allocation and no from-scratch scans —
//!
//! * `running` is kept **sorted by `prefill_start`** (oldest admitted
//!   first) via insertion at admit time, so "oldest" is `first()` and
//!   "most recently prefilled" is reverse iteration; no per-step sorts.
//! * `RunningAggregates` caches the decode batch's size and total context
//!   tokens, updated on admit/append/offload/onload/finish events; the
//!   decode step duration comes from `decode_step_time_sum` on those
//!   cached totals instead of a per-request `Vec<usize>` each step.
//! * `active_buf`/`finished_buf` are reusable per-step buffers.
//! * The scheduler returns the retained-layer count `x` with each
//!   admission, so prefill steps no longer rebuild a `SchedContext`.
//! * Backend dispatch is static (monomorphised), so the seam costs
//!   nothing on the hot path (`engine/unified_step` in the hotpath
//!   bench tracks this).
//! * **Decode fast-forwarding (macro-stepping)**: when the machine is
//!   *stable* — queue empty, every running request fully GPU-resident —
//!   the engine solves the event horizon (`coordinator/horizon.rs`) for
//!   the number of decode iterations `k` provably unchanged by any
//!   scheduler decision, then commits all `k` in one macro-step:
//!   per-step clock/EMA replication plus one bulk `KvManager::alloc_span`
//!   per request. Scheduler invocations drop from O(total output tokens)
//!   to O(events); `engine/fastforward_*` in the hotpath bench tracks the
//!   win, and the whole thing is **bit-identical** to single-stepping
//!   (`rust/tests/prop_fastforward.rs`). `set_macro_steps(false)` (or
//!   `LAYERKV_MACRO=0`) restores pure single-stepping for debugging.
//!
//! `use_recompute_oracle()` switches every cached quantity back to
//! from-scratch recomputation each step (and disables macro-stepping);
//! `rust/tests/prop_invariants.rs` asserts both modes produce
//! bit-identical reports, and additionally that `Engine<SimBackend>`
//! matches the pre-refactor monolithic engine
//! (`tests/support/reference_engine.rs`) bit-for-bit.

use std::collections::VecDeque;

use crate::config::{Policy, ServingConfig};
use crate::coordinator::backend::{Clock, ExecutionBackend, SimBackend};
use crate::coordinator::block::{KvError, KvManager, PrefixMove, RequestSnapshot, Residency};
use crate::coordinator::horizon::{decode_horizon, HorizonInputs};
use crate::coordinator::predict::LengthPredictor;
use crate::coordinator::request::{Phase, ReqId, Request};
use crate::coordinator::scheduler::{make_scheduler, Action, SchedContext, Scheduler};
use crate::metrics::{Report, RequestRecord, TierTransition};
use crate::obs::{EngineTrace, EventKind, GaugeKind, GaugeSample, TraceHandle, TraceRecord};
use crate::sim::CostModel;
use crate::workload::{PrefixKey, Trace, TraceRequest};

/// The engine's clock-comparison epsilon: an arrival is admissible when
/// `arrival <= now + CLOCK_EPS`, and every driver (try_run's arrival
/// loop, the cluster lockstep, the incremental-drive tests) gates on the
/// same constant so the paths stay bit-identical.
pub const CLOCK_EPS: f64 = 1e-12;

/// Decode fast-forwarding default: on unless `LAYERKV_MACRO=0` (the
/// experiments' `--no-macro-steps` debugging toggle sets this).
fn macro_steps_enabled() -> bool {
    std::env::var("LAYERKV_MACRO").map(|v| v != "0").unwrap_or(true)
}

/// Consecutive disk-tier I/O errors before the engine fences the tier
/// (retires the disk pool and falls back to two-tier + recompute).
pub const DISK_FENCE_K: u32 = 3;

/// Sentinel `req` id for prefix-cache entries in the tier-transition log
/// (cache blocks belong to no live request). Entries always log layer 0:
/// a prefix entry moves all its layers together.
pub const PREFIX_REQ: ReqId = usize::MAX;

/// An unfinished request exported by [`Engine::drain`], carrying exactly
/// what a failover path needs to re-submit it elsewhere from scratch: the
/// ORIGINAL lengths (any partially generated tokens are discarded — this
/// is recompute preemption across replicas) and the original arrival, so
/// the eventual record's TTFT/queueing includes the downtime. The
/// progress fields (`committed`, `checkpointed`) make the wasted work
/// measurable — and, via [`Engine::drain_with_state`] +
/// [`Engine::adopt`], recoverable.
#[derive(Debug, Clone, PartialEq)]
pub struct DrainedRequest {
    /// Engine-local id (dense submission order); the caller owns the
    /// local -> global mapping.
    pub id: ReqId,
    pub arrival: f64,
    pub prompt_len: usize,
    pub output_len: usize,
    /// Shared-prefix identity, preserved so the failover target can
    /// still match (and publish into) its own prefix cache.
    pub prefix: PrefixKey,
    /// Tokens the request had committed when it was drained — the decode
    /// progress a from-scratch re-submission throws away.
    pub committed: usize,
    /// Tokens covered by the last durable disk checkpoint (0 with
    /// checkpointing off or the disk tier fenced — the fenced tier's
    /// checkpoints are not trustworthy, so failover degrades cleanly to
    /// the recompute path).
    pub checkpointed: usize,
}

/// Counters the experiments report alongside latency. Every `disk_*` /
/// `spill*` field stays exactly 0 in the two-tier configuration (disk
/// pool capacity 0), by construction of the gating in `Engine`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct EngineStats {
    pub steps: u64,
    pub prefill_steps: u64,
    pub decode_steps: u64,
    pub preemptions: u64,
    pub proactive_offload_layers: u64,
    pub oom_forced_offload_layers: u64,
    pub onloaded_layers: u64,
    pub offload_bytes: f64,
    pub onload_stream_bytes: f64,
    pub dropped: Vec<ReqId>,
    /// Seconds decode steps were inflated by host-KV streaming.
    pub stream_stall_s: f64,
    /// Seconds lost to PCIe contention (TP over PCIe without chunking).
    pub contention_s: f64,
    /// Layers spilled host -> disk under host pressure.
    pub spilled_layers: u64,
    /// Layers restored disk -> GPU (deep restores).
    pub disk_promoted_layers: u64,
    /// Bytes written to the disk tier (runtime spills + layers admitted
    /// straight to disk when the host pool was full).
    pub spill_bytes: f64,
    /// Bytes read back from the disk tier by restores.
    pub disk_restore_bytes: f64,
    /// Bytes the forced-progress decode path streamed from disk.
    pub disk_stream_bytes: f64,
    /// Seconds decode steps were inflated by the disk link specifically.
    pub disk_stall_s: f64,
    /// Disk-tier I/O failures observed (injected via `set_disk_faulty`
    /// or reported by a real backend's spill/restore hooks).
    pub disk_io_errors: u64,
    /// The disk tier was fenced after K consecutive I/O errors: its pool
    /// was retired and the engine fell back to two-tier + recompute.
    pub disk_fenced: bool,
    /// Prefix-cache hits served at admission. Every `prefix_*` counter
    /// stays exactly 0 with caching off or on a prefix-free trace.
    pub prefix_hits: u64,
    /// Admissions that carried a prefix key but found no entry.
    pub prefix_misses: u64,
    /// Prompt tokens whose recompute was skipped by cache hits.
    pub prefix_hit_tokens: u64,
    /// Entries published into the cache.
    pub prefix_inserts: u64,
    /// Entries dropped from the cache (LRU, pressure, or drain).
    pub prefix_evictions: u64,
    /// Cache entries demoted a tier under pool pressure.
    pub prefix_demotions: u64,
    /// Cache entries promoted to GPU while serving a hit.
    pub prefix_promotions: u64,
    /// Bytes restored host/disk -> GPU to serve cache hits.
    pub prefix_restore_bytes: f64,
    /// Incremental checkpoints written to the disk tier. All `ckpt_*`
    /// counters stay exactly 0 with checkpointing off (`ckpt_every_tokens
    /// == 0`), and checkpointing never perturbs execution either way —
    /// writes are virtual (priced, not clocked).
    pub ckpt_writes: u64,
    /// Bytes those checkpoints wrote (incremental: each write covers only
    /// tokens since the previous durable point).
    pub ckpt_bytes: f64,
    /// Seconds of disk-link time the checkpoint writes would consume —
    /// accounted, never added to the clock (writes ride the idle disk
    /// link off the critical path).
    pub ckpt_write_s: f64,
    /// Requests this engine adopted from another engine's
    /// [`Engine::drain_with_state`] snapshot.
    pub adoptions: u64,
    /// Bytes read from the durable checkpoint store to restore adopted
    /// requests' KV.
    pub adopt_restore_bytes: f64,
}

/// Incrementally-maintained totals over the running set: the membership
/// and token count of the decode batch (the fully-GPU-resident subset).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
struct RunningAggregates {
    /// Running requests whose KV is entirely on the GPU.
    resident_count: usize,
    /// Σ context_len over those — what one decode iteration streams.
    resident_tokens: usize,
}

impl RunningAggregates {
    fn recompute(running: &[ReqId], requests: &[Request], kv: &KvManager) -> Self {
        let mut a = RunningAggregates::default();
        for &rid in running {
            if kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false) {
                a.resident_count += 1;
                a.resident_tokens += requests[rid].context_len();
            }
        }
        a
    }
}

/// §Perf: O(1) router-facing load aggregates, maintained at every
/// submit/admit/append/preempt/finish/drop instead of re-scanning the
/// queue and running set per route decision (they used to be O(n) scans —
/// one per replica per arriving request at cluster scale). Maintained
/// identically in incremental and recompute-oracle mode (the oracle
/// recomputes *engine* state; these views feed only the router), and
/// validated against the `*_scan` getters by the property suite. The two
/// token counts and the remaining-token sum are exact integer bookkeeping;
/// the prefill-seconds sum is float add/sub of identical per-request terms
/// (re-pinned to 0.0 whenever the queue drains, so rounding residue
/// cannot accumulate across queue cycles).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
struct LoadView {
    waiting_tokens: usize,
    waiting_prefill_s: f64,
    running_tokens: usize,
    running_remaining_tokens: usize,
}

/// The coordinator. One instance runs one trace to completion against its
/// execution backend.
pub struct Engine<B: ExecutionBackend = SimBackend> {
    pub cfg: ServingConfig,
    pub cost: CostModel,
    pub kv: KvManager,
    pub backend: B,
    scheduler: Box<dyn Scheduler>,
    predictor: LengthPredictor,
    requests: Vec<Request>,
    waiting: VecDeque<ReqId>,
    /// §Perf invariant: sorted by `prefill_start` ascending.
    running: Vec<ReqId>,
    stats: EngineStats,
    records: Vec<RequestRecord>,
    agg: RunningAggregates,
    /// false = recompute-from-scratch oracle mode (property-test reference).
    incremental: bool,
    /// Eq. 5 restore watermark in blocks (fixed pool ⇒ computed once).
    restore_threshold: usize,
    /// Host-pressure spill watermark in host blocks (the host-tier analog
    /// of `restore_threshold`; only consulted when a disk tier exists).
    host_spill_threshold: usize,
    /// Tier-transition log (None = disabled, the default — zero overhead
    /// on the hot path).
    transitions: Option<Vec<TierTransition>>,
    /// Lifecycle-span tracer attachment (None = tracing off, the default
    /// — the hot path pays one `is_some` check and allocates nothing).
    /// Recording never feeds back into engine state: with tracing on,
    /// results are bit-identical to tracing off (`tests/prop_obs.rs`).
    trace: Option<EngineTrace>,
    /// Reusable per-step buffers (decode batch, finished list).
    active_buf: Vec<ReqId>,
    finished_buf: Vec<ReqId>,
    /// Σ (prompt + output) tokens handed to `submit` — the incremental
    /// path's livelock step bound grows with it (`try_run` derives the
    /// same bound from the whole trace upfront).
    submitted_tokens: u64,
    /// Decode fast-forwarding (macro-stepping) enabled. Default on; off in
    /// recompute-oracle mode and under `LAYERKV_MACRO=0`.
    macro_steps: bool,
    /// `Scheduler::decide` calls so far — the invocation count
    /// macro-stepping collapses from O(total output tokens) to O(events).
    /// Deliberately NOT part of `EngineStats`: it measures the driving
    /// loop, not the served workload, and differs between the macro and
    /// single-step paths by design.
    sched_invocations: u64,
    /// O(1) router-facing load aggregates (see the router-facing getters).
    view: LoadView,
    /// Reusable `tokens % block_size` histogram for the horizon solver.
    ff_hist: Vec<usize>,
    /// Reusable per-step duration buffer: the horizon solver records the
    /// span's decode durations here and the commit replays them, so the
    /// cost model is evaluated once per step, not twice.
    ff_durations: Vec<f64>,
    /// False between `drain()` and `reopen_admission()`: the engine is
    /// fenced off and `submit` is a caller bug (debug-asserted).
    admission_open: bool,
    /// Fault injection: while true every disk-tier spill/restore the
    /// engine attempts fails as an I/O error (the simulated analog of a
    /// failing NVMe; a real backend reports errors through its hooks
    /// instead).
    disk_faulty: bool,
    /// Consecutive disk-tier I/O errors; `DISK_FENCE_K` of them arms the
    /// fence. Reset by any successful disk-tier op.
    disk_err_streak: u32,
    /// The fence trips at the next step boundary (errors surface deep in
    /// loops that iterate `running` by index, where preempting in place
    /// would invalidate the iteration).
    disk_fence_pending: bool,
    /// Cached, *uncommitted* stable decode span backing the cluster event
    /// heap's horizon queries (`next_event_horizon`): the horizon
    /// solver's per-step durations, solved once at an infinite deadline,
    /// with `span_pos` of them already committed by `commit_span_until`
    /// and `span_end` the absolute clock instant the full span lands on.
    /// Any state perturbation invalidates it; committing a chunk replays
    /// exactly the floats the same steps would produce one at a time.
    span_durs: Vec<f64>,
    span_pos: usize,
    span_end: f64,
    span_valid: bool,
}

impl Engine<SimBackend> {
    /// The simulation engine: pools sized by the config's memory
    /// profiling pass (including the disk tier, capacity 0 on two-tier
    /// nodes), steps costed by the analytical models.
    pub fn new(cfg: ServingConfig, predictor: LengthPredictor) -> Self {
        let kv = KvManager::new_tiered(
            cfg.num_gpu_layer_blocks(),
            cfg.num_cpu_layer_blocks(),
            cfg.num_disk_layer_blocks(),
            cfg.block_size,
            cfg.model.n_layers,
        );
        let backend = SimBackend::new(&cfg);
        Engine::with_parts(cfg, kv, backend, predictor)
    }
}

impl<B: ExecutionBackend> Engine<B> {
    /// Assemble a coordinator from explicit parts: any backend, any pool
    /// sizing. The real serving path uses this with pools derived from
    /// its device byte budget.
    pub fn with_parts(
        cfg: ServingConfig,
        kv: KvManager,
        backend: B,
        predictor: LengthPredictor,
    ) -> Self {
        let cost = CostModel::new(cfg.clone());
        let scheduler = make_scheduler(&cfg);
        let restore_threshold =
            (cfg.avail_threshold_frac * kv.gpu.total() as f64) as usize;
        let host_spill_threshold =
            (cfg.avail_threshold_frac * kv.cpu.total() as f64) as usize;
        Engine {
            cfg,
            cost,
            kv,
            backend,
            scheduler,
            predictor,
            requests: Vec::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            stats: EngineStats::default(),
            records: Vec::new(),
            agg: RunningAggregates::default(),
            incremental: true,
            restore_threshold,
            host_spill_threshold,
            transitions: None,
            trace: crate::obs::sink::current().map(EngineTrace::attach),
            active_buf: Vec::new(),
            finished_buf: Vec::new(),
            submitted_tokens: 0,
            macro_steps: macro_steps_enabled(),
            sched_invocations: 0,
            view: LoadView::default(),
            ff_hist: Vec::new(),
            ff_durations: Vec::new(),
            admission_open: true,
            disk_faulty: false,
            disk_err_streak: 0,
            disk_fence_pending: false,
            span_durs: Vec::new(),
            span_pos: 0,
            span_end: 0.0,
            span_valid: false,
        }
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Toggle decode fast-forwarding (macro-stepping). Off = pure
    /// single-stepping, the debugging reference; the two are
    /// property-tested bit-identical (`tests/prop_fastforward.rs`).
    pub fn set_macro_steps(&mut self, on: bool) {
        self.macro_steps = on;
        self.span_valid = false;
    }

    /// `Scheduler::decide` calls so far. Macro-stepping's savings metric:
    /// single-stepping pays one per decode iteration, fast-forwarding one
    /// per *event* (arrival, completion, pool boundary).
    pub fn sched_invocations(&self) -> u64 {
        self.sched_invocations
    }

    /// Record every layer residency move (GPU <-> host <-> disk) into a
    /// tier-transition log. Off by default: the hot path pays nothing.
    pub fn enable_transition_log(&mut self) {
        self.transitions = Some(Vec::new());
    }

    /// Drain the transition log recorded since `enable_transition_log`.
    pub fn take_transitions(&mut self) -> Vec<TierTransition> {
        self.transitions.take().unwrap_or_default()
    }

    // --- tracing ---------------------------------------------------------
    //
    // All hooks below are pure observers: they read engine state and push
    // records into the attached ring; nothing flows back. With `trace`
    // None every hook is a single branch.

    /// Attach this engine to a tracer (allocates its track). Tests use
    /// this for isolation; the CLI path attaches via the global sink at
    /// construction instead.
    pub fn set_tracer(&mut self, handle: TraceHandle) {
        self.trace = Some(EngineTrace::attach(handle));
    }

    /// The trace track (Perfetto process row) this engine records on.
    pub fn trace_track(&self) -> Option<u32> {
        self.trace.as_ref().map(|t| t.track)
    }

    /// Record one span/instant on this engine's track, resolving the
    /// engine-local id to the trace's global id (the `PREFIX_REQ`
    /// sentinel passes through as `u64::MAX`).
    fn trace_emit(&self, kind: EventKind, t0: f64, t1: f64, rid: ReqId, a: u64, b: u64, c: u64) {
        if let Some(et) = self.trace.as_ref() {
            et.handle.record(TraceRecord {
                t0,
                t1,
                kind,
                track: et.track,
                req: et.gid(rid),
                a,
                b,
                c,
            });
        }
    }

    /// Instant event at the engine's current clock.
    fn trace_instant(&self, kind: EventKind, rid: ReqId, a: u64, b: u64, c: u64) {
        let now = self.backend.clock().now();
        self.trace_emit(kind, now, now, rid, a, b, c);
    }

    /// Sample every gauge onto this engine's track at the current clock.
    /// Called at existing event boundaries only (arrivals, cluster heap
    /// services, fault events) — tracing introduces no events of its own.
    pub fn trace_sample_gauges(&self) {
        let Some(et) = self.trace.as_ref() else { return };
        let t = self.backend.clock().now();
        let track = et.track;
        let mut tracer = et.handle.lock();
        let mut g = |kind: GaugeKind, value: f64| {
            tracer.gauge(GaugeSample { t, track, kind, value });
        };
        g(GaugeKind::GpuFreeBlocks, self.kv.gpu.available() as f64);
        g(GaugeKind::HostFreeBlocks, self.kv.cpu.available() as f64);
        g(GaugeKind::DiskFreeBlocks, self.kv.disk.available() as f64);
        g(GaugeKind::QueueDepth, self.waiting.len() as f64);
        g(GaugeKind::WaitingTokens, self.view.waiting_tokens as f64);
        g(GaugeKind::RunningTokens, self.view.running_tokens as f64);
        g(GaugeKind::Slowdown, self.backend.slowdown());
        g(GaugeKind::PrefixGpuBlocks, self.kv.prefix_blocks_on(Residency::Gpu) as f64);
    }

    fn log_transition(
        &mut self,
        rid: ReqId,
        layer: usize,
        from: Residency,
        to: Residency,
        blocks: usize,
    ) {
        if self.trace.is_some() {
            self.trace_instant(
                EventKind::TierMove,
                rid,
                from.tier_index() as u64,
                to.tier_index() as u64,
                blocks as u64,
            );
        }
        if let Some(log) = self.transitions.as_mut() {
            log.push(TierTransition {
                t: self.backend.clock().now(),
                req: rid,
                layer,
                from: from.tier_index(),
                to: to.tier_index(),
                blocks,
            });
        }
    }

    /// Switch to recomputing every cached aggregate from scratch each step
    /// (and re-sorting `running`), with macro-stepping disabled. Slower,
    /// straightforward, and the reference the incremental path must match
    /// bit-for-bit.
    pub fn use_recompute_oracle(&mut self) {
        self.incremental = false;
        self.macro_steps = false;
        self.span_valid = false;
    }

    // --- faults & graceful drain ----------------------------------------

    /// Stop admission and export every unfinished request for
    /// re-submission elsewhere (failover, scale-down). Running requests
    /// are recompute-preempted first — their KV is released on every tier
    /// and in the backend — then the whole queue is popped. Completed
    /// records and all counters survive; `reopen_admission` re-arms the
    /// engine (e.g. after a crash window ends). Exported requests are
    /// sorted by local id, i.e. original submission order.
    pub fn drain(&mut self) -> Vec<DrainedRequest> {
        self.admission_open = false;
        self.span_valid = false;
        while let Some(&rid) = self.running.first() {
            self.preempt_recompute(rid);
        }
        // the crash this models physically loses the cached KV too — the
        // prefix cache must not survive a drain (and pools must be empty
        // afterwards, as the failover invariants assert)
        let cleared = self.kv.prefix_clear();
        self.stats.prefix_evictions += cleared as u64;
        let mut out = Vec::with_capacity(self.waiting.len());
        while let Some(rid) = self.waiting.pop_front() {
            self.view_pop_waiting(rid);
            let r = &mut self.requests[rid];
            r.phase = Phase::Finished; // terminal here; lives on via re-submit
            let committed = r.generated;
            let checkpointed = if self.stats.disk_fenced { 0 } else { r.last_ckpt };
            out.push(DrainedRequest {
                id: rid,
                arrival: r.arrival,
                prompt_len: r.prompt_len,
                output_len: r.output_len,
                prefix: r.prefix,
                committed,
                checkpointed,
            });
            self.trace_instant(EventKind::Drain, rid, committed as u64, checkpointed as u64, 0);
        }
        out.sort_by_key(|d| d.id);
        debug_assert!(!self.has_work());
        out
    }

    /// [`Engine::drain`], but each unfinished request is exported as a
    /// full [`RequestSnapshot`]: decode progress, timing history, the
    /// layer-wise tier residency its KV held, and (on real backends) the
    /// token streams. The snapshot captures running-request state
    /// *before* the drain's recompute-preemption tears the block tables
    /// down. Ids are engine-local, like `drain` — the caller owns the
    /// local -> global mapping. Execution side effects are bit-identical
    /// to `drain` (same preemptions, same trace instants, same stats).
    pub fn drain_with_state(&mut self) -> Vec<RequestSnapshot> {
        // Residency and backend tokens exist only while the request is
        // running; everything else survives the drain on the Request.
        let mut live: Vec<(ReqId, Vec<Residency>, Option<(Vec<i32>, Vec<i32>)>)> = self
            .running
            .iter()
            .map(|&rid| {
                let layers = self
                    .kv
                    .table(rid)
                    .map(|t| t.layers.iter().map(|e| e.residency).collect())
                    .unwrap_or_default();
                (rid, layers, self.backend.snapshot_tokens(rid))
            })
            .collect();
        self.drain()
            .into_iter()
            .map(|d| {
                let (layers, tokens) = match live.iter_mut().find(|(rid, ..)| *rid == d.id) {
                    Some((_, l, t)) => (std::mem::take(l), t.take()),
                    None => (Vec::new(), None),
                };
                let r = &self.requests[d.id];
                RequestSnapshot {
                    id: d.id,
                    arrival: d.arrival,
                    prompt_len: d.prompt_len,
                    output_len: d.output_len,
                    prefix: d.prefix,
                    generated: d.committed,
                    checkpointed: d.checkpointed,
                    prefill_start: r.prefill_start,
                    first_token: r.first_token,
                    preemptions: r.preemptions,
                    predicted: r.predicted,
                    layers,
                    tokens,
                }
            })
            .collect()
    }

    /// Re-open admission after a `drain` (a recovered replica).
    pub fn reopen_admission(&mut self) {
        self.admission_open = true;
    }

    /// Is the engine accepting `submit`s (i.e. not drained)?
    pub fn admission_open(&self) -> bool {
        self.admission_open
    }

    /// Fault injection: while set, every disk-tier spill/restore fails as
    /// an I/O error. `DISK_FENCE_K` consecutive errors fence the tier.
    pub fn set_disk_faulty(&mut self, faulty: bool) {
        self.disk_faulty = faulty;
        self.span_valid = false;
    }

    /// Set the backend's service-rate degradation factor (straggler
    /// injection). Routed through the engine — not the backend directly —
    /// so the cached horizon span, whose durations embed the old factor,
    /// is invalidated with it.
    pub fn set_slowdown(&mut self, factor: f64) {
        self.span_valid = false;
        self.backend.set_slowdown(factor);
    }

    /// Has the disk tier been fenced (retired after K consecutive errors)?
    pub fn disk_fenced(&self) -> bool {
        self.stats.disk_fenced
    }

    /// Record one disk-tier I/O failure; arms the fence at the K-th
    /// consecutive error. The fence itself trips at the next step boundary
    /// (`maybe_fence_disk`) because errors surface inside loops indexing
    /// `running`, where preempting in place would invalidate the walk.
    fn note_disk_error(&mut self) {
        self.stats.disk_io_errors += 1;
        self.disk_err_streak += 1;
        if self.disk_err_streak >= DISK_FENCE_K && self.kv.disk.total() > 0 {
            self.disk_fence_pending = true;
        }
    }

    /// Step-boundary check for an armed disk fence. A plain bool test on
    /// the fault-free path.
    fn maybe_fence_disk(&mut self) {
        if self.disk_fence_pending {
            self.fence_disk();
        }
    }

    /// Degraded mode: the disk tier is unreliable — take it out of
    /// service instead of looping on errors. Every request still holding
    /// disk-resident layers is recompute-preempted (its re-prefill needs
    /// no disk reads), which releases all disk blocks; then the pool is
    /// retired (`total() == 0`), which by construction makes every disk
    /// path unreachable: the scheduler's tiered admission, `never_fits`'
    /// tiered arm, `relieve_host_pressure`, and the host-spill watermark
    /// all key on `disk.total() > 0`. The engine is now exactly a
    /// two-tier + recompute machine.
    fn fence_disk(&mut self) {
        self.disk_fence_pending = false;
        if self.kv.disk.total() == 0 {
            return;
        }
        loop {
            let victim = self.running.iter().copied().find(|&r| {
                self.kv.table(r).map(|t| t.n_disk_layers() > 0).unwrap_or(false)
            });
            match victim {
                Some(rid) => self.preempt_recompute(rid),
                None => break,
            }
        }
        debug_assert_eq!(self.kv.disk.used(), 0, "preemptions must free the disk pool");
        self.kv.disk.retire();
        self.stats.disk_fenced = true;
    }

    /// Run a trace to completion; returns the latency report. Panics if
    /// the backend fails (the simulated backend never does); fallible
    /// backends drive `try_run`.
    pub fn run(&mut self, trace: &Trace) -> Report {
        self.try_run(trace).expect("execution backend failed")
    }

    /// Run a trace to completion; returns the latency report.
    pub fn try_run(&mut self, trace: &Trace) -> anyhow::Result<Report> {
        self.requests = trace
            .requests
            .iter()
            .map(|t| Request::from_trace(t, self.predictor.predict(t.id, t.output_len)))
            .collect();
        self.agg = RunningAggregates::default();
        self.view = LoadView::default();
        self.span_valid = false;
        let mut next_arrival = 0usize;
        // generous step bound: every token plus scheduling slack
        let max_steps = 1000 + 4 * trace.total_tokens() as u64;

        loop {
            // admit arrivals up to `now`
            let arrivals_before = next_arrival;
            while next_arrival < self.requests.len()
                && self.requests[next_arrival].arrival
                    <= self.backend.clock().now() + CLOCK_EPS
            {
                let rid = next_arrival;
                next_arrival += 1;
                if self.trace.is_some() {
                    let r = &self.requests[rid];
                    self.trace_emit(
                        EventKind::Arrive,
                        r.arrival,
                        r.arrival,
                        rid,
                        r.prompt_len as u64,
                        r.output_len as u64,
                        0,
                    );
                }
                if self.backend.supports_prompt(self.requests[rid].prompt_len) {
                    self.waiting.push_back(rid);
                    self.view_push_waiting(rid);
                } else {
                    // the executor can never run this prompt (e.g. exceeds
                    // every compiled prefill bucket): reject it instead of
                    // emitting a zero-length record that skews TTFT/TPOT
                    self.stats.dropped.push(rid);
                    self.requests[rid].phase = Phase::Finished;
                    self.trace_instant(EventKind::Drop, rid, 0, 0, 0);
                }
            }
            if self.trace.is_some() && next_arrival != arrivals_before {
                self.trace_sample_gauges();
            }
            // the macro-stepping event horizon: the next arrival instant
            let deadline = self
                .requests
                .get(next_arrival)
                .map(|r| r.arrival)
                .unwrap_or(f64::INFINITY);

            self.maybe_fence_disk();
            self.oracle_refresh();

            let action = {
                // §Perf: make_contiguous avoids a per-step Vec allocation
                let waiting = self.waiting.make_contiguous();
                let ctx = SchedContext {
                    now: self.backend.clock().now(),
                    waiting,
                    running: &self.running,
                    requests: &self.requests,
                    kv: &self.kv,
                    cost: &self.cost,
                    cfg: &self.cfg,
                };
                self.scheduler.decide(&ctx)
            };
            self.sched_invocations += 1;

            let mut steps_taken = 1u64;
            match action {
                Action::Prefill(reqs) => self.step_prefill(&reqs)?,
                Action::Decode => steps_taken = self.decode_or_fast_forward(deadline)?,
                Action::Wait => {
                    if let Some(&r) = self.waiting.front() {
                        // pool pressure from retained prefixes? free them
                        // and re-run the scheduler before giving up on r
                        if self.relieve_for_admission(r) {
                            continue;
                        }
                        // a request that can never fit (prompt KV exceeds the
                        // whole pool under this policy) would deadlock FCFS:
                        // reject it like a serving front-end would
                        if self.never_fits(r) {
                            self.waiting.pop_front();
                            self.view_pop_waiting(r);
                            self.stats.dropped.push(r);
                            self.requests[r].phase = Phase::Finished;
                            self.trace_instant(EventKind::Drop, r, 0, 0, 0);
                            continue;
                        }
                    }
                    if next_arrival < self.requests.len() {
                        let t = self.requests[next_arrival].arrival;
                        self.backend.clock_mut().wait_until(t);
                        continue;
                    }
                    if self.running.is_empty() && self.waiting.is_empty() {
                        break; // drained
                    }
                    if self.running.is_empty() && next_arrival >= self.requests.len() {
                        // waiting blocked forever (pool busy by nothing):
                        // cannot happen unless never_fits missed it
                        let r = self.waiting.pop_front().unwrap();
                        self.view_pop_waiting(r);
                        self.stats.dropped.push(r);
                        self.requests[r].phase = Phase::Finished;
                        self.trace_instant(EventKind::Drop, r, 0, 0, 0);
                    }
                }
            }

            self.stats.steps += steps_taken;
            if self.backend.bounded_steps() && self.stats.steps > max_steps {
                panic!(
                    "engine exceeded {max_steps} steps ({} waiting, {} running) — livelock",
                    self.waiting.len(),
                    self.running.len()
                );
            }
        }
        Ok(Report::new(std::mem::take(&mut self.records)))
    }

    /// Could `r` EVER be admitted on an empty machine under this policy?
    fn never_fits(&self, r: ReqId) -> bool {
        let len = self.requests[r].prefill_len();
        let per_layer = len.div_ceil(self.cfg.block_size);
        let l = self.cfg.model.n_layers;
        match self.cfg.policy {
            Policy::Vllm => per_layer * l > self.kv.gpu.total(),
            Policy::LayerKv { .. } if self.kv.disk.total() > 0 => {
                // tiered admission on an empty machine: the scheduler's
                // shared feasibility solve, fed the whole host pool
                let x0 = self.cost.min_resident_layers(len);
                let (x, host_layers) =
                    self.cost.tiered_admission(len, x0, per_layer, self.kv.cpu.total());
                per_layer * x > self.kv.gpu.total()
                    || per_layer * (l - x - host_layers) > self.kv.disk.total()
            }
            Policy::LayerKv { .. } => {
                let x = self.cost.min_resident_layers(len);
                per_layer * x > self.kv.gpu.total()
                    || per_layer * (l - x) > self.kv.cpu.total()
            }
        }
    }

    // --- incremental driving (the cluster/ lockstep API) ----------------
    //
    // `try_run` owns the whole trace and its arrival clock; a
    // `cluster::Cluster` instead owns arrival time itself and drives each
    // replica engine through `submit` + `step_once`. The two paths are
    // deliberately line-for-line parallel: a 1-replica cluster on a trace
    // must be bit-identical to `try_run` on the same trace
    // (`tests/prop_cluster.rs` asserts it).

    /// Enqueue one request at the engine's current time. The caller must
    /// have advanced the clock to (at least) the request's arrival via
    /// [`Engine::wait_until`]. Returns the engine-local id (dense, in
    /// submission order) — the caller keeps the local -> global mapping.
    pub fn submit(&mut self, tr: &TraceRequest, predicted: (usize, usize)) -> ReqId {
        debug_assert!(self.admission_open, "submit on a drained engine (reopen_admission first)");
        self.span_valid = false;
        let local: ReqId = self.requests.len();
        let mut r = Request::from_trace(tr, predicted);
        r.id = local;
        self.submitted_tokens += (tr.prompt_len + tr.output_len) as u64;
        let supported = self.backend.supports_prompt(r.prompt_len);
        self.requests.push(r);
        if let Some(et) = self.trace.as_mut() {
            et.bind(local, tr.id);
        }
        self.trace_emit(
            EventKind::Arrive,
            tr.arrival,
            tr.arrival,
            local,
            tr.prompt_len as u64,
            tr.output_len as u64,
            0,
        );
        if supported {
            self.waiting.push_back(local);
            self.view_push_waiting(local);
        } else {
            // mirrors try_run's arrival-time rejection of prompts the
            // executor can never run
            self.stats.dropped.push(local);
            self.requests[local].phase = Phase::Finished;
            self.trace_instant(EventKind::Drop, local, 0, 0, 0);
        }
        local
    }

    /// Adopt a request exported by another engine's
    /// [`Engine::drain_with_state`] (crash failover, live migration). The
    /// request keeps its identity and history — original arrival (so the
    /// eventual record's queueing latency includes the downtime),
    /// first-token instant, preemption count. When a durable checkpoint
    /// exists and this backend restores modeled KV, the layer-wise
    /// allocation is rebuilt through the same tiered admission solve a
    /// fresh prefill would use and the request re-enters the decode loop
    /// directly, paying only the checkpoint-read transfer — no recompute.
    /// Otherwise it degrades to recompute-preemption semantics: re-enter
    /// the queue `Preempted` and re-prefill prompt + generated-so-far
    /// (real backends replay deterministically from the adopted token
    /// streams). Returns `(engine-local id, tokens resumed without
    /// recompute)` — 0 resumed on the recompute path.
    pub fn adopt(&mut self, snap: &RequestSnapshot) -> (ReqId, usize) {
        debug_assert!(self.admission_open, "adopt on a drained engine (reopen_admission first)");
        self.span_valid = false;
        let local: ReqId = self.requests.len();
        let tr = TraceRequest {
            id: snap.id,
            arrival: snap.arrival,
            prompt_len: snap.prompt_len,
            output_len: snap.output_len,
            prefix: snap.prefix,
        };
        let mut r = Request::from_trace(&tr, snap.predicted);
        r.id = local;
        r.prefill_start = snap.prefill_start;
        r.first_token = snap.first_token;
        r.preemptions = snap.preemptions;
        self.submitted_tokens += (snap.prompt_len + snap.output_len) as u64;
        let supported = self.backend.supports_prompt(snap.prompt_len);
        self.requests.push(r);
        if let Some(et) = self.trace.as_mut() {
            et.bind(local, snap.id);
        }
        // install token streams first, even for drops — real backends
        // index their per-request lanes by the dense local id
        self.backend.adopt(local, snap.tokens.clone());
        if !supported {
            self.stats.dropped.push(local);
            self.requests[local].phase = Phase::Finished;
            self.trace_instant(EventKind::Drop, local, 0, 0, 0);
            return (local, 0);
        }
        let resume = snap.resumable();
        if resume > 0 && self.backend.supports_kv_restore() && self.kv.disk.total() > 0 {
            // the durable prefix (prompt + resumed tokens) re-enters the
            // tier hierarchy through the admission-path feasibility solve
            let len = snap.prompt_len + resume;
            let per_layer = len.div_ceil(self.cfg.block_size);
            let alloc = match self.cfg.policy {
                Policy::Vllm => self.kv.allocate_full(local, len),
                Policy::LayerKv { .. } => {
                    let x0 = self.cost.min_resident_layers(len);
                    let (x, _) = self
                        .cost
                        .tiered_admission(len, x0, per_layer, self.kv.cpu.available());
                    self.kv.allocate_layerwise(local, len, x)
                }
            };
            if alloc.is_ok() {
                let layers = self.cfg.model.n_layers;
                let now = self.backend.clock().now();
                {
                    let r = &mut self.requests[local];
                    r.generated = resume;
                    r.last_ckpt = resume;
                    r.phase = Phase::Decoding;
                    if r.prefill_start.is_none() {
                        r.prefill_start = Some(now);
                    }
                }
                // the checkpoint read is a real disk -> GPU transfer on
                // the adopting replica's critical path (unlike the write,
                // which rode the idle link)
                self.backend.clock_mut().advance(self.cost.disk_restore_time(len, layers));
                self.stats.adoptions += 1;
                self.stats.adopt_restore_bytes += len as f64
                    * layers as f64
                    * self.cfg.offload_bytes_per_token_layer()
                    / self.cfg.tp as f64;
                let ps = self.requests[local].prefill_start.unwrap();
                let reqs_ref = &self.requests;
                let pos = self
                    .running
                    .partition_point(|&o| reqs_ref[o].prefill_start.unwrap_or(0.0) <= ps);
                self.running.insert(pos, local);
                self.agg_admit(local);
                self.view_admit_running(local);
                self.trace_instant(
                    EventKind::Adopt,
                    local,
                    snap.generated as u64,
                    resume as u64,
                    0,
                );
                return (local, resume);
            }
        }
        // degraded adoption: no checkpoint (or no restore path / no room) —
        // re-enter the queue; decode progress survives via recompute
        // preemption semantics (re-prefill covers prompt + generated)
        if snap.generated > 0 {
            let r = &mut self.requests[local];
            r.generated = snap.generated;
            r.phase = Phase::Preempted;
        }
        self.waiting.push_back(local);
        self.view_push_waiting(local);
        self.trace_instant(EventKind::Adopt, local, snap.generated as u64, 0, 0);
        (local, 0)
    }

    /// One scheduling step of the incremental path with no arrival in
    /// sight: [`Engine::step_once_until`] at an infinite event horizon.
    /// Callers that step an engine *up to a known arrival instant* must
    /// use `step_once_until` with that instant instead — otherwise a
    /// macro-step can legitimately commit decode work past the arrival the
    /// caller was about to submit, which single-stepping would not.
    pub fn step_once(&mut self, draining: bool) -> anyhow::Result<bool> {
        self.step_once_until(draining, f64::INFINITY)
    }

    /// One scheduling step of the incremental path — the body of
    /// `try_run`'s loop with the arrival bookkeeping lifted out. Returns
    /// `Ok(true)` when state changed (a step ran or a hopeless request was
    /// dropped) and `Ok(false)` when the engine can make no progress until
    /// the caller submits more work (or, with `draining`, is fully
    /// drained). `draining` corresponds to `try_run` having exhausted its
    /// arrivals: a queue blocked with nothing running drops its head
    /// instead of waiting for input that will never come. `deadline` is
    /// the caller's next submit instant — the decode fast-forward horizon,
    /// exactly `try_run`'s next-arrival bound.
    pub fn step_once_until(&mut self, draining: bool, deadline: f64) -> anyhow::Result<bool> {
        self.span_valid = false;
        self.maybe_fence_disk();
        self.oracle_refresh();
        let action = {
            let waiting = self.waiting.make_contiguous();
            let ctx = SchedContext {
                now: self.backend.clock().now(),
                waiting,
                running: &self.running,
                requests: &self.requests,
                kv: &self.kv,
                cost: &self.cost,
                cfg: &self.cfg,
            };
            self.scheduler.decide(&ctx)
        };
        self.sched_invocations += 1;
        let mut steps_taken = 1u64;
        match action {
            Action::Prefill(reqs) => self.step_prefill(&reqs)?,
            Action::Decode => steps_taken = self.decode_or_fast_forward(deadline)?,
            Action::Wait => {
                if let Some(&r) = self.waiting.front() {
                    // mirror try_run: retained prefixes yield before any
                    // wait/drop verdict on the queue head
                    if self.relieve_for_admission(r) {
                        return Ok(true); // state changed: caller re-steps
                    }
                    if self.never_fits(r) {
                        self.waiting.pop_front();
                        self.view_pop_waiting(r);
                        self.stats.dropped.push(r);
                        self.requests[r].phase = Phase::Finished;
                        self.trace_instant(EventKind::Drop, r, 0, 0, 0);
                        return Ok(true); // try_run's `continue`: no step count
                    }
                }
                if self.running.is_empty() && self.waiting.is_empty() {
                    return Ok(false); // drained (try_run's `break`)
                }
                if !draining {
                    // blocked until new input; the caller advances the
                    // clock at the next submit (try_run's wait_until path)
                    return Ok(false);
                }
                if self.running.is_empty() {
                    // no arrivals will ever come: drop the blocked head,
                    // exactly as try_run does past its last arrival
                    let r = self.waiting.pop_front().unwrap();
                    self.view_pop_waiting(r);
                    self.stats.dropped.push(r);
                    self.requests[r].phase = Phase::Finished;
                    self.trace_instant(EventKind::Drop, r, 0, 0, 0);
                }
                // falls through to the step count, as in try_run
            }
        }
        self.stats.steps += steps_taken;
        let bound = 1000 + 4 * self.submitted_tokens;
        if self.backend.bounded_steps() && self.stats.steps > bound {
            panic!(
                "engine exceeded {bound} steps ({} waiting, {} running) — livelock",
                self.waiting.len(),
                self.running.len()
            );
        }
        Ok(true)
    }

    /// Engine time now (the backend clock).
    pub fn now(&self) -> f64 {
        self.backend.clock().now()
    }

    /// Advance the clock to `t` (never backwards) — the incremental
    /// equivalent of `try_run`'s idle-until-next-arrival jump.
    pub fn wait_until(&mut self, t: f64) {
        self.span_valid = false;
        self.backend.clock_mut().wait_until(t);
    }

    /// Anything queued or decoding?
    pub fn has_work(&self) -> bool {
        !self.running.is_empty() || !self.waiting.is_empty()
    }

    /// Completed-request records so far (appended in completion order).
    /// The cluster router reads TTFT feedback from the tail of this.
    pub fn records(&self) -> &[RequestRecord] {
        &self.records
    }

    /// Close out an incremental run: the same report `try_run` returns.
    pub fn take_report(&mut self) -> Report {
        Report::new(std::mem::take(&mut self.records))
    }

    // --- router-facing load views ---------------------------------------
    //
    // §Perf: all four aggregate views are O(1) reads of the `LoadView`
    // cache (a router calls every one of them per replica per arriving
    // request). The `*_scan` forms are the O(n) from-scratch oracles the
    // property suite validates the cache against.

    pub fn waiting_len(&self) -> usize {
        self.waiting.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Σ prefill tokens over the queue — the queued token demand a
    /// KV-pressure router scores against the pools. O(1).
    pub fn waiting_tokens(&self) -> usize {
        self.view.waiting_tokens
    }

    /// Σ context tokens over the running set (what decode iterations
    /// stream each step). O(1).
    pub fn running_tokens(&self) -> usize {
        self.view.running_tokens
    }

    /// Σ modeled prefill time over the queue — the prefill backlog an
    /// SLO-aware router counts as unavoidable delay ahead of a new
    /// request. O(1) (float add/sub cache; agrees with the scan to
    /// rounding, and is re-pinned to 0 whenever the queue drains).
    pub fn waiting_prefill_s(&self) -> f64 {
        self.view.waiting_prefill_s
    }

    /// Σ predicted-median remaining output tokens over the running set —
    /// the decode work outstanding before blocks free up. O(1).
    pub fn running_remaining_tokens(&self) -> usize {
        self.view.running_remaining_tokens
    }

    /// From-scratch oracle for [`Engine::waiting_tokens`].
    pub fn waiting_tokens_scan(&self) -> usize {
        self.waiting.iter().map(|&r| self.requests[r].prefill_len()).sum()
    }

    /// From-scratch oracle for [`Engine::running_tokens`].
    pub fn running_tokens_scan(&self) -> usize {
        self.running.iter().map(|&r| self.requests[r].context_len()).sum()
    }

    /// From-scratch oracle for [`Engine::waiting_prefill_s`].
    pub fn waiting_prefill_s_scan(&self) -> f64 {
        self.waiting.iter().map(|&r| self.cost.prefill_time(self.requests[r].prefill_len())).sum()
    }

    /// From-scratch oracle for [`Engine::running_remaining_tokens`].
    pub fn running_remaining_tokens_scan(&self) -> usize {
        self.running
            .iter()
            .map(|&r| {
                let req = &self.requests[r];
                req.predicted_median().saturating_sub(req.generated)
            })
            .sum()
    }

    // --- load-view upkeep ------------------------------------------------

    /// A request entered the queue (arrival admission, submit, or a
    /// recompute preemption's re-queue — its phase is already `Preempted`
    /// there, so `prefill_len` includes the generated tokens, matching
    /// what the scan would count).
    fn view_push_waiting(&mut self, rid: ReqId) {
        let len = self.requests[rid].prefill_len();
        self.view.waiting_tokens += len;
        self.view.waiting_prefill_s += self.cost.prefill_time(len);
    }

    /// A request left the queue (admission or drop); call after the
    /// removal but before any phase change, so `prefill_len` matches what
    /// `view_push_waiting` added.
    fn view_pop_waiting(&mut self, rid: ReqId) {
        let len = self.requests[rid].prefill_len();
        self.view.waiting_tokens -= len;
        self.view.waiting_prefill_s -= self.cost.prefill_time(len);
        if self.waiting.is_empty() {
            // pin the float sum back to exactly zero so subtraction
            // rounding cannot accumulate across queue cycles
            self.view.waiting_prefill_s = 0.0;
        }
    }

    /// A request joined the running set (post-allocation, pre-first-token).
    fn view_admit_running(&mut self, rid: ReqId) {
        let r = &self.requests[rid];
        self.view.running_tokens += r.context_len();
        self.view.running_remaining_tokens +=
            r.predicted_median().saturating_sub(r.generated);
    }

    /// A request is leaving the running set (finish or preemption); call
    /// before its timing fields change.
    fn view_remove_running(&mut self, rid: ReqId) {
        let r = &self.requests[rid];
        self.view.running_tokens -= r.context_len();
        self.view.running_remaining_tokens -=
            r.predicted_median().saturating_sub(r.generated);
    }

    /// A running request generated one more token; call AFTER its
    /// `generated` was incremented.
    fn view_append_token(&mut self, rid: ReqId) {
        self.view.running_tokens += 1;
        let r = &self.requests[rid];
        // remaining = median.saturating_sub(generated) only shrinks while
        // generated has not passed the predicted median
        if r.generated <= r.predicted_median() {
            self.view.running_remaining_tokens -= 1;
        }
    }

    // --- incremental-state upkeep --------------------------------------

    /// Oracle mode: re-derive everything the incremental path maintains.
    fn oracle_refresh(&mut self) {
        if self.incremental {
            return;
        }
        let reqs = &self.requests;
        self.running.sort_by(|&a, &b| {
            let ta = reqs[a].prefill_start.unwrap_or(0.0);
            let tb = reqs[b].prefill_start.unwrap_or(0.0);
            // total order: a NaN timestamp (which would be a bug upstream)
            // sorts last instead of panicking mid-run
            ta.total_cmp(&tb)
        });
        self.agg = RunningAggregates::recompute(&self.running, &self.requests, &self.kv);
    }

    /// A request joined `running` (post-allocation).
    fn agg_admit(&mut self, rid: ReqId) {
        if self.incremental
            && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
        {
            self.agg.resident_count += 1;
            self.agg.resident_tokens += self.requests[rid].context_len();
        }
    }

    /// A request is about to leave `running` (finish or preemption); must
    /// run while its KV table still exists.
    fn agg_remove(&mut self, rid: ReqId) {
        if self.incremental
            && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
        {
            self.agg.resident_count -= 1;
            self.agg.resident_tokens -= self.requests[rid].context_len();
        }
    }

    /// Offload with aggregate upkeep and backend mirroring: a formerly
    /// fully-resident request drops out of the decode batch, and a real
    /// backend moves the layer's tensor to the host pool. When the host
    /// pool itself is full and a disk tier exists, cold host layers spill
    /// one level further down and the offload retries.
    fn kv_offload(&mut self, rid: ReqId, layer: usize) -> Result<usize, KvError> {
        let was_resident =
            self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false);
        let mut out = self.kv.offload_layer(rid, layer);
        if out == Err(KvError::CpuExhausted) && self.kv.disk.total() > 0 {
            let need = self
                .kv
                .table(rid)
                .map(|t| t.layers[layer].blocks.len())
                .unwrap_or(0);
            if need > 0 && self.relieve_host_pressure(need) {
                out = self.kv.offload_layer(rid, layer);
            }
        }
        if let Ok(n) = out {
            if n > 0 {
                self.backend.offload_layer(rid, layer);
                self.log_transition(rid, layer, Residency::Gpu, Residency::Cpu, n);
                if self.incremental && was_resident {
                    self.agg.resident_count -= 1;
                    self.agg.resident_tokens -= self.requests[rid].context_len();
                }
            }
        }
        out
    }

    /// Onload with aggregate upkeep and backend mirroring: a request whose
    /// last parked layer returns becomes decode-batch eligible again.
    fn kv_onload(&mut self, rid: ReqId, layer: usize) -> Result<usize, KvError> {
        let out = self.kv.onload_layer(rid, layer);
        if let Ok(n) = out {
            if n > 0 {
                self.backend.onload_layer(rid, layer);
                self.log_transition(rid, layer, Residency::Cpu, Residency::Gpu, n);
                if self.incremental
                    && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
                {
                    self.agg.resident_count += 1;
                    self.agg.resident_tokens += self.requests[rid].context_len();
                }
            }
        }
        out
    }

    /// Bytes one layer of `rid`'s KV occupies on the wire (token-exact,
    /// matching the admission-path accounting — NOT block-rounded).
    fn layer_wire_bytes(&self, rid: ReqId) -> f64 {
        let tokens = self.kv.table(rid).map(|t| t.tokens).unwrap_or(0);
        tokens as f64 * self.cfg.offload_bytes_per_token_layer() / self.cfg.tp as f64
    }

    /// Virtual incremental checkpointing: after `rid`'s committed-token
    /// count grows, advance its durable point to the latest crossing of
    /// the `ckpt_every_tokens` grid (plus an initial point at token 1, so
    /// the expensive prefill becomes durable as soon as any decode
    /// progress exists). Writes are *virtual*: bytes and disk-link
    /// seconds are accounted in the `ckpt_*` stats — priced through the
    /// same wire-bytes model as spills — but the clock never advances, so
    /// checkpointing on is execution-bit-identical to off
    /// (`tests/prop_migration.rs` pins this). The durable point after any
    /// commit depends only on `generated`, never on how commits were
    /// chunked, so lockstep and heap drives agree on every snapshot.
    /// Skipped while the disk tier is faulty, fenced, or absent — a
    /// checkpoint nobody could read back is not durability.
    fn maybe_checkpoint(&mut self, rid: ReqId) {
        let k = self.cfg.ckpt_every_tokens;
        if k == 0 {
            return;
        }
        if self.disk_faulty || self.kv.disk.total() == 0 {
            return;
        }
        let r = &self.requests[rid];
        let g = r.generated;
        let target = if g >= k { g - g % k } else { usize::from(g >= 1) };
        if target <= r.last_ckpt {
            return;
        }
        // incremental: the first write covers the prompt too (the whole
        // durable prefix), later writes only the tokens since the last
        let delta =
            if r.last_ckpt == 0 { r.prompt_len + target } else { target - r.last_ckpt };
        let layers = self.cfg.model.n_layers;
        self.requests[rid].last_ckpt = target;
        self.stats.ckpt_writes += 1;
        self.stats.ckpt_bytes += delta as f64
            * layers as f64
            * self.cfg.offload_bytes_per_token_layer()
            / self.cfg.tp as f64;
        self.stats.ckpt_write_s += self.cost.spill_time(delta, layers);
        self.trace_instant(EventKind::Checkpoint, rid, target as u64, delta as u64, 0);
    }

    /// Spill with backend mirroring and stats: host -> disk. Decode-batch
    /// membership is unaffected — a host layer was already non-resident.
    /// `Ok(0)` on a disk-tier I/O failure (injected or reported by the
    /// backend's write hook): the layer stays host-resident, the error
    /// counts toward the fence, and the caller sees "no progress".
    fn kv_spill(&mut self, rid: ReqId, layer: usize) -> Result<usize, KvError> {
        if self.disk_faulty {
            self.note_disk_error();
            return Ok(0);
        }
        let out = self.kv.spill_layer(rid, layer);
        if let Ok(n) = out {
            if n > 0 {
                if self.backend.spill_layer(rid, layer).is_err() {
                    // the write failed: the layer never left the host.
                    // Roll the block accounting back (infallible — the
                    // host blocks the spill just freed are still free).
                    let rolled = self.kv.unspill_layer(rid, layer);
                    debug_assert!(matches!(rolled, Ok(m) if m == n));
                    self.note_disk_error();
                    return Ok(0);
                }
                self.disk_err_streak = 0;
                self.log_transition(rid, layer, Residency::Cpu, Residency::Disk, n);
                self.stats.spilled_layers += 1;
                self.stats.spill_bytes += self.layer_wire_bytes(rid);
            }
        }
        out
    }

    /// Deep restore with aggregate upkeep: disk -> GPU directly (a disk
    /// read plus the h2d copy; `disk_restore_bytes` tracks the deep leg).
    /// `Ok(0)` on a disk-tier I/O failure, as in `kv_spill`: the layer
    /// stays disk-resident and the error counts toward the fence.
    fn kv_promote_disk(&mut self, rid: ReqId, layer: usize) -> Result<usize, KvError> {
        if self.disk_faulty {
            self.note_disk_error();
            return Ok(0);
        }
        let out = self.kv.promote_disk_layer(rid, layer);
        if let Ok(n) = out {
            if n > 0 {
                if self.backend.promote_disk_layer(rid, layer).is_err() {
                    // the disk read failed: the bytes never moved. Undo
                    // the accounting (infallible — the disk blocks the
                    // promote just freed are still free).
                    let rolled = self.kv.demote_gpu_layer_to_disk(rid, layer);
                    debug_assert!(matches!(rolled, Ok(m) if m == n));
                    self.note_disk_error();
                    return Ok(0);
                }
                self.disk_err_streak = 0;
                self.log_transition(rid, layer, Residency::Disk, Residency::Gpu, n);
                self.stats.disk_promoted_layers += 1;
                self.stats.disk_restore_bytes += self.layer_wire_bytes(rid);
                if self.incremental
                    && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
                {
                    self.agg.resident_count += 1;
                    self.agg.resident_tokens += self.requests[rid].context_len();
                }
            }
        }
        out
    }

    /// Host pool under pressure: spill parked (host-resident) layers of
    /// the most recently prefilled requests — the coldest tables, farthest
    /// from completion — down to the disk tier until `need` host blocks
    /// have been freed. Returns false without mutating anything in the
    /// two-tier configuration (no disk pool).
    fn relieve_host_pressure(&mut self, need: usize) -> bool {
        // prefix-cache entries go first (spill to disk or fall out of the
        // cache entirely) — even in the two-tier configuration, where live
        // tables have nowhere to spill but cache entries can simply die
        let mut freed = self.demote_prefix_host(need);
        if freed >= need {
            return true;
        }
        if self.kv.disk.total() == 0 {
            return false;
        }
        let n_layers = self.cfg.model.n_layers;
        for vi in (0..self.running.len()).rev() {
            let v = self.running[vi];
            for layer in 0..n_layers {
                if freed >= need {
                    return true;
                }
                let Some(t) = self.kv.table(v) else { break };
                if t.layers[layer].residency != Residency::Cpu {
                    continue;
                }
                match self.kv_spill(v, layer) {
                    Ok(n) if n > 0 => freed += n,
                    _ => return freed >= need, // disk full: stop spilling
                }
            }
        }
        freed >= need
    }

    // --- cross-request prefix cache -------------------------------------
    //
    // All five hooks are bit-invisible unless `cfg.prefix_cache` is on AND
    // the trace carries non-zero prefix keys: with either absent the store
    // stays empty, every early-return fires, and no pool observable moves
    // — the property suite pins the engine to the frozen oracle on exactly
    // that claim.

    /// Admission-time lookup for `rid` (prefill length `len`), called
    /// after its table was allocated and before the backend prices the
    /// prefill. On a hit, `cached_prefix` tells the backend how many
    /// prompt tokens to skip; host/disk hits add the restore transfer to
    /// the batch duration and byte counters.
    fn acquire_prefix(&mut self, rid: ReqId, len: usize, duration: &mut f64) {
        if !self.cfg.prefix_cache {
            return;
        }
        let key = self.requests[rid].prefix;
        if key.hash == 0 {
            return;
        }
        // always recompute at least the final prompt token: prefill must
        // emit token 1, and the scheduler's estimate mirrors this cap
        let want = key.len.min(len.saturating_sub(1));
        match self.kv.prefix_acquire(key.hash, want) {
            Some(hit) => {
                self.requests[rid].cached_prefix = hit.tokens;
                self.stats.prefix_hits += 1;
                self.stats.prefix_hit_tokens += hit.tokens as u64;
                self.trace_instant(
                    EventKind::PrefixHit,
                    rid,
                    hit.tokens as u64,
                    hit.tier.tier_index() as u64,
                    0,
                );
                let layers = self.cfg.model.n_layers;
                match hit.tier {
                    Residency::Gpu => {}
                    Residency::Cpu => {
                        *duration += self.cost.onload_time(hit.tokens, layers);
                        self.stats.prefix_restore_bytes +=
                            self.prefix_wire_bytes(hit.tokens);
                    }
                    Residency::Disk => {
                        *duration += self.cost.disk_restore_time(hit.tokens, layers);
                        self.stats.prefix_restore_bytes +=
                            self.prefix_wire_bytes(hit.tokens);
                    }
                }
                if hit.promoted {
                    self.stats.prefix_promotions += 1;
                    self.log_transition(
                        PREFIX_REQ,
                        0,
                        hit.tier,
                        Residency::Gpu,
                        hit.blocks,
                    );
                }
            }
            None => self.stats.prefix_misses += 1,
        }
    }

    /// Drop `rid`'s lease if it holds one (`cached_prefix` doubles as the
    /// live-lease marker — set only by a successful acquire).
    fn release_prefix_lease(&mut self, rid: ReqId) {
        if self.requests[rid].cached_prefix > 0 {
            let hash = self.requests[rid].prefix.hash;
            self.kv.prefix_release(hash);
            self.requests[rid].cached_prefix = 0;
        }
    }

    /// Publish `rid`'s final context into the cache at completion.
    fn publish_prefix(&mut self, rid: ReqId) {
        if !self.cfg.prefix_cache {
            return;
        }
        let key = self.requests[rid].prefix;
        if key.publish == 0 {
            return;
        }
        let out = self.kv.prefix_publish(key.publish, self.requests[rid].context_len());
        if out.inserted {
            self.stats.prefix_inserts += 1;
        }
        self.stats.prefix_evictions += out.evicted as u64;
    }

    /// Bytes `tokens` of cached KV occupy on the wire across all layers
    /// (token-exact, matching `layer_wire_bytes`' accounting).
    fn prefix_wire_bytes(&self, tokens: usize) -> f64 {
        tokens as f64
            * self.cfg.model.n_layers as f64
            * self.cfg.offload_bytes_per_token_layer()
            / self.cfg.tp as f64
    }

    /// Demote GPU-resident cache entries until `need` blocks free (or the
    /// cache is out of GPU blocks). O(1) bail when the cache holds no GPU
    /// blocks, so the pre-cache hot paths are untouched.
    fn demote_prefix_gpu(&mut self, need: usize) -> usize {
        if self.kv.prefix_blocks_on(Residency::Gpu) == 0 {
            return 0;
        }
        let mut moves = Vec::new();
        let freed = self.kv.prefix_demote_gpu(need, &mut moves);
        self.note_prefix_moves(&moves);
        freed
    }

    /// Host-tier analog of [`Engine::demote_prefix_gpu`].
    fn demote_prefix_host(&mut self, need: usize) -> usize {
        if self.kv.prefix_blocks_on(Residency::Cpu) == 0 {
            return 0;
        }
        let mut moves = Vec::new();
        let freed = self.kv.prefix_demote_host(need, &mut moves);
        self.note_prefix_moves(&moves);
        freed
    }

    /// Fold a batch of cache demotions into the stats and the transition
    /// log (`PREFIX_REQ` sentinel rows; outright evictions have no
    /// destination tier and only count).
    fn note_prefix_moves(&mut self, moves: &[PrefixMove]) {
        for m in moves {
            match m.to {
                Some(to) => {
                    self.stats.prefix_demotions += 1;
                    self.log_transition(PREFIX_REQ, 0, m.from, to, m.blocks);
                }
                None => self.stats.prefix_evictions += 1,
            }
        }
    }

    /// The scheduler returned `Wait` with `head` at the front of the
    /// queue. If the cache is holding blocks the admission may need,
    /// demote cache entries and report true so the caller re-runs the
    /// scheduler on the roomier pools — a retained prefix must never
    /// starve (or force the drop of) a live request. Terminates: every
    /// true return strictly shrinks the cache's GPU/host footprint, and
    /// nothing repopulates it while the queue is blocked.
    fn relieve_for_admission(&mut self, head: ReqId) -> bool {
        if !self.cfg.prefix_cache {
            return false;
        }
        let demand =
            self.requests[head].prefill_len().div_ceil(self.cfg.block_size)
                * self.cfg.model.n_layers;
        let mut freed = 0usize;
        if self.kv.gpu.available() < demand {
            freed += self.demote_prefix_gpu(demand - self.kv.gpu.available());
        }
        if self.kv.cpu.available() < demand {
            freed += self.demote_prefix_host(demand - self.kv.cpu.available());
        }
        freed > 0
    }

    // --- decode fast-forward (macro-stepping) ---------------------------

    /// The shared `Action::Decode` arm of `try_run` and `step_once_until`
    /// (one body, so the two drive paths cannot desynchronize): try the
    /// macro-step first, fall back to one single step, and return the
    /// engine steps consumed for the caller's step accounting.
    fn decode_or_fast_forward(&mut self, deadline: f64) -> anyhow::Result<u64> {
        // 0 = not stable / horizon too short: run the single-step path
        let k = self.fast_forward_decode(deadline);
        if k == 0 {
            self.step_decode()?;
            return Ok(1);
        }
        Ok(k)
    }

    /// The scheduler just returned `Action::Decode`. If the machine is
    /// *stable* — queue empty, nothing parked on host or disk (so every
    /// running request is fully GPU-resident, `restore_layers` and the
    /// host spill watermark are no-ops, and the decode batch is the whole
    /// running set) — solve the event horizon and commit all `k`
    /// iterations up to it in one macro-step. Returns the number of engine
    /// steps committed; 0 means "not applicable, run the single-step
    /// path". Bit-identical to `k` single steps by construction
    /// (`tests/prop_fastforward.rs` drives the proof).
    fn fast_forward_decode(&mut self, deadline: f64) -> u64 {
        if !self.macro_steps || !self.incremental || !self.backend.supports_fast_forward()
        {
            return 0;
        }
        if !self.waiting.is_empty() || self.kv.cpu.used() != 0 || self.kv.disk.used() != 0
        {
            return 0;
        }
        let batch = self.running.len();
        if batch == 0 || batch > self.backend.max_decode_lanes() {
            return 0;
        }
        // nothing parked anywhere => every table is fully GPU-resident
        debug_assert_eq!(self.agg.resident_count, batch);
        let bs = self.kv.block_size;
        self.ff_hist.clear();
        self.ff_hist.resize(bs, 0);
        let mut min_remaining = usize::MAX;
        for &rid in &self.running {
            let Some(t) = self.kv.table(rid) else { return 0 };
            self.ff_hist[t.tokens % bs] += 1;
            let r = &self.requests[rid];
            min_remaining = min_remaining.min(r.output_len.saturating_sub(r.generated));
        }
        if min_remaining <= 1 {
            return 0; // a completion lands this very step: single-step it
        }
        let k = decode_horizon(
            &HorizonInputs {
                now: self.backend.clock().now(),
                deadline,
                resident_tokens: self.agg.resident_tokens,
                batch,
                gpu_available: self.kv.gpu.available(),
                gpu_total: self.kv.gpu.total(),
                n_layers: self.cfg.model.n_layers,
                offload_gate: matches!(self.cfg.policy, Policy::LayerKv { .. }),
                cost: &self.cost,
            },
            min_remaining - 1, // stop strictly before the first completion
            &self.ff_hist,
            &mut self.ff_durations,
        );
        if k < 2 {
            return 0; // nothing to skip: keep the single-step path hot
        }
        self.commit_fast_forward(k);
        k as u64
    }

    /// Commit `k` horizon-cleared decode iterations at once. The clock and
    /// the scheduler's TPOT feedback replay the solver's recorded per-step
    /// duration sequence exactly (float accumulation order is semantics,
    /// and the cost model is evaluated once per step, in the solver); the
    /// block tables take one bulk `alloc_span` per request instead of `k`
    /// `append_token`s.
    fn commit_fast_forward(&mut self, k: usize) {
        debug_assert_eq!(self.ff_durations.len(), k);
        let batch = self.running.len();
        let span_begin = self.backend.clock().now();
        #[cfg(debug_assertions)]
        let (now0, ctx0) = (span_begin, self.agg.resident_tokens);
        for &d in &self.ff_durations {
            self.backend.clock_mut().advance(d);
            self.scheduler.observe_decode_step(d);
        }
        if self.trace.is_some() {
            // the whole macro-step renders as one decode span per request
            let t1 = self.backend.clock().now();
            for &rid in &self.running {
                self.trace_emit(
                    EventKind::Decode,
                    span_begin,
                    t1,
                    rid,
                    k as u64,
                    self.agg.resident_tokens as u64,
                    0,
                );
            }
        }
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            self.backend.clock().now().to_bits(),
            self.cost.decode_span_end(now0, ctx0, batch, k).to_bits(),
            "macro-step clock must equal the closed-form span end"
        );
        for i in 0..self.running.len() {
            let rid = self.running[i];
            self.kv
                .alloc_span(rid, k)
                .expect("horizon solver cleared the span's block growth");
            let r = &mut self.requests[rid];
            let consumed = r.predicted_median().saturating_sub(r.generated).min(k);
            r.generated += k;
            debug_assert!(!r.done(), "horizon must stop before any completion");
            self.view.running_tokens += k;
            self.view.running_remaining_tokens -= consumed;
            self.maybe_checkpoint(rid);
        }
        self.agg.resident_tokens += k * batch;
        self.stats.decode_steps += k as u64;
    }

    // --- cached horizon span (cluster event-heap support) ---------------
    //
    // The cluster's event heap needs each replica's *next event horizon* —
    // the earliest instant its state can change on its own — without
    // committing anything. On a stable machine that instant is the end of
    // the decode span the horizon solver would clear, so we cache one
    // uncommitted solve (at an infinite deadline, capped at
    // `min_remaining - 1`) and commit deadline-bounded chunks of it as the
    // heap advances this replica. Bit-identity with the lockstep drive
    // rests on three facts, each already load-bearing in PR 5:
    //
    // 1. *Skipping the stable decide is unobservable.* With the queue
    //    empty and a non-empty running set, every scheduler returns
    //    `Action::Decode` unconditionally, and `decide`'s only mutations
    //    are idempotent caches. `sched_invocations` is deliberately not
    //    part of `EngineStats`.
    // 2. *The deadline only adds stop points.* The solver walks the same
    //    per-step duration sequence whatever the deadline; a finite
    //    deadline merely truncates it at the first step whose start
    //    violates `deadline > t + CLOCK_EPS` — the exact condition
    //    `commit_span_until` re-applies per chunk. So the ∞-solve
    //    committed in deadline-bounded chunks covers the same iteration
    //    set, with the same floats, as lockstep's repeated
    //    deadline-bounded solves between the same sync instants.
    // 3. *Chunked commits compose.* `alloc_span(a)` then `alloc_span(b)`
    //    equals `alloc_span(a + b)` (PR 5 free-list discipline); the
    //    clock/TPOT-EMA floats accumulate per step in the same order;
    //    `consumed = min(remaining, c)` chunks compose; and a chunk of 1
    //    equals `step_decode` on a stable machine (PR 5's property test).
    //
    // Any state perturbation — a submit, a drain, a fault toggle, a
    // slowdown change, or an ordinary `step_once_until` — invalidates the
    // cache; `plan_span` re-solves lazily on the next query.

    /// Nothing queued, nothing running, no armed disk fence: the engine
    /// cannot change state until the caller submits work (an armed fence
    /// *would* fire at the next step boundary, so it counts as work).
    fn quiescent(&self) -> bool {
        !self.has_work() && !self.disk_fence_pending
    }

    /// Solve and cache an uncommitted stable decode span. Returns false —
    /// leaving the cache invalid — when the machine is not in the stable
    /// regime (`fast_forward_decode`'s preconditions) or the horizon is
    /// empty.
    fn plan_span(&mut self) -> bool {
        self.span_valid = false;
        if !self.macro_steps || !self.incremental || !self.backend.supports_fast_forward()
        {
            return false;
        }
        if self.disk_fence_pending
            || !self.waiting.is_empty()
            || self.kv.cpu.used() != 0
            || self.kv.disk.used() != 0
        {
            return false;
        }
        let batch = self.running.len();
        if batch == 0 || batch > self.backend.max_decode_lanes() {
            return false;
        }
        debug_assert_eq!(self.agg.resident_count, batch);
        let bs = self.kv.block_size;
        self.ff_hist.clear();
        self.ff_hist.resize(bs, 0);
        let mut min_remaining = usize::MAX;
        for &rid in &self.running {
            let Some(t) = self.kv.table(rid) else { return false };
            self.ff_hist[t.tokens % bs] += 1;
            let r = &self.requests[rid];
            min_remaining = min_remaining.min(r.output_len.saturating_sub(r.generated));
        }
        if min_remaining <= 1 {
            return false; // a completion lands this very step: single-step it
        }
        let k = decode_horizon(
            &HorizonInputs {
                now: self.backend.clock().now(),
                deadline: f64::INFINITY,
                resident_tokens: self.agg.resident_tokens,
                batch,
                gpu_available: self.kv.gpu.available(),
                gpu_total: self.kv.gpu.total(),
                n_layers: self.cfg.model.n_layers,
                offload_gate: matches!(self.cfg.policy, Policy::LayerKv { .. }),
                cost: &self.cost,
            },
            min_remaining - 1, // stop strictly before the first completion
            &self.ff_hist,
            &mut self.span_durs,
        );
        if k == 0 {
            return false;
        }
        // Cache the span's landing instant by the same sequential float
        // accumulation the chunk commits will replay, so a replica popped
        // at its horizon lands on `span_end` to the bit — and horizon
        // queries stay O(1) instead of re-summing the tail.
        let mut t = self.backend.clock().now();
        for &d in &self.span_durs {
            t += d;
        }
        self.span_end = t;
        self.span_pos = 0;
        self.span_valid = true;
        true
    }

    /// Commit the cached span's iterations whose *start* lies strictly
    /// before `deadline` (the solver's own stop rule). Returns the number
    /// of decode iterations committed; 0 means no span applies here and
    /// the caller should take the ordinary scheduling path.
    fn commit_span_until(&mut self, deadline: f64) -> u64 {
        if !self.span_valid && !self.plan_span() {
            return 0;
        }
        let mut c = 0usize;
        let mut t = self.backend.clock().now();
        while self.span_pos + c < self.span_durs.len() && deadline > t + CLOCK_EPS {
            t += self.span_durs[self.span_pos + c];
            c += 1;
        }
        if c == 0 {
            return 0;
        }
        self.commit_span_chunk(c);
        if self.span_pos >= self.span_durs.len() {
            self.span_valid = false;
        }
        c as u64
    }

    /// `commit_fast_forward` for a mid-span chunk: same per-step clock and
    /// TPOT replay, same bulk allocation, plus the `stats.steps` the
    /// lockstep drive would have counted through its `step_once_until`
    /// wrapper (there is no wrapper call here to count them).
    fn commit_span_chunk(&mut self, c: usize) {
        debug_assert!(self.span_valid && self.span_pos + c <= self.span_durs.len());
        let batch = self.running.len();
        let span_begin = self.backend.clock().now();
        #[cfg(debug_assertions)]
        let (now0, ctx0) = (span_begin, self.agg.resident_tokens);
        for i in 0..c {
            let d = self.span_durs[self.span_pos + i];
            self.backend.clock_mut().advance(d);
            self.scheduler.observe_decode_step(d);
        }
        if self.trace.is_some() {
            // a heap-driven span chunk renders as one decode span, same
            // shape as the lockstep macro-step it replaces
            let t1 = self.backend.clock().now();
            for &rid in &self.running {
                self.trace_emit(
                    EventKind::Decode,
                    span_begin,
                    t1,
                    rid,
                    c as u64,
                    self.agg.resident_tokens as u64,
                    0,
                );
            }
        }
        #[cfg(debug_assertions)]
        debug_assert_eq!(
            self.backend.clock().now().to_bits(),
            self.cost.decode_span_end(now0, ctx0, batch, c).to_bits(),
            "span chunk clock must equal the closed-form span end"
        );
        for i in 0..self.running.len() {
            let rid = self.running[i];
            self.kv
                .alloc_span(rid, c)
                .expect("horizon solver cleared the span's block growth");
            let r = &mut self.requests[rid];
            let consumed = r.predicted_median().saturating_sub(r.generated).min(c);
            r.generated += c;
            debug_assert!(!r.done(), "horizon must stop before any completion");
            self.view.running_tokens += c;
            self.view.running_remaining_tokens -= consumed;
            self.maybe_checkpoint(rid);
        }
        self.agg.resident_tokens += c * batch;
        self.stats.decode_steps += c as u64;
        self.stats.steps += c as u64;
        self.span_pos += c;
    }

    /// The earliest instant this engine's state can change without new
    /// input: `INFINITY` when quiescent, the cached span's landing instant
    /// when the stable regime applies, else `now()` (meaning: the cluster
    /// must drive an ordinary step to find out). Commits nothing.
    pub fn next_event_horizon(&mut self) -> f64 {
        if self.quiescent() {
            return f64::INFINITY;
        }
        if !self.span_valid && !self.plan_span() {
            return self.now();
        }
        self.span_end
    }

    /// Advance this engine to `t` exactly as the lockstep cluster drive
    /// would (`while t > now + CLOCK_EPS { step_once_until(draining, t) }`),
    /// but committing cached span chunks in place of the scheduler-bearing
    /// steps they replace. Returns the number of scheduler-bearing steps
    /// actually taken (the cluster's `advances` metric — span chunks count
    /// zero).
    pub fn advance_until(&mut self, t: f64, draining: bool) -> anyhow::Result<u64> {
        let mut decides = 0u64;
        while t > self.backend.clock().now() + CLOCK_EPS {
            if self.commit_span_until(t) > 0 {
                continue;
            }
            if self.quiescent() {
                break; // idle: the clock advances at the next submit
            }
            decides += 1;
            if !self.step_once_until(draining, t)? {
                break; // blocked until new input
            }
        }
        Ok(decides)
    }

    /// Service this engine's own heap event at instant `t`: advance to
    /// `t`, then take the one deadline-bounded scheduling step the
    /// lockstep drive would take at the next external sync `cap` — the
    /// identical call, on identical state, it would make there. Returns
    /// (scheduler-bearing steps taken, whether the forced step progressed)
    /// — `false` means the engine is blocked (or quiescent) and must not
    /// be re-armed until the next external touch, which keeps the heap
    /// loop free of zero-progress spins.
    pub fn service_horizon_event(
        &mut self,
        t: f64,
        cap: f64,
        draining: bool,
    ) -> anyhow::Result<(u64, bool)> {
        let mut decides = self.advance_until(t, draining)?;
        if self.quiescent() {
            return Ok((decides, false));
        }
        decides += 1;
        let progressed = self.step_once_until(draining, cap)?;
        Ok((decides, progressed))
    }

    // --- prefill -------------------------------------------------------

    fn step_prefill(&mut self, reqs: &[(ReqId, usize)]) -> anyhow::Result<()> {
        let mut duration = 0.0;
        let mut offload_bytes = 0.0;
        let mut spill_bytes = 0.0;
        for &(rid, x) in reqs {
            let len = self.requests[rid].prefill_len();
            let alloc = match self.cfg.policy {
                Policy::Vllm => self.kv.allocate_full(rid, len),
                Policy::LayerKv { .. } => self.kv.allocate_layerwise(rid, len, x),
            };
            if alloc.is_err() {
                // scheduler overcommitted (shouldn't happen; defensive):
                // leave in queue for the next round
                continue;
            }
            // admissions are a queue prefix -> O(1) pop in the common case
            if self.waiting.front() == Some(&rid) {
                self.waiting.pop_front();
                self.view_pop_waiting(rid);
            } else if let Some(pos) = self.waiting.iter().position(|&w| w == rid) {
                self.waiting.remove(pos);
                self.view_pop_waiting(rid);
            }
            if self.requests[rid].prefill_start.is_none() {
                let now = self.backend.clock().now();
                self.requests[rid].prefill_start = Some(now);
                // the queued span closes at first admission; preempt
                // re-admissions keep their original prefill_start and
                // show up as Preempt instants instead
                self.trace_emit(EventKind::Queued, self.requests[rid].arrival, now, rid, 0, 0, 0);
            }
            self.trace_instant(EventKind::Admit, rid, x as u64, 0, 0);
            // prefix-cache lookup: the matched span skips recompute (the
            // backend prices the suffix only); host/disk hits charge the
            // restore transfer here, against the batch duration
            self.acquire_prefix(rid, len, &mut duration);
            // execute: modeled duration (sim) or the real forward pass
            let out = self.backend.prefill(&self.requests[rid], &self.kv)?;
            duration += out.duration;
            offload_bytes += out.offload_bytes;
            spill_bytes += out.spill_bytes;
            // wall-clock backends report the actual first-token instant so
            // a batched admission doesn't charge later requests' prefill
            // time to earlier requests' TTFT
            if let Some(t) = out.first_token_at {
                if self.requests[rid].first_token.is_none() {
                    self.requests[rid].first_token = Some(t);
                }
            }

            let r = &mut self.requests[rid];
            r.preemptions += matches!(r.phase, Phase::Preempted) as usize;
            r.phase = Phase::Decoding;
            // §Perf invariant: insert in prefill_start order. Fresh
            // admissions land at the tail (time is monotone); only
            // preempt re-admissions (older prefill_start) move inward.
            let ps = self.requests[rid].prefill_start.unwrap();
            let reqs_ref = &self.requests;
            let pos = self
                .running
                .partition_point(|&o| reqs_ref[o].prefill_start.unwrap_or(0.0) <= ps);
            self.running.insert(pos, rid);
            self.agg_admit(rid);
            self.view_admit_running(rid);
        }
        self.stats.offload_bytes += offload_bytes;
        self.stats.spill_bytes += spill_bytes;
        let prefill_begin = self.backend.clock().now();
        self.backend.clock_mut().advance(duration);
        self.stats.prefill_steps += 1;
        if self.trace.is_some() {
            // one prefill span per request admitted this batch (the batch
            // shares one modeled duration, so the spans coincide)
            let t1 = self.backend.clock().now();
            for &(rid, _) in reqs {
                if self.requests[rid].phase == Phase::Decoding {
                    let r = &self.requests[rid];
                    self.trace_emit(
                        EventKind::Prefill,
                        prefill_begin,
                        t1,
                        rid,
                        r.prompt_len as u64,
                        r.cached_prefix as u64,
                        0,
                    );
                }
            }
        }

        // first token emitted at prefill end (fresh admissions only:
        // `generated == 0` — preempt re-admissions keep their history)
        let now = self.backend.clock().now();
        for &(rid, _) in reqs {
            if self.requests[rid].phase == Phase::Decoding
                && self.requests[rid].generated == 0
            {
                if self.requests[rid].first_token.is_none() {
                    self.requests[rid].first_token = Some(now);
                    self.trace_instant(EventKind::FirstToken, rid, 0, 0, 0);
                }
                self.requests[rid].generated = 1;
                self.view_append_token(rid);
                if self.incremental
                    && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
                {
                    self.agg.resident_tokens += 1; // context grew with token 1
                }
                if self.requests[rid].done() {
                    self.complete(rid);
                } else {
                    self.maybe_checkpoint(rid);
                }
            }
        }
        Ok(())
    }

    // --- decode ----------------------------------------------------------

    fn step_decode(&mut self) -> anyhow::Result<()> {
        debug_assert!(!self.running.is_empty());

        // Restore parked KV first: LayerKV "maximizes the number of layers
        // retained on the GPU" — oldest admitted requests restore first
        // (they finish soonest and free blocks fastest).
        if matches!(self.cfg.policy, Policy::LayerKv { .. }) {
            self.restore_layers();
        }
        if !self.incremental {
            self.agg =
                RunningAggregates::recompute(&self.running, &self.requests, &self.kv);
        }

        // The decode batch is the GPU-resident subset, capped at what the
        // executor can batch in one step (unbounded in simulation).
        // Requests whose KV is still (partly) on the host are *parked*:
        // they already got their first token at prefill end (the TTFT win)
        // and rejoin once blocks free up. If nothing is fully resident,
        // force-run the oldest parked request with layer-by-layer host
        // streaming (§4's decode-phase h2d path) so progress is guaranteed.
        let mut active = std::mem::take(&mut self.active_buf);
        active.clear();
        let mut stream_bytes = 0.0;
        let mut disk_stream_bytes = 0.0;
        let cap = self.backend.max_decode_lanes();
        let total_ctx = if self.agg.resident_count > 0 {
            active.extend(self.running.iter().copied().filter(|&r| {
                self.kv.table(r).map(|t| t.fully_resident()).unwrap_or(false)
            }));
            debug_assert_eq!(active.len(), self.agg.resident_count);
            if active.len() > cap {
                active.truncate(cap);
                active.iter().map(|&r| self.requests[r].context_len()).sum()
            } else {
                self.agg.resident_tokens
            }
        } else {
            let oldest = *self.running.first().expect("running nonempty");
            if let Some(t) = self.kv.table(oldest) {
                // layers parked two tiers down stream through the disk
                // link first AND then cross the PCIe h2d path like host
                // layers, so they appear in both byte counts (both 0 in
                // the two-tier configuration's disk half)
                disk_stream_bytes = t.n_disk_layers() as f64
                    * t.tokens as f64
                    * self.cfg.offload_bytes_per_token_layer()
                    / self.cfg.tp as f64;
                stream_bytes = (t.n_cpu_layers() + t.n_disk_layers()) as f64
                    * t.tokens as f64
                    * self.cfg.offload_bytes_per_token_layer()
                    / self.cfg.tp as f64;
            }
            active.push(oldest);
            self.requests[oldest].context_len()
        };

        let out = self.backend.decode(
            &active,
            &self.requests,
            &self.kv,
            total_ctx,
            stream_bytes,
            disk_stream_bytes,
        )?;
        self.stats.stream_stall_s += out.stream_stall_s;
        self.stats.onload_stream_bytes += stream_bytes;
        self.stats.disk_stream_bytes += disk_stream_bytes;
        self.stats.disk_stall_s += out.disk_stall_s;
        self.stats.contention_s += out.contention_s;
        let decode_begin = self.backend.clock().now();
        self.backend.clock_mut().advance(out.duration);
        self.stats.decode_steps += 1;
        self.scheduler.observe_decode_step(out.duration);
        if self.trace.is_some() {
            let t1 = self.backend.clock().now();
            for &rid in &active {
                self.trace_emit(EventKind::Decode, decode_begin, t1, rid, 1, total_ctx as u64, 0);
            }
        }

        // advance the active batch by one token
        let mut finished = std::mem::take(&mut self.finished_buf);
        finished.clear();
        for &rid in &active {
            match self.kv.append_token(rid) {
                Ok(()) => {}
                Err(KvError::GpuExhausted) => {
                    if !self.relieve_gpu_pressure(rid) {
                        continue; // token lost this step; retried next step
                    }
                    if self.kv.append_token(rid).is_err() {
                        continue;
                    }
                }
                Err(KvError::CpuExhausted) => {
                    // CpuExhausted covers the whole host-side hierarchy:
                    // only spill-and-retry when the HOST pool is the
                    // bottleneck — if the disk pool is what ran out,
                    // spilling host layers into it would consume the very
                    // blocks the append needs (no-op without a disk tier;
                    // the token is simply retried next step, as before)
                    let need =
                        self.kv.table(rid).map(|t| t.n_cpu_layers()).unwrap_or(0);
                    if need == 0
                        || self.kv.cpu.available() >= need
                        || !self.relieve_host_pressure(need)
                    {
                        continue;
                    }
                    if self.kv.append_token(rid).is_err() {
                        continue;
                    }
                }
                Err(KvError::UnknownRequest) => continue,
            }
            if self.requests[rid].phase != Phase::Decoding {
                continue;
            }
            self.backend.commit_token(rid);
            self.requests[rid].generated += 1;
            self.view_append_token(rid);
            if self.incremental
                && self.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
            {
                self.agg.resident_tokens += 1;
            }
            if self.requests[rid].done() {
                finished.push(rid);
            } else {
                self.maybe_checkpoint(rid);
            }
        }
        for &rid in &finished {
            self.complete(rid);
        }
        finished.clear();
        self.finished_buf = finished;
        active.clear();
        self.active_buf = active;

        // Eq. 5 proactive offload check
        let plan = {
            let waiting = self.waiting.make_contiguous();
            let ctx = SchedContext {
                now: self.backend.clock().now(),
                waiting,
                running: &self.running,
                requests: &self.requests,
                kv: &self.kv,
                cost: &self.cost,
                cfg: &self.cfg,
            };
            self.scheduler.proactive_offloads(&ctx)
        };
        for (rid, layer) in plan {
            if let Ok(n) = self.kv_offload(rid, layer) {
                if n > 0 {
                    self.stats.proactive_offload_layers += 1;
                    self.stats.offload_bytes += n as f64
                        * self.cfg.block_size as f64
                        * self.cfg.offload_bytes_per_token_layer()
                        / self.cfg.tp as f64;
                }
            }
        }

        // Tiered hierarchy: keep host headroom above the watermark by
        // spilling cold layer tables down to disk — the host-tier analog
        // of the Eq. 5 GPU watermark, so the next offload/admission wave
        // doesn't stall on a saturated host pool. Unreachable without a
        // disk tier.
        if self.kv.disk.total() > 0 && self.kv.cpu.available() < self.host_spill_threshold
        {
            let need = self.host_spill_threshold - self.kv.cpu.available();
            self.relieve_host_pressure(need);
        }
        Ok(())
    }

    /// GPU pool exhausted mid-decode. LayerKV: force-offload resident
    /// layers of the most recently prefilled requests (§3.1.1: x/2 first,
    /// then all). vLLM: recompute-preempt the most recent request.
    fn relieve_gpu_pressure(&mut self, needy: ReqId) -> bool {
        // retained prefixes are strictly lower-value than live decode:
        // demote cache entries first, under both policies (a no-op — and
        // bit-invisible — when the cache holds nothing on the GPU)
        let need = self.requests[needy].context_len() / self.cfg.block_size + 1;
        let prefix_freed = self.demote_prefix_gpu(need);
        if prefix_freed >= need {
            return true;
        }
        match self.cfg.policy {
            Policy::LayerKv { .. } => {
                let n_layers = self.cfg.model.n_layers;
                let mut freed = prefix_freed;
                for pass in 0..2 {
                    // most recently prefilled first: reverse sorted order
                    for vi in (0..self.running.len()).rev() {
                        let v = self.running[vi];
                        let Some(t) = self.kv.table(v) else { continue };
                        let resident = t.n_gpu_layers();
                        if resident == 0 {
                            continue;
                        }
                        let take = if pass == 0 { resident / 2 } else { resident };
                        let mut taken = 0usize;
                        for layer in 0..n_layers {
                            if taken >= take {
                                break;
                            }
                            let Some(t) = self.kv.table(v) else { break };
                            if t.layers[layer].residency != Residency::Gpu {
                                continue;
                            }
                            if freed >= need {
                                return true;
                            }
                            taken += 1;
                            if let Ok(n) = self.kv_offload(v, layer) {
                                freed += n;
                                self.stats.oom_forced_offload_layers += 1;
                            }
                        }
                    }
                    if freed >= need {
                        return true;
                    }
                }
                freed > 0
            }
            Policy::Vllm => {
                // preempt the most recently admitted running request
                // (not the needy one if possible): last in sorted order.
                // Skip requests that already emitted their final token
                // this step (still in `running` until the deferred
                // complete()): preempting one would requeue a finished
                // request and serve it twice.
                let reqs = &self.requests;
                let victim = self
                    .running
                    .iter()
                    .rev()
                    .copied()
                    .find(|&r| r != needy && !reqs[r].done())
                    .or(Some(needy));
                match victim {
                    Some(v) => {
                        self.preempt_recompute(v);
                        true
                    }
                    None => false,
                }
            }
        }
    }

    /// vLLM recompute preemption: drop all KV, requeue at the FRONT.
    fn preempt_recompute(&mut self, rid: ReqId) {
        self.agg_remove(rid);
        self.view_remove_running(rid);
        self.release_prefix_lease(rid);
        let _ = self.kv.release(rid);
        self.backend.evict(rid);
        self.running.retain(|&r| r != rid);
        self.requests[rid].phase = Phase::Preempted;
        self.waiting.push_front(rid);
        // phase is already Preempted, so the queue view charges the full
        // re-prefill (prompt + generated) — exactly what the scan counts
        self.view_push_waiting(rid);
        self.stats.preemptions += 1;
        self.trace_instant(EventKind::Preempt, rid, 0, 0, 0);
    }

    /// Move parked layers back to GPU while free blocks last (oldest
    /// running requests first — they'll finish soonest; `running` is
    /// already in that order). Host layers onload over PCIe; disk layers
    /// take the deep restore (disk read + h2d), whose extra cost the
    /// SLO-aware scheduler already priced into the admission-time x-solve.
    /// Restores stop at the Eq. 5 threshold so restore and proactive
    /// offload don't thrash against each other (hysteresis).
    fn restore_layers(&mut self) {
        if self.kv.cpu.used() == 0 && self.kv.disk.used() == 0 {
            return; // §Perf: nothing parked anywhere — skip entirely
        }
        let threshold = self.restore_threshold;
        let n_layers = self.cfg.model.n_layers;
        for i in 0..self.running.len() {
            let rid = self.running[i];
            for layer in 0..n_layers {
                let Some(t) = self.kv.table(rid) else { break };
                let res = t.layers[layer].residency;
                if res == Residency::Gpu {
                    continue;
                }
                let per_layer = t.blocks_per_layer(t.tokens).max(1);
                if self.kv.gpu.available() < threshold + per_layer {
                    return; // stay above the proactive-offload watermark
                }
                let moved = match res {
                    Residency::Cpu => self.kv_onload(rid, layer),
                    _ => self.kv_promote_disk(rid, layer),
                };
                match moved {
                    Ok(n) if n > 0 => {
                        if res == Residency::Cpu {
                            self.stats.onloaded_layers += 1;
                        }
                    }
                    _ => return, // pool full: stop restoring entirely
                }
            }
        }
    }

    fn complete(&mut self, rid: ReqId) {
        self.agg_remove(rid);
        self.view_remove_running(rid);
        let _ = self.kv.release(rid);
        self.backend.release(rid);
        self.running.retain(|&r| r != rid);
        self.release_prefix_lease(rid);
        self.publish_prefix(rid);
        let now = self.backend.clock().now();
        let r = &mut self.requests[rid];
        r.phase = Phase::Finished;
        r.finish = Some(now);
        self.records.push(RequestRecord {
            id: r.id,
            arrival: r.arrival,
            prefill_start: r.prefill_start.unwrap_or(r.arrival),
            first_token: r.first_token.unwrap_or(now),
            finish: now,
            prompt_len: r.prompt_len,
            output_len: r.output_len,
        });
        let generated = self.requests[rid].generated as u64;
        self.trace_instant(EventKind::Finish, rid, generated, 0, 0);
    }
}

/// The predictor `run_trace` (and the reference engine's wrapper) builds:
/// bucket ceiling from the trace's longest output, fixed seed. Public so
/// tests that need a hand-assembled `Engine` (e.g. the golden trace
/// replay, which reads the tier-transition log) reproduce `run_trace`'s
/// behaviour bit-for-bit.
pub fn standard_predictor(trace: &Trace, predictor_accuracy: f64) -> LengthPredictor {
    LengthPredictor::new(
        trace.requests.iter().map(|r| r.output_len).max().unwrap_or(1024).max(2),
        predictor_accuracy,
        42,
    )
}

fn run_trace_with(
    cfg: ServingConfig,
    trace: &Trace,
    predictor_accuracy: f64,
    oracle: bool,
) -> (Report, EngineStats) {
    let predictor = standard_predictor(trace, predictor_accuracy);
    let mut engine = Engine::new(cfg, predictor);
    if oracle {
        engine.use_recompute_oracle();
    }
    let report = engine.run(trace);
    let stats = engine.stats().clone();
    (report, stats)
}

/// Convenience: run one (config, trace) pair with the standard predictor.
pub fn run_trace(cfg: ServingConfig, trace: &Trace, predictor_accuracy: f64) -> (Report, EngineStats) {
    run_trace_with(cfg, trace, predictor_accuracy, false)
}

/// As `run_trace`, but on the recompute-from-scratch oracle — the
/// reference the incremental engine is property-tested against. Shares
/// `run_trace`'s setup exactly, so the two runs differ only in aggregate
/// maintenance.
pub fn run_trace_oracle(cfg: ServingConfig, trace: &Trace, predictor_accuracy: f64) -> (Report, EngineStats) {
    run_trace_with(cfg, trace, predictor_accuracy, true)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::fixed::FixedWorkload;
    use crate::workload::arrivals::Arrivals;
    use crate::util::Rng;

    fn small_trace(prompt: usize, n: usize, rate: f64) -> Trace {
        FixedWorkload {
            prompt_len: prompt,
            output_len: 64,
            n_requests: n,
            arrivals: Arrivals::Poisson { rate },
        }
        .generate(&mut Rng::new(1))
    }

    fn run(policy: Policy, prompt: usize, n: usize, rate: f64) -> (Report, EngineStats) {
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
        run_trace(cfg, &small_trace(prompt, n, rate), 0.8)
    }

    #[test]
    fn vllm_completes_all_requests() {
        let (rep, stats) = run(Policy::Vllm, 512, 20, 1.0);
        assert_eq!(rep.records.len(), 20);
        assert!(stats.dropped.is_empty());
        // every record is causally ordered
        for r in &rep.records {
            assert!(r.prefill_start >= r.arrival - 1e-9);
            assert!(r.first_token >= r.prefill_start);
            assert!(r.finish >= r.first_token);
        }
    }

    #[test]
    fn layerkv_completes_all_requests() {
        let (rep, stats) = run(Policy::LayerKv { slo_aware: true }, 512, 20, 1.0);
        assert_eq!(rep.records.len(), 20);
        assert!(stats.dropped.is_empty());
    }

    #[test]
    fn layerkv_beats_vllm_ttft_under_long_context_load() {
        // the paper's core claim, in miniature: long prompts, output 512
        // (the Fig. 4 configuration), arrivals at 1 req/s
        let cfg_v = ServingConfig::llama2_7b_tp1();
        let cfg_l = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let trace = FixedWorkload::paper(8192).generate(&mut Rng::new(1));
        let trace = Trace { requests: trace.requests[..40].to_vec() };
        let (v, _) = run_trace(cfg_v, &trace, 0.8);
        let (l, _) = run_trace(cfg_l, &trace, 0.8);
        let vt = v.ttft().mean();
        let lt = l.ttft().mean();
        assert!(
            lt < 0.5 * vt,
            "LayerKV mean TTFT {lt:.2}s must clearly beat vLLM {vt:.2}s at 8k context"
        );
    }

    #[test]
    fn short_context_parity() {
        // at short contexts both policies admit instantly; TTFT ~ equal
        let (v, _) = run(Policy::Vllm, 128, 20, 0.5);
        let (l, _) = run(Policy::LayerKv { slo_aware: true }, 128, 20, 0.5);
        let ratio = l.ttft().mean() / v.ttft().mean();
        assert!((0.5..2.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn makespan_bounded_by_arrivals_plus_service() {
        let (rep, _) = run(Policy::Vllm, 256, 10, 2.0);
        assert!(rep.makespan > 0.0);
        // 10 requests * 64 tokens at >=15ms/token plus prefills: sane band
        assert!(rep.makespan < 120.0, "makespan={}", rep.makespan);
    }

    #[test]
    fn drops_impossible_request_instead_of_deadlock() {
        let mut cfg = ServingConfig::llama2_7b_tp1();
        cfg.max_model_len = 16384;
        cfg.max_batched_tokens = 20000;
        // shrink the pool below one 16k prompt's full-KV demand
        cfg.gpu_mem_util = 0.30;
        let trace = small_trace(16384, 3, 1.0);
        let (rep, stats) = run_trace(cfg, &trace, 1.0);
        assert_eq!(rep.records.len() + stats.dropped.len(), 3);
        assert!(!stats.dropped.is_empty());
    }

    #[test]
    fn engine_time_is_monotone() {
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(Policy::LayerKv { slo_aware: true });
        let trace = small_trace(1024, 30, 2.0);
        let (rep, _) = run_trace(cfg, &trace, 0.8);
        for r in &rep.records {
            assert!(r.finish <= rep.makespan + 1e-9);
        }
    }

    #[test]
    fn two_tier_run_never_touches_disk_stats() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let (_, stats) = run_trace(cfg, &small_trace(2048, 20, 2.0), 0.8);
        assert_eq!(stats.spilled_layers, 0);
        assert_eq!(stats.disk_promoted_layers, 0);
        assert_eq!(stats.spill_bytes, 0.0);
        assert_eq!(stats.disk_restore_bytes, 0.0);
        assert_eq!(stats.disk_stream_bytes, 0.0);
        assert_eq!(stats.disk_stall_s, 0.0);
    }

    #[test]
    fn disk_tier_absorbs_host_saturation() {
        use crate::config::DiskSpec;
        // shrink the host swap pool below one long prompt's non-retained
        // demand: without a disk tier such requests can never fit and are
        // rejected; with one they spill and complete
        let mut starved = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        starved.cpu_swap_bytes = 1 << 28; // 256 MB host swap
        let trace = small_trace(8192, 6, 1.0);

        let (rep_two, stats_two) = run_trace(starved.clone(), &trace, 0.8);
        assert!(
            !stats_two.dropped.is_empty(),
            "starved two-tier config must reject long prompts"
        );

        let tiered = starved.with_disk(DiskSpec::nvme_4tb());
        let (rep_three, stats_three) = run_trace(tiered, &trace, 0.8);
        assert_eq!(rep_three.records.len(), 6, "disk tier must serve everything");
        assert!(stats_three.dropped.is_empty());
        assert!(
            stats_three.spill_bytes > 0.0,
            "host saturation must engage the disk tier"
        );
        assert!(rep_three.records.len() > rep_two.records.len());
    }

    #[test]
    fn enabled_transition_log_matches_counters() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let trace = small_trace(4096, 12, 2.0);
        let predictor = standard_predictor(&trace, 0.8);
        let mut e = Engine::new(cfg, predictor);
        e.enable_transition_log();
        let _ = e.run(&trace);
        let stats = e.stats().clone();
        let log = e.take_transitions();
        use crate::metrics::{TIER_DISK, TIER_GPU, TIER_HOST};
        let count = |from, to| log.iter().filter(|t| t.from == from && t.to == to).count() as u64;
        assert_eq!(
            count(TIER_GPU, TIER_HOST),
            stats.proactive_offload_layers + stats.oom_forced_offload_layers,
            "every offload must be logged"
        );
        assert_eq!(count(TIER_HOST, TIER_GPU), stats.onloaded_layers);
        assert_eq!(count(TIER_HOST, TIER_DISK), stats.spilled_layers);
        assert_eq!(count(TIER_DISK, TIER_GPU), stats.disk_promoted_layers);
        // two-tier run: the log must contain no disk tier at all
        assert_eq!(count(TIER_HOST, TIER_DISK), 0);
        // time-ordered
        assert!(log.windows(2).all(|w| w[0].t <= w[1].t));
    }

    #[test]
    fn submit_step_once_matches_try_run_smoke() {
        // full randomized coverage lives in tests/prop_cluster.rs (the
        // 1-replica cluster bit-identity property); this is the fast
        // in-tree guard that the incremental API mirrors try_run
        for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
            let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
            let trace = small_trace(1024, 12, 2.0);
            let (bare, bare_stats) = run_trace(cfg.clone(), &trace, 0.8);

            let predictor = standard_predictor(&trace, 0.8);
            let mut e = Engine::new(cfg, predictor.clone());
            for tr in &trace.requests {
                // drive the engine up to this arrival, then hand it over
                // (the same pattern Cluster::run uses; CLOCK_EPS mirrors
                // try_run's arrival-admission epsilon, and the arrival is
                // the decode fast-forward horizon)
                while tr.arrival > e.now() + CLOCK_EPS {
                    if !e.step_once_until(false, tr.arrival).unwrap() {
                        break;
                    }
                }
                if tr.arrival > e.now() + CLOCK_EPS {
                    e.wait_until(tr.arrival);
                }
                e.submit(tr, predictor.predict(tr.id, tr.output_len));
            }
            while e.has_work() {
                if !e.step_once(true).unwrap() {
                    break;
                }
            }
            let inc_stats = e.stats().clone();
            let inc = e.take_report();
            assert_eq!(inc.records, bare.records, "policy {policy:?}");
            assert_eq!(inc.makespan.to_bits(), bare.makespan.to_bits());
            assert_eq!(inc_stats, bare_stats, "policy {policy:?}");
        }
    }

    #[test]
    fn macro_stepping_matches_single_step_smoke() {
        // full randomized coverage lives in tests/prop_fastforward.rs;
        // this is the fast in-tree guard that decode fast-forwarding is
        // invisible in everything but the scheduler-invocation count
        for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
            let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
            let trace = small_trace(1024, 10, 2.0);
            let predictor = standard_predictor(&trace, 0.8);
            let mut fast = Engine::new(cfg.clone(), predictor.clone());
            fast.set_macro_steps(true);
            let rep_fast = fast.run(&trace);
            let mut slow = Engine::new(cfg, predictor);
            slow.set_macro_steps(false);
            let rep_slow = slow.run(&trace);
            assert_eq!(rep_fast.records, rep_slow.records, "policy {policy:?}");
            assert_eq!(rep_fast.makespan.to_bits(), rep_slow.makespan.to_bits());
            assert_eq!(fast.stats(), slow.stats(), "policy {policy:?}");
            assert!(
                fast.sched_invocations() < slow.sched_invocations(),
                "macro-stepping must skip scheduler invocations ({} vs {})",
                fast.sched_invocations(),
                slow.sched_invocations()
            );
        }
    }

    fn session_trace(n_sessions: usize, rate: f64, seed: u64) -> Trace {
        crate::workload::SessionWorkload::chat(n_sessions, rate).generate(&mut Rng::new(seed))
    }

    #[test]
    fn prefix_cache_invisible_without_prefix_keys() {
        // a trace with no prefix keys (every hash 0) must be bit-identical
        // with the cache on or off — the store never populates, so every
        // hook early-returns; full randomized coverage (routers x
        // macro-stepping) lives in tests/prop_prefix.rs
        for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
            let trace = small_trace(2048, 15, 2.0);
            let on = ServingConfig::llama2_7b_tp1()
                .with_policy(policy)
                .with_prefix_cache(true);
            let off = ServingConfig::llama2_7b_tp1()
                .with_policy(policy)
                .with_prefix_cache(false);
            let (a, sa) = run_trace(on, &trace, 0.8);
            let (b, sb) = run_trace(off, &trace, 0.8);
            assert_eq!(a.records, b.records, "policy {policy:?}");
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
            assert_eq!(sa, sb, "policy {policy:?}");
            assert_eq!(sa.prefix_hits, 0);
            assert_eq!(sa.prefix_misses, 0);
            assert_eq!(sa.prefix_inserts, 0);
        }
    }

    #[test]
    fn prefix_counters_reconcile_with_transition_log() {
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true })
            .with_prefix_cache(true);
        let trace = session_trace(8, 2.0, 11);
        let predictor = standard_predictor(&trace, 0.8);
        let mut e = Engine::new(cfg, predictor);
        e.enable_transition_log();
        let _ = e.run(&trace);
        let stats = e.stats().clone();
        assert!(stats.prefix_inserts > 0, "session turns must publish");
        assert!(stats.prefix_hits > 0, "later turns must hit the cache");
        assert!(stats.prefix_hit_tokens > 0);
        // every cache tier move — demotion under pool pressure, promotion
        // on a warm/cold hit — must appear in the transition log under the
        // PREFIX_REQ sentinel; outright evictions free blocks without a
        // destination tier and only count
        let log = e.take_transitions();
        let cache_rows = log.iter().filter(|t| t.req == PREFIX_REQ).count() as u64;
        assert_eq!(
            cache_rows,
            stats.prefix_promotions + stats.prefix_demotions,
            "cache tier moves must reconcile with the transition log"
        );
        // live entries are exactly the published-minus-evicted set, and a
        // drained engine holds no leases
        assert_eq!(
            e.kv.prefix_entries() as u64,
            stats.prefix_inserts - stats.prefix_evictions
        );
        assert_eq!(e.kv.prefix_leases(), 0, "drained engine must hold no leases");
    }

    #[test]
    fn prefix_cache_cuts_session_ttft() {
        // multi-turn chat sessions share a long population prefix: with
        // the cache on, later turns skip most of their prefill compute
        let cfg = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let trace = session_trace(8, 1.0, 7);
        let (on, son) = run_trace(cfg.clone().with_prefix_cache(true), &trace, 0.8);
        let (off, soff) = run_trace(cfg.with_prefix_cache(false), &trace, 0.8);
        assert!(son.prefix_hits > 0);
        assert_eq!(soff.prefix_hits + soff.prefix_misses + soff.prefix_inserts, 0);
        let (t_on, t_off) = (on.ttft().mean(), off.ttft().mean());
        assert!(
            t_on < 0.85 * t_off,
            "cache-on mean TTFT {t_on:.3}s must clearly beat cache-off {t_off:.3}s"
        );
    }

    #[test]
    fn incremental_matches_oracle_smoke() {
        // full randomized coverage lives in tests/prop_invariants.rs; this
        // is the fast in-tree guard
        for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
            let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
            let trace = small_trace(2048, 15, 2.0);
            let (a, sa) = run_trace(cfg.clone(), &trace, 0.8);
            let (b, sb) = run_trace_oracle(cfg, &trace, 0.8);
            assert_eq!(a.records, b.records, "policy {policy:?}");
            assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
            assert_eq!(sa.steps, sb.steps);
            assert_eq!(sa.preemptions, sb.preemptions);
        }
    }
}
