//! The continuous-batching execution engine (simulated executor).
//!
//! Drives the full request lifecycle against the analytical cost models:
//! iteration-level scheduling (one prefill batch or one decode iteration
//! per step), layer-wise KV allocation/offloading per the active policy,
//! recompute preemption, and the decode-phase host-KV streaming penalty.
//!
//! Virtual time: the engine advances `now` by each step's modeled
//! duration; all latency metrics fall out of the same clock the paper
//! measures with wall time.

use std::collections::VecDeque;

use crate::config::{Fabric, Policy, ServingConfig};
use crate::coordinator::block::{KvError, KvManager};
use crate::coordinator::predict::LengthPredictor;
use crate::coordinator::request::{Phase, ReqId, Request};
use crate::coordinator::scheduler::{make_scheduler, Action, SchedContext, Scheduler};
use crate::metrics::{Report, RequestRecord};
use crate::sim::CostModel;
use crate::workload::Trace;

/// Counters the experiments report alongside latency.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    pub steps: u64,
    pub prefill_steps: u64,
    pub decode_steps: u64,
    pub preemptions: u64,
    pub proactive_offload_layers: u64,
    pub oom_forced_offload_layers: u64,
    pub onloaded_layers: u64,
    pub offload_bytes: f64,
    pub onload_stream_bytes: f64,
    pub dropped: Vec<ReqId>,
    /// Seconds decode steps were inflated by host-KV streaming.
    pub stream_stall_s: f64,
    /// Seconds lost to PCIe contention (TP over PCIe without chunking).
    pub contention_s: f64,
}

/// Simulation engine. One instance runs one trace to completion.
pub struct Engine {
    pub cfg: ServingConfig,
    pub cost: CostModel,
    pub kv: KvManager,
    scheduler: Box<dyn Scheduler>,
    predictor: LengthPredictor,
    requests: Vec<Request>,
    waiting: VecDeque<ReqId>,
    running: Vec<ReqId>,
    now: f64,
    stats: EngineStats,
    records: Vec<RequestRecord>,
}

impl Engine {
    pub fn new(cfg: ServingConfig, predictor: LengthPredictor) -> Self {
        let cost = CostModel::new(cfg.clone());
        let kv = KvManager::new(
            cfg.num_gpu_layer_blocks(),
            cfg.num_cpu_layer_blocks(),
            cfg.block_size,
            cfg.model.n_layers,
        );
        let scheduler = make_scheduler(&cfg);
        Engine {
            cfg,
            cost,
            kv,
            scheduler,
            predictor,
            requests: Vec::new(),
            waiting: VecDeque::new(),
            running: Vec::new(),
            now: 0.0,
            stats: EngineStats::default(),
            records: Vec::new(),
        }
    }

    pub fn stats(&self) -> &EngineStats {
        &self.stats
    }

    /// Run a trace to completion; returns the latency report.
    pub fn run(&mut self, trace: &Trace) -> Report {
        self.requests = trace
            .requests
            .iter()
            .map(|t| Request::from_trace(t, self.predictor.predict(t.id, t.output_len)))
            .collect();
        let mut next_arrival = 0usize;
        // generous step bound: every token plus scheduling slack
        let max_steps = 1000 + 4 * trace.total_tokens() as u64;

        loop {
            // admit arrivals up to `now`
            while next_arrival < self.requests.len()
                && self.requests[next_arrival].arrival <= self.now + 1e-12
            {
                self.waiting.push_back(next_arrival);
                next_arrival += 1;
            }

            let action = {
                // §Perf: make_contiguous avoids a per-step Vec allocation
                let waiting = self.waiting.make_contiguous();
                let ctx = SchedContext {
                    now: self.now,
                    waiting,
                    running: &self.running,
                    requests: &self.requests,
                    kv: &self.kv,
                    cost: &self.cost,
                    cfg: &self.cfg,
                };
                self.scheduler.decide(&ctx)
            };

            match action {
                Action::Prefill(reqs) => self.step_prefill(&reqs),
                Action::Decode => self.step_decode(),
                Action::Wait => {
                    if let Some(&r) = self.waiting.front() {
                        // a request that can never fit (prompt KV exceeds the
                        // whole pool under this policy) would deadlock FCFS:
                        // reject it like a serving front-end would
                        if self.never_fits(r) {
                            self.waiting.pop_front();
                            self.stats.dropped.push(r);
                            self.requests[r].phase = Phase::Finished;
                            continue;
                        }
                    }
                    if next_arrival < self.requests.len() {
                        self.now = self.requests[next_arrival].arrival.max(self.now);
                        continue;
                    }
                    if self.running.is_empty() && self.waiting.is_empty() {
                        break; // drained
                    }
                    if self.running.is_empty() && next_arrival >= self.requests.len() {
                        // waiting blocked forever (pool busy by nothing):
                        // cannot happen unless never_fits missed it
                        let r = self.waiting.pop_front().unwrap();
                        self.stats.dropped.push(r);
                        self.requests[r].phase = Phase::Finished;
                    }
                }
            }

            self.stats.steps += 1;
            if self.stats.steps > max_steps {
                panic!(
                    "engine exceeded {max_steps} steps ({} waiting, {} running) — livelock",
                    self.waiting.len(),
                    self.running.len()
                );
            }
        }
        Report::new(std::mem::take(&mut self.records))
    }

    /// Could `r` EVER be admitted on an empty machine under this policy?
    fn never_fits(&self, r: ReqId) -> bool {
        let len = self.requests[r].prefill_len();
        let per_layer = len.div_ceil(self.cfg.block_size);
        match self.cfg.policy {
            Policy::Vllm => per_layer * self.cfg.model.n_layers > self.kv.gpu.total(),
            Policy::LayerKv { .. } => {
                let x = self.cost.min_resident_layers(len);
                per_layer * x > self.kv.gpu.total()
                    || per_layer * (self.cfg.model.n_layers - x) > self.kv.cpu.total()
            }
        }
    }

    // --- prefill -------------------------------------------------------

    fn step_prefill(&mut self, reqs: &[ReqId]) {
        let mut duration = 0.0;
        let mut offload_bytes = 0.0;
        for &rid in reqs {
            let len = self.requests[rid].prefill_len();
            let x = {
                let waiting = self.waiting.make_contiguous();
                let ctx = SchedContext {
                    now: self.now,
                    waiting,
                    running: &self.running,
                    requests: &self.requests,
                    kv: &self.kv,
                    cost: &self.cost,
                    cfg: &self.cfg,
                };
                self.scheduler.retained_layers(&ctx, len)
            };
            let alloc = match self.cfg.policy {
                Policy::Vllm => self.kv.allocate_full(rid, len),
                Policy::LayerKv { .. } => self.kv.allocate_layerwise(rid, len, x),
            };
            if alloc.is_err() {
                // scheduler overcommitted (shouldn't happen; defensive):
                // leave in queue for the next round
                continue;
            }
            // d2h of the L-x offloaded layers rides under the prefill
            // (§3.1.1 chose x so T_offload <= T_prefill)
            let l = self.cfg.model.n_layers;
            offload_bytes += len as f64
                * (l - x.min(l)) as f64
                * self.cfg.offload_bytes_per_token_layer()
                / self.cfg.tp as f64;

            self.waiting.retain(|&w| w != rid);
            let r = &mut self.requests[rid];
            if r.prefill_start.is_none() {
                r.prefill_start = Some(self.now);
            }
            duration += self.cost.prefill_time(len);
            r.preemptions += matches!(r.phase, Phase::Preempted) as usize;
            r.phase = Phase::Decoding;
            self.running.push(rid);
        }
        self.stats.offload_bytes += offload_bytes;
        self.now += duration;
        self.stats.prefill_steps += 1;

        // first token emitted at prefill end
        for &rid in reqs {
            let r = &mut self.requests[rid];
            if r.phase == Phase::Decoding && r.first_token.is_none() {
                r.first_token = Some(self.now);
                r.generated = 1;
                if r.done() {
                    self.complete(rid);
                }
            }
        }
    }

    // --- decode ----------------------------------------------------------

    fn step_decode(&mut self) {
        debug_assert!(!self.running.is_empty());

        // Restore parked KV first: LayerKV "maximizes the number of layers
        // retained on the GPU" — oldest admitted requests restore first
        // (they finish soonest and free blocks fastest).
        if matches!(self.cfg.policy, Policy::LayerKv { .. }) {
            self.restore_layers();
        }

        // The decode batch is the GPU-resident subset. Requests whose KV
        // is still (partly) on the host are *parked*: they already got
        // their first token at prefill end (the TTFT win) and rejoin once
        // blocks free up. If nothing is fully resident, force-run the
        // oldest parked request with layer-by-layer host streaming (§4's
        // decode-phase h2d path) so progress is guaranteed.
        let mut active: Vec<ReqId> = self
            .running
            .iter()
            .copied()
            .filter(|&r| self.kv.table(r).map(|t| t.cpu_layers().is_empty()).unwrap_or(false))
            .collect();
        let mut stream_bytes = 0.0;
        if active.is_empty() {
            let oldest = self
                .running
                .iter()
                .copied()
                .min_by(|&a, &b| {
                    let ta = self.requests[a].prefill_start.unwrap_or(0.0);
                    let tb = self.requests[b].prefill_start.unwrap_or(0.0);
                    ta.partial_cmp(&tb).unwrap()
                })
                .expect("running nonempty");
            if let Some(t) = self.kv.table(oldest) {
                stream_bytes = t.cpu_layers().len() as f64
                    * t.tokens as f64
                    * self.cfg.offload_bytes_per_token_layer()
                    / self.cfg.tp as f64;
            }
            active.push(oldest);
        }

        let ctx_lens: Vec<usize> =
            active.iter().map(|&r| self.requests[r].context_len()).collect();
        let compute = self.cost.decode_step_time(&ctx_lens);
        let stream_time = if stream_bytes > 0.0 {
            stream_bytes / self.cost.pcie_bw_per_gpu() + self.cfg.node.pcie.latency
        } else {
            0.0
        };
        let mut step = compute.max(stream_time);
        self.stats.stream_stall_s += (stream_time - compute).max(0.0);
        self.stats.onload_stream_bytes += stream_bytes;

        // §3.1.3 PCIe contention: TP over PCIe shares the link between
        // all-reduce and KV streams. The check+chunk mechanism confines the
        // penalty to chunk tails; without it the overlap serializes.
        if self.cfg.tp > 1 && self.cfg.node.fabric == Fabric::Pcie && stream_bytes > 0.0 {
            let ar = self.cost.allreduce_time(active.len());
            let penalty = if self.cfg.pcie_chunking { 0.05 * ar } else { ar.min(stream_time) };
            step += penalty;
            self.stats.contention_s += penalty;
        }

        self.now += step;
        self.stats.decode_steps += 1;
        self.scheduler.observe_decode_step(step);

        // advance the active batch by one token
        let mut finished = Vec::new();
        for rid in active {
            match self.kv.append_token(rid) {
                Ok(()) => {}
                Err(KvError::GpuExhausted) => {
                    if !self.relieve_gpu_pressure(rid) {
                        continue; // token lost this step; retried next step
                    }
                    if self.kv.append_token(rid).is_err() {
                        continue;
                    }
                }
                Err(KvError::CpuExhausted) => continue,
                Err(KvError::UnknownRequest) => continue,
            }
            let r = &mut self.requests[rid];
            if r.phase != Phase::Decoding {
                continue;
            }
            r.generated += 1;
            if r.done() {
                finished.push(rid);
            }
        }
        for rid in finished {
            self.complete(rid);
        }

        // Eq. 5 proactive offload check
        let plan = {
            let waiting = self.waiting.make_contiguous();
            let ctx = SchedContext {
                now: self.now,
                waiting,
                running: &self.running,
                requests: &self.requests,
                kv: &self.kv,
                cost: &self.cost,
                cfg: &self.cfg,
            };
            self.scheduler.proactive_offloads(&ctx)
        };
        for (rid, layer) in plan {
            if let Ok(n) = self.kv.offload_layer(rid, layer) {
                if n > 0 {
                    self.stats.proactive_offload_layers += 1;
                    self.stats.offload_bytes += n as f64
                        * self.cfg.block_size as f64
                        * self.cfg.offload_bytes_per_token_layer()
                        / self.cfg.tp as f64;
                }
            }
        }
    }

    /// GPU pool exhausted mid-decode. LayerKV: force-offload resident
    /// layers of the most recently prefilled requests (§3.1.1: x/2 first,
    /// then all). vLLM: recompute-preempt the most recent request.
    fn relieve_gpu_pressure(&mut self, needy: ReqId) -> bool {
        match self.cfg.policy {
            Policy::LayerKv { .. } => {
                let mut victims: Vec<ReqId> = self
                    .running
                    .iter()
                    .copied()
                    .filter(|&r| self.kv.table(r).map(|t| t.n_gpu_layers() > 0).unwrap_or(false))
                    .collect();
                victims.sort_by(|&a, &b| {
                    let ta = self.requests[a].prefill_start.unwrap_or(0.0);
                    let tb = self.requests[b].prefill_start.unwrap_or(0.0);
                    tb.partial_cmp(&ta).unwrap()
                });
                let need = self.requests[needy].context_len() / self.cfg.block_size + 1;
                let mut freed = 0usize;
                for pass in 0..2 {
                    for &v in &victims {
                        let Some(t) = self.kv.table(v) else { continue };
                        let gpu_layers = t.gpu_layers();
                        let take = if pass == 0 { gpu_layers.len() / 2 } else { gpu_layers.len() };
                        for layer in gpu_layers.into_iter().take(take) {
                            if freed >= need {
                                return true;
                            }
                            if let Ok(n) = self.kv.offload_layer(v, layer) {
                                freed += n;
                                self.stats.oom_forced_offload_layers += 1;
                            }
                        }
                    }
                    if freed >= need {
                        return true;
                    }
                }
                freed > 0
            }
            Policy::Vllm => {
                // preempt the most recently admitted running request
                // (not the needy one if possible)
                let victim = self
                    .running
                    .iter()
                    .copied()
                    .filter(|&r| r != needy)
                    .max_by(|&a, &b| {
                        let ta = self.requests[a].prefill_start.unwrap_or(0.0);
                        let tb = self.requests[b].prefill_start.unwrap_or(0.0);
                        ta.partial_cmp(&tb).unwrap()
                    })
                    .or(Some(needy));
                match victim {
                    Some(v) => {
                        self.preempt_recompute(v);
                        true
                    }
                    None => false,
                }
            }
        }
    }

    /// vLLM recompute preemption: drop all KV, requeue at the FRONT.
    fn preempt_recompute(&mut self, rid: ReqId) {
        let _ = self.kv.release(rid);
        self.running.retain(|&r| r != rid);
        self.requests[rid].phase = Phase::Preempted;
        self.waiting.push_front(rid);
        self.stats.preemptions += 1;
    }

    /// Move CPU-resident layers back to GPU while free blocks last
    /// (oldest running requests first — they'll finish soonest). Restores
    /// stop at the Eq. 5 threshold so restore and proactive offload don't
    /// thrash against each other (hysteresis).
    fn restore_layers(&mut self) {
        if self.kv.cpu.used() == 0 {
            return; // §Perf: nothing parked — skip the sort entirely
        }
        let threshold =
            (self.cfg.avail_threshold_frac * self.kv.gpu.total() as f64) as usize;
        let mut order: Vec<ReqId> = self.running.clone();
        order.sort_by(|&a, &b| {
            let ta = self.requests[a].prefill_start.unwrap_or(0.0);
            let tb = self.requests[b].prefill_start.unwrap_or(0.0);
            ta.partial_cmp(&tb).unwrap()
        });
        for rid in order {
            let Some(t) = self.kv.table(rid) else { continue };
            let per_layer = t.blocks_per_layer(t.tokens).max(1);
            for layer in t.cpu_layers() {
                if self.kv.gpu.available() < threshold + per_layer {
                    return; // stay above the proactive-offload watermark
                }
                match self.kv.onload_layer(rid, layer) {
                    Ok(n) if n > 0 => self.stats.onloaded_layers += 1,
                    _ => return, // pool full: stop restoring entirely
                }
            }
        }
    }

    fn complete(&mut self, rid: ReqId) {
        let _ = self.kv.release(rid);
        self.running.retain(|&r| r != rid);
        let r = &mut self.requests[rid];
        r.phase = Phase::Finished;
        r.finish = Some(self.now);
        self.records.push(RequestRecord {
            id: r.id,
            arrival: r.arrival,
            prefill_start: r.prefill_start.unwrap_or(r.arrival),
            first_token: r.first_token.unwrap_or(self.now),
            finish: self.now,
            prompt_len: r.prompt_len,
            output_len: r.output_len,
        });
    }

}

/// Convenience: run one (config, trace) pair with the standard predictor.
pub fn run_trace(cfg: ServingConfig, trace: &Trace, predictor_accuracy: f64) -> (Report, EngineStats) {
    let predictor = LengthPredictor::new(
        trace.requests.iter().map(|r| r.output_len).max().unwrap_or(1024).max(2),
        predictor_accuracy,
        42,
    );
    let mut engine = Engine::new(cfg, predictor);
    let report = engine.run(trace);
    let stats = engine.stats().clone();
    (report, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::fixed::FixedWorkload;
    use crate::workload::arrivals::Arrivals;
    use crate::util::Rng;

    fn small_trace(prompt: usize, n: usize, rate: f64) -> Trace {
        FixedWorkload {
            prompt_len: prompt,
            output_len: 64,
            n_requests: n,
            arrivals: Arrivals::Poisson { rate },
        }
        .generate(&mut Rng::new(1))
    }

    fn run(policy: Policy, prompt: usize, n: usize, rate: f64) -> (Report, EngineStats) {
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(policy);
        run_trace(cfg, &small_trace(prompt, n, rate), 0.8)
    }

    #[test]
    fn vllm_completes_all_requests() {
        let (rep, stats) = run(Policy::Vllm, 512, 20, 1.0);
        assert_eq!(rep.records.len(), 20);
        assert!(stats.dropped.is_empty());
        // every record is causally ordered
        for r in &rep.records {
            assert!(r.prefill_start >= r.arrival - 1e-9);
            assert!(r.first_token >= r.prefill_start);
            assert!(r.finish >= r.first_token);
        }
    }

    #[test]
    fn layerkv_completes_all_requests() {
        let (rep, stats) = run(Policy::LayerKv { slo_aware: true }, 512, 20, 1.0);
        assert_eq!(rep.records.len(), 20);
        assert!(stats.dropped.is_empty());
    }

    #[test]
    fn layerkv_beats_vllm_ttft_under_long_context_load() {
        // the paper's core claim, in miniature: long prompts, output 512
        // (the Fig. 4 configuration), arrivals at 1 req/s
        let cfg_v = ServingConfig::llama2_7b_tp1();
        let cfg_l = ServingConfig::llama2_7b_tp1()
            .with_policy(Policy::LayerKv { slo_aware: true });
        let trace = FixedWorkload::paper(8192).generate(&mut Rng::new(1));
        let trace = Trace { requests: trace.requests[..40].to_vec() };
        let (v, _) = run_trace(cfg_v, &trace, 0.8);
        let (l, _) = run_trace(cfg_l, &trace, 0.8);
        let vt = v.ttft().mean();
        let lt = l.ttft().mean();
        assert!(
            lt < 0.5 * vt,
            "LayerKV mean TTFT {lt:.2}s must clearly beat vLLM {vt:.2}s at 8k context"
        );
    }

    #[test]
    fn short_context_parity() {
        // at short contexts both policies admit instantly; TTFT ~ equal
        let (v, _) = run(Policy::Vllm, 128, 20, 0.5);
        let (l, _) = run(Policy::LayerKv { slo_aware: true }, 128, 20, 0.5);
        let ratio = l.ttft().mean() / v.ttft().mean();
        assert!((0.5..2.0).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn makespan_bounded_by_arrivals_plus_service() {
        let (rep, _) = run(Policy::Vllm, 256, 10, 2.0);
        assert!(rep.makespan > 0.0);
        // 10 requests * 64 tokens at >=15ms/token plus prefills: sane band
        assert!(rep.makespan < 120.0, "makespan={}", rep.makespan);
    }

    #[test]
    fn drops_impossible_request_instead_of_deadlock() {
        let mut cfg = ServingConfig::llama2_7b_tp1();
        cfg.max_model_len = 16384;
        cfg.max_batched_tokens = 20000;
        // shrink the pool below one 16k prompt's full-KV demand
        cfg.gpu_mem_util = 0.30;
        let trace = small_trace(16384, 3, 1.0);
        let (rep, stats) = run_trace(cfg, &trace, 1.0);
        assert_eq!(rep.records.len() + stats.dropped.len(), 3);
        assert!(!stats.dropped.is_empty());
    }

    #[test]
    fn engine_time_is_monotone() {
        let cfg = ServingConfig::llama2_7b_tp1().with_policy(Policy::LayerKv { slo_aware: true });
        let trace = small_trace(1024, 30, 2.0);
        let (rep, _) = run_trace(cfg, &trace, 0.8);
        for r in &rep.records {
            assert!(r.finish <= rep.makespan + 1e-9);
        }
    }
}
