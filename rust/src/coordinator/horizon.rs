//! Event-horizon solver for decode fast-forwarding (macro-stepping).
//!
//! When the scheduler returns `Action::Decode` on a *stable* machine —
//! the queue is empty and every running request's KV is fully GPU-resident
//! (nothing parked on host or disk) — every subsequent engine loop turn is
//! provably another identical-shape decode iteration until the next
//! state-changing **event**:
//!
//! * the next **arrival** crosses the admission epsilon (the `deadline`
//!   hint the caller threads in: `try_run`'s next trace arrival, or the
//!   cluster lockstep's next routed request),
//! * the earliest **completion** (min remaining output tokens over the
//!   batch — ground truth, not the predictor bucket: `Request::done`
//!   consumes `output_len`),
//! * a **GPU pool event**: block-boundary growth either exhausts the free
//!   list (the single-step path would force-offload or recompute-preempt)
//!   or, under the LayerKV policy, drops free blocks to ≤ 25 % of the pool
//!   — the point where `proactive_offloads` stops short-circuiting and the
//!   Eq. 5 forecast could start planning offloads.
//!
//! Host/disk watermark crossings and restore activity cannot occur inside
//! the span: stability requires `cpu.used() == 0 && disk.used() == 0`, so
//! the host pool sits at full availability (≥ its spill watermark) and
//! `restore_layers` short-circuits. Decode-lane caps are constant per
//! backend; the engine only fast-forwards when the whole running set fits
//! one batch.
//!
//! The solver walks candidate steps with O(1) work each — the per-step
//! decode duration is `CostModel::decode_step_time_sum` on the running
//! context total, and block-boundary growth comes from a histogram of
//! `table.tokens % block_size` over the batch — and returns the largest
//! committable `k`. The clock bound accumulates durations *sequentially*
//! (`t += d_j`), the exact float-op sequence `VirtualClock::advance`
//! performs, so the macro-step's final clock is bit-identical to `k`
//! single steps.

use crate::coordinator::engine::CLOCK_EPS;
use crate::sim::CostModel;

/// Everything the solver reads about the stable machine. One snapshot —
/// the solver mutates nothing.
pub struct HorizonInputs<'a> {
    /// Engine clock now (the span's step 1 is already committed to run
    /// at this instant: the scheduler decided `Decode` for it).
    pub now: f64,
    /// Next arrival instant (`f64::INFINITY` when no arrival is pending).
    /// Step `j ≥ 2` is only committable while the admission check before
    /// it — `arrival <= t + CLOCK_EPS` — would still come up empty.
    pub deadline: f64,
    /// Σ context tokens over the (fully resident) decode batch.
    pub resident_tokens: usize,
    /// Decode batch size (= the whole running set).
    pub batch: usize,
    /// Free GPU layer-blocks right now.
    pub gpu_available: usize,
    /// GPU pool capacity in layer-blocks.
    pub gpu_total: usize,
    /// Layers every table grows at a block boundary (all GPU-resident).
    pub n_layers: usize,
    /// LayerKV policy: keep free blocks above 25 % of the pool so the
    /// Eq. 5 proactive-offload check keeps short-circuiting to "no plan"
    /// (the vLLM baseline never offloads proactively; it only needs the
    /// free list to cover the span's growth).
    pub offload_gate: bool,
    pub cost: &'a CostModel,
}

/// Largest `k` such that decode steps `1..=k` can be committed as one
/// macro-step with bit-identical outcome to `k` single steps.
///
/// `max_k` is the completion bound (min remaining output tokens − 1, so
/// the span stops strictly before any request finishes) and `hist[c]`
/// counts batch tables with `tokens % block_size == c` — table tokens at
/// step `j` have advanced by `j − 1`, so the tables crossing a block
/// boundary at step `j` are exactly those with residue
/// `(block_size − (j − 1) % block_size) % block_size`.
///
/// Each committed step's duration is pushed onto `durations` (cleared
/// first; a reusable caller buffer) so the committing engine replays the
/// exact same floats instead of re-evaluating the cost model `k` more
/// times.
///
/// Returns 0 when even the already-decided first step violates a pool
/// constraint — the caller falls back to the single-step path, which owns
/// the messy cases (forced offload, preemption, forecast offloads).
pub fn decode_horizon(
    inp: &HorizonInputs,
    max_k: usize,
    hist: &[usize],
    durations: &mut Vec<f64>,
) -> usize {
    let bs = hist.len();
    debug_assert!(bs > 0 && inp.batch > 0);
    durations.clear();
    let mut t = inp.now;
    let mut ctx = inp.resident_tokens;
    let mut avail = inp.gpu_available;
    let mut k = 0usize;
    while k < max_k {
        let j = k + 1;
        // an arrival admitted before step j ends the span (step 1 was
        // decided after this turn's admissions, so it carries no bound)
        if j >= 2 && inp.deadline <= t + CLOCK_EPS {
            break;
        }
        // block-boundary growth this step: every matching table adds one
        // block per (GPU-resident) layer
        let residue = (bs - (j - 1) % bs) % bs;
        let need = hist[residue] * inp.n_layers;
        if need > avail {
            break; // single-step path would hit relieve_gpu_pressure
        }
        let after = avail - need;
        if inp.offload_gate && after * 4 <= inp.gpu_total {
            break; // Eq. 5 forecast would no longer short-circuit
        }
        // commit step j: same accumulation order as the engine's clock
        let d = inp.cost.decode_step_time_sum(ctx, inp.batch);
        durations.push(d);
        t += d;
        ctx += inp.batch;
        avail = after;
        k = j;
    }
    debug_assert_eq!(durations.len(), k);
    // the walk above IS CostModel::decode_span_end — assert the two stay
    // the same sequence (the engine's debug cross-check relies on it)
    debug_assert_eq!(
        t.to_bits(),
        inp.cost.decode_span_end(inp.now, inp.resident_tokens, inp.batch, k).to_bits()
    );
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;

    fn inputs(cost: &CostModel) -> HorizonInputs<'_> {
        HorizonInputs {
            now: 10.0,
            deadline: f64::INFINITY,
            resident_tokens: 4096,
            batch: 4,
            gpu_available: 50_000,
            gpu_total: 60_000,
            n_layers: 32,
            offload_gate: true,
            cost,
        }
    }

    #[test]
    fn completion_bound_caps_the_span() {
        let cost = CostModel::new(ServingConfig::llama2_7b_tp1());
        let hist = vec![0usize; 16]; // no table near a block boundary
        let inp = inputs(&cost);
        assert_eq!(decode_horizon(&inp, 0, &hist, &mut Vec::new()), 0);
        assert_eq!(decode_horizon(&inp, 7, &hist, &mut Vec::new()), 7);
        assert_eq!(decode_horizon(&inp, 5000, &hist, &mut Vec::new()), 5000);
    }

    #[test]
    fn durations_buffer_replays_the_walk() {
        let cost = CostModel::new(ServingConfig::llama2_7b_tp1());
        let hist = vec![0usize; 16];
        let inp = inputs(&cost);
        let mut durs = vec![99.0]; // stale content must be cleared
        let k = decode_horizon(&inp, 25, &hist, &mut durs);
        assert_eq!(k, 25);
        assert_eq!(durs.len(), 25);
        for (i, d) in durs.iter().enumerate() {
            let want =
                cost.decode_step_time_sum(inp.resident_tokens + i * inp.batch, inp.batch);
            assert_eq!(d.to_bits(), want.to_bits(), "step {i}");
        }
    }

    #[test]
    fn deadline_bounds_by_clock_accumulation() {
        let cost = CostModel::new(ServingConfig::llama2_7b_tp1());
        let hist = vec![0usize; 16];
        let mut inp = inputs(&cost);
        // replay the solver's own accumulation to find where 3 steps land
        let mut t = inp.now;
        for i in 0..3usize {
            t += cost.decode_step_time_sum(inp.resident_tokens + i * inp.batch, inp.batch);
        }
        // an arrival exactly at the 3-step mark: steps 1..=3 run (step 4's
        // pre-check sees the arrival due), so the span is 3
        inp.deadline = t;
        assert_eq!(decode_horizon(&inp, 1000, &hist, &mut Vec::new()), 3);
        // an arrival already due bounds the span to the decided step only
        inp.deadline = inp.now;
        assert_eq!(decode_horizon(&inp, 1000, &hist, &mut Vec::new()), 1);
        // far-future arrival: completion bound wins again
        inp.deadline = t + 1.0e9;
        assert!(decode_horizon(&inp, 1000, &hist, &mut Vec::new()) > 3);
    }

    #[test]
    fn gpu_capacity_bounds_block_boundaries() {
        let cost = CostModel::new(ServingConfig::llama2_7b_tp1());
        // 2 tables sitting right on a boundary (residue 0): they grow at
        // step 1, then again every 16 steps
        let mut hist = vec![0usize; 16];
        hist[0] = 2;
        let mut inp = inputs(&cost);
        inp.offload_gate = false;
        inp.batch = 2;
        // room for exactly 3 boundary waves of 2 tables * 32 layers
        inp.gpu_available = 3 * 2 * 32;
        inp.gpu_total = 1 << 20;
        // waves land at steps 1, 17, 33; the 4th wave at step 49 fails
        assert_eq!(decode_horizon(&inp, 10_000, &hist, &mut Vec::new()), 48);
        // first step itself infeasible -> 0 (caller single-steps)
        inp.gpu_available = 63;
        assert_eq!(decode_horizon(&inp, 10_000, &hist, &mut Vec::new()), 0);
    }

    #[test]
    fn layerkv_gate_stops_above_pool_pressure() {
        let cost = CostModel::new(ServingConfig::llama2_7b_tp1());
        let mut hist = vec![0usize; 16];
        hist[0] = 1;
        let mut inp = inputs(&cost);
        inp.batch = 1;
        inp.n_layers = 32;
        inp.gpu_total = 1000;
        // 282 free: first boundary leaves 250 = exactly 25 % -> the gate
        // (avail * 4 > total) fails right at step 1
        inp.gpu_available = 282;
        assert_eq!(decode_horizon(&inp, 10_000, &hist, &mut Vec::new()), 0);
        // one block of headroom: step 1 passes, the next wave at step 17
        // would leave 219 < 25 % -> span is 16
        inp.gpu_available = 283;
        assert_eq!(decode_horizon(&inp, 10_000, &hist, &mut Vec::new()), 16);
        // vLLM ignores the gate and runs to raw capacity
        inp.offload_gate = false;
        assert!(decode_horizon(&inp, 10_000, &hist, &mut Vec::new()) > 16);
    }
}
