//! The paper's Layer-3 contribution: layer-wise KV cache management and
//! SLO-aware scheduling for continuous-batching LLM serving.
//!
//! * `block`     — physical pools + layer-wise block tables (§3.1.1-3.1.2)
//! * `scheduler` — vLLM baseline + LayerKV SLO-aware policies (Alg. 1)
//! * `predict`   — output-length bucket predictor (§3.1)
//! * `backend`   — the `ExecutionBackend` seam: simulated vs real executor
//! * `engine`    — the backend-generic continuous-batching coordinator
//! * `horizon`   — the decode fast-forward (macro-stepping) event solver
//! * `request`   — request lifecycle + Eq. 1 timing state

pub mod backend;
pub mod block;
pub mod engine;
pub mod horizon;
pub mod predict;
pub mod request;
pub mod scheduler;

pub use backend::{
    Clock, DecodeOutcome, ExecutionBackend, PrefillOutcome, SimBackend, VirtualClock,
    WallClock,
};
pub use block::{KvError, KvManager};
pub use engine::{
    run_trace, standard_predictor, DrainedRequest, Engine, EngineStats, CLOCK_EPS,
    DISK_FENCE_K,
};
pub use predict::LengthPredictor;
pub use request::{Phase, ReqId, Request};
pub use scheduler::{Action, Scheduler};
