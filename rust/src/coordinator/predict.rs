//! Output-length predictor (§3.1): the paper frames generation-length
//! prediction as multi-class classification over percentile ranges and
//! cites a proxy-model approach [31]. The proxy model itself is external
//! to LayerKV, so we implement the interface the scheduler consumes — an
//! oracle-with-noise bucket classifier with configurable accuracy
//! (DESIGN.md §2 substitution table; the accuracy sweep is an ablation
//! bench).

use crate::util::Rng;

/// Bucketed length predictor. `accuracy` is the probability the true
/// bucket is returned; otherwise a uniformly random *other* bucket is
/// (deterministically per request) returned — the worst-case error mode.
#[derive(Debug, Clone)]
pub struct LengthPredictor {
    /// Bucket boundaries: bucket i covers [bounds[i], bounds[i+1]).
    bounds: Vec<usize>,
    accuracy: f64,
    seed: u64,
}

impl LengthPredictor {
    /// Percentile-range buckets reaching the model's max output regime.
    pub fn new(max_len: usize, accuracy: f64, seed: u64) -> Self {
        assert!((0.0..=1.0).contains(&accuracy));
        let mut bounds = vec![1, 32, 64, 128, 256, 512, 1024, 2048];
        bounds.retain(|&b| b < max_len);
        bounds.push(max_len.max(2));
        LengthPredictor { bounds, accuracy, seed }
    }

    /// Perfect oracle (upper bound for ablations).
    pub fn oracle(max_len: usize) -> Self {
        Self::new(max_len, 1.0, 0)
    }

    pub fn n_buckets(&self) -> usize {
        self.bounds.len() - 1
    }

    fn bucket_of(&self, len: usize) -> usize {
        for i in 0..self.n_buckets() {
            if len < self.bounds[i + 1] {
                return i;
            }
        }
        self.n_buckets() - 1
    }

    pub fn bucket_range(&self, i: usize) -> (usize, usize) {
        (self.bounds[i], self.bounds[i + 1])
    }

    /// Predict the bucket [lo, hi) for a request. Deterministic in
    /// (seed, request id) so repeated calls agree — the scheduler may
    /// re-query at every step.
    pub fn predict(&self, req_id: usize, true_len: usize) -> (usize, usize) {
        let truth = self.bucket_of(true_len);
        if self.accuracy >= 1.0 || self.n_buckets() == 1 {
            return self.bucket_range(truth);
        }
        let mut rng = Rng::new(self.seed ^ (req_id as u64).wrapping_mul(0x9E3779B97F4A7C15));
        if rng.chance(self.accuracy) {
            self.bucket_range(truth)
        } else {
            // uniformly among the other buckets
            let mut other = rng.range_usize(0, self.n_buckets() - 1);
            if other >= truth {
                other += 1;
            }
            self.bucket_range(other)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_brackets_truth() {
        let p = LengthPredictor::oracle(4096);
        for len in [1usize, 31, 32, 100, 511, 512, 2047, 4000] {
            let (lo, hi) = p.predict(0, len);
            assert!(lo <= len && len < hi, "len={len} got [{lo},{hi})");
        }
    }

    #[test]
    fn deterministic_per_request() {
        let p = LengthPredictor::new(2048, 0.5, 7);
        for id in 0..50 {
            assert_eq!(p.predict(id, 300), p.predict(id, 300));
        }
    }

    #[test]
    fn accuracy_is_respected() {
        let p = LengthPredictor::new(2048, 0.8, 3);
        let truth = 300;
        let hits = (0..5000)
            .filter(|&id| {
                let (lo, hi) = p.predict(id, truth);
                lo <= truth && truth < hi
            })
            .count();
        let rate = hits as f64 / 5000.0;
        assert!((rate - 0.8).abs() < 0.03, "rate={rate}");
    }

    #[test]
    fn zero_accuracy_never_hits() {
        let p = LengthPredictor::new(2048, 0.0, 1);
        for id in 0..100 {
            let (lo, hi) = p.predict(id, 100);
            assert!(!(lo <= 100 && 100 < hi));
        }
    }

    #[test]
    fn buckets_cover_range() {
        let p = LengthPredictor::new(512, 1.0, 0);
        assert_eq!(p.bucket_range(0).0, 1);
        let last = p.bucket_range(p.n_buckets() - 1);
        assert_eq!(last.1, 512);
    }
}
