//! Request lifecycle: the state machine every request walks through the
//! engine, plus the timing fields the SLO-aware scheduler consumes (Eq. 1).

use crate::workload::{PrefixKey, TraceRequest};

pub type ReqId = usize;

/// Where a request is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// In the queue, KV not allocated.
    Waiting,
    /// In the decode loop, generating tokens.
    Decoding,
    /// Preempted by recompute (vLLM semantics): KV dropped, waiting to
    /// re-prefill prompt + generated-so-far.
    Preempted,
    /// All output tokens emitted, KV released.
    Finished,
}

/// Engine-side request state.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: ReqId,
    pub arrival: f64,
    pub prompt_len: usize,
    /// Ground-truth output length (engine stops there; the scheduler only
    /// sees the predictor's bucket).
    pub output_len: usize,
    pub phase: Phase,
    /// Tokens generated so far (N_past in Eq. 1).
    pub generated: usize,
    /// First time its prefill began executing (queueing ends here).
    pub prefill_start: Option<f64>,
    /// First token emission (TTFT ends here; T_past starts here).
    pub first_token: Option<f64>,
    pub finish: Option<f64>,
    /// Predicted output-length bucket [lo, hi) from the multi-class
    /// predictor (§3.1).
    pub predicted: (usize, usize),
    /// Recompute preemptions suffered (vLLM baseline path).
    pub preemptions: usize,
    /// Shared-prefix identity from the trace (zero = none).
    pub prefix: PrefixKey,
    /// Tokens of this request's current prefill served from the prefix
    /// cache (set at admission, 0 when caching is off or nothing
    /// matched; also the live-lease marker — reset when the lease is
    /// released).
    pub cached_prefix: usize,
    /// Committed tokens covered by the last durable disk checkpoint
    /// (0 = none; stays 0 when checkpointing is off or the disk tier is
    /// fenced). Failover can resume this far without recompute.
    pub last_ckpt: usize,
}

impl Request {
    pub fn from_trace(t: &TraceRequest, predicted: (usize, usize)) -> Self {
        Request {
            id: t.id,
            arrival: t.arrival,
            prompt_len: t.prompt_len,
            output_len: t.output_len,
            phase: Phase::Waiting,
            generated: 0,
            prefill_start: None,
            first_token: None,
            finish: None,
            predicted,
            preemptions: 0,
            prefix: t.prefix,
            cached_prefix: 0,
            last_ckpt: 0,
        }
    }

    /// Current context length (prompt + generated tokens).
    pub fn context_len(&self) -> usize {
        self.prompt_len + self.generated
    }

    /// Tokens a (re-)prefill must process now: the original prompt, plus —
    /// after a recompute preemption — everything generated so far.
    pub fn prefill_len(&self) -> usize {
        self.prompt_len + if self.phase == Phase::Preempted { self.generated } else { 0 }
    }

    /// T_past of Eq. 1: decoding time spent so far, *including* time spent
    /// waiting between decode iterations.
    pub fn decode_time_past(&self, now: f64) -> f64 {
        match self.first_token {
            Some(ft) => (now - ft).max(0.0),
            None => 0.0,
        }
    }

    /// Observed per-token decode rate; None until two tokens exist.
    pub fn observed_tpot(&self, now: f64) -> Option<f64> {
        if self.generated >= 2 {
            Some(self.decode_time_past(now) / (self.generated - 1).max(1) as f64)
        } else {
            None
        }
    }

    /// N_future of Eq. 1: conservative remaining-tokens estimate — the
    /// *lower bound* of the predicted bucket minus what's generated,
    /// floored at 1 (the paper constrains it to positive integers).
    pub fn n_future(&self) -> usize {
        self.predicted.0.saturating_sub(self.generated).max(1)
    }

    /// Median of the predicted bucket — the Eq. 5 Released(t) estimate of
    /// the total generation length.
    pub fn predicted_median(&self) -> usize {
        (self.predicted.0 + self.predicted.1) / 2
    }

    pub fn done(&self) -> bool {
        self.generated >= self.output_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req() -> Request {
        Request::from_trace(
            &TraceRequest {
                id: 0,
                arrival: 1.0,
                prompt_len: 100,
                output_len: 50,
                ..Default::default()
            },
            (32, 64),
        )
    }

    #[test]
    fn lifecycle_defaults() {
        let r = req();
        assert_eq!(r.phase, Phase::Waiting);
        assert_eq!(r.context_len(), 100);
        assert_eq!(r.prefill_len(), 100);
        assert_eq!(r.decode_time_past(99.0), 0.0);
        assert!(r.observed_tpot(99.0).is_none());
    }

    #[test]
    fn preempted_prefill_includes_generated() {
        let mut r = req();
        r.generated = 10;
        r.phase = Phase::Preempted;
        assert_eq!(r.prefill_len(), 110);
        r.phase = Phase::Decoding;
        assert_eq!(r.prefill_len(), 100);
    }

    #[test]
    fn eq1_terms() {
        let mut r = req();
        r.first_token = Some(10.0);
        r.generated = 11;
        // T_past includes waiting: 2s over 10 intervals
        assert!((r.decode_time_past(12.0) - 2.0).abs() < 1e-12);
        assert!((r.observed_tpot(12.0).unwrap() - 0.2).abs() < 1e-12);
        // N_future = lower bound 32 - 11 = 21
        assert_eq!(r.n_future(), 21);
        r.generated = 40; // past the lower bound -> floored at 1
        assert_eq!(r.n_future(), 1);
        assert_eq!(r.predicted_median(), 48);
    }

    #[test]
    fn done_at_output_len() {
        let mut r = req();
        r.generated = 49;
        assert!(!r.done());
        r.generated = 50;
        assert!(r.done());
    }
}
