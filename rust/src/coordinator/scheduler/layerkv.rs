//! LayerKV's SLO-aware scheduler (§3.1, Algorithm 1) plus the Eq. 5
//! block-availability forecaster driving proactive offload.
//!
//! Admission differs from the vLLM baseline in two stacked ways:
//!
//! 1. **Layer-wise admission** (§3.1.1): a prompt only needs GPU blocks for
//!    the x layers whose offload cannot hide under the prefill
//!    (x = CostModel::min_resident_layers); the other L-x layers'
//!    KV goes straight to host blocks, so long prompts admit almost
//!    immediately instead of waiting for whole-request block releases.
//!
//! 2. **TPOT-slack gating** (Eqs. 1-2, Algorithm 1): inserting prefills
//!    stalls running decodes, so the scheduler admits at most n prefills
//!    such that their summed estimated prefill time stays below every
//!    decoding request's remaining TPOT-SLO slack. `slo_aware = false`
//!    disables this gate (the Fig. 8 ablation).

use super::{Action, OffloadPlan, SchedContext, Scheduler};
use crate::coordinator::block::Residency;
use crate::coordinator::request::{Phase, ReqId};

/// Forecast horizon for Eq. 5, in scheduling stages. One stage approximates
/// `block_size` decode iterations (the cadence at which every running
/// sequence consumes one more block per resident layer).
const FORECAST_STAGES: usize = 4;

#[derive(Debug)]
pub struct LayerKvScheduler {
    slo_aware: bool,
    /// Fallback TPOT estimate until a request has its own history (EMA of
    /// observed decode-step times, seeded from the cost model lazily).
    tpot_ema: Option<f64>,
    /// §Perf: Eq. 5 threshold in blocks — depends only on the fixed pool
    /// size and config, so it is computed once on first use.
    threshold_blocks: Option<i64>,
}

impl LayerKvScheduler {
    pub fn new(slo_aware: bool) -> Self {
        LayerKvScheduler { slo_aware, tpot_ema: None, threshold_blocks: None }
    }

    /// Feed back a measured decode-step duration (engine calls this via
    /// the trait; public for tests).
    pub fn observe_decode_step(&mut self, dt: f64) {
        self.tpot_ema = Some(match self.tpot_ema {
            Some(ema) => 0.9 * ema + 0.1 * dt,
            None => dt,
        });
    }

    /// Eq. 1: T_allow_prefill for one decoding request.
    fn t_allow_prefill(&self, ctx: &SchedContext, rid: ReqId) -> f64 {
        let r = &ctx.requests[rid];
        let n_past = r.generated as f64;
        let t_past = r.decode_time_past(ctx.now);
        let n_future = r.n_future() as f64;
        let cur_tpot = r
            .observed_tpot(ctx.now)
            .or(self.tpot_ema)
            .unwrap_or_else(|| ctx.cost.decode_step_time(&[r.context_len()]));
        let t_future = cur_tpot * n_future;
        ctx.cfg.slo.tpot_s * (n_past + n_future) - (t_past + t_future)
    }

    /// min_i T_allow_prefill over the *actively decoding* set (Eq. 2's
    /// bound). Requests whose KV is (partly) parked on the host are
    /// swapped out of the decode batch — they are not "currently in the
    /// decoding phase" that an inserted prefill would stall. §Perf: the
    /// residency test reads the table's cached aggregate (O(1), no Vec).
    fn min_slack(&self, ctx: &SchedContext) -> f64 {
        ctx.running
            .iter()
            .filter(|&&rid| {
                ctx.kv.table(rid).map(|t| t.fully_resident()).unwrap_or(false)
            })
            .map(|&rid| self.t_allow_prefill(ctx, rid))
            .fold(f64::INFINITY, f64::min)
    }

    /// Eq. 5 forecast: projected free GPU layer-blocks at each of the next
    /// FORECAST_STAGES stage boundaries. Released(t) uses the predictor's
    /// bucket *median*; Allocated(t) conservatively charges one block per
    /// resident layer per running sequence per stage.
    fn forecast_min_avail(&self, ctx: &SchedContext) -> i64 {
        let mut avail = ctx.kv.gpu.available() as i64;
        let mut min_avail = avail;
        for stage in 1..=FORECAST_STAGES {
            let horizon_tokens = stage * ctx.cfg.block_size;
            let mut released = 0i64;
            let mut allocated = 0i64;
            for &rid in ctx.running {
                let r = &ctx.requests[rid];
                let Some(table) = ctx.kv.table(rid) else { continue };
                let remaining = r.predicted_median().saturating_sub(r.generated);
                if remaining <= horizon_tokens && remaining > (stage - 1) * ctx.cfg.block_size {
                    // predicted to finish within this stage
                    released += table.gpu_blocks_held() as i64;
                } else if remaining > horizon_tokens {
                    allocated += table.n_gpu_layers() as i64;
                }
            }
            avail += released - allocated;
            min_avail = min_avail.min(avail);
        }
        min_avail
    }
}

impl Scheduler for LayerKvScheduler {
    fn name(&self) -> &'static str {
        if self.slo_aware {
            "layerkv"
        } else {
            "layerkv-no-slo"
        }
    }

    fn retained_layers(&self, ctx: &SchedContext, prompt_len: usize) -> usize {
        match ctx.cfg.x_override {
            Some(x) => x.min(ctx.cfg.model.n_layers),
            None => ctx.cost.min_resident_layers(prompt_len),
        }
    }

    /// Algorithm 1 + layer-wise block feasibility, generalized to the
    /// GPU -> host -> disk hierarchy: non-retained layers fill the host
    /// pool first; overflow continues to the disk tier, and the retained
    /// count x is re-solved against the slower disk link (its transfer —
    /// and the symmetric restore — must still hide under the prefill
    /// window, §3.1.1). With no disk pool this is exactly the two-tier
    /// admission loop.
    fn decide(&mut self, ctx: &SchedContext) -> Action {
        let slack = if self.slo_aware { self.min_slack(ctx) } else { f64::INFINITY };

        let mut admitted = Vec::new();
        let mut sum_prefill = 0.0;
        let mut free_gpu = ctx.kv.gpu.available();
        let mut free_cpu = ctx.kv.cpu.available();
        let mut free_disk = ctx.kv.disk.available();
        let disk_enabled = ctx.kv.disk.total() > 0;
        let l = ctx.cfg.model.n_layers;
        let mut batched_tokens = 0usize;
        let mut seqs = ctx.running.len();

        if slack > 0.0 {
            for &rid in ctx.waiting {
                let r = &ctx.requests[rid];
                let len = r.prefill_len();
                // Cache-aware admission: time gates (token budget, Eq. 2
                // slack) see only the un-cached suffix the GPU will
                // compute; block demand below stays on the full length so
                // the solve matches what the engine actually allocates.
                let eff = ctx.effective_prefill_len(rid);
                let mut x = self.retained_layers(ctx, len);
                let per_layer = len.div_ceil(ctx.cfg.block_size);
                let (need_gpu, need_cpu, need_disk) = if disk_enabled {
                    // deeper tier in play: the shared feasibility solve
                    // accounts the disk link's (restore) cost in x and
                    // splits the non-retained layers host-first
                    let (xt, host_layers) =
                        ctx.cost.tiered_admission(len, x, per_layer, free_cpu);
                    x = xt;
                    (
                        per_layer * x,
                        per_layer * host_layers,
                        per_layer * (l - x - host_layers),
                    )
                } else {
                    (per_layer * x, per_layer * (l - x), 0)
                };
                if seqs + 1 > ctx.cfg.max_num_seqs
                    || batched_tokens + eff > ctx.cfg.max_batched_tokens
                    || free_gpu < need_gpu
                    || free_cpu < need_cpu
                    || free_disk < need_disk
                {
                    break;
                }
                // Algorithm 1 line 6: admit while the cumulative prefill
                // time stays inside every decoder's slack.
                let t_prefill = ctx.cost.prefill_time(eff);
                if self.slo_aware && sum_prefill + t_prefill >= slack {
                    break;
                }
                sum_prefill += t_prefill;
                free_gpu -= need_gpu;
                free_cpu -= need_cpu;
                free_disk -= need_disk;
                batched_tokens += eff;
                seqs += 1;
                admitted.push((rid, x)); // x already solved: engine reuses it
            }
        }

        if !admitted.is_empty() {
            Action::Prefill(admitted)
        } else if !ctx.running.is_empty() {
            Action::Decode
        } else if !ctx.waiting.is_empty() {
            // queue blocked purely by resources (or slack): if nothing is
            // decoding we have to wait for arrivals/releases
            Action::Wait
        } else {
            Action::Wait
        }
    }

    /// §3.1.1 last paragraph: when the forecast dips below the threshold,
    /// offload retained layers of the *most recently prefilled* decoding
    /// requests — first half their resident layers (x/2), then all.
    ///
    /// §Perf: the engine keeps `ctx.running` sorted oldest-first, so
    /// "most recent first" is a reverse iteration — no per-call sort, and
    /// resident layers are walked in place instead of materialised.
    fn proactive_offloads(&mut self, ctx: &SchedContext) -> OffloadPlan {
        // §Perf: the stage-by-stage forecast only matters near pressure;
        // with >25% of the pool free it cannot dip below the (10%)
        // threshold within the horizon of a few stages.
        if ctx.kv.gpu.available() * 4 > ctx.kv.gpu.total() {
            return Vec::new();
        }
        let threshold = *self.threshold_blocks.get_or_insert_with(|| {
            (ctx.cfg.avail_threshold_frac * ctx.kv.gpu.total() as f64) as i64
        });
        let mut shortfall = threshold - self.forecast_min_avail(ctx);
        if shortfall <= 0 {
            return Vec::new();
        }

        let mut plan = Vec::new();
        // pass 1: x/2 layers each; pass 2: the rest
        for pass in 0..2 {
            // most recently prefilled first = reverse of the engine order
            for &rid in ctx.running.iter().rev() {
                if ctx.requests[rid].phase != Phase::Decoding {
                    continue;
                }
                if shortfall <= 0 {
                    return plan;
                }
                let Some(table) = ctx.kv.table(rid) else { continue };
                let resident = table.n_gpu_layers();
                let take = if pass == 0 { resident / 2 } else { resident };
                let per_layer = table.blocks_per_layer(table.tokens).max(1);
                let mut taken = 0usize;
                for (layer, entry) in table.layers.iter().enumerate() {
                    if taken >= take {
                        break;
                    }
                    if entry.residency != Residency::Gpu {
                        continue;
                    }
                    taken += 1;
                    if plan.contains(&(rid, layer)) {
                        continue;
                    }
                    plan.push((rid, layer));
                    shortfall -= per_layer as i64;
                    if shortfall <= 0 {
                        return plan;
                    }
                }
            }
        }
        plan
    }

    fn observe_decode_step(&mut self, dt: f64) {
        LayerKvScheduler::observe_decode_step(self, dt);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Policy, ServingConfig};
    use crate::coordinator::block::KvManager;
    use crate::coordinator::request::Request;
    use crate::sim::CostModel;
    use crate::workload::TraceRequest;

    struct Fixture {
        cfg: ServingConfig,
        cost: CostModel,
        kv: KvManager,
        requests: Vec<Request>,
        waiting: Vec<ReqId>,
        running: Vec<ReqId>,
    }

    impl Fixture {
        fn new(gpu_blocks: usize) -> Self {
            let cfg = ServingConfig::llama2_7b_tp1()
                .with_policy(Policy::LayerKv { slo_aware: true });
            let cost = CostModel::new(cfg.clone());
            let kv = KvManager::new(gpu_blocks, 1_000_000, cfg.block_size, cfg.model.n_layers);
            Fixture { cfg, cost, kv, requests: Vec::new(), waiting: Vec::new(), running: Vec::new() }
        }

        fn add_waiting(&mut self, prompt_len: usize) -> ReqId {
            let id = self.requests.len();
            self.requests.push(Request::from_trace(
                &TraceRequest { id, arrival: 0.0, prompt_len, output_len: 512, ..Default::default() },
                (256, 512),
            ));
            self.waiting.push(id);
            id
        }

        fn add_decoding(&mut self, prompt_len: usize, generated: usize, first_token: f64) -> ReqId {
            let id = self.requests.len();
            let mut r = Request::from_trace(
                &TraceRequest { id, arrival: 0.0, prompt_len, output_len: 512, ..Default::default() },
                (256, 512),
            );
            r.phase = Phase::Decoding;
            r.generated = generated;
            r.prefill_start = Some(first_token - 0.1);
            r.first_token = Some(first_token);
            self.requests.push(r);
            self.running.push(id);
            self.kv
                .allocate_full(id, prompt_len + generated)
                .expect("fixture decode alloc");
            id
        }

        fn ctx(&self, now: f64) -> SchedContext<'_> {
            SchedContext {
                now,
                waiting: &self.waiting,
                running: &self.running,
                requests: &self.requests,
                kv: &self.kv,
                cost: &self.cost,
                cfg: &self.cfg,
            }
        }
    }

    #[test]
    fn admits_long_prompt_with_few_gpu_blocks() {
        // 16k prompt under vLLM needs 1024 blocks * 32 layers = 32768
        // layer-blocks; LayerKV's x is 0 for 16k so a tiny pool suffices.
        let mut f = Fixture::new(2048);
        let rid = f.add_waiting(16 * 1024);
        let mut s = LayerKvScheduler::new(true);
        assert_eq!(s.retained_layers(&f.ctx(0.0), 16 * 1024), 0);
        assert_eq!(s.decide(&f.ctx(0.0)), Action::Prefill(vec![(rid, 0)]));
    }

    #[test]
    fn short_prompt_retains_layers_on_slow_link() {
        let mut f = Fixture::new(2048);
        f.cfg.node.pcie.bandwidth = 1.0e9;
        f.cost = CostModel::new(f.cfg.clone());
        let s = LayerKvScheduler::new(true);
        let x = s.retained_layers(&f.ctx(0.0), 64);
        assert!(x > 0, "short prompts must retain x > 0 layers on a slow link");
    }

    #[test]
    fn slo_gate_blocks_when_decoder_has_no_slack() {
        let mut f = Fixture::new(100_000);
        f.add_waiting(8192);
        // a decoder already at its TPOT budget: 100 tokens in 100*tpot_slo
        let now = 30.0;
        let rid = f.add_decoding(1024, 100, now - 100.0 * f.cfg.slo.tpot_s);
        // its future needs the full remaining budget -> slack ~ 0
        let mut s = LayerKvScheduler::new(true);
        s.observe_decode_step(f.cfg.slo.tpot_s); // future estimated at SLO rate
        let slack = s.t_allow_prefill(&f.ctx(now), rid);
        assert!(slack < 0.5, "slack={slack}");
        assert_eq!(s.decide(&f.ctx(now)), Action::Decode);
    }

    #[test]
    fn slo_gate_admits_when_slack_ample() {
        let mut f = Fixture::new(100_000);
        let w = f.add_waiting(128);
        // decoder running well ahead of its TPOT budget
        let now = 1.0;
        f.add_decoding(1024, 50, now - 50.0 * 0.02); // 20ms/token << 200ms SLO
        let mut s = LayerKvScheduler::new(true);
        s.observe_decode_step(0.02);
        let x = s.retained_layers(&f.ctx(now), 128);
        assert_eq!(s.decide(&f.ctx(now)), Action::Prefill(vec![(w, x)]));
    }

    #[test]
    fn no_slo_variant_ignores_slack() {
        let mut f = Fixture::new(100_000);
        let w = f.add_waiting(8192);
        let now = 30.0;
        f.add_decoding(1024, 100, now - 100.0 * f.cfg.slo.tpot_s);
        let mut s = LayerKvScheduler::new(false);
        s.observe_decode_step(f.cfg.slo.tpot_s);
        // ablation admits regardless — this is what trades TPOT for TTFT
        let x = s.retained_layers(&f.ctx(now), 8192);
        assert_eq!(s.decide(&f.ctx(now)), Action::Prefill(vec![(w, x)]));
    }

    #[test]
    fn eq2_caps_number_of_admissions() {
        let mut f = Fixture::new(1_000_000);
        for _ in 0..8 {
            f.add_waiting(8192);
        }
        let now = 1.0;
        // decoder with ~2.5s of slack; each 8k prefill is ~1s
        f.add_decoding(512, 20, now - 20.0 * 0.08);
        let mut s = LayerKvScheduler::new(true);
        s.observe_decode_step(0.08);
        let slack = s.min_slack(&f.ctx(now));
        assert!(slack.is_finite() && slack > 0.0);
        match s.decide(&f.ctx(now)) {
            Action::Prefill(reqs) => {
                let t1 = f.cost.prefill_time(8192);
                let expect = (slack / t1).ceil() as usize;
                assert!(
                    !reqs.is_empty() && reqs.len() <= expect && reqs.len() < 8,
                    "admitted {} with slack {slack} (prefill {t1})",
                    reqs.len()
                );
            }
            a => panic!("expected Prefill, got {a:?}"),
        }
    }

    #[test]
    fn tiered_admission_overflows_host_to_disk() {
        use crate::config::DiskSpec;
        // host pool far too small for a 16k prompt's non-retained layers;
        // a disk tier absorbs the overflow, with the retained count
        // re-solved against the slower disk link (never smaller than the
        // host-only solve)
        let mut f = Fixture::new(1_000_000);
        f.cfg.node.disk = DiskSpec::nvme_4tb();
        f.cost = CostModel::new(f.cfg.clone());
        let host_blocks = 2048; // 16k prompt needs 1024 blocks/layer
        f.kv = KvManager::new_tiered(
            1_000_000,
            host_blocks,
            1_000_000,
            f.cfg.block_size,
            f.cfg.model.n_layers,
        );
        let rid = f.add_waiting(16 * 1024);
        let mut s = LayerKvScheduler::new(true);
        let x_flat = s.retained_layers(&f.ctx(0.0), 16 * 1024);
        match s.decide(&f.ctx(0.0)) {
            Action::Prefill(reqs) => {
                assert_eq!(reqs.len(), 1);
                let (id, x) = reqs[0];
                assert_eq!(id, rid);
                let host_cap = host_blocks / 1024; // 2 layers fit the host
                let x_tiered =
                    f.cost.min_resident_layers_tiered(16 * 1024, host_cap);
                assert_eq!(x, x_flat.max(x_tiered));
                assert!(x >= x_flat);
            }
            a => panic!("expected Prefill, got {a:?}"),
        }
        // without the disk tier the same admission must wait
        let mut two = Fixture::new(1_000_000);
        two.kv = KvManager::new_tiered(
            1_000_000,
            host_blocks,
            0,
            two.cfg.block_size,
            two.cfg.model.n_layers,
        );
        two.add_waiting(16 * 1024);
        let mut s2 = LayerKvScheduler::new(true);
        assert_eq!(s2.decide(&two.ctx(0.0)), Action::Wait);
    }

    #[test]
    fn forecast_triggers_offload_when_pool_tight() {
        // tiny pool: one decoder holding most blocks, queue pressure ahead
        let mut f = Fixture::new(40);
        let now = 5.0;
        f.add_decoding(16, 0, now - 0.1); // 1 block * 32 layers = 32 blocks
        let mut s = LayerKvScheduler::new(true);
        let plan = s.proactive_offloads(&f.ctx(now));
        assert!(!plan.is_empty(), "tight pool must trigger proactive offload");
        // plan targets the decoding request's resident layers
        assert!(plan.iter().all(|&(rid, layer)| rid == 0 && layer < 32));
    }

    #[test]
    fn forecast_quiet_when_pool_ample() {
        let mut f = Fixture::new(1_000_000);
        let now = 5.0;
        f.add_decoding(1024, 10, now - 0.5);
        let mut s = LayerKvScheduler::new(true);
        assert!(s.proactive_offloads(&f.ctx(now)).is_empty());
    }
}
