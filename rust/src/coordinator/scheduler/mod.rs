//! Scheduler interface shared by the vLLM baseline and LayerKV.
//!
//! Iteration-level (continuous) batching: every engine step the scheduler
//! inspects queue + running set + pool state and picks ONE action —
//! admit a batch of prefills, run one decode iteration, or idle.

pub mod layerkv;
pub mod vllm;

pub use layerkv::LayerKvScheduler;
pub use vllm::VllmScheduler;

use crate::config::ServingConfig;
use crate::coordinator::block::KvManager;
use crate::coordinator::request::{ReqId, Request};
use crate::sim::CostModel;

/// Read-only view the engine hands the scheduler each step.
pub struct SchedContext<'a> {
    pub now: f64,
    /// FCFS queue (front first). Includes recompute-preempted requests.
    pub waiting: &'a [ReqId],
    /// Requests currently in the decode phase. §Perf invariant: the engine
    /// keeps this sorted by `prefill_start` ascending (oldest admitted
    /// first), so policies that need recency ordering iterate instead of
    /// sorting a copy each step.
    pub running: &'a [ReqId],
    /// All requests, indexed by id.
    pub requests: &'a [Request],
    pub kv: &'a KvManager,
    pub cost: &'a CostModel,
    pub cfg: &'a ServingConfig,
}

impl SchedContext<'_> {
    /// Prefill tokens the GPU will actually *compute* for this request:
    /// the prompt minus whatever block-aligned prefix the cache can
    /// serve. Mirrors the engine's `prefix_acquire` cap exactly (same
    /// floor-to-block-boundary, same "keep at least one token" clamp),
    /// so admission gates on the cost the backend will later charge.
    /// Block *demand* intentionally still uses the full length — cached
    /// blocks are re-materialised into the request's own table, so the
    /// allocation the scheduler solves for is unchanged.
    pub fn effective_prefill_len(&self, rid: ReqId) -> usize {
        let r = &self.requests[rid];
        let len = r.prefill_len();
        if !self.cfg.prefix_cache || r.prefix.hash == 0 {
            return len;
        }
        match self.kv.prefix_probe(r.prefix.hash) {
            Some((tokens, _)) => {
                let want = r.prefix.len.min(len.saturating_sub(1));
                len - tokens.min(want / self.cfg.block_size * self.cfg.block_size)
            }
            None => len,
        }
    }
}

/// What the engine should do this step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Action {
    /// Run the prefill of these queued requests (one batched step). Each
    /// entry carries the retained-layer count `x` the scheduler already
    /// solved during admission (§3.1.1), so the engine allocates without
    /// rebuilding a scheduling context.
    Prefill(Vec<(ReqId, usize)>),
    /// Run one decode iteration over the running set.
    Decode,
    /// Nothing runnable: idle until the next arrival.
    Wait,
}

/// A (request, layer) pair to offload GPU -> host.
pub type OffloadPlan = Vec<(ReqId, usize)>;

pub trait Scheduler {
    fn name(&self) -> &'static str;

    /// Pick this step's action.
    fn decide(&mut self, ctx: &SchedContext) -> Action;

    /// How many layers admission must retain on the GPU for a prompt of
    /// this length (§3.1.1's x; the vLLM baseline retains all layers).
    fn retained_layers(&self, ctx: &SchedContext, prompt_len: usize) -> usize {
        let _ = prompt_len;
        ctx.cfg.model.n_layers
    }

    /// Eq. 5 proactive offloading: layers to move to the host *now*
    /// because the block-availability forecast runs short. Baseline: none.
    fn proactive_offloads(&mut self, ctx: &SchedContext) -> OffloadPlan {
        let _ = ctx;
        Vec::new()
    }

    /// Feedback: a decode step of this duration just executed (LayerKV's
    /// T_future estimator consumes it; baseline ignores it).
    fn observe_decode_step(&mut self, dt: f64) {
        let _ = dt;
    }
}

/// Construct the scheduler for a policy.
pub fn make_scheduler(cfg: &ServingConfig) -> Box<dyn Scheduler> {
    match cfg.policy {
        crate::config::Policy::Vllm => Box::new(VllmScheduler::new()),
        crate::config::Policy::LayerKv { slo_aware } => {
            Box::new(LayerKvScheduler::new(slo_aware))
        }
    }
}
