//! Baseline scheduler with vLLM 0.5.x semantics (the paper's comparator):
//!
//! * prefill-priority continuous batching: whenever queued requests fit,
//!   run a prefill-only step before more decode iterations;
//! * request-wise KV admission (Fig. 2): a prompt is admitted only when
//!   blocks for its FULL prompt KV — all layers — are free, with a small
//!   watermark held back;
//! * FCFS with head-of-line blocking (no reordering past the head);
//! * caps: max_num_seqs running sequences, max_batched_tokens per step.
//!
//! This is exactly the admission rule whose clash with long prompts
//! produces the queuing-delay explosion of Fig. 1.

use super::{Action, SchedContext, Scheduler};

/// Fraction of the GPU pool kept free at admission (vLLM's watermark).
const WATERMARK: f64 = 0.01;

#[derive(Debug, Default)]
pub struct VllmScheduler {
    /// §Perf: the watermark depends only on the (fixed) pool size, so it
    /// is computed once on first `decide` instead of every step.
    watermark_blocks: Option<usize>,
}

impl VllmScheduler {
    pub fn new() -> Self {
        VllmScheduler::default()
    }
}

impl Scheduler for VllmScheduler {
    fn name(&self) -> &'static str {
        "vllm"
    }

    fn decide(&mut self, ctx: &SchedContext) -> Action {
        let watermark = *self
            .watermark_blocks
            .get_or_insert_with(|| (ctx.kv.gpu.total() as f64 * WATERMARK) as usize);
        let mut admitted = Vec::new();
        let mut free = ctx.kv.gpu.available();
        let mut batched_tokens = 0usize;
        let mut seqs = ctx.running.len();

        for &rid in ctx.waiting {
            let r = &ctx.requests[rid];
            let len = r.prefill_len();
            let need = ctx.kv.gpu_blocks_full(len);
            if seqs + 1 > ctx.cfg.max_num_seqs
                || batched_tokens + len > ctx.cfg.max_batched_tokens
                || free < need + watermark
            {
                break; // FCFS head-of-line blocking
            }
            free -= need;
            batched_tokens += len;
            seqs += 1;
            admitted.push((rid, ctx.cfg.model.n_layers)); // all layers resident
        }

        if !admitted.is_empty() {
            Action::Prefill(admitted)
        } else if !ctx.running.is_empty() {
            Action::Decode
        } else {
            Action::Wait
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ServingConfig;
    use crate::coordinator::block::KvManager;
    use crate::coordinator::request::Request;
    use crate::sim::CostModel;
    use crate::workload::TraceRequest;

    fn mk_requests(lens: &[usize]) -> Vec<Request> {
        lens.iter()
            .enumerate()
            .map(|(id, &prompt_len)| {
                Request::from_trace(
                    &TraceRequest { id, arrival: 0.0, prompt_len, output_len: 32, ..Default::default() },
                    (32, 64),
                )
            })
            .collect()
    }

    fn ctx_parts() -> (ServingConfig, CostModel) {
        let cfg = ServingConfig::llama2_7b_tp1();
        (cfg.clone(), CostModel::new(cfg))
    }

    #[test]
    fn admits_when_blocks_free() {
        let (cfg, cost) = ctx_parts();
        let kv = KvManager::new(cfg.num_gpu_layer_blocks(), 1000, cfg.block_size, cfg.model.n_layers);
        let reqs = mk_requests(&[128, 128]);
        let waiting = vec![0, 1];
        let mut s = VllmScheduler::new();
        let action = s.decide(&SchedContext {
            now: 0.0,
            waiting: &waiting,
            running: &[],
            requests: &reqs,
            kv: &kv,
            cost: &cost,
            cfg: &cfg,
        });
        assert_eq!(action, Action::Prefill(vec![(0, 32), (1, 32)]));
    }

    #[test]
    fn head_of_line_blocks_long_prompt() {
        let (cfg, cost) = ctx_parts();
        // pool sized so the 16k prompt (1024 blocks * 32 layers) cannot fit
        let kv = KvManager::new(1000, 1000, cfg.block_size, cfg.model.n_layers);
        let reqs = mk_requests(&[16384, 128]);
        let waiting = vec![0, 1];
        let mut s = VllmScheduler::new();
        let running = vec![];
        let action = s.decide(&SchedContext {
            now: 0.0,
            waiting: &waiting,
            running: &running,
            requests: &reqs,
            kv: &kv,
            cost: &cost,
            cfg: &cfg,
        });
        // head doesn't fit -> NOTHING admitted (short one blocked behind it)
        assert_eq!(action, Action::Wait);
    }

    #[test]
    fn decodes_when_queue_blocked_but_running() {
        let (cfg, cost) = ctx_parts();
        let kv = KvManager::new(10, 1000, cfg.block_size, cfg.model.n_layers);
        let reqs = mk_requests(&[16384]);
        let waiting = vec![0];
        let running = vec![];
        let mut s = VllmScheduler::new();
        // no running -> Wait
        let a = s.decide(&SchedContext {
            now: 0.0,
            waiting: &waiting,
            running: &running,
            requests: &reqs,
            kv: &kv,
            cost: &cost,
            cfg: &cfg,
        });
        assert_eq!(a, Action::Wait);
        // with running -> Decode
        let running = vec![0];
        let a = s.decide(&SchedContext {
            now: 0.0,
            waiting: &waiting,
            running: &running,
            requests: &reqs,
            kv: &kv,
            cost: &cost,
            cfg: &cfg,
        });
        assert_eq!(a, Action::Decode);
    }

    #[test]
    fn respects_max_num_seqs() {
        let (mut cfg, cost) = ctx_parts();
        cfg.max_num_seqs = 1;
        let kv = KvManager::new(cfg.num_gpu_layer_blocks(), 1000, cfg.block_size, cfg.model.n_layers);
        let reqs = mk_requests(&[128, 128]);
        let waiting = vec![0, 1];
        let mut s = VllmScheduler::new();
        let action = s.decide(&SchedContext {
            now: 0.0,
            waiting: &waiting,
            running: &[],
            requests: &reqs,
            kv: &kv,
            cost: &cost,
            cfg: &cfg,
        });
        assert_eq!(action, Action::Prefill(vec![(0, 32)]));
    }

    #[test]
    fn respects_token_budget() {
        let (mut cfg, cost) = ctx_parts();
        cfg.max_batched_tokens = 200;
        let kv = KvManager::new(cfg.num_gpu_layer_blocks(), 1000, cfg.block_size, cfg.model.n_layers);
        let reqs = mk_requests(&[128, 128]);
        let waiting = vec![0, 1];
        let mut s = VllmScheduler::new();
        let action = s.decide(&SchedContext {
            now: 0.0,
            waiting: &waiting,
            running: &[],
            requests: &reqs,
            kv: &kv,
            cost: &cost,
            cfg: &cfg,
        });
        assert_eq!(action, Action::Prefill(vec![(0, 32)]));
    }
}
