//! Experiment harness: one runner per table/figure in the paper's
//! evaluation (DESIGN.md §5 experiment index). Each runner sweeps the
//! paper's parameters through the simulation engine and returns the rows
//! the paper plots; `print_*` helpers render them as aligned text so
//! `cargo bench`/`cargo run -- experiment <id>` regenerate the series.
//!
//! §Perf: every sweep's (config, seed) cells are independent, so the
//! runners fan them across cores via `parallel::par_map` — deterministic
//! per-cell seeds, row order preserved, identical output to serial mode
//! (`LAYERKV_SERIAL=1` / `LAYERKV_THREADS=n` to control).

pub mod parallel;
pub mod plot;
pub mod report;

use crate::cluster::{
    Cluster, ClusterConfig, CrashWindow, FaultPlan, IoBurst, RouterPolicy, Straggler,
};
use crate::config::{Policy, ServingConfig, SloTargets};
use crate::coordinator::run_trace;
use crate::metrics::Report;
use crate::util::Rng;
use crate::workload::fixed::FixedWorkload;
use crate::workload::sharegpt::ShareGptWorkload;
use crate::workload::arrivals::Arrivals;
use crate::workload::Trace;

pub use parallel::{par_map, par_map_threads};
pub use plot::{render, PlotSeries};
pub use report::{print_table, Table};

/// Default predictor accuracy (the proxy-model literature the paper cites
/// reports ~0.8 bucket accuracy).
pub const PREDICTOR_ACC: f64 = 0.8;

/// Quick mode shrinks request counts so test suites stay fast.
pub fn quick() -> bool {
    std::env::var("LAYERKV_QUICK").map(|v| v != "0").unwrap_or(false)
}

fn n_requests(full: usize) -> usize {
    if quick() {
        (full / 5).max(20)
    } else {
        full
    }
}

/// The paper's three eval setups (model, TP) by short name.
pub fn setup(name: &str) -> ServingConfig {
    match name {
        "7b" => ServingConfig::llama2_7b_tp1(),
        "34b" => ServingConfig::yi_34b_tp2(),
        "70b" => ServingConfig::llama31_70b_tp4(),
        other => panic!("unknown setup {other}"),
    }
}

/// One (policy, workload) run.
pub fn run_fixed(cfg: ServingConfig, ctx_len: usize, n: usize, seed: u64) -> Report {
    let trace = FixedWorkload {
        prompt_len: ctx_len,
        output_len: 512,
        n_requests: n,
        arrivals: Arrivals::Poisson { rate: 1.0 },
    }
    .generate(&mut Rng::new(seed));
    run_trace(cfg, &trace, PREDICTOR_ACC).0
}

pub fn run_sharegpt(cfg: ServingConfig, rate: f64, n: usize, seed: u64) -> Report {
    let trace = ShareGptWorkload::paper(rate, n).generate(&mut Rng::new(seed));
    run_trace(cfg, &trace, PREDICTOR_ACC).0
}

// ---------------------------------------------------------------------
// Fig. 1 — motivation: TTFT/TPOT + queuing-vs-prefill breakdown across
// context lengths (Llama-2-7B, 1 GPU, 1 req/s, output 512, vLLM).
// ---------------------------------------------------------------------

pub struct Fig1Row {
    pub ctx: usize,
    pub ttft_mean: f64,
    pub tpot_mean: f64,
    pub queueing_mean: f64,
    pub prefill_mean: f64,
}

pub fn fig1() -> Vec<Fig1Row> {
    let n = n_requests(100);
    par_map(CONTEXTS_7B, |&ctx| {
        let max_len = ctx.max(2048);
        let cfg = setup("7b").with_max_model_len(max_len.max(16384));
        let rep = run_fixed(cfg, ctx, n, 7);
        Fig1Row {
            ctx,
            ttft_mean: rep.ttft().mean(),
            tpot_mean: rep.tpot().mean(),
            queueing_mean: rep.queueing().mean(),
            prefill_mean: rep.prefill().mean(),
        }
    })
}

pub const CONTEXTS_7B: &[usize] = &[128, 512, 1024, 2048, 4096, 8192, 16384];
pub const CONTEXTS_34B: &[usize] = &[128, 512, 1024, 2048, 4096, 8192];
pub const CONTEXTS_70B: &[usize] = &[128, 512, 1024, 2048, 4096];

pub fn print_fig1(rows: &[Fig1Row]) {
    let mut t = Table::new(
        "Fig. 1 — TTFT/TPOT and queueing-vs-prefill breakdown (Llama-2-7B, vLLM, 1 req/s)",
        &["ctx", "TTFT(s)", "TPOT(s)", "queue(s)", "prefill(s)", "queue%"],
    );
    for r in rows {
        let frac = if r.ttft_mean > 0.0 { 100.0 * r.queueing_mean / r.ttft_mean } else { 0.0 };
        t.row(&[
            r.ctx.to_string(),
            format!("{:.3}", r.ttft_mean),
            format!("{:.4}", r.tpot_mean),
            format!("{:.3}", r.queueing_mean),
            format!("{:.3}", r.prefill_mean),
            format!("{frac:.1}"),
        ]);
    }
    t.print();
}

// ---------------------------------------------------------------------
// Fig. 4 — LayerKV vs vLLM across context lengths, 3 models.
// ---------------------------------------------------------------------

pub struct Fig4Row {
    pub model: &'static str,
    pub ctx: usize,
    pub ttft_vllm: f64,
    pub ttft_layerkv: f64,
    pub tput_vllm: f64,
    pub tput_layerkv: f64,
}

/// One Fig. 4 cell: both policies on one (model, ctx) point.
fn fig4_cell(model: &'static str, ctx: usize, n: usize) -> Fig4Row {
    let base = setup(model).with_max_model_len(16384.min(setup(model).model.max_context));
    let v = run_fixed(base.clone().with_policy(Policy::Vllm), ctx, n, 11);
    let l = run_fixed(
        base.with_policy(Policy::LayerKv { slo_aware: true }),
        ctx,
        n,
        11,
    );
    Fig4Row {
        model,
        ctx,
        ttft_vllm: v.ttft().mean(),
        ttft_layerkv: l.ttft().mean(),
        tput_vllm: v.throughput_tok_s(),
        tput_layerkv: l.throughput_tok_s(),
    }
}

pub fn fig4_for(model: &'static str, contexts: &[usize]) -> Vec<Fig4Row> {
    let n = n_requests(100);
    par_map(contexts, |&ctx| fig4_cell(model, ctx, n))
}

pub fn fig4() -> Vec<Fig4Row> {
    // one flat cell list across all three models: better core utilisation
    // than three sequential per-model sweeps
    let n = n_requests(100);
    let mut cells: Vec<(&'static str, usize)> = Vec::new();
    for &ctx in CONTEXTS_7B {
        cells.push(("7b", ctx));
    }
    for &ctx in CONTEXTS_34B {
        cells.push(("34b", ctx));
    }
    for &ctx in CONTEXTS_70B {
        cells.push(("70b", ctx));
    }
    par_map(&cells, |&(model, ctx)| fig4_cell(model, ctx, n))
}

pub fn print_fig4(rows: &[Fig4Row]) {
    let mut t = Table::new(
        "Fig. 4 — LayerKV vs vLLM under varying context lengths (1 req/s, output 512)",
        &["model", "ctx", "TTFT vLLM(s)", "TTFT LayerKV(s)", "speedup", "tput vLLM", "tput LKV", "tput ratio"],
    );
    for r in rows {
        t.row(&[
            r.model.to_string(),
            r.ctx.to_string(),
            format!("{:.2}", r.ttft_vllm),
            format!("{:.2}", r.ttft_layerkv),
            format!("{:.1}x", r.ttft_vllm / r.ttft_layerkv.max(1e-9)),
            format!("{:.1}", r.tput_vllm),
            format!("{:.1}", r.tput_layerkv),
            format!("{:.3}", r.tput_layerkv / r.tput_vllm.max(1e-9)),
        ]);
    }
    t.print();
    // the paper's log-scale TTFT line plot, per model
    for model in ["7b", "34b", "70b"] {
        let pts = |f: &dyn Fn(&Fig4Row) -> f64| -> Vec<(f64, f64)> {
            rows.iter().filter(|r| r.model == model).map(|r| (r.ctx as f64, f(r))).collect()
        };
        let series = [
            PlotSeries { name: "vLLM".into(), points: pts(&|r| r.ttft_vllm.max(1e-3)), glyph: 'v' },
            PlotSeries { name: "LayerKV".into(), points: pts(&|r| r.ttft_layerkv.max(1e-3)), glyph: 'L' },
        ];
        if !series[0].points.is_empty() {
            print!("{}", render(&format!("Fig. 4 TTFT vs context — {model} (log y)"), &series, 64, 12, true));
        }
    }
}

// ---------------------------------------------------------------------
// Fig. 5 — degree of parallelism (Yi-34B, TP 2/4/8).
// ---------------------------------------------------------------------

pub struct Fig5Row {
    pub tp: usize,
    pub ctx: usize,
    pub ttft_vllm: f64,
    pub ttft_layerkv: f64,
    pub tput_vllm: f64,
    pub tput_layerkv: f64,
}

pub fn fig5() -> Vec<Fig5Row> {
    let n = n_requests(100);
    let mut cells: Vec<(usize, usize)> = Vec::new();
    for &tp in &[2usize, 4, 8] {
        for &ctx in CONTEXTS_34B {
            cells.push((tp, ctx));
        }
    }
    par_map(&cells, |&(tp, ctx)| {
        let mut base = setup("34b");
        base.tp = tp;
        let v = run_fixed(base.clone().with_policy(Policy::Vllm), ctx, n, 13);
        let l = run_fixed(
            base.clone().with_policy(Policy::LayerKv { slo_aware: true }),
            ctx,
            n,
            13,
        );
        Fig5Row {
            tp,
            ctx,
            ttft_vllm: v.ttft().mean(),
            ttft_layerkv: l.ttft().mean(),
            tput_vllm: v.throughput_tok_s(),
            tput_layerkv: l.throughput_tok_s(),
        }
    })
}

pub fn print_fig5(rows: &[Fig5Row]) {
    let mut t = Table::new(
        "Fig. 5 — varying degree of parallelism (Yi-34B-200K)",
        &["TP", "ctx", "TTFT vLLM(s)", "TTFT LayerKV(s)", "speedup", "tput ratio"],
    );
    for r in rows {
        t.row(&[
            r.tp.to_string(),
            r.ctx.to_string(),
            format!("{:.2}", r.ttft_vllm),
            format!("{:.2}", r.ttft_layerkv),
            format!("{:.1}x", r.ttft_vllm / r.ttft_layerkv.max(1e-9)),
            format!("{:.3}", r.tput_layerkv / r.tput_vllm.max(1e-9)),
        ]);
    }
    t.print();
}

// ---------------------------------------------------------------------
// Figs. 6 & 7 — ShareGPT arrival-rate sweep: mean + P99 TTFT, throughput.
// ---------------------------------------------------------------------

pub const RATES: &[f64] = &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0];

pub struct Fig67Row {
    pub rate: f64,
    pub ttft_mean_vllm: f64,
    pub ttft_mean_layerkv: f64,
    pub ttft_p99_vllm: f64,
    pub ttft_p99_layerkv: f64,
    pub tput_vllm: f64,
    pub tput_layerkv: f64,
}

pub fn fig6_7() -> Vec<Fig67Row> {
    let n = n_requests(500);
    par_map(RATES, |&rate| {
        let base = setup("7b");
        let v = run_sharegpt(base.clone().with_policy(Policy::Vllm), rate, n, 17);
        let l = run_sharegpt(
            base.with_policy(Policy::LayerKv { slo_aware: true }),
            rate,
            n,
            17,
        );
        let (mut vt, mut lt) = (v.ttft(), l.ttft());
        Fig67Row {
            rate,
            ttft_mean_vllm: vt.mean(),
            ttft_mean_layerkv: lt.mean(),
            ttft_p99_vllm: vt.p99(),
            ttft_p99_layerkv: lt.p99(),
            tput_vllm: v.throughput_tok_s(),
            tput_layerkv: l.throughput_tok_s(),
        }
    })
}

pub fn print_fig6(rows: &[Fig67Row]) {
    let mut t = Table::new(
        "Fig. 6 — ShareGPT, varying arrival rates (Llama-2-7B): mean TTFT + throughput",
        &["req/s", "TTFT vLLM(s)", "TTFT LayerKV(s)", "speedup", "tput vLLM", "tput LKV", "ratio"],
    );
    for r in rows {
        t.row(&[
            format!("{:.1}", r.rate),
            format!("{:.2}", r.ttft_mean_vllm),
            format!("{:.2}", r.ttft_mean_layerkv),
            format!("{:.1}x", r.ttft_mean_vllm / r.ttft_mean_layerkv.max(1e-9)),
            format!("{:.1}", r.tput_vllm),
            format!("{:.1}", r.tput_layerkv),
            format!("{:.3}", r.tput_layerkv / r.tput_vllm.max(1e-9)),
        ]);
    }
    t.print();
    let series = [
        PlotSeries {
            name: "vLLM".into(),
            points: rows.iter().map(|r| (r.rate, r.ttft_mean_vllm.max(1e-3))).collect(),
            glyph: 'v',
        },
        PlotSeries {
            name: "LayerKV".into(),
            points: rows.iter().map(|r| (r.rate, r.ttft_mean_layerkv.max(1e-3))).collect(),
            glyph: 'L',
        },
    ];
    print!("{}", render("Fig. 6 mean TTFT vs arrival rate (log y)", &series, 64, 12, true));
}

pub fn print_fig7(rows: &[Fig67Row]) {
    let mut t = Table::new(
        "Fig. 7 — ShareGPT, varying arrival rates: P99 TTFT",
        &["req/s", "P99 vLLM(s)", "P99 LayerKV(s)", "speedup"],
    );
    for r in rows {
        t.row(&[
            format!("{:.1}", r.rate),
            format!("{:.2}", r.ttft_p99_vllm),
            format!("{:.2}", r.ttft_p99_layerkv),
            format!("{:.1}x", r.ttft_p99_vllm / r.ttft_p99_layerkv.max(1e-9)),
        ]);
    }
    t.print();
}

// ---------------------------------------------------------------------
// Fig. 8 — SLO violation rate sweep, incl. the no-SLO-scheduler ablation.
// ---------------------------------------------------------------------

pub struct Fig8Row {
    pub rate: f64,
    pub viol_vllm: f64,
    pub viol_layerkv: f64,
    pub viol_layerkv_noslo: f64,
}

pub fn fig8() -> Vec<Fig8Row> {
    let n = n_requests(500);
    let slo = SloTargets { ttft_s: 3.0, tpot_s: 0.2 };
    par_map(&[4.0, 4.5, 5.0, 5.5, 6.0, 6.5, 7.0, 7.5, 8.0], |&rate| {
        let mut base = setup("7b");
        base.slo = slo;
        let v = run_sharegpt(base.clone().with_policy(Policy::Vllm), rate, n, 19);
        let l = run_sharegpt(
            base.clone().with_policy(Policy::LayerKv { slo_aware: true }),
            rate,
            n,
            19,
        );
        let ln = run_sharegpt(
            base.with_policy(Policy::LayerKv { slo_aware: false }),
            rate,
            n,
            19,
        );
        Fig8Row {
            rate,
            viol_vllm: v.slo_violation_rate(&slo),
            viol_layerkv: l.slo_violation_rate(&slo),
            viol_layerkv_noslo: ln.slo_violation_rate(&slo),
        }
    })
}

pub fn print_fig8(rows: &[Fig8Row]) {
    let mut t = Table::new(
        "Fig. 8 — SLO violation rate (TTFT<=3s, TPOT<=200ms), ShareGPT",
        &["req/s", "vLLM %", "LayerKV %", "LayerKV w/o SLO-sched %"],
    );
    for r in rows {
        t.row(&[
            format!("{:.1}", r.rate),
            format!("{:.1}", 100.0 * r.viol_vllm),
            format!("{:.1}", 100.0 * r.viol_layerkv),
            format!("{:.1}", 100.0 * r.viol_layerkv_noslo),
        ]);
    }
    t.print();
}

// ---------------------------------------------------------------------
// Tier sweep — the KV hierarchy experiment: a host-saturating long-prompt
// workload swept over disk-tier capacities. Disk capacity 0 is the
// two-tier baseline, which must reject (or queue) what the deeper
// hierarchy serves; growing the disk tier converts rejections into
// completions at bounded TTFT.
// ---------------------------------------------------------------------

pub struct TierSweepRow {
    /// Disk tier capacity in GB (0 = host-only baseline).
    pub disk_gb: u64,
    pub completed: usize,
    pub rejected: usize,
    pub ttft_mean: f64,
    pub queue_mean: f64,
    /// MB written to the disk tier (admission overflow + runtime spills).
    pub spill_mb: f64,
    /// MB read back by deep restores.
    pub restore_mb: f64,
}

/// The sweep at an explicit request count (tests use a small one).
pub fn tier_sweep_with(n: usize) -> Vec<TierSweepRow> {
    use crate::config::DiskSpec;
    const DISK_GB: &[u64] = &[0, 8, 64, 512];
    par_map(DISK_GB, |&gb| {
        let mut cfg = setup("7b").with_policy(Policy::LayerKv { slo_aware: true });
        // starve the host swap pool so long prompts overflow it: 1 GB of
        // host KV vs ~0.5 GB of host demand per 4k prompt
        cfg.cpu_swap_bytes = 1 << 30;
        if gb > 0 {
            cfg.node.disk = DiskSpec::nvme(gb * (1u64 << 30));
        }
        let trace = FixedWorkload {
            prompt_len: 4096,
            output_len: 64,
            n_requests: n,
            arrivals: Arrivals::Poisson { rate: 1.0 },
        }
        .generate(&mut Rng::new(23));
        let (rep, stats) = run_trace(cfg, &trace, PREDICTOR_ACC);
        TierSweepRow {
            disk_gb: gb,
            completed: rep.records.len(),
            rejected: stats.dropped.len(),
            ttft_mean: rep.ttft().mean(),
            queue_mean: rep.queueing().mean(),
            spill_mb: stats.spill_bytes / 1e6,
            restore_mb: stats.disk_restore_bytes / 1e6,
        }
    })
}

pub fn tier_sweep() -> Vec<TierSweepRow> {
    tier_sweep_with(n_requests(60))
}

pub fn print_tier_sweep(rows: &[TierSweepRow]) {
    let mut t = Table::new(
        "Tier sweep — GPU->host->disk hierarchy under host-saturating 4k prompts \
         (1 GB host swap, 1 req/s)",
        &["disk GB", "completed", "rejected", "TTFT(s)", "queue(s)", "spill MB", "restore MB"],
    );
    for r in rows {
        t.row(&[
            r.disk_gb.to_string(),
            r.completed.to_string(),
            r.rejected.to_string(),
            format!("{:.3}", r.ttft_mean),
            format!("{:.3}", r.queue_mean),
            format!("{:.1}", r.spill_mb),
            format!("{:.1}", r.restore_mb),
        ]);
    }
    t.print();
}

// ---------------------------------------------------------------------
// Bursty scenario — single engine under two-state on/off arrivals vs a
// Poisson trace at the same mean rate: the clumped arrivals inflate the
// TTFT tail far beyond what the mean rate predicts, which is the regime
// the cluster router has to absorb one level up.
// ---------------------------------------------------------------------

pub struct BurstyRow {
    pub arrivals: &'static str,
    pub policy: Policy,
    pub ttft_mean: f64,
    pub ttft_p99: f64,
    pub viol: f64,
    pub tput: f64,
}

pub fn bursty() -> Vec<BurstyRow> {
    let n = n_requests(400);
    let rate = 3.0;
    let mut cells: Vec<(&'static str, Policy)> = Vec::new();
    for arrivals in ["poisson", "on/off 2x"] {
        for policy in [Policy::Vllm, Policy::LayerKv { slo_aware: true }] {
            cells.push((arrivals, policy));
        }
    }
    par_map(&cells, |&(arrivals, policy)| {
        let mut w = ShareGptWorkload::paper(rate, n);
        if arrivals != "poisson" {
            w.arrivals = Arrivals::bursty(rate, 2.0);
        }
        let trace = w.generate(&mut Rng::new(31));
        let cfg = setup("7b").with_policy(policy);
        let slo = cfg.slo;
        let (rep, _) = run_trace(cfg, &trace, PREDICTOR_ACC);
        let mut ttft = rep.ttft();
        BurstyRow {
            arrivals,
            policy,
            ttft_mean: ttft.mean(),
            ttft_p99: ttft.p99(),
            viol: rep.slo_violation_rate(&slo),
            tput: rep.throughput_tok_s(),
        }
    })
}

pub fn print_bursty(rows: &[BurstyRow]) {
    let mut t = Table::new(
        "Bursty arrivals — on/off (MMPP-style) vs Poisson at the same 3 req/s mean \
         (ShareGPT, Llama-2-7B)",
        &["arrivals", "policy", "TTFT mean(s)", "TTFT p99(s)", "viol %", "tok/s"],
    );
    for r in rows {
        t.row(&[
            r.arrivals.to_string(),
            r.policy.name().to_string(),
            format!("{:.2}", r.ttft_mean),
            format!("{:.2}", r.ttft_p99),
            format!("{:.1}", 100.0 * r.viol),
            format!("{:.1}", r.tput),
        ]);
    }
    t.print();
}

// ---------------------------------------------------------------------
// Cluster sweep — multi-replica serving: router policies × replica
// counts under bursty ShareGPT-style load, offered load scaled with the
// replica count. Round-robin is the state-blind baseline; KV-pressure
// and SLO-aware routing read the replicas' live pool aggregates / cost
// models and should hold the p99 TTFT and violation tail down.
// ---------------------------------------------------------------------

/// The reference per-replica load (req/s) — what the headline comparison
/// and the integration test use. The mean sits just under one engine's
/// ShareGPT capacity, with the 3x bursts pushing well past it —
/// transient overload the router can absorb by spreading, rather than
/// steady-state saturation no routing policy can fix.
pub const CLUSTER_RATE_PER_REPLICA: f64 = 2.5;

pub struct ClusterRow {
    pub replicas: usize,
    pub router: RouterPolicy,
    pub rate: f64,
    pub ttft_mean: f64,
    pub ttft_p99: f64,
    pub viol: f64,
    pub tput: f64,
    /// Largest fraction of requests any one replica received.
    pub max_share: f64,
    pub dropped: usize,
}

/// The bursty ShareGPT-style trace the cluster experiment routes:
/// ShareGPT length mixture, two-state on/off arrivals at 3x burstiness
/// (bursts at 3x the mean rate, 1/3 duty cycle).
pub fn cluster_trace(mean_rate: f64, n: usize, seed: u64) -> Trace {
    let mut w = ShareGptWorkload::paper(mean_rate, n);
    w.arrivals = Arrivals::bursty(mean_rate, 3.0);
    w.generate(&mut Rng::new(seed))
}

/// Per-replica arrival rates the sweep crosses with replica counts and
/// routers: under, near, and past one engine's ShareGPT capacity.
pub const CLUSTER_RATES_PER_REPLICA: &[f64] = &[1.5, 2.5, 3.5];

/// The sweep at an explicit per-replica request count (tests use a small
/// one).
pub fn cluster_sweep_with(n_per_replica: usize) -> Vec<ClusterRow> {
    cluster_sweep_cells(&[2, 4, 8], n_per_replica)
}

/// The scaled-up sweep decode fast-forwarding pays for: fleet sizes to 32
/// replicas at several times the per-cell trace volume. Before
/// macro-stepping, each cell cost O(total output tokens) scheduler
/// invocations per replica — this grid was unaffordable in CI; now each
/// replica advances O(events) per cell (`experiment cluster-wide`;
/// `--no-macro-steps` restores the old cost for comparison).
pub fn cluster_sweep_wide() -> Vec<ClusterRow> {
    cluster_sweep_cells(&[4, 8, 16, 32], n_requests(300))
}

fn cluster_sweep_cells(replica_counts: &[usize], n_per_replica: usize) -> Vec<ClusterRow> {
    let mut cells: Vec<(usize, f64, RouterPolicy)> = Vec::new();
    for &k in replica_counts {
        for &rate_per in CLUSTER_RATES_PER_REPLICA {
            for &router in RouterPolicy::ALL {
                cells.push((k, rate_per, router));
            }
        }
    }
    par_map(&cells, |&(k, rate_per, router)| {
        let rate = rate_per * k as f64;
        // seed 23 draws a well-alternating on/off sample (realized mean
        // near nominal, many distinct bursts) rather than one mega-burst
        let trace = cluster_trace(rate, n_per_replica * k, 23);
        let cfg = setup("7b").with_policy(Policy::LayerKv { slo_aware: true });
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, k, router));
        let out = cluster.run(&trace).expect("sim cluster run");
        let s = out.summary(&cfg.slo);
        ClusterRow {
            replicas: k,
            router,
            rate,
            ttft_mean: s.ttft_mean,
            ttft_p99: s.ttft_p99,
            viol: s.viol_rate,
            tput: s.throughput_tok_s,
            max_share: s.max_share(),
            dropped: out.dropped.len(),
        }
    })
}

pub fn cluster_sweep() -> Vec<ClusterRow> {
    cluster_sweep_with(n_requests(100))
}

pub fn print_cluster(rows: &[ClusterRow]) {
    let mut t = Table::new(
        "Cluster sweep — router policies x replica counts x arrival rates, bursty \
         ShareGPT load (1.5/2.5/3.5 req/s per replica mean, 3x bursts)",
        &["replicas", "router", "req/s", "TTFT mean(s)", "TTFT p99(s)", "viol %", "tok/s", "max share", "dropped"],
    );
    for r in rows {
        t.row(&[
            r.replicas.to_string(),
            r.router.name().to_string(),
            format!("{:.1}", r.rate),
            format!("{:.2}", r.ttft_mean),
            format!("{:.2}", r.ttft_p99),
            format!("{:.1}", 100.0 * r.viol),
            format!("{:.1}", r.tput),
            format!("{:.2}", r.max_share),
            r.dropped.to_string(),
        ]);
    }
    t.print();
    // the headline comparison: state-blind vs pressure-aware at each size,
    // at the bursty-but-stable reference rate
    let mut sizes: Vec<usize> = rows.iter().map(|r| r.replicas).collect();
    sizes.sort_unstable();
    sizes.dedup();
    for &k in sizes.iter().filter(|&&k| k >= 4) {
        let get = |p: RouterPolicy| {
            rows.iter().find(|r| {
                r.replicas == k
                    && r.router == p
                    && (r.rate - CLUSTER_RATE_PER_REPLICA * k as f64).abs() < 1e-9
            })
        };
        if let (Some(rr), Some(kv), Some(slo)) = (
            get(RouterPolicy::RoundRobin),
            get(RouterPolicy::KvPressure),
            get(RouterPolicy::SloAware),
        ) {
            let best_p99 = kv.ttft_p99.min(slo.ttft_p99);
            let best_viol = kv.viol.min(slo.viol);
            println!(
                "{k} replicas: pressure-aware routing p99 TTFT {best_p99:.2}s vs \
                 round-robin {:.2}s ({:.1}x), violations {:.1}% vs {:.1}%",
                rr.ttft_p99,
                rr.ttft_p99 / best_p99.max(1e-9),
                100.0 * best_viol,
                100.0 * rr.viol,
            );
        }
    }
}

// ---------------------------------------------------------------------
// Fault sweep — router policies under injected faults on a 3-replica
// cluster: a mid-run crash (with recovery), then crash + straggler +
// disk-I/O burst together. The question is graceful degradation: every
// policy loses the same capacity, but the state-aware routers see the
// failover load and the straggler's degraded service rate in their
// scores, so they should keep goodput (SLO-meeting completions/s) and
// the p99 TTFT tail closer to the fault-free baseline than round-robin.
// ---------------------------------------------------------------------

pub struct FaultRow {
    pub scenario: &'static str,
    pub router: RouterPolicy,
    pub completed: usize,
    pub failed: usize,
    pub retries: u64,
    /// Prefill tokens whose compute was repeated by crash failover.
    pub recomputed_tokens: u64,
    /// Victims resumed from a checkpoint instead of resubmitted.
    pub adoptions: u64,
    pub downtime_s: f64,
    pub ttft_p99: f64,
    pub viol: f64,
    /// SLO-meeting completions per second of makespan.
    pub goodput: f64,
}

/// The scenarios the sweep crosses with routers. Windows are fractions of
/// the trace's arrival span so the faults always land mid-run.
pub const FAULT_SCENARIOS: &[&str] = &["none", "crashes", "crashes+stragglers"];

fn fault_plan_for(scenario: &str, horizon: f64) -> FaultPlan {
    let mut plan = FaultPlan { probation_s: horizon * 0.05, ..FaultPlan::default() };
    if scenario == "none" {
        return plan;
    }
    // one replica down for ~30% of the run, coming back
    plan.crashes.push(CrashWindow {
        replica: 0,
        at: horizon * 0.25,
        recover_at: horizon * 0.55,
    });
    if scenario == "crashes+stragglers" {
        plan.stragglers.push(Straggler {
            replica: 1,
            from: horizon * 0.2,
            until: horizon * 0.7,
            slowdown: 4.0,
        });
        plan.io_bursts.push(IoBurst {
            replica: 2,
            from: horizon * 0.3,
            until: horizon * 0.6,
        });
    }
    plan
}

/// The sweep at an explicit per-replica request count (tests and the CI
/// smoke use a small one).
pub fn fault_sweep_with(n_per_replica: usize) -> Vec<FaultRow> {
    const K: usize = 3;
    let mut cells: Vec<(&'static str, RouterPolicy)> = Vec::new();
    for &scenario in FAULT_SCENARIOS {
        for &router in RouterPolicy::ALL {
            cells.push((scenario, router));
        }
    }
    par_map(&cells, |&(scenario, router)| {
        let rate = CLUSTER_RATE_PER_REPLICA * K as f64;
        let trace = cluster_trace(rate, n_per_replica * K, 23);
        let horizon =
            trace.requests.last().map(|r| r.arrival).unwrap_or(0.0).max(1.0);
        let cfg = setup("7b").with_policy(Policy::LayerKv { slo_aware: true });
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, K, router))
            .with_faults(fault_plan_for(scenario, horizon));
        let out = cluster.run(&trace).expect("faulted cluster run");
        let f = out.faults.clone().unwrap_or_default();
        let mut ttft = out.merged.ttft();
        FaultRow {
            scenario,
            router,
            completed: out.merged.records.len(),
            failed: out.failed.len(),
            retries: f.retries,
            recomputed_tokens: f.recomputed_tokens,
            adoptions: f.adoptions,
            downtime_s: f.downtime_s,
            ttft_p99: ttft.p99(),
            viol: out.merged.slo_violation_rate(&cfg.slo),
            goodput: out.merged.goodput_req_s(&cfg.slo),
        }
    })
}

pub fn fault_sweep() -> Vec<FaultRow> {
    fault_sweep_with(n_requests(100))
}

pub fn print_faults(rows: &[FaultRow]) {
    let mut t = Table::new(
        "Fault sweep — router policies under crashes/stragglers/disk-I/O bursts \
         (3 replicas, bursty ShareGPT load, 2.5 req/s per replica mean)",
        &["scenario", "router", "completed", "failed", "retries", "recomputed tok",
          "adoptions", "down(s)", "TTFT p99(s)", "viol %", "goodput req/s"],
    );
    for r in rows {
        t.row(&[
            r.scenario.to_string(),
            r.router.name().to_string(),
            r.completed.to_string(),
            r.failed.to_string(),
            r.retries.to_string(),
            r.recomputed_tokens.to_string(),
            r.adoptions.to_string(),
            format!("{:.1}", r.downtime_s),
            format!("{:.2}", r.ttft_p99),
            format!("{:.1}", 100.0 * r.viol),
            format!("{:.3}", r.goodput),
        ]);
    }
    t.print();
    // headline: how gracefully each routing family degrades under faults
    for &scenario in FAULT_SCENARIOS.iter().filter(|&&s| s != "none") {
        let get = |p: RouterPolicy| {
            rows.iter().find(|r| r.scenario == scenario && r.router == p)
        };
        if let (Some(rr), Some(kv), Some(slo)) = (
            get(RouterPolicy::RoundRobin),
            get(RouterPolicy::KvPressure),
            get(RouterPolicy::SloAware),
        ) {
            let best_good = kv.goodput.max(slo.goodput);
            let best_p99 = kv.ttft_p99.min(slo.ttft_p99);
            println!(
                "{scenario}: pressure-/slo-aware goodput {best_good:.3} req/s vs \
                 round-robin {:.3} ({:.2}x), p99 TTFT {best_p99:.2}s vs {:.2}s",
                rr.goodput,
                best_good / rr.goodput.max(1e-9),
                rr.ttft_p99,
            );
        }
    }
}

// ---------------------------------------------------------------------
// Checkpointed failover — the stateful-failover contrast: the same
// crash-heavy plan (every replica down once, staggered so survivors can
// adopt) run recompute-only vs with layer-wise KV checkpointing to the
// NVMe tier. Without checkpoints every crash victim re-prefills its
// whole context on a survivor; with them the survivor restores the
// last checkpoint and re-prefills only the few-token suffix, so the
// recomputed-prefill-token bill collapses.
// ---------------------------------------------------------------------

/// Checkpoint cadence the contrast (and the CI smoke) uses: one
/// incremental disk checkpoint per 8 committed tokens.
pub const CKPT_EVERY: usize = 8;

pub struct CkptRow {
    /// "recompute-only" or "ckpt-8".
    pub variant: &'static str,
    pub completed: usize,
    pub failed: usize,
    pub retries: u64,
    pub adoptions: u64,
    pub recomputed_tokens: u64,
    pub resumed_tokens: u64,
    pub ttft_p99: f64,
}

/// Crash-heavy plan: each of the 3 replicas goes down once, the windows
/// staggered so no two overlap and two survivors are always up to adopt
/// the victims' checkpoints.
fn ckpt_crash_plan(horizon: f64) -> FaultPlan {
    let mut plan = FaultPlan { probation_s: horizon * 0.05, ..FaultPlan::default() };
    for r in 0..3usize {
        let at = horizon * (0.25 + 0.18 * r as f64);
        plan.crashes.push(CrashWindow { replica: r, at, recover_at: at + horizon * 0.12 });
    }
    plan
}

/// The contrast at an explicit per-replica request count (tests and the
/// CI smoke use a small one).
pub fn ckpt_contrast_with(n_per_replica: usize) -> Vec<CkptRow> {
    const K: usize = 3;
    let variants: &[(&'static str, usize)] =
        &[("recompute-only", 0), ("ckpt-8", CKPT_EVERY)];
    par_map(variants, |&(variant, every)| {
        let rate = CLUSTER_RATE_PER_REPLICA * K as f64;
        let trace = cluster_trace(rate, n_per_replica * K, 23);
        let horizon =
            trace.requests.last().map(|r| r.arrival).unwrap_or(0.0).max(1.0);
        // both variants get the NVMe tier (checkpoints live there); only
        // the cadence differs, so the contrast isolates checkpointing
        let mut cfg = setup("7b")
            .with_policy(Policy::LayerKv { slo_aware: true })
            .with_disk(crate::config::DiskSpec::nvme_4tb());
        if every > 0 {
            cfg = cfg.with_checkpointing(every);
        }
        let mut cluster =
            Cluster::new(&ClusterConfig::homogeneous(&cfg, K, RouterPolicy::KvPressure))
                .with_faults(ckpt_crash_plan(horizon));
        let out = cluster.run(&trace).expect("ckpt contrast run");
        let f = out.faults.clone().unwrap_or_default();
        let mut ttft = out.merged.ttft();
        CkptRow {
            variant,
            completed: out.merged.records.len(),
            failed: out.failed.len(),
            retries: f.retries,
            adoptions: f.adoptions,
            recomputed_tokens: f.recomputed_tokens,
            resumed_tokens: f.resumed_tokens,
            ttft_p99: ttft.p99(),
        }
    })
}

pub fn ckpt_contrast() -> Vec<CkptRow> {
    ckpt_contrast_with(n_requests(100))
}

/// Title prefix `faults-check` locates the captured table by.
pub const CKPT_TABLE_TITLE: &str = "Checkpointed failover";

pub fn print_ckpt(rows: &[CkptRow]) {
    let mut t = Table::new(
        "Checkpointed failover — crash-heavy plan (every replica down once, staggered) \
         on 3 replicas with an NVMe tier: recompute-only vs checkpointing every 8 tokens",
        &["failover", "completed", "failed", "retries", "adoptions",
          "recomputed tok", "resumed tok", "TTFT p99(s)"],
    );
    for r in rows {
        t.row(&[
            r.variant.to_string(),
            r.completed.to_string(),
            r.failed.to_string(),
            r.retries.to_string(),
            r.adoptions.to_string(),
            r.recomputed_tokens.to_string(),
            r.resumed_tokens.to_string(),
            format!("{:.2}", r.ttft_p99),
        ]);
    }
    t.print();
    let get = |v: &str| rows.iter().find(|r| r.variant == v);
    if let (Some(off), Some(on)) = (get("recompute-only"), get("ckpt-8")) {
        let red = 100.0
            * (1.0 - on.recomputed_tokens as f64 / off.recomputed_tokens.max(1) as f64);
        println!(
            "checkpointing cut recomputed prefill tokens by {red:.1}% \
             ({} -> {}), adopting {} crash victim(s) mid-decode \
             ({} tokens resumed from checkpoints)",
            off.recomputed_tokens, on.recomputed_tokens, on.adoptions, on.resumed_tokens,
        );
    }
}

// ---------------------------------------------------------------------
// Fleet sweep — the event-heap payoff run: 64-512 replicas under a
// diurnal day/night load at 3x the cluster sweep's per-replica trace
// volume. Under lockstep this grid cost O(replicas x arrivals) replica
// advances per cell (every replica touched at every arrival, fleet-wide
// idle included); the cluster-wide event heap advances only the replicas
// whose horizons actually land, so advances/request stays flat as the
// fleet grows. `set_lockstep(true)` / LAYERKV_LOCKSTEP=1 re-runs any
// cell on the oracle drive for comparison (bit-identical results).
// ---------------------------------------------------------------------

/// Replica counts the full fleet sweep crosses with routers.
pub const FLEET_SIZES: &[usize] = &[64, 128, 256, 512];
/// Quick-mode subset — CI still exercises a 256-replica cell.
pub const FLEET_SIZES_QUICK: &[usize] = &[64, 256];

pub struct FleetRow {
    pub replicas: usize,
    pub router: RouterPolicy,
    /// Long-run mean arrival rate (req/s) across the whole fleet.
    pub rate: f64,
    pub completed: usize,
    pub dropped: usize,
    pub ttft_p99: f64,
    pub viol: f64,
    pub tput: f64,
    /// Scheduler-bearing replica advances the drive spent on the cell.
    pub advances: u64,
    /// Advances per trace request — the O(total events) witness: flat
    /// across fleet sizes on the heap drive, O(replicas) under lockstep.
    pub advances_per_req: f64,
}

/// The diurnal ShareGPT-style trace the fleet routes: sinusoidal
/// day/night rate swinging 0.4x-1.6x around `mean_rate` over a 60 s
/// "day", so each cell spans two full cycles with a whole-fleet trough.
pub fn fleet_trace(mean_rate: f64, n: usize, seed: u64) -> Trace {
    let mut w = ShareGptWorkload::paper(mean_rate, n);
    w.arrivals = Arrivals::Diurnal {
        base_rate: mean_rate * 0.4,
        peak_rate: mean_rate * 1.6,
        period_s: 60.0,
    };
    w.generate(&mut Rng::new(seed))
}

/// The sweep at an explicit per-replica request count (tests and the CI
/// smoke use a small one).
pub fn fleet_sweep_with(n_per_replica: usize) -> Vec<FleetRow> {
    let sizes: &[usize] = if quick() { FLEET_SIZES_QUICK } else { FLEET_SIZES };
    let mut cells: Vec<(usize, RouterPolicy)> = Vec::new();
    for &k in sizes {
        // the state-blind baseline vs one pressure-aware router is the
        // comparison that matters at this scale; the full four-router
        // cross lives in `experiment cluster`/`cluster-wide`
        for router in [RouterPolicy::RoundRobin, RouterPolicy::KvPressure] {
            cells.push((k, router));
        }
    }
    par_map(&cells, |&(k, router)| {
        let rate = CLUSTER_RATE_PER_REPLICA * k as f64;
        let trace = fleet_trace(rate, n_per_replica * k, 41);
        let n = trace.requests.len();
        let cfg = setup("7b").with_policy(Policy::LayerKv { slo_aware: true });
        let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, k, router));
        let out = cluster.run(&trace).expect("sim fleet run");
        let s = out.summary(&cfg.slo);
        FleetRow {
            replicas: k,
            router,
            rate,
            completed: out.merged.records.len(),
            dropped: out.dropped.len(),
            ttft_p99: s.ttft_p99,
            viol: s.viol_rate,
            tput: s.throughput_tok_s,
            advances: cluster.advances(),
            advances_per_req: cluster.advances() as f64 / n.max(1) as f64,
        }
    })
}

/// 3x the cluster sweep's per-replica trace volume (quick mode shrinks
/// it the usual 5x, keeping the 256-replica cell affordable in CI).
pub fn fleet_sweep() -> Vec<FleetRow> {
    fleet_sweep_with(n_requests(300))
}

pub fn print_fleet(rows: &[FleetRow]) {
    let mut t = Table::new(
        "Fleet sweep — cluster-wide event heap at 64-512 replicas, diurnal \
         ShareGPT load (2.5 req/s per replica mean, 0.4x-1.6x day/night swing)",
        &["replicas", "router", "req/s", "completed", "dropped", "TTFT p99(s)",
          "viol %", "tok/s", "advances", "adv/req"],
    );
    for r in rows {
        t.row(&[
            r.replicas.to_string(),
            r.router.name().to_string(),
            format!("{:.0}", r.rate),
            r.completed.to_string(),
            r.dropped.to_string(),
            format!("{:.2}", r.ttft_p99),
            format!("{:.1}", 100.0 * r.viol),
            format!("{:.1}", r.tput),
            r.advances.to_string(),
            format!("{:.1}", r.advances_per_req),
        ]);
    }
    t.print();
    // headline: the O(total events) witness — advances/request must not
    // grow with the fleet (lockstep's grows linearly in replica count)
    let mut sizes: Vec<usize> = rows.iter().map(|r| r.replicas).collect();
    sizes.sort_unstable();
    sizes.dedup();
    if let (Some(&lo), Some(&hi)) = (sizes.first(), sizes.last()) {
        let mean_adv = |k: usize| {
            let cells: Vec<f64> = rows
                .iter()
                .filter(|r| r.replicas == k)
                .map(|r| r.advances_per_req)
                .collect();
            cells.iter().sum::<f64>() / cells.len().max(1) as f64
        };
        if lo != hi {
            println!(
                "event heap: {:.1} advances/request at {lo} replicas vs {:.1} at \
                 {hi} ({:.2}x across a {}x fleet growth; lockstep would scale ~{}x)",
                mean_adv(lo),
                mean_adv(hi),
                mean_adv(hi) / mean_adv(lo).max(1e-9),
                hi / lo,
                hi / lo,
            );
        }
    }
}

// ---------------------------------------------------------------------
// Prefix sweep — cross-request prefix caching on multi-turn sessions:
// the same chained-session trace with the cache off (every turn pays
// full prefill) vs on (later turns recompute only their un-cached
// suffix), plus the cluster-level comparison of kv-pressure routing
// against the prefix-aware policy that steers session turns back to
// the replica already holding their context.
// ---------------------------------------------------------------------

pub struct PrefixRow {
    /// "engine" (single replica) or "cluster-K".
    pub scope: &'static str,
    /// Cache/router variant within the scope.
    pub variant: &'static str,
    pub completed: usize,
    pub ttft_mean: f64,
    pub ttft_p99: f64,
    pub tput: f64,
    pub hits: u64,
    pub misses: u64,
    /// Prompt tokens whose recompute was skipped by cache hits.
    pub hit_tokens: u64,
    pub inserts: u64,
    pub evictions: u64,
}

/// Replicas in the cluster half of the sweep.
pub const PREFIX_CLUSTER_K: usize = 2;

/// The chained multi-turn chat trace the prefix sweep runs (see
/// `SessionWorkload::chat`): long shared system prompts, short user
/// turns, think-time gaps. Deterministic per seed.
pub fn prefix_trace(n_sessions: usize, rate: f64, seed: u64) -> Trace {
    crate::workload::SessionWorkload::chat(n_sessions, rate).generate(&mut Rng::new(seed))
}

/// The sweep at an explicit session count (tests and the CI smoke use a
/// small one).
pub fn prefix_sweep_with(n_sessions: usize) -> Vec<PrefixRow> {
    #[derive(Clone, Copy)]
    enum Cell {
        Engine { cache: bool },
        Cluster { router: RouterPolicy },
    }
    let cells = [
        Cell::Engine { cache: false },
        Cell::Engine { cache: true },
        Cell::Cluster { router: RouterPolicy::KvPressure },
        Cell::Cluster { router: RouterPolicy::PrefixAware },
    ];
    par_map(&cells, |&cell| match cell {
        Cell::Engine { cache } => {
            let trace = prefix_trace(n_sessions, 0.5, 47);
            let cfg = setup("7b")
                .with_policy(Policy::LayerKv { slo_aware: true })
                .with_prefix_cache(cache);
            let (rep, stats) = run_trace(cfg, &trace, PREDICTOR_ACC);
            let mut ttft = rep.ttft();
            PrefixRow {
                scope: "engine",
                variant: if cache { "cache" } else { "no-cache" },
                completed: rep.records.len(),
                ttft_mean: ttft.mean(),
                ttft_p99: ttft.p99(),
                tput: rep.throughput_tok_s(),
                hits: stats.prefix_hits,
                misses: stats.prefix_misses,
                hit_tokens: stats.prefix_hit_tokens,
                inserts: stats.prefix_inserts,
                evictions: stats.prefix_evictions,
            }
        }
        Cell::Cluster { router } => {
            let k = PREFIX_CLUSTER_K;
            let trace = prefix_trace(n_sessions * k, 0.5 * k as f64, 47);
            let cfg = setup("7b")
                .with_policy(Policy::LayerKv { slo_aware: true })
                .with_prefix_cache(true);
            let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, k, router));
            let out = cluster.run(&trace).expect("prefix cluster run");
            let sum = |f: &dyn Fn(&crate::coordinator::EngineStats) -> u64| -> u64 {
                out.per_replica.iter().map(|o| f(&o.stats)).sum()
            };
            let mut ttft = out.merged.ttft();
            PrefixRow {
                scope: "cluster-2",
                variant: router.name(),
                completed: out.merged.records.len(),
                ttft_mean: ttft.mean(),
                ttft_p99: ttft.p99(),
                tput: out.merged.throughput_tok_s(),
                hits: sum(&|s| s.prefix_hits),
                misses: sum(&|s| s.prefix_misses),
                hit_tokens: sum(&|s| s.prefix_hit_tokens),
                inserts: sum(&|s| s.prefix_inserts),
                evictions: sum(&|s| s.prefix_evictions),
            }
        }
    })
}

pub fn prefix_sweep() -> Vec<PrefixRow> {
    prefix_sweep_with(n_requests(60))
}

pub fn print_prefix(rows: &[PrefixRow]) {
    let mut t = Table::new(
        "Prefix sweep — cross-request prefix caching on multi-turn chat sessions \
         (3k shared system prompts, chained histories, 20 s think time)",
        &["scope", "variant", "completed", "TTFT mean(s)", "TTFT p99(s)", "tok/s",
          "hits", "misses", "hit rate", "hit Mtok", "inserts", "evicts"],
    );
    for r in rows {
        let total = r.hits + r.misses;
        let hr = if total > 0 { r.hits as f64 / total as f64 } else { 0.0 };
        t.row(&[
            r.scope.to_string(),
            r.variant.to_string(),
            r.completed.to_string(),
            format!("{:.3}", r.ttft_mean),
            format!("{:.3}", r.ttft_p99),
            format!("{:.1}", r.tput),
            r.hits.to_string(),
            r.misses.to_string(),
            format!("{:.2}", hr),
            format!("{:.2}", r.hit_tokens as f64 / 1e6),
            r.inserts.to_string(),
            r.evictions.to_string(),
        ]);
    }
    t.print();
    // headline: mean-TTFT reduction the cache buys on the same trace
    let get = |variant: &str| rows.iter().find(|r| r.scope == "engine" && r.variant == variant);
    if let (Some(off), Some(on)) = (get("no-cache"), get("cache")) {
        let red = 100.0 * (1.0 - on.ttft_mean / off.ttft_mean.max(1e-9));
        println!(
            "engine: prefix cache cuts mean TTFT {:.3}s -> {:.3}s ({red:.1}% reduction), \
             p99 {:.3}s -> {:.3}s",
            off.ttft_mean, on.ttft_mean, off.ttft_p99, on.ttft_p99,
        );
    }
    let getc = |variant: &str| rows.iter().find(|r| r.scope == "cluster-2" && r.variant == variant);
    if let (Some(kv), Some(pa)) = (getc("kv-pressure"), getc("prefix-aware")) {
        let (kt, pt) = (kv.hits + kv.misses, pa.hits + pa.misses);
        println!(
            "cluster: prefix-aware routing hit rate {:.2} vs kv-pressure {:.2}, \
             mean TTFT {:.3}s vs {:.3}s",
            if pt > 0 { pa.hits as f64 / pt as f64 } else { 0.0 },
            if kt > 0 { kv.hits as f64 / kt as f64 } else { 0.0 },
            pa.ttft_mean,
            kv.ttft_mean,
        );
    }
}

// ---------------------------------------------------------------------
// Table 1 is qualitative — rendered directly.
// ---------------------------------------------------------------------

pub fn print_table1() {
    let mut t = Table::new(
        "Table 1 — LLM serving system comparison",
        &["framework", "KV mgmt", "KV offloading", "SLO-aware sched"],
    );
    t.row(&["vLLM".into(), "request-wise".into(), "request-wise".into(), "not supported".into()]);
    t.row(&["DistServe".into(), "request-wise".into(), "not supported".into(), "static".into()]);
    t.row(&["DeepSpeed-FastGen".into(), "request-wise".into(), "not supported".into(), "static".into()]);
    t.row(&["LayerKV (ours)".into(), "layer-wise".into(), "layer-wise".into(), "dynamic".into()]);
    t.print();
}
