//! Scoped-thread job pool (std-only) for the experiment harness.
//!
//! Every figure sweep is a list of independent (config, seed) cells; this
//! module fans them across cores with `std::thread::scope` while keeping
//! the output **deterministic and order-preserving**: each cell seeds its
//! own `Rng`, workers pull cells from a shared atomic cursor, and results
//! are stitched back by index — so `par_map` returns exactly what the
//! serial `items.iter().map(f).collect()` would, just faster.
//! `rust/tests/prop_invariants.rs` asserts that equivalence.
//!
//! Knobs: `LAYERKV_THREADS=<n>` pins the worker count; `LAYERKV_SERIAL=1`
//! forces in-place serial execution (useful when bisecting).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Worker count: `LAYERKV_THREADS` override, else all available cores.
pub fn default_threads() -> usize {
    if std::env::var("LAYERKV_SERIAL").map(|v| v != "0").unwrap_or(false) {
        return 1;
    }
    std::env::var("LAYERKV_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        })
}

/// Map `f` over `items` on up to `default_threads()` workers, preserving
/// input order in the output.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_threads(items, default_threads(), f)
}

/// As `par_map` with an explicit worker count (1 = serial in-place).
pub fn par_map_threads<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let threads = threads.max(1).min(items.len());
    if threads <= 1 {
        return items.iter().map(&f).collect();
    }

    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    std::thread::scope(|scope| {
        let mut workers = Vec::with_capacity(threads);
        for _ in 0..threads {
            let tx = tx.clone();
            let cursor = &cursor;
            let f = &f;
            workers.push(scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                if tx.send((i, r)).is_err() {
                    break;
                }
            }));
        }
        drop(tx); // workers hold the remaining senders

        let mut slots: Vec<Option<R>> = (0..items.len()).map(|_| None).collect();
        for (i, r) in rx {
            debug_assert!(slots[i].is_none(), "cell {i} produced twice");
            slots[i] = Some(r);
        }
        // the rx loop ends once every worker exited; re-raise a panicking
        // cell's own payload (e.g. an engine livelock diagnostic) instead
        // of masking it with a generic missing-slot error
        for w in workers {
            if let Err(panic) = w.join() {
                std::panic::resume_unwind(panic);
            }
        }
        slots.into_iter().map(|s| s.expect("cell lost")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_values() {
        let items: Vec<usize> = (0..100).collect();
        let serial: Vec<usize> = items.iter().map(|&x| x * x).collect();
        for threads in [1, 2, 4, 9] {
            let par = par_map_threads(&items, threads, |&x| x * x);
            assert_eq!(par, serial, "threads={threads}");
        }
    }

    #[test]
    fn handles_empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(&empty, |&x| x).is_empty());
        assert_eq!(par_map(&[7u32], |&x| x + 1), vec![8]);
    }

    #[test]
    fn threads_cap_never_zero() {
        assert!(default_threads() >= 1);
    }

    #[test]
    #[should_panic]
    fn worker_panics_propagate() {
        let items: Vec<usize> = (0..8).collect();
        let _ = par_map_threads(&items, 4, |&x| {
            assert!(x != 5, "boom");
            x
        });
    }
}
