//! ASCII line plots for experiment series — the terminal rendition of the
//! paper's figures (log-y like the paper's TTFT plots).

/// One named series of (x, y) points.
#[derive(Debug, Clone)]
pub struct PlotSeries {
    pub name: String,
    pub points: Vec<(f64, f64)>,
    pub glyph: char,
}

/// Render series into a `width` x `height` character grid with (optionally
/// log-scaled) y axis and labeled ticks.
pub fn render(title: &str, series: &[PlotSeries], width: usize, height: usize, log_y: bool) -> String {
    assert!(width >= 16 && height >= 4);
    let all: Vec<(f64, f64)> = series.iter().flat_map(|s| s.points.iter().copied()).collect();
    if all.is_empty() {
        return format!("== {title} == (no data)\n");
    }
    let tx = |x: f64| x;
    let ty = |y: f64| if log_y { y.max(1e-12).log10() } else { y };
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y) in &all {
        x0 = x0.min(tx(x));
        x1 = x1.max(tx(x));
        y0 = y0.min(ty(y));
        y1 = y1.max(ty(y));
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }

    let mut grid = vec![vec![' '; width]; height];
    for s in series {
        // draw with linear interpolation between consecutive points
        for w in s.points.windows(2) {
            let steps = width * 2;
            for k in 0..=steps {
                let f = k as f64 / steps as f64;
                let x = tx(w[0].0) * (1.0 - f) + tx(w[1].0) * f;
                let y = ty(w[0].1) * (1.0 - f) + ty(w[1].1) * f;
                let col = ((x - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
                let row = ((y - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
                let row = height - 1 - row.min(height - 1);
                grid[row][col.min(width - 1)] = s.glyph;
            }
        }
        if s.points.len() == 1 {
            let (x, y) = s.points[0];
            let col = ((tx(x) - x0) / (x1 - x0) * (width - 1) as f64).round() as usize;
            let row = ((ty(y) - y0) / (y1 - y0) * (height - 1) as f64).round() as usize;
            grid[height - 1 - row.min(height - 1)][col.min(width - 1)] = s.glyph;
        }
    }

    let unscale = |v: f64| if log_y { 10f64.powf(v) } else { v };
    let mut out = format!("\n== {title} ==\n");
    for (i, row) in grid.iter().enumerate() {
        let yv = unscale(y1 - (y1 - y0) * i as f64 / (height - 1) as f64);
        let label = if yv.abs() >= 100.0 {
            format!("{yv:>8.0}")
        } else if yv.abs() >= 1.0 {
            format!("{yv:>8.1}")
        } else {
            format!("{yv:>8.3}")
        };
        out.push_str(&format!("{label} |{}\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!("{:>8} +{}\n", "", "-".repeat(width)));
    out.push_str(&format!("{:>9}{:<.6}  ...  {:.6}\n", "", x0, x1));
    let legend: Vec<String> =
        series.iter().map(|s| format!("{} {}", s.glyph, s.name)).collect();
    out.push_str(&format!("{:>9}{}\n", "", legend.join("    ")));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_series() -> Vec<PlotSeries> {
        vec![
            PlotSeries {
                name: "vLLM".into(),
                points: vec![(1.0, 1.0), (2.0, 10.0), (3.0, 100.0)],
                glyph: 'v',
            },
            PlotSeries {
                name: "LayerKV".into(),
                points: vec![(1.0, 1.0), (2.0, 2.0), (3.0, 5.0)],
                glyph: 'L',
            },
        ]
    }

    #[test]
    fn renders_grid_with_legend() {
        let s = render("demo", &two_series(), 40, 10, true);
        assert!(s.contains("== demo =="));
        assert!(s.contains('v') && s.contains('L'));
        assert!(s.contains("v vLLM") && s.contains("L LayerKV"));
        // 10 data rows + axis + x labels + legend + title
        assert_eq!(s.lines().filter(|l| l.contains('|')).count(), 10);
    }

    #[test]
    fn log_scale_separates_magnitudes() {
        let lin = render("lin", &two_series(), 40, 10, false);
        let log = render("log", &two_series(), 40, 10, true);
        // on the log plot the two series start at the same row; on linear
        // they are indistinguishable at small values — just assert both
        // render and differ
        assert_ne!(lin, log);
    }

    #[test]
    fn empty_series_is_graceful() {
        let s = render("empty", &[], 40, 10, false);
        assert!(s.contains("no data"));
    }

    #[test]
    fn single_point_renders() {
        let s = render(
            "one",
            &[PlotSeries { name: "p".into(), points: vec![(1.0, 5.0)], glyph: '*' }],
            30,
            6,
            false,
        );
        assert!(s.contains('*'));
    }
}
