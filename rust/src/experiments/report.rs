//! Aligned-text table rendering for experiment output, plus an optional
//! JSON capture: while capture is armed, every printed table is also
//! recorded as `{"title", "headers", "rows"}` so `experiment --json`
//! can hand CI machine-checkable results instead of scraped stdout.

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::util::Json;

/// Armed by `begin_capture`; `Table::print` appends a JSON object per
/// table while armed. Process-wide, like the experiment toggles
/// (`LAYERKV_QUICK` etc.) — the CLI is single-threaded.
static CAPTURE: Mutex<Option<Vec<Json>>> = Mutex::new(None);

/// Start recording printed tables (clears any previous capture).
pub fn begin_capture() {
    *CAPTURE.lock().expect("capture poisoned") = Some(Vec::new());
}

/// Stop recording and return everything captured since `begin_capture`
/// as a JSON array; `None` if capture was never armed.
pub fn take_captured() -> Option<Json> {
    CAPTURE.lock().expect("capture poisoned").take().map(Json::Arr)
}

pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// The capture-side shape of this table.
    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("title".to_string(), Json::Str(self.title.clone()));
        obj.insert(
            "headers".to_string(),
            Json::Arr(self.headers.iter().map(|h| Json::Str(h.clone())).collect()),
        );
        obj.insert(
            "rows".to_string(),
            Json::Arr(
                self.rows
                    .iter()
                    .map(|r| Json::Arr(r.iter().map(|c| Json::Str(c.clone())).collect()))
                    .collect(),
            ),
        );
        Json::Obj(obj)
    }

    pub fn print(&self) {
        print!("{}", self.render());
        if let Some(cap) = CAPTURE.lock().expect("capture poisoned").as_mut() {
            cap.push(self.to_json());
        }
    }
}

/// Free-standing convenience.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    let mut t = Table::new(title, headers);
    for r in rows {
        t.row(r);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["100".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long_header"));
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    fn capture_records_printed_tables_as_json() {
        begin_capture();
        let mut t = Table::new("capture-demo-q7", &["col", "val"]);
        t.row(&["x".into(), "1.5".into()]);
        t.print();
        let cap = take_captured().expect("capture was armed");
        // other tests may print tables concurrently: look for ours
        let arr = cap.as_arr().expect("array of tables");
        let ours = arr
            .iter()
            .find(|j| {
                j.get("title").and_then(|t| t.as_str()) == Some("capture-demo-q7")
            })
            .expect("printed table captured");
        assert_eq!(ours.req("headers").unwrap().as_arr().unwrap().len(), 2);
        let rows = ours.req("rows").unwrap();
        assert_eq!(rows.as_arr().unwrap().len(), 1);
        // round-trips through the serializer
        let reparsed = Json::parse(&cap.dump()).unwrap();
        assert!(reparsed.as_arr().is_some());
        // capture is one-shot: a second take is None until re-armed
        assert!(take_captured().is_none());
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
