//! Aligned-text table rendering for experiment output.

pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "column count mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Free-standing convenience.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    let mut t = Table::new(title, headers);
    for r in rows {
        t.row(r);
    }
    t.print();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["a", "long_header"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["100".into(), "x".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("long_header"));
        let lines: Vec<&str> = s.lines().filter(|l| !l.is_empty()).collect();
        // header + separator + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "column count")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
