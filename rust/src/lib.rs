//! # LayerKV — layer-wise KV cache management for LLM serving
//!
//! Reproduction of *LayerKV: Optimizing Large Language Model Serving with
//! Layer-wise KV Cache Management* (Xiong et al., Ant Group, 2024) as a
//! three-layer rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the serving coordinator: continuous
//!   batching, paged KV with layer-wise block tables, GPU->host offloading,
//!   the SLO-aware scheduler (Alg. 1 / Eqs. 1-5), and the discrete-event
//!   cluster simulator that stands in for the paper's 8xL20 testbed.
//! * **Layer 2** (`python/compile/model.py`) — a tiny GQA transformer in
//!   JAX with per-layer KV inputs/outputs, AOT-lowered to HLO text.
//! * **Layer 1** (`python/compile/kernels/`) — Pallas kernels: tiled
//!   causal flash attention, dense decode attention, paged (block-table)
//!   decode attention.
//!
//! Python runs only at `make artifacts`; the serving binary loads the HLO
//! via PJRT (`runtime/`) and never calls Python.
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for
//! paper-vs-measured results.

// The §Perf hot loops iterate layer indices against multiple parallel
// structures (block tables + pools + the backend mirror), where index
// loops are the clearest form; keep this style lint off so the CI
// `clippy -D warnings` gate guards correctness lints without fighting
// the idiom.
#![allow(clippy::needless_range_loop)]

pub mod benchutil;
pub mod cluster;
pub mod config;
pub mod runtime;
pub mod coordinator;
pub mod experiments;
pub mod metrics;
pub mod obs;
pub mod server;
pub mod sim;
pub mod util;
pub mod workload;
