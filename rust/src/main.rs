//! LayerKV command-line entry point.
//!
//! ```text
//! layerkv experiment <fig1|fig4|fig5|fig6|fig7|fig8|tiers|bursty|cluster|cluster-wide|fleet|faults|prefix|table1|all>
//!                    [--quick] [--macro-steps|--no-macro-steps] [--no-prefix-cache]
//! layerkv sim --model <7b|34b|70b> --policy <vllm|layerkv|layerkv-no-slo>
//!             --ctx <tokens> --rate <req/s> --requests <n> [--sharegpt]
//!             [--replicas N] [--router <policy>] [--faults SPEC] [--ckpt K] [--lockstep]
//! layerkv serve [--addr 127.0.0.1:7181] [--artifacts DIR] [--budget BYTES]
//!               [--policy <vllm|layerkv|layerkv-no-slo>] [--max-batch N]
//!               [--ref-model] [--replicas N] [--router <policy>]
//! layerkv bench-check [--baseline BENCH_baseline.json] [--current BENCH_hotpath.json]
//!                     [--factor 2.5] [--update]
//! layerkv trace-check TRACE.json
//! layerkv faults-check TABLES.json [--min-reduction PCT]
//! layerkv selftest [--artifacts DIR]
//! ```
//!
//! `sim`/`experiment --trace-out` records per-request lifecycle spans and
//! virtual-time gauges into a bounded ring (`obs/`) and exports Chrome
//! trace-event JSON; `--trace-jsonl` exports the same records as JSONL;
//! `experiment --json` writes every printed table as machine-checkable
//! JSON; `trace-check` validates an exported trace.
//!
//! `serve --policy` exercises every scheduler against real tokens —
//! the same `make_scheduler` policies the simulator runs. `--ref-model`
//! serves the deterministic in-process executor instead of PJRT
//! artifacts (works offline). `--replicas N` runs N engine workers behind
//! the front-end, with `--router` picking the replica-selection policy
//! (round-robin | jsq | kv-pressure | slo-aware | prefix-aware — see
//! `cluster/`).
//!
//! `sim --replicas N` routes the trace across an N-replica simulated
//! cluster; `--faults SPEC` injects a deterministic fault schedule
//! (`crash=R@T1[:T2],straggle=R@T1:T2xF,io=R@T1:T2,migrate=S>D@T,retries=N,probation=S`
//! — see `cluster::faults::FaultPlan::parse_spec`). `--ckpt K` turns on
//! layer-wise KV checkpointing every K committed tokens (provisioning
//! the NVMe tier when the preset has none), so crash victims are
//! adopted from their last checkpoint instead of recomputed;
//! `faults-check` asserts the checkpointing headline (recomputed-token
//! reduction) from an `experiment faults --json` capture. `--lockstep` (or
//! LAYERKV_LOCKSTEP=1) drives the cluster on the per-arrival lockstep
//! oracle instead of the cluster-wide event heap — bit-identical
//! results, O(replicas x arrivals) cost.
//!
//! Argument parsing is hand-rolled (clap is unavailable offline).

use std::process::ExitCode;

use layerkv::config::{Policy, ServingConfig};
use layerkv::coordinator::run_trace;
use layerkv::experiments as exp;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1..];
    let result = match cmd {
        "experiment" => cmd_experiment(rest),
        "sim" => cmd_sim(rest),
        "serve" => cmd_serve(rest),
        "bench-check" => cmd_bench_check(rest),
        "trace-check" => cmd_trace_check(rest),
        "faults-check" => cmd_faults_check(rest),
        "selftest" => cmd_selftest(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_help();
            Err(anyhow::anyhow!("bad usage"))
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "layerkv — layer-wise KV cache management for LLM serving (paper reproduction)\n\
         \n\
         USAGE:\n\
         \x20 layerkv experiment <fig1|fig4|fig5|fig6|fig7|fig8|tiers|bursty|cluster|cluster-wide|fleet|faults|prefix|table1|all>\n\
         \x20                    [--quick] [--macro-steps|--no-macro-steps] [--no-prefix-cache]\n\
         \x20                    [--json TABLES.json] [--trace-out TRACE.json] [--trace-jsonl TRACE.jsonl]\n\
         \x20 layerkv sim --model 7b --policy layerkv --ctx 4096 --rate 1.0 --requests 100 [--sharegpt]\n\
         \x20             [--replicas N] [--router round-robin|jsq|kv-pressure|slo-aware|prefix-aware] [--lockstep]\n\
         \x20             [--faults crash=R@T1[:T2],straggle=R@T1:T2xF,io=R@T1:T2,migrate=S>D@T,retries=N,probation=S]\n\
         \x20             [--ckpt K] [--trace-out TRACE.json] [--trace-jsonl TRACE.jsonl]\n\
         \x20 layerkv serve [--addr 127.0.0.1:7181] [--artifacts DIR] [--budget BYTES]\n\
         \x20               [--policy vllm|layerkv|layerkv-no-slo] [--max-batch N] [--ref-model]\n\
         \x20               [--replicas N] [--router round-robin|jsq|kv-pressure|slo-aware|prefix-aware]\n\
         \x20 layerkv bench-check [--baseline BENCH_baseline.json] [--current BENCH_hotpath.json]\n\
         \x20                     [--factor 2.5] [--update]\n\
         \x20 layerkv trace-check TRACE.json\n\
         \x20 layerkv faults-check TABLES.json [--min-reduction PCT]\n\
         \x20 layerkv selftest [--artifacts DIR]\n\
         \n\
         `--trace-out` records per-request lifecycle spans and virtual-time\n\
         gauges into a bounded ring and writes Chrome trace-event JSON\n\
         (load it in Perfetto or chrome://tracing); `--trace-jsonl` writes\n\
         the same records as one JSON object per line. `trace-check`\n\
         validates an exported trace (parses, per-track monotonic\n\
         timestamps, every arrival reaches a terminal event)."
    );
}

/// `--key value` / `--flag` extraction.
fn opt(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).cloned()
}

fn flag(args: &[String], key: &str) -> bool {
    args.iter().any(|a| a == key)
}

/// Install the process-global trace sink when `--trace-out` or
/// `--trace-jsonl` is present. Must run before any engine is built —
/// engines attach to the sink in their constructors; with no sink the
/// tracing hooks cost one branch and allocate nothing.
fn trace_sink(args: &[String]) -> Option<layerkv::obs::TraceHandle> {
    (opt(args, "--trace-out").is_some() || opt(args, "--trace-jsonl").is_some()).then(|| {
        layerkv::obs::sink::install(
            layerkv::obs::DEFAULT_SPAN_CAP,
            layerkv::obs::DEFAULT_GAUGE_CAP,
        )
    })
}

/// Write whatever the sink captured during this run: Chrome trace-event
/// JSON for `--trace-out` (one track per replica, one lane per request
/// phase), JSONL for `--trace-jsonl`. No-op when tracing was off.
fn export_trace(args: &[String], sink: Option<layerkv::obs::TraceHandle>) -> anyhow::Result<()> {
    let Some(handle) = sink else { return Ok(()) };
    let tracer = handle.lock();
    if let Some(path) = opt(args, "--trace-out") {
        let j = layerkv::obs::export::chrome_trace(&tracer);
        std::fs::write(&path, j.dump())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!(
            "trace: {} span(s), {} gauge sample(s) -> {path} \
             (load in Perfetto or chrome://tracing)",
            tracer.spans_len(),
            tracer.gauges_len()
        );
    }
    if let Some(path) = opt(args, "--trace-jsonl") {
        std::fs::write(&path, layerkv::obs::export::jsonl(&tracer))
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("trace: jsonl -> {path}");
    }
    drop(tracer);
    layerkv::obs::sink::clear();
    Ok(())
}

/// Validate an exported Chrome trace: it parses, timestamps are
/// monotonic per track, and every arrived request reaches a terminal
/// event (finish/drop/failed) unless the span ring wrapped.
fn cmd_trace_check(args: &[String]) -> anyhow::Result<()> {
    let path = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("usage: layerkv trace-check TRACE.json"))?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let j = layerkv::util::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
    let summary = layerkv::obs::export::validate_chrome(&j)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    println!("trace-check: {path}: {summary}");
    Ok(())
}

/// CI chaos gate: read an `experiment faults --json` capture, find the
/// checkpointed-failover table, and fail unless checkpointing cut the
/// recomputed prefill tokens by at least `--min-reduction` percent
/// (default 50) while actually adopting crash victims.
fn cmd_faults_check(args: &[String]) -> anyhow::Result<()> {
    let path = args
        .first()
        .filter(|a| !a.starts_with("--"))
        .cloned()
        .ok_or_else(|| {
            anyhow::anyhow!("usage: layerkv faults-check TABLES.json [--min-reduction PCT]")
        })?;
    let min_reduction: f64 =
        opt(args, "--min-reduction").unwrap_or_else(|| "50".into()).parse()?;
    let text = std::fs::read_to_string(&path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let j = layerkv::util::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
    let tables = j
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{path}: expected an array of captured tables"))?;
    let table = tables
        .iter()
        .find(|t| {
            t.get("title")
                .and_then(|s| s.as_str())
                .is_some_and(|s| s.starts_with(exp::CKPT_TABLE_TITLE))
        })
        .ok_or_else(|| {
            anyhow::anyhow!(
                "{path}: no '{}' table — generate it with \
                 `layerkv experiment faults --json {path}`",
                exp::CKPT_TABLE_TITLE
            )
        })?;
    let headers = table
        .req("headers")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{path}: headers must be an array"))?
        .iter()
        .map(|h| h.as_str().unwrap_or("").to_string())
        .collect::<Vec<_>>();
    let col = |name: &str| -> anyhow::Result<usize> {
        headers
            .iter()
            .position(|h| h == name)
            .ok_or_else(|| anyhow::anyhow!("{path}: missing column '{name}'"))
    };
    let (variant_c, recomp_c, adopt_c) =
        (col("failover")?, col("recomputed tok")?, col("adoptions")?);
    let rows = table
        .req("rows")?
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{path}: rows must be an array"))?;
    let cell = |variant: &str, c: usize| -> anyhow::Result<f64> {
        rows.iter()
            .filter_map(|r| r.as_arr())
            .find(|r| r.get(variant_c).and_then(|v| v.as_str()) == Some(variant))
            .and_then(|r| r.get(c))
            .and_then(|v| v.as_str())
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("{path}: no numeric row for '{variant}'"))
    };
    let off = cell("recompute-only", recomp_c)?;
    let on = cell("ckpt-8", recomp_c)?;
    let adoptions = cell("ckpt-8", adopt_c)?;
    anyhow::ensure!(
        off > 0.0,
        "recompute-only run incurred no recomputed tokens: the crash plan \
         found no victims, so the contrast is vacuous"
    );
    anyhow::ensure!(
        adoptions > 0.0,
        "checkpointed run adopted no crash victims: checkpointing never engaged"
    );
    let reduction = 100.0 * (1.0 - on / off);
    anyhow::ensure!(
        reduction >= min_reduction,
        "checkpointing cut recomputed prefill tokens by only {reduction:.1}% \
         ({off:.0} -> {on:.0}), below the {min_reduction:.0}% floor"
    );
    println!(
        "faults-check: checkpointing cut recomputed prefill tokens by \
         {reduction:.1}% ({off:.0} -> {on:.0}, {adoptions:.0} adoption(s)) \
         — >= {min_reduction:.0}% floor"
    );
    Ok(())
}

fn cmd_experiment(args: &[String]) -> anyhow::Result<()> {
    if flag(args, "--quick") {
        std::env::set_var("LAYERKV_QUICK", "1");
    }
    // decode fast-forwarding toggle (default on; bit-identical results
    // either way — off is the O(tokens) single-step debugging path)
    if flag(args, "--no-macro-steps") {
        std::env::set_var("LAYERKV_MACRO", "0");
    } else if flag(args, "--macro-steps") {
        std::env::set_var("LAYERKV_MACRO", "1");
    }
    // cross-request prefix cache (default on; `experiment prefix` runs its
    // own on/off contrast regardless of this toggle)
    if flag(args, "--no-prefix-cache") {
        std::env::set_var("LAYERKV_PREFIX", "0");
    }
    let sink = trace_sink(args);
    let json_out = opt(args, "--json");
    if json_out.is_some() {
        exp::report::begin_capture();
    }
    let which = args.first().map(String::as_str).unwrap_or("all");
    let run = |id: &str| -> anyhow::Result<()> {
        match id {
            "fig1" => exp::print_fig1(&exp::fig1()),
            "fig4" => exp::print_fig4(&exp::fig4()),
            "fig5" => exp::print_fig5(&exp::fig5()),
            "fig6" => exp::print_fig6(&exp::fig6_7()),
            "fig7" => exp::print_fig7(&exp::fig6_7()),
            "table1" => exp::print_table1(),
            "fig8" => exp::print_fig8(&exp::fig8()),
            "tiers" => exp::print_tier_sweep(&exp::tier_sweep()),
            "bursty" => exp::print_bursty(&exp::bursty()),
            "cluster" => exp::print_cluster(&exp::cluster_sweep()),
            // the macro-stepping payoff: fleets to 32 replicas at 3x the
            // trace volume per cell (kept out of `all` — it is the
            // dedicated scale run)
            "cluster-wide" => exp::print_cluster(&exp::cluster_sweep_wide()),
            // the event-heap payoff: 64-512 replicas under diurnal load
            // (kept out of `all` alongside cluster-wide — scale runs)
            "fleet" => exp::print_fleet(&exp::fleet_sweep()),
            "faults" => {
                exp::print_faults(&exp::fault_sweep());
                // the stateful-failover headline: recompute-only vs
                // checkpointed adoption under a crash-heavy plan
                // (`faults-check` asserts it from the --json capture)
                exp::print_ckpt(&exp::ckpt_contrast());
            }
            "prefix" => exp::print_prefix(&exp::prefix_sweep()),
            other => anyhow::bail!("unknown experiment '{other}'"),
        }
        Ok(())
    };
    if which == "all" {
        for id in [
            "table1", "fig1", "fig4", "fig5", "fig6", "fig7", "fig8", "tiers", "bursty",
            "cluster", "faults", "prefix",
        ] {
            run(id)?;
        }
    } else {
        run(which)?;
    }
    if let Some(path) = json_out {
        let cap = exp::report::take_captured()
            .unwrap_or_else(|| layerkv::util::Json::Arr(Vec::new()));
        std::fs::write(&path, cap.dump())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("experiment tables -> {path}");
    }
    export_trace(args, sink)
}

fn parse_policy(name: &str) -> anyhow::Result<Policy> {
    match name {
        "vllm" => Ok(Policy::Vllm),
        "layerkv" => Ok(Policy::LayerKv { slo_aware: true }),
        "layerkv-no-slo" => Ok(Policy::LayerKv { slo_aware: false }),
        other => anyhow::bail!("unknown policy '{other}'"),
    }
}

fn cmd_sim(args: &[String]) -> anyhow::Result<()> {
    let model = opt(args, "--model").unwrap_or_else(|| "7b".into());
    let policy = parse_policy(opt(args, "--policy").as_deref().unwrap_or("layerkv"))?;
    let ctx: usize = opt(args, "--ctx").unwrap_or_else(|| "2048".into()).parse()?;
    let rate: f64 = opt(args, "--rate").unwrap_or_else(|| "1.0".into()).parse()?;
    let n: usize = opt(args, "--requests").unwrap_or_else(|| "100".into()).parse()?;
    let seed: u64 = opt(args, "--seed").unwrap_or_else(|| "7".into()).parse()?;

    let mut cfg: ServingConfig = exp::setup(&model).with_policy(policy);
    if let Some(k) = opt(args, "--ckpt") {
        let every: usize = k.parse()?;
        anyhow::ensure!(every > 0, "--ckpt must be a positive token count");
        // checkpoints live on the disk tier; provision the NVMe spec the
        // tiered presets use when the chosen preset has none
        if !cfg.node.disk.enabled() {
            cfg.node.disk = layerkv::config::DiskSpec::nvme_4tb();
        }
        cfg = cfg.with_checkpointing(every);
    }
    let trace = if let Some(path) = opt(args, "--trace") {
        // replay a recorded JSON-lines trace
        layerkv::workload::trace::load(std::path::Path::new(&path))?
    } else if flag(args, "--sharegpt") {
        ShareGptWorkload::paper(rate, n).generate(&mut Rng::new(seed))
    } else {
        FixedWorkload {
            prompt_len: ctx,
            output_len: 512,
            n_requests: n,
            arrivals: Arrivals::Poisson { rate },
        }
        .generate(&mut Rng::new(seed))
    };
    if let Some(path) = opt(args, "--save-trace") {
        layerkv::workload::trace::save(&trace, std::path::Path::new(&path))?;
        println!("trace saved to {path}");
    }
    let replicas: usize = opt(args, "--replicas").unwrap_or_else(|| "1".into()).parse()?;
    anyhow::ensure!(replicas >= 1, "--replicas must be at least 1");
    let faults_spec = opt(args, "--faults");
    // engines attach to the sink at construction, so this must precede
    // run_trace / Cluster::new
    let sink = trace_sink(args);
    if replicas > 1 || faults_spec.is_some() {
        sim_cluster(args, cfg, &trace, replicas, faults_spec)?;
        return export_trace(args, sink);
    }
    let (rep, stats) = run_trace(cfg.clone(), &trace, exp::PREDICTOR_ACC);
    let (mut ttft, mut tpot) = (rep.ttft(), rep.tpot());
    println!("model={model} policy={} ctx={ctx} rate={rate} n={n}", cfg.policy.name());
    println!(
        "TTFT   mean {:8.3}s   p50 {:8.3}s   p99 {:8.3}s",
        ttft.mean(),
        ttft.p50(),
        ttft.p99()
    );
    println!(
        "TPOT   mean {:8.4}s   p99 {:8.4}s",
        tpot.mean(),
        tpot.p99()
    );
    println!(
        "queue  mean {:8.3}s   prefill mean {:8.3}s",
        rep.queueing().mean(),
        rep.prefill().mean()
    );
    println!(
        "tput   {:.1} tok/s   {:.2} req/s   violations {:.1}%",
        rep.throughput_tok_s(),
        rep.throughput_req_s(),
        100.0 * rep.slo_violation_rate(&cfg.slo)
    );
    println!(
        "steps  {} ({} prefill, {} decode)   preemptions {}   offload {:.1} MB   onload-stream {:.1} MB",
        stats.steps,
        stats.prefill_steps,
        stats.decode_steps,
        stats.preemptions,
        stats.offload_bytes / 1e6,
        stats.onload_stream_bytes / 1e6,
    );
    export_trace(args, sink)
}

/// `sim` over a multi-replica cluster, optionally fault-injected.
fn sim_cluster(
    args: &[String],
    cfg: ServingConfig,
    trace: &layerkv::workload::Trace,
    replicas: usize,
    faults_spec: Option<String>,
) -> anyhow::Result<()> {
    use layerkv::cluster::{Cluster, ClusterConfig, FaultPlan, RouterPolicy};
    let router_name = opt(args, "--router").unwrap_or_else(|| "kv-pressure".into());
    let router = RouterPolicy::parse(&router_name).ok_or_else(|| {
        anyhow::anyhow!("unknown router '{router_name}' (round-robin|jsq|kv-pressure|slo-aware|prefix-aware)")
    })?;
    let mut cluster = Cluster::new(&ClusterConfig::homogeneous(&cfg, replicas, router));
    if let Some(spec) = &faults_spec {
        let plan = FaultPlan::parse_spec(spec).map_err(|e| anyhow::anyhow!(e))?;
        cluster = cluster.with_faults(plan);
    }
    if flag(args, "--lockstep") {
        cluster.set_lockstep(true);
    }
    let out = cluster.run(trace)?;
    let (mut ttft, mut tpot) = (out.merged.ttft(), out.merged.tpot());
    println!(
        "cluster replicas={replicas} router={} policy={} n={}",
        router.name(),
        cfg.policy.name(),
        trace.requests.len()
    );
    println!(
        "completed {}   dropped {}   failed {}",
        out.merged.records.len(),
        out.dropped.len(),
        out.failed.len()
    );
    println!(
        "TTFT   mean {:8.3}s   p50 {:8.3}s   p99 {:8.3}s",
        ttft.mean(),
        ttft.p50(),
        ttft.p99()
    );
    println!("TPOT   mean {:8.4}s   p99 {:8.4}s", tpot.mean(), tpot.p99());
    println!(
        "tput   {:.1} tok/s   goodput {:.2} req/s   violations {:.1}%",
        out.merged.throughput_tok_s(),
        out.merged.goodput_req_s(&cfg.slo),
        100.0 * out.merged.slo_violation_rate(&cfg.slo)
    );
    let routed: Vec<String> =
        out.per_replica.iter().map(|o| o.routed.to_string()).collect();
    println!("routed per replica: [{}]", routed.join(", "));
    if let Some(f) = &out.faults {
        println!(
            "faults crashes {}   recoveries {}   stragglers {}   io bursts {}   \
             migrations {}   retries {}   downtime {:.1}s",
            f.crashes, f.recoveries, f.straggler_windows, f.io_bursts, f.migrations,
            f.retries, f.downtime_s
        );
        println!(
            "failover adoptions {}   resumed tokens {}   recomputed tokens {}",
            f.adoptions, f.resumed_tokens, f.recomputed_tokens
        );
        for ev in cluster.fault_log() {
            println!("  {}", ev.render());
        }
    }
    Ok(())
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let addr = opt(args, "--addr").unwrap_or_else(|| "127.0.0.1:7181".into());
    let dir = opt(args, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(layerkv::runtime::artifacts::default_dir);
    let budget: usize = opt(args, "--budget").unwrap_or_else(|| "2097152".into()).parse()?;
    let policy = parse_policy(opt(args, "--policy").as_deref().unwrap_or("layerkv"))?;
    let max_batch: usize = opt(args, "--max-batch").unwrap_or_else(|| "8".into()).parse()?;
    let replicas: usize = opt(args, "--replicas").unwrap_or_else(|| "1".into()).parse()?;
    anyhow::ensure!(replicas >= 1, "--replicas must be at least 1");
    let router_name = opt(args, "--router").unwrap_or_else(|| "kv-pressure".into());
    let router = layerkv::cluster::RouterPolicy::parse(&router_name)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown router '{router_name}' (round-robin|jsq|kv-pressure|slo-aware|prefix-aware)"
        ))?;
    let cfg = layerkv::runtime::RealEngineConfig {
        device_kv_budget: budget,
        policy,
        max_batch,
        ..Default::default()
    };
    let artifacts = (!flag(args, "--ref-model")).then_some(dir.as_path());
    layerkv::server::serve(&addr, artifacts, cfg, replicas, router)
}

/// One recorded bench series: (name, ns_per_iter, iters). `iters == 0`
/// marks a *seed* baseline entry (committed ceiling, not yet measured on
/// this class of machine).
fn load_bench_json(path: &str) -> anyhow::Result<Vec<(String, f64, f64)>> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {path}: {e}"))?;
    let json = layerkv::util::Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {path}: {e}"))?;
    let arr = json
        .as_arr()
        .ok_or_else(|| anyhow::anyhow!("{path}: bench json must be an array"))?;
    let mut out = Vec::new();
    for entry in arr {
        let name = entry
            .req("name")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("{path}: series name must be a string"))?
            .to_string();
        let ns = entry
            .req("ns_per_iter")?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("{path}: {name}: ns_per_iter must be a number"))?;
        let iters = entry.req("iters")?.as_f64().unwrap_or(0.0);
        out.push((name, ns, iters));
    }
    Ok(out)
}

/// CI perf gate: compare the fresh `BENCH_hotpath.json` against the
/// committed baseline and fail on any `kv_manager/` / `scheduler/` /
/// `engine/` / `cluster/` / `obs/` series regressing past `--factor` (default
/// 2.5x), or silently vanishing from the run. `--update` refreshes the
/// baseline from the current results instead (do this deliberately, on a
/// representative machine, when a slowdown is intended).
fn cmd_bench_check(args: &[String]) -> anyhow::Result<()> {
    let current = opt(args, "--current").unwrap_or_else(|| "BENCH_hotpath.json".into());
    let baseline = opt(args, "--baseline").unwrap_or_else(|| "BENCH_baseline.json".into());
    let factor: f64 = opt(args, "--factor").unwrap_or_else(|| "2.5".into()).parse()?;
    if flag(args, "--update") {
        std::fs::copy(&current, &baseline)
            .map_err(|e| anyhow::anyhow!("copying {current} -> {baseline}: {e}"))?;
        println!("bench-check: baseline {baseline} refreshed from {current}");
        return Ok(());
    }
    const PREFIXES: &[&str] = &["kv_manager/", "scheduler/", "engine/", "cluster/", "obs/"];
    let gated = |name: &str| PREFIXES.iter().any(|p| name.starts_with(p));
    let cur = load_bench_json(&current)?;
    let base = load_bench_json(&baseline)?;
    let mut failures: Vec<String> = Vec::new();
    let mut checked = 0usize;
    let mut seed_ceilings = 0usize;
    for (name, ns, _) in &cur {
        if !gated(name) {
            continue;
        }
        match base.iter().find(|(b, _, _)| b == name) {
            None => println!(
                "bench-check: {name}: new series (no baseline entry) — \
                 refresh with `bench-check --update` once reviewed"
            ),
            Some((_, base_ns, base_iters)) => {
                checked += 1;
                let ratio = ns / base_ns.max(1e-9);
                let tag = if *base_iters == 0.0 { " [seed baseline]" } else { "" };
                if *base_iters == 0.0 {
                    seed_ceilings += 1;
                }
                if ratio > factor {
                    failures.push(format!(
                        "{name}: {ns:.1} ns/iter vs baseline {base_ns:.1} = {ratio:.2}x{tag}"
                    ));
                } else {
                    println!("bench-check: {name}: {ratio:.2}x of baseline{tag} — ok");
                }
            }
        }
    }
    // a deleted bench would otherwise dodge the gate forever
    for (name, _, _) in &base {
        if gated(name) && !cur.iter().any(|(c, _, _)| c == name) {
            failures.push(format!("{name}: in the baseline but missing from {current}"));
        }
    }
    anyhow::ensure!(
        checked > 0,
        "no comparable series found (checked prefixes: {PREFIXES:?})"
    );
    // A seed ceiling (iters == 0 in the committed baseline) was never
    // measured on this machine class, so "within {factor}x" of it means
    // very little — passing against one used to be completely silent.
    // Say so loudly, and emit a GitHub Actions `::warning` annotation so
    // CI surfaces it on the run summary instead of burying it in the log.
    if seed_ceilings > 0 {
        let msg = format!(
            "bench-check: {seed_ceilings}/{checked} series compared against SEED \
             ceilings (iters == 0: never measured on this machine class) — the \
             gate is advisory for those; refresh with `cargo bench` + \
             `layerkv bench-check --update` on a representative machine"
        );
        eprintln!("WARNING: {msg}");
        println!("::warning title=bench-check seed baseline::{msg}");
    }
    if failures.is_empty() {
        println!("bench-check: {checked} series within {factor}x of the baseline");
        Ok(())
    } else {
        for f in &failures {
            eprintln!("bench-check REGRESSION: {f}");
        }
        anyhow::bail!(
            "{} series regressed past {factor}x (if intentional, refresh with \
             `layerkv bench-check --update`)",
            failures.len()
        )
    }
}

fn cmd_selftest(args: &[String]) -> anyhow::Result<()> {
    let dir = opt(args, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(layerkv::runtime::artifacts::default_dir);
    println!("loading artifacts from {}", dir.display());
    let model = layerkv::runtime::TinyModel::load(&dir)?;
    println!(
        "compiled {} prefill bucket(s) {:?}, {} decode bucket(s) {:?}, paged kernel: {}",
        model.art.prefill_buckets().len(),
        model.art.prefill_buckets(),
        model.art.decode_batches().len(),
        model.art.decode_batches(),
        model.has_paged_kernel(),
    );
    let prompt: Vec<i32> = (0..24).map(|i| (i * 3) % 256).collect();
    let out = model.prefill(&prompt)?;
    println!("prefill(24 tokens): bucket {}, first token {}", out.bucket, layerkv::runtime::argmax(&out.logits));
    println!("selftest OK");
    Ok(())
}
