//! LayerKV command-line entry point.
//!
//! ```text
//! layerkv experiment <fig1|fig4|fig5|fig6|fig7|fig8|tiers|bursty|cluster|table1|all> [--quick]
//! layerkv sim --model <7b|34b|70b> --policy <vllm|layerkv|layerkv-no-slo>
//!             --ctx <tokens> --rate <req/s> --requests <n> [--sharegpt]
//! layerkv serve [--addr 127.0.0.1:7181] [--artifacts DIR] [--budget BYTES]
//!               [--policy <vllm|layerkv|layerkv-no-slo>] [--max-batch N]
//!               [--ref-model] [--replicas N] [--router <policy>]
//! layerkv selftest [--artifacts DIR]
//! ```
//!
//! `serve --policy` exercises every scheduler against real tokens —
//! the same `make_scheduler` policies the simulator runs. `--ref-model`
//! serves the deterministic in-process executor instead of PJRT
//! artifacts (works offline). `--replicas N` runs N engine workers behind
//! the front-end, with `--router` picking the replica-selection policy
//! (round-robin | jsq | kv-pressure | slo-aware — see `cluster/`).
//!
//! Argument parsing is hand-rolled (clap is unavailable offline).

use std::process::ExitCode;

use layerkv::config::{Policy, ServingConfig};
use layerkv::coordinator::run_trace;
use layerkv::experiments as exp;
use layerkv::util::Rng;
use layerkv::workload::arrivals::Arrivals;
use layerkv::workload::fixed::FixedWorkload;
use layerkv::workload::sharegpt::ShareGptWorkload;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let rest = &args[1..];
    let result = match cmd {
        "experiment" => cmd_experiment(rest),
        "sim" => cmd_sim(rest),
        "serve" => cmd_serve(rest),
        "selftest" => cmd_selftest(rest),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'");
            print_help();
            Err(anyhow::anyhow!("bad usage"))
        }
    };
    match result {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn print_help() {
    println!(
        "layerkv — layer-wise KV cache management for LLM serving (paper reproduction)\n\
         \n\
         USAGE:\n\
         \x20 layerkv experiment <fig1|fig4|fig5|fig6|fig7|fig8|tiers|bursty|cluster|table1|all> [--quick]\n\
         \x20 layerkv sim --model 7b --policy layerkv --ctx 4096 --rate 1.0 --requests 100 [--sharegpt]\n\
         \x20 layerkv serve [--addr 127.0.0.1:7181] [--artifacts DIR] [--budget BYTES]\n\
         \x20               [--policy vllm|layerkv|layerkv-no-slo] [--max-batch N] [--ref-model]\n\
         \x20               [--replicas N] [--router round-robin|jsq|kv-pressure|slo-aware]\n\
         \x20 layerkv selftest [--artifacts DIR]"
    );
}

/// `--key value` / `--flag` extraction.
fn opt(args: &[String], key: &str) -> Option<String> {
    args.iter().position(|a| a == key).and_then(|i| args.get(i + 1)).cloned()
}

fn flag(args: &[String], key: &str) -> bool {
    args.iter().any(|a| a == key)
}

fn cmd_experiment(args: &[String]) -> anyhow::Result<()> {
    if flag(args, "--quick") {
        std::env::set_var("LAYERKV_QUICK", "1");
    }
    let which = args.first().map(String::as_str).unwrap_or("all");
    let run = |id: &str| -> anyhow::Result<()> {
        match id {
            "fig1" => exp::print_fig1(&exp::fig1()),
            "fig4" => exp::print_fig4(&exp::fig4()),
            "fig5" => exp::print_fig5(&exp::fig5()),
            "fig6" => exp::print_fig6(&exp::fig6_7()),
            "fig7" => exp::print_fig7(&exp::fig6_7()),
            "table1" => exp::print_table1(),
            "fig8" => exp::print_fig8(&exp::fig8()),
            "tiers" => exp::print_tier_sweep(&exp::tier_sweep()),
            "bursty" => exp::print_bursty(&exp::bursty()),
            "cluster" => exp::print_cluster(&exp::cluster_sweep()),
            other => anyhow::bail!("unknown experiment '{other}'"),
        }
        Ok(())
    };
    if which == "all" {
        for id in
            ["table1", "fig1", "fig4", "fig5", "fig6", "fig7", "fig8", "tiers", "bursty", "cluster"]
        {
            run(id)?;
        }
        Ok(())
    } else {
        run(which)
    }
}

fn parse_policy(name: &str) -> anyhow::Result<Policy> {
    match name {
        "vllm" => Ok(Policy::Vllm),
        "layerkv" => Ok(Policy::LayerKv { slo_aware: true }),
        "layerkv-no-slo" => Ok(Policy::LayerKv { slo_aware: false }),
        other => anyhow::bail!("unknown policy '{other}'"),
    }
}

fn cmd_sim(args: &[String]) -> anyhow::Result<()> {
    let model = opt(args, "--model").unwrap_or_else(|| "7b".into());
    let policy = parse_policy(opt(args, "--policy").as_deref().unwrap_or("layerkv"))?;
    let ctx: usize = opt(args, "--ctx").unwrap_or_else(|| "2048".into()).parse()?;
    let rate: f64 = opt(args, "--rate").unwrap_or_else(|| "1.0".into()).parse()?;
    let n: usize = opt(args, "--requests").unwrap_or_else(|| "100".into()).parse()?;
    let seed: u64 = opt(args, "--seed").unwrap_or_else(|| "7".into()).parse()?;

    let cfg: ServingConfig = exp::setup(&model).with_policy(policy);
    let trace = if let Some(path) = opt(args, "--trace") {
        // replay a recorded JSON-lines trace
        layerkv::workload::trace::load(std::path::Path::new(&path))?
    } else if flag(args, "--sharegpt") {
        ShareGptWorkload::paper(rate, n).generate(&mut Rng::new(seed))
    } else {
        FixedWorkload {
            prompt_len: ctx,
            output_len: 512,
            n_requests: n,
            arrivals: Arrivals::Poisson { rate },
        }
        .generate(&mut Rng::new(seed))
    };
    if let Some(path) = opt(args, "--save-trace") {
        layerkv::workload::trace::save(&trace, std::path::Path::new(&path))?;
        println!("trace saved to {path}");
    }
    let (rep, stats) = run_trace(cfg.clone(), &trace, exp::PREDICTOR_ACC);
    let (mut ttft, mut tpot) = (rep.ttft(), rep.tpot());
    println!("model={model} policy={} ctx={ctx} rate={rate} n={n}", cfg.policy.name());
    println!(
        "TTFT   mean {:8.3}s   p50 {:8.3}s   p99 {:8.3}s",
        ttft.mean(),
        ttft.p50(),
        ttft.p99()
    );
    println!(
        "TPOT   mean {:8.4}s   p99 {:8.4}s",
        tpot.mean(),
        tpot.p99()
    );
    println!(
        "queue  mean {:8.3}s   prefill mean {:8.3}s",
        rep.queueing().mean(),
        rep.prefill().mean()
    );
    println!(
        "tput   {:.1} tok/s   {:.2} req/s   violations {:.1}%",
        rep.throughput_tok_s(),
        rep.throughput_req_s(),
        100.0 * rep.slo_violation_rate(&cfg.slo)
    );
    println!(
        "steps  {} ({} prefill, {} decode)   preemptions {}   offload {:.1} MB   onload-stream {:.1} MB",
        stats.steps,
        stats.prefill_steps,
        stats.decode_steps,
        stats.preemptions,
        stats.offload_bytes / 1e6,
        stats.onload_stream_bytes / 1e6,
    );
    Ok(())
}

fn cmd_serve(args: &[String]) -> anyhow::Result<()> {
    let addr = opt(args, "--addr").unwrap_or_else(|| "127.0.0.1:7181".into());
    let dir = opt(args, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(layerkv::runtime::artifacts::default_dir);
    let budget: usize = opt(args, "--budget").unwrap_or_else(|| "2097152".into()).parse()?;
    let policy = parse_policy(opt(args, "--policy").as_deref().unwrap_or("layerkv"))?;
    let max_batch: usize = opt(args, "--max-batch").unwrap_or_else(|| "8".into()).parse()?;
    let replicas: usize = opt(args, "--replicas").unwrap_or_else(|| "1".into()).parse()?;
    anyhow::ensure!(replicas >= 1, "--replicas must be at least 1");
    let router_name = opt(args, "--router").unwrap_or_else(|| "kv-pressure".into());
    let router = layerkv::cluster::RouterPolicy::parse(&router_name)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown router '{router_name}' (round-robin|jsq|kv-pressure|slo-aware)"
        ))?;
    let cfg = layerkv::runtime::RealEngineConfig {
        device_kv_budget: budget,
        policy,
        max_batch,
        ..Default::default()
    };
    let artifacts = (!flag(args, "--ref-model")).then_some(dir.as_path());
    layerkv::server::serve(&addr, artifacts, cfg, replicas, router)
}

fn cmd_selftest(args: &[String]) -> anyhow::Result<()> {
    let dir = opt(args, "--artifacts")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(layerkv::runtime::artifacts::default_dir);
    println!("loading artifacts from {}", dir.display());
    let model = layerkv::runtime::TinyModel::load(&dir)?;
    println!(
        "compiled {} prefill bucket(s) {:?}, {} decode bucket(s) {:?}, paged kernel: {}",
        model.art.prefill_buckets().len(),
        model.art.prefill_buckets(),
        model.art.decode_batches().len(),
        model.art.decode_batches(),
        model.has_paged_kernel(),
    );
    let prompt: Vec<i32> = (0..24).map(|i| (i * 3) % 256).collect();
    let out = model.prefill(&prompt)?;
    println!("prefill(24 tokens): bucket {}, first token {}", out.bucket, layerkv::runtime::argmax(&out.logits));
    println!("selftest OK");
    Ok(())
}
