//! Serving metrics: per-request latency records, aggregated into the
//! series the paper reports (mean/P99 TTFT, TPOT, queuing breakdown,
//! throughput, SLO violation rate) — plus the tier-transition log the
//! KV-hierarchy tests replay (every layer move GPU <-> host <-> disk).

use crate::config::SloTargets;
use crate::util::Series;

/// Tier indices for [`TierTransition`] (kept as plain u8s so metrics stays
/// dependency-free; `Residency::tier_index` produces them).
pub const TIER_GPU: u8 = 0;
pub const TIER_HOST: u8 = 1;
pub const TIER_DISK: u8 = 2;

/// One layer's residency move in the GPU -> host -> disk hierarchy, as
/// recorded by the engine when its transition log is enabled. The golden
/// trace-replay test asserts this log is reproducible and consistent with
/// the engine's offload/onload/spill counters.
#[derive(Debug, Clone, PartialEq)]
pub struct TierTransition {
    /// Engine time of the move (seconds).
    pub t: f64,
    /// Engine-internal request id.
    pub req: usize,
    /// Layer index within the request's table.
    pub layer: usize,
    /// Source tier (TIER_GPU / TIER_HOST / TIER_DISK).
    pub from: u8,
    /// Destination tier.
    pub to: u8,
    /// Layer-blocks moved.
    pub blocks: usize,
}

impl TierTransition {
    /// Compact one-line rendering (stable across runs for a fixed trace;
    /// time is rendered to bits so the log doubles as a bit-identity
    /// witness).
    pub fn render(&self) -> String {
        format!(
            "t={:016x} req={} layer={} {}->{} blocks={}",
            self.t.to_bits(),
            self.req,
            self.layer,
            self.from,
            self.to,
            self.blocks
        )
    }
}

/// What a fault-plan event does to a replica (see `cluster::faults`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Replica goes down: fenced, drained, its requests re-routed.
    Crash,
    /// Replica comes back: admission reopens, probation window starts.
    Recover,
    /// Service-rate degradation begins (factor >= 1.0).
    StragglerStart { slowdown: f64 },
    StragglerEnd,
    /// Disk-tier I/O errors begin on this replica.
    IoErrorStart,
    IoErrorEnd,
    /// Planned live migration: this replica (the source) drains with
    /// full state and `dst` adopts everything; the source is then fenced
    /// (administratively down, scale-down semantics).
    Migrate { dst: usize },
}

impl FaultKind {
    /// Stable ordering rank for same-instant events (crashes before
    /// recoveries so a zero-length window still drains; migrations after
    /// crashes so a same-instant crash on the destination is seen).
    pub fn rank(&self) -> u8 {
        match self {
            FaultKind::Crash => 0,
            FaultKind::Migrate { .. } => 1,
            FaultKind::StragglerStart { .. } => 2,
            FaultKind::IoErrorStart => 3,
            FaultKind::IoErrorEnd => 4,
            FaultKind::StragglerEnd => 5,
            FaultKind::Recover => 6,
        }
    }
}

/// One fault event in cluster virtual time, applied to one replica.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    /// Cluster virtual time of the event (seconds).
    pub t: f64,
    pub replica: usize,
    pub kind: FaultKind,
}

impl FaultEvent {
    /// Compact one-line rendering; time is rendered to bits so the event
    /// log doubles as a determinism witness (like `TierTransition`).
    pub fn render(&self) -> String {
        format!("t={:016x} replica={} {:?}", self.t.to_bits(), self.replica, self.kind)
    }
}

/// Rollup of a faulted cluster run: what was injected and what it cost.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSummary {
    pub crashes: usize,
    pub recoveries: usize,
    pub straggler_windows: usize,
    pub io_bursts: usize,
    /// Re-submissions of drained requests (failover traffic).
    pub retries: u64,
    /// Requests that exhausted their retry budget or never found a live
    /// replica to land on.
    pub failed: usize,
    /// Σ per-replica seconds spent crashed (windows still open at the end
    /// of the run count up to the run's end).
    pub downtime_s: f64,
    /// Planned live migrations executed (source drained with state, every
    /// request adopted by the destination).
    pub migrations: usize,
    /// Drained requests adopted from a checkpoint snapshot instead of
    /// re-submitted from scratch (checkpointed failover + migrations).
    pub adoptions: u64,
    /// Prefill-equivalent tokens failover had to recompute: the whole
    /// context (prompt + committed) for from-scratch re-submissions, only
    /// the suffix past the checkpoint for adoptions. The headline the
    /// checkpointing experiment contrasts.
    pub recomputed_tokens: u64,
    /// Tokens failover resumed straight from durable checkpoints (prompt
    /// + checkpointed progress of each adopted request).
    pub resumed_tokens: u64,
}

/// Per-request latency record (all timestamps in seconds of engine time).
#[derive(Debug, Clone, PartialEq)]
pub struct RequestRecord {
    pub id: usize,
    pub arrival: f64,
    /// When its prefill started executing.
    pub prefill_start: f64,
    /// When the first token was emitted (prefill end).
    pub first_token: f64,
    /// When the last token was emitted.
    pub finish: f64,
    pub prompt_len: usize,
    pub output_len: usize,
}

impl RequestRecord {
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    pub fn queueing(&self) -> f64 {
        self.prefill_start - self.arrival
    }

    pub fn prefill_latency(&self) -> f64 {
        self.first_token - self.prefill_start
    }

    /// Time Per Output Token over the decode phase.
    pub fn tpot(&self) -> f64 {
        if self.output_len <= 1 {
            return 0.0;
        }
        (self.finish - self.first_token) / (self.output_len - 1) as f64
    }

    pub fn violates(&self, slo: &SloTargets) -> bool {
        self.ttft() > slo.ttft_s || self.tpot() > slo.tpot_s
    }
}

/// Aggregated report over a run.
#[derive(Debug, Clone)]
pub struct Report {
    pub records: Vec<RequestRecord>,
    /// Engine time when the last request finished.
    pub makespan: f64,
}

impl Report {
    pub fn new(mut records: Vec<RequestRecord>) -> Self {
        records.sort_by_key(|r| r.id);
        let makespan = records.iter().map(|r| r.finish).fold(0.0, f64::max);
        Report { records, makespan }
    }

    fn series<F: Fn(&RequestRecord) -> f64>(&self, f: F) -> Series {
        let mut s = Series::new();
        for r in &self.records {
            s.push(f(r));
        }
        s
    }

    pub fn ttft(&self) -> Series {
        self.series(|r| r.ttft())
    }
    pub fn tpot(&self) -> Series {
        self.series(|r| r.tpot())
    }
    pub fn queueing(&self) -> Series {
        self.series(|r| r.queueing())
    }
    pub fn prefill(&self) -> Series {
        self.series(|r| r.prefill_latency())
    }

    /// Output tokens per second over the makespan (the paper's throughput
    /// bar charts).
    pub fn throughput_tok_s(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.records.iter().map(|r| r.output_len as f64).sum::<f64>() / self.makespan
    }

    /// Completed requests per second.
    pub fn throughput_req_s(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.records.len() as f64 / self.makespan
    }

    /// Goodput: completed requests that met both SLOs, per second of
    /// makespan. The fault experiments report this because under crashes
    /// raw throughput hides retries that finished uselessly late.
    pub fn goodput_req_s(&self, slo: &SloTargets) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        self.records.iter().filter(|r| !r.violates(slo)).count() as f64 / self.makespan
    }

    /// Fraction of requests violating either SLO (Fig. 8).
    pub fn slo_violation_rate(&self, slo: &SloTargets) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        self.records.iter().filter(|r| r.violates(slo)).count() as f64
            / self.records.len() as f64
    }
}

/// One replica's slice of a cluster run, rolled up for the cluster
/// experiments and the per-replica report the `cluster/` subsystem emits.
#[derive(Debug, Clone)]
pub struct ReplicaSummary {
    pub replica: usize,
    /// Requests the router sent here (completed + dropped + in flight;
    /// after a drained run, completed + dropped).
    pub routed: usize,
    pub completed: usize,
    pub dropped: usize,
    pub ttft_mean: f64,
    pub ttft_p99: f64,
    pub viol_rate: f64,
}

impl ReplicaSummary {
    pub fn from_report(
        replica: usize,
        routed: usize,
        dropped: usize,
        report: &Report,
        slo: &SloTargets,
    ) -> Self {
        let mut ttft = report.ttft();
        ReplicaSummary {
            replica,
            routed,
            completed: report.records.len(),
            dropped,
            ttft_mean: ttft.mean(),
            ttft_p99: ttft.p99(),
            viol_rate: report.slo_violation_rate(slo),
        }
    }
}

/// Cluster-wide rollup: the merged latency distribution plus each
/// replica's share.
#[derive(Debug, Clone)]
pub struct ClusterSummary {
    pub ttft_mean: f64,
    pub ttft_p99: f64,
    pub viol_rate: f64,
    pub throughput_tok_s: f64,
    pub per_replica: Vec<ReplicaSummary>,
}

impl ClusterSummary {
    pub fn new(merged: &Report, slo: &SloTargets, per_replica: Vec<ReplicaSummary>) -> Self {
        let mut ttft = merged.ttft();
        ClusterSummary {
            ttft_mean: ttft.mean(),
            ttft_p99: ttft.p99(),
            viol_rate: merged.slo_violation_rate(slo),
            throughput_tok_s: merged.throughput_tok_s(),
            per_replica,
        }
    }

    /// Largest fraction of routed requests any one replica received —
    /// 1/n for perfect balance, 1.0 when one replica got everything.
    pub fn max_share(&self) -> f64 {
        let total: usize = self.per_replica.iter().map(|r| r.routed).sum();
        if total == 0 {
            return 0.0;
        }
        let max = self.per_replica.iter().map(|r| r.routed).max().unwrap_or(0);
        max as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(id: usize, arrival: f64, ps: f64, ft: f64, fin: f64, out: usize) -> RequestRecord {
        RequestRecord {
            id,
            arrival,
            prefill_start: ps,
            first_token: ft,
            finish: fin,
            prompt_len: 128,
            output_len: out,
        }
    }

    #[test]
    fn latency_decomposition() {
        let r = rec(0, 1.0, 3.0, 4.5, 10.0, 12);
        assert!((r.ttft() - 3.5).abs() < 1e-12);
        assert!((r.queueing() - 2.0).abs() < 1e-12);
        assert!((r.prefill_latency() - 1.5).abs() < 1e-12);
        // ttft == queueing + prefill (the Fig. 1b identity)
        assert!((r.ttft() - (r.queueing() + r.prefill_latency())).abs() < 1e-12);
        assert!((r.tpot() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn single_token_output_has_zero_tpot() {
        let r = rec(0, 0.0, 0.0, 1.0, 1.0, 1);
        assert_eq!(r.tpot(), 0.0);
    }

    #[test]
    fn violation_logic() {
        let slo = SloTargets { ttft_s: 3.0, tpot_s: 0.2 };
        assert!(!rec(0, 0.0, 1.0, 2.0, 2.0 + 0.1 * 9.0, 10).violates(&slo));
        assert!(rec(0, 0.0, 3.0, 4.0, 5.0, 10).violates(&slo)); // ttft 4 > 3
        assert!(rec(0, 0.0, 0.0, 1.0, 1.0 + 0.3 * 9.0, 10).violates(&slo)); // tpot
    }

    #[test]
    fn tier_transition_render_is_stable() {
        let tr = TierTransition {
            t: 1.5,
            req: 3,
            layer: 7,
            from: TIER_HOST,
            to: TIER_DISK,
            blocks: 4,
        };
        assert_eq!(tr.render(), tr.clone().render());
        assert!(tr.render().contains("1->2"));
        assert!(tr.render().contains("req=3"));
    }

    #[test]
    fn cluster_summary_rollup_and_balance() {
        let slo = SloTargets { ttft_s: 3.0, tpot_s: 10.0 };
        let fast = Report::new(vec![rec(0, 0.0, 0.5, 1.0, 2.0, 10)]);
        let slow = Report::new(vec![rec(1, 0.0, 3.0, 4.0, 5.0, 10)]);
        let merged = Report::new(
            fast.records.iter().chain(slow.records.iter()).cloned().collect(),
        );
        let per = vec![
            ReplicaSummary::from_report(0, 3, 0, &fast, &slo),
            ReplicaSummary::from_report(1, 1, 0, &slow, &slo),
        ];
        assert_eq!(per[0].completed, 1);
        assert_eq!(per[0].viol_rate, 0.0);
        assert_eq!(per[1].viol_rate, 1.0); // ttft 4 > 3
        let s = ClusterSummary::new(&merged, &slo, per);
        assert_eq!(s.per_replica.len(), 2);
        assert!((s.viol_rate - 0.5).abs() < 1e-12);
        assert!((s.max_share() - 0.75).abs() < 1e-12); // 3 of 4 routed
    }

    #[test]
    fn fault_event_render_is_stable_and_ranks_order_same_instant() {
        let ev = FaultEvent { t: 20.0, replica: 1, kind: FaultKind::Crash };
        assert_eq!(ev.render(), ev.clone().render());
        assert!(ev.render().contains("replica=1"));
        assert!(FaultKind::Crash.rank() < FaultKind::Recover.rank());
        assert!(
            FaultKind::StragglerStart { slowdown: 2.0 }.rank()
                < FaultKind::StragglerEnd.rank()
        );
    }

    #[test]
    fn goodput_counts_only_slo_ok_completions() {
        let slo = SloTargets { ttft_s: 3.0, tpot_s: 10.0 };
        let rep = Report::new(vec![
            rec(0, 0.0, 0.5, 1.0, 2.0, 10),  // ttft 1.0: ok
            rec(1, 0.0, 3.0, 4.0, 5.0, 10),  // ttft 4.0: violates
        ]);
        assert!((rep.goodput_req_s(&slo) - 1.0 / 5.0).abs() < 1e-12);
        assert!((rep.throughput_req_s() - 2.0 / 5.0).abs() < 1e-12);
    }

    #[test]
    fn max_share_empty_single_and_skewed() {
        let slo = SloTargets { ttft_s: 3.0, tpot_s: 10.0 };
        // no replicas at all: 0, not NaN from 0/0
        let empty = ClusterSummary::new(&Report::new(Vec::new()), &slo, Vec::new());
        assert_eq!(empty.max_share(), 0.0);
        // replicas present but nothing routed yet: same guard
        let idle = ClusterSummary::new(
            &Report::new(Vec::new()),
            &slo,
            vec![
                ReplicaSummary::from_report(0, 0, 0, &Report::new(Vec::new()), &slo),
                ReplicaSummary::from_report(1, 0, 0, &Report::new(Vec::new()), &slo),
            ],
        );
        assert_eq!(idle.max_share(), 0.0);
        // a single replica always holds the full share
        let rep = Report::new(vec![rec(0, 0.0, 0.5, 1.0, 2.0, 10)]);
        let single = ClusterSummary::new(
            &rep,
            &slo,
            vec![ReplicaSummary::from_report(0, 5, 0, &rep, &slo)],
        );
        assert_eq!(single.max_share(), 1.0);
        // fully skewed routing: one replica got everything
        let skew = ClusterSummary::new(
            &rep,
            &slo,
            vec![
                ReplicaSummary::from_report(0, 8, 0, &rep, &slo),
                ReplicaSummary::from_report(1, 0, 0, &Report::new(Vec::new()), &slo),
            ],
        );
        assert_eq!(skew.max_share(), 1.0);
    }

    #[test]
    fn goodput_zero_when_every_completion_violates() {
        let slo = SloTargets { ttft_s: 0.5, tpot_s: 0.01 };
        let rep = Report::new(vec![
            rec(0, 0.0, 1.0, 2.0, 4.0, 10),
            rec(1, 0.0, 2.0, 3.0, 5.0, 10),
        ]);
        assert_eq!(rep.goodput_req_s(&slo), 0.0);
        assert!(rep.throughput_req_s() > 0.0); // raw throughput still counts them
        assert_eq!(rep.slo_violation_rate(&slo), 1.0);
    }

    #[test]
    fn empty_report_rollups_are_finite_zeros() {
        let slo = SloTargets { ttft_s: 3.0, tpot_s: 10.0 };
        let rep = Report::new(Vec::new());
        assert_eq!(rep.makespan, 0.0);
        assert_eq!(rep.throughput_tok_s(), 0.0);
        assert_eq!(rep.throughput_req_s(), 0.0);
        assert_eq!(rep.goodput_req_s(&slo), 0.0);
        assert_eq!(rep.slo_violation_rate(&slo), 0.0);
        // a zero-completion replica row renders 0s, never ±inf/NaN
        let rs = ReplicaSummary::from_report(0, 0, 0, &rep, &slo);
        assert!(rs.ttft_mean.is_finite() && rs.ttft_p99.is_finite());
        assert_eq!(rs.ttft_mean, 0.0);
        assert_eq!(rs.viol_rate, 0.0);
        let mut ttft = rep.ttft();
        assert_eq!(ttft.min(), 0.0);
        assert_eq!(ttft.max(), 0.0);
        assert_eq!(ttft.p99(), 0.0);
    }

    #[test]
    fn report_aggregates() {
        let recs = vec![rec(1, 0.0, 0.5, 1.0, 2.0, 10), rec(0, 0.0, 1.0, 2.0, 4.0, 20)];
        let rep = Report::new(recs);
        assert_eq!(rep.records[0].id, 0); // sorted
        assert_eq!(rep.makespan, 4.0);
        assert!((rep.throughput_tok_s() - 30.0 / 4.0).abs() < 1e-12);
        assert!((rep.throughput_req_s() - 0.5).abs() < 1e-12);
        let mut ttft = rep.ttft();
        assert!((ttft.mean() - 1.5).abs() < 1e-12);
        assert!(ttft.p99() > 1.0);
    }
}
