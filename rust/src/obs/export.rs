//! Trace exporters: Chrome trace-event JSON (load in Perfetto /
//! `chrome://tracing`) and line-delimited JSON, plus the validator the
//! CI smoke runs over exported traces (`trace-check`).
//!
//! Chrome mapping: one *process* (`pid`) per replica track, one *thread*
//! (`tid`) per request phase — lane 0 carries instant events (arrivals,
//! tier moves, faults, terminals), lanes 1-3 the queued/prefill/decode
//! spans. Gauges render as "C" counter events on lane 0, so Perfetto
//! draws per-replica free-block / queue-depth / slowdown graphs under
//! each replica's span rows. Timestamps are virtual seconds scaled to
//! the format's microseconds.

use std::collections::{BTreeMap, BTreeSet};

use super::{fault_name, EventKind, GaugeSample, TraceRecord, Tracer};
use crate::util::Json;

fn num(x: f64) -> Json {
    Json::Num(x)
}

fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

/// `req` payload as exported: the prefix-store sentinel renders as -1.
fn req_num(req: u64) -> Json {
    if req == u64::MAX {
        num(-1.0)
    } else {
        num(req as f64)
    }
}

/// Kind-specific args for one record (always includes `req`).
fn record_args(r: &TraceRecord) -> Json {
    let mut pairs: Vec<(&str, Json)> = vec![("req", req_num(r.req))];
    match r.kind {
        EventKind::Arrive => {
            pairs.push(("prompt_len", num(r.a as f64)));
            pairs.push(("output_len", num(r.b as f64)));
        }
        EventKind::Admit => pairs.push(("retained_layers", num(r.a as f64))),
        EventKind::Prefill => {
            pairs.push(("prompt_len", num(r.a as f64)));
            pairs.push(("cached_prefix", num(r.b as f64)));
        }
        EventKind::Decode => {
            pairs.push(("iterations", num(r.a as f64)));
            pairs.push(("batch_tokens", num(r.b as f64)));
        }
        EventKind::TierMove => {
            pairs.push(("from_tier", num(r.a as f64)));
            pairs.push(("to_tier", num(r.b as f64)));
            pairs.push(("blocks", num(r.c as f64)));
        }
        EventKind::PrefixHit => {
            pairs.push(("tokens", num(r.a as f64)));
            pairs.push(("tier", num(r.b as f64)));
        }
        EventKind::Fault => {
            pairs.push(("fault", jstr(fault_name(r.a))));
            if r.c != 0 {
                pairs.push(("slowdown", num(f64::from_bits(r.c))));
            }
        }
        EventKind::Finish => pairs.push(("generated", num(r.a as f64))),
        EventKind::Drain => {
            pairs.push(("committed", num(r.a as f64)));
            pairs.push(("checkpointed", num(r.b as f64)));
        }
        EventKind::Checkpoint => {
            pairs.push(("durable_tokens", num(r.a as f64)));
            pairs.push(("delta_tokens", num(r.b as f64)));
        }
        EventKind::Adopt => {
            pairs.push(("committed", num(r.a as f64)));
            pairs.push(("resumed", num(r.b as f64)));
        }
        EventKind::Queued
        | EventKind::FirstToken
        | EventKind::Preempt
        | EventKind::Resubmit
        | EventKind::Drop
        | EventKind::Failed => {}
    }
    obj(pairs)
}

fn span_event(r: &TraceRecord) -> Json {
    obj(vec![
        ("ph", jstr("X")),
        ("name", jstr(r.kind.name())),
        ("cat", jstr("lifecycle")),
        ("pid", num(r.track as f64)),
        ("tid", num(r.kind.lane() as f64)),
        ("ts", num(r.t0 * 1e6)),
        ("dur", num((r.t1 - r.t0).max(0.0) * 1e6)),
        ("args", record_args(r)),
    ])
}

fn instant_event(r: &TraceRecord) -> Json {
    obj(vec![
        ("ph", jstr("i")),
        ("name", jstr(r.kind.name())),
        ("cat", jstr("lifecycle")),
        ("pid", num(r.track as f64)),
        ("tid", num(r.kind.lane() as f64)),
        ("ts", num(r.t0 * 1e6)),
        ("s", jstr("t")),
        ("args", record_args(r)),
    ])
}

fn counter_event(g: &GaugeSample) -> Json {
    obj(vec![
        ("ph", jstr("C")),
        ("name", jstr(g.kind.name())),
        ("pid", num(g.track as f64)),
        ("tid", num(0.0)),
        ("ts", num(g.t * 1e6)),
        ("args", obj(vec![("value", num(g.value))])),
    ])
}

const LANE_NAMES: [&str; 4] = ["events", "queued", "prefill", "decode"];

/// Render the tracer's contents as one Chrome trace-event JSON document.
pub fn chrome_trace(t: &Tracer) -> Json {
    let mut tracks: BTreeSet<u32> = BTreeSet::new();
    for r in t.spans() {
        tracks.insert(r.track);
    }
    for g in t.gauges() {
        tracks.insert(g.track);
    }

    // metadata first: Perfetto names the process/thread rows from these
    let mut events: Vec<Json> = Vec::new();
    for &track in &tracks {
        events.push(obj(vec![
            ("ph", jstr("M")),
            ("name", jstr("process_name")),
            ("pid", num(track as f64)),
            ("args", obj(vec![("name", jstr(&format!("replica-{track}")))])),
        ]));
        for (lane, lane_name) in LANE_NAMES.iter().enumerate() {
            events.push(obj(vec![
                ("ph", jstr("M")),
                ("name", jstr("thread_name")),
                ("pid", num(track as f64)),
                ("tid", num(lane as f64)),
                ("args", obj(vec![("name", jstr(lane_name))])),
            ]));
        }
    }

    // data events, sorted by virtual timestamp (total order: exported
    // traces are monotonic per track by construction)
    let mut timed: Vec<(f64, Json)> = Vec::new();
    for r in t.spans() {
        let ev = if r.kind.is_span() { span_event(r) } else { instant_event(r) };
        timed.push((r.t0, ev));
    }
    for g in t.gauges() {
        timed.push((g.t, counter_event(g)));
    }
    timed.sort_by(|a, b| a.0.total_cmp(&b.0));
    events.extend(timed.into_iter().map(|(_, e)| e));

    obj(vec![
        ("traceEvents", Json::Arr(events)),
        ("displayTimeUnit", jstr("ms")),
        (
            "otherData",
            obj(vec![
                ("span_count", num(t.spans_len() as f64)),
                ("gauge_count", num(t.gauges_len() as f64)),
                ("dropped_spans", num(t.spans_dropped() as f64)),
                ("dropped_gauges", num(t.gauges_dropped() as f64)),
            ]),
        ),
    ])
}

/// Render the tracer's contents as JSONL: one self-describing object per
/// line (spans/instants first, then gauges), for ad-hoc tooling.
pub fn jsonl(t: &Tracer) -> String {
    let mut out = String::new();
    for r in t.spans() {
        let line = obj(vec![
            ("type", jstr(if r.kind.is_span() { "span" } else { "instant" })),
            ("kind", jstr(r.kind.name())),
            ("track", num(r.track as f64)),
            ("req", req_num(r.req)),
            ("t0", num(r.t0)),
            ("t1", num(r.t1)),
            ("args", record_args(r)),
        ]);
        out.push_str(&line.dump());
        out.push('\n');
    }
    for g in t.gauges() {
        let line = obj(vec![
            ("type", jstr("gauge")),
            ("kind", jstr(g.kind.name())),
            ("track", num(g.track as f64)),
            ("t", num(g.t)),
            ("value", num(g.value)),
        ]);
        out.push_str(&line.dump());
        out.push('\n');
    }
    out
}

/// Validate an exported Chrome trace document (the `trace-check` CLI and
/// the prop suite run this): every event well-formed, timestamps
/// monotonic per (track, lane), and — unless the span ring wrapped —
/// every arrived request reaching a terminal mark (finish/drop/failed)
/// and every drained request later re-entering somewhere (an `adopt` or
/// `resubmit` instant) or exhausting its retry budget (`failed`).
/// Returns a one-line summary on success.
pub fn validate_chrome(j: &Json) -> Result<String, String> {
    let events = j
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| "missing traceEvents array".to_string())?;
    let mut last: BTreeMap<(u64, u64), f64> = BTreeMap::new();
    let mut tracks: BTreeSet<u64> = BTreeSet::new();
    let mut arrived: BTreeSet<i64> = BTreeSet::new();
    let mut terminal: BTreeSet<i64> = BTreeSet::new();
    let mut drained: BTreeSet<i64> = BTreeSet::new();
    let mut redispatched: BTreeSet<i64> = BTreeSet::new();
    let mut n_events = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing ph"))?;
        if ph == "M" {
            continue;
        }
        let pid = ev
            .get("pid")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("event {i}: missing pid"))? as u64;
        let tid = ev.get("tid").and_then(Json::as_f64).unwrap_or(0.0) as u64;
        let ts = ev
            .get("ts")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("event {i}: missing ts"))?;
        if !ts.is_finite() {
            return Err(format!("event {i}: non-finite ts"));
        }
        if let Some(&prev) = last.get(&(pid, tid)) {
            if ts < prev {
                return Err(format!(
                    "track {pid} lane {tid}: ts went backwards ({ts} after {prev})"
                ));
            }
        }
        last.insert((pid, tid), ts);
        tracks.insert(pid);
        n_events += 1;
        if ph == "X" {
            let dur = ev
                .get("dur")
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("event {i}: X event missing dur"))?;
            if !(dur >= 0.0) {
                return Err(format!("event {i}: negative or NaN dur {dur}"));
            }
        }
        let name = ev.get("name").and_then(Json::as_str).unwrap_or("");
        let req = ev.get("args").and_then(|a| a.get("req")).and_then(Json::as_f64);
        if let Some(r) = req {
            let r = r as i64;
            if r >= 0 {
                if name == "arrive" {
                    arrived.insert(r);
                }
                if matches!(name, "finish" | "drop" | "failed") {
                    terminal.insert(r);
                }
                if name == "drain" {
                    drained.insert(r);
                }
                if matches!(name, "adopt" | "resubmit" | "failed") {
                    redispatched.insert(r);
                }
            }
        }
    }
    let dropped = j
        .get("otherData")
        .and_then(|o| o.get("dropped_spans"))
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    if dropped == 0.0 {
        for r in &arrived {
            if !terminal.contains(r) {
                return Err(format!("request {r} arrived but never reached a terminal span"));
            }
        }
        for r in &drained {
            if !redispatched.contains(r) {
                return Err(format!(
                    "request {r} was drained but never adopted, resubmitted, or failed"
                ));
            }
        }
    }
    Ok(format!(
        "{n_events} events on {} track(s); {} request(s) arrived, {} terminal{}",
        tracks.len(),
        arrived.len(),
        terminal.len(),
        if dropped > 0.0 { " (span ring wrapped; lifecycle check skipped)" } else { "" }
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::{GaugeKind, TraceHandle};

    fn sample_handle() -> TraceHandle {
        let h = TraceHandle::new(64, 64);
        let span = |t0: f64, t1: f64, kind: EventKind, req: u64| TraceRecord {
            t0,
            t1,
            kind,
            track: 0,
            req,
            a: 8,
            b: 4,
            c: 0,
        };
        h.record(span(0.0, 0.0, EventKind::Arrive, 0));
        h.record(span(0.0, 0.5, EventKind::Queued, 0));
        h.record(span(0.5, 0.9, EventKind::Prefill, 0));
        h.record(span(0.9, 0.9, EventKind::FirstToken, 0));
        h.record(span(0.9, 2.0, EventKind::Decode, 0));
        h.record(span(2.0, 2.0, EventKind::Finish, 0));
        h.gauge(GaugeSample { t: 0.5, track: 0, kind: GaugeKind::QueueDepth, value: 1.0 });
        h.gauge(GaugeSample { t: 2.0, track: 0, kind: GaugeKind::QueueDepth, value: 0.0 });
        h
    }

    #[test]
    fn chrome_export_roundtrips_and_validates() {
        let h = sample_handle();
        let t = h.lock();
        let j = chrome_trace(&t);
        // serialization roundtrip through the in-tree parser
        let parsed = Json::parse(&j.dump()).expect("chrome trace parses");
        let summary = validate_chrome(&parsed).expect("trace validates");
        assert!(summary.contains("1 track(s)"), "{summary}");
        assert!(summary.contains("1 request(s) arrived"), "{summary}");
        // spans became X events with nonnegative dur, instants i events
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("X")));
        assert!(events.iter().any(|e| e.get("ph").unwrap().as_str() == Some("C")));
        assert!(events
            .iter()
            .any(|e| e.get("name").and_then(Json::as_str) == Some("process_name")));
    }

    #[test]
    fn jsonl_lines_each_parse() {
        let h = sample_handle();
        let t = h.lock();
        let text = jsonl(&t);
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 8); // 6 records + 2 gauges
        for line in lines {
            let j = Json::parse(line).expect("jsonl line parses");
            assert!(j.get("type").is_some());
        }
    }

    #[test]
    fn validator_catches_missing_terminal() {
        let h = TraceHandle::new(8, 8);
        h.record(TraceRecord {
            t0: 0.0,
            t1: 0.0,
            kind: EventKind::Arrive,
            track: 0,
            req: 5,
            a: 0,
            b: 0,
            c: 0,
        });
        let j = chrome_trace(&h.lock());
        let err = validate_chrome(&j).unwrap_err();
        assert!(err.contains("request 5"), "{err}");
    }

    #[test]
    fn validator_requires_drain_to_pair_with_adopt_or_resubmit() {
        let rec = |t: f64, kind: EventKind| TraceRecord {
            t0: t,
            t1: t,
            kind,
            track: 0,
            req: 3,
            a: 0,
            b: 0,
            c: 0,
        };
        // drained and never seen again: rejected
        let h = TraceHandle::new(16, 16);
        h.record(rec(0.0, EventKind::Arrive));
        h.record(rec(1.0, EventKind::Drain));
        h.record(rec(2.0, EventKind::Finish));
        let err = validate_chrome(&chrome_trace(&h.lock())).unwrap_err();
        assert!(err.contains("drained"), "{err}");
        // drained then adopted: valid
        let h = TraceHandle::new(16, 16);
        h.record(rec(0.0, EventKind::Arrive));
        h.record(rec(1.0, EventKind::Drain));
        h.record(rec(1.5, EventKind::Adopt));
        h.record(rec(2.0, EventKind::Finish));
        validate_chrome(&chrome_trace(&h.lock())).expect("adopted drain valid");
        // drained then resubmitted: valid
        let h = TraceHandle::new(16, 16);
        h.record(rec(0.0, EventKind::Arrive));
        h.record(rec(1.0, EventKind::Drain));
        h.record(rec(1.5, EventKind::Resubmit));
        h.record(rec(2.0, EventKind::Finish));
        validate_chrome(&chrome_trace(&h.lock())).expect("resubmitted drain valid");
        // drained then failed (budget exhausted): valid
        let h = TraceHandle::new(16, 16);
        h.record(rec(0.0, EventKind::Arrive));
        h.record(rec(1.0, EventKind::Drain));
        h.record(rec(2.0, EventKind::Failed));
        validate_chrome(&chrome_trace(&h.lock())).expect("failed drain valid");
    }

    #[test]
    fn validator_catches_backwards_timestamps() {
        let src = r#"{"traceEvents": [
            {"ph": "i", "name": "arrive", "pid": 0, "tid": 0, "ts": 5.0, "s": "t"},
            {"ph": "i", "name": "finish", "pid": 0, "tid": 0, "ts": 1.0, "s": "t"}
        ]}"#;
        let j = Json::parse(src).unwrap();
        let err = validate_chrome(&j).unwrap_err();
        assert!(err.contains("backwards"), "{err}");
    }

    #[test]
    fn wrapped_ring_skips_lifecycle_check_but_stays_valid() {
        let h = TraceHandle::new(2, 2);
        for i in 0..5u64 {
            h.record(TraceRecord {
                t0: i as f64,
                t1: i as f64,
                kind: EventKind::Arrive,
                track: 0,
                req: i,
                a: 0,
                b: 0,
                c: 0,
            });
        }
        let t = h.lock();
        assert!(t.spans_dropped() > 0);
        let summary = validate_chrome(&chrome_trace(&t)).expect("wrapped trace valid");
        assert!(summary.contains("ring wrapped"), "{summary}");
    }

    #[test]
    fn prefix_store_sentinel_renders_as_minus_one() {
        let h = TraceHandle::new(8, 8);
        h.record(TraceRecord {
            t0: 1.0,
            t1: 1.0,
            kind: EventKind::TierMove,
            track: 0,
            req: u64::MAX,
            a: 1,
            b: 0,
            c: 4,
        });
        let j = chrome_trace(&h.lock());
        let dump = j.dump();
        assert!(dump.contains("\"req\":-1"), "{dump}");
        validate_chrome(&j).expect("sentinel-only trace valid");
    }
}
