//! Deterministic observability plane: per-request lifecycle spans and
//! virtual-time gauges, recorded into preallocated ring buffers and
//! exported as Chrome trace-event JSON (Perfetto-loadable) or JSONL.
//!
//! Design contract (property-tested in `tests/prop_obs.rs`):
//!
//! * **Invisible to results.** Tracing never mutates engine or cluster
//!   state, never draws randomness, and never changes control flow: with
//!   tracing on, records/makespan/stats are bit-identical to tracing off
//!   across routers x macro-stepping x heap-vs-lockstep x fault plans.
//!   With tracing off the hot paths pay one `Option::is_some` check and
//!   allocate nothing.
//! * **Bounded memory.** Both rings are preallocated at install time and
//!   overwrite their oldest entries when full; `dropped()` counts what
//!   was overwritten so exporters can flag truncated traces.
//! * **Virtual time.** Every record is stamped with the owning engine's
//!   clock (simulated seconds on `SimBackend`), so a trace of a
//!   macro-stepped heap-driven fleet reads the same as one from the
//!   lockstep oracle.
//!
//! The engine and cluster attach to a [`TraceHandle`] either explicitly
//! (`set_tracer`) or via the process-global [`sink`] the CLI installs
//! for `--trace-out`; each engine allocates its own track (one Perfetto
//! process row per replica).

pub mod export;

use std::sync::{Arc, Mutex, MutexGuard};

/// Default span-ring capacity installed by the CLI (~4 MB of records).
pub const DEFAULT_SPAN_CAP: usize = 1 << 16;
/// Default gauge-ring capacity installed by the CLI.
pub const DEFAULT_GAUGE_CAP: usize = 1 << 14;

/// Fault instant codes (the `a` payload of [`EventKind::Fault`] records);
/// the cluster maps its `FaultKind` onto these when folding fault events
/// into the trace.
pub const FAULT_CRASH: u64 = 0;
pub const FAULT_RECOVER: u64 = 1;
pub const FAULT_STRAGGLER_START: u64 = 2;
pub const FAULT_STRAGGLER_END: u64 = 3;
pub const FAULT_IO_ERROR_START: u64 = 4;
pub const FAULT_IO_ERROR_END: u64 = 5;
pub const FAULT_MIGRATE: u64 = 6;

/// Human name of a fault instant code (for exporters).
pub fn fault_name(code: u64) -> &'static str {
    match code {
        FAULT_CRASH => "crash",
        FAULT_RECOVER => "recover",
        FAULT_STRAGGLER_START => "straggler_start",
        FAULT_STRAGGLER_END => "straggler_end",
        FAULT_IO_ERROR_START => "io_error_start",
        FAULT_IO_ERROR_END => "io_error_end",
        FAULT_MIGRATE => "migrate",
        _ => "unknown",
    }
}

/// What one trace record describes. Spans carry `[t0, t1]`; instants
/// carry `t0 == t1`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// Request entered the system (instant at its arrival time).
    /// `a` = prompt tokens, `b` = output tokens.
    Arrive,
    /// Span from arrival to first admission into prefill.
    Queued,
    /// Admission instant. `a` = retained layers granted at admission.
    Admit,
    /// Span over the prefill batch that produced this request's first
    /// token. `a` = prompt tokens prefetched, `b` = prefix tokens served
    /// from cache.
    Prefill,
    /// First token emitted (instant; the TTFT mark).
    FirstToken,
    /// Span over one decode step or one macro-stepped decode run.
    /// `a` = decode iterations covered, `b` = batch tokens in flight.
    Decode,
    /// Preempted back to the waiting queue (recompute path).
    Preempt,
    /// One layer's residency move. `a` = source tier, `b` = destination
    /// tier (`metrics::TIER_*`), `c` = layer-blocks moved.
    TierMove,
    /// Prefix-cache hit at admission. `a` = tokens served from cache,
    /// `b` = tier the cached blocks resided on.
    PrefixHit,
    /// Evicted unfinished by a drain (crash failover / scale-down).
    /// `a` = tokens committed at the drain, `b` = tokens covered by the
    /// last durable checkpoint.
    Drain,
    /// Re-submitted to another replica after a drain.
    Resubmit,
    /// Incremental KV checkpoint written to the disk tier (virtual: the
    /// write is priced, never clocked). `a` = committed tokens now
    /// durable, `b` = tokens this write covered.
    Checkpoint,
    /// Adopted by another replica from a drain-with-state snapshot.
    /// `a` = tokens committed at the drain, `b` = tokens resumed from the
    /// durable checkpoint (0 = degraded to the recompute path).
    Adopt,
    /// A fault-plan event applied to this replica. `a` = fault code
    /// (`FAULT_*`), `c` = slowdown bits for straggler starts.
    Fault,
    /// Completed (terminal). `a` = tokens generated.
    Finish,
    /// Dropped by admission control (terminal).
    Drop,
    /// Exhausted its failover retry budget (terminal, cluster-level).
    Failed,
}

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Arrive => "arrive",
            EventKind::Queued => "queued",
            EventKind::Admit => "admit",
            EventKind::Prefill => "prefill",
            EventKind::FirstToken => "first_token",
            EventKind::Decode => "decode",
            EventKind::Preempt => "preempt",
            EventKind::TierMove => "tier_move",
            EventKind::PrefixHit => "prefix_hit",
            EventKind::Drain => "drain",
            EventKind::Resubmit => "resubmit",
            EventKind::Checkpoint => "checkpoint",
            EventKind::Adopt => "adopt",
            EventKind::Fault => "fault",
            EventKind::Finish => "finish",
            EventKind::Drop => "drop",
            EventKind::Failed => "failed",
        }
    }

    /// Spans render as Chrome "X" complete events; everything else as
    /// "i" instants.
    pub fn is_span(&self) -> bool {
        matches!(self, EventKind::Queued | EventKind::Prefill | EventKind::Decode)
    }

    /// Which per-replica lane (Chrome `tid`) the record renders on: one
    /// lane per request phase plus lane 0 for instants.
    pub fn lane(&self) -> u32 {
        match self {
            EventKind::Queued => 1,
            EventKind::Prefill => 2,
            EventKind::Decode => 3,
            _ => 0,
        }
    }

    /// Terminal lifecycle marks: every arrived request must reach one
    /// (validated by `export::validate_chrome` unless the ring wrapped).
    pub fn is_terminal(&self) -> bool {
        matches!(self, EventKind::Finish | EventKind::Drop | EventKind::Failed)
    }
}

/// One span or instant, stamped in virtual time. `req` is the trace's
/// global request id (`u64::MAX` = the shared prefix store, not a
/// request). `a`/`b`/`c` are kind-specific payloads (see [`EventKind`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceRecord {
    pub t0: f64,
    pub t1: f64,
    pub kind: EventKind,
    pub track: u32,
    pub req: u64,
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

/// What a gauge sample measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GaugeKind {
    GpuFreeBlocks,
    HostFreeBlocks,
    DiskFreeBlocks,
    QueueDepth,
    WaitingTokens,
    RunningTokens,
    Slowdown,
    PrefixGpuBlocks,
}

impl GaugeKind {
    pub fn name(&self) -> &'static str {
        match self {
            GaugeKind::GpuFreeBlocks => "gpu_free_blocks",
            GaugeKind::HostFreeBlocks => "host_free_blocks",
            GaugeKind::DiskFreeBlocks => "disk_free_blocks",
            GaugeKind::QueueDepth => "queue_depth",
            GaugeKind::WaitingTokens => "waiting_tokens",
            GaugeKind::RunningTokens => "running_tokens",
            GaugeKind::Slowdown => "slowdown",
            GaugeKind::PrefixGpuBlocks => "prefix_gpu_blocks",
        }
    }
}

/// One gauge sample on one replica's track, in virtual time. Sampled at
/// existing event boundaries (arrivals, horizon services, fault events)
/// — never from new heap events.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GaugeSample {
    pub t: f64,
    pub track: u32,
    pub kind: GaugeKind,
    pub value: f64,
}

/// Fixed-capacity overwrite-oldest ring. Preallocated at construction;
/// `push` never allocates past the first `cap` entries and never grows
/// the buffer, so tracing memory is bounded for arbitrarily long runs.
#[derive(Debug, Clone)]
pub struct Ring<T: Copy> {
    buf: Vec<T>,
    cap: usize,
    /// Index of the oldest entry once the ring has wrapped.
    head: usize,
    /// Entries overwritten (or discarded on a zero-capacity ring).
    dropped: u64,
}

impl<T: Copy> Ring<T> {
    pub fn new(cap: usize) -> Self {
        Ring { buf: Vec::with_capacity(cap), cap, head: 0, dropped: 0 }
    }

    pub fn push(&mut self, x: T) {
        if self.cap == 0 {
            self.dropped += 1;
        } else if self.buf.len() < self.cap {
            self.buf.push(x);
        } else {
            self.buf[self.head] = x;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Entries lost to overwriting; nonzero means the exported trace is
    /// missing its oldest records.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Oldest-to-newest iteration.
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.buf[self.head..].iter().chain(self.buf[..self.head].iter())
    }
}

/// The recorder: span + gauge rings plus the track allocator replicas
/// draw their Perfetto process ids from.
#[derive(Debug, Clone)]
pub struct Tracer {
    spans: Ring<TraceRecord>,
    gauges: Ring<GaugeSample>,
    next_track: u32,
}

impl Tracer {
    pub fn new(span_cap: usize, gauge_cap: usize) -> Self {
        Tracer { spans: Ring::new(span_cap), gauges: Ring::new(gauge_cap), next_track: 0 }
    }

    pub fn record(&mut self, r: TraceRecord) {
        self.spans.push(r);
    }

    pub fn gauge(&mut self, g: GaugeSample) {
        self.gauges.push(g);
    }

    /// Hand out the next track id (one per attached engine, in attach
    /// order — replica i gets track i when a cluster attaches in order).
    pub fn alloc_track(&mut self) -> u32 {
        let t = self.next_track;
        self.next_track += 1;
        t
    }

    pub fn spans(&self) -> impl Iterator<Item = &TraceRecord> {
        self.spans.iter()
    }

    pub fn gauges(&self) -> impl Iterator<Item = &GaugeSample> {
        self.gauges.iter()
    }

    pub fn spans_len(&self) -> usize {
        self.spans.len()
    }

    pub fn gauges_len(&self) -> usize {
        self.gauges.len()
    }

    pub fn span_capacity(&self) -> usize {
        self.spans.capacity()
    }

    pub fn gauge_capacity(&self) -> usize {
        self.gauges.capacity()
    }

    pub fn spans_dropped(&self) -> u64 {
        self.spans.dropped()
    }

    pub fn gauges_dropped(&self) -> u64 {
        self.gauges.dropped()
    }
}

/// Shared, thread-safe handle to one [`Tracer`]. Cloned into every
/// attached engine/cluster; `par_map` experiment cells and server worker
/// threads can all feed one trace.
#[derive(Debug, Clone)]
pub struct TraceHandle(Arc<Mutex<Tracer>>);

impl TraceHandle {
    pub fn new(span_cap: usize, gauge_cap: usize) -> Self {
        TraceHandle(Arc::new(Mutex::new(Tracer::new(span_cap, gauge_cap))))
    }

    pub fn record(&self, r: TraceRecord) {
        self.lock().record(r);
    }

    pub fn gauge(&self, g: GaugeSample) {
        self.lock().gauge(g);
    }

    pub fn alloc_track(&self) -> u32 {
        self.lock().alloc_track()
    }

    /// Direct access (exporters, batched gauge writes). A panicked
    /// recorder thread must not poison everyone else's trace.
    pub fn lock(&self) -> MutexGuard<'_, Tracer> {
        self.0.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// One engine's attachment to a trace: its handle, its track, and the
/// local-id -> global-trace-id binding for requests routed in via
/// `submit` (whose engine-local ids differ from the trace's).
#[derive(Debug, Clone)]
pub struct EngineTrace {
    pub handle: TraceHandle,
    pub track: u32,
    gids: Vec<usize>,
}

impl EngineTrace {
    pub fn attach(handle: TraceHandle) -> Self {
        let track = handle.alloc_track();
        EngineTrace { handle, track, gids: Vec::new() }
    }

    /// Bind engine-local request id -> global trace id.
    pub fn bind(&mut self, local: usize, gid: usize) {
        if self.gids.len() <= local {
            self.gids.resize(local + 1, usize::MAX);
        }
        self.gids[local] = gid;
    }

    /// Global trace id for an engine-local id (falls back to the local
    /// id, which already *is* the trace id on the whole-trace run path).
    pub fn gid(&self, local: usize) -> u64 {
        match self.gids.get(local) {
            Some(&g) if g != usize::MAX => g as u64,
            _ => local as u64,
        }
    }
}

/// Process-global sink: the CLI installs a handle before constructing
/// engines/clusters, which self-attach in their constructors; the CLI
/// exports and clears afterwards. Tests that need isolation bypass the
/// sink entirely via `set_tracer`.
pub mod sink {
    use super::TraceHandle;
    use std::sync::Mutex;

    static SINK: Mutex<Option<TraceHandle>> = Mutex::new(None);

    /// Install a fresh tracer as the process-global sink and return it.
    pub fn install(span_cap: usize, gauge_cap: usize) -> TraceHandle {
        let h = TraceHandle::new(span_cap, gauge_cap);
        *SINK.lock().unwrap_or_else(|e| e.into_inner()) = Some(h.clone());
        h
    }

    /// The currently installed sink, if any (engine constructors call
    /// this; None means tracing is off and costs nothing).
    pub fn current() -> Option<TraceHandle> {
        SINK.lock().unwrap_or_else(|e| e.into_inner()).clone()
    }

    pub fn clear() {
        *SINK.lock().unwrap_or_else(|e| e.into_inner()) = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(t: f64, kind: EventKind, req: u64) -> TraceRecord {
        TraceRecord { t0: t, t1: t, kind, track: 0, req, a: 0, b: 0, c: 0 }
    }

    #[test]
    fn ring_never_exceeds_capacity_and_keeps_newest() {
        let mut r: Ring<u64> = Ring::new(4);
        for i in 0..10u64 {
            r.push(i);
            assert!(r.len() <= 4);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 6);
        let got: Vec<u64> = r.iter().copied().collect();
        assert_eq!(got, vec![6, 7, 8, 9]);
    }

    #[test]
    fn zero_capacity_ring_discards_everything() {
        let mut r: Ring<u64> = Ring::new(0);
        r.push(1);
        r.push(2);
        assert_eq!(r.len(), 0);
        assert_eq!(r.dropped(), 2);
        assert!(r.iter().next().is_none());
    }

    #[test]
    fn tracer_allocates_distinct_tracks() {
        let h = TraceHandle::new(16, 16);
        assert_eq!(h.alloc_track(), 0);
        assert_eq!(h.alloc_track(), 1);
        assert_eq!(h.alloc_track(), 2);
    }

    #[test]
    fn engine_trace_gid_binding_and_fallback() {
        let mut et = EngineTrace::attach(TraceHandle::new(16, 16));
        // unbound locals fall back to themselves (whole-trace run path)
        assert_eq!(et.gid(3), 3);
        et.bind(0, 41);
        et.bind(2, 7);
        assert_eq!(et.gid(0), 41);
        assert_eq!(et.gid(1), 1); // gap stays fallback
        assert_eq!(et.gid(2), 7);
        // the PREFIX_REQ sentinel passes through as u64::MAX
        assert_eq!(et.gid(usize::MAX), u64::MAX);
    }

    #[test]
    fn records_iterate_oldest_to_newest() {
        let h = TraceHandle::new(3, 3);
        for i in 0..5 {
            h.record(rec(i as f64, EventKind::Decode, i));
        }
        let t = h.lock();
        let reqs: Vec<u64> = t.spans().map(|r| r.req).collect();
        assert_eq!(reqs, vec![2, 3, 4]);
        assert_eq!(t.spans_dropped(), 2);
        assert_eq!(t.span_capacity(), 3);
    }

    #[test]
    fn sink_install_current_clear() {
        // serialized against nothing: tests in this module are the only
        // sink users in the unit suite
        sink::clear();
        assert!(sink::current().is_none());
        let h = sink::install(8, 8);
        let c = sink::current().expect("installed");
        c.record(rec(0.0, EventKind::Arrive, 0));
        assert_eq!(h.lock().spans_len(), 1);
        sink::clear();
        assert!(sink::current().is_none());
    }

    #[test]
    fn kind_taxonomy() {
        assert!(EventKind::Decode.is_span());
        assert!(!EventKind::Finish.is_span());
        assert!(EventKind::Finish.is_terminal());
        assert!(EventKind::Drop.is_terminal());
        assert!(EventKind::Failed.is_terminal());
        assert!(!EventKind::Arrive.is_terminal());
        assert_eq!(EventKind::Prefill.lane(), 2);
        assert_eq!(EventKind::Fault.lane(), 0);
        assert!(!EventKind::Checkpoint.is_span());
        assert!(!EventKind::Adopt.is_span());
        assert!(!EventKind::Adopt.is_terminal());
        assert_eq!(EventKind::Checkpoint.name(), "checkpoint");
        assert_eq!(EventKind::Adopt.lane(), 0);
        assert_eq!(fault_name(FAULT_CRASH), "crash");
        assert_eq!(fault_name(FAULT_MIGRATE), "migrate");
        assert_eq!(fault_name(99), "unknown");
    }
}
