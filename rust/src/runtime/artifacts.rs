//! Artifact loading: the manifest + weights `make artifacts` produced.
//!
//! The build contract with python/compile/aot.py:
//! * `manifest.json` — model config, ordered weight table, executable index;
//! * `weights.bin` — f32 LE, concatenated in the manifest's entry order
//!   (== jax's sorted-dict flatten order);
//! * `*.hlo.txt` — HLO text per executable (text, never serialized proto:
//!   xla_extension 0.5.1 rejects jax>=0.5's 64-bit instruction ids).

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::Json;

/// Mirror of python ModelConfig.
#[derive(Debug, Clone, PartialEq)]
pub struct TinyModelConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ffn_hidden: usize,
    pub max_seq: usize,
}

/// One weight tensor's slot in weights.bin.
#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize, // elements, not bytes
}

impl WeightEntry {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled program.
#[derive(Debug, Clone, PartialEq)]
pub enum ExecutableKind {
    Prefill { seq_len: usize },
    Decode { batch: usize, max_seq: usize },
    PagedAttn,
}

#[derive(Debug, Clone)]
pub struct ExecutableEntry {
    pub kind: ExecutableKind,
    pub path: PathBuf,
}

/// Parsed artifact bundle.
#[derive(Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    pub model: TinyModelConfig,
    pub weights: Vec<WeightEntry>,
    pub weight_data: Vec<f32>,
    pub executables: Vec<ExecutableEntry>,
}

impl Artifacts {
    /// Load and validate `dir/manifest.json` + `dir/weights.bin`.
    pub fn load(dir: &Path) -> Result<Artifacts> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;

        let m = j.req("model")?;
        let get = |k: &str| -> Result<usize> {
            Ok(m.req(k)?.as_usize().context("not a number")?)
        };
        let model = TinyModelConfig {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            n_kv_heads: get("n_kv_heads")?,
            head_dim: get("head_dim")?,
            ffn_hidden: get("ffn_hidden")?,
            max_seq: get("max_seq")?,
        };

        let mut weights = Vec::new();
        let mut offset = 0usize;
        for e in j.req("weights")?.req("entries")?.as_arr().context("entries")? {
            let name = e.req("name")?.as_str().context("name")?.to_string();
            let shape: Vec<usize> = e
                .req("shape")?
                .as_arr()
                .context("shape")?
                .iter()
                .map(|x| x.as_usize().unwrap_or(0))
                .collect();
            let w = WeightEntry { name, shape, offset };
            offset += w.numel();
            weights.push(w);
        }

        let weights_file = dir.join(
            j.req("weights")?.req("file")?.as_str().context("weights file")?,
        );
        let raw = std::fs::read(&weights_file)
            .with_context(|| format!("reading {}", weights_file.display()))?;
        if raw.len() != offset * 4 {
            bail!(
                "weights.bin is {} bytes; manifest expects {} f32s ({} bytes)",
                raw.len(),
                offset,
                offset * 4
            );
        }
        let weight_data: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();

        let mut executables = Vec::new();
        for e in j.req("executables")?.as_arr().context("executables")? {
            let kind_s = e.req("kind")?.as_str().context("kind")?;
            let path = dir.join(e.req("path")?.as_str().context("path")?);
            if !path.exists() {
                bail!("missing artifact {}", path.display());
            }
            let kind = match kind_s {
                "prefill" => ExecutableKind::Prefill {
                    seq_len: e.req("seq_len")?.as_usize().context("seq_len")?,
                },
                "decode" => ExecutableKind::Decode {
                    batch: e.req("batch")?.as_usize().context("batch")?,
                    max_seq: e.req("max_seq")?.as_usize().context("max_seq")?,
                },
                "paged_attn" => ExecutableKind::PagedAttn,
                other => bail!("unknown executable kind '{other}'"),
            };
            executables.push(ExecutableEntry { kind, path });
        }

        Ok(Artifacts { dir: dir.to_path_buf(), model, weights, weight_data, executables })
    }

    /// Slice of one weight's data.
    pub fn weight(&self, entry: &WeightEntry) -> &[f32] {
        &self.weight_data[entry.offset..entry.offset + entry.numel()]
    }

    pub fn prefill_buckets(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .executables
            .iter()
            .filter_map(|e| match e.kind {
                ExecutableKind::Prefill { seq_len } => Some(seq_len),
                _ => None,
            })
            .collect();
        v.sort_unstable();
        v
    }

    pub fn decode_batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .executables
            .iter()
            .filter_map(|e| match e.kind {
                ExecutableKind::Decode { batch, .. } => Some(batch),
                _ => None,
            })
            .collect();
        v.sort_unstable();
        v
    }

    /// Smallest prefill bucket >= len.
    pub fn prefill_bucket_for(&self, len: usize) -> Option<usize> {
        self.prefill_buckets().into_iter().find(|&b| b >= len)
    }

    /// Smallest decode batch bucket >= n.
    pub fn decode_bucket_for(&self, n: usize) -> Option<usize> {
        self.decode_batches().into_iter().find(|&b| b >= n)
    }
}

/// Default artifact location: `$LAYERKV_ARTIFACTS` or `./artifacts`.
pub fn default_dir() -> PathBuf {
    std::env::var("LAYERKV_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> Option<PathBuf> {
        let d = default_dir();
        d.join("manifest.json").exists().then_some(d)
    }

    #[test]
    fn loads_real_manifest() {
        let Some(dir) = have_artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let a = Artifacts::load(&dir).unwrap();
        assert_eq!(a.model.n_layers, 4);
        assert_eq!(a.model.vocab, 256);
        assert!(!a.prefill_buckets().is_empty());
        assert!(!a.decode_batches().is_empty());
        // weights table is dense and ordered
        let total: usize = a.weights.iter().map(|w| w.numel()).sum();
        assert_eq!(total, a.weight_data.len());
        let names: Vec<&str> = a.weights.iter().map(|w| w.name.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        assert_eq!(names, sorted, "weights must be in sorted (jax flatten) order");
    }

    #[test]
    fn bucket_selection() {
        let Some(dir) = have_artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let a = Artifacts::load(&dir).unwrap();
        assert_eq!(a.prefill_bucket_for(1), Some(16));
        assert_eq!(a.prefill_bucket_for(17), Some(32));
        assert_eq!(a.prefill_bucket_for(10_000), None);
        assert_eq!(a.decode_bucket_for(3), Some(4));
    }

    #[test]
    fn rejects_missing_dir() {
        assert!(Artifacts::load(Path::new("/nonexistent-xyz")).is_err());
    }
}
