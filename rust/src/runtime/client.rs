//! PJRT runtime: load the AOT HLO-text artifacts, compile them once on the
//! CPU client, keep weights resident as device buffers, and expose typed
//! prefill/decode calls. Adapted from /opt/xla-example/load_hlo.
//!
//! This is the only module that touches the `xla` crate; everything above
//! works with plain `Vec<f32>` tensors.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::artifacts::{Artifacts, ExecutableKind};

/// Per-layer KV tensor from a prefill: `[2, KH, T, D]` row-major, with `T`
/// trimmed to the true prompt length.
#[derive(Debug, Clone)]
pub struct LayerKv {
    pub data: Vec<f32>,
    pub kh: usize,
    pub t: usize,
    pub d: usize,
}

impl LayerKv {
    pub fn numel(&self) -> usize {
        2 * self.kh * self.t * self.d
    }

    pub fn bytes(&self) -> usize {
        self.numel() * 4
    }
}

/// Result of one prefill call.
#[derive(Debug)]
pub struct PrefillOut {
    /// Logits at the last true prompt position, `[vocab]`.
    pub logits: Vec<f32>,
    /// Per-layer KV, trimmed to the prompt length.
    pub kv: Vec<LayerKv>,
    /// The bucket the call actually executed (>= prompt length).
    pub bucket: usize,
}

/// Result of one batched decode call.
#[derive(Debug)]
pub struct DecodeOut {
    /// `[batch, vocab]` row-major (only the first `n_real` rows meaningful).
    pub logits: Vec<f32>,
    pub batch: usize,
}

/// The compiled tiny model: weights resident on the PJRT device, one
/// executable per prefill bucket and per decode batch size.
pub struct TinyModel {
    client: xla::PjRtClient,
    pub art: Artifacts,
    weight_bufs: Vec<xla::PjRtBuffer>,
    prefill_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    decode_exes: BTreeMap<usize, xla::PjRtLoadedExecutable>,
    paged_exe: Option<xla::PjRtLoadedExecutable>,
}

impl TinyModel {
    /// Load artifacts from `dir`, compile every executable, upload weights.
    pub fn load(dir: &Path) -> Result<TinyModel> {
        let art = Artifacts::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;

        let mut weight_bufs = Vec::with_capacity(art.weights.len());
        for w in &art.weights {
            let data = art.weight(w);
            let buf = client
                .buffer_from_host_buffer::<f32>(data, &w.shape, None)
                .with_context(|| format!("uploading weight {}", w.name))?;
            weight_bufs.push(buf);
        }

        let mut prefill_exes = BTreeMap::new();
        let mut decode_exes = BTreeMap::new();
        let mut paged_exe = None;
        for e in &art.executables {
            let proto = xla::HloModuleProto::from_text_file(
                e.path.to_str().context("non-utf8 path")?,
            )
            .map_err(|err| anyhow::anyhow!("parsing {}: {err}", e.path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|err| anyhow::anyhow!("compiling {}: {err}", e.path.display()))?;
            match e.kind {
                ExecutableKind::Prefill { seq_len } => {
                    prefill_exes.insert(seq_len, exe);
                }
                ExecutableKind::Decode { batch, .. } => {
                    decode_exes.insert(batch, exe);
                }
                ExecutableKind::PagedAttn => paged_exe = Some(exe),
            }
        }
        if prefill_exes.is_empty() || decode_exes.is_empty() {
            bail!("artifact bundle lacks prefill/decode executables");
        }
        Ok(TinyModel { client, art, weight_bufs, prefill_exes, decode_exes, paged_exe })
    }

    pub fn n_layers(&self) -> usize {
        self.art.model.n_layers
    }

    pub fn max_seq(&self) -> usize {
        self.art.model.max_seq
    }

    pub fn has_paged_kernel(&self) -> bool {
        self.paged_exe.is_some()
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<i32>(data, dims, None)?)
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer::<f32>(data, dims, None)?)
    }

    /// Run a prefill over `tokens` (length <= max bucket). Pads up to the
    /// smallest bucket; trims KV back to the true length.
    pub fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let t_true = tokens.len();
        let bucket = self
            .art
            .prefill_bucket_for(t_true)
            .with_context(|| format!("prompt of {t_true} tokens exceeds all buckets"))?;
        let exe = &self.prefill_exes[&bucket];
        let mut padded = tokens.to_vec();
        padded.resize(bucket, 0);

        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        let tok_buf = self.buf_i32(&padded, &[bucket])?;
        args.push(&tok_buf);

        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let outs = result[0][0].to_literal_sync()?.to_tuple()?;
        let m = &self.art.model;
        anyhow::ensure!(outs.len() == 1 + m.n_layers, "unexpected output arity");

        // logits [bucket, vocab] -> row at t_true-1
        let logits_all = outs[0].to_vec::<f32>()?;
        let logits =
            logits_all[(t_true - 1) * m.vocab..t_true * m.vocab].to_vec();

        // kv_i [2, KH, bucket, D] -> trim T axis to t_true
        let mut kv = Vec::with_capacity(m.n_layers);
        for out in &outs[1..] {
            let full = out.to_vec::<f32>()?;
            let (kh, d) = (m.n_kv_heads, m.head_dim);
            let mut data = Vec::with_capacity(2 * kh * t_true * d);
            for c in 0..2 {
                for h in 0..kh {
                    let base = (c * kh + h) * bucket * d;
                    data.extend_from_slice(&full[base..base + t_true * d]);
                }
            }
            kv.push(LayerKv { data, kh, t: t_true, d });
        }
        Ok(PrefillOut { logits, kv, bucket })
    }

    /// One batched decode step.
    ///
    /// * `tokens[i]`, `lens[i]` — next input token and current cache length
    ///   of lane `i`;
    /// * `kvs[layer]` — `[B, 2, KH, Smax, D]` row-major scratch the caller
    ///   owns; the new token's KV is written back into it at `lens[i]`.
    ///
    /// Lanes beyond the real count must have `lens = 0` and token 0.
    pub fn decode(
        &self,
        tokens: &[i32],
        lens: &[i32],
        kvs: &mut [Vec<f32>],
    ) -> Result<DecodeOut> {
        let b = tokens.len();
        anyhow::ensure!(lens.len() == b, "tokens/lens length mismatch");
        anyhow::ensure!(
            self.decode_exes.contains_key(&b),
            "no decode executable for batch {b} (buckets: {:?})",
            self.art.decode_batches()
        );
        let m = &self.art.model;
        let per_layer = b * 2 * m.n_kv_heads * m.max_seq * m.head_dim;
        anyhow::ensure!(kvs.len() == m.n_layers, "kv layer count");
        for kv in kvs.iter() {
            anyhow::ensure!(kv.len() == per_layer, "kv lane size");
        }

        let exe = &self.decode_exes[&b];
        let tok_buf = self.buf_i32(tokens, &[b])?;
        let len_buf = self.buf_i32(lens, &[b])?;
        let kv_dims = [b, 2, m.n_kv_heads, m.max_seq, m.head_dim];
        let mut kv_bufs = Vec::with_capacity(kvs.len());
        for kv in kvs.iter() {
            kv_bufs.push(self.buf_f32(kv, &kv_dims)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&len_buf);
        for kb in &kv_bufs {
            args.push(kb);
        }

        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let outs = result[0][0].to_literal_sync()?.to_tuple()?;
        anyhow::ensure!(outs.len() == 1 + m.n_layers, "unexpected output arity");
        let logits = outs[0].to_vec::<f32>()?;
        for (kv, out) in kvs.iter_mut().zip(&outs[1..]) {
            *kv = out.to_vec::<f32>()?;
        }
        Ok(DecodeOut { logits, batch: b })
    }

    /// Run the standalone paged-attention kernel artifact (perf target).
    /// Shapes are fixed by the manifest's PAGED_SHAPE.
    pub fn paged_attn(
        &self,
        q: &[f32],
        q_dims: &[usize],
        pages: &[f32],
        pages_dims: &[usize],
        table: &[i32],
        table_dims: &[usize],
        lens: &[i32],
    ) -> Result<Vec<f32>> {
        let exe = self.paged_exe.as_ref().context("no paged_attn artifact")?;
        let qb = self.buf_f32(q, q_dims)?;
        let pb = self.buf_f32(pages, pages_dims)?;
        let tb = self.buf_i32(table, table_dims)?;
        let lb = self.buf_i32(lens, &[lens.len()])?;
        let args = [&qb, &pb, &tb, &lb];
        let result = exe.execute_b::<&xla::PjRtBuffer>(&args)?;
        let outs = result[0][0].to_literal_sync()?.to_tuple()?;
        Ok(outs[0].to_vec::<f32>()?)
    }
}

/// What `PjrtBackend` needs from an executor: bucketed prefill and
/// batched decode over dense per-layer KV tensors. Implemented by
/// [`TinyModel`] (the compiled-HLO PJRT path) and by
/// `runtime::RefModel` (a deterministic in-process stand-in that lets
/// the real serving path run — and be tested — without PJRT artifacts).
pub trait TokenModel {
    /// Model geometry (layer count, heads, max sequence, vocab).
    fn spec(&self) -> &super::artifacts::TinyModelConfig;

    /// Smallest compiled prefill bucket that fits `len` tokens.
    fn prefill_bucket_for(&self, len: usize) -> Option<usize>;

    /// Smallest compiled decode batch that fits `lanes` lanes.
    fn decode_bucket_for(&self, lanes: usize) -> Option<usize>;

    /// Largest prompt any compiled prefill bucket can run. A recompute
    /// re-prefill replays prompt + generated-so-far, so the serving
    /// wrapper caps generation lengths against this too.
    fn max_prefill_len(&self) -> usize;

    /// Largest decode batch available.
    fn max_decode_batch(&self) -> usize;

    /// Run a prefill; returns last-position logits + trimmed per-layer KV.
    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut>;

    /// One batched decode step over caller-owned `[B, 2, KH, Smax, D]`
    /// scratch; the new token's KV row is written back at each lane's
    /// current length.
    fn decode(&self, tokens: &[i32], lens: &[i32], kvs: &mut [Vec<f32>]) -> Result<DecodeOut>;
}

impl TokenModel for TinyModel {
    fn spec(&self) -> &super::artifacts::TinyModelConfig {
        &self.art.model
    }

    fn prefill_bucket_for(&self, len: usize) -> Option<usize> {
        self.art.prefill_bucket_for(len)
    }

    fn decode_bucket_for(&self, lanes: usize) -> Option<usize> {
        self.art.decode_bucket_for(lanes)
    }

    fn max_prefill_len(&self) -> usize {
        self.art.prefill_buckets().last().copied().unwrap_or(0)
    }

    fn max_decode_batch(&self) -> usize {
        self.art.decode_batches().last().copied().unwrap_or(1)
    }

    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        TinyModel::prefill(self, tokens)
    }

    fn decode(&self, tokens: &[i32], lens: &[i32], kvs: &mut [Vec<f32>]) -> Result<DecodeOut> {
        TinyModel::decode(self, tokens, lens, kvs)
    }
}

/// Greedy (argmax) sampling over one logits row.
pub fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &x) in logits.iter().enumerate() {
        if x > logits[best] {
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_max() {
        assert_eq!(argmax(&[0.1, 3.0, -1.0, 2.9]), 1);
        assert_eq!(argmax(&[5.0]), 0);
    }
}
