//! Layer-wise KV store for the real PJRT serving path (S7 in DESIGN.md).
//!
//! Holds every live request's per-layer KV tensors and tracks which tier
//! each layer sits in: the bounded "device" pool, the host pool, or —
//! when a spill directory is configured — real spill files on disk. On
//! the CPU-only testbed the first two pools are host RAM, but the copies
//! (and the disk-tier file I/O) are real and the byte accounting mirrors
//! what a CUDA build would push over the interconnect and NVMe — the
//! policy layer (what to offload/spill, when to restore) is identical to
//! the simulator's.

use std::collections::HashMap;
use std::path::PathBuf;

use crate::coordinator::request::ReqId;

use super::client::LayerKv;

#[derive(Debug, Clone, Default)]
pub struct KvStoreStats {
    pub offloads: u64,
    pub onloads: u64,
    pub offload_bytes: u64,
    pub onload_bytes: u64,
    /// Host -> disk spill file writes.
    pub spills: u64,
    /// Disk -> host restores (file read + delete).
    pub unspills: u64,
    pub spill_bytes: u64,
    pub unspill_bytes: u64,
    /// Bytes read from spill files by decode-path streaming (the layer
    /// stayed on disk).
    pub disk_read_bytes: u64,
    /// Disk-tier I/O failures: spill writes and restore/stream reads that
    /// errored. Spill/restore failures also propagate to the caller as
    /// `Err`; the decode-path streaming reads additionally fall back to
    /// zeroed history (see `append_row`/`fill_scratch`) but still count
    /// here so the degradation is observable.
    pub io_errors: u64,
}

#[derive(Debug)]
struct StoredLayer {
    kv: LayerKv,
    on_device: bool,
    /// When Some, the layer's data lives in this spill file and
    /// `kv.data` is empty (the kh/t/d metadata stays authoritative, so
    /// `kv.bytes()` still reports the true tensor size).
    spill_path: Option<PathBuf>,
}

/// Byte-budgeted tiered KV store (device / host / spill files).
#[derive(Debug)]
pub struct KvStore {
    device_budget: usize,
    device_used: usize,
    host_used: usize,
    disk_used: usize,
    /// Directory for spill files; None disables the disk tier.
    spill_dir: Option<PathBuf>,
    entries: HashMap<ReqId, Vec<StoredLayer>>,
    pub stats: KvStoreStats,
}

impl KvStore {
    pub fn new(device_budget_bytes: usize) -> Self {
        KvStore {
            device_budget: device_budget_bytes,
            device_used: 0,
            host_used: 0,
            disk_used: 0,
            spill_dir: None,
            entries: HashMap::new(),
            stats: KvStoreStats::default(),
        }
    }

    /// Enable the disk tier: spilled layers are written as files under
    /// `dir`, created here once so the spill hot path is a single write.
    pub fn with_spill_dir(device_budget_bytes: usize, dir: PathBuf) -> Self {
        let mut s = Self::new(device_budget_bytes);
        std::fs::create_dir_all(&dir).ok(); // spills fail gracefully if this did
        s.spill_dir = Some(dir);
        s
    }

    pub fn device_used(&self) -> usize {
        self.device_used
    }

    pub fn host_used(&self) -> usize {
        self.host_used
    }

    pub fn disk_used(&self) -> usize {
        self.disk_used
    }

    pub fn contains(&self, req: ReqId) -> bool {
        self.entries.contains_key(&req)
    }

    /// Store a prefill's KV. Layers in `retained` go to the device pool
    /// (if the budget allows), the rest to the host pool — the offload
    /// traffic a GPU build would overlap with the prefill itself.
    /// (Layers the coordinator admitted straight to the disk tier are
    /// spilled right after via `spill_layer`.)
    pub fn insert(&mut self, req: ReqId, kv: Vec<LayerKv>, retained: &[usize]) {
        let mut layers = Vec::with_capacity(kv.len());
        for (i, layer) in kv.into_iter().enumerate() {
            let bytes = layer.bytes();
            let want_device = retained.contains(&i);
            let on_device = want_device && self.device_used + bytes <= self.device_budget;
            if on_device {
                self.device_used += bytes;
            } else {
                self.host_used += bytes;
                self.stats.offloads += 1;
                self.stats.offload_bytes += bytes as u64;
            }
            layers.push(StoredLayer { kv: layer, on_device, spill_path: None });
        }
        let prev = self.entries.insert(req, layers);
        debug_assert!(prev.is_none(), "request {req} inserted twice");
    }

    /// Layers of `req` currently on the host (not device, not spilled).
    pub fn host_layers(&self, req: ReqId) -> Vec<usize> {
        self.entries
            .get(&req)
            .map(|ls| {
                ls.iter()
                    .enumerate()
                    .filter(|(_, l)| !l.on_device && l.spill_path.is_none())
                    .map(|(i, _)| i)
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Layers of `req` currently in spill files.
    pub fn disk_layers(&self, req: ReqId) -> Vec<usize> {
        self.entries
            .get(&req)
            .map(|ls| {
                ls.iter()
                    .enumerate()
                    .filter(|(_, l)| l.spill_path.is_some())
                    .map(|(i, _)| i)
                    .collect()
            })
            .unwrap_or_default()
    }

    pub fn fully_resident(&self, req: ReqId) -> bool {
        self.entries.get(&req).map(|ls| ls.iter().all(|l| l.on_device)).unwrap_or(false)
    }

    /// Move one layer device -> host. Returns bytes moved.
    pub fn offload_layer(&mut self, req: ReqId, layer: usize) -> usize {
        let Some(ls) = self.entries.get_mut(&req) else { return 0 };
        let l = &mut ls[layer];
        if !l.on_device {
            return 0;
        }
        let bytes = l.kv.bytes();
        l.on_device = false;
        self.device_used -= bytes;
        self.host_used += bytes;
        self.stats.offloads += 1;
        self.stats.offload_bytes += bytes as u64;
        bytes
    }

    /// Move one layer host -> device if the budget allows. Returns bytes.
    /// Spilled layers do not onload directly — restore them with
    /// `unspill_layer` first (or both at once via `promote_layer`).
    pub fn onload_layer(&mut self, req: ReqId, layer: usize) -> usize {
        let Some(ls) = self.entries.get_mut(&req) else { return 0 };
        let l = &mut ls[layer];
        if l.on_device || l.spill_path.is_some() {
            return 0;
        }
        let bytes = l.kv.bytes();
        if self.device_used + bytes > self.device_budget {
            return 0;
        }
        l.on_device = true;
        self.device_used += bytes;
        self.host_used -= bytes;
        self.stats.onloads += 1;
        self.stats.onload_bytes += bytes as u64;
        bytes
    }

    /// Spill one host layer to a real file under the spill directory and
    /// free its host copy. Returns bytes written — `Ok(0)` when the layer
    /// is on the device, already spilled, or the tier is disabled — and
    /// `Err` when the file write failed (the layer stays host-resident
    /// and the failure counts toward `stats.io_errors`).
    pub fn spill_layer(&mut self, req: ReqId, layer: usize) -> std::io::Result<usize> {
        let Some(dir) = self.spill_dir.as_ref() else { return Ok(0) };
        let path = dir.join(format!("kv_r{req}_l{layer}.bin"));
        let Some(ls) = self.entries.get_mut(&req) else { return Ok(0) };
        let l = &mut ls[layer];
        if l.on_device || l.spill_path.is_some() {
            return Ok(0);
        }
        if let Err(e) = write_f32_file(&path, &l.kv.data) {
            self.stats.io_errors += 1;
            return Err(e);
        }
        let bytes = l.kv.bytes();
        l.kv.data = Vec::new(); // host copy freed; metadata stays
        l.spill_path = Some(path);
        self.host_used -= bytes;
        self.disk_used += bytes;
        self.stats.spills += 1;
        self.stats.spill_bytes += bytes as u64;
        Ok(bytes)
    }

    /// Restore one spilled layer back to the host pool (read + delete the
    /// spill file). Returns bytes read — `Ok(0)` when the layer is not
    /// spilled — and `Err` when the spill file is unreadable or truncated
    /// (the layer stays on disk and the failure counts toward
    /// `stats.io_errors`).
    pub fn unspill_layer(&mut self, req: ReqId, layer: usize) -> std::io::Result<usize> {
        let Some(ls) = self.entries.get_mut(&req) else { return Ok(0) };
        let l = &mut ls[layer];
        let Some(path) = l.spill_path.clone() else { return Ok(0) };
        let Some(data) = read_f32_file(&path, l.kv.numel()) else {
            self.stats.io_errors += 1;
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("spill file unreadable or truncated: {}", path.display()),
            ));
        };
        std::fs::remove_file(&path).ok();
        l.kv.data = data;
        l.spill_path = None;
        let bytes = l.kv.bytes();
        self.disk_used -= bytes;
        self.host_used += bytes;
        self.stats.unspills += 1;
        self.stats.unspill_bytes += bytes as u64;
        Ok(bytes)
    }

    /// Deep restore: disk -> host -> device in one call (mirrors the
    /// coordinator's `promote_disk_layer`). Returns bytes moved to the
    /// device — `Ok(0)` if either leg declined (the layer may
    /// legitimately end up host-resident when the device budget is
    /// full) — and `Err` when the unspill read failed.
    pub fn promote_layer(&mut self, req: ReqId, layer: usize) -> std::io::Result<usize> {
        if self.unspill_layer(req, layer)? == 0 {
            return Ok(0);
        }
        Ok(self.onload_layer(req, layer))
    }

    /// Append one committed token's KV to every layer of `req`.
    /// `rows[layer]` is the `[2, KH, D]` row (c-major, then head, then
    /// dim) the decode step produced for the tail position. This is the
    /// engine-confirmed half of the decode step: rows for tokens the
    /// coordinator rejected (block-pool OOM) are simply never appended
    /// and get recomputed next step.
    pub fn append_row(&mut self, req: ReqId, rows: &[Vec<f32>]) {
        let Some(ls) = self.entries.get_mut(&req) else { return };
        debug_assert_eq!(ls.len(), rows.len(), "row per layer");
        let mut disk_read = 0u64;
        let mut disk_grown = 0usize;
        let mut disk_unspilled = 0usize;
        let mut host_grown = 0usize;
        let mut io_errs = 0u64;
        for (layer, row) in ls.iter_mut().zip(rows.iter()) {
            let kv = &mut layer.kv;
            let (kh, d) = (kv.kh, kv.d);
            debug_assert_eq!(row.len(), 2 * kh * d);
            // spilled layers grow via read-modify-write of their spill
            // file — slow by design, this is the disk tier's
            // forced-progress path. A failed read means the file is gone
            // or corrupt: the history is unrecoverable, so fall through
            // with zeroed history rather than desynchronizing this
            // layer's token count from its siblings (the token was
            // already committed by the coordinator; fill_scratch would
            // otherwise serve a truncated cache forever).
            let data: Vec<f32> = match &layer.spill_path {
                Some(path) => match read_f32_file(path, 2 * kh * kv.t * d) {
                    Some(v) => {
                        disk_read += (v.len() * 4) as u64;
                        v
                    }
                    None => {
                        io_errs += 1;
                        vec![0.0; 2 * kh * kv.t * d]
                    }
                },
                None => std::mem::take(&mut kv.data),
            };
            // grow [2, KH, T, D] -> [2, KH, T+1, D]
            let mut out = Vec::with_capacity(2 * kh * (kv.t + 1) * d);
            for c in 0..2 {
                for h in 0..kh {
                    let old = (c * kh + h) * kv.t * d;
                    out.extend_from_slice(&data[old..old + kv.t * d]);
                    let src = (c * kh + h) * d;
                    out.extend_from_slice(&row[src..src + d]);
                }
            }
            let grown = (out.len() - data.len()) as u64; // 2*KH*D floats
            let grown_bytes = (grown * 4) as usize;
            if let Some(path) = layer.spill_path.clone() {
                if write_f32_file(&path, &out).is_ok() {
                    kv.t += 1;
                    disk_grown += grown_bytes;
                } else {
                    // the rewrite failed: keep the grown tensor as a host
                    // copy instead of desynchronizing this layer's token
                    // count from its siblings (the token was already
                    // committed by the coordinator). The old spill file is
                    // stale — remove it.
                    io_errs += 1;
                    std::fs::remove_file(&path).ok();
                    let old_bytes = kv.bytes();
                    kv.data = out;
                    kv.t += 1;
                    layer.spill_path = None;
                    disk_unspilled += old_bytes;
                    host_grown += old_bytes + grown_bytes;
                }
            } else {
                kv.data = out;
                kv.t += 1;
                if layer.on_device {
                    self.device_used += grown_bytes;
                } else {
                    self.host_used += grown_bytes;
                }
            }
        }
        self.disk_used += disk_grown;
        self.disk_used -= disk_unspilled;
        self.host_used += host_grown;
        self.stats.disk_read_bytes += disk_read;
        self.stats.io_errors += io_errs;
    }

    /// Fill lane `lane` of the dense scratch from the store (any residency;
    /// host reads count as onload stream bytes).
    pub fn fill_scratch(
        &mut self,
        req: ReqId,
        scratch: &mut [Vec<f32>],
        lane: usize,
        _b: usize,
        smax: usize,
    ) -> usize {
        let Some(ls) = self.entries.get(&req) else { return 0 };
        let mut streamed = 0usize;
        let mut disk_read = 0u64;
        let mut io_errs = 0u64;
        for (layer, s) in ls.iter().zip(scratch.iter_mut()) {
            let kv = &layer.kv;
            let (kh, d, t) = (kv.kh, kv.d, kv.t);
            // spilled layers stream straight from their file (the layer
            // stays on disk; this is the forced-progress read path). A
            // failed read serves zeroed history — never the stale bytes
            // of whatever occupied this scratch lane last step (the same
            // policy append_row applies to the same fault).
            let file_data: Option<Vec<f32>> = match &layer.spill_path {
                Some(path) => match read_f32_file(path, 2 * kh * t * d) {
                    Some(v) => {
                        disk_read += (v.len() * 4) as u64;
                        Some(v)
                    }
                    None => {
                        io_errs += 1;
                        Some(vec![0.0; 2 * kh * t * d])
                    }
                },
                None => None,
            };
            let data: &[f32] = file_data.as_deref().unwrap_or(&kv.data);
            for c in 0..2 {
                for h in 0..kh {
                    let src = (c * kh + h) * t * d;
                    let dst = (((lane * 2 + c) * kh + h) * smax) * d;
                    s[dst..dst + t * d].copy_from_slice(&data[src..src + t * d]);
                }
            }
            if !layer.on_device {
                streamed += kv.bytes();
            }
        }
        if streamed > 0 {
            self.stats.onload_bytes += streamed as u64;
        }
        self.stats.disk_read_bytes += disk_read;
        self.stats.io_errors += io_errs;
        streamed
    }

    pub fn tokens(&self, req: ReqId) -> usize {
        self.entries.get(&req).and_then(|ls| ls.first()).map(|l| l.kv.t).unwrap_or(0)
    }

    pub fn release(&mut self, req: ReqId) {
        if let Some(ls) = self.entries.remove(&req) {
            for l in ls {
                if let Some(path) = &l.spill_path {
                    std::fs::remove_file(path).ok();
                    self.disk_used -= l.kv.bytes();
                } else if l.on_device {
                    self.device_used -= l.kv.bytes();
                } else {
                    self.host_used -= l.kv.bytes();
                }
            }
        }
    }
}

/// Write f32s as LE bytes — the one producer of the spill-file format
/// `read_f32_file` consumes.
fn write_f32_file(path: &std::path::Path, data: &[f32]) -> std::io::Result<()> {
    let mut buf = Vec::with_capacity(data.len() * 4);
    for v in data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path, &buf)
}

/// Read a spill file back as f32 LE; None on I/O error or size mismatch.
fn read_f32_file(path: &std::path::Path, numel: usize) -> Option<Vec<f32>> {
    let bytes = std::fs::read(path).ok()?;
    if bytes.len() != numel * 4 {
        return None;
    }
    let mut out = Vec::with_capacity(numel);
    for chunk in bytes.chunks_exact(4) {
        out.push(f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
    }
    Some(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(t: usize) -> LayerKv {
        LayerKv { data: vec![1.0; 2 * 2 * t * 4], kh: 2, t, d: 4 }
    }

    fn four_layers(t: usize) -> Vec<LayerKv> {
        (0..4).map(|_| kv(t)).collect()
    }

    #[test]
    fn insert_respects_budget_and_retained() {
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::new(2 * layer_bytes);
        s.insert(0, four_layers(8), &[1, 3]);
        assert_eq!(s.device_used(), 2 * layer_bytes);
        assert_eq!(s.host_layers(0), vec![0, 2]);
        assert!(!s.fully_resident(0));
        assert_eq!(s.stats.offloads, 2);
    }

    #[test]
    fn budget_overflow_spills_to_host() {
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::new(layer_bytes); // room for one layer only
        s.insert(0, four_layers(8), &[0, 1, 2, 3]);
        assert_eq!(s.device_used(), layer_bytes);
        assert_eq!(s.host_layers(0).len(), 3);
    }

    #[test]
    fn offload_onload_roundtrip() {
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::new(4 * layer_bytes);
        s.insert(0, four_layers(8), &[0, 1, 2, 3]);
        assert!(s.fully_resident(0));
        assert_eq!(s.offload_layer(0, 2), layer_bytes);
        assert_eq!(s.host_layers(0), vec![2]);
        assert_eq!(s.onload_layer(0, 2), layer_bytes);
        assert!(s.fully_resident(0));
        // idempotent
        assert_eq!(s.onload_layer(0, 2), 0);
    }

    #[test]
    fn scratch_roundtrip_appends() {
        let (b, smax, kh, d) = (2usize, 16usize, 2usize, 4usize);
        let mut s = KvStore::new(usize::MAX);
        s.insert(7, four_layers(3), &[0, 1, 2, 3]);
        let mut scratch: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; b * 2 * kh * smax * d]).collect();
        let streamed = s.fill_scratch(7, &mut scratch, 1, b, smax);
        assert_eq!(streamed, 0); // resident
        // append the row the model would have written at pos 3 of lane 1
        let rows: Vec<Vec<f32>> = (0..4).map(|_| vec![9.0f32; 2 * kh * d]).collect();
        s.append_row(7, &rows);
        assert_eq!(s.tokens(7), 4);
        // re-fill and check the appended row is there
        let mut scratch2: Vec<Vec<f32>> =
            (0..4).map(|_| vec![0.0; b * 2 * kh * smax * d]).collect();
        s.fill_scratch(7, &mut scratch2, 0, b, smax);
        let base = 3 * d; // lane 0, c 0, head 0, pos 3
        assert_eq!(scratch2[0][base], 9.0);
    }

    #[test]
    fn append_row_grows_every_layer_and_accounts_bytes() {
        let mut s = KvStore::new(kv(8).bytes() * 3); // room for 3 of 4 layers
        s.insert(0, four_layers(8), &[0, 1, 2, 3]);
        let (dev0, host0) = (s.device_used(), s.host_used());
        let rows: Vec<Vec<f32>> = (0..4).map(|_| vec![2.5f32; 2 * 2 * 4]).collect();
        s.append_row(0, &rows);
        assert_eq!(s.tokens(0), 9);
        let row_bytes = 2 * 2 * 4 * 4; // 2 planes * KH * D * f32
        assert_eq!(s.device_used(), dev0 + 3 * row_bytes);
        assert_eq!(s.host_used(), host0 + row_bytes);
        // the appended value is readable back at the tail position
        let (b, smax) = (1, 16);
        let mut scratch: Vec<Vec<f32>> =
            (0..4).map(|_| vec![0.0; b * 2 * 2 * smax * 4]).collect();
        s.fill_scratch(0, &mut scratch, 0, b, smax);
        assert_eq!(scratch[0][8 * 4], 2.5); // head 0, pos 8, dim 0
    }

    #[test]
    fn release_frees_both_pools() {
        let mut s = KvStore::new(kv(8).bytes() * 2);
        s.insert(0, four_layers(8), &[0, 1]);
        s.release(0);
        assert_eq!(s.device_used(), 0);
        assert_eq!(s.host_used(), 0);
        assert!(!s.contains(0));
    }

    fn spill_dir(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("layerkv-kvstore-{tag}-{}", std::process::id()))
    }

    #[test]
    fn spill_writes_a_real_file_and_frees_host() {
        let dir = spill_dir("roundtrip");
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::with_spill_dir(2 * layer_bytes, dir.clone());
        s.insert(0, four_layers(8), &[1, 3]); // 0, 2 on host
        let host0 = s.host_used();
        assert_eq!(s.spill_layer(0, 0).unwrap(), layer_bytes);
        assert_eq!(s.host_used(), host0 - layer_bytes);
        assert_eq!(s.disk_used(), layer_bytes);
        assert_eq!(s.disk_layers(0), vec![0]);
        assert_eq!(s.host_layers(0), vec![2]);
        assert!(dir.join("kv_r0_l0.bin").exists(), "spill must hit the filesystem");
        // device-resident and already-spilled layers refuse to spill
        assert_eq!(s.spill_layer(0, 1).unwrap(), 0);
        assert_eq!(s.spill_layer(0, 0).unwrap(), 0);
        // spilled layers do not onload directly
        assert_eq!(s.onload_layer(0, 0), 0);
        // restore reads the bytes back and deletes the file
        assert_eq!(s.unspill_layer(0, 0).unwrap(), layer_bytes);
        assert!(!dir.join("kv_r0_l0.bin").exists());
        assert_eq!(s.disk_used(), 0);
        assert_eq!(s.host_used(), host0);
        assert_eq!(s.stats.spills, 1);
        assert_eq!(s.stats.unspills, 1);
        s.release(0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn spill_disabled_without_dir() {
        let mut s = KvStore::new(usize::MAX);
        s.insert(0, four_layers(8), &[]);
        assert_eq!(s.spill_layer(0, 0).unwrap(), 0);
        assert_eq!(s.disk_used(), 0);
    }

    #[test]
    fn spilled_layer_streams_and_appends_through_the_file() {
        let dir = spill_dir("append");
        let (b, smax, kh, d) = (1usize, 16usize, 2usize, 4usize);
        let mut s = KvStore::with_spill_dir(0, dir.clone()); // nothing fits the device
        s.insert(7, four_layers(3), &[]);
        assert!(s.spill_layer(7, 2).unwrap() > 0);
        // decode still reads the spilled layer's true bytes
        let mut scratch: Vec<Vec<f32>> =
            (0..4).map(|_| vec![0.0; b * 2 * kh * smax * d]).collect();
        s.fill_scratch(7, &mut scratch, 0, b, smax);
        assert_eq!(scratch[2][0], 1.0, "spilled layer must stream from its file");
        assert!(s.stats.disk_read_bytes > 0);
        // append grows the file-backed layer too
        let rows: Vec<Vec<f32>> = (0..4).map(|_| vec![5.0f32; 2 * kh * d]).collect();
        let disk0 = s.disk_used();
        s.append_row(7, &rows);
        assert_eq!(s.tokens(7), 4);
        assert_eq!(s.disk_used(), disk0 + 2 * kh * d * 4);
        let mut scratch2: Vec<Vec<f32>> =
            (0..4).map(|_| vec![0.0; b * 2 * kh * smax * d]).collect();
        s.fill_scratch(7, &mut scratch2, 0, b, smax);
        assert_eq!(scratch2[2][3 * d], 5.0, "appended row readable from the file");
        // promote: disk -> host (device budget 0 keeps it off-device)
        assert_eq!(s.promote_layer(7, 2).unwrap(), 0);
        assert!(s.disk_layers(7).is_empty(), "unspill leg must have run");
        s.release(7);
        assert_eq!((s.device_used(), s.host_used(), s.disk_used()), (0, 0, 0));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_spill_file_is_an_error_not_a_mask() {
        let dir = spill_dir("ioerr");
        let mut s = KvStore::with_spill_dir(0, dir.clone());
        s.insert(9, four_layers(4), &[]);
        assert!(s.spill_layer(9, 1).unwrap() > 0);
        // sabotage the disk tier: the spill file vanishes out from under us
        std::fs::remove_file(dir.join("kv_r9_l1.bin")).unwrap();
        assert!(s.unspill_layer(9, 1).is_err(), "lost file must surface as Err");
        assert!(s.promote_layer(9, 1).is_err());
        assert_eq!(s.stats.io_errors, 2);
        // the layer stays disk-resident (accounting untouched) so the
        // caller can decide to fence the tier and recompute instead.
        assert_eq!(s.disk_layers(9), vec![1]);
        // the streaming read path degrades to zeroed history + a count
        let (b, smax, kh, d) = (1usize, 16usize, 2usize, 4usize);
        let mut scratch: Vec<Vec<f32>> =
            (0..4).map(|_| vec![7.0; b * 2 * kh * smax * d]).collect();
        s.fill_scratch(9, &mut scratch, 0, b, smax);
        assert_eq!(scratch[1][0], 0.0, "lost layer must stream zeros, not stale bytes");
        assert_eq!(s.stats.io_errors, 3);
        s.release(9);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn release_deletes_spill_files() {
        let dir = spill_dir("release");
        let mut s = KvStore::with_spill_dir(0, dir.clone());
        s.insert(3, four_layers(8), &[]);
        assert!(s.spill_layer(3, 0).unwrap() > 0);
        assert!(s.spill_layer(3, 1).unwrap() > 0);
        let f0 = dir.join("kv_r3_l0.bin");
        assert!(f0.exists());
        s.release(3);
        assert!(!f0.exists(), "release must clean spill files");
        assert_eq!(s.disk_used(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
