//! Layer-wise KV store for the real PJRT serving path (S7 in DESIGN.md).
//!
//! Holds every live request's per-layer KV tensors and tracks which layers
//! sit in the bounded "device" pool vs the host pool. On the CPU-only
//! testbed both pools are host RAM, but the copies are real and the byte
//! accounting mirrors what a CUDA/TPU build would push over the
//! interconnect — the policy layer (what to offload, when to restore) is
//! identical to the simulator's.

use std::collections::HashMap;

use crate::coordinator::request::ReqId;

use super::client::LayerKv;

#[derive(Debug, Clone, Default)]
pub struct KvStoreStats {
    pub offloads: u64,
    pub onloads: u64,
    pub offload_bytes: u64,
    pub onload_bytes: u64,
}

#[derive(Debug)]
struct StoredLayer {
    kv: LayerKv,
    on_device: bool,
}

/// Byte-budgeted two-pool KV store.
#[derive(Debug)]
pub struct KvStore {
    device_budget: usize,
    device_used: usize,
    host_used: usize,
    entries: HashMap<ReqId, Vec<StoredLayer>>,
    pub stats: KvStoreStats,
}

impl KvStore {
    pub fn new(device_budget_bytes: usize) -> Self {
        KvStore {
            device_budget: device_budget_bytes,
            device_used: 0,
            host_used: 0,
            entries: HashMap::new(),
            stats: KvStoreStats::default(),
        }
    }

    pub fn device_used(&self) -> usize {
        self.device_used
    }

    pub fn host_used(&self) -> usize {
        self.host_used
    }

    pub fn device_free(&self) -> usize {
        self.device_budget.saturating_sub(self.device_used)
    }

    pub fn contains(&self, req: ReqId) -> bool {
        self.entries.contains_key(&req)
    }

    /// Store a prefill's KV. Layers in `retained` go to the device pool
    /// (if the budget allows), the rest to the host pool — the offload
    /// traffic a GPU build would overlap with the prefill itself.
    pub fn insert(&mut self, req: ReqId, kv: Vec<LayerKv>, retained: &[usize]) {
        let mut layers = Vec::with_capacity(kv.len());
        for (i, layer) in kv.into_iter().enumerate() {
            let bytes = layer.bytes();
            let want_device = retained.contains(&i);
            let on_device = want_device && self.device_used + bytes <= self.device_budget;
            if on_device {
                self.device_used += bytes;
            } else {
                self.host_used += bytes;
                self.stats.offloads += 1;
                self.stats.offload_bytes += bytes as u64;
            }
            layers.push(StoredLayer { kv: layer, on_device });
        }
        let prev = self.entries.insert(req, layers);
        debug_assert!(prev.is_none(), "request {req} inserted twice");
    }

    /// Layers of `req` currently on the host.
    pub fn host_layers(&self, req: ReqId) -> Vec<usize> {
        self.entries
            .get(&req)
            .map(|ls| {
                ls.iter().enumerate().filter(|(_, l)| !l.on_device).map(|(i, _)| i).collect()
            })
            .unwrap_or_default()
    }

    pub fn fully_resident(&self, req: ReqId) -> bool {
        self.entries.get(&req).map(|ls| ls.iter().all(|l| l.on_device)).unwrap_or(false)
    }

    /// Bytes of one request's KV on the host.
    pub fn host_bytes(&self, req: ReqId) -> usize {
        self.entries
            .get(&req)
            .map(|ls| ls.iter().filter(|l| !l.on_device).map(|l| l.kv.bytes()).sum())
            .unwrap_or(0)
    }

    /// Move one layer device -> host. Returns bytes moved.
    pub fn offload_layer(&mut self, req: ReqId, layer: usize) -> usize {
        let Some(ls) = self.entries.get_mut(&req) else { return 0 };
        let l = &mut ls[layer];
        if !l.on_device {
            return 0;
        }
        let bytes = l.kv.bytes();
        l.on_device = false;
        self.device_used -= bytes;
        self.host_used += bytes;
        self.stats.offloads += 1;
        self.stats.offload_bytes += bytes as u64;
        bytes
    }

    /// Move one layer host -> device if the budget allows. Returns bytes.
    pub fn onload_layer(&mut self, req: ReqId, layer: usize) -> usize {
        let Some(ls) = self.entries.get_mut(&req) else { return 0 };
        let l = &mut ls[layer];
        if l.on_device {
            return 0;
        }
        let bytes = l.kv.bytes();
        if self.device_used + bytes > self.device_budget {
            return 0;
        }
        l.on_device = true;
        self.device_used += bytes;
        self.host_used -= bytes;
        self.stats.onloads += 1;
        self.stats.onload_bytes += bytes as u64;
        bytes
    }

    /// Restore as many host layers of `req` as the budget allows.
    pub fn try_restore(&mut self, req: ReqId) -> usize {
        let layers = self.host_layers(req);
        let mut moved = 0;
        for l in layers {
            moved += self.onload_layer(req, l);
        }
        moved
    }

    /// Copy lane `lane` of a dense decode scratch back as the appended
    /// token's KV. `scratch[layer]` is `[B, 2, KH, Smax, D]`; the new row
    /// sits at position `pos` of the sequence axis.
    pub fn append_from_scratch(
        &mut self,
        req: ReqId,
        scratch: &[Vec<f32>],
        lane: usize,
        _b: usize,
        smax: usize,
        pos: usize,
    ) {
        let Some(ls) = self.entries.get_mut(&req) else { return };
        for (layer, s) in ls.iter_mut().zip(scratch.iter()) {
            let kv = &mut layer.kv;
            let (kh, d) = (kv.kh, kv.d);
            debug_assert_eq!(s.len(), _b * 2 * kh * smax * d);
            debug_assert_eq!(pos, kv.t, "append must be at the current tail");
            // grow [2, KH, T, D] -> [2, KH, T+1, D]
            let mut out = Vec::with_capacity(2 * kh * (kv.t + 1) * d);
            for c in 0..2 {
                for h in 0..kh {
                    let old = (c * kh + h) * kv.t * d;
                    out.extend_from_slice(&kv.data[old..old + kv.t * d]);
                    let src = (((lane * 2 + c) * kh + h) * smax + pos) * d;
                    out.extend_from_slice(&s[src..src + d]);
                }
            }
            let grown = (out.len() - kv.data.len()) as u64; // 2*KH*D floats
            kv.data = out;
            kv.t += 1;
            let grown_bytes = grown * 4;
            if layer.on_device {
                self.device_used += grown_bytes as usize;
            } else {
                self.host_used += grown_bytes as usize;
            }
        }
    }

    /// Fill lane `lane` of the dense scratch from the store (any residency;
    /// host reads count as onload stream bytes).
    pub fn fill_scratch(
        &mut self,
        req: ReqId,
        scratch: &mut [Vec<f32>],
        lane: usize,
        _b: usize,
        smax: usize,
    ) -> usize {
        let Some(ls) = self.entries.get(&req) else { return 0 };
        let mut streamed = 0usize;
        for (layer, s) in ls.iter().zip(scratch.iter_mut()) {
            let kv = &layer.kv;
            let (kh, d, t) = (kv.kh, kv.d, kv.t);
            for c in 0..2 {
                for h in 0..kh {
                    let src = (c * kh + h) * t * d;
                    let dst = (((lane * 2 + c) * kh + h) * smax) * d;
                    s[dst..dst + t * d].copy_from_slice(&kv.data[src..src + t * d]);
                }
            }
            if !layer.on_device {
                streamed += kv.bytes();
            }
        }
        if streamed > 0 {
            self.stats.onload_bytes += streamed as u64;
        }
        streamed
    }

    pub fn tokens(&self, req: ReqId) -> usize {
        self.entries.get(&req).and_then(|ls| ls.first()).map(|l| l.kv.t).unwrap_or(0)
    }

    pub fn release(&mut self, req: ReqId) {
        if let Some(ls) = self.entries.remove(&req) {
            for l in ls {
                if l.on_device {
                    self.device_used -= l.kv.bytes();
                } else {
                    self.host_used -= l.kv.bytes();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(t: usize) -> LayerKv {
        LayerKv { data: vec![1.0; 2 * 2 * t * 4], kh: 2, t, d: 4 }
    }

    fn four_layers(t: usize) -> Vec<LayerKv> {
        (0..4).map(|_| kv(t)).collect()
    }

    #[test]
    fn insert_respects_budget_and_retained() {
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::new(2 * layer_bytes);
        s.insert(0, four_layers(8), &[1, 3]);
        assert_eq!(s.device_used(), 2 * layer_bytes);
        assert_eq!(s.host_layers(0), vec![0, 2]);
        assert!(!s.fully_resident(0));
        assert_eq!(s.stats.offloads, 2);
    }

    #[test]
    fn budget_overflow_spills_to_host() {
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::new(layer_bytes); // room for one layer only
        s.insert(0, four_layers(8), &[0, 1, 2, 3]);
        assert_eq!(s.device_used(), layer_bytes);
        assert_eq!(s.host_layers(0).len(), 3);
    }

    #[test]
    fn offload_onload_roundtrip() {
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::new(4 * layer_bytes);
        s.insert(0, four_layers(8), &[0, 1, 2, 3]);
        assert!(s.fully_resident(0));
        assert_eq!(s.offload_layer(0, 2), layer_bytes);
        assert_eq!(s.host_layers(0), vec![2]);
        assert_eq!(s.onload_layer(0, 2), layer_bytes);
        assert!(s.fully_resident(0));
        // idempotent
        assert_eq!(s.onload_layer(0, 2), 0);
    }

    #[test]
    fn try_restore_partial_under_budget() {
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::new(3 * layer_bytes);
        s.insert(0, four_layers(8), &[]);
        assert_eq!(s.host_layers(0).len(), 4);
        let moved = s.try_restore(0);
        assert_eq!(moved, 3 * layer_bytes);
        assert_eq!(s.host_layers(0).len(), 1);
    }

    #[test]
    fn scratch_roundtrip_appends() {
        let (b, smax, kh, d) = (2, 16, 2, 4);
        let mut s = KvStore::new(usize::MAX);
        s.insert(7, four_layers(3), &[0, 1, 2, 3]);
        let mut scratch: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; b * 2 * kh * smax * d]).collect();
        let streamed = s.fill_scratch(7, &mut scratch, 1, b, smax);
        assert_eq!(streamed, 0); // resident
        // pretend the model wrote a new row at pos 3 of lane 1
        for sc in &mut scratch {
            for c in 0..2 {
                for h in 0..kh {
                    let base = (((1 * 2 + c) * kh + h) * smax + 3) * d;
                    for x in 0..d {
                        sc[base + x] = 9.0;
                    }
                }
            }
        }
        s.append_from_scratch(7, &scratch, 1, b, smax, 3);
        assert_eq!(s.tokens(7), 4);
        // re-fill and check the appended row is there
        let mut scratch2: Vec<Vec<f32>> =
            (0..4).map(|_| vec![0.0; b * 2 * kh * smax * d]).collect();
        s.fill_scratch(7, &mut scratch2, 0, b, smax);
        let base = ((0 * kh + 0) * smax + 3) * d;
        assert_eq!(scratch2[0][base], 9.0);
    }

    #[test]
    fn release_frees_both_pools() {
        let mut s = KvStore::new(kv(8).bytes() * 2);
        s.insert(0, four_layers(8), &[0, 1]);
        s.release(0);
        assert_eq!(s.device_used(), 0);
        assert_eq!(s.host_used(), 0);
        assert!(!s.contains(0));
    }
}
