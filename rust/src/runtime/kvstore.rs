//! Layer-wise KV store for the real PJRT serving path (S7 in DESIGN.md).
//!
//! Holds every live request's per-layer KV tensors and tracks which layers
//! sit in the bounded "device" pool vs the host pool. On the CPU-only
//! testbed both pools are host RAM, but the copies are real and the byte
//! accounting mirrors what a CUDA/TPU build would push over the
//! interconnect — the policy layer (what to offload, when to restore) is
//! identical to the simulator's.

use std::collections::HashMap;

use crate::coordinator::request::ReqId;

use super::client::LayerKv;

#[derive(Debug, Clone, Default)]
pub struct KvStoreStats {
    pub offloads: u64,
    pub onloads: u64,
    pub offload_bytes: u64,
    pub onload_bytes: u64,
}

#[derive(Debug)]
struct StoredLayer {
    kv: LayerKv,
    on_device: bool,
}

/// Byte-budgeted two-pool KV store.
#[derive(Debug)]
pub struct KvStore {
    device_budget: usize,
    device_used: usize,
    host_used: usize,
    entries: HashMap<ReqId, Vec<StoredLayer>>,
    pub stats: KvStoreStats,
}

impl KvStore {
    pub fn new(device_budget_bytes: usize) -> Self {
        KvStore {
            device_budget: device_budget_bytes,
            device_used: 0,
            host_used: 0,
            entries: HashMap::new(),
            stats: KvStoreStats::default(),
        }
    }

    pub fn device_used(&self) -> usize {
        self.device_used
    }

    pub fn host_used(&self) -> usize {
        self.host_used
    }

    pub fn contains(&self, req: ReqId) -> bool {
        self.entries.contains_key(&req)
    }

    /// Store a prefill's KV. Layers in `retained` go to the device pool
    /// (if the budget allows), the rest to the host pool — the offload
    /// traffic a GPU build would overlap with the prefill itself.
    pub fn insert(&mut self, req: ReqId, kv: Vec<LayerKv>, retained: &[usize]) {
        let mut layers = Vec::with_capacity(kv.len());
        for (i, layer) in kv.into_iter().enumerate() {
            let bytes = layer.bytes();
            let want_device = retained.contains(&i);
            let on_device = want_device && self.device_used + bytes <= self.device_budget;
            if on_device {
                self.device_used += bytes;
            } else {
                self.host_used += bytes;
                self.stats.offloads += 1;
                self.stats.offload_bytes += bytes as u64;
            }
            layers.push(StoredLayer { kv: layer, on_device });
        }
        let prev = self.entries.insert(req, layers);
        debug_assert!(prev.is_none(), "request {req} inserted twice");
    }

    /// Layers of `req` currently on the host.
    pub fn host_layers(&self, req: ReqId) -> Vec<usize> {
        self.entries
            .get(&req)
            .map(|ls| {
                ls.iter().enumerate().filter(|(_, l)| !l.on_device).map(|(i, _)| i).collect()
            })
            .unwrap_or_default()
    }

    pub fn fully_resident(&self, req: ReqId) -> bool {
        self.entries.get(&req).map(|ls| ls.iter().all(|l| l.on_device)).unwrap_or(false)
    }

    /// Move one layer device -> host. Returns bytes moved.
    pub fn offload_layer(&mut self, req: ReqId, layer: usize) -> usize {
        let Some(ls) = self.entries.get_mut(&req) else { return 0 };
        let l = &mut ls[layer];
        if !l.on_device {
            return 0;
        }
        let bytes = l.kv.bytes();
        l.on_device = false;
        self.device_used -= bytes;
        self.host_used += bytes;
        self.stats.offloads += 1;
        self.stats.offload_bytes += bytes as u64;
        bytes
    }

    /// Move one layer host -> device if the budget allows. Returns bytes.
    pub fn onload_layer(&mut self, req: ReqId, layer: usize) -> usize {
        let Some(ls) = self.entries.get_mut(&req) else { return 0 };
        let l = &mut ls[layer];
        if l.on_device {
            return 0;
        }
        let bytes = l.kv.bytes();
        if self.device_used + bytes > self.device_budget {
            return 0;
        }
        l.on_device = true;
        self.device_used += bytes;
        self.host_used -= bytes;
        self.stats.onloads += 1;
        self.stats.onload_bytes += bytes as u64;
        bytes
    }

    /// Append one committed token's KV to every layer of `req`.
    /// `rows[layer]` is the `[2, KH, D]` row (c-major, then head, then
    /// dim) the decode step produced for the tail position. This is the
    /// engine-confirmed half of the decode step: rows for tokens the
    /// coordinator rejected (block-pool OOM) are simply never appended
    /// and get recomputed next step.
    pub fn append_row(&mut self, req: ReqId, rows: &[Vec<f32>]) {
        let Some(ls) = self.entries.get_mut(&req) else { return };
        debug_assert_eq!(ls.len(), rows.len(), "row per layer");
        for (layer, row) in ls.iter_mut().zip(rows.iter()) {
            let kv = &mut layer.kv;
            let (kh, d) = (kv.kh, kv.d);
            debug_assert_eq!(row.len(), 2 * kh * d);
            // grow [2, KH, T, D] -> [2, KH, T+1, D]
            let mut out = Vec::with_capacity(2 * kh * (kv.t + 1) * d);
            for c in 0..2 {
                for h in 0..kh {
                    let old = (c * kh + h) * kv.t * d;
                    out.extend_from_slice(&kv.data[old..old + kv.t * d]);
                    let src = (c * kh + h) * d;
                    out.extend_from_slice(&row[src..src + d]);
                }
            }
            let grown = (out.len() - kv.data.len()) as u64; // 2*KH*D floats
            kv.data = out;
            kv.t += 1;
            let grown_bytes = grown * 4;
            if layer.on_device {
                self.device_used += grown_bytes as usize;
            } else {
                self.host_used += grown_bytes as usize;
            }
        }
    }

    /// Fill lane `lane` of the dense scratch from the store (any residency;
    /// host reads count as onload stream bytes).
    pub fn fill_scratch(
        &mut self,
        req: ReqId,
        scratch: &mut [Vec<f32>],
        lane: usize,
        _b: usize,
        smax: usize,
    ) -> usize {
        let Some(ls) = self.entries.get(&req) else { return 0 };
        let mut streamed = 0usize;
        for (layer, s) in ls.iter().zip(scratch.iter_mut()) {
            let kv = &layer.kv;
            let (kh, d, t) = (kv.kh, kv.d, kv.t);
            for c in 0..2 {
                for h in 0..kh {
                    let src = (c * kh + h) * t * d;
                    let dst = (((lane * 2 + c) * kh + h) * smax) * d;
                    s[dst..dst + t * d].copy_from_slice(&kv.data[src..src + t * d]);
                }
            }
            if !layer.on_device {
                streamed += kv.bytes();
            }
        }
        if streamed > 0 {
            self.stats.onload_bytes += streamed as u64;
        }
        streamed
    }

    pub fn tokens(&self, req: ReqId) -> usize {
        self.entries.get(&req).and_then(|ls| ls.first()).map(|l| l.kv.t).unwrap_or(0)
    }

    pub fn release(&mut self, req: ReqId) {
        if let Some(ls) = self.entries.remove(&req) {
            for l in ls {
                if l.on_device {
                    self.device_used -= l.kv.bytes();
                } else {
                    self.host_used -= l.kv.bytes();
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(t: usize) -> LayerKv {
        LayerKv { data: vec![1.0; 2 * 2 * t * 4], kh: 2, t, d: 4 }
    }

    fn four_layers(t: usize) -> Vec<LayerKv> {
        (0..4).map(|_| kv(t)).collect()
    }

    #[test]
    fn insert_respects_budget_and_retained() {
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::new(2 * layer_bytes);
        s.insert(0, four_layers(8), &[1, 3]);
        assert_eq!(s.device_used(), 2 * layer_bytes);
        assert_eq!(s.host_layers(0), vec![0, 2]);
        assert!(!s.fully_resident(0));
        assert_eq!(s.stats.offloads, 2);
    }

    #[test]
    fn budget_overflow_spills_to_host() {
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::new(layer_bytes); // room for one layer only
        s.insert(0, four_layers(8), &[0, 1, 2, 3]);
        assert_eq!(s.device_used(), layer_bytes);
        assert_eq!(s.host_layers(0).len(), 3);
    }

    #[test]
    fn offload_onload_roundtrip() {
        let layer_bytes = kv(8).bytes();
        let mut s = KvStore::new(4 * layer_bytes);
        s.insert(0, four_layers(8), &[0, 1, 2, 3]);
        assert!(s.fully_resident(0));
        assert_eq!(s.offload_layer(0, 2), layer_bytes);
        assert_eq!(s.host_layers(0), vec![2]);
        assert_eq!(s.onload_layer(0, 2), layer_bytes);
        assert!(s.fully_resident(0));
        // idempotent
        assert_eq!(s.onload_layer(0, 2), 0);
    }

    #[test]
    fn scratch_roundtrip_appends() {
        let (b, smax, kh, d) = (2usize, 16usize, 2usize, 4usize);
        let mut s = KvStore::new(usize::MAX);
        s.insert(7, four_layers(3), &[0, 1, 2, 3]);
        let mut scratch: Vec<Vec<f32>> = (0..4).map(|_| vec![0.0; b * 2 * kh * smax * d]).collect();
        let streamed = s.fill_scratch(7, &mut scratch, 1, b, smax);
        assert_eq!(streamed, 0); // resident
        // append the row the model would have written at pos 3 of lane 1
        let rows: Vec<Vec<f32>> = (0..4).map(|_| vec![9.0f32; 2 * kh * d]).collect();
        s.append_row(7, &rows);
        assert_eq!(s.tokens(7), 4);
        // re-fill and check the appended row is there
        let mut scratch2: Vec<Vec<f32>> =
            (0..4).map(|_| vec![0.0; b * 2 * kh * smax * d]).collect();
        s.fill_scratch(7, &mut scratch2, 0, b, smax);
        let base = 3 * d; // lane 0, c 0, head 0, pos 3
        assert_eq!(scratch2[0][base], 9.0);
    }

    #[test]
    fn append_row_grows_every_layer_and_accounts_bytes() {
        let mut s = KvStore::new(kv(8).bytes() * 3); // room for 3 of 4 layers
        s.insert(0, four_layers(8), &[0, 1, 2, 3]);
        let (dev0, host0) = (s.device_used(), s.host_used());
        let rows: Vec<Vec<f32>> = (0..4).map(|_| vec![2.5f32; 2 * 2 * 4]).collect();
        s.append_row(0, &rows);
        assert_eq!(s.tokens(0), 9);
        let row_bytes = 2 * 2 * 4 * 4; // 2 planes * KH * D * f32
        assert_eq!(s.device_used(), dev0 + 3 * row_bytes);
        assert_eq!(s.host_used(), host0 + row_bytes);
        // the appended value is readable back at the tail position
        let (b, smax) = (1, 16);
        let mut scratch: Vec<Vec<f32>> =
            (0..4).map(|_| vec![0.0; b * 2 * 2 * smax * 4]).collect();
        s.fill_scratch(0, &mut scratch, 0, b, smax);
        assert_eq!(scratch[0][8 * 4], 2.5); // head 0, pos 8, dim 0
    }

    #[test]
    fn release_frees_both_pools() {
        let mut s = KvStore::new(kv(8).bytes() * 2);
        s.insert(0, four_layers(8), &[0, 1]);
        s.release(0);
        assert_eq!(s.device_used(), 0);
        assert_eq!(s.host_used(), 0);
        assert!(!s.contains(0));
    }
}
