//! Runtime: the rust side of the AOT bridge. Loads `artifacts/*.hlo.txt`
//! via the xla crate's PJRT CPU client, keeps weights resident, and serves
//! the tiny model end-to-end with layer-wise KV residency management.
//!
//! Since the `ExecutionBackend` refactor, execution lives behind two
//! seams: `TokenModel` (what runs a forward pass — the PJRT `TinyModel`
//! or the deterministic `RefModel`) and `PjrtBackend` (the
//! `ExecutionBackend` the shared coordinator drives). All scheduling and
//! retention policy lives in `coordinator/`.

pub mod artifacts;
pub mod client;
pub mod kvstore;
pub mod realengine;
pub mod refmodel;

pub use artifacts::{Artifacts, ExecutableKind, TinyModelConfig};
pub use client::{argmax, DecodeOut, LayerKv, PrefillOut, TinyModel, TokenModel};
pub use kvstore::{KvStore, KvStoreStats};
pub use realengine::{
    tiny_serving_config, PjrtBackend, RealEngine, RealEngineConfig, ServeOutcome,
    ServeRequest, ServeResult,
};
pub use refmodel::RefModel;
