//! Runtime: the rust side of the AOT bridge. Loads `artifacts/*.hlo.txt`
//! via the xla crate's PJRT CPU client, keeps weights resident, and serves
//! the tiny model end-to-end with layer-wise KV residency management.

pub mod artifacts;
pub mod client;
pub mod kvstore;
pub mod realengine;

pub use artifacts::{Artifacts, ExecutableKind, TinyModelConfig};
pub use client::{argmax, DecodeOut, LayerKv, PrefillOut, TinyModel};
pub use kvstore::{KvStore, KvStoreStats};
pub use realengine::{RealEngine, RealEngineConfig, ServeRequest, ServeResult};
