//! Real serving path: the `PjrtBackend` executor + a thin serving
//! wrapper. This is the end-to-end proof that all three layers compose —
//! actual tokens flow through the Pallas-kernel HLO, and the coordinator
//! moves real per-layer KV tensors between the bounded device pool and
//! the host pool.
//!
//! Since the `ExecutionBackend` refactor this file contains **no
//! scheduling or retention policy**: admission, the §3.1.1 retained-layer
//! x-solve, TPOT-slack gating, restore/offload hysteresis, and recompute
//! preemption all live in `Engine<B>` + `make_scheduler` + `KvManager` —
//! the *same* code the simulator runs. The backend only executes:
//! `TokenModel` forward passes (PJRT `TinyModel`, or the deterministic
//! `RefModel` stand-in), a `KvStore` holding the actual tensors whose
//! residency mirrors the `KvManager` layer tables, and a wall clock.
//!
//! Timings are wall-clock; the serving loop is Python-free.

use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::config::{DiskSpec, ModelSpec, NodeSpec, Policy, ServingConfig};
use crate::coordinator::backend::{
    Clock, DecodeOutcome, ExecutionBackend, PrefillOutcome, WallClock,
};
use crate::coordinator::block::KvManager;
use crate::coordinator::engine::Engine;
use crate::coordinator::predict::LengthPredictor;
use crate::coordinator::request::{ReqId, Request};
use crate::metrics::Report;
use crate::workload::{Trace, TraceRequest};

use super::artifacts::TinyModelConfig;
use super::client::{argmax, TinyModel, TokenModel};
use super::kvstore::{KvStore, KvStoreStats};

/// Host pool capacity in layer-blocks: effectively unbounded (host RAM).
const HOST_LAYER_BLOCKS: usize = 1 << 20;

/// One inference job for the real engine.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: ReqId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Seconds after engine start at which this request becomes visible.
    pub arrival_s: f64,
}

/// Completed job.
#[derive(Debug, Clone)]
pub struct ServeResult {
    pub id: ReqId,
    pub output: Vec<i32>,
    pub record: crate::metrics::RequestRecord,
}

/// Everything one `serve` call produced.
#[derive(Debug)]
pub struct ServeOutcome {
    /// Completed requests, sorted by the caller's ids.
    pub results: Vec<ServeResult>,
    /// Rejected requests (caller id, reason): oversized prompts and
    /// requests that can never fit the KV pools. They get no
    /// `RequestRecord` — a zero-length record would skew the TTFT/TPOT
    /// percentiles — and surface as an explicit error in the server
    /// response instead.
    pub dropped: Vec<(ReqId, String)>,
    /// Latency report over the completed requests (engine-internal ids,
    /// i.e. positions in arrival order).
    pub report: Report,
    /// The engine's counters for this batch (preemptions, offload
    /// traffic, cache hits) — the `/metrics` endpoint renders these.
    pub stats: crate::coordinator::EngineStats,
}

#[derive(Debug, Clone)]
pub struct RealEngineConfig {
    /// Device-pool byte budget for KV (small by default so layer-wise
    /// offloading actually exercises on the tiny model).
    pub device_kv_budget: usize,
    pub policy: Policy,
    /// Max decode lanes per step (must be <= largest decode bucket).
    pub max_batch: usize,
    /// Host pool capacity in layer-blocks (defaults to effectively
    /// unbounded, the pre-hierarchy behaviour).
    pub host_layer_blocks: usize,
    /// Disk tier capacity in layer-blocks (0 = two-tier, the default).
    pub disk_layer_blocks: usize,
    /// Where spilled layers' tensor files land; defaults to a per-process
    /// directory under the system temp dir (an "artifacts" scratch area).
    pub spill_dir: Option<std::path::PathBuf>,
}

impl Default for RealEngineConfig {
    fn default() -> Self {
        RealEngineConfig {
            device_kv_budget: 2 << 20, // 2 MiB: a few requests' full KV
            policy: Policy::LayerKv { slo_aware: true },
            max_batch: 8,
            host_layer_blocks: HOST_LAYER_BLOCKS,
            disk_layer_blocks: 0,
            spill_dir: None,
        }
    }
}

/// `ServingConfig` describing the tiny executor to the policy layer:
/// real model geometry, CPU-testbed hardware magnitudes. On this path
/// the cost model only steers the scheduler's heuristics — measured
/// latencies come from the wall clock.
pub fn tiny_serving_config(
    spec: &TinyModelConfig,
    policy: Policy,
    max_batch: usize,
) -> ServingConfig {
    let mut model = ModelSpec::tiny();
    model.n_layers = spec.n_layers;
    model.n_heads = spec.n_heads;
    model.n_kv_heads = spec.n_kv_heads;
    model.head_dim = spec.head_dim;
    model.hidden = spec.d_model;
    model.ffn_hidden = spec.ffn_hidden;
    model.vocab = spec.vocab;
    model.max_context = spec.max_seq;
    let mut cfg = ServingConfig::new(model, NodeSpec::cpu_pjrt_testbed(), 1)
        .with_policy(policy)
        .with_max_model_len(spec.max_seq);
    cfg.block_size = 16;
    cfg.max_num_seqs = max_batch.max(1);
    cfg
}

/// Device layer-blocks a byte budget buys for this model geometry (f32).
fn device_layer_blocks(spec: &TinyModelConfig, block_size: usize, budget_bytes: usize) -> usize {
    let layer_block_bytes = block_size * 2 * spec.n_kv_heads * spec.head_dim * 4;
    budget_bytes / layer_block_bytes.max(1)
}

/// Per-request token state the executor owns (the coordinator only sees
/// lengths).
#[derive(Debug, Default, Clone)]
struct Gen {
    prompt: Vec<i32>,
    out: Vec<i32>,
}

/// A decoded-but-unconfirmed token: committed (KV row appended, token
/// recorded) only once the coordinator's block accounting accepted the
/// growth; otherwise discarded and recomputed next step.
#[derive(Debug)]
struct PendingTok {
    token: i32,
    /// Per layer, the `[2, KH, D]` row for the tail position.
    rows: Vec<Vec<f32>>,
}

/// The real executor: `TokenModel` forward passes on wall time, tensors
/// in a two-pool `KvStore` whose residency mirrors the coordinator's
/// `KvManager` layer tables (the `KvManager` is the budget authority;
/// the store holds the bytes).
pub struct PjrtBackend<M: TokenModel = TinyModel> {
    model: Rc<M>,
    store: KvStore,
    clock: WallClock,
    max_batch: usize,
    gens: Vec<Gen>,
    pending: HashMap<ReqId, PendingTok>,
    /// Reusable buffer for the retained-layer indices of one admission
    /// (the PR 1 scratch idiom — `gpu_layers()` is an iterator now).
    retained_buf: Vec<usize>,
}

impl<M: TokenModel> PjrtBackend<M> {
    pub fn new(model: Rc<M>, max_batch: usize) -> Self {
        PjrtBackend {
            model,
            store: KvStore::new(usize::MAX),
            clock: WallClock::new(),
            max_batch,
            gens: Vec::new(),
            pending: HashMap::new(),
            retained_buf: Vec::new(),
        }
    }

    /// As `new`, but with the disk tier enabled: layers the coordinator
    /// spills are written as real files under `spill_dir`.
    pub fn with_spill_dir(model: Rc<M>, max_batch: usize, spill_dir: std::path::PathBuf) -> Self {
        let mut b = Self::new(model, max_batch);
        b.store = KvStore::with_spill_dir(usize::MAX, spill_dir);
        b
    }

    /// Register each job's prompt tokens, indexed by engine `ReqId`
    /// (position in the trace). Public so failover harnesses can build a
    /// backend for a standalone `Engine` and later install adopted lanes
    /// beside these (see `ExecutionBackend::adopt`).
    pub fn load_jobs(&mut self, jobs: &[ServeRequest]) {
        self.gens = jobs
            .iter()
            .map(|j| Gen { prompt: j.prompt.clone(), out: Vec::new() })
            .collect();
    }

    pub fn kv_stats(&self) -> &KvStoreStats {
        &self.store.stats
    }

    fn take_output(&mut self, rid: ReqId) -> Vec<i32> {
        std::mem::take(&mut self.gens[rid].out)
    }
}

impl<M: TokenModel> ExecutionBackend for PjrtBackend<M> {
    type Clk = WallClock;

    fn clock(&self) -> &WallClock {
        &self.clock
    }

    fn clock_mut(&mut self) -> &mut WallClock {
        &mut self.clock
    }

    fn max_decode_lanes(&self) -> usize {
        self.max_batch.min(self.model.max_decode_batch()).max(1)
    }

    fn supports_prompt(&self, prompt_len: usize) -> bool {
        self.model.prefill_bucket_for(prompt_len).is_some()
    }

    fn bounded_steps(&self) -> bool {
        false // wall-clock engines idle-spin between arrivals
    }

    fn prefill(&mut self, req: &Request, kv: &KvManager) -> Result<PrefillOutcome> {
        let t0 = self.clock.now();
        let rid = req.id;
        let fresh = req.first_token.is_none();
        let toks: Vec<i32> = if fresh {
            self.gens[rid].prompt.clone()
        } else {
            // recompute re-prefill after a preemption: prompt ++ tokens
            // generated so far, minus the trailing one — it becomes the
            // next decode's input, exactly like a fresh first token.
            // The KvManager allocated for prefill_len() = prompt+generated
            // (the sim's recompute-cost convention), so for re-admitted
            // requests the block accounting stays one token conservative
            // vs the store's actual cache — deliberate: the budget
            // authority may under-promise, never over-promise.
            let g = &self.gens[rid];
            let keep = g.out.len().saturating_sub(1);
            let mut t = Vec::with_capacity(g.prompt.len() + keep);
            t.extend_from_slice(&g.prompt);
            t.extend_from_slice(&g.out[..keep]);
            t
        };
        let out = self.model.clone().prefill(&toks)?;
        // the KvManager table's residency is the retained set the
        // scheduler solved; non-retained layers go straight to the host
        // pool (the offload traffic a GPU build overlaps with the
        // prefill), and layers the coordinator admitted directly to the
        // disk tier are spilled to their files right away
        self.retained_buf.clear();
        if let Some(t) = kv.table(rid) {
            self.retained_buf.extend(t.gpu_layers());
        }
        let before = self.store.stats.offload_bytes;
        if self.store.contains(rid) {
            self.store.release(rid); // defensive: stale entry
        }
        self.store.insert(rid, out.kv, &self.retained_buf);
        let offloaded = (self.store.stats.offload_bytes - before) as f64;
        let mut spill_bytes = 0.0;
        if let Some(t) = kv.table(rid) {
            if t.n_disk_layers() > 0 {
                self.retained_buf.clear();
                self.retained_buf.extend(t.disk_layers());
                for i in 0..self.retained_buf.len() {
                    let layer = self.retained_buf[i];
                    // a failed write leaves the layer host-resident; the
                    // store counts the error, and the layer stays usable
                    // (decode streams from host instead of the file)
                    if let Ok(b) = self.store.spill_layer(rid, layer) {
                        spill_bytes += b as f64;
                    }
                }
            }
        }
        if fresh {
            self.gens[rid].out.push(argmax(&out.logits));
        }
        let done = self.clock.now();
        Ok(PrefillOutcome {
            duration: done - t0,
            offload_bytes: offloaded,
            spill_bytes,
            // stamp TTFT at THIS request's prefill end, not the batch's
            first_token_at: fresh.then_some(done),
        })
    }

    fn decode(
        &mut self,
        lanes: &[ReqId],
        _requests: &[Request],
        _kv: &KvManager,
        _total_ctx: usize,
        _stream_bytes: f64,
        _disk_stream_bytes: f64,
    ) -> Result<DecodeOutcome> {
        let t0 = self.clock.now();
        self.pending.clear();
        let model = self.model.clone();
        let spec = model.spec().clone();
        let b = model
            .decode_bucket_for(lanes.len())
            .with_context(|| format!("no decode bucket for {} lanes", lanes.len()))?;
        let (kh, d, smax) = (spec.n_kv_heads, spec.head_dim, spec.max_seq);
        let per_layer = b * 2 * kh * smax * d;
        let mut scratch: Vec<Vec<f32>> =
            (0..spec.n_layers).map(|_| vec![0.0f32; per_layer]).collect();
        let mut tokens = vec![0i32; b];
        let mut lens = vec![0i32; b];
        for (lane, &rid) in lanes.iter().enumerate() {
            self.store.fill_scratch(rid, &mut scratch, lane, b, smax);
            tokens[lane] = *self.gens[rid].out.last().expect("running lane has tokens");
            lens[lane] = self.store.tokens(rid) as i32;
        }

        let out = model.decode(&tokens, &lens, &mut scratch)?;

        for (lane, &rid) in lanes.iter().enumerate() {
            let next = argmax(&out.logits[lane * spec.vocab..(lane + 1) * spec.vocab]);
            let pos = lens[lane] as usize;
            // stage the new KV row; committed per lane once the block
            // accounting accepts the growth
            let mut rows = Vec::with_capacity(spec.n_layers);
            for s in &scratch {
                let mut row = Vec::with_capacity(2 * kh * d);
                for c in 0..2 {
                    for h in 0..kh {
                        let src = (((lane * 2 + c) * kh + h) * smax + pos) * d;
                        row.extend_from_slice(&s[src..src + d]);
                    }
                }
                rows.push(row);
            }
            self.pending.insert(rid, PendingTok { token: next, rows });
        }
        Ok(DecodeOutcome {
            duration: self.clock.now() - t0,
            stream_stall_s: 0.0,
            contention_s: 0.0,
            disk_stall_s: 0.0,
        })
    }

    fn commit_token(&mut self, rid: ReqId) {
        if let Some(p) = self.pending.remove(&rid) {
            self.store.append_row(rid, &p.rows);
            self.gens[rid].out.push(p.token);
        }
    }

    // `supports_kv_restore` stays false: the crash that produced the
    // snapshot physically lost this store's tensors, so adoption goes
    // through the recompute re-prefill path — which replays the adopted
    // token streams below deterministically.

    fn snapshot_tokens(&self, rid: ReqId) -> Option<(Vec<i32>, Vec<i32>)> {
        self.gens.get(rid).map(|g| (g.prompt.clone(), g.out.clone()))
    }

    fn adopt(&mut self, rid: ReqId, tokens: Option<(Vec<i32>, Vec<i32>)>) {
        // lanes are indexed by the dense engine-local id: backfill any
        // gap (defensive; adoption normally lands at gens.len())
        if self.gens.len() <= rid {
            self.gens.resize_with(rid + 1, Gen::default);
        }
        if let Some((prompt, out)) = tokens {
            self.gens[rid] = Gen { prompt, out };
        }
    }

    fn offload_layer(&mut self, rid: ReqId, layer: usize) {
        self.store.offload_layer(rid, layer);
    }

    fn onload_layer(&mut self, rid: ReqId, layer: usize) {
        self.store.onload_layer(rid, layer);
    }

    fn spill_layer(&mut self, rid: ReqId, layer: usize) -> Result<()> {
        self.store.spill_layer(rid, layer)?;
        Ok(())
    }

    fn unspill_layer(&mut self, rid: ReqId, layer: usize) -> Result<()> {
        self.store.unspill_layer(rid, layer)?;
        Ok(())
    }

    fn promote_disk_layer(&mut self, rid: ReqId, layer: usize) -> Result<()> {
        self.store.promote_layer(rid, layer)?;
        Ok(())
    }

    fn evict(&mut self, rid: ReqId) {
        self.pending.remove(&rid);
        self.store.release(rid); // generated tokens survive for re-prefill
    }

    fn release(&mut self, rid: ReqId) {
        self.pending.remove(&rid);
        self.store.release(rid);
    }
}

/// The serving wrapper: keeps the (expensive to load) model across calls
/// and runs each batch through a fresh `Engine<PjrtBackend>` — same
/// `make_scheduler` policies and `KvManager` accounting as the simulator.
pub struct RealEngine<M: TokenModel = TinyModel> {
    model: Rc<M>,
    pub cfg: RealEngineConfig,
    kv_stats: KvStoreStats,
}

impl RealEngine<TinyModel> {
    /// Load the compiled PJRT artifacts.
    pub fn load(artifacts_dir: &Path, cfg: RealEngineConfig) -> Result<Self> {
        Ok(Self::with_model(Rc::new(TinyModel::load(artifacts_dir)?), cfg))
    }
}

impl<M: TokenModel> RealEngine<M> {
    /// Wrap any executor (e.g. `RefModel` for PJRT-free runs).
    pub fn with_model(model: Rc<M>, cfg: RealEngineConfig) -> Self {
        RealEngine { model, cfg, kv_stats: KvStoreStats::default() }
    }

    /// Cumulative KV-store traffic across all `serve` calls.
    pub fn kv_stats(&self) -> &KvStoreStats {
        &self.kv_stats
    }

    /// Serve a whole batch of requests to completion (arrivals honoured by
    /// wall-clock). Returns per-request results, rejections, and a latency
    /// report.
    pub fn serve(&mut self, mut jobs: Vec<ServeRequest>) -> Result<ServeOutcome> {
        jobs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let spec = self.model.spec().clone();
        let smax = spec.max_seq;
        // a recompute re-prefill replays prompt + generated-so-far minus
        // one, so generation is capped to keep that inside the largest
        // compiled prefill bucket (and the cache inside max_seq) — like
        // any context-window-bound server
        let max_prefill = self.model.max_prefill_len();
        let orig_ids: Vec<usize> = jobs.iter().map(|j| j.id).collect();
        let trace = Trace {
            requests: jobs
                .iter()
                .enumerate()
                .map(|(i, j)| TraceRequest {
                    id: i,
                    arrival: j.arrival_s.max(0.0),
                    prompt_len: j.prompt.len(),
                    output_len: j
                        .max_new_tokens
                        .min(smax.saturating_sub(j.prompt.len()))
                        .min((max_prefill + 1).saturating_sub(j.prompt.len()))
                        .max(1),
                    prefix: Default::default(),
                })
                .collect(),
        };

        let mut scfg = tiny_serving_config(&spec, self.cfg.policy, self.cfg.max_batch);
        if self.cfg.disk_layer_blocks > 0 {
            // describe the spill-file tier to the policy layer so the
            // scheduler's tiered x-solve prices the deeper link; like the
            // rest of the CPU-testbed numbers these are magnitudes, not
            // measurements — wall time is what gets reported
            let layer_block_bytes =
                scfg.block_size * 2 * spec.n_kv_heads * spec.head_dim * 4;
            scfg.node.disk = DiskSpec {
                bandwidth: 1.0e9,
                latency: 100e-6,
                capacity_bytes: (self.cfg.disk_layer_blocks * layer_block_bytes) as u64,
            };
        }
        let kv = KvManager::new_tiered(
            device_layer_blocks(&spec, scfg.block_size, self.cfg.device_kv_budget),
            self.cfg.host_layer_blocks,
            self.cfg.disk_layer_blocks,
            scfg.block_size,
            spec.n_layers,
        );
        let mut backend = if self.cfg.disk_layer_blocks > 0 {
            let dir = self.cfg.spill_dir.clone().unwrap_or_else(|| {
                // unique per serve() call: spill files are keyed only by
                // (request, layer), so engines sharing a directory would
                // corrupt each other's tensors
                use std::sync::atomic::{AtomicU64, Ordering};
                static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);
                std::env::temp_dir().join(format!(
                    "layerkv-spill-{}-{}",
                    std::process::id(),
                    SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
                ))
            });
            PjrtBackend::with_spill_dir(self.model.clone(), self.cfg.max_batch, dir)
        } else {
            PjrtBackend::new(self.model.clone(), self.cfg.max_batch)
        };
        backend.load_jobs(&jobs);
        let predictor = LengthPredictor::new(smax.max(2), 1.0, 42);
        let mut engine = Engine::with_parts(scfg, kv, backend, predictor);

        let report = engine.try_run(&trace)?;
        let stats = engine.stats().clone();
        let s = engine.backend.kv_stats();
        self.kv_stats.offloads += s.offloads;
        self.kv_stats.onloads += s.onloads;
        self.kv_stats.offload_bytes += s.offload_bytes;
        self.kv_stats.onload_bytes += s.onload_bytes;
        self.kv_stats.spills += s.spills;
        self.kv_stats.unspills += s.unspills;
        self.kv_stats.spill_bytes += s.spill_bytes;
        self.kv_stats.unspill_bytes += s.unspill_bytes;
        self.kv_stats.disk_read_bytes += s.disk_read_bytes;
        self.kv_stats.io_errors += s.io_errors;

        let mut results: Vec<ServeResult> = report
            .records
            .iter()
            .map(|rec| {
                let mut record = rec.clone();
                record.id = orig_ids[rec.id];
                ServeResult {
                    id: record.id,
                    output: engine.backend.take_output(rec.id),
                    record,
                }
            })
            .collect();
        results.sort_by_key(|r| r.id);
        let dropped = stats
            .dropped
            .iter()
            .map(|&rid| {
                (
                    orig_ids[rid],
                    format!(
                        "prompt of {} tokens cannot be served (exceeds every \
                         prefill bucket or the KV pools)",
                        trace.requests[rid].prompt_len
                    ),
                )
            })
            .collect();
        Ok(ServeOutcome { results, dropped, report, stats })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RefModel;

    fn engine(policy: Policy, budget: usize) -> RealEngine<RefModel> {
        RealEngine::with_model(
            Rc::new(RefModel::new()),
            RealEngineConfig {
                device_kv_budget: budget,
                policy,
                max_batch: 8,
                ..Default::default()
            },
        )
    }

    fn jobs(n: usize, prompt_len: usize, out: usize) -> Vec<ServeRequest> {
        (0..n)
            .map(|id| ServeRequest {
                id,
                prompt: (0..prompt_len).map(|i| ((id * 7 + i) % 256) as i32).collect(),
                max_new_tokens: out,
                arrival_s: 0.0,
            })
            .collect()
    }

    #[test]
    fn serves_batch_end_to_end() {
        let mut e = engine(Policy::LayerKv { slo_aware: true }, 2 << 20);
        let out = e.serve(jobs(4, 24, 8)).unwrap();
        assert_eq!(out.results.len(), 4);
        assert!(out.dropped.is_empty());
        for r in &out.results {
            assert_eq!(r.output.len(), 8);
            assert!(r.output.iter().all(|&t| (0..256).contains(&t)));
            assert!(r.record.finish >= r.record.first_token);
        }
        assert!(out.report.throughput_tok_s() > 0.0);
    }

    #[test]
    fn deterministic_outputs_across_runs() {
        let mut a = engine(Policy::LayerKv { slo_aware: true }, 2 << 20);
        let mut b = engine(Policy::LayerKv { slo_aware: true }, 2 << 20);
        let ra = a.serve(jobs(2, 16, 6)).unwrap();
        let rb = b.serve(jobs(2, 16, 6)).unwrap();
        for (x, y) in ra.results.iter().zip(&rb.results) {
            assert_eq!(x.output, y.output);
        }
    }

    #[test]
    fn offloading_engaged_under_tiny_budget_same_tokens() {
        // Ground truth with an ample budget...
        let mut big = engine(Policy::LayerKv { slo_aware: true }, 64 << 20);
        let rb = big.serve(jobs(3, 32, 6)).unwrap();
        // ...must match a budget so small most layers live on the host.
        let mut tiny = engine(Policy::LayerKv { slo_aware: true }, 4 << 10);
        let rt = tiny.serve(jobs(3, 32, 6)).unwrap();
        assert!(tiny.kv_stats().offload_bytes > 0, "tiny budget must offload");
        assert_eq!(rb.results.len(), rt.results.len());
        for (x, y) in rb.results.iter().zip(&rt.results) {
            assert_eq!(x.output, y.output, "offloading must not change tokens");
        }
    }

    #[test]
    fn oversized_prompt_is_dropped_with_reason_not_recorded() {
        let mut e = engine(Policy::LayerKv { slo_aware: true }, 2 << 20);
        let mut js = jobs(2, 16, 4);
        js.push(ServeRequest {
            id: 2,
            prompt: vec![1; 600], // > every prefill bucket (max 512)
            max_new_tokens: 4,
            arrival_s: 0.0,
        });
        let out = e.serve(js).unwrap();
        assert_eq!(out.results.len(), 2);
        assert_eq!(out.dropped.len(), 1);
        assert_eq!(out.dropped[0].0, 2);
        assert!(out.dropped[0].1.contains("600"));
        // no zero-length record skews the report
        assert_eq!(out.report.records.len(), 2);
        assert!(out.report.records.iter().all(|r| r.output_len > 0));
    }

    #[test]
    fn disk_spill_serves_what_a_starved_host_rejects_same_tokens() {
        // ground truth: ample host pool
        let mut ample = engine(Policy::LayerKv { slo_aware: true }, 2 << 20);
        let ra = ample.serve(jobs(4, 64, 6)).unwrap();
        assert_eq!(ra.results.len(), 4);

        // starved host (4 layer-blocks) + no disk: long prompts can never
        // park their non-retained layers -> rejected
        let spill_dir = std::env::temp_dir()
            .join(format!("layerkv-realengine-spill-{}", std::process::id()));
        let starved = |disk_blocks: usize| RealEngineConfig {
            device_kv_budget: 2 << 20,
            policy: Policy::LayerKv { slo_aware: true },
            max_batch: 8,
            host_layer_blocks: 4,
            disk_layer_blocks: disk_blocks,
            spill_dir: Some(spill_dir.clone()),
        };
        let mut no_disk = RealEngine::with_model(Rc::new(RefModel::new()), starved(0));
        let rn = no_disk.serve(jobs(4, 64, 6)).unwrap();
        assert!(
            !rn.dropped.is_empty(),
            "starved host without a disk tier must reject"
        );

        // same starved host + a disk tier: spill files engage, everything
        // completes, and the tokens match the ample-host ground truth
        let mut tiered = RealEngine::with_model(Rc::new(RefModel::new()), starved(4096));
        let rt = tiered.serve(jobs(4, 64, 6)).unwrap();
        assert!(rt.dropped.is_empty(), "disk tier must serve everything");
        assert_eq!(rt.results.len(), 4);
        assert!(
            tiered.kv_stats().spill_bytes > 0,
            "host saturation must write real spill files"
        );
        for (a, b) in ra.results.iter().zip(&rt.results) {
            assert_eq!(a.output, b.output, "spilling must not change tokens");
        }
        // all spill files are cleaned up on release
        let leftovers = std::fs::read_dir(&spill_dir)
            .map(|d| d.count())
            .unwrap_or(0);
        assert_eq!(leftovers, 0, "spill files must be deleted on release");
        std::fs::remove_dir_all(&spill_dir).ok();
    }

    #[test]
    fn tiny_config_pools_scale_with_budget() {
        let spec = RefModel::new().spec().clone();
        let one_block = 16 * 2 * spec.n_kv_heads * spec.head_dim * 4;
        assert_eq!(device_layer_blocks(&spec, 16, one_block), 1);
        assert_eq!(device_layer_blocks(&spec, 16, 10 * one_block), 10);
        let cfg = tiny_serving_config(&spec, Policy::Vllm, 4);
        assert_eq!(cfg.model.n_layers, spec.n_layers);
        assert_eq!(cfg.max_num_seqs, 4);
        assert_eq!(cfg.max_model_len, spec.max_seq);
    }
}
