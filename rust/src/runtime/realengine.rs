//! Real serving engine: continuous batching over the PJRT-compiled tiny
//! model with LayerKV-style layer-wise KV residency. This is the
//! end-to-end proof that all three layers compose — actual tokens flow
//! through the Pallas-kernel HLO, and the coordinator moves real per-layer
//! KV tensors between the bounded device pool and the host pool.
//!
//! Timings are wall-clock; the serving loop is Python-free.

use std::collections::VecDeque;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::Policy;
use crate::coordinator::request::ReqId;
use crate::metrics::{Report, RequestRecord};

use super::client::{argmax, TinyModel};
use super::kvstore::{KvStore, KvStoreStats};

/// One inference job for the real engine.
#[derive(Debug, Clone)]
pub struct ServeRequest {
    pub id: ReqId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    /// Seconds after engine start at which this request becomes visible.
    pub arrival_s: f64,
}

/// Completed job.
#[derive(Debug, Clone)]
pub struct ServeResult {
    pub id: ReqId,
    pub output: Vec<i32>,
    pub record: RequestRecord,
}

#[derive(Debug, Clone)]
pub struct RealEngineConfig {
    /// Device-pool byte budget for KV (small by default so layer-wise
    /// offloading actually exercises on the tiny model).
    pub device_kv_budget: usize,
    pub policy: Policy,
    /// Max decode lanes per step (must be <= largest decode bucket).
    pub max_batch: usize,
}

impl Default for RealEngineConfig {
    fn default() -> Self {
        RealEngineConfig {
            device_kv_budget: 2 << 20, // 2 MiB: a few requests' full KV
            policy: Policy::LayerKv { slo_aware: true },
            max_batch: 8,
        }
    }
}

struct Live {
    id: ReqId,
    tokens_generated: Vec<i32>,
    max_new: usize,
    arrival: f64,
    prefill_start: f64,
    first_token: f64,
    prompt_len: usize,
}

/// Synchronous continuous-batching loop over the PJRT model.
pub struct RealEngine {
    pub model: TinyModel,
    pub cfg: RealEngineConfig,
    store: KvStore,
}

impl RealEngine {
    pub fn load(artifacts_dir: &Path, cfg: RealEngineConfig) -> Result<Self> {
        let model = TinyModel::load(artifacts_dir)?;
        let store = KvStore::new(cfg.device_kv_budget);
        Ok(RealEngine { model, cfg, store })
    }

    pub fn kv_stats(&self) -> &KvStoreStats {
        &self.store.stats
    }

    /// Retained-layer choice at admission: LayerKV keeps a fraction that
    /// fits the device budget (long prompts -> fewer layers, mirroring the
    /// x-solve); the vLLM baseline wants everything resident.
    fn retained_for(&self, prompt_len: usize) -> Vec<usize> {
        let l = self.model.n_layers();
        match self.cfg.policy {
            Policy::Vllm => (0..l).collect(),
            Policy::LayerKv { .. } => {
                let m = &self.model.art.model;
                let layer_bytes = 2 * m.n_kv_heads * prompt_len * m.head_dim * 4;
                let fit = if layer_bytes == 0 {
                    l
                } else {
                    (self.store.device_free() / layer_bytes).min(l)
                };
                crate::coordinator::block::LayerBlockTable::interleaved_retained(l, fit)
            }
        }
    }

    /// Serve a whole batch of requests to completion (arrivals honoured by
    /// wall-clock). Returns per-request results + a latency report.
    pub fn serve(&mut self, mut jobs: Vec<ServeRequest>) -> Result<(Vec<ServeResult>, Report)> {
        jobs.sort_by(|a, b| a.arrival_s.partial_cmp(&b.arrival_s).unwrap());
        let t0 = Instant::now();
        let now = || t0.elapsed().as_secs_f64();

        let mut pending: VecDeque<ServeRequest> = jobs.into();
        let mut waiting: VecDeque<ServeRequest> = VecDeque::new();
        let mut running: Vec<Live> = Vec::new();
        let mut results: Vec<ServeResult> = Vec::new();

        let m = self.model.art.model.clone();
        let smax = m.max_seq;

        while !(pending.is_empty() && waiting.is_empty() && running.is_empty()) {
            // arrivals
            while pending.front().map(|j| j.arrival_s <= now()).unwrap_or(false) {
                waiting.push_back(pending.pop_front().unwrap());
            }

            // admission: prefill everything that fits a bucket (layer-wise
            // residency makes admission cheap; vLLM mode only admits when
            // the full KV fits the device budget)
            while let Some(job) = waiting.front() {
                let plen = job.prompt.len();
                let Some(_bucket) = self.model.art.prefill_bucket_for(plen) else {
                    // oversized prompt: reject
                    let job = waiting.pop_front().unwrap();
                    results.push(ServeResult {
                        id: job.id,
                        output: Vec::new(),
                        record: RequestRecord {
                            id: job.id,
                            arrival: job.arrival_s,
                            prefill_start: now(),
                            first_token: now(),
                            finish: now(),
                            prompt_len: plen,
                            output_len: 0,
                        },
                    });
                    continue;
                };
                let full_bytes = m.n_layers * 2 * m.n_kv_heads * plen * m.head_dim * 4;
                if matches!(self.cfg.policy, Policy::Vllm)
                    && self.store.device_free() < full_bytes
                    // degraded-admission escape: a prompt larger than the
                    // whole budget would head-of-line block forever; admit
                    // it alone on an empty pool and let it spill
                    && !(self.store.device_used() == 0 && running.is_empty())
                {
                    break; // vLLM: head-of-line blocked on device KV space
                }
                if running.len() >= self.cfg.max_batch {
                    break;
                }
                let job = waiting.pop_front().unwrap();
                let prefill_start = now();
                let out = self.model.prefill(&job.prompt)?;
                let first = argmax(&out.logits);
                let retained = self.retained_for(plen);
                self.store.insert(job.id, out.kv, &retained);
                let first_token = now();
                running.push(Live {
                    id: job.id,
                    tokens_generated: vec![first],
                    max_new: job.max_new_tokens,
                    arrival: job.arrival_s,
                    prefill_start,
                    first_token,
                    prompt_len: plen,
                });
            }

            // decode step over the resident subset
            if !running.is_empty() {
                // restore parked KV while budget allows (oldest first)
                for live in &running {
                    self.store.try_restore(live.id);
                }
                let mut lanes: Vec<usize> = (0..running.len())
                    .filter(|&i| self.store.fully_resident(running[i].id))
                    .take(self.cfg.max_batch)
                    .collect();
                if lanes.is_empty() {
                    lanes.push(0); // force progress with host streaming
                }
                let b = self
                    .model
                    .art
                    .decode_bucket_for(lanes.len())
                    .context("no decode bucket")?;

                let per_layer = b * 2 * m.n_kv_heads * smax * m.head_dim;
                let mut scratch: Vec<Vec<f32>> =
                    (0..m.n_layers).map(|_| vec![0.0; per_layer]).collect();
                let mut tokens = vec![0i32; b];
                let mut lens = vec![0i32; b];
                for (lane, &ri) in lanes.iter().enumerate() {
                    let live = &running[ri];
                    self.store.fill_scratch(live.id, &mut scratch, lane, b, smax);
                    tokens[lane] = *live.tokens_generated.last().unwrap();
                    lens[lane] = (live.prompt_len + live.tokens_generated.len() - 1) as i32;
                }

                let out = self.model.decode(&tokens, &lens, &mut scratch)?;
                let tnow = now();
                let mut finished: Vec<usize> = Vec::new();
                for (lane, &ri) in lanes.iter().enumerate() {
                    let live = &mut running[ri];
                    let next =
                        argmax(&out.logits[lane * m.vocab..(lane + 1) * m.vocab]);
                    self.store.append_from_scratch(
                        live.id,
                        &scratch,
                        lane,
                        b,
                        smax,
                        lens[lane] as usize,
                    );
                    live.tokens_generated.push(next);
                    let ctx = live.prompt_len + live.tokens_generated.len();
                    if live.tokens_generated.len() >= live.max_new || ctx >= smax {
                        finished.push(ri);
                    }
                }
                let _ = tnow;
                finished.sort_unstable_by(|a, b| b.cmp(a));
                for ri in finished {
                    let live = running.swap_remove(ri);
                    self.store.release(live.id);
                    let fin = now();
                    results.push(ServeResult {
                        id: live.id,
                        record: RequestRecord {
                            id: live.id,
                            arrival: live.arrival,
                            prefill_start: live.prefill_start,
                            first_token: live.first_token,
                            finish: fin,
                            prompt_len: live.prompt_len,
                            output_len: live.tokens_generated.len(),
                        },
                        output: live.tokens_generated,
                    });
                }
            } else if waiting.is_empty() {
                // idle: spin-wait for the next arrival (coarse sleep)
                if let Some(j) = pending.front() {
                    let dt = j.arrival_s - now();
                    if dt > 0.0 {
                        std::thread::sleep(std::time::Duration::from_secs_f64(dt.min(0.005)));
                    }
                }
            }
        }

        results.sort_by_key(|r| r.id);
        let report = Report::new(results.iter().map(|r| r.record.clone()).collect());
        Ok((results, report))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::artifacts::default_dir;

    fn engine(policy: Policy, budget: usize) -> Option<RealEngine> {
        let dir = default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return None;
        }
        RealEngine::load(
            &dir,
            RealEngineConfig { device_kv_budget: budget, policy, max_batch: 8 },
        )
        .ok()
    }

    fn jobs(n: usize, prompt_len: usize, out: usize) -> Vec<ServeRequest> {
        (0..n)
            .map(|id| ServeRequest {
                id,
                prompt: (0..prompt_len).map(|i| ((id * 7 + i) % 256) as i32).collect(),
                max_new_tokens: out,
                arrival_s: 0.0,
            })
            .collect()
    }

    #[test]
    fn serves_batch_end_to_end() {
        let Some(mut e) = engine(Policy::LayerKv { slo_aware: true }, 2 << 20) else { return };
        let (results, report) = e.serve(jobs(4, 24, 8)).unwrap();
        assert_eq!(results.len(), 4);
        for r in &results {
            assert_eq!(r.output.len(), 8);
            assert!(r.output.iter().all(|&t| (0..256).contains(&t)));
        }
        assert!(report.throughput_tok_s() > 0.0);
    }

    #[test]
    fn deterministic_outputs_across_runs() {
        let Some(mut a) = engine(Policy::LayerKv { slo_aware: true }, 2 << 20) else { return };
        let Some(mut b) = engine(Policy::LayerKv { slo_aware: true }, 2 << 20) else { return };
        let (ra, _) = a.serve(jobs(2, 16, 6)).unwrap();
        let (rb, _) = b.serve(jobs(2, 16, 6)).unwrap();
        for (x, y) in ra.iter().zip(&rb) {
            assert_eq!(x.output, y.output);
        }
    }

    #[test]
    fn offloading_engaged_under_tiny_budget_same_tokens() {
        // Ground truth with an ample budget...
        let Some(mut big) = engine(Policy::LayerKv { slo_aware: true }, 64 << 20) else { return };
        let (rb, _) = big.serve(jobs(3, 32, 6)).unwrap();
        // ...must match a budget so small most layers live on the host.
        let Some(mut tiny) = engine(Policy::LayerKv { slo_aware: true }, 16 << 10) else { return };
        let (rt, _) = tiny.serve(jobs(3, 32, 6)).unwrap();
        assert!(tiny.kv_stats().offload_bytes > 0, "tiny budget must offload");
        for (x, y) in rb.iter().zip(&rt) {
            assert_eq!(x.output, y.output, "offloading must not change tokens");
        }
    }
}
