//! A deterministic in-process `TokenModel`: the reference executor.
//!
//! The PJRT path needs compiled artifacts (`make artifacts`) and the real
//! `xla` bindings, neither of which exists in offline builds. `RefModel`
//! stands in with pure-Rust arithmetic that keeps the two properties the
//! serving stack's tests rely on:
//!
//! 1. **Prefill/decode consistency** — decoding token `n` on the cache of
//!    `prefill(prompt[..n])` produces exactly `prefill(prompt[..n+1])`'s
//!    next token, so recompute preemption and re-prefill are lossless.
//! 2. **Cache sensitivity** — the next token is a function of the *entire
//!    cache contents* (an exact dyadic-rational sum over every stored KV
//!    value), so any residency bug that corrupts or drops a KV row
//!    changes the output stream. Offload/onload that preserves bytes is
//!    numerically invisible, exactly like the real model.
//!
//! Every KV value is a multiple of 1/64 and every context sum stays below
//! 2^19/64, so f32 accumulation is exact and order-independent — outputs
//! are bit-deterministic across batch sizes and residency histories.

use anyhow::{ensure, Context, Result};

use super::artifacts::TinyModelConfig;
use super::client::{DecodeOut, LayerKv, PrefillOut, TokenModel};

/// One KV element: a deterministic function of (token, position, layer,
/// k/v plane, head, dim) in {0/64, ..., 63/64}.
fn kv_elem(token: i32, pos: usize, layer: usize, c: usize, h: usize, x: usize) -> f32 {
    let t = token.max(0) as u64;
    let v = t * 7
        + pos as u64 * 13
        + layer as u64 * 3
        + c as u64 * 17
        + h as u64 * 5
        + x as u64;
    (v % 64) as f32 / 64.0
}

/// Deterministic stand-in executor (see module docs).
#[derive(Debug, Clone)]
pub struct RefModel {
    cfg: TinyModelConfig,
    prefill_buckets: Vec<usize>,
    decode_batches: Vec<usize>,
}

impl RefModel {
    pub fn new() -> Self {
        RefModel {
            cfg: TinyModelConfig {
                vocab: 256,
                d_model: 128,
                n_layers: 4,
                n_heads: 4,
                n_kv_heads: 2,
                head_dim: 8,
                ffn_hidden: 256,
                max_seq: 512,
            },
            prefill_buckets: vec![16, 32, 64, 128, 256, 512],
            decode_batches: vec![1, 2, 4, 8],
        }
    }

    /// Contribution of one cache row (layer 0, K plane) to the context sum.
    fn row_sum(&self, token: i32, pos: usize) -> f32 {
        let (kh, d) = (self.cfg.n_kv_heads, self.cfg.head_dim);
        let mut s = 0.0f32;
        for h in 0..kh {
            for x in 0..d {
                s += kv_elem(token, pos, 0, 0, h, x);
            }
        }
        s
    }

    /// Greedy next token from (last input token, context rows incl. it,
    /// exact context sum).
    fn token_from(&self, token: i32, ctx_rows: usize, s: f32) -> i32 {
        let si = (s * 64.0).round() as u64; // exact: s is a multiple of 1/64
        let t = token.max(0) as u64;
        ((t * 31 + ctx_rows as u64 * 17 + si * 11) % self.cfg.vocab as u64) as i32
    }
}

impl Default for RefModel {
    fn default() -> Self {
        Self::new()
    }
}

impl TokenModel for RefModel {
    fn spec(&self) -> &TinyModelConfig {
        &self.cfg
    }

    fn prefill_bucket_for(&self, len: usize) -> Option<usize> {
        self.prefill_buckets.iter().copied().find(|&b| b >= len)
    }

    fn decode_bucket_for(&self, lanes: usize) -> Option<usize> {
        self.decode_batches.iter().copied().find(|&b| b >= lanes)
    }

    fn max_prefill_len(&self) -> usize {
        self.prefill_buckets.last().copied().unwrap_or(0)
    }

    fn max_decode_batch(&self) -> usize {
        self.decode_batches.last().copied().unwrap_or(1)
    }

    fn prefill(&self, tokens: &[i32]) -> Result<PrefillOut> {
        let t = tokens.len();
        ensure!(t > 0, "empty prompt");
        let bucket = self
            .prefill_bucket_for(t)
            .with_context(|| format!("prompt of {t} tokens exceeds all buckets"))?;
        let (kh, d, l) = (self.cfg.n_kv_heads, self.cfg.head_dim, self.cfg.n_layers);
        let mut kv = Vec::with_capacity(l);
        for layer in 0..l {
            // [2, KH, T, D] row-major, trimmed to the true prompt length
            let mut data = Vec::with_capacity(2 * kh * t * d);
            for c in 0..2 {
                for h in 0..kh {
                    for (p, &tok) in tokens.iter().enumerate() {
                        for x in 0..d {
                            data.push(kv_elem(tok, p, layer, c, h, x));
                        }
                    }
                }
            }
            kv.push(LayerKv { data, kh, t, d });
        }
        let mut s = 0.0f32;
        for (p, &tok) in tokens.iter().enumerate() {
            s += self.row_sum(tok, p);
        }
        let next = self.token_from(tokens[t - 1], t, s);
        let mut logits = vec![0.0f32; self.cfg.vocab];
        logits[next as usize] = 1.0;
        Ok(PrefillOut { logits, kv, bucket })
    }

    fn decode(&self, tokens: &[i32], lens: &[i32], kvs: &mut [Vec<f32>]) -> Result<DecodeOut> {
        let b = tokens.len();
        ensure!(lens.len() == b, "tokens/lens length mismatch");
        ensure!(
            self.decode_batches.contains(&b),
            "no decode executable for batch {b} (buckets: {:?})",
            self.decode_batches
        );
        let (kh, d, l, smax) =
            (self.cfg.n_kv_heads, self.cfg.head_dim, self.cfg.n_layers, self.cfg.max_seq);
        let per_layer = b * 2 * kh * smax * d;
        ensure!(kvs.len() == l, "kv layer count");
        for kv in kvs.iter() {
            ensure!(kv.len() == per_layer, "kv lane size");
        }

        let mut logits = vec![0.0f32; b * self.cfg.vocab];
        for lane in 0..b {
            let tok = tokens[lane];
            let t = lens[lane] as usize;
            ensure!(t < smax, "lane {lane} cache full ({t} >= {smax})");
            // context sum over the stored rows (layer 0, K plane) ...
            let mut s = 0.0f32;
            for h in 0..kh {
                let base = ((lane * 2 * kh + h) * smax) * d;
                for v in &kvs[0][base..base + t * d] {
                    s += *v;
                }
            }
            // ... plus the new row this step appends at position t
            s += self.row_sum(tok, t);
            // write the new token's KV row back into every layer's scratch
            for (layer, kv) in kvs.iter_mut().enumerate() {
                for c in 0..2 {
                    for h in 0..kh {
                        let base = (((lane * 2 + c) * kh + h) * smax + t) * d;
                        for x in 0..d {
                            kv[base + x] = kv_elem(tok, t, layer, c, h, x);
                        }
                    }
                }
            }
            let next = self.token_from(tok, t + 1, s);
            logits[lane * self.cfg.vocab + next as usize] = 1.0;
        }
        Ok(DecodeOut { logits, batch: b })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::argmax;

    fn scratch_for(m: &RefModel, b: usize) -> Vec<Vec<f32>> {
        let c = m.spec().clone();
        (0..c.n_layers)
            .map(|_| vec![0.0f32; b * 2 * c.n_kv_heads * c.max_seq * c.head_dim])
            .collect()
    }

    fn fill_lane(m: &RefModel, kv: &LayerKv, buf: &mut [f32], lane: usize) {
        let c = m.spec();
        for plane in 0..2 {
            for h in 0..c.n_kv_heads {
                let src = (plane * c.n_kv_heads + h) * kv.t * kv.d;
                let dst = (((lane * 2 + plane) * c.n_kv_heads + h) * c.max_seq) * kv.d;
                buf[dst..dst + kv.t * kv.d].copy_from_slice(&kv.data[src..src + kv.t * kv.d]);
            }
        }
    }

    #[test]
    fn prefill_decode_consistency() {
        // decode on prefill(p[..n-1])'s cache must equal prefill(p[..n])
        let m = RefModel::new();
        let prompt: Vec<i32> = (0..16).map(|i| (i * 13 + 5) % 256).collect();
        let full = m.prefill(&prompt).unwrap();
        let part = m.prefill(&prompt[..15]).unwrap();
        let mut kvs = scratch_for(&m, 1);
        for (layer, kv) in part.kv.iter().enumerate() {
            fill_lane(&m, kv, &mut kvs[layer], 0);
        }
        let out = m.decode(&[prompt[15]], &[15], &mut kvs).unwrap();
        assert_eq!(argmax(&full.logits), argmax(&out.logits));
    }

    #[test]
    fn decode_is_batch_invariant() {
        let m = RefModel::new();
        let p1: Vec<i32> = (0..12).map(|i| (i * 3 + 1) % 256).collect();
        let p2: Vec<i32> = (0..20).map(|i| (i * 11 + 2) % 256).collect();
        let o1 = m.prefill(&p1).unwrap();
        let o2 = m.prefill(&p2).unwrap();

        let mut both = scratch_for(&m, 2);
        for (layer, (a, c)) in o1.kv.iter().zip(&o2.kv).enumerate() {
            fill_lane(&m, a, &mut both[layer], 0);
            fill_lane(&m, c, &mut both[layer], 1);
        }
        let b2 = m.decode(&[7, 9], &[12, 20], &mut both).unwrap();

        let mut solo = scratch_for(&m, 1);
        for (layer, a) in o1.kv.iter().enumerate() {
            fill_lane(&m, a, &mut solo[layer], 0);
        }
        let b1 = m.decode(&[7], &[12], &mut solo).unwrap();
        let v = m.spec().vocab;
        assert_eq!(argmax(&b2.logits[..v]), argmax(&b1.logits[..v]));
    }

    #[test]
    fn output_depends_on_cache_contents() {
        // corrupt one stored KV value -> the next token changes
        let m = RefModel::new();
        let prompt: Vec<i32> = (0..24).map(|i| (i * 7) % 256).collect();
        let o = m.prefill(&prompt).unwrap();
        let mut clean = scratch_for(&m, 1);
        let mut dirty = scratch_for(&m, 1);
        for (layer, kv) in o.kv.iter().enumerate() {
            fill_lane(&m, kv, &mut clean[layer], 0);
            fill_lane(&m, kv, &mut dirty[layer], 0);
        }
        dirty[0][3] += 21.0 / 64.0; // layer 0, K plane, inside the context sum
        let a = m.decode(&[5], &[24], &mut clean).unwrap();
        let b = m.decode(&[5], &[24], &mut dirty).unwrap();
        assert_ne!(argmax(&a.logits), argmax(&b.logits));
    }

    #[test]
    fn bucket_lookup() {
        let m = RefModel::new();
        assert_eq!(m.prefill_bucket_for(1), Some(16));
        assert_eq!(m.prefill_bucket_for(17), Some(32));
        assert_eq!(m.prefill_bucket_for(513), None);
        assert_eq!(m.decode_bucket_for(3), Some(4));
        assert_eq!(m.max_decode_batch(), 8);
    }
}
