//! Serving front-end: a line-delimited TCP protocol over the real engine
//! (S18). Thread-per-connection with a shared single engine worker —
//! std::thread + mpsc stand in for tokio, which is unavailable offline
//! (DESIGN.md §2).
//!
//! Protocol (one JSON object per line):
//!   request:  {"id": 1, "prompt": [12, 7, ...], "max_new_tokens": 16}
//!   response: {"id": 1, "output": [...], "ttft_ms": 1.2, "tpot_ms": 0.4}
//!   rejected: {"id": 1, "error": "prompt of 600 tokens cannot be served ..."}
//!
//! Malformed requests (non-JSON, missing fields, non-integer prompt
//! tokens) get `{"error": ...}` back; rejected-but-well-formed requests
//! (e.g. oversized prompts) get `{"id": ..., "error": ...}` — they are
//! never silently coerced into the token stream or the latency records.
//!
//! The engine behind the socket is `Engine<PjrtBackend>` under whichever
//! scheduler `--policy` selects (vLLM baseline, LayerKV, LayerKV without
//! the SLO gate) — the same `make_scheduler` policies the simulator runs.
//!
//! Example session: `cargo run --release -- serve` then
//! `printf '{"id":1,"prompt":[1,2,3],"max_new_tokens":4}\n' | nc 127.0.0.1 7181`

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::rc::Rc;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use crate::runtime::{RealEngine, RealEngineConfig, RefModel, ServeRequest, TokenModel};
use crate::util::Json;

/// A queued inference job plus its reply channel.
struct Job {
    req: ServeRequest,
    reply: mpsc::Sender<String>,
}

/// Parse one request line.
fn parse_request(line: &str) -> Result<ServeRequest> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
    let id = j.req("id")?.as_usize().context("id")?;
    let arr = j.req("prompt")?.as_arr().context("prompt")?;
    let mut prompt = Vec::with_capacity(arr.len());
    for x in arr {
        // strict: a malformed token must produce a JSON error response,
        // never a silently-coerced 0 corrupting the token stream
        let v = x
            .as_f64()
            .filter(|v| v.fract() == 0.0 && (0.0..=i32::MAX as f64).contains(v))
            .context("prompt must be an array of non-negative integer token ids")?;
        prompt.push(v as i32);
    }
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = j.get("max_new_tokens").and_then(|x| x.as_usize()).unwrap_or(16);
    Ok(ServeRequest { id, prompt, max_new_tokens: max_new, arrival_s: 0.0 })
}

fn render_response(id: usize, output: &[i32], ttft_s: f64, tpot_s: f64) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("id".to_string(), Json::Num(id as f64));
    obj.insert(
        "output".to_string(),
        Json::Arr(output.iter().map(|&t| Json::Num(t as f64)).collect()),
    );
    obj.insert("ttft_ms".to_string(), Json::Num((ttft_s * 1e3 * 1e3).round() / 1e3));
    obj.insert("tpot_ms".to_string(), Json::Num((tpot_s * 1e3 * 1e3).round() / 1e3));
    Json::Obj(obj).dump()
}

/// `{"id": .., "error": ..}` (or just `{"error": ..}` when the id is
/// unknown), with proper JSON string escaping.
fn render_error(id: Option<usize>, msg: &str) -> String {
    let mut obj = BTreeMap::new();
    if let Some(id) = id {
        obj.insert("id".to_string(), Json::Num(id as f64));
    }
    obj.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(obj).dump()
}

/// Engine worker: drains the job queue, batching whatever is pending.
fn engine_worker<M: TokenModel>(mut engine: RealEngine<M>, rx: mpsc::Receiver<Job>) {
    while let Ok(first) = rx.recv() {
        // micro-batch: grab everything already queued
        let mut jobs = vec![first];
        while let Ok(j) = rx.try_recv() {
            jobs.push(j);
        }
        let reqs: Vec<ServeRequest> = jobs
            .iter()
            .enumerate()
            .map(|(i, j)| ServeRequest { id: i, ..j.req.clone() })
            .collect();
        match engine.serve(reqs) {
            Ok(out) => {
                for r in out.results {
                    let job = &jobs[r.id];
                    let line = render_response(
                        job.req.id,
                        &r.output,
                        r.record.ttft(),
                        r.record.tpot(),
                    );
                    let _ = job.reply.send(line);
                }
                // rejections come back as explicit errors, not fake records
                for (rid, why) in out.dropped {
                    let job = &jobs[rid];
                    let _ = job.reply.send(render_error(Some(job.req.id), &why));
                }
            }
            Err(e) => {
                for job in &jobs {
                    let _ = job.reply.send(render_error(Some(job.req.id), &format!("{e:#}")));
                }
            }
        }
    }
}

fn handle_conn(stream: TcpStream, tx: Arc<Mutex<mpsc::Sender<Job>>>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let reply = match parse_request(&line) {
            Ok(req) => {
                let (rtx, rrx) = mpsc::channel();
                {
                    let guard = tx.lock().expect("engine queue poisoned");
                    if guard.send(Job { req, reply: rtx }).is_err() {
                        break;
                    }
                }
                rrx.recv().unwrap_or_else(|_| render_error(None, "engine gone"))
            }
            Err(e) => render_error(None, &format!("{e:#}")),
        };
        if writeln!(writer, "{reply}").is_err() {
            break;
        }
    }
    let _ = peer;
}

/// Run the server (blocks forever). `artifacts_dir = None` serves the
/// deterministic in-process `RefModel` instead of the PJRT artifacts —
/// every `Policy` variant works on either executor.
pub fn serve(addr: &str, artifacts_dir: Option<&Path>, cfg: RealEngineConfig) -> Result<()> {
    let (tx, rx) = mpsc::channel::<Job>();
    // PJRT handles are not Send: the engine lives entirely on the worker
    // thread; load errors come back over a one-shot channel.
    let (init_tx, init_rx) = mpsc::channel::<std::result::Result<(), String>>();
    match artifacts_dir {
        Some(dir) => {
            let dir = dir.to_path_buf();
            std::thread::spawn(move || match RealEngine::load(&dir, cfg) {
                Ok(engine) => {
                    let _ = init_tx.send(Ok(()));
                    engine_worker(engine, rx);
                }
                Err(e) => {
                    let _ = init_tx.send(Err(format!("{e:#}")));
                }
            });
        }
        None => {
            std::thread::spawn(move || {
                let engine = RealEngine::with_model(Rc::new(RefModel::new()), cfg);
                let _ = init_tx.send(Ok(()));
                engine_worker(engine, rx);
            });
        }
    }
    init_rx
        .recv()
        .context("engine thread died during init")?
        .map_err(|e| anyhow::anyhow!(e))?;
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    println!("layerkv serving on {addr}");
    let tx = Arc::new(Mutex::new(tx));
    for stream in listener.incoming().flatten() {
        let tx = Arc::clone(&tx);
        std::thread::spawn(move || handle_conn(stream, tx));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_valid_request() {
        let r = parse_request(r#"{"id": 3, "prompt": [1, 2, 3], "max_new_tokens": 5}"#).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
    }

    #[test]
    fn default_max_new_tokens() {
        let r = parse_request(r#"{"id": 1, "prompt": [9]}"#).unwrap();
        assert_eq!(r.max_new_tokens, 16);
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"id": 1}"#).is_err());
        assert!(parse_request(r#"{"id": 1, "prompt": []}"#).is_err());
    }

    #[test]
    fn rejects_malformed_prompt_tokens_instead_of_coercing() {
        // these all used to silently become token 0
        for bad in [
            r#"{"id": 1, "prompt": ["seven"]}"#,
            r#"{"id": 1, "prompt": [1, null, 3]}"#,
            r#"{"id": 1, "prompt": [1.5]}"#,
            r#"{"id": 1, "prompt": [-2]}"#,
            r#"{"id": 1, "prompt": [true]}"#,
        ] {
            assert!(parse_request(bad).is_err(), "must reject {bad}");
        }
        // integral floats are fine (JSON has no integer type)
        assert_eq!(parse_request(r#"{"id": 1, "prompt": [2.0]}"#).unwrap().prompt, vec![2]);
    }

    #[test]
    fn response_roundtrips_as_json() {
        let line = render_response(7, &[1, 2], 0.0123, 0.004);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.req("id").unwrap().as_usize(), Some(7));
        assert_eq!(j.req("output").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.req("ttft_ms").unwrap().as_f64().unwrap() > 12.0);
    }

    #[test]
    fn error_responses_are_json_with_escaping() {
        let line = render_error(Some(4), "bad \"quote\" and \\slash");
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.req("id").unwrap().as_usize(), Some(4));
        assert_eq!(j.req("error").unwrap().as_str().unwrap(), "bad \"quote\" and \\slash");
        let anon = render_error(None, "nope");
        assert!(Json::parse(&anon).unwrap().get("id").is_none());
    }
}
