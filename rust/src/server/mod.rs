//! Serving front-end: a line-delimited TCP protocol over the real engine
//! (S18). Thread-per-connection over one or more engine workers —
//! std::thread + mpsc stand in for tokio, which is unavailable offline
//! (DESIGN.md §2).
//!
//! Protocol (one JSON object per line):
//!   request:  {"id": 1, "prompt": [12, 7, ...], "max_new_tokens": 16}
//!   response: {"id": 1, "output": [...], "ttft_ms": 1.2, "tpot_ms": 0.4}
//!   rejected: {"id": 1, "error": "prompt of 600 tokens cannot be served ..."}
//!
//! Malformed requests (non-JSON, missing fields, non-integer prompt
//! tokens) get `{"error": ...}` back; rejected-but-well-formed requests
//! (e.g. oversized prompts) get `{"id": ..., "error": ...}` — they are
//! never silently coerced into the token stream or the latency records.
//!
//! The engine behind the socket is `Engine<PjrtBackend>` under whichever
//! scheduler `--policy` selects (vLLM baseline, LayerKV, LayerKV without
//! the SLO gate) — the same `make_scheduler` policies the simulator runs.
//!
//! With `--replicas N` the front-end runs N engine workers — each its own
//! thread, its own engine, its own job queue, exactly the shape of one
//! serving process per replica in a real deployment — and routes every
//! request with the `cluster/` router policy selected by `--router`.
//! Worker engines cannot be inspected across threads (as replica
//! processes cannot across nodes), so the front-end routes on its own
//! load ledger: queued jobs, in-flight tokens (the KV-demand proxy a
//! replica would export), and an EWMA of each worker's delivered TTFTs.
//!
//! Worker failure is handled at the front-end, not the client: every job
//! waits on its reply with a bounded timeout, a worker that misses it (or
//! whose thread died) is fenced out of the ledger, and the job fails over
//! to the survivors under a deterministic jittered exponential backoff —
//! one attempt per configured worker, so a request is answered or
//! explicitly errored, never silently lost. This is the thread-level
//! twin of `cluster::faults`' crash failover.
//!
//! The same port answers `GET /metrics` with the Prometheus text
//! exposition format: protocol counters (requests/responses/failovers),
//! the routing ledger (queued jobs/tokens, TTFT EWMA, liveness) and the
//! accumulated `EngineStats` of every worker, labelled by worker index.
//! `GET /healthz` is the liveness probe: per-worker `{dead, hung,
//! fenced}` as JSON, HTTP 200 while any worker is routable and 503 once
//! the whole fleet is fenced.
//!
//! Example session: `cargo run --release -- serve` then
//! `printf '{"id":1,"prompt":[1,2,3],"max_new_tokens":4}\n' | nc 127.0.0.1 7181`
//! or `curl http://127.0.0.1:7181/metrics`

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::cluster::router::ewma_update;
use crate::cluster::RouterPolicy;
use crate::coordinator::EngineStats;
use crate::runtime::{RealEngine, RealEngineConfig, RefModel, ServeRequest, TokenModel};
use crate::util::{Json, Rng};

/// A queued inference job plus its reply channel.
struct Job {
    req: ServeRequest,
    reply: mpsc::Sender<String>,
}

/// One worker's share of the front-end load ledger.
#[derive(Debug, Clone, Default)]
struct WorkerLoad {
    /// Jobs routed here and not yet answered.
    queued_jobs: usize,
    /// Σ (prompt + max_new) tokens of those jobs — the KV-demand proxy.
    queued_tokens: usize,
    /// EWMA of TTFTs this worker delivered (None until the first).
    ewma_ttft_s: Option<f64>,
    /// Its queue receiver is gone (worker thread died): never route here
    /// again, and ignore whatever in-flight ledger shares it froze.
    dead: bool,
    /// It missed a reply deadline (wedged engine, still holding its
    /// queue): fenced like dead, but reported distinctly on `/healthz`.
    hung: bool,
}

impl WorkerLoad {
    /// Out of the routing rotation for any reason.
    fn fenced(&self) -> bool {
        self.dead || self.hung
    }
}

/// Rough per-token service time of the CPU executors — only used to put
/// queued tokens and observed TTFT on one axis for slo-aware picks.
const SERVE_TOKEN_S: f64 = 1e-3;

/// How long the front-end waits for a worker to answer one job before
/// fencing it as hung. Generous: covers a full micro-batch on the CPU
/// executors, so only a genuinely wedged engine trips it.
const REPLY_TIMEOUT: Duration = Duration::from_secs(120);

/// Base delay of the jittered exponential backoff between failover
/// attempts: doubles each attempt, scaled by a deterministic jitter in
/// [0.5, 1.0) so retrying clients spread out instead of thundering.
const BACKOFF_BASE_S: f64 = 5e-3;

/// Pick a live worker for a job of `tokens` under `policy`; None when
/// every worker is dead. `rr` is the round-robin cursor value for this
/// job. Ties break toward the lowest index, like the simulation router.
fn pick_worker(policy: RouterPolicy, loads: &[WorkerLoad], rr: usize) -> Option<usize> {
    let alive = loads.iter().filter(|l| !l.fenced()).count();
    if alive == 0 {
        return None;
    }
    let argmin = |score: &dyn Fn(&WorkerLoad) -> f64| -> usize {
        let mut best = 0usize;
        let mut best_score = f64::INFINITY;
        for (i, l) in loads.iter().enumerate() {
            if l.fenced() {
                continue;
            }
            let s = score(l);
            if s < best_score {
                best_score = s;
                best = i;
            }
        }
        best
    };
    Some(match policy {
        RouterPolicy::RoundRobin => {
            // cycle over the live workers only
            let nth = rr % alive;
            loads
                .iter()
                .enumerate()
                .filter(|(_, l)| !l.fenced())
                .nth(nth)
                .map(|(i, _)| i)
                .unwrap_or(0)
        }
        RouterPolicy::JoinShortestQueue => argmin(&|l| l.queued_jobs as f64),
        RouterPolicy::KvPressure => argmin(&|l| l.queued_tokens as f64),
        RouterPolicy::SloAware => argmin(&|l| {
            l.queued_tokens as f64 * SERVE_TOKEN_S + l.ewma_ttft_s.unwrap_or(0.0)
        }),
    })
}

/// One worker's accumulated serving totals for `/metrics` — folded in
/// batch by batch as its engine finishes `serve` calls.
#[derive(Debug, Clone, Default)]
struct WorkerStats {
    /// Micro-batches this worker served.
    batches: u64,
    /// Well-formed requests the engine rejected (oversized prompts etc.).
    rejected: u64,
    /// Engine counters summed across batches (`dropped` stays empty: the
    /// ids are batch-local and meaningless across batches).
    engine: EngineStats,
}

/// Fold one batch's engine counters into a worker's running totals.
fn fold_stats(acc: &mut EngineStats, s: &EngineStats) {
    acc.steps += s.steps;
    acc.prefill_steps += s.prefill_steps;
    acc.decode_steps += s.decode_steps;
    acc.preemptions += s.preemptions;
    acc.proactive_offload_layers += s.proactive_offload_layers;
    acc.oom_forced_offload_layers += s.oom_forced_offload_layers;
    acc.onloaded_layers += s.onloaded_layers;
    acc.offload_bytes += s.offload_bytes;
    acc.onload_stream_bytes += s.onload_stream_bytes;
    acc.stream_stall_s += s.stream_stall_s;
    acc.contention_s += s.contention_s;
    acc.spilled_layers += s.spilled_layers;
    acc.disk_promoted_layers += s.disk_promoted_layers;
    acc.spill_bytes += s.spill_bytes;
    acc.disk_restore_bytes += s.disk_restore_bytes;
    acc.disk_stream_bytes += s.disk_stream_bytes;
    acc.disk_stall_s += s.disk_stall_s;
    acc.disk_io_errors += s.disk_io_errors;
    acc.disk_fenced |= s.disk_fenced;
    acc.prefix_hits += s.prefix_hits;
    acc.prefix_misses += s.prefix_misses;
    acc.prefix_hit_tokens += s.prefix_hit_tokens;
    acc.prefix_inserts += s.prefix_inserts;
    acc.prefix_evictions += s.prefix_evictions;
    acc.prefix_demotions += s.prefix_demotions;
    acc.prefix_promotions += s.prefix_promotions;
    acc.prefix_restore_bytes += s.prefix_restore_bytes;
    acc.ckpt_writes += s.ckpt_writes;
    acc.ckpt_bytes += s.ckpt_bytes;
    acc.ckpt_write_s += s.ckpt_write_s;
    acc.adoptions += s.adoptions;
    acc.adopt_restore_bytes += s.adopt_restore_bytes;
}

/// Append one `# HELP` + `# TYPE` header pair (Prometheus text format).
fn prom_family(out: &mut String, name: &str, kind: &str, help: &str) {
    use std::fmt::Write as _;
    let _ = writeln!(out, "# HELP layerkv_{name} {help}");
    let _ = writeln!(out, "# TYPE layerkv_{name} {kind}");
}

/// Append one sample line, optionally labelled with its worker index.
fn prom_sample(out: &mut String, name: &str, worker: Option<usize>, v: f64) {
    use std::fmt::Write as _;
    let _ = match worker {
        Some(w) => writeln!(out, "layerkv_{name}{{worker=\"{w}\"}} {v}"),
        None => writeln!(out, "layerkv_{name} {v}"),
    };
}

/// The shared front-end: per-worker queues plus the load ledger the
/// router reads.
struct Frontend {
    policy: RouterPolicy,
    rr: AtomicUsize,
    loads: Mutex<Vec<WorkerLoad>>,
    txs: Vec<Mutex<mpsc::Sender<Job>>>,
    /// Per-job reply deadline; missing it fences the worker as hung.
    reply_timeout: Duration,
    /// Base delay of the failover backoff (doubles per attempt).
    backoff_base_s: f64,
    /// Sleep hook between failover attempts — injectable so integration
    /// tests record the exact deterministic backoff schedule instead of
    /// actually sleeping through it.
    sleeper: Box<dyn Fn(Duration) + Send + Sync>,
    /// Protocol counters for `/metrics`.
    requests_total: AtomicU64,
    responses_ok: AtomicU64,
    responses_err: AtomicU64,
    /// Workers fenced out of routing (crash, hang, or dead queue).
    failovers_total: AtomicU64,
    /// Per-worker engine totals, folded in as batches complete.
    worker_stats: Mutex<Vec<WorkerStats>>,
}

impl Frontend {
    fn new(policy: RouterPolicy, txs: Vec<mpsc::Sender<Job>>) -> Self {
        Frontend {
            policy,
            rr: AtomicUsize::new(0),
            loads: Mutex::new(vec![WorkerLoad::default(); txs.len()]),
            worker_stats: Mutex::new(vec![WorkerStats::default(); txs.len()]),
            txs: txs.into_iter().map(Mutex::new).collect(),
            reply_timeout: REPLY_TIMEOUT,
            backoff_base_s: BACKOFF_BASE_S,
            sleeper: Box::new(|d| std::thread::sleep(d)),
            requests_total: AtomicU64::new(0),
            responses_ok: AtomicU64::new(0),
            responses_err: AtomicU64::new(0),
            failovers_total: AtomicU64::new(0),
        }
    }

    #[cfg(test)]
    fn with_reply_timeout(mut self, d: Duration) -> Self {
        self.reply_timeout = d;
        self
    }

    /// Replace the backoff schedule (base seconds + sleep hook). Tests
    /// inject a recorder so failover runs deterministically with no real
    /// sleeping; the jitter itself is already seeded per request id.
    #[cfg(test)]
    fn with_backoff(
        mut self,
        base_s: f64,
        sleeper: Box<dyn Fn(Duration) + Send + Sync>,
    ) -> Self {
        self.backoff_base_s = base_s;
        self.sleeper = sleeper;
        self
    }

    /// Fence a worker out of routing — `hung` for a missed reply deadline
    /// (the thread still holds its queue), dead for a dropped queue. Its
    /// in-flight ledger shares are frozen but ignored from here on;
    /// `saturating_sub` keeps any late `job_done` from a merely-slow
    /// worker harmless.
    fn fence(&self, worker: usize, hung: bool) {
        let l = &mut self.loads.lock().expect("load ledger poisoned")[worker];
        if hung {
            l.hung = true;
        } else {
            l.dead = true;
        }
        self.failovers_total.fetch_add(1, Ordering::Relaxed);
    }

    /// Route and enqueue one job, returning the worker it landed on;
    /// `None` only when every worker is gone. A send failure marks that
    /// worker dead and retries the others, so one crashed engine degrades
    /// capacity instead of killing clients.
    fn dispatch(&self, req: ServeRequest, reply: mpsc::Sender<String>) -> Option<usize> {
        let tokens = req.prompt.len() + req.max_new_tokens;
        let mut job = Job { req, reply };
        for _ in 0..self.txs.len() {
            let w = {
                let mut loads = self.loads.lock().expect("load ledger poisoned");
                let rr = self.rr.fetch_add(1, Ordering::Relaxed);
                let Some(w) = pick_worker(self.policy, &loads, rr) else {
                    return None; // every worker is dead
                };
                loads[w].queued_jobs += 1;
                loads[w].queued_tokens += tokens;
                w
            };
            let result = {
                let guard = self.txs[w].lock().expect("engine queue poisoned");
                guard.send(job)
            };
            match result {
                Ok(()) => return Some(w),
                Err(mpsc::SendError(unsent)) => {
                    // recover the job, roll the ledger share back, and
                    // fence the dead worker off before retrying
                    job = unsent;
                    let mut loads = self.loads.lock().expect("load ledger poisoned");
                    loads[w].queued_jobs = loads[w].queued_jobs.saturating_sub(1);
                    loads[w].queued_tokens = loads[w].queued_tokens.saturating_sub(tokens);
                    loads[w].dead = true;
                    self.failovers_total.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
        None
    }

    /// Serve one request end to end: dispatch, wait (bounded) for the
    /// reply, and on a hung or dead worker fence it and fail the job over
    /// — with jittered exponential backoff between attempts — until a
    /// reply arrives or every worker has been tried. Always returns
    /// exactly one response line per request (a JSON error when the fleet
    /// is gone), so request ids are conserved at the client no matter
    /// which workers die.
    fn call(&self, req: ServeRequest, rng: &mut Rng) -> String {
        let id = req.id;
        for attempt in 0..self.txs.len() {
            if attempt > 0 {
                let base = self.backoff_base_s * (1u64 << (attempt - 1).min(10)) as f64;
                (self.sleeper)(Duration::from_secs_f64(base * (0.5 + 0.5 * rng.f64())));
            }
            let (rtx, rrx) = mpsc::channel();
            let Some(w) = self.dispatch(req.clone(), rtx) else { break };
            match rrx.recv_timeout(self.reply_timeout) {
                Ok(line) => return line,
                // timeout: the worker is hung on this job (or wedged
                // behind one). Fence it; if it ever answers, the reply
                // lands in this dropped channel and the ledger update is
                // ignored (fenced workers are never routed to again).
                Err(mpsc::RecvTimeoutError::Timeout) => self.fence(w, true),
                // the worker thread died mid-batch and dropped our reply
                // sender: dead, not hung
                Err(mpsc::RecvTimeoutError::Disconnected) => self.fence(w, false),
            }
        }
        render_error(Some(id), "no live engine workers")
    }

    /// A worker finished (or rejected) a job: release its ledger share
    /// and feed the TTFT back when one was delivered.
    fn job_done(&self, worker: usize, tokens: usize, ttft_s: Option<f64>) {
        let mut loads = self.loads.lock().expect("load ledger poisoned");
        let l = &mut loads[worker];
        l.queued_jobs = l.queued_jobs.saturating_sub(1);
        l.queued_tokens = l.queued_tokens.saturating_sub(tokens);
        if let Some(t) = ttft_s {
            l.ewma_ttft_s = Some(ewma_update(l.ewma_ttft_s, t));
        }
    }

    /// Fold one served batch's engine counters into a worker's totals.
    fn record_batch(&self, worker: usize, s: &EngineStats, rejected: u64) {
        let mut all = self.worker_stats.lock().expect("worker stats poisoned");
        let w = &mut all[worker];
        w.batches += 1;
        w.rejected += rejected;
        fold_stats(&mut w.engine, s);
    }

    /// Render the live `/metrics` payload in the Prometheus text
    /// exposition format: protocol counters, the routing ledger, and
    /// every engine counter as a per-worker series.
    fn metrics_text(&self) -> String {
        let mut o = String::new();
        prom_family(&mut o, "requests_total", "counter", "Request lines received");
        prom_sample(
            &mut o,
            "requests_total",
            None,
            self.requests_total.load(Ordering::Relaxed) as f64,
        );
        prom_family(&mut o, "responses_total", "counter", "Responses sent, by status");
        {
            use std::fmt::Write as _;
            let ok = self.responses_ok.load(Ordering::Relaxed);
            let err = self.responses_err.load(Ordering::Relaxed);
            let _ = writeln!(o, "layerkv_responses_total{{status=\"ok\"}} {ok}");
            let _ = writeln!(o, "layerkv_responses_total{{status=\"error\"}} {err}");
        }
        prom_family(&mut o, "failovers_total", "counter", "Workers fenced out of routing");
        prom_sample(
            &mut o,
            "failovers_total",
            None,
            self.failovers_total.load(Ordering::Relaxed) as f64,
        );

        let loads = self.loads.lock().expect("load ledger poisoned").clone();
        prom_family(&mut o, "worker_up", "gauge", "1 while the worker is routable");
        for (i, l) in loads.iter().enumerate() {
            prom_sample(&mut o, "worker_up", Some(i), if l.fenced() { 0.0 } else { 1.0 });
        }
        prom_family(&mut o, "worker_queued_jobs", "gauge", "Jobs routed and unanswered");
        for (i, l) in loads.iter().enumerate() {
            prom_sample(&mut o, "worker_queued_jobs", Some(i), l.queued_jobs as f64);
        }
        prom_family(
            &mut o,
            "worker_queued_tokens",
            "gauge",
            "Prompt+decode tokens of queued jobs (KV-demand proxy)",
        );
        for (i, l) in loads.iter().enumerate() {
            prom_sample(&mut o, "worker_queued_tokens", Some(i), l.queued_tokens as f64);
        }
        prom_family(
            &mut o,
            "worker_ttft_ewma_seconds",
            "gauge",
            "EWMA of delivered TTFTs (0 until the first)",
        );
        for (i, l) in loads.iter().enumerate() {
            prom_sample(
                &mut o,
                "worker_ttft_ewma_seconds",
                Some(i),
                l.ewma_ttft_s.unwrap_or(0.0),
            );
        }

        let stats = self.worker_stats.lock().expect("worker stats poisoned").clone();
        prom_family(&mut o, "worker_batches_total", "counter", "Micro-batches served");
        for (i, w) in stats.iter().enumerate() {
            prom_sample(&mut o, "worker_batches_total", Some(i), w.batches as f64);
        }
        prom_family(
            &mut o,
            "worker_rejected_total",
            "counter",
            "Well-formed requests the engine rejected",
        );
        for (i, w) in stats.iter().enumerate() {
            prom_sample(&mut o, "worker_rejected_total", Some(i), w.rejected as f64);
        }
        // coerce each closure to a fn pointer so one loop renders the
        // whole engine-counter table
        type Get = fn(&EngineStats) -> f64;
        let engine_counters: &[(&str, &str, Get)] = &[
            ("engine_steps_total", "Scheduler steps executed", |s| s.steps as f64),
            ("engine_prefill_steps_total", "Prefill steps", |s| s.prefill_steps as f64),
            ("engine_decode_steps_total", "Decode steps", |s| s.decode_steps as f64),
            ("engine_preemptions_total", "Recompute preemptions", |s| s.preemptions as f64),
            (
                "engine_proactive_offload_layers_total",
                "Layers offloaded GPU->host proactively",
                |s| s.proactive_offload_layers as f64,
            ),
            (
                "engine_oom_offload_layers_total",
                "Layers force-offloaded under GPU pressure",
                |s| s.oom_forced_offload_layers as f64,
            ),
            ("engine_onload_layers_total", "Layers restored host->GPU", |s| {
                s.onloaded_layers as f64
            }),
            ("engine_offload_bytes_total", "Bytes offloaded GPU->host", |s| s.offload_bytes),
            (
                "engine_onload_stream_bytes_total",
                "Bytes streamed host->GPU during decode",
                |s| s.onload_stream_bytes,
            ),
            (
                "engine_stream_stall_seconds_total",
                "Decode time lost to host-KV streaming",
                |s| s.stream_stall_s,
            ),
            (
                "engine_contention_seconds_total",
                "Decode time lost to PCIe contention",
                |s| s.contention_s,
            ),
            ("engine_spilled_layers_total", "Layers spilled host->disk", |s| {
                s.spilled_layers as f64
            }),
            (
                "engine_disk_promoted_layers_total",
                "Layers restored disk->GPU",
                |s| s.disk_promoted_layers as f64,
            ),
            ("engine_spill_bytes_total", "Bytes written to the disk tier", |s| s.spill_bytes),
            (
                "engine_disk_restore_bytes_total",
                "Bytes read back from the disk tier",
                |s| s.disk_restore_bytes,
            ),
            (
                "engine_disk_stream_bytes_total",
                "Bytes decode streamed from disk",
                |s| s.disk_stream_bytes,
            ),
            (
                "engine_disk_stall_seconds_total",
                "Decode time lost to the disk link",
                |s| s.disk_stall_s,
            ),
            ("engine_disk_io_errors_total", "Disk-tier I/O failures", |s| {
                s.disk_io_errors as f64
            }),
            ("engine_disk_fenced", "1 after the disk tier was retired", |s| {
                if s.disk_fenced {
                    1.0
                } else {
                    0.0
                }
            }),
            ("engine_prefix_hits_total", "Prefix-cache hits", |s| s.prefix_hits as f64),
            ("engine_prefix_misses_total", "Prefix-cache misses", |s| s.prefix_misses as f64),
            (
                "engine_prefix_hit_tokens_total",
                "Prompt tokens served from the prefix cache",
                |s| s.prefix_hit_tokens as f64,
            ),
            ("engine_prefix_inserts_total", "Prefix-cache inserts", |s| {
                s.prefix_inserts as f64
            }),
            ("engine_prefix_evictions_total", "Prefix-cache evictions", |s| {
                s.prefix_evictions as f64
            }),
            ("engine_prefix_demotions_total", "Prefix entries demoted a tier", |s| {
                s.prefix_demotions as f64
            }),
            ("engine_prefix_promotions_total", "Prefix entries promoted to GPU", |s| {
                s.prefix_promotions as f64
            }),
            (
                "engine_prefix_restore_bytes_total",
                "Bytes restored to serve prefix hits",
                |s| s.prefix_restore_bytes,
            ),
            ("engine_ckpt_writes_total", "Incremental KV checkpoints written", |s| {
                s.ckpt_writes as f64
            }),
            ("engine_ckpt_bytes_total", "Bytes of KV checkpointed to disk", |s| s.ckpt_bytes),
            (
                "engine_ckpt_write_seconds_total",
                "Idle-link time spent writing checkpoints",
                |s| s.ckpt_write_s,
            ),
            ("engine_adoptions_total", "Requests adopted from checkpoints", |s| {
                s.adoptions as f64
            }),
            (
                "engine_adopt_restore_bytes_total",
                "Bytes read back restoring adopted requests",
                |s| s.adopt_restore_bytes,
            ),
        ];
        for (name, help, get) in engine_counters {
            let kind = if *name == "engine_disk_fenced" { "gauge" } else { "counter" };
            prom_family(&mut o, name, kind, help);
            for (i, w) in stats.iter().enumerate() {
                prom_sample(&mut o, name, Some(i), get(&w.engine));
            }
        }
        o
    }

    /// The `/healthz` body plus its routability verdict: per-worker
    /// `{dead, hung, fenced}` and an overall status — `true` (HTTP 200)
    /// while at least one worker is routable, `false` (503) when the
    /// whole fleet is fenced.
    fn healthz_json(&self) -> (bool, String) {
        let loads = self.loads.lock().expect("load ledger poisoned").clone();
        let any_up = loads.iter().any(|l| !l.fenced());
        let workers: Vec<Json> = loads
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let mut o = BTreeMap::new();
                o.insert("worker".to_string(), Json::Num(i as f64));
                o.insert("dead".to_string(), Json::Bool(l.dead));
                o.insert("hung".to_string(), Json::Bool(l.hung));
                o.insert("fenced".to_string(), Json::Bool(l.fenced()));
                Json::Obj(o)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert(
            "status".to_string(),
            Json::Str(if any_up { "ok" } else { "down" }.to_string()),
        );
        top.insert("workers".to_string(), Json::Arr(workers));
        (any_up, Json::Obj(top).dump())
    }
}

/// Parse one request line.
fn parse_request(line: &str) -> Result<ServeRequest> {
    let j = Json::parse(line).map_err(|e| anyhow::anyhow!("{e}"))?;
    let id = j.req("id")?.as_usize().context("id")?;
    let arr = j.req("prompt")?.as_arr().context("prompt")?;
    let mut prompt = Vec::with_capacity(arr.len());
    for x in arr {
        // strict: a malformed token must produce a JSON error response,
        // never a silently-coerced 0 corrupting the token stream
        let v = x
            .as_f64()
            .filter(|v| v.fract() == 0.0 && (0.0..=i32::MAX as f64).contains(v))
            .context("prompt must be an array of non-negative integer token ids")?;
        prompt.push(v as i32);
    }
    anyhow::ensure!(!prompt.is_empty(), "empty prompt");
    let max_new = j.get("max_new_tokens").and_then(|x| x.as_usize()).unwrap_or(16);
    Ok(ServeRequest { id, prompt, max_new_tokens: max_new, arrival_s: 0.0 })
}

fn render_response(id: usize, output: &[i32], ttft_s: f64, tpot_s: f64) -> String {
    let mut obj = BTreeMap::new();
    obj.insert("id".to_string(), Json::Num(id as f64));
    obj.insert(
        "output".to_string(),
        Json::Arr(output.iter().map(|&t| Json::Num(t as f64)).collect()),
    );
    obj.insert("ttft_ms".to_string(), Json::Num((ttft_s * 1e3 * 1e3).round() / 1e3));
    obj.insert("tpot_ms".to_string(), Json::Num((tpot_s * 1e3 * 1e3).round() / 1e3));
    Json::Obj(obj).dump()
}

/// `{"id": .., "error": ..}` (or just `{"error": ..}` when the id is
/// unknown), with proper JSON string escaping.
fn render_error(id: Option<usize>, msg: &str) -> String {
    let mut obj = BTreeMap::new();
    if let Some(id) = id {
        obj.insert("id".to_string(), Json::Num(id as f64));
    }
    obj.insert("error".to_string(), Json::Str(msg.to_string()));
    Json::Obj(obj).dump()
}

/// Engine worker: drains its job queue, batching whatever is pending,
/// and reports completions back to the front-end ledger.
fn engine_worker<M: TokenModel>(
    mut engine: RealEngine<M>,
    rx: mpsc::Receiver<Job>,
    front: Arc<Frontend>,
    worker: usize,
) {
    let job_tokens =
        |j: &Job| -> usize { j.req.prompt.len() + j.req.max_new_tokens };
    while let Ok(first) = rx.recv() {
        // micro-batch: grab everything already queued
        let mut jobs = vec![first];
        while let Ok(j) = rx.try_recv() {
            jobs.push(j);
        }
        let reqs: Vec<ServeRequest> = jobs
            .iter()
            .enumerate()
            .map(|(i, j)| ServeRequest { id: i, ..j.req.clone() })
            .collect();
        match engine.serve(reqs) {
            Ok(out) => {
                front.record_batch(worker, &out.stats, out.dropped.len() as u64);
                for r in out.results {
                    let job = &jobs[r.id];
                    let line = render_response(
                        job.req.id,
                        &r.output,
                        r.record.ttft(),
                        r.record.tpot(),
                    );
                    front.job_done(worker, job_tokens(job), Some(r.record.ttft()));
                    let _ = job.reply.send(line);
                }
                // rejections come back as explicit errors, not fake records
                for (rid, why) in out.dropped {
                    let job = &jobs[rid];
                    front.job_done(worker, job_tokens(job), None);
                    let _ = job.reply.send(render_error(Some(job.req.id), &why));
                }
            }
            Err(e) => {
                for job in &jobs {
                    front.job_done(worker, job_tokens(job), None);
                    let _ = job.reply.send(render_error(Some(job.req.id), &format!("{e:#}")));
                }
            }
        }
    }
}

/// Full HTTP response for a `GET <path>` line on the JSON port — the
/// `/metrics` scrape surface (Prometheus text format) and the
/// `/healthz` liveness probe (JSON per-worker `{dead, hung, fenced}`,
/// 200 while any worker is routable, 503 when the whole fleet is
/// fenced); anything else is a 404. Split out of `handle_conn` so it
/// tests without a socket.
fn http_response(path: &str, front: &Frontend) -> String {
    let (status, ctype, body) = if path == "/metrics" {
        ("200 OK", "text/plain; version=0.0.4", front.metrics_text())
    } else if path == "/healthz" {
        let (up, body) = front.healthz_json();
        let status = if up { "200 OK" } else { "503 Service Unavailable" };
        (status, "application/json", body + "\n")
    } else {
        ("404 Not Found", "text/plain; version=0.0.4", "not found\n".to_string())
    };
    format!(
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )
}

fn handle_conn(stream: TcpStream, front: Arc<Frontend>) {
    let peer = stream.peer_addr().ok();
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        // an HTTP GET on the JSON port: answer the scrape and close (the
        // remaining header lines die with the connection)
        if let Some(rest) = line.strip_prefix("GET ") {
            let path = rest.split_whitespace().next().unwrap_or("");
            let _ = write!(writer, "{}", http_response(path, &front));
            return;
        }
        front.requests_total.fetch_add(1, Ordering::Relaxed);
        let reply = match parse_request(&line) {
            Ok(req) => {
                // per-request deterministic jitter seed: replays of the
                // same request sequence back off identically
                let mut rng = Rng::new(0xBACC0FF ^ req.id as u64);
                front.call(req, &mut rng)
            }
            Err(e) => render_error(None, &format!("{e:#}")),
        };
        let failed = match Json::parse(&reply) {
            Ok(j) => j.get("error").is_some(),
            Err(_) => true,
        };
        if failed {
            front.responses_err.fetch_add(1, Ordering::Relaxed);
        } else {
            front.responses_ok.fetch_add(1, Ordering::Relaxed);
        }
        if writeln!(writer, "{reply}").is_err() {
            break;
        }
    }
    let _ = peer;
}

/// Run the server (blocks forever). `artifacts_dir = None` serves the
/// deterministic in-process `RefModel` instead of the PJRT artifacts —
/// every `Policy` variant works on either executor. `replicas` engine
/// workers run behind the front-end, with `router` picking which one
/// each request joins (one worker + any router degenerates to the old
/// single-engine server).
pub fn serve(
    addr: &str,
    artifacts_dir: Option<&Path>,
    cfg: RealEngineConfig,
    replicas: usize,
    router: RouterPolicy,
) -> Result<()> {
    assert!(replicas >= 1, "need at least one replica");
    let mut txs = Vec::with_capacity(replicas);
    let mut rxs = Vec::with_capacity(replicas);
    for _ in 0..replicas {
        let (tx, rx) = mpsc::channel::<Job>();
        txs.push(tx);
        rxs.push(rx);
    }
    let front = Arc::new(Frontend::new(router, txs));
    // PJRT handles are not Send: each engine lives entirely on its worker
    // thread; load errors come back over a one-shot channel.
    let (init_tx, init_rx) = mpsc::channel::<std::result::Result<(), String>>();
    for (worker, rx) in rxs.into_iter().enumerate() {
        let init_tx = init_tx.clone();
        let front = Arc::clone(&front);
        let cfg = cfg.clone();
        match artifacts_dir {
            Some(dir) => {
                let dir = dir.to_path_buf();
                std::thread::spawn(move || match RealEngine::load(&dir, cfg) {
                    Ok(engine) => {
                        let _ = init_tx.send(Ok(()));
                        engine_worker(engine, rx, front, worker);
                    }
                    Err(e) => {
                        let _ = init_tx.send(Err(format!("{e:#}")));
                    }
                });
            }
            None => {
                std::thread::spawn(move || {
                    let engine = RealEngine::with_model(Rc::new(RefModel::new()), cfg);
                    let _ = init_tx.send(Ok(()));
                    engine_worker(engine, rx, front, worker);
                });
            }
        }
    }
    // drop the original sender: a worker panicking before its init send
    // must close the channel (-> recv error), not hang the front-end
    drop(init_tx);
    for _ in 0..replicas {
        init_rx
            .recv()
            .context("engine thread died during init")?
            .map_err(|e| anyhow::anyhow!(e))?;
    }
    let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
    println!(
        "layerkv serving on {addr} ({replicas} replica{}, router {}); \
         GET /metrics for Prometheus counters",
        if replicas == 1 { "" } else { "s" },
        router.name()
    );
    for stream in listener.incoming().flatten() {
        let front = Arc::clone(&front);
        std::thread::spawn(move || handle_conn(stream, front));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn load(jobs: usize, tokens: usize, ewma: Option<f64>) -> WorkerLoad {
        WorkerLoad {
            queued_jobs: jobs,
            queued_tokens: tokens,
            ewma_ttft_s: ewma,
            dead: false,
            hung: false,
        }
    }

    #[test]
    fn pick_worker_policies() {
        let loads = vec![
            load(2, 4000, Some(0.05)),
            load(1, 9000, Some(2.0)),
            load(3, 100, None),
        ];
        assert_eq!(pick_worker(RouterPolicy::RoundRobin, &loads, 4), Some(1));
        assert_eq!(pick_worker(RouterPolicy::JoinShortestQueue, &loads, 0), Some(1));
        assert_eq!(pick_worker(RouterPolicy::KvPressure, &loads, 0), Some(2));
        // slo-aware: 4000 tokens + 50ms ewma ~ 4.05s, 9000 + 2s ~ 11s,
        // 100 tokens + no history ~ 0.1s
        assert_eq!(pick_worker(RouterPolicy::SloAware, &loads, 0), Some(2));
        // ties break toward the lowest worker index
        let even = vec![load(1, 100, None), load(1, 100, None)];
        assert_eq!(pick_worker(RouterPolicy::JoinShortestQueue, &even, 0), Some(0));
        assert_eq!(pick_worker(RouterPolicy::KvPressure, &even, 0), Some(0));
    }

    #[test]
    fn pick_worker_skips_dead_workers() {
        let mut loads = vec![load(0, 0, None), load(5, 9000, Some(3.0))];
        loads[0].dead = true;
        // worker 0 would win every policy, but it is dead
        for p in RouterPolicy::ALL {
            assert_eq!(pick_worker(*p, &loads, 0), Some(1), "policy {}", p.name());
        }
        loads[1].dead = true;
        assert_eq!(pick_worker(RouterPolicy::KvPressure, &loads, 0), None);
    }

    #[test]
    fn pick_worker_skips_hung_workers_too() {
        let mut loads = vec![load(0, 0, None), load(5, 9000, Some(3.0))];
        loads[0].hung = true;
        for p in RouterPolicy::ALL {
            assert_eq!(pick_worker(*p, &loads, 0), Some(1), "policy {}", p.name());
        }
        loads[1].hung = true;
        assert_eq!(pick_worker(RouterPolicy::RoundRobin, &loads, 0), None);
    }

    #[test]
    fn frontend_ledger_tracks_dispatch_and_completion() {
        let (tx0, rx0) = mpsc::channel();
        let (tx1, rx1) = mpsc::channel();
        let front = Frontend::new(RouterPolicy::KvPressure, vec![tx0, tx1]);
        let req =
            ServeRequest { id: 1, prompt: vec![1, 2, 3], max_new_tokens: 5, arrival_s: 0.0 };
        let (rtx, _rrx) = mpsc::channel();
        assert_eq!(front.dispatch(req.clone(), rtx), Some(0));
        // 8 tokens landed on worker 0 (kv-pressure tie -> lowest index)
        assert_eq!(front.loads.lock().unwrap()[0].queued_tokens, 8);
        assert_eq!(front.loads.lock().unwrap()[0].queued_jobs, 1);
        // the next kv-pressure dispatch avoids the loaded worker
        let (rtx, _rrx) = mpsc::channel();
        assert_eq!(front.dispatch(req, rtx), Some(1));
        assert_eq!(front.loads.lock().unwrap()[1].queued_tokens, 8);
        // completion releases the ledger share and records the TTFT EWMA
        front.job_done(0, 8, Some(0.5));
        let l = front.loads.lock().unwrap()[0].clone();
        assert_eq!(l.queued_jobs, 0);
        assert_eq!(l.queued_tokens, 0);
        assert_eq!(l.ewma_ttft_s, Some(0.5));
        drop((rx0, rx1));
    }

    /// One live RefModel engine worker on its own thread (engines are not
    /// Send, so it is built inside the thread, like `serve` does).
    fn spawn_live_worker(
        rx: mpsc::Receiver<Job>,
        front: Arc<Frontend>,
        worker: usize,
    ) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let engine =
                RealEngine::with_model(Rc::new(RefModel::new()), RealEngineConfig::default());
            engine_worker(engine, rx, front, worker);
        })
    }

    fn call_ids(front: &Arc<Frontend>, ids: &[usize]) -> Vec<String> {
        ids.iter()
            .map(|&id| {
                let req = ServeRequest {
                    id,
                    prompt: vec![1, 2, 3],
                    max_new_tokens: 4,
                    arrival_s: 0.0,
                };
                front.call(req, &mut Rng::new(id as u64))
            })
            .collect()
    }

    #[test]
    fn dead_worker_is_fenced_and_every_request_id_answered() {
        let (tx0, rx0) = mpsc::channel::<Job>();
        let (tx1, rx1) = mpsc::channel::<Job>();
        // worker 0 "crashed before boot": its queue receiver is dropped
        drop(rx0);
        let front = Arc::new(Frontend::new(RouterPolicy::RoundRobin, vec![tx0, tx1]));
        let live = spawn_live_worker(rx1, Arc::clone(&front), 1);
        let ids: Vec<usize> = (100..108).collect();
        let replies = call_ids(&front, &ids);
        // conservation: exactly one successful reply per id, in order
        for (line, &id) in replies.iter().zip(&ids) {
            let j = Json::parse(line).unwrap();
            assert!(j.get("error").is_none(), "unexpected error: {line}");
            assert_eq!(j.req("id").unwrap().as_usize(), Some(id));
        }
        assert!(front.loads.lock().unwrap()[0].dead, "send failure fences worker 0");
        assert!(!front.loads.lock().unwrap()[1].dead);
        // the worker thread holds its own Arc<Frontend> (and thus its own
        // queue sender), so it parks in recv until the process exits —
        // same lifecycle as `serve`'s workers. Don't join it.
        drop(live);
    }

    #[test]
    fn hung_worker_times_out_fences_and_fails_over() {
        let (tx0, rx0) = mpsc::channel::<Job>();
        let (tx1, rx1) = mpsc::channel::<Job>();
        let front = Arc::new(
            Frontend::new(RouterPolicy::RoundRobin, vec![tx0, tx1])
                .with_reply_timeout(Duration::from_millis(50))
                // no-op sleeper: the failover path runs deterministically
                // with zero wall-clock backoff
                .with_backoff(BACKOFF_BASE_S, Box::new(|_| {})),
        );
        // worker 0 hangs: accepts jobs forever, never replies
        let hung = std::thread::spawn(move || {
            let mut parked = Vec::new();
            while let Ok(j) = rx0.recv() {
                parked.push(j); // keep reply senders alive: a true hang,
                                // not a disconnect
            }
        });
        let live = spawn_live_worker(rx1, Arc::clone(&front), 1);
        let ids: Vec<usize> = (7..13).collect();
        let replies = call_ids(&front, &ids);
        for (line, &id) in replies.iter().zip(&ids) {
            let j = Json::parse(line).unwrap();
            assert!(j.get("error").is_none(), "unexpected error: {line}");
            assert_eq!(j.req("id").unwrap().as_usize(), Some(id));
        }
        let l = front.loads.lock().unwrap()[0].clone();
        assert!(l.hung, "timeout fences the worker as hung");
        assert!(!l.dead, "a hang is not a death: its queue is still held");
        assert!(l.fenced());
        // only the first request paid the timeout: the fence keeps every
        // later round-robin pick off the hung worker. Worker threads park
        // in recv (they hold their own Arc<Frontend>); don't join.
        drop((live, hung));
    }

    #[test]
    fn all_workers_dead_yields_explicit_error_per_request() {
        let (tx0, rx0) = mpsc::channel::<Job>();
        drop(rx0);
        let front = Arc::new(Frontend::new(RouterPolicy::SloAware, vec![tx0]));
        let req =
            ServeRequest { id: 41, prompt: vec![5], max_new_tokens: 2, arrival_s: 0.0 };
        let line = front.call(req, &mut Rng::new(1));
        let j = Json::parse(&line).unwrap();
        // the id still comes back: the client can account for the request
        assert_eq!(j.req("id").unwrap().as_usize(), Some(41));
        assert!(j.req("error").unwrap().as_str().is_some());
    }

    #[test]
    fn parses_valid_request() {
        let r = parse_request(r#"{"id": 3, "prompt": [1, 2, 3], "max_new_tokens": 5}"#).unwrap();
        assert_eq!(r.id, 3);
        assert_eq!(r.prompt, vec![1, 2, 3]);
        assert_eq!(r.max_new_tokens, 5);
    }

    #[test]
    fn default_max_new_tokens() {
        let r = parse_request(r#"{"id": 1, "prompt": [9]}"#).unwrap();
        assert_eq!(r.max_new_tokens, 16);
    }

    #[test]
    fn rejects_bad_requests() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"id": 1}"#).is_err());
        assert!(parse_request(r#"{"id": 1, "prompt": []}"#).is_err());
    }

    #[test]
    fn rejects_malformed_prompt_tokens_instead_of_coercing() {
        // these all used to silently become token 0
        for bad in [
            r#"{"id": 1, "prompt": ["seven"]}"#,
            r#"{"id": 1, "prompt": [1, null, 3]}"#,
            r#"{"id": 1, "prompt": [1.5]}"#,
            r#"{"id": 1, "prompt": [-2]}"#,
            r#"{"id": 1, "prompt": [true]}"#,
        ] {
            assert!(parse_request(bad).is_err(), "must reject {bad}");
        }
        // integral floats are fine (JSON has no integer type)
        assert_eq!(parse_request(r#"{"id": 1, "prompt": [2.0]}"#).unwrap().prompt, vec![2]);
    }

    #[test]
    fn response_roundtrips_as_json() {
        let line = render_response(7, &[1, 2], 0.0123, 0.004);
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.req("id").unwrap().as_usize(), Some(7));
        assert_eq!(j.req("output").unwrap().as_arr().unwrap().len(), 2);
        assert!(j.req("ttft_ms").unwrap().as_f64().unwrap() > 12.0);
    }

    #[test]
    fn metrics_text_renders_counters_and_worker_series() {
        let (tx0, _rx0) = mpsc::channel::<Job>();
        let (tx1, _rx1) = mpsc::channel::<Job>();
        let front = Frontend::new(RouterPolicy::RoundRobin, vec![tx0, tx1]);
        front.requests_total.fetch_add(3, Ordering::Relaxed);
        front.responses_ok.fetch_add(2, Ordering::Relaxed);
        front.responses_err.fetch_add(1, Ordering::Relaxed);
        front.fence(1, false);
        front.record_batch(
            0,
            &EngineStats {
                steps: 7,
                preemptions: 2,
                prefix_hits: 4,
                offload_bytes: 1024.0,
                ..Default::default()
            },
            1,
        );
        let text = front.metrics_text();
        assert!(text.contains("# TYPE layerkv_requests_total counter"));
        assert!(text.contains("layerkv_requests_total 3"));
        assert!(text.contains("layerkv_responses_total{status=\"ok\"} 2"));
        assert!(text.contains("layerkv_responses_total{status=\"error\"} 1"));
        assert!(text.contains("layerkv_failovers_total 1"));
        assert!(text.contains("layerkv_worker_up{worker=\"0\"} 1"));
        assert!(text.contains("layerkv_worker_up{worker=\"1\"} 0"));
        assert!(text.contains("layerkv_engine_steps_total{worker=\"0\"} 7"));
        assert!(text.contains("layerkv_engine_preemptions_total{worker=\"0\"} 2"));
        assert!(text.contains("layerkv_engine_prefix_hits_total{worker=\"0\"} 4"));
        assert!(text.contains("layerkv_engine_offload_bytes_total{worker=\"0\"} 1024"));
        assert!(text.contains("layerkv_worker_rejected_total{worker=\"0\"} 1"));
        assert!(text.contains("layerkv_worker_batches_total{worker=\"0\"} 1"));
        // series for the second worker exist too (all zero)
        assert!(text.contains("layerkv_engine_steps_total{worker=\"1\"} 0"));
    }

    #[test]
    fn metrics_endpoint_speaks_http() {
        let (tx0, _rx0) = mpsc::channel::<Job>();
        let front = Frontend::new(RouterPolicy::RoundRobin, vec![tx0]);
        let resp = http_response("/metrics", &front);
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(resp.contains("Content-Type: text/plain"));
        let body = resp.split("\r\n\r\n").nth(1).expect("has a body");
        assert!(body.contains("layerkv_requests_total 0"));
        let len: usize = resp
            .split("Content-Length: ")
            .nth(1)
            .and_then(|s| s.split('\r').next())
            .and_then(|s| s.parse().ok())
            .expect("content length");
        assert_eq!(len, body.len());
        let missing = http_response("/nope", &front);
        assert!(missing.starts_with("HTTP/1.1 404"));
    }

    #[test]
    fn failover_backoff_is_seeded_exponential_and_injectable() {
        // worker 0 accepts each job, then drops it (reply sender dies) ->
        // Disconnected -> fence -> one backed-off failover to worker 1
        let (tx0, rx0) = mpsc::channel::<Job>();
        let (tx1, rx1) = mpsc::channel::<Job>();
        let slept = Arc::new(Mutex::new(Vec::<Duration>::new()));
        let rec = Arc::clone(&slept);
        let base = 0.25; // large on purpose: a real sleep here would hang the test
        let front = Arc::new(
            Frontend::new(RouterPolicy::RoundRobin, vec![tx0, tx1]).with_backoff(
                base,
                Box::new(move |d| rec.lock().unwrap().push(d)),
            ),
        );
        let dropper = std::thread::spawn(move || while rx0.recv().is_ok() {});
        let live = spawn_live_worker(rx1, Arc::clone(&front), 1);
        let replies = call_ids(&front, &[900]);
        let j = Json::parse(&replies[0]).unwrap();
        assert!(j.get("error").is_none(), "failover must answer: {}", replies[0]);
        assert!(front.loads.lock().unwrap()[0].dead);
        // exactly one backoff (attempt 1), jittered into [base/2, base)
        let sleeps = slept.lock().unwrap().clone();
        assert_eq!(sleeps.len(), 1);
        let s = sleeps[0].as_secs_f64();
        assert!((base * 0.5..base).contains(&s), "jittered backoff {s} vs base {base}");
        // and bit-exactly the seeded schedule: replaying the request
        // replays the delay, independent of wall-clock or thread timing
        let expect = base * (0.5 + 0.5 * Rng::new(900).f64());
        assert!((s - expect).abs() < 1e-12, "jitter {s} != seeded {expect}");
        drop((dropper, live));
    }

    #[test]
    fn healthz_reports_per_worker_state_without_a_socket() {
        let (tx0, _rx0) = mpsc::channel::<Job>();
        let (tx1, _rx1) = mpsc::channel::<Job>();
        let front = Frontend::new(RouterPolicy::RoundRobin, vec![tx0, tx1]);
        // all live: 200 with every flag false
        let resp = http_response("/healthz", &front);
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(resp.contains("Content-Type: application/json"));
        let body = resp.split("\r\n\r\n").nth(1).expect("has a body");
        let j = Json::parse(body.trim()).unwrap();
        assert_eq!(j.req("status").unwrap().as_str(), Some("ok"));
        let workers = j.req("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers.len(), 2);
        for w in workers {
            assert_eq!(w.req("dead").unwrap().as_bool(), Some(false));
            assert_eq!(w.req("hung").unwrap().as_bool(), Some(false));
            assert_eq!(w.req("fenced").unwrap().as_bool(), Some(false));
        }
        // hang worker 0: still 200 (worker 1 routable), flags split
        front.fence(0, true);
        let (up, body) = front.healthz_json();
        assert!(up);
        let j = Json::parse(&body).unwrap();
        let workers = j.req("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers[0].req("hung").unwrap().as_bool(), Some(true));
        assert_eq!(workers[0].req("dead").unwrap().as_bool(), Some(false));
        assert_eq!(workers[0].req("fenced").unwrap().as_bool(), Some(true));
        assert_eq!(workers[1].req("fenced").unwrap().as_bool(), Some(false));
        // kill worker 1 too: the whole fleet is fenced -> 503
        front.fence(1, false);
        let resp = http_response("/healthz", &front);
        assert!(resp.starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(body.trim()).unwrap();
        assert_eq!(j.req("status").unwrap().as_str(), Some("down"));
        assert_eq!(
            j.req("workers").unwrap().as_arr().unwrap()[1].req("dead").unwrap().as_bool(),
            Some(true)
        );
    }

    #[test]
    fn healthz_integration_reflects_a_crashed_worker() {
        // worker 0 "crashed before boot" (queue receiver dropped); its
        // death is only discovered when traffic tries to land on it
        let (tx0, rx0) = mpsc::channel::<Job>();
        let (tx1, rx1) = mpsc::channel::<Job>();
        drop(rx0);
        let front = Arc::new(Frontend::new(RouterPolicy::RoundRobin, vec![tx0, tx1]));
        let live = spawn_live_worker(rx1, Arc::clone(&front), 1);
        let (up_before, _) = front.healthz_json();
        assert!(up_before, "undetected crash: still reported routable");
        let replies = call_ids(&front, &[55, 56]);
        for line in &replies {
            assert!(Json::parse(line).unwrap().get("error").is_none(), "{line}");
        }
        // the crash surfaced through dispatch: healthz now shows it dead
        let resp = http_response("/healthz", &front);
        assert!(resp.starts_with("HTTP/1.1 200 OK\r\n"), "survivor keeps the fleet up");
        let body = resp.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(body.trim()).unwrap();
        let workers = j.req("workers").unwrap().as_arr().unwrap();
        assert_eq!(workers[0].req("dead").unwrap().as_bool(), Some(true));
        assert_eq!(workers[0].req("fenced").unwrap().as_bool(), Some(true));
        assert_eq!(workers[1].req("fenced").unwrap().as_bool(), Some(false));
        drop(live);
    }

    #[test]
    fn fold_stats_accumulates_checkpoint_and_adoption_counters() {
        let mut acc = EngineStats::default();
        let s = EngineStats {
            ckpt_writes: 3,
            ckpt_bytes: 4096.0,
            ckpt_write_s: 0.5,
            adoptions: 2,
            adopt_restore_bytes: 2048.0,
            ..Default::default()
        };
        fold_stats(&mut acc, &s);
        fold_stats(&mut acc, &s);
        assert_eq!(acc.ckpt_writes, 6);
        assert_eq!(acc.adoptions, 4);
        assert!((acc.ckpt_bytes - 8192.0).abs() < 1e-9);
        assert!((acc.ckpt_write_s - 1.0).abs() < 1e-12);
        assert!((acc.adopt_restore_bytes - 4096.0).abs() < 1e-9);
    }

    #[test]
    fn error_responses_are_json_with_escaping() {
        let line = render_error(Some(4), "bad \"quote\" and \\slash");
        let j = Json::parse(&line).unwrap();
        assert_eq!(j.req("id").unwrap().as_usize(), Some(4));
        assert_eq!(j.req("error").unwrap().as_str().unwrap(), "bad \"quote\" and \\slash");
        let anon = render_error(None, "nope");
        assert!(Json::parse(&anon).unwrap().get("id").is_none());
    }
}
