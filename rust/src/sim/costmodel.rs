//! Analytical cost models — the simulator's substitute for real GPU
//! execution (DESIGN.md §2). Each formula is the one the paper itself uses
//! to reason about overlap:
//!
//! * prefill time: Eq. 3 (superlinear in seqlen),
//! * offload time: Eq. 4 (linear in seqlen),
//! * decode step: memory-bound weights+KV streaming (standard roofline),
//! * tensor-parallel all-reduce: per-layer ring cost on NVLink or PCIe.

use crate::config::{Fabric, ServingConfig};

/// Fraction of peak FLOPs a dense prefill achieves (MFU). Folded together
/// with the paper's alpha this calibrates Eq. 3 to the L20 regime.
const PREFILL_MFU: f64 = 0.75;
/// Fixed per-step overhead (kernel launches, scheduler, sampler).
const STEP_OVERHEAD_S: f64 = 2.0e-3;

#[derive(Debug, Clone)]
pub struct CostModel {
    pub cfg: ServingConfig,
}

impl CostModel {
    pub fn new(cfg: ServingConfig) -> Self {
        CostModel { cfg }
    }

    /// Eq. 3: T_prefill = alpha * s * (2*n_param + 2*s*hidden) / FLOPs,
    /// with TP scaling and per-layer all-reduce added.
    pub fn prefill_time(&self, seqlen: usize) -> f64 {
        self.prefill_compute_time(seqlen) + STEP_OVERHEAD_S
    }

    /// Eq. 3 without the fixed step overhead — the window offloads can
    /// actually overlap with (§3.1.1's x-solve uses this).
    pub fn prefill_compute_time(&self, seqlen: usize) -> f64 {
        let c = &self.cfg;
        let s = seqlen as f64;
        let flops = s * (2.0 * c.model.n_params as f64 + 2.0 * s * c.model.hidden as f64);
        let device_flops = c.node.gpu.peak_flops * PREFILL_MFU * c.tp as f64;
        let compute = c.alpha * flops / device_flops;
        compute + self.allreduce_time(seqlen)
    }

    /// Eq. 4: offload time for `layers` layers of a `seqlen`-token KV
    /// shard over the (per-GPU share of the) PCIe link.
    pub fn offload_time(&self, seqlen: usize, layers: usize) -> f64 {
        if layers == 0 || seqlen == 0 {
            return 0.0;
        }
        let c = &self.cfg;
        let bytes_per_gpu = seqlen as f64
            * layers as f64
            * c.offload_bytes_per_token_layer()
            / c.tp as f64;
        c.beta * bytes_per_gpu / self.pcie_bw_per_gpu() + c.node.pcie.latency
    }

    /// Effective host link bandwidth one GPU sees (testbed: two GPUs share
    /// each PCIe connection).
    pub fn pcie_bw_per_gpu(&self) -> f64 {
        let c = &self.cfg;
        let sharing = c.node.pcie.gpus_per_link.min(c.tp.max(1)) as f64;
        c.node.pcie.bandwidth / sharing
    }

    /// §3.1.1: minimum layers that must stay resident so offloading the
    /// other L-x fully hides under the prefill (T_offload <= T_prefill).
    /// Long prompts push x to 0; short prompts keep x near L.
    pub fn min_resident_layers(&self, seqlen: usize) -> usize {
        let l = self.cfg.model.n_layers;
        let t_prefill = self.prefill_compute_time(seqlen);
        // offload_time is linear in `layers`; solve for the largest
        // offloadable count, then x = L - offloadable.
        let per_layer = self.offload_time(seqlen, 1);
        if per_layer <= 0.0 {
            return 0;
        }
        let offloadable = (t_prefill / per_layer).floor() as usize;
        l.saturating_sub(offloadable)
    }

    /// Eq. 4 generalized to the disk tier: time to push `layers` layers of
    /// a `seqlen`-token KV shard over the host<->disk link (host pressure
    /// spill, or the deep half of an admission that overflows host RAM).
    /// Infinite when the node has no disk tier.
    pub fn spill_time(&self, seqlen: usize, layers: usize) -> f64 {
        if layers == 0 || seqlen == 0 {
            return 0.0;
        }
        let c = &self.cfg;
        if c.node.disk.bandwidth <= 0.0 {
            return f64::INFINITY;
        }
        let bytes_per_gpu = seqlen as f64
            * layers as f64
            * c.offload_bytes_per_token_layer()
            / c.tp as f64;
        c.beta * bytes_per_gpu / c.node.disk.bandwidth + c.node.disk.latency
    }

    /// Restoring from the disk tier traverses the same link (symmetric
    /// sequential bandwidth), plus the PCIe hop host->device.
    pub fn disk_restore_time(&self, seqlen: usize, layers: usize) -> f64 {
        if layers == 0 || seqlen == 0 {
            return 0.0;
        }
        self.spill_time(seqlen, layers) + self.onload_time(seqlen, layers)
    }

    /// §3.1.1's x-solve, tier-aware: the first `host_layers` offloaded
    /// layers ride the PCIe link; anything past them must continue to the
    /// slower disk link, which hides fewer layers under the same prefill
    /// window — and costs symmetrically more to restore. Solves the
    /// largest offloadable count with the cumulative (host-then-disk)
    /// transfer time still <= T_prefill, then x = L - offloadable.
    /// With ample `host_layers` this reduces exactly to
    /// `min_resident_layers`.
    pub fn min_resident_layers_tiered(&self, seqlen: usize, host_layers: usize) -> usize {
        let l = self.cfg.model.n_layers;
        let t_prefill = self.prefill_compute_time(seqlen);
        let per_host = self.offload_time(seqlen, 1);
        if per_host <= 0.0 {
            return 0;
        }
        let host_side = ((t_prefill / per_host).floor() as usize).min(host_layers).min(l);
        let t_left = t_prefill - host_side as f64 * per_host;
        let per_disk = self.spill_time(seqlen, 1);
        let disk_side = if per_disk.is_finite() && per_disk > 0.0 && t_left > 0.0 {
            (t_left / per_disk).floor() as usize
        } else {
            0
        };
        l.saturating_sub(host_side + disk_side)
    }

    /// Solve one tiered admission: given the flat-solved retained count
    /// `x0`, the per-layer block demand, and the host blocks available,
    /// return `(x, host_layers)` — the retained count re-solved against
    /// the disk link when the host pool cannot hold all non-retained
    /// layers, and how many of them fill the host (in layer order; the
    /// rest overflow to disk). This is THE feasibility formula: the
    /// LayerKV scheduler, the engine's `never_fits`, and the allocator's
    /// host-fill split all agree through it.
    pub fn tiered_admission(
        &self,
        seqlen: usize,
        x0: usize,
        per_layer: usize,
        free_cpu_blocks: usize,
    ) -> (usize, usize) {
        let l = self.cfg.model.n_layers;
        let host_cap =
            if per_layer == 0 { l } else { free_cpu_blocks / per_layer };
        let mut x = x0;
        if host_cap < l - x {
            x = x.max(self.min_resident_layers_tiered(seqlen, host_cap));
        }
        (x, host_cap.min(l - x))
    }

    /// One iteration of batched decode. Memory-bound: stream the weight
    /// shard once plus every running request's resident KV; compute rides
    /// under that. `ctx_lens` are the current context lengths.
    pub fn decode_step_time(&self, ctx_lens: &[usize]) -> f64 {
        self.decode_step_time_sum(ctx_lens.iter().sum(), ctx_lens.len())
    }

    /// Sum form of `decode_step_time`: the formula only consumes the batch
    /// size and the *total* context length, so the engine feeds it the
    /// incrementally-maintained running-token aggregate instead of
    /// materialising a per-request Vec every step (§Perf).
    pub fn decode_step_time_sum(&self, total_ctx: usize, batch: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let c = &self.cfg;
        let weights = c.weight_bytes_per_gpu() as f64 / c.node.gpu.mem_bw;
        let kv_bytes =
            total_ctx as f64 * c.model.kv_bytes_per_token() as f64 / c.tp as f64;
        let kv = kv_bytes / c.node.gpu.mem_bw;
        let flops = 2.0 * c.model.n_params as f64 * batch as f64;
        let compute = flops / (c.node.gpu.peak_flops * c.tp as f64);
        (weights + kv).max(compute) + self.allreduce_time(batch) + STEP_OVERHEAD_S
    }

    /// Engine clock after `k` stable decode iterations starting at `now`
    /// over a fixed batch whose total context starts at `total_ctx` and
    /// grows by `batch` tokens per step (every lane appends one token).
    ///
    /// Deliberately accumulated per-step in sequence — NOT algebraically
    /// collapsed — because float addition is non-associative and the
    /// macro-stepping engine's contract is that the span's final clock is
    /// **bit-identical** to `k` successive `decode_step_time_sum` clock
    /// advances (the horizon solver and the committing engine both walk
    /// this exact sequence).
    pub fn decode_span_end(&self, now: f64, total_ctx: usize, batch: usize, k: usize) -> f64 {
        let mut t = now;
        let mut ctx = total_ctx;
        for _ in 0..k {
            t += self.decode_step_time_sum(ctx, batch);
            ctx += batch;
        }
        t
    }

    /// Closed form for the KV bytes a `k`-step stable decode span streams
    /// from GPU memory (reporting/roofline use — not on the bit-identity
    /// path, so the arithmetic series IS collapsed): Σ_{i=0}^{k-1}
    /// (total_ctx + i·batch) tokens of per-token KV, per GPU shard.
    pub fn decode_span_kv_bytes(&self, total_ctx: usize, batch: usize, k: usize) -> f64 {
        let c = &self.cfg;
        let tokens =
            k as f64 * total_ctx as f64 + batch as f64 * (k as f64 - 1.0) * k as f64 / 2.0;
        tokens * c.model.kv_bytes_per_token() as f64 / c.tp as f64
    }

    /// Per-forward-pass all-reduce cost under TP: two all-reduces per layer
    /// over `tokens` activations (§3.1.3). On NVLink this is fast and off
    /// the PCIe; on PCIe-fabric nodes it shares the link with KV swaps.
    pub fn allreduce_time(&self, tokens: usize) -> f64 {
        let c = &self.cfg;
        if c.tp <= 1 {
            return 0.0;
        }
        let bytes = tokens as f64 * c.model.hidden as f64 * c.model.dtype_bytes as f64;
        // ring all-reduce moves 2*(tp-1)/tp of the data per rank
        let ring = 2.0 * (c.tp as f64 - 1.0) / c.tp as f64;
        let (bw, lat) = match c.node.fabric {
            Fabric::NvLink => (c.node.nvlink_bw, 3.0e-6),
            Fabric::Pcie => (self.pcie_bw_per_gpu(), c.node.pcie.latency),
        };
        let per_allreduce = ring * bytes / bw + lat;
        2.0 * c.model.n_layers as f64 * per_allreduce
    }

    /// Time to fetch `layers` layers of a `seqlen` KV shard host->device
    /// (decode-phase streaming of offloaded layers). Same link as offload.
    pub fn onload_time(&self, seqlen: usize, layers: usize) -> f64 {
        self.offload_time(seqlen, layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NodeSpec, Policy, ServingConfig};

    fn cm() -> CostModel {
        CostModel::new(ServingConfig::llama2_7b_tp1())
    }

    #[test]
    fn prefill_superlinear() {
        let m = cm();
        let t1 = m.prefill_compute_time(1024);
        let t16 = m.prefill_compute_time(16 * 1024);
        // 16x tokens must cost MORE than 16x time (quadratic attention term)
        assert!(t16 > 16.0 * t1, "t1={t1} t16={t16}");
    }

    #[test]
    fn prefill_regime_matches_paper_fig1() {
        // Fig. 1b: prefill latency ~ O(0.1s) at 1-2k, ~seconds at 16k.
        let m = cm();
        assert!(m.prefill_time(128) < 0.1);
        let t16k = m.prefill_time(16 * 1024);
        assert!((1.0..10.0).contains(&t16k), "t16k={t16k}");
    }

    #[test]
    fn offload_linear_in_layers_and_tokens() {
        let m = cm();
        let t1 = m.offload_time(1024, 8) - m.cfg.node.pcie.latency;
        let t2 = m.offload_time(2048, 8) - m.cfg.node.pcie.latency;
        let t3 = m.offload_time(1024, 16) - m.cfg.node.pcie.latency;
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        assert!((t3 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn long_prompts_need_zero_resident_layers() {
        let m = cm();
        // Paper §3.1.1: "When the prompt is long, x can be zero". On the
        // L20 (fast PCIe relative to 7B prefill FLOPs) x reaches 0 early.
        assert_eq!(m.min_resident_layers(16 * 1024), 0);
        // monotone non-increasing in seqlen
        let xs: Vec<usize> =
            [32, 128, 512, 2048, 8192].iter().map(|&s| m.min_resident_layers(s)).collect();
        assert!(xs.windows(2).all(|w| w[1] <= w[0]), "{xs:?}");
    }

    #[test]
    fn short_prompts_retain_layers_when_link_is_slow() {
        // Paper §3.1.1: "when the prompt is short, x is greater than zero,
        // requiring at least x KV cache layers to remain in GPU memory".
        // The crossover depends on link speed vs compute; on a constrained
        // link (e.g. the per-GPU share of a contended gen3 x8) it shows up
        // at realistic prompt lengths.
        let mut cfg = ServingConfig::llama2_7b_tp1();
        cfg.node.pcie.bandwidth = 1.0e9; // ~1 GB/s effective share
        let m = CostModel::new(cfg);
        let x_short = m.min_resident_layers(64);
        let x_long = m.min_resident_layers(16 * 1024);
        assert!(x_short > 0, "x_short={x_short}");
        // Eqs. 3-4 are both ~linear in s until the quadratic attention
        // term bites (s ~ n_param/hidden), so x is only *weakly* monotone
        // across realistic prompt lengths — see DESIGN.md §7.
        assert!(x_long <= x_short, "x_long={x_long} x_short={x_short}");
    }

    #[test]
    fn offload_hides_under_prefill_at_solved_x() {
        let m = cm();
        for s in [64usize, 256, 1024, 4096, 16384] {
            let x = m.min_resident_layers(s);
            let l = m.cfg.model.n_layers;
            assert!(
                m.offload_time(s, l - x) <= m.prefill_time(s) + 1e-9,
                "s={s} x={x}"
            );
        }
    }

    #[test]
    fn tiered_x_solve_degrades_gracefully() {
        use crate::config::DiskSpec;
        let mut cfg = ServingConfig::llama2_7b_tp1();
        cfg.node.disk = DiskSpec::nvme_4tb();
        let m = CostModel::new(cfg);
        let s = 4096;
        let x_flat = m.min_resident_layers(s);
        // ample host: tiered solve collapses to the flat solve
        assert_eq!(m.min_resident_layers_tiered(s, 10_000), x_flat);
        // no host at all: every offload rides the slower disk link, so
        // fewer layers hide under the prefill -> x can only grow
        let x_disk_only = m.min_resident_layers_tiered(s, 0);
        assert!(x_disk_only >= x_flat, "x_disk_only={x_disk_only} x_flat={x_flat}");
        // monotone: more host room never increases x
        let mut prev = x_disk_only;
        for host in [1usize, 4, 8, 16, 32] {
            let x = m.min_resident_layers_tiered(s, host);
            assert!(x <= prev, "host={host}: x={x} prev={prev}");
            prev = x;
        }
    }

    #[test]
    fn spill_slower_than_offload_restore_costs_both_links() {
        use crate::config::DiskSpec;
        let mut cfg = ServingConfig::llama2_7b_tp1();
        cfg.node.disk = DiskSpec::nvme_4tb();
        let m = CostModel::new(cfg);
        assert!(m.spill_time(2048, 8) > m.offload_time(2048, 8));
        assert!(
            m.disk_restore_time(2048, 8)
                > m.spill_time(2048, 8).max(m.onload_time(2048, 8))
        );
        assert_eq!(m.spill_time(0, 8), 0.0);
        assert_eq!(m.spill_time(2048, 0), 0.0);
        // two-tier node: the disk link does not exist
        let two = CostModel::new(ServingConfig::llama2_7b_tp1());
        assert_eq!(two.spill_time(2048, 1), f64::INFINITY);
        assert_eq!(two.min_resident_layers_tiered(2048, 10_000), two.min_resident_layers(2048));
    }

    #[test]
    fn decode_step_in_tpot_regime() {
        // L20 + 7B: weights stream = 13.5GB/864GB/s ~ 15.6ms; with batch
        // KV this lands in the paper's 20-60ms TPOT band.
        let m = cm();
        let t = m.decode_step_time(&[1024; 8]);
        assert!((0.015..0.1).contains(&t), "t={t}");
        // larger contexts stream more KV
        assert!(m.decode_step_time(&[8192; 8]) > t);
    }

    #[test]
    fn decode_span_end_replays_per_step_accumulation() {
        // the macro-stepping contract: bit-identical to stepping k times
        let m = cm();
        let mut t = 3.5f64;
        let mut ctx = 2048usize;
        for _ in 0..37 {
            t += m.decode_step_time_sum(ctx, 4);
            ctx += 4;
        }
        assert_eq!(m.decode_span_end(3.5, 2048, 4, 37).to_bits(), t.to_bits());
        assert_eq!(m.decode_span_end(3.5, 2048, 4, 0).to_bits(), 3.5f64.to_bits());
    }

    #[test]
    fn decode_span_kv_bytes_matches_series_sum() {
        let m = cm();
        let per_tok = m.cfg.model.kv_bytes_per_token() as f64 / m.cfg.tp as f64;
        let mut want = 0.0;
        for i in 0..10usize {
            want += (1000 + i * 4) as f64 * per_tok;
        }
        let got = m.decode_span_kv_bytes(1000, 4, 10);
        assert!((got - want).abs() < 1e-6 * want, "got={got} want={want}");
        assert_eq!(m.decode_span_kv_bytes(1000, 4, 0), 0.0);
    }

    #[test]
    fn tp_speeds_up_prefill_but_adds_allreduce() {
        let c2 = ServingConfig::yi_34b_tp2().with_policy(Policy::Vllm);
        let mut c4 = ServingConfig::yi_34b_tp2();
        c4.tp = 4;
        let m2 = CostModel::new(c2);
        let m4 = CostModel::new(c4);
        assert!(m4.prefill_time(4096) < m2.prefill_time(4096));
        assert!(m4.allreduce_time(4096) > 0.0);
    }

    #[test]
    fn nvlink_allreduce_cheaper_than_pcie() {
        let mut pcie = ServingConfig::yi_34b_tp2();
        pcie.node = NodeSpec::l20_node();
        let mut nv = ServingConfig::yi_34b_tp2();
        nv.node = NodeSpec::l20_node_nvlink();
        assert!(
            CostModel::new(nv).allreduce_time(2048) < CostModel::new(pcie).allreduce_time(2048)
        );
    }
}
