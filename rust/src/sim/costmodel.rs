//! Analytical cost models — the simulator's substitute for real GPU
//! execution (DESIGN.md §2). Each formula is the one the paper itself uses
//! to reason about overlap:
//!
//! * prefill time: Eq. 3 (superlinear in seqlen),
//! * offload time: Eq. 4 (linear in seqlen),
//! * decode step: memory-bound weights+KV streaming (standard roofline),
//! * tensor-parallel all-reduce: per-layer ring cost on NVLink or PCIe.

use crate::config::{Fabric, ServingConfig};

/// Fraction of peak FLOPs a dense prefill achieves (MFU). Folded together
/// with the paper's alpha this calibrates Eq. 3 to the L20 regime.
const PREFILL_MFU: f64 = 0.75;
/// Fixed per-step overhead (kernel launches, scheduler, sampler).
const STEP_OVERHEAD_S: f64 = 2.0e-3;

#[derive(Debug, Clone)]
pub struct CostModel {
    pub cfg: ServingConfig,
}

impl CostModel {
    pub fn new(cfg: ServingConfig) -> Self {
        CostModel { cfg }
    }

    /// Eq. 3: T_prefill = alpha * s * (2*n_param + 2*s*hidden) / FLOPs,
    /// with TP scaling and per-layer all-reduce added.
    pub fn prefill_time(&self, seqlen: usize) -> f64 {
        self.prefill_compute_time(seqlen) + STEP_OVERHEAD_S
    }

    /// Eq. 3 without the fixed step overhead — the window offloads can
    /// actually overlap with (§3.1.1's x-solve uses this).
    pub fn prefill_compute_time(&self, seqlen: usize) -> f64 {
        let c = &self.cfg;
        let s = seqlen as f64;
        let flops = s * (2.0 * c.model.n_params as f64 + 2.0 * s * c.model.hidden as f64);
        let device_flops = c.node.gpu.peak_flops * PREFILL_MFU * c.tp as f64;
        let compute = c.alpha * flops / device_flops;
        compute + self.allreduce_time(seqlen)
    }

    /// Eq. 4: offload time for `layers` layers of a `seqlen`-token KV
    /// shard over the (per-GPU share of the) PCIe link.
    pub fn offload_time(&self, seqlen: usize, layers: usize) -> f64 {
        if layers == 0 || seqlen == 0 {
            return 0.0;
        }
        let c = &self.cfg;
        let bytes_per_gpu = seqlen as f64
            * layers as f64
            * c.offload_bytes_per_token_layer()
            / c.tp as f64;
        c.beta * bytes_per_gpu / self.pcie_bw_per_gpu() + c.node.pcie.latency
    }

    /// Effective host link bandwidth one GPU sees (testbed: two GPUs share
    /// each PCIe connection).
    pub fn pcie_bw_per_gpu(&self) -> f64 {
        let c = &self.cfg;
        let sharing = c.node.pcie.gpus_per_link.min(c.tp.max(1)) as f64;
        c.node.pcie.bandwidth / sharing
    }

    /// §3.1.1: minimum layers that must stay resident so offloading the
    /// other L-x fully hides under the prefill (T_offload <= T_prefill).
    /// Long prompts push x to 0; short prompts keep x near L.
    pub fn min_resident_layers(&self, seqlen: usize) -> usize {
        let l = self.cfg.model.n_layers;
        let t_prefill = self.prefill_compute_time(seqlen);
        // offload_time is linear in `layers`; solve for the largest
        // offloadable count, then x = L - offloadable.
        let per_layer = self.offload_time(seqlen, 1);
        if per_layer <= 0.0 {
            return 0;
        }
        let offloadable = (t_prefill / per_layer).floor() as usize;
        l.saturating_sub(offloadable)
    }

    /// One iteration of batched decode. Memory-bound: stream the weight
    /// shard once plus every running request's resident KV; compute rides
    /// under that. `ctx_lens` are the current context lengths.
    pub fn decode_step_time(&self, ctx_lens: &[usize]) -> f64 {
        self.decode_step_time_sum(ctx_lens.iter().sum(), ctx_lens.len())
    }

    /// Sum form of `decode_step_time`: the formula only consumes the batch
    /// size and the *total* context length, so the engine feeds it the
    /// incrementally-maintained running-token aggregate instead of
    /// materialising a per-request Vec every step (§Perf).
    pub fn decode_step_time_sum(&self, total_ctx: usize, batch: usize) -> f64 {
        if batch == 0 {
            return 0.0;
        }
        let c = &self.cfg;
        let weights = c.weight_bytes_per_gpu() as f64 / c.node.gpu.mem_bw;
        let kv_bytes =
            total_ctx as f64 * c.model.kv_bytes_per_token() as f64 / c.tp as f64;
        let kv = kv_bytes / c.node.gpu.mem_bw;
        let flops = 2.0 * c.model.n_params as f64 * batch as f64;
        let compute = flops / (c.node.gpu.peak_flops * c.tp as f64);
        (weights + kv).max(compute) + self.allreduce_time(batch) + STEP_OVERHEAD_S
    }

    /// Per-forward-pass all-reduce cost under TP: two all-reduces per layer
    /// over `tokens` activations (§3.1.3). On NVLink this is fast and off
    /// the PCIe; on PCIe-fabric nodes it shares the link with KV swaps.
    pub fn allreduce_time(&self, tokens: usize) -> f64 {
        let c = &self.cfg;
        if c.tp <= 1 {
            return 0.0;
        }
        let bytes = tokens as f64 * c.model.hidden as f64 * c.model.dtype_bytes as f64;
        // ring all-reduce moves 2*(tp-1)/tp of the data per rank
        let ring = 2.0 * (c.tp as f64 - 1.0) / c.tp as f64;
        let (bw, lat) = match c.node.fabric {
            Fabric::NvLink => (c.node.nvlink_bw, 3.0e-6),
            Fabric::Pcie => (self.pcie_bw_per_gpu(), c.node.pcie.latency),
        };
        let per_allreduce = ring * bytes / bw + lat;
        2.0 * c.model.n_layers as f64 * per_allreduce
    }

    /// Time to fetch `layers` layers of a `seqlen` KV shard host->device
    /// (decode-phase streaming of offloaded layers). Same link as offload.
    pub fn onload_time(&self, seqlen: usize, layers: usize) -> f64 {
        self.offload_time(seqlen, layers)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NodeSpec, Policy, ServingConfig};

    fn cm() -> CostModel {
        CostModel::new(ServingConfig::llama2_7b_tp1())
    }

    #[test]
    fn prefill_superlinear() {
        let m = cm();
        let t1 = m.prefill_compute_time(1024);
        let t16 = m.prefill_compute_time(16 * 1024);
        // 16x tokens must cost MORE than 16x time (quadratic attention term)
        assert!(t16 > 16.0 * t1, "t1={t1} t16={t16}");
    }

    #[test]
    fn prefill_regime_matches_paper_fig1() {
        // Fig. 1b: prefill latency ~ O(0.1s) at 1-2k, ~seconds at 16k.
        let m = cm();
        assert!(m.prefill_time(128) < 0.1);
        let t16k = m.prefill_time(16 * 1024);
        assert!((1.0..10.0).contains(&t16k), "t16k={t16k}");
    }

    #[test]
    fn offload_linear_in_layers_and_tokens() {
        let m = cm();
        let t1 = m.offload_time(1024, 8) - m.cfg.node.pcie.latency;
        let t2 = m.offload_time(2048, 8) - m.cfg.node.pcie.latency;
        let t3 = m.offload_time(1024, 16) - m.cfg.node.pcie.latency;
        assert!((t2 / t1 - 2.0).abs() < 1e-9);
        assert!((t3 / t1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn long_prompts_need_zero_resident_layers() {
        let m = cm();
        // Paper §3.1.1: "When the prompt is long, x can be zero". On the
        // L20 (fast PCIe relative to 7B prefill FLOPs) x reaches 0 early.
        assert_eq!(m.min_resident_layers(16 * 1024), 0);
        // monotone non-increasing in seqlen
        let xs: Vec<usize> =
            [32, 128, 512, 2048, 8192].iter().map(|&s| m.min_resident_layers(s)).collect();
        assert!(xs.windows(2).all(|w| w[1] <= w[0]), "{xs:?}");
    }

    #[test]
    fn short_prompts_retain_layers_when_link_is_slow() {
        // Paper §3.1.1: "when the prompt is short, x is greater than zero,
        // requiring at least x KV cache layers to remain in GPU memory".
        // The crossover depends on link speed vs compute; on a constrained
        // link (e.g. the per-GPU share of a contended gen3 x8) it shows up
        // at realistic prompt lengths.
        let mut cfg = ServingConfig::llama2_7b_tp1();
        cfg.node.pcie.bandwidth = 1.0e9; // ~1 GB/s effective share
        let m = CostModel::new(cfg);
        let x_short = m.min_resident_layers(64);
        let x_long = m.min_resident_layers(16 * 1024);
        assert!(x_short > 0, "x_short={x_short}");
        // Eqs. 3-4 are both ~linear in s until the quadratic attention
        // term bites (s ~ n_param/hidden), so x is only *weakly* monotone
        // across realistic prompt lengths — see DESIGN.md §7.
        assert!(x_long <= x_short, "x_long={x_long} x_short={x_short}");
    }

    #[test]
    fn offload_hides_under_prefill_at_solved_x() {
        let m = cm();
        for s in [64usize, 256, 1024, 4096, 16384] {
            let x = m.min_resident_layers(s);
            let l = m.cfg.model.n_layers;
            assert!(
                m.offload_time(s, l - x) <= m.prefill_time(s) + 1e-9,
                "s={s} x={x}"
            );
        }
    }

    #[test]
    fn decode_step_in_tpot_regime() {
        // L20 + 7B: weights stream = 13.5GB/864GB/s ~ 15.6ms; with batch
        // KV this lands in the paper's 20-60ms TPOT band.
        let m = cm();
        let t = m.decode_step_time(&[1024; 8]);
        assert!((0.015..0.1).contains(&t), "t={t}");
        // larger contexts stream more KV
        assert!(m.decode_step_time(&[8192; 8]) > t);
    }

    #[test]
    fn tp_speeds_up_prefill_but_adds_allreduce() {
        let c2 = ServingConfig::yi_34b_tp2().with_policy(Policy::Vllm);
        let mut c4 = ServingConfig::yi_34b_tp2();
        c4.tp = 4;
        let m2 = CostModel::new(c2);
        let m4 = CostModel::new(c4);
        assert!(m4.prefill_time(4096) < m2.prefill_time(4096));
        assert!(m4.allreduce_time(4096) > 0.0);
    }

    #[test]
    fn nvlink_allreduce_cheaper_than_pcie() {
        let mut pcie = ServingConfig::yi_34b_tp2();
        pcie.node = NodeSpec::l20_node();
        let mut nv = ServingConfig::yi_34b_tp2();
        nv.node = NodeSpec::l20_node_nvlink();
        assert!(
            CostModel::new(nv).allreduce_time(2048) < CostModel::new(pcie).allreduce_time(2048)
        );
    }
}
