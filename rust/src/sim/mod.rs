//! The discrete-event substrate that stands in for the paper's L20
//! testbed: analytical cost models (Eqs. 3-4 + roofline decode) and a PCIe
//! link occupancy model with the §3.1.3 contention mechanism.
//!
//! The *policies* under study (schedulers, allocators, offload planning)
//! live in `coordinator/` and are shared between this simulated executor
//! and the real PJRT executor — the simulator only supplies time.

pub mod costmodel;
pub mod pcie;

pub use costmodel::CostModel;
pub use pcie::{BusyWindow, PcieLink, SwapOutcome, TransferLink};
