//! Transfer-link occupancy model (bandwidth + fixed latency + chunking),
//! with the §3.1.3 contention-mitigation mechanism: before launching a
//! swap, check whether the link is busy with an all-reduce; if so, back
//! off for a fraction of the all-reduce latency and re-check;
//! additionally split swaps into sub-units so an all-reduce arriving
//! mid-swap only waits for the current chunk.
//!
//! [`TransferLink`] is tier-agnostic: the GPU<->host PCIe link and the
//! host<->disk spill path are both instances — disk is just a slower,
//! higher-latency, higher-capacity "PCIe-like" link (`PcieLink` remains
//! as an alias for the original name).
//!
//! The simulator uses this to answer: "a swap of B bytes is requested at
//! time t while all-reduces occupy the link during [a_i, b_i) windows —
//! when does it finish, and how much did it slow the all-reduces?"

/// A half-open busy window [start, end) on the link.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BusyWindow {
    pub start: f64,
    pub end: f64,
}

/// Outcome of scheduling one swap on the link.
#[derive(Debug, Clone, PartialEq)]
pub struct SwapOutcome {
    /// When the last byte lands.
    pub finish: f64,
    /// Seconds of overlap between the swap and all-reduce windows (the
    /// contention the check mechanism is designed to eliminate).
    pub contended: f64,
}

/// One tier-to-tier transfer link: bandwidth, fixed per-transfer latency,
/// and optional chunked scheduling around busy windows.
#[derive(Debug, Clone)]
pub struct TransferLink {
    /// Bytes/s available to the swapping endpoint.
    pub bandwidth: f64,
    /// Fixed per-transfer latency.
    pub latency: f64,
    /// §3.1.3 mechanism on/off (ablation: `bench ablations pcie`).
    pub chunking: bool,
    /// Sub-unit size when chunking (bytes).
    pub chunk_bytes: f64,
    /// Fraction of the all-reduce latency to back off before re-checking.
    pub backoff_frac: f64,
}

/// The original name: the GPU<->host instance of [`TransferLink`].
pub type PcieLink = TransferLink;

impl TransferLink {
    pub fn new(bandwidth: f64, latency: f64, chunking: bool) -> Self {
        TransferLink {
            bandwidth,
            latency,
            chunking,
            chunk_bytes: 8.0 * 1024.0 * 1024.0,
            backoff_frac: 0.25,
        }
    }

    /// The host<->disk instance: spills do not contend with all-reduces,
    /// so chunking is off and larger transfer units are used.
    pub fn disk(spec: &crate::config::hardware::DiskSpec) -> Self {
        TransferLink {
            bandwidth: spec.bandwidth,
            latency: spec.latency,
            chunking: false,
            chunk_bytes: 64.0 * 1024.0 * 1024.0,
            backoff_frac: 0.25,
        }
    }

    /// Pure (uncontended) transfer time for `bytes`: latency + bytes/bw.
    /// 0 bytes cost nothing; a disabled link (bandwidth 0) is infinite.
    pub fn transfer_time(&self, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return 0.0;
        }
        if self.bandwidth <= 0.0 {
            return f64::INFINITY;
        }
        self.latency + bytes / self.bandwidth
    }

    /// Schedule a swap of `bytes` starting no earlier than `t`, against the
    /// (sorted, disjoint) all-reduce busy windows.
    ///
    /// Without chunking the swap launches immediately and degrades any
    /// overlapped all-reduce (contended > 0). With the check+chunk
    /// mechanism each sub-unit launches only when the link is observed
    /// idle, so contention is limited to sub-unit tails.
    pub fn schedule_swap(&self, t: f64, bytes: f64, busy: &[BusyWindow]) -> SwapOutcome {
        if bytes <= 0.0 {
            return SwapOutcome { finish: t, contended: 0.0 };
        }
        if !self.chunking {
            let dur = self.latency + bytes / self.bandwidth;
            let contended = overlap(t, t + dur, busy);
            return SwapOutcome { finish: t + dur, contended };
        }
        let mut now = t;
        let mut remaining = bytes;
        let mut contended = 0.0;
        let mut first = true;
        while remaining > 0.0 {
            // check: if the link is busy at `now`, back off until the
            // current window ends (repeatedly, in backoff steps)
            while let Some(w) = window_at(now, busy) {
                let backoff = ((w.end - w.start) * self.backoff_frac).max(1e-7);
                now = (now + backoff).min(w.end);
                if now >= w.end {
                    now = w.end;
                    break;
                }
            }
            let chunk = remaining.min(self.chunk_bytes);
            let dur = chunk / self.bandwidth + if first { self.latency } else { 0.0 };
            first = false;
            // an all-reduce may still arrive mid-chunk: that residue is the
            // (much smaller) contention the paper accepts
            contended += overlap(now, now + dur, busy);
            now += dur;
            remaining -= chunk;
        }
        SwapOutcome { finish: now, contended }
    }
}

/// Total overlap of [s, e) with the busy windows.
fn overlap(s: f64, e: f64, busy: &[BusyWindow]) -> f64 {
    busy.iter()
        .map(|w| (e.min(w.end) - s.max(w.start)).max(0.0))
        .sum()
}

/// The window containing time `t`, if any.
fn window_at(t: f64, busy: &[BusyWindow]) -> Option<BusyWindow> {
    busy.iter().copied().find(|w| w.start <= t && t < w.end)
}

#[cfg(test)]
mod tests {
    use super::*;

    const BW: f64 = 26.0e9;

    #[test]
    fn idle_link_swap_is_pure_bandwidth() {
        let link = PcieLink::new(BW, 10e-6, true);
        let out = link.schedule_swap(0.0, 26.0e9, &[]);
        assert!((out.finish - (1.0 + 10e-6)).abs() < 1e-6);
        assert_eq!(out.contended, 0.0);
    }

    #[test]
    fn unchunked_swap_contends_with_allreduce() {
        let link = PcieLink::new(BW, 0.0, false);
        let busy = vec![BusyWindow { start: 0.0, end: 0.5 }];
        let out = link.schedule_swap(0.0, BW, &busy); // 1s transfer
        assert!(out.contended > 0.4, "contended={}", out.contended);
    }

    #[test]
    fn chunked_swap_avoids_contention() {
        let link = PcieLink::new(BW, 0.0, true);
        let busy = vec![
            BusyWindow { start: 0.0, end: 0.5 },
            BusyWindow { start: 1.0, end: 1.5 },
        ];
        let out = link.schedule_swap(0.0, BW, &busy);
        // launches only in idle gaps: contention only from chunks already
        // in flight when a window opens; must be far below the unchunked 1s
        assert!(out.contended < 0.05, "contended={}", out.contended);
        // but it still completes (later than the idle-link 1s)
        assert!(out.finish > 1.0);
    }

    #[test]
    fn chunked_finish_accounts_for_waiting() {
        let link = PcieLink::new(BW, 0.0, true);
        let busy = vec![BusyWindow { start: 0.0, end: 2.0 }];
        let out = link.schedule_swap(0.0, 1024.0, &busy);
        assert!(out.finish >= 2.0); // waited out the all-reduce
        assert_eq!(out.contended, 0.0);
    }

    #[test]
    fn transfer_time_basics() {
        let link = TransferLink::new(BW, 10e-6, true);
        assert_eq!(link.transfer_time(0.0), 0.0);
        assert!((link.transfer_time(BW) - (1.0 + 10e-6)).abs() < 1e-9);
        // disabled link (the two-tier configuration's disk): infinite
        let off = TransferLink::new(0.0, 0.0, false);
        assert_eq!(off.transfer_time(1.0), f64::INFINITY);
    }

    #[test]
    fn disk_link_models_a_slower_pcie() {
        let disk = TransferLink::disk(&crate::config::hardware::DiskSpec::nvme_4tb());
        let pcie = TransferLink::new(BW, 10e-6, true);
        let bytes = 1.0e9;
        assert!(disk.transfer_time(bytes) > pcie.transfer_time(bytes));
        // same scheduling machinery applies
        let out = disk.schedule_swap(0.0, bytes, &[]);
        assert!((out.finish - disk.transfer_time(bytes)).abs() < 1e-9);
    }

    #[test]
    fn zero_bytes_is_noop() {
        let link = PcieLink::new(BW, 10e-6, true);
        let out = link.schedule_swap(3.0, 0.0, &[]);
        assert_eq!(out, SwapOutcome { finish: 3.0, contended: 0.0 });
    }

    #[test]
    fn overlap_math() {
        let busy = vec![
            BusyWindow { start: 1.0, end: 2.0 },
            BusyWindow { start: 3.0, end: 4.0 },
        ];
        assert!((overlap(0.0, 5.0, &busy) - 2.0).abs() < 1e-12);
        assert!((overlap(1.5, 3.5, &busy) - 1.0).abs() < 1e-12);
        assert_eq!(overlap(2.0, 3.0, &busy), 0.0);
    }
}
